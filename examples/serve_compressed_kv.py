"""Continuous-batching serving through the paged FZ KV pool (paper §2.4).

A synthetic trace with more concurrent sequences than the raw slab can hold:
the pool completes it anyway because cold pages tier down to FZ-compressed
containers (freeing their physical slots) and preempted sequences are
compress-parked instead of recomputed. Every request's tokens are checked
against the never-parked whole-cache oracle (``Engine.generate``).

    PYTHONPATH=src python examples/serve_compressed_kv.py            # full
    PYTHONPATH=src python examples/serve_compressed_kv.py --smoke    # CI: tiny
                                     # model, 2-page pool, 8-step trace
    PYTHONPATH=src python examples/serve_compressed_kv.py --smoke --kernels
                                     # CI kernel-parity smoke: same trace
                                     # through the Pallas flash-decode kernel
                                     # (page-native gather) + FZ kernel stages
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import zoo
from repro.serve import Engine, PoolConfig, Request


def build(smoke: bool, kernels: bool = False):
    if smoke:
        cfg = configs.get("glm4-9b", smoke=True)
        pool = PoolConfig(num_pages=2, page_size=8, seq_capacity=32,
                          cold_after=1, eb=1e-4, use_kernels=kernels)
        trace = dict(n_reqs=2, prompt_lens=(8, 8), n_new=8, max_batch=2)
    else:
        cfg = dataclasses.replace(
            configs.get("glm4-9b"),
            arch_id="glm4-mini", n_layers=4, d_model=256, n_heads=8,
            n_kv_heads=2, d_ff=704, vocab=4096, head_dim=32)
        # page-aligned prompts make several lanes open a fresh page on the
        # same step, overflowing the 5-slot slab -> compress-park preemption
        pool = PoolConfig(num_pages=5, page_size=16, seq_capacity=128,
                          cold_after=2, eb=1e-4, use_kernels=kernels)
        trace = dict(n_reqs=6, prompt_lens=(48, 32, 48, 32, 32, 16),
                     n_new=12, max_batch=3)
    return cfg, pool, trace


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model, 2-page pool, 8-step trace (CI)")
    ap.add_argument("--kernels", action="store_true",
                    help="route decode through the Pallas flash-decode kernel "
                         "(page-native gather) and FZ through the kernel "
                         "stages — interpret mode off-TPU")
    args = ap.parse_args()

    cfg, pool_cfg, trace = build(args.smoke, args.kernels)
    model = zoo.build(cfg)
    params = model.init(jax.random.key(0))
    mode = "pallas-kernel paged decode" if args.kernels else "reference decode"
    print(f"decode path: {mode}")
    print(f"serving {cfg.arch_id}: {model.param_count() / 1e6:.1f}M params, "
          f"pool {pool_cfg.num_pages} pages x {pool_cfg.page_size} tokens")

    rng = np.random.default_rng(0)
    reqs = [Request(req_id=i,
                    tokens=rng.integers(0, cfg.vocab, (s,), dtype=np.int32),
                    n_new=trace["n_new"], priority=i % 2)
            for i, s in enumerate(trace["prompt_lens"])]
    pages_demanded = sum(-(-len(r.tokens) // pool_cfg.page_size) +
                         -(-r.n_new // pool_cfg.page_size) for r in reqs)
    print(f"trace demands ~{pages_demanded} pages raw; slab holds "
          f"{pool_cfg.num_pages} — completion requires compressed parking")

    eng = Engine(model, params, pool=pool_cfg)
    outputs, stats, pool = eng.serve(reqs, max_batch=trace["max_batch"])
    assert len(outputs) == len(reqs), "trace did not complete"
    assert stats.preemptions >= 1, "trace never exercised compress-parking"

    slab = pool_cfg.num_pages * pool.slot_bytes
    print(f"\ncompleted {stats.completed} requests in {stats.decode_steps} "
          f"decode steps: {stats.admissions} admissions, "
          f"{stats.preemptions} preemptions (compress-park), "
          f"{stats.resumes} resumes, {stats.tiered_pages} pages tiered cold")
    print(f"pool memory high-water: {stats.high_water_used_bytes / 1e3:.1f} KB "
          f"(raw slab in use + compressed payloads) vs "
          f"{stats.high_water_demand_bytes / 1e3:.1f} KB had all live pages "
          f"stayed raw ({stats.high_water_demand_bytes / max(stats.high_water_used_bytes, 1):.2f}x)"
          f"; preallocated slab {slab / 1e3:.1f} KB")

    # parity vs. the never-parked whole-cache oracle
    agrees = []
    for r in reqs:
        oracle, _ = eng.generate({"tokens": jnp.asarray(r.tokens)[None]}, r.n_new)
        agrees.append(float((np.asarray(oracle[0]) == outputs[r.req_id]).mean()))
    mean_agree = float(np.mean(agrees))
    print(f"decode-token agreement, pooled (parked) vs never-parked oracle "
          f"at eb={pool_cfg.eb:g}: {mean_agree * 100:.1f}% "
          f"(per request: {[f'{a:.2f}' for a in agrees]})")
    print("sample continuation (pooled):", outputs[reqs[0].req_id][:10])
    assert mean_agree >= 0.9, f"parked decode diverged from oracle: {agrees}"


if __name__ == "__main__":
    main()
