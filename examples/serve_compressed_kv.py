"""Serving with FZ-compressed KV-cache parking (paper §2.4 in-memory use case).

Batched prefill -> greedy decode; between steps the KV cache is parked
(compressed in device memory) and resumed, modeling preemption/swap in a
production serving stack.

    PYTHONPATH=src python examples/serve_compressed_kv.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import zoo
from repro.serve import Engine, KVCompressionConfig
from repro.serve.engine import cache_bytes, compressed_cache_bytes


def main():
    cfg = dataclasses.replace(
        configs.get("glm4-9b"),
        arch_id="glm4-mini", n_layers=6, d_model=512, n_heads=8, n_kv_heads=2,
        d_ff=1408, vocab=8192, head_dim=64)
    model = zoo.build(cfg)
    params = model.init(jax.random.key(0))
    print(f"serving {cfg.arch_id}: {model.param_count() / 1e6:.1f}M params")

    rng = np.random.default_rng(0)
    B, S, new_tokens = 4, 512, 16
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S), dtype=np.int32))}

    plain = Engine(model, params)
    toks_plain, cache = plain.generate(batch, new_tokens)

    comp = Engine(model, params,
                  kv_compress=KVCompressionConfig(enabled=True, eb=1e-4, min_leaf_size=4096))
    toks_comp, _ = comp.generate(batch, new_tokens, park_between=True)

    parked = comp.park(cache)
    raw = cache_bytes(cache)
    packed = compressed_cache_bytes(parked)
    agree = float(jnp.mean((toks_plain == toks_comp).astype(jnp.float32)))
    print(f"KV cache: {raw / 1e6:.1f} MB -> {packed / 1e6:.1f} MB "
          f"({raw / packed:.2f}x) at eb=1e-4")
    print(f"decode-token agreement plain vs parked-every-step: {agree * 100:.1f}%")
    print("sample continuation (plain): ", np.asarray(toks_plain[0][:10]))
    print("sample continuation (parked):", np.asarray(toks_comp[0][:10]))


if __name__ == "__main__":
    main()
