"""Prefix-shared continuous batching through the paged FZ KV pool (§2.4).

A seeded trace-driven load (tracegen): Poisson arrivals drawing from a
template pool, so most requests share a long prompt prefix — the production
shape the radix page table is built for. The pool completes a trace whose
raw demand exceeds the slab because (a) matched prefixes are *mapped*, not
re-prefilled (one physical page serves every reader; writes copy-on-write),
(b) cold pages tier down to entropy-coded FZ byte containers
(``PoolConfig.cold_entropy``, docs/CONTAINER_FORMAT.md), freeing their
slots, and (c) preempted sequences are compress-parked instead of
recomputed.
Every request's tokens are checked against the never-parked whole-cache
oracle (``Engine.generate``).

    PYTHONPATH=src python examples/serve_compressed_kv.py            # full
    PYTHONPATH=src python examples/serve_compressed_kv.py --smoke    # CI: tiny
                                     # model, 3-page pool, 4-request trace
    PYTHONPATH=src python examples/serve_compressed_kv.py --smoke --kernels
                                     # CI kernel-parity smoke: same trace
                                     # through the Pallas flash-decode kernel
                                     # (page-native gather) + FZ kernel stages
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs, obs
from repro.models import zoo
from repro.obs import cli as obs_cli
from repro.serve import Engine, PoolConfig
from repro.serve.kvpool import TraceGenConfig, generate, latency_summary


def build(smoke: bool, kernels: bool = False):
    if smoke:
        cfg = configs.get("glm4-9b", smoke=True)
        pool = PoolConfig(num_pages=3, page_size=8, seq_capacity=32,
                          cold_after=1, eb=1e-4, use_kernels=kernels,
                          cold_entropy=True)
        tg = TraceGenConfig(seed=1, n_requests=4, vocab=cfg.vocab,
                            arrival_rate=2.0, n_templates=1,
                            template_len=(12, 12), template_reuse=0.9,
                            suffix_len=(2, 4), n_new=(4, 6),
                            priorities=(0, 1), ttft_slo=6, itl_slo=4)
        max_batch = 2
    else:
        cfg = dataclasses.replace(
            configs.get("glm4-9b"),
            arch_id="glm4-mini", n_layers=4, d_model=256, n_heads=8,
            n_kv_heads=2, d_ff=704, vocab=4096, head_dim=32)
        # 4 slots against ~37 pages of raw demand: tight enough that running
        # tails protect most of the slab, so admission pressure has to
        # compress-park victims, not just tier cold pages
        pool = PoolConfig(num_pages=4, page_size=16, seq_capacity=128,
                          cold_after=2, eb=1e-4, use_kernels=kernels,
                          max_cached_pages=6, cold_entropy=True)
        tg = TraceGenConfig(seed=4, n_requests=8, vocab=cfg.vocab,
                            arrival_rate=1.0, n_templates=2,
                            template_len=(32, 48), template_reuse=0.75,
                            suffix_len=(4, 8), n_new=(8, 12),
                            priorities=(0, 1), ttft_slo=10, itl_slo=6)
        max_batch = 3
    return cfg, pool, tg, max_batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model, 3-page pool, 4-request trace (CI)")
    ap.add_argument("--kernels", action="store_true",
                    help="route decode through the Pallas flash-decode kernel "
                         "(page-native gather) and FZ through the kernel "
                         "stages — interpret mode off-TPU")
    obs_cli.add_args(ap)
    args = ap.parse_args()
    obs_cli.start(args)

    cfg, pool_cfg, tg, max_batch = build(args.smoke, args.kernels)
    model = zoo.build(cfg)
    params = model.init(jax.random.key(0))
    mode = "pallas-kernel paged decode" if args.kernels else "reference decode"
    print(f"decode path: {mode}")
    print(f"serving {cfg.arch_id}: {model.param_count() / 1e6:.1f}M params, "
          f"pool {pool_cfg.num_pages} pages x {pool_cfg.page_size} tokens, "
          f"prefix_mode={pool_cfg.prefix_mode}")

    reqs = generate(tg)
    pages_demanded = sum(-(-len(r.tokens) // pool_cfg.page_size) +
                         -(-r.n_new // pool_cfg.page_size) for r in reqs)
    prompt_tokens = sum(len(r.tokens) for r in reqs)
    print(f"trace: {len(reqs)} requests / {prompt_tokens} prompt tokens from "
          f"{tg.n_templates} template(s) at {tg.template_reuse:.0%} reuse, "
          f"Poisson rate {tg.arrival_rate}/step; demands ~{pages_demanded} "
          f"pages raw vs a {pool_cfg.num_pages}-slot slab")

    eng = Engine(model, params, pool=pool_cfg)
    outputs, stats, pool = eng.serve(reqs, max_batch=max_batch)
    assert len(outputs) == len(reqs), "trace did not complete"
    assert stats.preemptions >= 1, "trace never exercised compress-parking"
    assert stats.prefix_hits >= 2, "trace never exercised prefix sharing"
    assert stats.cow_promotions >= 1, "trace never exercised copy-on-write"

    slab = pool_cfg.num_pages * pool.slot_bytes
    print(f"\ncompleted {stats.completed} requests in {stats.decode_steps} "
          f"decode steps: {stats.admissions} admissions, "
          f"{stats.preemptions} preemptions (compress-park), "
          f"{stats.resumes} resumes, {stats.tiered_pages} pages tiered cold")
    print(f"prefix sharing: {stats.prefix_hits}/{stats.admissions} admissions "
          f"hit the radix cache; {stats.prefill_tokens} prompt tokens "
          f"prefilled, {stats.prefill_tokens_saved} served from shared pages; "
          f"{stats.cow_promotions} copy-on-write forks, "
          f"{stats.shared_cold_reads_deduped} shared cold reads deduped "
          f"({stats.pool_decompressions} decompressions in "
          f"{stats.decompress_dispatches} batched dispatches)")
    lat = latency_summary(stats, tg)
    print(f"latency (steps): ttft p50/p99 {lat['ttft_p50']:.0f}/"
          f"{lat['ttft_p99']:.1f}, itl p50/p99 {lat['itl_p50']:.0f}/"
          f"{lat['itl_p99']:.1f}; SLO attainment ttft "
          f"{lat['ttft_slo_attained']:.0%}, itl {lat['itl_slo_attained']:.0%}")
    print(f"pool memory high-water: {stats.high_water_used_bytes / 1e3:.1f} KB "
          f"(raw slab in use + compressed payloads) vs "
          f"{stats.high_water_logical_bytes / 1e3:.1f} KB had every reader "
          f"held private raw pages "
          f"({stats.high_water_logical_bytes / max(stats.high_water_used_bytes, 1):.2f}x)"
          f"; preallocated slab {slab / 1e3:.1f} KB")

    # parity vs. the never-parked whole-cache oracle
    agrees = []
    for r in reqs:
        oracle, _ = eng.generate({"tokens": jnp.asarray(r.tokens)[None]}, r.n_new)
        agrees.append(float((np.asarray(oracle[0]) == outputs[r.req_id]).mean()))
    mean_agree = float(np.mean(agrees))
    print(f"decode-token agreement, pooled (shared + parked) vs never-parked "
          f"oracle at eb={pool_cfg.eb:g}: {mean_agree * 100:.1f}% "
          f"(per request: {[f'{a:.2f}' for a in agrees]})")
    print("sample continuation (pooled):", outputs[reqs[0].req_id][:10])
    assert mean_agree >= 0.9, f"shared decode diverged from oracle: {agrees}"

    # telemetry cross-checks: the registry's eager FZ dispatch counts must
    # agree exactly with the pool's own accounting, and the run must finish
    # with zero error-bound sentinel violations
    snap = obs.snapshot()
    fz_decomp = sum(v for k, v in snap["counters"].items()
                    if k.startswith("fz_dispatches{op=decompress,"))
    assert fz_decomp == stats.decompress_dispatches, (
        f"fz decompress dispatches {fz_decomp} != pool "
        f"{stats.decompress_dispatches}")
    assert not obs.violations(), f"sentinel violations: {obs.violations()}"
    # entropy cold tier: parked pages were serialized through to_bytes with
    # the probe-gated entropy stage — the counters prove the tier ran, and
    # the zero-violations assert above covered its bit-exact decode path
    ent_ops = {k: v for k, v in snap["counters"].items()
               if k.startswith("entropy_stage{")}
    assert any("tier=kv_cold_entropy" in k for k in ent_ops), \
        f"entropy cold tier never exercised: {sorted(ent_ops)}"
    n_sel = sum(v for k, v in ent_ops.items()
                if "op=encode" in k and "selected=true" in k)
    n_skip = sum(v for k, v in ent_ops.items()
                 if "op=encode" in k and "selected=false" in k)
    print(f"telemetry: {fz_decomp} fz decompress dispatches == pool "
          f"accounting; entropy stage on {n_sel} parked containers "
          f"({n_skip} probe-skipped); 0 sentinel violations")
    if args.kernels:
        # tuned dispatch: with use_kernels on, the pool's kernel_mode="auto"
        # FZ entries and the engine's decode-attention choice must have
        # resolved through the repro.tune registry (cached winner or the
        # backend-aware fallback) — never a hardcoded path
        tuned = {k: v for k, v in snap["counters"].items()
                 if k.startswith(("tune_cache{", "tune_selected{"))
                 and "site=dispatch" in k}
        assert tuned, "kernels smoke never dispatched through repro.tune"
        assert any(k.startswith("tune_selected{") and "op=decode_attention" in k
                   for k in tuned), \
            "decode attention never resolved through repro.tune"
        assert any(k.startswith("tune_selected{") and "op=fz." in k
                   for k in tuned), \
            "FZ kernel_mode=auto never resolved through repro.tune"
        print(f"tuned dispatch: {sum(tuned.values())} repro.tune "
              f"resolutions across {len(tuned)} counter keys")
    obs_cli.finish(args, metadata={"arch": cfg.arch_id,
                                   "mode": "serve-prefix-shared"})


if __name__ == "__main__":
    main()
