"""Quickstart: error-bounded lossy compression of a scientific field.

    PYTHONPATH=src python examples/quickstart.py

The same flow (at smaller shapes) is the README.md quickstart snippet, which
CI's docs check executes on every PR (repro.testing.docsnippets).
"""
import jax.numpy as jnp

from repro.core import fz, metrics
from repro.data import make_field


def main():
    field = jnp.asarray(make_field("turbulent", (128, 128, 64), seed=0))
    raw_mb = field.size * field.dtype.itemsize / 1e6   # dtype-correct bytes
    print(f"field: {field.shape} {field.dtype}, {raw_mb:.1f} MB")

    for eb in (1e-2, 1e-3, 1e-4):
        cfg = fz.FZConfig(eb=eb, eb_mode="rel")        # paper-style relative bound
        rec, comp = fz.roundtrip(field, cfg)
        print(f"eb={eb:g}: "
              f"CR={float(comp.compression_ratio()):6.2f}x  "
              f"PSNR={float(metrics.psnr(field, rec)):6.2f} dB  "
              f"max|err|={float(metrics.max_abs_err(field, rec)):.3e} "
              f"(bound {float(comp.eb_abs):.3e})")

    # source-dtype accounting: a bfloat16 input is charged 2 bytes/value
    # (comp.raw_bytes() == n * 2, not the float32-inflated n * 4), so the
    # printed ratio is honest for half-precision slabs like KV caches
    bf = field.astype(jnp.bfloat16)
    cfg = fz.FZConfig(eb=1e-3, eb_mode="rel")
    comp = fz.compress(bf, cfg)
    assert int(comp.raw_bytes()) == bf.size * 2
    print(f"bfloat16 source: raw {int(comp.raw_bytes()) / 1e6:.1f} MB, "
          f"CR={float(comp.compression_ratio()):.2f}x (dtype-correct)")

    # cold tier: serialize to the versioned byte container, optionally with
    # the second-stage entropy coder (docs/CONTAINER_FORMAT.md); decode
    # routes on the header flag and reconstruction is bit-exact
    cfg = fz.FZConfig(eb=1e-3, eb_mode="rel")
    comp = fz.compress(field, cfg)
    plain = fz.to_bytes(comp, cfg, entropy=False)
    cold = fz.to_bytes(comp, cfg, entropy="auto")
    assert jnp.array_equal(fz.decompress_bytes(cold), fz.decompress(comp, cfg))
    print(f"cold tier: plain {len(plain) / 1e6:.2f} MB -> "
          f"entropy {len(cold) / 1e6:.2f} MB "
          f"(x{len(plain) / len(cold):.2f} on top of FZ)")

    # kernel path (Pallas, interpret-mode on CPU; Mosaic on TPU)
    cfg = fz.FZConfig(eb=1e-3, use_kernels=True, exact_outliers=False)
    rec, comp = fz.roundtrip(field, cfg)
    print(f"pallas-kernel path: CR={float(comp.compression_ratio()):.2f}x "
          f"(bit-identical to the reference path)")


if __name__ == "__main__":
    main()
