"""Quickstart: error-bounded lossy compression of a scientific field.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.core import fz, metrics
from repro.data import make_field


def main():
    field = jnp.asarray(make_field("turbulent", (128, 128, 64), seed=0))
    print(f"field: {field.shape} float32, {field.size * 4 / 1e6:.1f} MB")

    for eb in (1e-2, 1e-3, 1e-4):
        cfg = fz.FZConfig(eb=eb, eb_mode="rel")        # paper-style relative bound
        rec, comp = fz.roundtrip(field, cfg)
        print(f"eb=1e{int(jnp.log10(eb))}: "
              f"CR={float(comp.compression_ratio()):6.2f}x  "
              f"PSNR={float(metrics.psnr(field, rec)):6.2f} dB  "
              f"max|err|={float(metrics.max_abs_err(field, rec)):.3e} "
              f"(bound {float(comp.eb_abs):.3e})")

    # kernel path (Pallas, interpret-mode on CPU; Mosaic on TPU)
    cfg = fz.FZConfig(eb=1e-3, use_kernels=True, exact_outliers=False)
    rec, comp = fz.roundtrip(field, cfg)
    print(f"pallas-kernel path: CR={float(comp.compression_ratio()):.2f}x "
          f"(bit-identical to the reference path)")


if __name__ == "__main__":
    main()
