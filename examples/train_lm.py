"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

Exercises the full production path at laptop scale: sharded step (1-device
mesh here; any (pod, data, model) on a fleet), AdamW + warmup-cosine,
checkpoint/restart (kill it mid-run and re-launch: it resumes), straggler
watchdog, and FZ-compressed checkpoints.

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse
import dataclasses

import jax

from repro import configs
from repro.configs.base import ShapeConfig
from repro.data.tokens import TokenStream
from repro.launch.mesh import make_local_mesh
from repro.models import zoo
from repro.train import TrainConfig, Trainer


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=300)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=256)
    p.add_argument("--ckpt-dir", default="/tmp/fzjax_train_lm")
    args = p.parse_args()

    # ~100M params: yi-6b family scaled down (keeps GQA + SwiGLU structure)
    cfg = dataclasses.replace(
        configs.get("yi-6b"),
        arch_id="yi-100m", n_layers=10, d_model=640, n_heads=10, n_kv_heads=2,
        d_ff=1792, vocab=16_384, head_dim=64)
    model = zoo.build(cfg)
    print(f"arch={cfg.arch_id}  params={model.param_count() / 1e6:.1f}M")

    mesh = make_local_mesh()
    shape = ShapeConfig("train_local", args.seq, args.batch, "train")
    stream = TokenStream(vocab_size=cfg.vocab, seq_len=args.seq,
                         global_batch=args.batch, seed=0)
    trainer = Trainer(model, shape, mesh,
                      TrainConfig(peak_lr=3e-4, warmup_steps=30, total_steps=args.steps),
                      stream=stream, ckpt_dir=args.ckpt_dir, ckpt_every=50,
                      ckpt_codec="fz")
    if trainer.step:
        print(f"resumed from checkpoint at step {trainer.step}")

    hist = trainer.run(args.steps - trainer.step)
    for m in hist[:: max(len(hist) // 12, 1)]:
        print(f"step {m['step']:4d}  loss {m['loss']:.4f}  lr {m['lr']:.2e}  "
              f"{m['seconds']:.2f}s" + ("  [straggler]" if m["straggler"] else ""))
    print(f"final loss: {hist[-1]['loss']:.4f} (from {hist[0]['loss']:.4f})")
    if trainer.watchdog.events:
        print("straggler events:", [(e.step, round(e.seconds, 2)) for e in trainer.watchdog.events])


if __name__ == "__main__":
    main()
