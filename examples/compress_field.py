"""The paper's core use case as a CLI: compress scientific fields and report
the paper's metrics (CR, bitrate, PSNR, SSIM) across error bounds.

    PYTHONPATH=src python examples/compress_field.py --kind wavefront
"""
import argparse

import jax.numpy as jnp

from repro.core import fz, metrics
from repro.data import FIELD_KINDS, make_field


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--kind", choices=FIELD_KINDS, default="wavefront")
    p.add_argument("--shape", type=int, nargs=3, default=(128, 128, 64))
    p.add_argument("--code-mode", choices=["sign_mag", "zigzag"], default="sign_mag")
    args = p.parse_args()

    f = jnp.asarray(make_field(args.kind, tuple(args.shape), seed=0))
    raw_mb = f.size * 4 / 1e6
    print(f"{args.kind} field {tuple(f.shape)} = {raw_mb:.1f} MB, "
          f"codes={args.code_mode}")
    print("eb_rel,CR,bitrate,PSNR_dB,SSIM(mid-slice),max_err,bound")
    for eb in (1e-2, 5e-3, 1e-3, 5e-4, 1e-4):
        cfg = fz.FZConfig(eb=eb, code_mode=args.code_mode)
        rec, c = fz.roundtrip(f, cfg)
        mid = f.shape[0] // 2
        ssim = float(metrics.ssim2d(f[mid], rec[mid])) if f.ndim == 3 else float("nan")
        cr = float(c.compression_ratio())
        br = float(metrics.bitrate(c.raw_bytes(), c.used_bytes(), f.dtype))
        print(f"{eb:.0e},{cr:.2f},{br:.2f},"
              f"{float(metrics.psnr(f, rec)):.2f},{ssim:.4f},"
              f"{float(metrics.max_abs_err(f, rec)):.3e},{float(c.eb_abs):.3e}")


if __name__ == "__main__":
    main()
