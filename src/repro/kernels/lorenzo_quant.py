"""Fused pre-quantization + Lorenzo + code-conversion Pallas kernel (paper §3.2).

One pass: float data -> saturating sign-magnitude u16 codes, branch-free
(the paper's "pred-quant-v2": no radius shift, no outlier path, fewer
branches -> no warp divergence; on TPU this becomes select-only VPU code).

Halo handling (TPU adaptation): cuSZ's CUDA kernel re-quantizes chunk-border
elements redundantly per thread block. Here each grid step owns a band of
leading-axis rows/planes and receives a 1-row halo *view of the same input
array* via a second BlockSpec (block shape 1 along the banded axis makes the
index map element-granular), so no shifted copies are materialized in HBM —
traffic is n + n/band vs. the GPU version's redundant boundary recompute.

Banding: the band covers all trailing axes, so all trailing-axis differences
are band-internal; only the leading-axis difference needs the halo. The
first band masks its (clamped) halo to zero via pl.program_id.

Kernel-path limitation (faithful to the paper): no exact-outlier side
channel. FZConfig(use_kernels=True, exact_outliers=True) routes quantization
through the reference path instead (see kernels/ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.analysis.kernelspec import (BlockDecl, KernelSpec, register_spec)

MAX_MAG = 0x7FFF
MAX_BAND = 8                  # leading-axis rows/planes per grid step
VMEM_BAND_BUDGET = 4 << 20    # bytes of band input in VMEM (headroom cap)


def band_for(trailing_elems: int, *, itemsize: int = 4) -> int:
    """Rows/planes per band so the band's *input* stays within the VMEM
    budget (large 3D fields: a single 1024x1024 f32 plane is 4 MiB).

    Dtype-aware: the budget divides by the input's real ``itemsize``, so a
    bf16 input (2 B/elem — kept native in HBM/VMEM, cast to f32 only inside
    the kernel body) gets twice the band an f32 input does instead of
    half-utilized bands. The resource analyzer (repro.analysis.resources)
    cross-checks this helper against its own footprint model.
    """
    return max(1, min(MAX_BAND,
                      VMEM_BAND_BUDGET // max(trailing_elems * itemsize, 1)))


def _prequant(x: jax.Array, two_eb: jax.Array) -> jax.Array:
    # divide (not multiply-by-reciprocal): bit-identical to the reference;
    # reciprocal multiply flips rint at ties and breaks exactness. The f32
    # cast makes sub-f32 inputs (bf16 bands kept native for VMEM headroom)
    # quantize exactly as the reference's pre-cast data: widening is exact.
    return jnp.rint(x.astype(jnp.float32) / two_eb).astype(jnp.int32)


def _to_code(d: jax.Array, code_mode: str) -> jax.Array:
    if code_mode == "sign_mag":
        mag = jnp.minimum(jnp.abs(d), MAX_MAG)
        return mag.astype(jnp.uint16) | jnp.where(d < 0, jnp.uint16(0x8000), jnp.uint16(0))
    # zigzag
    z = jnp.minimum((d << 1) ^ (d >> 31), 0xFFFF)
    return z.astype(jnp.uint16)


def _shift_prepend(q: jax.Array, first, axis: int) -> jax.Array:
    """q shifted by one along ``axis`` with ``first`` as the leading slice."""
    tail = jax.lax.slice_in_dim(q, 0, q.shape[axis] - 1, axis=axis)
    return jax.lax.concatenate([first, tail], dimension=axis)


def band_codes(x_band: jax.Array, halo: jax.Array, two_eb: jax.Array, *,
               ndim: int, code_mode: str, is_first) -> jax.Array:
    """Kernel-body helper: one band of input + 1-row halo -> u16 Lorenzo codes.

    Shared between the standalone quantization kernel below and the fused
    compress megakernel (kernels/fused_compress.py) so both paths stay
    bit-identical by construction. ``is_first`` masks the (clamped) halo of
    the first band to the zero boundary condition.
    """
    q = _prequant(x_band, two_eb)
    h = _prequant(halo, two_eb)
    h = jnp.where(is_first, jnp.zeros_like(h), h)
    if ndim == 1:
        # flattened-1D layout (rows, C): continuous diff across row ends.
        # previous element of col 0 = last col of previous row; for the
        # band's first row that is the halo row's last element.
        prev_last = _shift_prepend(q[:, -1:], h[:, -1:], axis=0)  # (band, 1)
        d = q - _shift_prepend(q, prev_last, axis=1)
    else:
        # leading-axis diff uses the halo slice; trailing axes internal.
        d = q - _shift_prepend(q, h, axis=0)
        for ax in range(1, ndim):
            zero = jnp.zeros_like(jax.lax.slice_in_dim(d, 0, 1, axis=ax))
            d = d - _shift_prepend(d, zero, axis=ax)
    return _to_code(d, code_mode)


def _make_kernel(ndim: int, code_mode: str):
    def kernel(x_ref, halo_ref, eb_ref, out_ref):
        out_ref[...] = band_codes(x_ref[...], halo_ref[...], 2.0 * eb_ref[0, 0],
                                  ndim=ndim, code_mode=code_mode,
                                  is_first=pl.program_id(0) == 0)
    return kernel


@functools.partial(jax.jit, static_argnames=("code_mode", "interpret"))
def lorenzo_quant(data: jax.Array, eb: jax.Array, *, code_mode: str = "sign_mag",
                  interpret: bool = False) -> jax.Array:
    """float (1-3)D -> u16 codes, identical to ref.lorenzo_quant_ref.

    1D inputs are reshaped to (rows, 1024) with the cross-row boundary handled
    inside the kernel, so the difference stream matches the flat reference.
    """
    shape = data.shape
    ndim = data.ndim
    if ndim > 3:
        raise ValueError(f"Lorenzo kernel supports 1-3D, got {ndim}D")
    # sub-f32 floats stay native (halved HBM traffic, doubled bands); the
    # exact widening cast to f32 happens inside the kernel (_prequant)
    x = data if (jnp.issubdtype(data.dtype, jnp.floating)
                 and data.dtype.itemsize <= 4) else data.astype(jnp.float32)
    if ndim == 1:
        c = 1024
        n = x.size
        rows = (n + c - 1) // c
        x = jnp.pad(x, (0, rows * c - n)).reshape(rows, c)
        kern_nd = 1
    else:
        kern_nd = ndim
    lead = x.shape[0]
    trailing_elems = 1
    for s in x.shape[1:]:
        trailing_elems *= s
    band = band_for(trailing_elems, itemsize=x.dtype.itemsize)
    bands = (lead + band - 1) // band
    pad_lead = bands * band - lead
    x = jnp.pad(x, [(0, pad_lead)] + [(0, 0)] * (x.ndim - 1))
    trailing = x.shape[1:]

    band_block = (band, *trailing)
    halo_block = (1, *trailing)
    zeros_trail = (0,) * len(trailing)

    def band_index(i):
        return (i, *zeros_trail)

    def halo_index(i):
        return (jnp.maximum(i * band - 1, 0), *zeros_trail)

    eb_arr = jnp.reshape(eb.astype(jnp.float32), (1, 1))
    codes = pl.pallas_call(
        _make_kernel(kern_nd, code_mode),
        grid=(bands,),
        in_specs=[pl.BlockSpec(band_block, band_index),
                  pl.BlockSpec(halo_block, halo_index),
                  pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec(band_block, band_index),
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.uint16),
        # bands are independent (the halo is a read-only input view, no
        # cross-step scratch): declared parallel deliberately
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x, x, eb_arr)

    if ndim == 1:
        return codes.reshape(-1)[: shape[0]]
    return codes[: shape[0]]


# ---------------------------------------------------------------------------
# Static-analysis declaration (repro.analysis): mirrors the launch above
# ---------------------------------------------------------------------------

@register_spec("lorenzo_quant")
def kernel_spec(shape: tuple[int, ...], dtype: str = "float32") -> KernelSpec:
    """KernelSpec for ``lorenzo_quant`` at one (shape, dtype) point."""
    itemsize = {"float32": 4, "bfloat16": 2, "float16": 2}[dtype]
    n = 1
    for s in shape:
        n *= s
    if len(shape) == 1:
        lead, trailing = -(-n // 1024), (1024,)
    else:
        lead, trailing = shape[0], tuple(shape[1:])
    t_elems = 1
    for s in trailing:
        t_elems *= s
    band = band_for(t_elems, itemsize=itemsize)
    bands = -(-lead // band)
    band_block = (band, *trailing)
    zeros_trail = (0,) * len(trailing)
    return KernelSpec(
        name="lorenzo_quant", module=__name__, grid=(bands,),
        in_blocks=(
            BlockDecl("x", band_block, dtype,
                      index_map=lambda i: (i, *zeros_trail)),
            BlockDecl("halo", (1, *trailing), dtype,
                      index_map=lambda i: (max(i * band - 1, 0),
                                           *zeros_trail)),
            BlockDecl("eb", (1, 1), "float32", index_map=lambda i: (0, 0)),
        ),
        out_blocks=(
            BlockDecl("codes", band_block, "uint16",
                      index_map=lambda i: (i, *zeros_trail)),
        ),
        dimension_semantics=("parallel",),
        kernel_fn=_make_kernel(1 if len(shape) == 1 else len(shape),
                               "sign_mag"),
        point=f"shape={shape} dtype={dtype} band={band}")
