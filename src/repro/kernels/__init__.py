"""Pallas TPU kernels for the paper's compute hot spots (+ oracles).

kernel modules (pl.pallas_call + BlockSpec VMEM tiling):
    lorenzo_quant    -- fused pre-quantization + Lorenzo + sign-mag codes
    bitshuffle_flag  -- fused bitshuffle + zero-block flags (paper's fusion)
    fused_compress   -- single-launch compress megakernel: quant + Lorenzo +
                        shuffle + flags + in-kernel phase-2 compaction; the
                        code stream never touches HBM
    fused_decode     -- single-launch decompress megakernel: flag unpack +
                        offset-gather decode + unshuffle + inverse Lorenzo
    flash_decode     -- block-parallel KV-tile decode attention (contiguous
                        + paged layouts; serving hot path)
ops.py -- jit wrappers (interpret-mode fallback off-TPU); ref.py -- oracles.
"""
from . import (bitshuffle_flag, flash_decode, fused_compress,  # noqa: F401
               fused_decode, lorenzo_quant, ops, ref)
