"""Pallas TPU kernels for the paper's compute hot spots (+ oracles).

kernel modules (pl.pallas_call + BlockSpec VMEM tiling):
    lorenzo_quant    -- fused pre-quantization + Lorenzo + sign-mag codes
    bitshuffle_flag  -- fused bitshuffle + zero-block flags (paper's fusion)
    flash_decode     -- block-parallel KV-tile decode attention (contiguous
                        + paged layouts; serving hot path)
ops.py -- jit wrappers (interpret-mode fallback off-TPU); ref.py -- oracles.
"""
from . import bitshuffle_flag, flash_decode, lorenzo_quant, ops, ref  # noqa: F401
