"""Pallas TPU kernels for the paper's compute hot spots (+ oracles).

kernel modules (pl.pallas_call + BlockSpec VMEM tiling):
    lorenzo_quant    -- fused pre-quantization + Lorenzo + sign-mag codes
    bitshuffle_flag  -- fused bitshuffle + zero-block flags (paper's fusion)
    fused_compress   -- single-launch compress megakernel: quant + Lorenzo +
                        shuffle + flags + in-kernel phase-2 compaction; the
                        code stream never touches HBM
    fused_decode     -- single-launch decompress megakernel: flag unpack +
                        offset-gather decode + unshuffle + inverse Lorenzo
    flash_decode     -- block-parallel KV-tile decode attention (contiguous
                        + paged layouts; serving hot path)
ops.py -- jit wrappers (interpret-mode fallback off-TPU); ref.py -- oracles.

Static-analysis contract: every ``pl.pallas_call`` site in these modules
registers a :class:`~repro.analysis.kernelspec.KernelSpec` builder
(``@register_spec(name)``) next to the launch it mirrors — same grid, block
shapes/dtypes/index maps, scratch declarations, and
``dimension_semantics``, built with the same geometry helpers the wrapper
uses (``plan_stream``, ``band_for``, the module TILE constants) so the spec
cannot drift silently. ``repro.analysis`` evaluates the registered specs
over the shipped config space (``python -m repro.analysis --check``; the
``scripts/ci.sh analyze`` tier): VMEM/SMEM budgets, lane fill, and
carry-vs-semantics soundness. A new kernel, or any change to a launch's
geometry, must update its builder in the same commit — the analyze tier's
committed baseline (``analysis/baseline.json``) will flag the drift.
"""
from . import (bitshuffle_flag, flash_decode, fused_compress,  # noqa: F401
               fused_decode, lorenzo_quant, ops, ref)
