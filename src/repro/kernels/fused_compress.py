"""Single-launch fused FZ compress megakernel (paper §3.5 taken to its limit).

One ``pallas_call`` runs the ENTIRE compression pipeline — pre-quantization +
Lorenzo (with the 1-row halo BlockSpec from kernels/lorenzo_quant) +
bitshuffle + zero-block flagging + phase-2 compaction — so the u16 code
stream and the shuffled word stream live and die in VMEM. The staged kernel
path (lorenzo_quant, then bitshuffle_flag, then an XLA ``cumsum``/``nonzero``/
``take`` epilogue) round-trips both streams through HBM (~4n extra bytes on
an n-byte input); here HBM sees only the float input and the container
outputs.

Grid-band reconciliation: Lorenzo wants leading-axis bands (all trailing-axis
differences band-internal, one halo row/plane for the leading axis) while the
shuffle wants whole TILE=4096-code tiles. A band of ``band * trailing`` codes
is generally tile-misaligned, so the kernel exploits the TPU grid's
*sequential* execution: a VMEM scratch buffer carries the < TILE leftover
codes of each step into the next (right-aligned, so every concatenation point
is static), and only whole tiles are shuffled per step. Steps beyond the last
band (when the zero-padded stream outruns ``bands * band * trailing``) reuse
the clamped final band and mask everything to the zero pad.

Phase-2 compaction (the decoupled-lookback analogue): the running payload
offset rides in SMEM scratch across sequential grid steps; each step computes
its blocks' global offsets as ``smem_offset + local exclusive cumsum`` and
scatters surviving 16-byte blocks straight into the payload output (row
``capacity`` is a write-off trash slot for beyond-capacity blocks, sliced off
by the wrapper). ``jnp.nonzero`` and the full-stream materialization are gone.

TPU notes: the sequential carry requires ``dimension_semantics=("arbitrary",)``
(set below; interpret mode ignores it). The in-kernel scatter/gather on the
payload ref and the element-granular dynamic slice of the stream buffer are
interpreter-validated on CPU; Mosaic lowering of those two ops (plus the
VMEM residency of a capacity-sized payload) is the open hillclimb item
tracked in ROADMAP.md — production shapes (pages, gradient leaves) are
lane-aligned, the adversarial odd shapes of the property suite are
interpret-only either way.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.analysis.kernelspec import (BlockDecl, KernelSpec, ScratchDecl,
                                       register_spec)
from . import bitshuffle_flag as _bsf
from . import lorenzo_quant as _lq

TILE = _bsf.TILE                                  # 4096 codes per shuffle tile
GROUP = _bsf.GROUP                                # 16
GROUPS_PER_TILE = _bsf.GROUPS_PER_TILE            # 256
BLOCK_WORDS = _bsf.BLOCK_WORDS                    # 8 u16 words per zero block
BLOCKS_PER_TILE = _bsf.BLOCKS_PER_TILE            # 512
FLAG_WORDS_PER_TILE = BLOCKS_PER_TILE // 32       # 16 packed u32 per tile
ROW_1D = 1024                                     # flattened-1D row width


@dataclasses.dataclass(frozen=True)
class StreamPlan:
    """Static geometry reconciling Lorenzo bands with TILE-aligned code tiles.

    Shared by the compress and decompress megakernels so both walk the code
    stream in exactly the same band order (and therefore agree on where every
    band's codes sit in the tiled stream).
    """
    shape: tuple                  # original array shape
    kern_nd: int                  # dims the kernel sees (1 == rows x ROW_1D)
    lead: int                     # leading-axis length of the kernel view
    trailing: tuple               # trailing axes of the kernel view
    band: int                     # leading rows/planes per grid step
    bands: int                    # ceil(lead / band)
    m: int                        # codes produced per grid step
    n: int                        # real elements
    padded_n: int                 # code-stream length (TILE multiple)
    total_tiles: int              # padded_n // TILE

    @property
    def wmax_compress(self) -> int:
        """Most whole tiles one compress step can complete (carry < TILE)."""
        return (TILE - 1 + self.m) // TILE

    @property
    def wmax_decode(self) -> int:
        """Most whole tiles one decode step may need to open."""
        return (self.m + TILE - 1) // TILE

    @property
    def flag_words(self) -> int:
        return self.total_tiles * FLAG_WORDS_PER_TILE


def _fused_band(trailing_elems: int, *, itemsize: int = 4) -> int:
    """Band sizing for the fused kernels: at least ~2 tiles of codes per step
    (so tiny trailing axes don't degenerate into thousands of carry-only
    steps) but still within the per-band VMEM budget for wide planes.

    ``itemsize`` is the band input's element size, mirroring
    ``lorenzo_quant.band_for``'s dtype awareness. The fused wrappers cast
    to f32 before the launch today (the StreamPlan must agree between the
    compress and decompress megakernels, and decode's band output is always
    f32), so they plan at the default itemsize=4; the parameter keeps the
    budget math honest for the analyzer and for a future native-bf16 plan.
    """
    budget_rows = max(1, _lq.VMEM_BAND_BUDGET // (itemsize * trailing_elems))
    want = max(_lq.MAX_BAND, -(-2 * TILE // trailing_elems))
    return max(1, min(budget_rows, want))


def plan_stream(shape: tuple[int, ...]) -> StreamPlan:
    ndim = len(shape)
    if not 1 <= ndim <= 3:
        raise ValueError(f"fused FZ kernels support 1-3D, got {ndim}D")
    n = 1
    for s in shape:
        n *= s
    if ndim == 1:
        lead, trailing, kern_nd = -(-n // ROW_1D), (ROW_1D,), 1
    else:
        lead, trailing, kern_nd = shape[0], tuple(shape[1:]), ndim
    t_elems = 1
    for s in trailing:
        t_elems *= s
    band = _fused_band(t_elems)
    bands = -(-lead // band)
    padded_n = -(-n // TILE) * TILE
    return StreamPlan(shape=tuple(shape), kern_nd=kern_nd, lead=lead,
                      trailing=trailing, band=band, bands=bands,
                      m=band * t_elems, n=n, padded_n=padded_n,
                      total_tiles=padded_n // TILE)


def _pad_to_kernel_view(data: jax.Array, p: StreamPlan) -> jax.Array:
    """float32 (1-3)D array -> padded (bands*band, *trailing) kernel view."""
    x = data.astype(jnp.float32)
    if p.kern_nd == 1:
        x = jnp.pad(x.reshape(-1), (0, p.lead * ROW_1D - p.n)).reshape(p.lead, ROW_1D)
    pad_lead = p.bands * p.band - p.lead
    return jnp.pad(x, [(0, pad_lead)] + [(0, 0)] * (x.ndim - 1))


def _shuffle_tiles(proc: jax.Array, wmax: int):
    """(wmax*TILE,) u16 codes -> (shuffled (wmax, TILE), blocks, flags)."""
    groups = proc.reshape(wmax * GROUPS_PER_TILE, GROUP)
    t = _bsf.transpose16_inkernel(groups).reshape(wmax, GROUPS_PER_TILE, GROUP)
    shuffled = jnp.swapaxes(t, 1, 2).reshape(wmax, TILE)
    blocks = shuffled.reshape(wmax * BLOCKS_PER_TILE, BLOCK_WORDS)
    flags = jnp.any(blocks != 0, axis=-1)
    return blocks, flags


def _pack_flag_words(fv: jax.Array, nb: int) -> jax.Array:
    """(nb,) bool flags -> (nb//32,) packed u32 words (LSB-first)."""
    bits = fv.reshape(nb // 32, 32).astype(jnp.uint32)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (nb // 32, 32), 1)
    return jnp.sum(bits << shifts, axis=1, dtype=jnp.uint32)


def _compact_into_payload(payload_ref, blocks, fv, base_off, capacity: int):
    """Scatter surviving blocks at ``base_off + local exclusive cumsum``.

    Row ``capacity`` of the payload ref is the trash slot: non-surviving and
    beyond-capacity blocks land there (reference semantics drop them).
    Returns this step's survivor count.
    """
    nb = fv.shape[0]
    fv_i = fv.astype(jnp.int32).reshape(1, nb)
    excl = (jnp.cumsum(fv_i, axis=1) - fv_i).reshape(nb)
    off = base_off + excl
    idx = jnp.where(fv & (off < capacity), off, capacity)
    payload_ref[idx] = blocks
    return jnp.sum(fv_i, dtype=jnp.int32)


# ---------------------------------------------------------------------------
# Full megakernel: float data -> (bitflags, payload, nnz) in one launch
# ---------------------------------------------------------------------------

def _make_compress_kernel(p: StreamPlan, capacity: int, code_mode: str):
    m, wmax = p.m, p.wmax_compress
    nb = wmax * BLOCKS_PER_TILE

    def kernel(x_ref, halo_ref, eb_ref, bitflags_ref, payload_ref, nnz_ref,
               carry_ref, sm_ref):
        i = pl.program_id(0)

        @pl.when(i == 0)
        def _():
            sm_ref[0] = 0                        # carry length (codes)
            sm_ref[1] = 0                        # running payload offset
            sm_ref[2] = 0                        # tiles emitted so far
            carry_ref[...] = jnp.zeros((1, TILE), jnp.uint16)
            payload_ref[...] = jnp.zeros((capacity + 1, BLOCK_WORDS), jnp.uint16)
            nnz_ref[0, 0] = 0

        codes = _lq.band_codes(x_ref[...], halo_ref[...], 2.0 * eb_ref[0, 0],
                               ndim=p.kern_nd, code_mode=code_mode,
                               is_first=i == 0)
        flat = codes.reshape(1, m)
        # zero everything past the real data: the stream then matches the
        # reference's zero-padded flat code stream exactly, including the
        # grid's flush steps past the last band (whose clamped input band is
        # entirely masked here)
        pos = i * m + jax.lax.broadcasted_iota(jnp.int32, (1, m), 1)
        flat = jnp.where(pos < p.n, flat, jnp.uint16(0))

        carry_len = sm_ref[0]
        # stream buffer: [0, TILE) carry (right-aligned, valid suffix is the
        # last carry_len codes), [TILE, TILE+m) this band's codes,
        # [TILE+m, 2*TILE+m) zero slack so the wmax-tile slice below is safe
        buf = jnp.concatenate(
            [carry_ref[...], flat, jnp.zeros((1, TILE), jnp.uint16)], axis=1)
        w = (carry_len + m) // TILE              # whole tiles ready this step
        proc = jax.lax.dynamic_slice(
            buf, (0, TILE - carry_len), (1, wmax * TILE)).reshape(-1)
        blocks, flags = _shuffle_tiles(proc, wmax)

        tiles_done = sm_ref[2]
        tile_of = jax.lax.broadcasted_iota(
            jnp.int32, (wmax, BLOCKS_PER_TILE), 0).reshape(nb)
        fv = flags & (tile_of < w) & (tiles_done + tile_of < p.total_tiles)

        step_nnz = _compact_into_payload(payload_ref, blocks, fv, sm_ref[1],
                                         capacity)
        # invalid-tail words are overwritten by the next step (or land in the
        # wrapper-sliced pad region), so the store needs no per-tile predicate
        bitflags_ref[0, pl.ds(tiles_done * FLAG_WORDS_PER_TILE,
                              wmax * FLAG_WORDS_PER_TILE)] = \
            _pack_flag_words(fv, nb)

        nnz_ref[0, 0] += step_nnz
        sm_ref[1] += step_nnz
        sm_ref[2] = tiles_done + w
        sm_ref[0] = carry_len + m - w * TILE
        # the last TILE codes of the valid stream (ending at buf[TILE+m))
        # become the next step's right-aligned carry — a static slice
        carry_ref[...] = buf[:, m:m + TILE]

    return kernel


@functools.partial(jax.jit, static_argnames=("code_mode", "capacity", "interpret"))
def fused_compress(data: jax.Array, eb: jax.Array, *, capacity: int,
                   code_mode: str = "sign_mag", interpret: bool = False):
    """float (1-3)D -> (bitflags u32[W], payload u16[capacity, 8], nnz i32[]).

    Bit-identical to ``enc.encode(shuffle.bitshuffle(pad(quantize(data))))``
    with the code stream never leaving VMEM.
    """
    p = plan_stream(data.shape)
    x = _pad_to_kernel_view(data, p)
    # flush steps keep the grid going until the zero-padded stream completes
    steps = max(p.bands, -(-p.padded_n // p.m))
    wmax = p.wmax_compress
    fw_pad = p.flag_words + wmax * FLAG_WORDS_PER_TILE

    band_block = (p.band, *p.trailing)
    zeros_trail = (0,) * len(p.trailing)

    def band_index(i):
        return (jnp.minimum(i, p.bands - 1), *zeros_trail)

    def halo_index(i):
        return (jnp.maximum(jnp.minimum(i, p.bands - 1) * p.band - 1, 0),
                *zeros_trail)

    eb_arr = jnp.reshape(jnp.asarray(eb, jnp.float32), (1, 1))
    bitflags, payload, nnz = pl.pallas_call(
        _make_compress_kernel(p, capacity, code_mode),
        grid=(steps,),
        in_specs=[pl.BlockSpec(band_block, band_index),
                  pl.BlockSpec((1, *p.trailing), halo_index),
                  pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=[pl.BlockSpec((1, fw_pad), lambda i: (0, 0)),
                   pl.BlockSpec((capacity + 1, BLOCK_WORDS), lambda i: (0, 0)),
                   pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((1, fw_pad), jnp.uint32),
                   jax.ShapeDtypeStruct((capacity + 1, BLOCK_WORDS), jnp.uint16),
                   jax.ShapeDtypeStruct((1, 1), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((1, TILE), jnp.uint16),
                        pltpu.SMEM((4,), jnp.int32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(x, x, eb_arr)
    return bitflags[0, :p.flag_words], payload[:capacity], nnz[0, 0]


# ---------------------------------------------------------------------------
# Codes-input megakernel: fused shuffle + flag + compaction (the outlier
# route — reference quantization already materialized the codes)
# ---------------------------------------------------------------------------

def _make_encode_kernel(capacity: int, tiles_per_step: int):
    nb = tiles_per_step * BLOCKS_PER_TILE

    def kernel(codes_ref, bitflags_ref, payload_ref, nnz_ref, sm_ref):
        i = pl.program_id(0)

        @pl.when(i == 0)
        def _():
            sm_ref[0] = 0
            payload_ref[...] = jnp.zeros((capacity + 1, BLOCK_WORDS), jnp.uint16)
            nnz_ref[0, 0] = 0

        blocks, flags = _shuffle_tiles(codes_ref[...].reshape(-1),
                                       tiles_per_step)
        # grid-padding tiles are all-zero codes -> never flagged, so no
        # tile-validity mask is needed on this aligned path
        step_nnz = _compact_into_payload(payload_ref, blocks, flags,
                                         sm_ref[0], capacity)
        bitflags_ref[...] = _pack_flag_words(
            flags, nb).reshape(1, tiles_per_step * FLAG_WORDS_PER_TILE)
        nnz_ref[0, 0] += step_nnz
        sm_ref[0] += step_nnz

    return kernel


@functools.partial(jax.jit, static_argnames=("capacity", "interpret"))
def fused_shuffle_encode(codes_flat: jax.Array, *, capacity: int,
                         interpret: bool = False):
    """(k*TILE,) u16 codes -> (bitflags, payload, nnz), compaction in-kernel.

    The kernelized phase 2 on its own: replaces the staged path's XLA
    ``cumsum`` + ``nonzero`` + ``take`` epilogue (and its full shuffled-stream
    HBM materialization) for callers that already hold the code stream.
    """
    if codes_flat.size % TILE:
        raise ValueError(f"size {codes_flat.size} not a multiple of TILE={TILE}")
    n_tiles = codes_flat.size // TILE
    tps = _bsf.TILES_PER_BLOCK
    padded = -(-n_tiles // tps) * tps
    x = jnp.pad(codes_flat.reshape(n_tiles, TILE), ((0, padded - n_tiles), (0, 0)))
    flag_words = n_tiles * FLAG_WORDS_PER_TILE
    bitflags, payload, nnz = pl.pallas_call(
        _make_encode_kernel(capacity, tps),
        grid=(padded // tps,),
        in_specs=[pl.BlockSpec((tps, TILE), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((1, tps * FLAG_WORDS_PER_TILE), lambda i: (0, i)),
                   pl.BlockSpec((capacity + 1, BLOCK_WORDS), lambda i: (0, 0)),
                   pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct(
                       (1, padded * FLAG_WORDS_PER_TILE), jnp.uint32),
                   jax.ShapeDtypeStruct((capacity + 1, BLOCK_WORDS), jnp.uint16),
                   jax.ShapeDtypeStruct((1, 1), jnp.int32)],
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(x)
    return bitflags[0, :flag_words], payload[:capacity], nnz[0, 0]


# ---------------------------------------------------------------------------
# Static-analysis declarations (repro.analysis): mirror the launches above
# ---------------------------------------------------------------------------

def _capacity_for(n: int, capacity_frac: float) -> int:
    """FZConfig.payload_capacity restated on this module's constants."""
    n_blocks = (-(-n // TILE) * TILE) // BLOCK_WORDS
    return max(1, int(n_blocks * capacity_frac))


@register_spec("fused_compress")
def kernel_spec(shape: tuple[int, ...], capacity_frac: float = 1.0,
                dtype: str = "float32") -> KernelSpec:
    """KernelSpec for ``fused_compress``. ``dtype`` is the *source* dtype;
    the wrapper casts to f32 before launch (the StreamPlan must agree with
    the decode megakernel), so the modeled input block is always f32."""
    p = plan_stream(tuple(shape))
    capacity = _capacity_for(p.n, capacity_frac)
    steps = max(p.bands, -(-p.padded_n // p.m))
    wmax = p.wmax_compress
    fw_pad = p.flag_words + wmax * FLAG_WORDS_PER_TILE
    zeros_trail = (0,) * len(p.trailing)
    clamp = p.bands - 1
    return KernelSpec(
        name="fused_compress", module=__name__, grid=(steps,),
        in_blocks=(
            BlockDecl("x", (p.band, *p.trailing), "float32",
                      index_map=lambda i: (min(i, clamp), *zeros_trail)),
            BlockDecl("halo", (1, *p.trailing), "float32",
                      index_map=lambda i: (max(min(i, clamp) * p.band - 1, 0),
                                           *zeros_trail)),
            BlockDecl("eb", (1, 1), "float32", index_map=lambda i: (0, 0)),
        ),
        out_blocks=(
            BlockDecl("bitflags", (1, fw_pad), "uint32",
                      index_map=lambda i: (0, 0)),
            BlockDecl("payload", (capacity + 1, BLOCK_WORDS), "uint16",
                      index_map=lambda i: (0, 0)),
            BlockDecl("nnz", (1, 1), "int32", index_map=lambda i: (0, 0)),
        ),
        scratch=(ScratchDecl("carry", (1, TILE), "uint16", "vmem"),
                 ScratchDecl("sm", (4,), "int32", "smem")),
        dimension_semantics=("arbitrary",),
        kernel_fn=_make_compress_kernel(p, capacity, "sign_mag"),
        point=(f"shape={tuple(shape)} src={dtype} "
               f"capacity_frac={capacity_frac} capacity={capacity}"))


@register_spec("fused_shuffle_encode")
def _encode_spec(n_tiles: int, capacity_frac: float = 1.0) -> KernelSpec:
    tps = _bsf.TILES_PER_BLOCK
    padded = -(-max(n_tiles, 1) // tps) * tps
    capacity = _capacity_for(n_tiles * TILE, capacity_frac)
    return KernelSpec(
        name="fused_shuffle_encode", module=__name__, grid=(padded // tps,),
        in_blocks=(BlockDecl("codes", (tps, TILE), "uint16",
                             index_map=lambda i: (i, 0)),),
        out_blocks=(
            BlockDecl("bitflags", (1, tps * FLAG_WORDS_PER_TILE), "uint32",
                      index_map=lambda i: (0, i)),
            BlockDecl("payload", (capacity + 1, BLOCK_WORDS), "uint16",
                      index_map=lambda i: (0, 0)),
            BlockDecl("nnz", (1, 1), "int32", index_map=lambda i: (0, 0)),
        ),
        scratch=(ScratchDecl("sm", (1,), "int32", "smem"),),
        dimension_semantics=("arbitrary",),
        kernel_fn=_make_encode_kernel(capacity, tps),
        point=f"n_tiles={n_tiles} capacity_frac={capacity_frac}")
