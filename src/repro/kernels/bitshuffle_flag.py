"""Fused bitshuffle + zero-block flagging Pallas TPU kernel (paper §3.3-3.4).

Mirrors FZ-GPU's fused CUDA kernel: one pass over the quantization codes in
fast memory produces BOTH the bitshuffled stream and the per-16-byte-block
zero flags, eliminating the extra HBM round-trip the paper eliminates with
shared memory (their Figure 10 "bitshuffle-mark-v2").

TPU adaptation (DESIGN.md §2):
  * warp ballot -> 4-stage masked-swap 16x16 bit-matrix transpose, expressed
    with lane-local shifts/masks and a static half-swap data movement
    (reshape + flip of a size-2 axis), i.e. no gathers, no cross-lane
    conflicts, fully VPU-vectorizable;
  * 32x33 padded shared memory -> VMEM tiles via BlockSpec; no banking.

Block layout: each grid step processes TILES_PER_BLOCK tiles of TILE=4096
codes (u16). VMEM footprint per step: in 64 KiB + out 64 KiB + flags 4 KiB —
comfortably within a v5e core's ~128 KiB-per-buffer budget at the default 8.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.analysis.kernelspec import BlockDecl, KernelSpec, register_spec

TILE = 4096
GROUP = 16
GROUPS_PER_TILE = TILE // GROUP          # 256
BLOCK_WORDS = 8                          # words per zero-flag block (16 B)
BLOCKS_PER_TILE = TILE // BLOCK_WORDS    # 512
TILES_PER_BLOCK = 8                      # tiles per grid step

_STAGES = ((8, 0xFF00), (4, 0xF0F0), (2, 0xCCCC), (1, 0xAAAA))


def _half_swap(x: jax.Array, delta: int) -> jax.Array:
    """Lane permutation i -> i XOR delta on the last axis (size 16), as a
    static reshape + flip of a size-2 axis (TPU-safe; no gather)."""
    s = x.shape
    y = x.reshape(s[:-1] + (GROUP // (2 * delta), 2, delta))
    return y[..., ::-1, :].reshape(s)


def transpose16_inkernel(x: jax.Array) -> jax.Array:
    """Masked-swap bit-matrix transpose of (..., 16) u16 groups (involution)."""
    lane = jax.lax.broadcasted_iota(jnp.int32, x.shape, x.ndim - 1)
    for delta, mask in _STAGES:
        m = jnp.uint16(mask)
        lo = jnp.uint16(~mask & 0xFFFF)
        partner = _half_swap(x, delta)
        hi_val = (x & m) | ((partner & m) >> delta)
        lo_val = ((partner & lo) << delta) | (x & lo)
        x = jnp.where((lane & delta) == 0, hi_val, lo_val)
    return x


def _bitshuffle_flag_kernel(codes_ref, shuffled_ref, flags_ref):
    """codes_ref: (TB, TILE) u16 -> shuffled (TB, TILE) u16, flags (TB, 512) u8."""
    tb = codes_ref.shape[0]
    g = codes_ref[...].reshape(tb, GROUPS_PER_TILE, GROUP)
    t = transpose16_inkernel(g)                       # (TB, 256 groups, 16 planes)
    planes = jnp.swapaxes(t, 1, 2)                    # (TB, 16 planes, 256 words)
    shuffled = planes.reshape(tb, TILE)
    shuffled_ref[...] = shuffled
    # fused phase-1 of the encoder: zero flags per 8-word block
    blocks = shuffled.reshape(tb, BLOCKS_PER_TILE, BLOCK_WORDS)
    flags_ref[...] = jnp.any(blocks != 0, axis=-1).astype(jnp.uint8)


def _unshuffle_kernel(shuffled_ref, codes_ref):
    tb = shuffled_ref.shape[0]
    planes = shuffled_ref[...].reshape(tb, GROUP, GROUPS_PER_TILE)
    t = jnp.swapaxes(planes, 1, 2)                    # (TB, 256, 16)
    codes_ref[...] = transpose16_inkernel(t).reshape(tb, TILE)


def _pad_tiles(n_tiles: int) -> int:
    return (n_tiles + TILES_PER_BLOCK - 1) // TILES_PER_BLOCK * TILES_PER_BLOCK


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitshuffle_flag(codes_tiles: jax.Array, *, interpret: bool = False):
    """(n_tiles, TILE) u16 -> (shuffled (n_tiles, TILE) u16, flags (n_tiles, 512) u8)."""
    n_tiles = codes_tiles.shape[0]
    padded = _pad_tiles(n_tiles)
    x = jnp.pad(codes_tiles, ((0, padded - n_tiles), (0, 0)))
    grid = padded // TILES_PER_BLOCK
    shuffled, flags = pl.pallas_call(
        _bitshuffle_flag_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((TILES_PER_BLOCK, TILE), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((TILES_PER_BLOCK, TILE), lambda i: (i, 0)),
                   pl.BlockSpec((TILES_PER_BLOCK, BLOCKS_PER_TILE), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((padded, TILE), jnp.uint16),
                   jax.ShapeDtypeStruct((padded, BLOCKS_PER_TILE), jnp.uint8)],
        # per-step tiles are independent: parallel by declaration, not default
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x)
    return shuffled[:n_tiles], flags[:n_tiles]


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitunshuffle_tiles(shuffled_tiles: jax.Array, *, interpret: bool = False) -> jax.Array:
    """(n_tiles, TILE) u16 shuffled -> original code order."""
    n_tiles = shuffled_tiles.shape[0]
    padded = _pad_tiles(n_tiles)
    x = jnp.pad(shuffled_tiles, ((0, padded - n_tiles), (0, 0)))
    grid = padded // TILES_PER_BLOCK
    codes = pl.pallas_call(
        _unshuffle_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((TILES_PER_BLOCK, TILE), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((TILES_PER_BLOCK, TILE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded, TILE), jnp.uint16),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x)
    return codes[:n_tiles]


# ---------------------------------------------------------------------------
# Static-analysis declarations (repro.analysis): mirror the launches above
# ---------------------------------------------------------------------------

def _grid_of(n_tiles: int) -> int:
    return _pad_tiles(max(n_tiles, 1)) // TILES_PER_BLOCK


@register_spec("bitshuffle_flag.shuffle")
def _shuffle_spec(n_tiles: int) -> KernelSpec:
    tb = TILES_PER_BLOCK
    return KernelSpec(
        name="bitshuffle_flag.shuffle", module=__name__,
        grid=(_grid_of(n_tiles),),
        in_blocks=(BlockDecl("codes", (tb, TILE), "uint16",
                             index_map=lambda i: (i, 0)),),
        out_blocks=(BlockDecl("shuffled", (tb, TILE), "uint16",
                              index_map=lambda i: (i, 0)),
                    BlockDecl("flags", (tb, BLOCKS_PER_TILE), "uint8",
                              index_map=lambda i: (i, 0))),
        dimension_semantics=("parallel",),
        kernel_fn=_bitshuffle_flag_kernel,
        point=f"n_tiles={n_tiles}")


@register_spec("bitshuffle_flag.unshuffle")
def _unshuffle_spec(n_tiles: int) -> KernelSpec:
    tb = TILES_PER_BLOCK
    return KernelSpec(
        name="bitshuffle_flag.unshuffle", module=__name__,
        grid=(_grid_of(n_tiles),),
        in_blocks=(BlockDecl("shuffled", (tb, TILE), "uint16",
                             index_map=lambda i: (i, 0)),),
        out_blocks=(BlockDecl("codes", (tb, TILE), "uint16",
                              index_map=lambda i: (i, 0)),),
        dimension_semantics=("parallel",),
        kernel_fn=_unshuffle_kernel,
        point=f"n_tiles={n_tiles}")
