"""Pure-jnp oracles for every Pallas kernel in this package.

These re-export / thin-wrap the core reference implementations so kernel
tests have a single import point, and add the fused-output oracles (the fused
kernels emit multiple results per pass; the oracle composes the unfused
reference stages to produce identical outputs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import encode as _enc
from repro.core import quant as _quant
from repro.core import shuffle as _shuffle

TILE = _shuffle.TILE
BLOCK_WORDS = _enc.BLOCK_WORDS
BLOCKS_PER_TILE = TILE // BLOCK_WORDS  # 512


def lorenzo_quant_ref(data: jax.Array, eb: jax.Array, *, code_mode: str = "sign_mag") -> jax.Array:
    """Fused pre-quantization + Lorenzo + sign-magnitude codes (paper mode:
    saturating, no outlier channel)."""
    q = jnp.rint(data.astype(jnp.float32) / (2.0 * eb)).astype(jnp.int32)
    delta = _quant.lorenzo_delta(q)
    codes, _, _ = _quant.to_codes(delta, code_mode=code_mode)
    return codes


def bitshuffle_flag_ref(codes_tiles: jax.Array):
    """Fused bitshuffle + zero-block byte flags.

    codes_tiles: (n_tiles, TILE) u16.
    Returns (shuffled (n_tiles, TILE) u16, byteflags (n_tiles, 512) u8) where
    byteflag b of tile t covers shuffled words [8b, 8b+8) of tile t.
    """
    n_tiles = codes_tiles.shape[0]
    shuffled = _shuffle.bitshuffle(codes_tiles.reshape(-1)).reshape(n_tiles, TILE)
    flags = jnp.any(shuffled.reshape(n_tiles, BLOCKS_PER_TILE, BLOCK_WORDS) != 0, axis=-1)
    return shuffled, flags.astype(jnp.uint8)


def bitunshuffle_ref(shuffled_tiles: jax.Array) -> jax.Array:
    """(n_tiles, TILE) u16 -> (n_tiles, TILE) u16 original code order."""
    n_tiles = shuffled_tiles.shape[0]
    return _shuffle.bitunshuffle(shuffled_tiles.reshape(-1)).reshape(n_tiles, TILE)


def dequant_lorenzo_ref(codes: jax.Array, eb: jax.Array, shape, *,
                        code_mode: str = "sign_mag") -> jax.Array:
    """Inverse fused kernel oracle: codes -> float reconstruction."""
    return _quant.dual_dequantize(codes, eb, tuple(shape), code_mode=code_mode)
