"""jit'd public wrappers around the Pallas kernels.

Interpret-mode fallback: on non-TPU backends (this container is CPU) the
kernels execute through the Pallas interpreter, which runs the kernel body
in Python/XLA for bit-exact validation against ref.py. On TPU the same
pallas_call lowers to Mosaic.

Signature compatibility: these wrappers expose the same interfaces as the
reference stages in repro.core so FZConfig(use_kernels=True) swaps them in
transparently (see core/fz.py:_stages).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import encode as _enc
from repro.core import quant as _quant
from . import bitshuffle_flag as _bsf
from . import lorenzo_quant as _lq

TILE = _bsf.TILE


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def lorenzo_quantize(data: jax.Array, eb: jax.Array, *, code_mode: str = "sign_mag",
                     outlier_capacity: int = 0):
    """Kernel-path dual-quantization (paper-faithful: saturating, no outliers).

    With outlier_capacity > 0 (strict-error-bound mode) the exact residual
    side channel needs the unsaturated deltas, which the fused kernel by
    design never materializes — quantization falls back to the reference
    implementation (the shuffle/encode kernels, the hot 70+% of the pipeline
    per paper Fig. 1, still run as kernels).
    """
    if outlier_capacity > 0:
        return _quant.dual_quantize(data, eb, code_mode=code_mode,
                                    outlier_capacity=outlier_capacity)
    codes = _lq.lorenzo_quant(data, eb, code_mode=code_mode, interpret=_interpret())
    zero_i = jnp.zeros((0,), jnp.int32)
    return codes, zero_i, zero_i, jnp.int32(0)


@partial(jax.jit, static_argnames=("capacity",))
def bitshuffle_flag_encode(codes_flat: jax.Array, *, capacity: int):
    """Fused kernel (shuffle + phase-1 flags) + XLA phase-2 (scan + gather).

    Matches repro.core.encode.encode(bitshuffle(codes_flat), capacity).
    """
    if codes_flat.size % TILE:
        raise ValueError(f"size {codes_flat.size} not a multiple of TILE={TILE}")
    tiles = codes_flat.reshape(-1, TILE)
    shuffled, byteflags = _bsf.bitshuffle_flag(tiles, interpret=_interpret())
    flags = byteflags.reshape(-1).astype(bool)
    nnz = jnp.sum(flags, dtype=jnp.int32)
    (src,) = jnp.nonzero(flags, size=capacity, fill_value=0)
    payload = shuffled.reshape(-1, _enc.BLOCK_WORDS)[src]
    payload = jnp.where(jnp.arange(capacity)[:, None] < nnz, payload, 0)
    return _enc.pack_bitflags(flags), payload.astype(jnp.uint16), nnz


@jax.jit
def bitshuffle(codes_flat: jax.Array) -> jax.Array:
    """Shuffle-only kernel path (flags discarded) for tests/benchmarks."""
    shuffled, _ = _bsf.bitshuffle_flag(codes_flat.reshape(-1, TILE), interpret=_interpret())
    return shuffled.reshape(-1)


@jax.jit
def bitunshuffle(words_flat: jax.Array) -> jax.Array:
    """Inverse transform kernel, same signature as core.shuffle.bitunshuffle."""
    tiles = words_flat.reshape(-1, TILE)
    return _bsf.bitunshuffle_tiles(tiles, interpret=_interpret()).reshape(-1)
