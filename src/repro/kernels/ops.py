"""jit'd public wrappers around the Pallas kernels.

Interpret-mode fallback: on non-TPU backends (this container is CPU) the
kernels execute through the Pallas interpreter, which runs the kernel body
in Python/XLA for bit-exact validation against ref.py. On TPU the same
pallas_call lowers to Mosaic. ``backend_interpret()`` is the one shared
backend check — benchmarks and callers outside this package route through it
instead of hardcoding ``interpret=True``.

Two kernel flavors, selected by ``FZConfig.kernel_mode`` (see core/fz.py):

  * ``"fused"`` (default): single-launch megakernels — the whole compress
    pipeline in one pallas_call (fused_compress.py) and the whole decompress
    pipeline in another (fused_decode.py); the code stream never touches HBM.
  * ``"staged"``: the PR-3-era two-kernel path (lorenzo_quant, then
    bitshuffle_flag with an XLA phase-2 epilogue) — retained as a second
    oracle next to the pure-jnp reference.

Signature compatibility: the staged wrappers expose the same interfaces as
the reference stages in repro.core so FZConfig swaps them in transparently
(see core/fz.py:_stages); the fused wrappers produce whole containers' worth
of fields per call.

Every stage body runs under an ``obs.span("fz.stage.<name>", backend=...)``.
These execute while jax is tracing the enclosing fz jit, so they record
once-per-compilation ``jit-trace`` events (nested, by timestamp, inside the
eager ``fz.compress``/``fz.decompress`` wrapper span that triggered the
compile) and the ``named_scope`` lands the stage name in XLA op metadata —
no runtime footprint in the compiled program.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro import obs
from repro.core import encode as _enc
from repro.core import quant as _quant
from repro.core import shuffle as _shuffle
from . import bitshuffle_flag as _bsf
from . import fused_compress as _fc
from . import fused_decode as _fd
from . import lorenzo_quant as _lq

TILE = _bsf.TILE


def backend_interpret() -> bool:
    """True when the Pallas kernels must run under the interpreter (non-TPU).

    The single source of truth for backend routing: kernels lower to Mosaic
    exactly when the default backend is a TPU, and benchmarks that want "the
    real lowering where available" ask here instead of pinning interpret=True.
    """
    return jax.default_backend() != "tpu"


_interpret = backend_interpret  # intra-module shorthand


def backend_label() -> str:
    """Span/metric label for where the kernels execute."""
    return "interpret" if _interpret() else "tpu"


# ---------------------------------------------------------------------------
# Staged kernel path ("kernel_mode=staged"): per-stage launches, XLA phase 2
# ---------------------------------------------------------------------------

def lorenzo_quantize(data: jax.Array, eb: jax.Array, *, code_mode: str = "sign_mag",
                     outlier_capacity: int = 0):
    """Kernel-path dual-quantization (paper-faithful: saturating, no outliers).

    With outlier_capacity > 0 (strict-error-bound mode) the exact residual
    side channel needs the unsaturated deltas, which the fused kernel by
    design never materializes — quantization falls back to the reference
    implementation (the shuffle/encode kernels, the hot 70+% of the pipeline
    per paper Fig. 1, still run as kernels).
    """
    with obs.span("fz.stage.quantize", backend=backend_label()):
        if outlier_capacity > 0:
            return _quant.dual_quantize(data, eb, code_mode=code_mode,
                                        outlier_capacity=outlier_capacity)
        codes = _lq.lorenzo_quant(data, eb, code_mode=code_mode,
                                  interpret=_interpret())
        zero_i = jnp.zeros((0,), jnp.int32)
        return codes, zero_i, zero_i, jnp.int32(0)


@partial(jax.jit, static_argnames=("capacity",))
def bitshuffle_flag_encode(codes_flat: jax.Array, *, capacity: int):
    """Fused kernel (shuffle + phase-1 flags) + XLA phase-2 (scan + gather).

    Matches repro.core.encode.encode(bitshuffle(codes_flat), capacity).
    """
    if codes_flat.size % TILE:
        raise ValueError(f"size {codes_flat.size} not a multiple of TILE={TILE}")
    with obs.span("fz.stage.shuffle_encode", backend=backend_label()):
        tiles = codes_flat.reshape(-1, TILE)
        shuffled, byteflags = _bsf.bitshuffle_flag(tiles, interpret=_interpret())
        flags = byteflags.reshape(-1).astype(bool)
        return _enc.compact_blocks(
            flags, shuffled.reshape(-1, _enc.BLOCK_WORDS), capacity=capacity)


@jax.jit
def bitshuffle(codes_flat: jax.Array) -> jax.Array:
    """Shuffle-only kernel path (flags discarded) for tests/benchmarks."""
    shuffled, _ = _bsf.bitshuffle_flag(codes_flat.reshape(-1, TILE), interpret=_interpret())
    return shuffled.reshape(-1)


@jax.jit
def bitunshuffle(words_flat: jax.Array) -> jax.Array:
    """Inverse transform kernel, same signature as core.shuffle.bitunshuffle."""
    with obs.span("fz.stage.unshuffle", backend=backend_label()):
        tiles = words_flat.reshape(-1, TILE)
        return _bsf.bitunshuffle_tiles(tiles, interpret=_interpret()).reshape(-1)


# ---------------------------------------------------------------------------
# Fused megakernel path ("kernel_mode=fused"): one launch per direction
# ---------------------------------------------------------------------------

def fused_compress_stages(data: jax.Array, eb: jax.Array, *,
                          code_mode: str, capacity: int,
                          outlier_capacity: int = 0):
    """One-launch compress: (bitflags, payload, nnz, oidx, oval, n_over).

    Outlier routing is EXPLICIT here (not a silent fallback): the exact
    residual side channel needs the unsaturated int32 deltas, and the fused
    megakernel by design never materializes them (codes are born saturated
    in VMEM). With ``outlier_capacity > 0`` the pipeline therefore routes
    quantization through the reference implementation to harvest the
    residuals and runs the fused shuffle+flag+compaction megakernel on the
    resulting codes — still no shuffled-stream HBM round trip, and the
    strict error bound is preserved (pinned in tests/test_kernels.py).
    """
    with obs.span("fz.stage.fused_compress", backend=backend_label()):
        if outlier_capacity > 0:
            codes, oidx, oval, n_over = _quant.dual_quantize(
                data, eb, code_mode=code_mode, outlier_capacity=outlier_capacity)
            flat = _shuffle.pad_to_tiles(codes.reshape(-1))
            bitflags, payload, nnz = _fc.fused_shuffle_encode(
                flat, capacity=capacity, interpret=_interpret())
            return bitflags, payload, nnz, oidx, oval, n_over
        bitflags, payload, nnz = _fc.fused_compress(
            data, eb, capacity=capacity, code_mode=code_mode,
            interpret=_interpret())
        zero_i = jnp.zeros((0,), jnp.int32)
        return bitflags, payload, nnz, zero_i, zero_i, jnp.int32(0)


def fused_decompress(bitflags: jax.Array, payload: jax.Array, eb: jax.Array, *,
                     shape: tuple[int, ...], code_mode: str,
                     outlier_idx: jax.Array | None = None,
                     outlier_val: jax.Array | None = None) -> jax.Array:
    """One-launch decompress mirroring :func:`fused_compress_stages`."""
    with obs.span("fz.stage.fused_decompress", backend=backend_label()):
        return _fd.fused_decompress(
            bitflags, payload, eb, shape=tuple(shape), code_mode=code_mode,
            outlier_idx=outlier_idx, outlier_val=outlier_val,
            interpret=_interpret())
