"""Single-launch fused FZ decompress megakernel (decode mirror of §3.5).

One ``pallas_call`` runs the ENTIRE decompression pipeline — bit-flag unpack +
offset-gather block decode + bitunshuffle + code→delta conversion + inverse
Lorenzo + dequantization — so neither the u16 word stream nor the code stream
ever touches HBM. The reference path materializes both (plus a global
``cumsum`` over all flags for the payload offsets); here the running payload
read offset rides in SMEM scratch across the TPU grid's *sequential* steps,
so each step's offsets are ``smem_offset + local exclusive cumsum`` — no
global scan, no gather over a materialized stream.

Stream geometry is the compress kernel's :class:`StreamPlan`: the decoder
walks the same leading-axis bands, holding the < TILE decoded-but-unconsumed
codes of each step in a right-aligned VMEM carry. The inverse-Lorenzo
leading-axis integration threads through scratch as well: per-axis prefix
sums commute, so each band only needs the previous band's last cumulative
row/plane (a ``(1, *trailing)`` i32 VMEM carry; for the flattened-1D layout a
single SMEM scalar), and all trailing-axis cumsums stay band-internal. 2D/3D
trailing-axis cumsums therefore run in-kernel too — no XLA epilogue was
needed in interpret mode; if Mosaic layouts fight the in-kernel trailing
cumsum on real TPU, peeling it back out is a one-line split (tracked with the
TPU hillclimb item in ROADMAP.md).

Exact-outlier residuals (the beyond-paper strict-bound channel) are applied
in-kernel: each band scatter-adds the residuals whose flat index lands in its
range into its delta slice (an extra trash column absorbs out-of-band and
unused slots, whose values are zero by construction).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.analysis.kernelspec import (BlockDecl, KernelSpec, ScratchDecl,
                                       register_spec)
from repro.core import quant as _quant
from . import bitshuffle_flag as _bsf
from .fused_compress import (BLOCK_WORDS, BLOCKS_PER_TILE, FLAG_WORDS_PER_TILE,
                             GROUP, GROUPS_PER_TILE, ROW_1D, TILE, StreamPlan,
                             _capacity_for, plan_stream)


def _unshuffle_tiles(words: jax.Array, wmax: int) -> jax.Array:
    """(wmax, TILE) u16 shuffled words -> (wmax*TILE,) u16 codes."""
    planes = words.reshape(wmax, GROUP, GROUPS_PER_TILE)
    t = jnp.swapaxes(planes, 1, 2)
    return _bsf.transpose16_inkernel(t).reshape(wmax * TILE)


def _inverse_lorenzo_band(delta: jax.Array, p: StreamPlan, qcarry_ref, sm_ref,
                          is_first):
    """Band delta (1, m) i32 -> band q (band, *trailing) i32, carrying the
    leading-axis integration through scratch. Trailing-axis prefix sums are
    band-internal (per-axis cumsums commute)."""
    if p.kern_nd == 1:
        rows = delta.reshape(p.band, ROW_1D)
        rs = jnp.cumsum(rows, axis=1)
        tot = rs[:, -1:]
        base = sm_ref[3] + jnp.cumsum(tot, axis=0) - tot       # exclusive
        q = rs + base
        sm_ref[3] = q[-1, -1]
        return q
    e = delta.reshape(p.band, *p.trailing)
    for ax in range(len(p.trailing), 0, -1):
        e = jnp.cumsum(e, axis=ax)
    carry = jnp.where(is_first, jnp.zeros_like(qcarry_ref[...]), qcarry_ref[...])
    q = jnp.cumsum(e, axis=0) + carry
    qcarry_ref[...] = q[-1:]
    return q


def _make_decode_kernel(p: StreamPlan, capacity: int, code_mode: str,
                        n_outliers: int):
    m, wmax = p.m, p.wmax_decode
    nb = wmax * BLOCKS_PER_TILE

    def kernel(*refs):
        if n_outliers:
            (bitflags_ref, payload_ref, eb_ref, oidx_ref, oval_ref,
             out_ref, carry_ref, qcarry_ref, sm_ref) = refs
        else:
            (bitflags_ref, payload_ref, eb_ref,
             out_ref, carry_ref, qcarry_ref, sm_ref) = refs
        i = pl.program_id(0)

        @pl.when(i == 0)
        def _():
            sm_ref[0] = 0                        # carry length (codes)
            sm_ref[1] = 0                        # running payload read offset
            sm_ref[2] = 0                        # tiles consumed so far
            sm_ref[3] = 0                        # 1D inverse-Lorenzo carry
            carry_ref[...] = jnp.zeros((1, TILE), jnp.uint16)

        carry_len = sm_ref[0]
        w = (m - carry_len + TILE - 1) // TILE   # tiles to open this step
        tiles_done = sm_ref[2]

        # unpack this step's candidate flags (wmax tiles' worth; the input is
        # zero-padded past the real flag words, so over-reads decode to zero)
        fw = bitflags_ref[0, pl.ds(tiles_done * FLAG_WORDS_PER_TILE,
                                   wmax * FLAG_WORDS_PER_TILE)]
        bits = (fw.reshape(nb // 32, 1) >>
                jax.lax.broadcasted_iota(jnp.uint32, (nb // 32, 32), 1)) & 1
        flags = bits.reshape(nb).astype(bool)
        tile_of = jax.lax.broadcasted_iota(
            jnp.int32, (wmax, BLOCKS_PER_TILE), 0).reshape(nb)
        fv = flags & (tile_of < w)               # beyond-w tiles stay unread

        # offset-gather decode at smem_offset + local exclusive cumsum
        fv_i = fv.astype(jnp.int32).reshape(1, nb)
        excl = (jnp.cumsum(fv_i, axis=1) - fv_i).reshape(nb)
        off = sm_ref[1] + excl
        in_cap = fv & (off < capacity)
        rows = payload_ref[jnp.minimum(off, capacity - 1)]
        blocks = jnp.where(in_cap[:, None], rows, jnp.uint16(0))
        codes = _unshuffle_tiles(blocks.reshape(wmax, TILE), wmax)

        # right-aligned code carry, same discipline as the compress kernel
        buf = jnp.concatenate([carry_ref[...], codes.reshape(1, -1)], axis=1)
        band_codes = jax.lax.dynamic_slice(
            buf, (0, TILE - carry_len), (1, m))
        carry_ref[...] = jax.lax.dynamic_slice(buf, (0, w * TILE), (1, TILE))
        sm_ref[0] = carry_len + w * TILE - m
        sm_ref[1] += jnp.sum(fv_i, dtype=jnp.int32)
        sm_ref[2] = tiles_done + w

        delta = _quant.from_codes(band_codes, code_mode=code_mode)
        if n_outliers:
            # residuals whose flat index lands in this band; unused slots
            # carry value 0 so stray in-range fill indices are harmless
            local = oidx_ref[...].reshape(n_outliers) - i * m
            ok = (local >= 0) & (local < m)
            tgt = jnp.where(ok, local, m)        # column m = trash slot
            ext = jnp.concatenate(
                [delta, jnp.zeros((1, 1), jnp.int32)], axis=1)
            ext = ext.at[0, tgt].add(
                jnp.where(ok, oval_ref[...].reshape(n_outliers), 0))
            delta = ext[:, :m]

        q = _inverse_lorenzo_band(delta, p, qcarry_ref, sm_ref, i == 0)
        out_ref[...] = q.reshape(p.band, *p.trailing).astype(jnp.float32) \
            * (2.0 * eb_ref[0, 0])

    return kernel


@functools.partial(jax.jit,
                   static_argnames=("shape", "code_mode", "interpret"))
def fused_decompress(bitflags: jax.Array, payload: jax.Array, eb: jax.Array,
                     *, shape: tuple[int, ...], code_mode: str = "sign_mag",
                     outlier_idx: jax.Array | None = None,
                     outlier_val: jax.Array | None = None,
                     interpret: bool = False) -> jax.Array:
    """Container fields -> float32[shape], whole inverse pipeline in-kernel.

    Bit-identical to ``dual_dequantize(bitunshuffle(decode(...)))`` including
    the optional exact-outlier residual channel.
    """
    p = plan_stream(tuple(shape))
    capacity = payload.shape[0]
    wmax = p.wmax_decode
    # flag words the decoder may touch: every band opens at most wmax tiles
    need = (-(-p.bands * p.m // TILE) + wmax) * FLAG_WORDS_PER_TILE
    bf = jnp.pad(bitflags.reshape(1, -1),
                 ((0, 0), (0, max(0, need - bitflags.size))))

    n_outliers = 0 if outlier_idx is None else int(outlier_idx.size)
    band_block = (p.band, *p.trailing)
    zeros_trail = (0,) * len(p.trailing)
    in_specs = [pl.BlockSpec((1, bf.shape[1]), lambda i: (0, 0)),
                pl.BlockSpec((capacity, BLOCK_WORDS), lambda i: (0, 0)),
                pl.BlockSpec((1, 1), lambda i: (0, 0))]
    args = [bf, payload, jnp.reshape(jnp.asarray(eb, jnp.float32), (1, 1))]
    if n_outliers:
        in_specs += [pl.BlockSpec((1, n_outliers), lambda i: (0, 0))] * 2
        args += [outlier_idx.reshape(1, -1).astype(jnp.int32),
                 outlier_val.reshape(1, -1).astype(jnp.int32)]

    qcarry_shape = (1, *p.trailing) if p.kern_nd > 1 else (1, 1)
    out = pl.pallas_call(
        _make_decode_kernel(p, capacity, code_mode, n_outliers),
        grid=(p.bands,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(band_block, lambda i: (i, *zeros_trail)),
        out_shape=jax.ShapeDtypeStruct((p.bands * p.band, *p.trailing),
                                       jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, TILE), jnp.uint16),
                        pltpu.VMEM(qcarry_shape, jnp.int32),
                        pltpu.SMEM((4,), jnp.int32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(*args)
    if p.kern_nd == 1:
        return out.reshape(-1)[: p.n]
    return out[: p.lead]


# ---------------------------------------------------------------------------
# Static-analysis declaration (repro.analysis): mirrors the launch above
# ---------------------------------------------------------------------------

@register_spec("fused_decode")
def kernel_spec(shape: tuple[int, ...],
                capacity_frac: float = 1.0) -> KernelSpec:
    p = plan_stream(tuple(shape))
    capacity = _capacity_for(p.n, capacity_frac)
    wmax = p.wmax_decode
    need = (-(-p.bands * p.m // TILE) + wmax) * FLAG_WORDS_PER_TILE
    zeros_trail = (0,) * len(p.trailing)
    qcarry_shape = (1, *p.trailing) if p.kern_nd > 1 else (1, 1)
    return KernelSpec(
        name="fused_decode", module=__name__, grid=(p.bands,),
        in_blocks=(
            BlockDecl("bitflags", (1, max(need, 1)), "uint32",
                      index_map=lambda i: (0, 0)),
            BlockDecl("payload", (capacity, BLOCK_WORDS), "uint16",
                      index_map=lambda i: (0, 0)),
            BlockDecl("eb", (1, 1), "float32", index_map=lambda i: (0, 0)),
        ),
        out_blocks=(
            BlockDecl("out", (p.band, *p.trailing), "float32",
                      index_map=lambda i: (i, *zeros_trail)),
        ),
        scratch=(ScratchDecl("carry", (1, TILE), "uint16", "vmem"),
                 ScratchDecl("qcarry", qcarry_shape, "int32", "vmem"),
                 ScratchDecl("sm", (4,), "int32", "smem")),
        dimension_semantics=("arbitrary",),
        kernel_fn=_make_decode_kernel(p, capacity, "sign_mag", 0),
        point=(f"shape={tuple(shape)} capacity_frac={capacity_frac} "
               f"capacity={capacity}"))
