"""Block-parallel Pallas flash-decode kernel (contiguous + paged layouts).

Decode attention over a long KV cache is the movement-bound serving hot path
(same class of kernel FZ-GPU optimizes in §3.3: all bandwidth, no reuse). The
jnp reference in ``dist/flash_decode.decode_partials`` recomputes the full
(B, KVH, G, S) score matrix in HBM; this kernel tiles the KV sequence axis
and keeps the online-softmax state on-chip:

  * grid = (B, T): one grid step per (batch row, KV tile). T is the last grid
    axis, so tiles of one row run back-to-back and the partials accumulate in
    the revisited output block (standard Pallas accumulation: the out
    BlockSpec index map ignores ``t``, so the block stays resident in VMEM
    across the whole row).
  * per tile: s = q @ k_tile^T, masked by the valid prefix, then the running
    (max, exp-sum, weighted-value) triple is rescaled and accumulated — the
    same math as ``dist/flash_decode.decode_partials``, but per tile with the
    cross-tile combine fused on-chip instead of one S-wide softmax.
  * tile geometry: KV_TILE = 128 positions per step (lane-aligned on TPU; any
    divisor works in interpret mode). VMEM per grid step is the k/v tiles —
    2 * KV_TILE * KVH * hd elements — plus the (KVH, G)-shaped state, far
    under a v5e core's budget for every geometry in this repo.

Two entry points share the one kernel body:

  * ``decode_partials`` — contiguous (B, S, KVH, D) caches, reshaped for free
    into (B, T, KV_TILE, KVH, D) tiles (row-major adjacency preserved);
  * ``decode_partials_pages`` — the kvpool slab layout (B, P, ps, KVH, D)
    consumed *directly*: a page is a tile, no contiguous materialization.

Both return the ``(m, num, den)`` triple of the jnp reference and are its
oracle-pinned drop-ins (tests/test_kernels.py, 2e-4); ``shard_offset`` is
folded into the length mask (``pos < length - offset``) so the sequence-
sharded combine in ``dist/flash_decode.flash_decode_shard`` works unchanged.
Like kernels/ops.py, non-TPU backends run through the Pallas interpreter.

Empty-slice contract (inherited from the jnp reference): a fully-masked
slice yields m == NEG_INF and num == den == 0. The combined output is 0
because num and den are 0 — NOT because the renorm weight vanishes; when
*every* slice is empty the renorm weight is exp(NEG_INF - NEG_INF) == 1.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.analysis.kernelspec import BlockDecl, KernelSpec, register_spec

NEG_INF = -1e30        # same finite stand-in as dist/flash_decode.py
KV_TILE = 128          # default KV positions per grid step (TPU lane width)


def _interpret() -> bool:
    from . import ops
    return ops.backend_interpret()   # the package's one backend check


def _flash_decode_kernel(len_ref, q_ref, k_ref, v_ref, m_ref, num_ref, den_ref,
                         *, tile: int):
    """One (batch row, KV tile) grid step of the online softmax.

    len_ref: (1, 1) i32 effective valid length (already offset-adjusted);
    q_ref: (1, KVH, G, D) f32 pre-scaled query; k_ref/v_ref: (1, 1, tile,
    KVH, D) cache tile; m/num/den refs: the (1, KVH, G[, D]) f32 partials,
    revisited across every tile of the row and accumulated in place.
    """
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        m_ref[0] = jnp.full(m_ref.shape[1:], NEG_INF, jnp.float32)
        num_ref[0] = jnp.zeros(num_ref.shape[1:], jnp.float32)
        den_ref[0] = jnp.zeros(den_ref.shape[1:], jnp.float32)

    length = len_ref[0, 0]
    q = q_ref[0]                                     # (KVH, G, D) f32
    k = k_ref[0, 0].astype(jnp.float32)              # (tile, KVH, D)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jnp.einsum("hgd,khd->hgk", q, k)             # (KVH, G, tile)
    pos = t * tile + jax.lax.broadcasted_iota(jnp.int32, (tile,), 0)
    valid = pos < length
    s = jnp.where(valid[None, None, :], s, NEG_INF)

    m_prev = m_ref[0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    p = jnp.where(valid[None, None, :], p, 0.0)      # empty-tile safety
    corr = jnp.exp(m_prev - m_new)                   # 1 while both are NEG_INF
    m_ref[0] = m_new
    den_ref[0] = den_ref[0] * corr + jnp.sum(p, axis=-1)
    num_ref[0] = num_ref[0] * corr[..., None] + jnp.einsum("hgk,khd->hgd", p, v)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _decode_partials_tiles(q4: jax.Array, k_tiles: jax.Array, v_tiles: jax.Array,
                           length_eff: jax.Array, *, interpret: bool):
    """Core pallas_call. q4: (B, KVH, G, D) f32 pre-scaled; k/v_tiles:
    (B, T, tile, KVH, D); length_eff: (B,) i32. Returns (m, num, den) with
    shapes (B, KVH, G), (B, KVH, G, D), (B, KVH, G), all f32."""
    B, KVH, G, D = q4.shape
    T, tile = k_tiles.shape[1], k_tiles.shape[2]
    m, num, den = pl.pallas_call(
        functools.partial(_flash_decode_kernel, tile=tile),
        grid=(B, T),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, t: (b, 0)),
            pl.BlockSpec((1, KVH, G, D), lambda b, t: (b, 0, 0, 0)),
            pl.BlockSpec((1, 1, tile, KVH, D), lambda b, t: (b, t, 0, 0, 0)),
            pl.BlockSpec((1, 1, tile, KVH, D), lambda b, t: (b, t, 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, KVH, G), lambda b, t: (b, 0, 0)),
            pl.BlockSpec((1, KVH, G, D), lambda b, t: (b, 0, 0, 0)),
            pl.BlockSpec((1, KVH, G), lambda b, t: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, KVH, G), jnp.float32),
            jax.ShapeDtypeStruct((B, KVH, G, D), jnp.float32),
            jax.ShapeDtypeStruct((B, KVH, G), jnp.float32),
        ],
        # batch rows are independent ("parallel"); the KV-tile axis carries
        # the online-softmax state in the revisited output blocks, so it
        # must stay sequential ("arbitrary") — checked by repro.analysis
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(length_eff.reshape(B, 1), q4, k_tiles, v_tiles)
    return m, num, den


# ---------------------------------------------------------------------------
# Static-analysis declaration (repro.analysis): mirrors the launch above
# ---------------------------------------------------------------------------

@register_spec("flash_decode")
def kernel_spec(B: int, S: int, KVH: int, G: int, D: int,
                kv_tile: int | None = None, point: str = "") -> KernelSpec:
    """KernelSpec at one attention geometry. ``kv_tile`` is the page size on
    the paged path (a page is a tile); contiguous uses KV_TILE clamped to S.
    The tile lands on the lane axis of the in-kernel score matrix, so it is
    declared lane-critical: ps < 128 under-fills the VPU."""
    tile = min(kv_tile or KV_TILE, S)
    T = -(-S // tile)
    return KernelSpec(
        name="flash_decode", module=__name__, grid=(B, T),
        in_blocks=(
            BlockDecl("len", (1, 1), "int32",
                      index_map=lambda b, t: (b, 0)),
            BlockDecl("q", (1, KVH, G, D), "float32",
                      index_map=lambda b, t: (b, 0, 0, 0)),
            BlockDecl("k", (1, 1, tile, KVH, D), "float32",
                      index_map=lambda b, t: (b, t, 0, 0, 0)),
            BlockDecl("v", (1, 1, tile, KVH, D), "float32",
                      index_map=lambda b, t: (b, t, 0, 0, 0)),
        ),
        out_blocks=(
            BlockDecl("m", (1, KVH, G), "float32",
                      index_map=lambda b, t: (b, 0, 0)),
            BlockDecl("num", (1, KVH, G, D), "float32",
                      index_map=lambda b, t: (b, 0, 0, 0)),
            BlockDecl("den", (1, KVH, G), "float32",
                      index_map=lambda b, t: (b, 0, 0)),
        ),
        dimension_semantics=("parallel", "arbitrary"),
        kernel_fn=_flash_decode_kernel,
        critical_lanes=(("kv_tile", tile),),
        point=point or f"B={B} S={S} KVH={KVH} G={G} D={D} tile={tile}")


def _prep_q(q: jax.Array, KVH: int):
    B, H, D = q.shape
    G = H // KVH
    return q.reshape(B, KVH, G, D).astype(jnp.float32) * D ** -0.5


def _length_eff(length: jax.Array, shard_offset, s_valid: int) -> jax.Array:
    # fold the slice's global offset into the mask (pos + off < length) and
    # clamp to the slice's real width: tile padding lies at pos >= s_valid
    # and must never pass the mask, even when the global length extends past
    # this slice (a later shard holds those positions)
    le = (jnp.asarray(length, jnp.int32)
          - jnp.asarray(shard_offset, jnp.int32)).reshape(-1)
    return jnp.minimum(le, jnp.int32(s_valid))


def decode_partials(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                    length: jax.Array, *, shard_offset: jax.Array | int = 0,
                    kv_tile: int | None = None,
                    interpret: bool | None = None):
    """Kernel drop-in for ``dist.flash_decode.decode_partials`` (contiguous).

    q: (B, H, D); k_cache/v_cache: (B, S_slice, KVH, D); length: (B,) global
    valid prefix; ``shard_offset``: global position of this slice's first
    slot. The slice is padded to a multiple of ``kv_tile`` (default
    ``KV_TILE``, clamped to the slice) and reshaped — row-major, so the
    reshape is free — into (B, T, kv_tile, KVH, D) tiles; padding lands past
    ``length`` and is masked. Returns (m, num, den) as the jnp reference.
    """
    if interpret is None:
        interpret = _interpret()
    B, S, KVH, D = k_cache.shape
    G = q.shape[1] // KVH
    if S == 0:                       # zero-width slice: the empty contract
        return (jnp.full((B, KVH, G), NEG_INF, jnp.float32),
                jnp.zeros((B, KVH, G, D), jnp.float32),
                jnp.zeros((B, KVH, G), jnp.float32))
    tile = min(kv_tile or KV_TILE, S)
    pad = (-S) % tile
    if pad:
        cfg = ((0, 0), (0, pad), (0, 0), (0, 0))
        k_cache = jnp.pad(k_cache, cfg)
        v_cache = jnp.pad(v_cache, cfg)
    T = (S + pad) // tile
    kt = k_cache.reshape(B, T, tile, KVH, D)
    vt = v_cache.reshape(B, T, tile, KVH, D)
    return _decode_partials_tiles(_prep_q(q, KVH), kt, vt,
                                  _length_eff(length, shard_offset, S),
                                  interpret=interpret)


def decode_partials_pages(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                          length: jax.Array, *,
                          interpret: bool | None = None):
    """Page-native partials: the kvpool slab layout is already tiled.

    q: (B, H, D); k_pages/v_pages: (B, P, ps, KVH, D) exactly as
    ``PagePool.gather_pages`` emits them — each page is one KV tile, so the
    pool never materializes the contiguous ``seq_capacity``-wide cache;
    length: (B,) valid prefix over the concatenated pages. Returns
    (m, num, den). On TPU, ``ps`` should be lane-aligned (>= 128) for full
    VPU utilization; interpret mode accepts any page size.
    """
    if interpret is None:
        interpret = _interpret()
    _, P, ps, KVH, _ = k_pages.shape
    return _decode_partials_tiles(_prep_q(q, KVH), k_pages, v_pages,
                                  _length_eff(length, 0, P * ps),
                                  interpret=interpret)


def combine_partials(m, num, den, dtype=jnp.float32) -> jax.Array:
    """Normalize accumulated partials to the attention output (B, H, D).

    All-empty rows have num == den == 0 and come out exactly 0."""
    B, KVH, G, D = num.shape
    out = num / jnp.maximum(den, 1e-30)[..., None]
    return out.reshape(B, KVH * G, D).astype(dtype)


def flash_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                 length: jax.Array, *, kv_tile: int | None = None,
                 interpret: bool | None = None) -> jax.Array:
    """Single-device kernel decode attention over a contiguous cache;
    drop-in for ``models.attention.decode_attention``."""
    m, num, den = decode_partials(q, k_cache, v_cache, length,
                                  kv_tile=kv_tile, interpret=interpret)
    return combine_partials(m, num, den, dtype=q.dtype)
