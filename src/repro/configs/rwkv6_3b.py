"""RWKV6-3B "Finch" [arXiv:2404.05892; hf] — attn-free, data-dependent decay."""
from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40, d_ff=8960,
    vocab=65536, head_dim=64,
    rwkv_head_dim=64,
)
SMOKE = CONFIG.reduced()
