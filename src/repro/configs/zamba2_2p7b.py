"""Zamba2-2.7B [arXiv:2411.15242; hf] — Mamba2 backbone + shared attn blocks."""
from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10240,
    vocab=32000, head_dim=80, rope_theta=1e4,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, conv_width=4,
    shared_attn_every=6,
)
SMOKE = CONFIG.reduced()
