"""DBRX-132B [hf:databricks/dbrx-base] — fine-grained MoE 16e top-4."""
from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=10752,
    vocab=100352, head_dim=128, rope_theta=5e5,
    n_experts=16, top_k=4,
)
SMOKE = CONFIG.reduced()
