"""Qwen2-VL-72B backbone [arXiv:2409.12191; hf] — M-RoPE, vision stub."""
from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=29568,
    vocab=152064, head_dim=128, rope_theta=1e6,
    mrope_sections=(16, 24, 24),
)
SMOKE = CONFIG.reduced()
