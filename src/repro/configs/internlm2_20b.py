"""InternLM2-20B [arXiv:2403.17297; hf] — dense GQA kv=8."""
from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="internlm2-20b", family="dense",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
    vocab=92544, head_dim=128, rope_theta=1e6,
)
SMOKE = CONFIG.reduced()
