"""Assigned architecture registry: --arch <id> resolves here."""
from importlib import import_module

from .base import SHAPES, ArchConfig, ShapeConfig, cells_for  # noqa: F401

ARCH_IDS = (
    "qwen2-vl-72b", "glm4-9b", "internlm2-20b", "yi-6b", "mistral-large-123b",
    "whisper-tiny", "dbrx-132b", "llama4-scout-17b-a16e", "zamba2-2.7b", "rwkv6-3b",
)

_MODULES = {
    "qwen2-vl-72b": "qwen2_vl_72b",
    "glm4-9b": "glm4_9b",
    "internlm2-20b": "internlm2_20b",
    "yi-6b": "yi_6b",
    "mistral-large-123b": "mistral_large_123b",
    "whisper-tiny": "whisper_tiny",
    "dbrx-132b": "dbrx_132b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "zamba2-2.7b": "zamba2_2p7b",
    "rwkv6-3b": "rwkv6_3b",
}


def get(arch_id: str, smoke: bool = False) -> ArchConfig:
    mod = import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.SMOKE if smoke else mod.CONFIG
