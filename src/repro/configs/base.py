"""Architecture config schema + the assigned input-shape suite.

Every assigned architecture gets one module in this package exporting
``CONFIG`` (the exact published dims) and ``SMOKE`` (a reduced same-family
config for CPU smoke tests). ``repro.configs.get(arch_id)`` resolves either.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    family: str                      # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    shared_expert: bool = False
    # VLM (qwen2-vl M-RoPE; vision frontend stubbed per brief)
    mrope_sections: tuple[int, int, int] | None = None
    # audio (whisper; conv frontend stubbed per brief)
    n_audio_ctx: int = 0
    n_enc_layers: int = 0
    # SSM / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    shared_attn_every: int = 0       # zamba2: shared attn block cadence
    # RWKV
    rwkv_head_dim: int = 64
    remat: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """long_500k runs only for sub-quadratic archs (DESIGN.md §4)."""
        return self.family in ("ssm", "hybrid")

    def reduced(self, **overrides) -> "ArchConfig":
        """Same-family tiny config for CPU smoke tests."""
        base = dict(
            arch_id=self.arch_id + "-smoke",
            n_layers=min(self.n_layers, 4 if (self.shared_attn_every or self.n_enc_layers) else 2),
            d_model=128, n_heads=4, n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            d_ff=256, vocab=512, head_dim=32,
            n_experts=min(self.n_experts, 4), top_k=min(self.top_k, 2),
            n_audio_ctx=min(self.n_audio_ctx, 64),
            n_enc_layers=min(self.n_enc_layers, 2),
            ssm_state=min(self.ssm_state, 16), ssm_head_dim=32 if self.ssm_state else 64,
            shared_attn_every=2 if self.shared_attn_every else 0,
            rwkv_head_dim=32 if self.family == "ssm" else 64,
        )
        if self.mrope_sections:
            base["mrope_sections"] = (4, 6, 6)  # sums to head_dim/2 = 16
        base.update(overrides)
        return dataclasses.replace(self, **base)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def cells_for(cfg: ArchConfig) -> list[str]:
    """The dry-run cells this arch runs (shape skips per DESIGN.md §4)."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.supports_long_context:
        names.append("long_500k")
    return names
