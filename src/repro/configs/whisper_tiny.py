"""Whisper-tiny [arXiv:2212.04356] — enc-dec, conv frontend stubbed."""
from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6, d_ff=1536,
    vocab=51865, head_dim=64,
    n_audio_ctx=1500, n_enc_layers=4,
)
SMOKE = CONFIG.reduced()
