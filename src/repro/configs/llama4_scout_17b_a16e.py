"""Llama4-Scout-17B-16E [hf:meta-llama] — MoE 16e top-1 + shared expert,
early-fusion vision stubbed (text backbone per brief)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
    vocab=202048, head_dim=128, rope_theta=5e5,
    n_experts=16, top_k=1, shared_expert=True,
)
SMOKE = CONFIG.reduced()
