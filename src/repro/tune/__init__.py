"""repro.tune — empirical kernel autotuner with a parity-gated winner cache.

The registry (:mod:`registry`) names tunable ops and their per-backend
candidate implementations; the tuner (:mod:`tuner`) micro-benchmarks the
candidates — after statically budget-checking them against
:mod:`repro.analysis` and parity-gating every one against the reference
path — and persists the winner in a versioned JSON cache (:mod:`cache`);
dispatch (:mod:`dispatch`) is the near-zero-overhead lookup the hot paths
call when ``kernel_mode="auto"`` (the ``FZConfig``/kvpool/engine/dist
default) resolves to a concrete execution path.

Pre-tune from the command line::

    python -m repro.tune --smoke          # tune the CI workload set
    python -m repro.tune --dump           # print the cached table

A faster-but-wrong candidate can never be selected: the parity gate
(bit-identity for decode paths, the error-bound invariant for compress)
runs before any candidate becomes eligible for timing.
"""
from .cache import SCHEMA_VERSION, TuneCache, cache_key, shape_bucket  # noqa: F401
from .dispatch import (active_cache, arch, backend, configure,  # noqa: F401
                       decode_attention_impl, fz_fallback_mode,
                       invalidate_memo, reset, resolve_fz)
from .impls import attn_cache_elems, fz_impl_config  # noqa: F401
from .registry import Candidate, OpSpec  # noqa: F401
from .tuner import TuneError, ensure_tuned, tune_op  # noqa: F401
