"""Persistent tuning cache: versioned JSON, keyed by (backend, op, bucket, dtype, arch).

The cache is the "pay once" half of the tuner contract: an empirical sweep
(warmup + median-of-k per candidate, parity-gated — see :mod:`tuner`) is
expensive, so its winner is written down and every later dispatch is a dict
lookup. Keys collapse the shape axis to the next power of two
(:func:`shape_bucket`): kernel-path crossover points move slowly with size,
so nearby shapes share one measurement instead of each paying their own.

Robustness rules, all pinned in tests/test_tune.py:

  * **schema invalidation** — a file whose ``schema`` field differs from
    :data:`SCHEMA_VERSION` is discarded wholesale (entry semantics may have
    changed); the next tune repopulates and rewrites it.
  * **corrupted-file recovery** — truncated or non-JSON files never raise:
    the cache loads empty (``status`` records why, a
    ``tune_cache{result=invalid}`` counter fires) and the next save writes a
    clean file.
  * **atomic writes** — save goes through a same-directory temp file +
    ``os.replace`` so a crash mid-write can only leave the old file or the
    new one, never a truncated hybrid.

The default location is ``$REPRO_TUNE_CACHE`` when set (CI points it at a
throwaway path; tests at tmp dirs), else ``~/.cache/repro/tune_cache.json``.
"""
from __future__ import annotations

import json
import os
import pathlib
import tempfile

from repro import obs

SCHEMA_VERSION = 1
ENV_VAR = "REPRO_TUNE_CACHE"


def default_path() -> pathlib.Path:
    env = os.environ.get(ENV_VAR)
    if env:
        return pathlib.Path(env)
    return pathlib.Path.home() / ".cache" / "repro" / "tune_cache.json"


def shape_bucket(n: int) -> int:
    """Collapse an element count to the next power of two (min 1)."""
    if n <= 1:
        return 1
    return 1 << (int(n) - 1).bit_length()


def cache_key(backend: str, op: str, n: int, dtype: str, arch: str) -> str:
    """Flat string key: ``backend|op|pow2:<bucket>|dtype|arch``."""
    return f"{backend}|{op}|pow2:{shape_bucket(n)}|{dtype}|{arch}"


class TuneCache:
    """In-memory view of one cache file; load() never raises on bad files."""

    def __init__(self, path: str | os.PathLike | None = None):
        self.path = pathlib.Path(path) if path is not None else default_path()
        self.entries: dict[str, dict] = {}
        self.status = "unloaded"

    def load(self) -> "TuneCache":
        try:
            raw = json.loads(self.path.read_text())
        except FileNotFoundError:
            self.status = "missing"
            return self
        except (OSError, UnicodeDecodeError, json.JSONDecodeError):
            self.status = "corrupt"
            obs.counter("tune_cache", result="invalid", site="load").inc()
            return self
        if (not isinstance(raw, dict)
                or raw.get("schema") != SCHEMA_VERSION
                or not isinstance(raw.get("entries"), dict)):
            self.status = "schema-mismatch"
            obs.counter("tune_cache", result="invalid", site="load").inc()
            return self
        self.entries = dict(raw["entries"])
        self.status = "ok"
        return self

    def save(self) -> None:
        self.path.parent.mkdir(parents=True, exist_ok=True)
        doc = {"schema": SCHEMA_VERSION, "entries": self.entries}
        fd, tmp = tempfile.mkstemp(dir=self.path.parent,
                                   prefix=self.path.name, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(doc, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def get(self, key: str) -> dict | None:
        e = self.entries.get(key)
        return e if isinstance(e, dict) and e.get("impl") else None

    def put(self, key: str, entry: dict) -> None:
        self.entries[key] = entry

    def __len__(self) -> int:
        return len(self.entries)
