"""Built-in tunable ops and their candidate implementations.

Three ops cover every tuned dispatch site in the tree:

  * ``fz.compress``   — reference / staged / fused compressor paths.
    Parity gate: the *error-bound invariant* — the candidate's container,
    decoded through the reference inverse pipeline, must reconstruct every
    element within ``eb_abs`` (plus the documented f32 rounding allowance).
    A candidate that is fast but breaks the bound can never be selected.
  * ``fz.decompress`` — same three paths on the inverse pipeline.
    Parity gate: *bit-identity* against the reference decode.
  * ``decode_attention`` — jnp oracle vs the Pallas flash-decode kernel.
    Parity gate: max-abs tolerance (2e-4 in f32, the repo's pinned
    kernel-vs-jnp bound; widened for bf16 outputs, which round to ~3
    decimal digits).

Contexts are deterministic (seeded by the workload size) so a tuning run is
reproducible; they compress well (cumulative-sum fields) so the measured
work resembles the scientific payloads the bench tier times. Candidates
with Pallas launches also declare ``kernel_specs`` — the
:mod:`repro.analysis` geometry the tuner statically budget-checks before
ever measuring (configs flagged ``vmem-overflow`` are skipped, not crashed).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fz

from . import registry

# f32 rounding allowance used by the property suite (tests/test_fz_properties)
F32_EPS_ALLOWANCE = 2.0 ** -22
EB = 1e-3
ATTN_TOL_F32 = 2e-4     # pinned kernel-vs-jnp bound (tests/test_kernels.py)
ATTN_TOL_LOWP = 4e-2    # bf16/f16 outputs round to ~8 mantissa bits

_FZ_IMPLS = ("reference", "staged", "fused")


def fz_impl_config(impl: str, eb: float = EB) -> fz.FZConfig:
    """Concrete (non-auto) FZConfig for one execution path."""
    return fz.FZConfig(eb=eb, exact_outliers=False,
                       use_kernels=impl != "reference",
                       kernel_mode=impl if impl != "reference" else "staged")


def _fz_context(*, n: int, dtype: str) -> dict:
    rng = np.random.default_rng(1234 + int(n))
    x = np.cumsum(rng.standard_normal(n).astype(np.float32) * 0.01)
    data = jnp.asarray(x).astype(dtype)
    ref_cfg = fz_impl_config("reference")
    container = jax.block_until_ready(fz._compress_jit(data, ref_cfg))
    return {"n": n, "dtype": dtype, "data": data,
            "ref_cfg": ref_cfg, "container": container}


def _n_tiles(n: int) -> int:
    return -(-int(n) // 4096)


def _fz_specs(impl: str, direction: str):
    """kernel_specs hook for one (impl, direction); None for the reference."""
    if impl == "reference":
        return None

    def specs(ctx: dict) -> list:
        import repro.kernels  # noqa: F401  -- registers the spec builders
        from repro.analysis.kernelspec import spec_builders
        b = spec_builders()
        shape, dtype = (ctx["n"],), ctx["dtype"]
        if direction == "compress":
            if impl == "fused":
                return [b["fused_compress"](shape=shape, dtype=dtype,
                                            capacity_frac=1.0)]
            return [b["lorenzo_quant"](shape=shape, dtype=dtype),
                    b["bitshuffle_flag.shuffle"](n_tiles=_n_tiles(ctx["n"]))]
        if impl == "fused":
            return [b["fused_decode"](shape=shape, capacity_frac=1.0)]
        return [b["bitshuffle_flag.unshuffle"](n_tiles=_n_tiles(ctx["n"]))]

    return specs


def _compress_runner(impl: str):
    def make_runner(ctx: dict):
        cfg = fz_impl_config(impl)
        data = ctx["data"]
        return lambda: fz._compress_jit(data, cfg)
    return make_runner


def _compress_parity(ctx: dict, out, ref_out) -> str | None:
    """Error-bound invariant: decode through the reference inverse pipeline."""
    x = np.asarray(jnp.asarray(ctx["data"], jnp.float32))
    rec = np.asarray(fz._decompress_jit(out, ctx["ref_cfg"]), np.float32)
    eb_abs = float(np.asarray(out.eb_abs))
    err = np.abs(x - rec)
    limit = eb_abs * (1 + 1e-6) + np.abs(x) * F32_EPS_ALLOWANCE
    if bool((err > limit).any()):
        return (f"error bound violated: max|x-x̂| {float(err.max()):.3g} "
                f"> eb_abs {eb_abs:.3g}")
    return None


def _decompress_runner(impl: str):
    def make_runner(ctx: dict):
        cfg = fz_impl_config(impl)
        c = ctx["container"]
        return lambda: fz._decompress_jit(c, cfg)
    return make_runner


def _decompress_parity(ctx: dict, out, ref_out) -> str | None:
    del ctx
    if not np.array_equal(np.asarray(out), np.asarray(ref_out)):
        return "decode not bit-identical to the reference inverse pipeline"
    return None


registry.register_op(registry.OpSpec(
    name="fz.compress", reference="reference", make_context=_fz_context,
    parity=_compress_parity, gate="error-bound"))
registry.register_op(registry.OpSpec(
    name="fz.decompress", reference="reference", make_context=_fz_context,
    parity=_decompress_parity, gate="bit-identity"))

for _impl in _FZ_IMPLS:
    registry.register(registry.Candidate(
        op="fz.compress", impl=_impl, make_runner=_compress_runner(_impl),
        kernel_specs=_fz_specs(_impl, "compress")))
    registry.register(registry.Candidate(
        op="fz.decompress", impl=_impl, make_runner=_decompress_runner(_impl),
        kernel_specs=_fz_specs(_impl, "decompress")))


# ---------------------------------------------------------------------------
# decode_attention: jnp oracle vs the Pallas flash-decode kernel
# ---------------------------------------------------------------------------

ATTN_KVH = 2
ATTN_D = 64
ATTN_B = 2
ATTN_G = 2


def _attn_geometry(n: int) -> tuple[int, int, int, int, int]:
    """(B, S, KVH, G, D) for a cache of ~n elements per sequence.

    Dispatch sites key on ``n = S * KVH * D`` (the per-sequence cache size,
    the axis the kernel tiles over); the remaining dims are held at a
    representative serving geometry.
    """
    s = max(8, int(n) // (ATTN_KVH * ATTN_D))
    return ATTN_B, s, ATTN_KVH, ATTN_G, ATTN_D


def attn_cache_elems(seq_len: int, n_kv_heads: int, head_dim: int) -> int:
    """The ``n`` a decode-attention dispatch site should tune/look up with."""
    return int(seq_len) * int(n_kv_heads) * int(head_dim)


def _attn_context(*, n: int, dtype: str) -> dict:
    b, s, kvh, g, d = _attn_geometry(n)
    k0, k1, k2 = jax.random.split(jax.random.key(7), 3)
    q = jax.random.normal(k0, (b, kvh * g, d), jnp.float32).astype(dtype)
    k = jax.random.normal(k1, (b, s, kvh, d), jnp.float32).astype(dtype)
    v = jax.random.normal(k2, (b, s, kvh, d), jnp.float32).astype(dtype)
    length = jnp.full((b,), s, jnp.int32)
    return {"n": n, "dtype": dtype, "q": q, "k": k, "v": v, "length": length,
            "geometry": (b, s, kvh, g, d)}


def _attn_runner(impl: str):
    def make_runner(ctx: dict):
        q, k, v, length = ctx["q"], ctx["k"], ctx["v"], ctx["length"]
        if impl == "jnp":
            from repro.models import attention
            return jax.jit(lambda: attention.decode_attention(q, k, v, length))
        from repro.kernels import flash_decode
        return jax.jit(lambda: flash_decode.flash_decode(q, k, v, length))
    return make_runner


def _attn_parity(ctx: dict, out, ref_out) -> str | None:
    a = np.asarray(jnp.asarray(out, jnp.float32))
    b = np.asarray(jnp.asarray(ref_out, jnp.float32))
    tol = ATTN_TOL_F32 if ctx["dtype"] in ("float32", "float64") else ATTN_TOL_LOWP
    diff = float(np.max(np.abs(a - b)))
    if diff > tol:
        return f"max|Δ| {diff:.3g} exceeds the {tol:g} kernel-parity bound"
    return None


def _attn_specs(ctx: dict) -> list:
    import repro.kernels  # noqa: F401
    from repro.analysis.kernelspec import spec_builders
    b, s, kvh, g, d = ctx["geometry"]
    return [spec_builders()["flash_decode"](
        B=b, S=s, KVH=kvh, G=g, D=d, kv_tile=None,
        point=f"tune n={ctx['n']}")]


registry.register_op(registry.OpSpec(
    name="decode_attention", reference="jnp", make_context=_attn_context,
    parity=_attn_parity, gate="tolerance"))
registry.register(registry.Candidate(
    op="decode_attention", impl="jnp", make_runner=_attn_runner("jnp")))
registry.register(registry.Candidate(
    op="decode_attention", impl="kernel", make_runner=_attn_runner("kernel"),
    kernel_specs=_attn_specs))


def evil_candidate(op: str, impl: str = "evil") -> registry.Candidate:
    """A fast-but-wrong candidate for parity-gate tests: returns the right
    pytree structure with zeroed data leaves (instant, never correct)."""
    spec = registry.op(op)

    def make_runner(ctx: dict):
        ref_impl = next(c for c in registry.candidates(op)
                        if c.impl == spec.reference)
        ref_out = jax.block_until_ready(ref_impl.make_runner(ctx)())
        zeros = jax.tree.map(jnp.zeros_like, ref_out)
        if dataclasses.is_dataclass(zeros):
            # keep the resolved bound so the error-bound gate sees a
            # plausible container whose *data* is wrong
            zeros = dataclasses.replace(zeros, eb_abs=ref_out.eb_abs)
        return lambda: zeros
    return registry.Candidate(op=op, impl=impl, make_runner=make_runner)
