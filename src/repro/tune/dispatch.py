"""Near-zero-overhead tuned dispatch: cached winner, else backend fallback.

This is the hot-path half of ``repro.tune``: ``fz.compress`` (and the
kvpool/engine/dist sites) resolve ``kernel_mode="auto"`` here on every
*eager* entry, so the lookup must cost a dict probe, not a file read. The
persistent cache is loaded once per process (and re-read only when the
tuner writes a new winner via :func:`invalidate_memo`), and resolutions are
memoized per ``(op, bucket, dtype)``.

Backend-aware fallback ordering (the bugfix half, see also the
``core/fz.py`` module docstring): when no tuning-cache entry exists for a
workload, "auto" does **not** blindly take the fused megakernels —
``BENCH_ci.json`` measures fused compress ~4x *slower* than staged under
the Pallas interpreter (the non-TPU execution mode), because the
interpreter executes the megakernel's sequential grid in Python. The static
ordering is therefore per-backend:

  * ``interpret`` / ``gpu`` (kernels interpret-executed today): staged
    before fused, reference last;
  * ``tpu``: fused first (single-launch, no HBM round-trip for the code
    stream — the paper's §3.5 fusion claim), staged, reference.

Untuned ``decode_attention`` keeps the kernel path — that request is
explicit (``use_kernels=True``) and kernel-vs-jnp parity is pinned; the
cache only *overrides* it where the jnp oracle measures faster.

Counters (gated on ``jax.core.trace_state_clean()`` so retraces are never
tallied): ``tune_cache{result=hit|miss, site=dispatch}`` and
``tune_selected{op=..., impl=..., site=dispatch}``.
"""
from __future__ import annotations

import jax

from repro import obs

from . import registry
from .cache import TuneCache, cache_key, shape_bucket

# per-backend static ordering when no cache entry exists (most-preferred
# first); "gpu" mirrors "interpret" until real Triton lowering is measured
FZ_FALLBACK = {
    "interpret": ("staged", "fused", "reference"),
    "gpu": ("staged", "fused", "reference"),
    "tpu": ("fused", "staged", "reference"),
}

_cache: TuneCache | None = None
_memo: dict[tuple[str, int, str], tuple[str, str]] = {}


def backend() -> str:
    """Registry backend label for the current jax default backend."""
    b = jax.default_backend()
    if b == "tpu":
        return "tpu"
    if b in ("gpu", "cuda", "rocm"):
        return "gpu"
    return "interpret"


def arch() -> str:
    """Device kind the measurements were taken on (part of the cache key)."""
    return jax.devices()[0].device_kind.replace(" ", "_").replace("|", "_")


def active_cache() -> TuneCache:
    """The process-wide cache, loaded lazily from the default path."""
    global _cache
    if _cache is None:
        _cache = TuneCache().load()
    return _cache


def configure(path=None) -> TuneCache:
    """Point the process at a specific cache file (tests, CLI --cache)."""
    global _cache
    _cache = TuneCache(path).load()
    _memo.clear()
    return _cache


def reset() -> None:
    """Drop the loaded cache and memo (next lookup reloads from disk)."""
    global _cache
    _cache = None
    _memo.clear()


def invalidate_memo() -> None:
    """Called by the tuner after writing a winner so dispatch sees it."""
    _memo.clear()


def _count(result: str, op: str, impl: str) -> None:
    if not jax.core.trace_state_clean():
        return
    obs.counter("tune_cache", result=result, site="dispatch").inc()
    obs.counter("tune_selected", op=op, impl=impl, site="dispatch").inc()


def _resolve(op: str, n: int, dtype: str, fallback_impl: str) -> str:
    memo_key = (op, shape_bucket(n), dtype)
    cached = _memo.get(memo_key)
    if cached is None:
        entry = active_cache().get(cache_key(backend(), op, n, dtype, arch()))
        if entry is not None:
            cached = (entry["impl"], "hit")
        else:
            cached = (fallback_impl, "miss")
        _memo[memo_key] = cached
    impl, result = cached
    _count(result, op, impl)
    return impl


def fz_fallback_mode(b: str | None = None) -> str:
    """First *kernel* choice of the static ordering ("staged" or "fused")."""
    for impl in FZ_FALLBACK.get(b or backend(), FZ_FALLBACK["interpret"]):
        if impl != "reference":
            return impl
    return "staged"


def resolve_fz(direction: str, n: int, dtype: str) -> str:
    """Winning impl for ``fz.compress``/``fz.decompress`` at this workload:
    ``"reference" | "staged" | "fused"``. ``direction`` is "compress" or
    "decompress"."""
    op = f"fz.{direction}"
    b = backend()
    fallback = next(
        (impl for impl in FZ_FALLBACK.get(b, FZ_FALLBACK["interpret"])
         if any(c.impl == impl for c in registry.candidates(op, backend=b))),
        "reference")
    return _resolve(op, n, dtype, fallback)


def decode_attention_impl(n: int, dtype: str) -> str:
    """Winning impl for decode attention at a per-sequence cache of ``n``
    elements: ``"kernel" | "jnp"``. Untuned default stays "kernel" — the
    caller asked for kernels and parity is pinned; the cache only overrides
    where the oracle measured faster."""
    return _resolve("decode_attention", n, str(dtype), "kernel")
