"""Empirical tuner: budget-check, parity-gate, then measure and cache.

``tune_op`` is the whole contract in one function, in eligibility order:

  1. **cache hit** — a valid entry for ``(backend, op, shape-bucket, dtype,
     arch)`` short-circuits everything: zero re-measurements on a warm
     cache (pinned by the double-run assert in ``scripts/ci.sh`` and
     tests/test_tune.py).
  2. **static budget skip** — candidates declaring ``kernel_specs`` are run
     through :func:`repro.analysis.resources.analyze_spec` at the context
     geometry; a ``vmem-overflow``/``smem-overflow`` finding skips the
     candidate (logged + counted + recorded in the entry) instead of
     measuring a launch the hardware cannot hold.
  3. **parity gate** — every surviving non-reference candidate's output is
     checked against the reference implementation (bit-identity for decode
     paths, the error-bound invariant for compress). Rejected candidates
     are recorded and *never eligible*, however fast they would have been.
  4. **measurement** — warmup launches then median-of-k wall time per
     eligible candidate, inside an ``obs.span("tune.measure", ...)`` so
     the timings land in the metrics registry and trace exporters.

The winner (min median) is written to the persistent cache and the
in-process dispatch memo is refreshed, so subsequent ``kernel_mode="auto"``
dispatches read it with near-zero overhead.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro import obs

from . import registry
from .cache import TuneCache, cache_key, shape_bucket


class TuneError(RuntimeError):
    """No eligible candidate survived the budget and parity gates."""


def _budget_skip(cand: registry.Candidate, ctx: dict) -> str | None:
    """Static resource check; returns the skip reason or None."""
    if cand.kernel_specs is None:
        return None
    from repro.analysis import resources
    for spec in cand.kernel_specs(ctx):
        for f in resources.analyze_spec(spec):
            if f.rule in ("vmem-overflow", "smem-overflow"):
                return f"{f.rule} ({spec.name}): {f.message}"
    return None


def _measure_us(runner, *, warmup: int, k: int) -> float:
    for _ in range(warmup):
        jax.block_until_ready(runner())
    ts = []
    for _ in range(k):
        t0 = time.perf_counter()
        jax.block_until_ready(runner())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def tune_op(op_name: str, *, n: int, dtype: str,
            cache: TuneCache | None = None, k: int = 3, warmup: int = 1,
            force: bool = False, log=print) -> tuple[dict, bool]:
    """Tune one op at one workload point; returns ``(entry, measured)``.

    ``measured`` is False exactly when the entry came from the cache — the
    invariant the CI double-run pins.
    """
    from . import dispatch
    if cache is None:
        cache = dispatch.active_cache()
    backend, arch = dispatch.backend(), dispatch.arch()
    key = cache_key(backend, op_name, n, dtype, arch)
    hit = cache.get(key)
    if hit is not None and not force:
        obs.counter("tune_cache", result="hit", site="tuner").inc()
        return hit, False
    obs.counter("tune_cache", result="miss", site="tuner").inc()

    spec = registry.op(op_name)
    ctx = spec.make_context(n=n, dtype=dtype)
    cands = registry.candidates(op_name, backend=backend)
    if not any(c.impl == spec.reference for c in cands):
        raise TuneError(f"{op_name}: reference impl {spec.reference!r} "
                        f"not registered for backend {backend!r}")
    ref_out = None
    measured: dict[str, float] = {}
    skipped: dict[str, str] = {}
    rejected: dict[str, str] = {}
    # reference first: every other candidate is gated against its output
    for cand in sorted(cands, key=lambda c: c.impl != spec.reference):
        why = _budget_skip(cand, ctx)
        if why is not None:
            skipped[cand.impl] = why
            obs.counter("tune_skipped", op=op_name, impl=cand.impl).inc()
            log(f"tune: {op_name}[{cand.impl}] n={n} skipped: {why}")
            continue
        runner = cand.make_runner(ctx)
        out = jax.block_until_ready(runner())
        if cand.impl == spec.reference:
            ref_out = out
        else:
            if ref_out is None:
                rejected[cand.impl] = "no reference output to gate against"
                continue
            err = spec.parity(ctx, out, ref_out)
            if err is not None:
                rejected[cand.impl] = err
                obs.counter("tune_parity_rejected", op=op_name,
                            impl=cand.impl).inc()
                log(f"tune: {op_name}[{cand.impl}] n={n} REJECTED "
                    f"({spec.gate} gate): {err}")
                continue
        with obs.span("tune.measure", op=op_name, impl=cand.impl):
            measured[cand.impl] = _measure_us(runner, warmup=warmup, k=k)
        obs.counter("tune_measurements", op=op_name, impl=cand.impl).inc()
    if not measured:
        raise TuneError(f"{op_name}: no candidate survived "
                        f"(skipped={skipped}, rejected={rejected})")
    winner = min(measured, key=measured.get)
    entry = {
        "impl": winner, "measured_us": measured, "skipped": skipped,
        "rejected": rejected, "backend": backend, "arch": arch,
        "op": op_name, "bucket": shape_bucket(n), "dtype": dtype,
        "gate": spec.gate, "k": k, "warmup": warmup,
    }
    cache.put(key, entry)
    cache.save()
    dispatch.invalidate_memo()
    obs.counter("tune_selected", op=op_name, impl=winner, site="tuner").inc()
    pretty = ", ".join(f"{i}={measured[i]:.0f}us" for i in sorted(measured))
    log(f"tune: {op_name} n={n} {dtype} [{backend}/{arch}] -> "
        f"{winner} ({pretty})")
    return entry, True


def ensure_tuned(workloads, *, cache: TuneCache | None = None, k: int = 3,
                 warmup: int = 1, force: bool = False, log=print) -> dict:
    """Tune a list of ``(op, n, dtype)`` points; returns a summary dict with
    per-point results plus hit/miss/measurement totals (what the CI tune
    step parses)."""
    from . import dispatch
    if cache is None:
        cache = dispatch.active_cache()
    results, hits, misses, n_measured = [], 0, 0, 0
    for op_name, n, dtype in workloads:
        entry, measured_now = tune_op(op_name, n=n, dtype=dtype, cache=cache,
                                      k=k, warmup=warmup, force=force, log=log)
        hits += not measured_now
        misses += measured_now
        n_measured += len(entry["measured_us"]) if measured_now else 0
        results.append({"op": op_name, "n": n, "dtype": dtype,
                        "impl": entry["impl"], "measured": measured_now,
                        "measured_us": entry["measured_us"],
                        "skipped": entry["skipped"],
                        "rejected": entry["rejected"]})
    return {"results": results, "hits": hits, "misses": misses,
            "measurements": n_measured, "backend": dispatch.backend(),
            "arch": dispatch.arch(), "cache_path": str(cache.path),
            "cache_entries": len(cache)}
