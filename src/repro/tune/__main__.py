"""``python -m repro.tune`` — pre-tune the kernel table / dump the cache.

The smoke workload set covers the shapes the CI tiers dispatch: the FZ
property/bench leaves (one-tile and bench-grid sizes, f32 + bf16) and the
serve-smoke decode-attention geometry. ``--json`` prints a machine-readable
summary (per-point winner + hit/miss/measurement totals) that
``scripts/ci.sh`` parses to assert a second invocation is pure cache hits.
"""
from __future__ import annotations

import argparse
import json
import sys

from . import cache as _cache
from . import dispatch, registry
from .impls import attn_cache_elems
from .tuner import ensure_tuned

# (op, n, dtype) points matching the CI dispatch sites: 4096 = one-tile
# property leaves, 65536 = the bench smoke grid (32*64*32), bf16 = KV pages;
# the attention point is the serve smoke pool geometry (seq_capacity=32,
# glm4-9b smoke heads)
SMOKE_WORKLOADS = (
    ("fz.compress", 4096, "float32"),
    ("fz.decompress", 4096, "float32"),
    ("fz.compress", 65536, "float32"),
    ("fz.decompress", 65536, "float32"),
    ("fz.compress", 65536, "bfloat16"),
    ("fz.decompress", 65536, "bfloat16"),
    ("decode_attention", attn_cache_elems(32, 2, 64), "bfloat16"),
)

FULL_NS = (4096, 65536, 1 << 20)


def _full_workloads():
    out = []
    for n in FULL_NS:
        for dtype in ("float32", "bfloat16"):
            out.append(("fz.compress", n, dtype))
            out.append(("fz.decompress", n, dtype))
    for s in (1024, 4096):
        out.append(("decode_attention", attn_cache_elems(s, 2, 64),
                    "bfloat16"))
    return tuple(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.tune",
        description="pre-tune the kernel dispatch table")
    ap.add_argument("--smoke", action="store_true",
                    help="tune the small CI workload set")
    ap.add_argument("--cache", default=None,
                    help=f"cache file (default ${_cache.ENV_VAR} "
                         f"or ~/.cache/repro/tune_cache.json)")
    ap.add_argument("--ops", default=None,
                    help="comma-separated op filter (default: all)")
    ap.add_argument("--k", type=int, default=3, help="timing reps per candidate")
    ap.add_argument("--warmup", type=int, default=1)
    ap.add_argument("--force", action="store_true",
                    help="re-measure even on cache hits")
    ap.add_argument("--json", action="store_true",
                    help="print a machine-readable summary to stdout")
    ap.add_argument("--dump", action="store_true",
                    help="print the cached table and exit (no tuning)")
    args = ap.parse_args(argv)

    tc = dispatch.configure(args.cache)
    log = (lambda *a: print(*a, file=sys.stderr)) if args.json else print

    if args.dump:
        doc = {"schema": _cache.SCHEMA_VERSION, "path": str(tc.path),
               "status": tc.status, "entries": tc.entries}
        if args.json:
            print(json.dumps(doc, indent=1, sort_keys=True))
        else:
            print(f"# {tc.path} [{tc.status}]")
            for key in sorted(tc.entries):
                e = tc.entries[key]
                us = ", ".join(f"{i}={v:.0f}us" for i, v in
                               sorted(e.get("measured_us", {}).items()))
                print(f"{key} -> {e.get('impl')} ({us})")
        return 0

    workloads = SMOKE_WORKLOADS if args.smoke else _full_workloads()
    if args.ops:
        keep = {o.strip() for o in args.ops.split(",")}
        unknown = keep - set(registry.ops())
        if unknown:
            ap.error(f"unknown ops {sorted(unknown)}; known {registry.ops()}")
        workloads = tuple(w for w in workloads if w[0] in keep)

    summary = ensure_tuned(workloads, cache=tc, k=args.k, warmup=args.warmup,
                           force=args.force, log=log)
    # this process's tune_* counters ride along as evidence: the CI tune
    # step pins "second run = pure hits" on tune_cache{result=hit,...}
    from repro import obs
    summary["counters"] = {k: v for k, v in obs.snapshot()["counters"].items()
                           if k.startswith(("tune_cache{", "tune_selected{",
                                            "tune_measurements{",
                                            "tune_skipped{",
                                            "tune_parity_rejected{"))}
    if args.json:
        print(json.dumps(summary, indent=1, sort_keys=True))
    else:
        print(f"tuned {summary['misses']} point(s), {summary['hits']} cache "
              f"hit(s), {summary['measurements']} measurement(s) "
              f"[{summary['backend']}/{summary['arch']}] -> "
              f"{summary['cache_path']}")
        for r in summary["results"]:
            print(f"  {r['op']} n={r['n']} {r['dtype']}: {r['impl']}"
                  f"{'' if r['measured'] else ' (cached)'}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
