"""Per-backend kernel registry: ops, candidate implementations, parity gates.

This replaces the boolean ``ops.backend_interpret()`` fork as the routing
vocabulary: an :class:`OpSpec` names a tunable operation and its *reference*
implementation (the correctness oracle), and each :class:`Candidate`
registers one implementation of that op together with the backends it may
run on. New backends (TPU Mosaic, GPU Triton) join by registering more
candidates — callers never grow another ``if backend == ...`` arm.

The registry is deliberately data-only: measurement and selection live in
:mod:`tuner`, the cached-winner lookup in :mod:`dispatch`. The built-in
candidates (reference / staged / fused FZ paths, jnp / Pallas decode
attention) are registered by importing :mod:`impls`, which happens lazily on
first lookup so ``repro.tune`` stays import-light.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Callable

BACKENDS = ("interpret", "tpu", "gpu")


@dataclasses.dataclass(frozen=True)
class OpSpec:
    """One tunable operation.

    ``make_context(n=..., dtype=...)`` builds the shared workload (inputs,
    precomputed reference artifacts) every candidate of the op runs against.
    ``parity(ctx, out, ref_out)`` returns ``None`` when ``out`` is acceptable
    against the reference output, else a human-readable rejection reason —
    bit-identity for decode paths, the error-bound invariant for compress.
    ``gate`` labels the parity discipline for logs and cache entries.
    """
    name: str
    reference: str
    make_context: Callable[..., dict]
    parity: Callable[[dict, object, object], str | None]
    gate: str


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One implementation of an op on some backends.

    ``make_runner(ctx)`` returns a zero-arg callable producing the op's
    output (the tuner blocks on it for timing). ``kernel_specs(ctx)``, when
    present, builds the :mod:`repro.analysis` KernelSpecs this candidate
    would launch at the context's geometry — the tuner statically checks
    them against hardware budgets and *skips* (never measures) candidates
    that would overflow VMEM/SMEM.
    """
    op: str
    impl: str
    make_runner: Callable[[dict], Callable[[], object]]
    backends: tuple[str, ...] = BACKENDS
    kernel_specs: Callable[[dict], list] | None = None


_OPS: dict[str, OpSpec] = {}
_CANDS: dict[str, dict[str, Candidate]] = {}
_builtin_loaded = False


def _ensure_builtin() -> None:
    global _builtin_loaded
    if not _builtin_loaded:
        _builtin_loaded = True
        from . import impls  # noqa: F401  -- registers the built-in candidates


def register_op(spec: OpSpec) -> OpSpec:
    _OPS[spec.name] = spec
    _CANDS.setdefault(spec.name, {})
    return spec


def register(cand: Candidate) -> Candidate:
    if cand.op not in _OPS:
        raise KeyError(f"candidate {cand.impl!r} for unregistered op {cand.op!r}")
    _CANDS[cand.op][cand.impl] = cand
    return cand


def op(name: str) -> OpSpec:
    _ensure_builtin()
    try:
        return _OPS[name]
    except KeyError:
        raise KeyError(f"unknown tunable op {name!r}; known: {sorted(_OPS)}") from None


def ops() -> tuple[str, ...]:
    _ensure_builtin()
    return tuple(sorted(_OPS))


def candidates(op_name: str, backend: str | None = None) -> list[Candidate]:
    _ensure_builtin()
    cands = list(_CANDS.get(op_name, {}).values())
    if backend is not None:
        cands = [c for c in cands if backend in c.backends]
    return cands


@contextlib.contextmanager
def scoped(cand: Candidate):
    """Temporarily register a candidate (tests seed wrong-output impls)."""
    register(cand)
    try:
        yield cand
    finally:
        if _CANDS.get(cand.op, {}).get(cand.impl) is cand:
            del _CANDS[cand.op][cand.impl]
