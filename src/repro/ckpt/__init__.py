from . import checkpoint, elastic  # noqa: F401
from .checkpoint import latest_step, restore, save  # noqa: F401
