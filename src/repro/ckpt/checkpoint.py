"""Checkpoint/restart: atomic, checksummed, FZ-compressible, keep-last-k.

Layout (one directory per step):
    <root>/step_000123/
        manifest.json     # leaf paths, shapes, dtypes, checksums, codec, meta
        <leaf-000...>.bin # raw little-endian bytes or FZ stream
    <root>/LATEST         # atomically-renamed pointer file

Fault-tolerance contract (exercised by tests/test_ckpt.py):
  * atomic publish: a crash mid-save never corrupts LATEST (tmp dir + rename);
  * integrity: every leaf carries a crc32; restore verifies before use;
  * resume: (step, data cursor, rng) round-trip bitwise; training continues
    exactly (same loss sequence) after restart;
  * keep-last-k garbage collection;
  * codec "fz": error-bounded lossy compression of float leaves (the paper's
    GPU->disk use case, §2.4) with exact outliers ON; small/int leaves stay
    raw. The manifest records exact compressed bytes for the ratio report.
"""
from __future__ import annotations

import json
import os
import shutil
import zlib
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

try:  # register "bfloat16" et al. with numpy's dtype registry
    import ml_dtypes  # noqa: F401
except ImportError:
    pass

from repro.core import fz

_FZ_CKPT = fz.FZConfig(eb=1e-5, eb_mode="rel", exact_outliers=True,
                       outlier_frac=1 / 64, use_kernels=False)
_MIN_FZ_SIZE = 65_536


def _leaf_paths(tree: Any) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(kp), leaf) for kp, leaf in flat]


def _serialize_fz(arr: np.ndarray) -> bytes:
    """Host-side exact FZ byte stream (header + bitflags + blocks + outliers)."""
    x = jnp.asarray(arr.reshape(-1), jnp.float32)
    c = fz.compress(x, _FZ_CKPT)
    nnz = int(c.nnz_blocks)
    n_out = int(c.n_outliers)
    parts = [
        np.asarray([arr.size, nnz, n_out], np.int64).tobytes(),
        np.asarray(c.eb_abs, np.float32).tobytes(),
        np.asarray(c.bitflags).tobytes(),
        np.asarray(c.payload)[:nnz].tobytes(),
        np.asarray(c.outlier_idx)[:n_out].tobytes(),
        np.asarray(c.outlier_val)[:n_out].tobytes(),
    ]
    return b"".join(parts)


def _deserialize_fz(raw: bytes, shape, dtype) -> np.ndarray:
    n, nnz, n_out = np.frombuffer(raw[:24], np.int64)
    eb = np.frombuffer(raw[24:28], np.float32)[0]
    off = 28
    nb = fz.FZConfig.n_blocks(int(n))
    nflag_words = (nb + 31) // 32
    bitflags = np.frombuffer(raw[off:off + 4 * nflag_words], np.uint32); off += 4 * nflag_words
    payload = np.frombuffer(raw[off:off + 16 * int(nnz)], np.uint16).reshape(int(nnz), 8); off += 16 * int(nnz)
    oidx = np.frombuffer(raw[off:off + 4 * int(n_out)], np.int32); off += 4 * int(n_out)
    oval = np.frombuffer(raw[off:off + 4 * int(n_out)], np.int32)
    cap = _FZ_CKPT.payload_capacity(int(n))
    pay = np.zeros((cap, 8), np.uint16)
    pay[: int(nnz)] = payload
    ocap = _FZ_CKPT.outlier_capacity(int(n))
    oi = np.full((ocap,), int(n), np.int32); oi[: int(n_out)] = oidx
    ov = np.zeros((ocap,), np.int32); ov[: int(n_out)] = oval
    c = fz.FZCompressed(
        bitflags=jnp.asarray(bitflags), payload=jnp.asarray(pay),
        nnz_blocks=jnp.int32(nnz), outlier_idx=jnp.asarray(oi),
        outlier_val=jnp.asarray(ov), n_outliers=jnp.int32(n_out),
        eb_abs=jnp.float32(eb), shape=(int(n),), dtype_name="float32")
    rec = np.asarray(fz.decompress(c, _FZ_CKPT))
    return rec.astype(dtype).reshape(shape)


def save(root: str, step: int, tree: Any, *, meta: dict | None = None,
         codec: str = "raw", keep_last: int = 3) -> str:
    """Atomic checkpoint write. codec: "raw" | "fz"."""
    host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
    name = f"step_{step:08d}"
    tmp = os.path.join(root, f".tmp_{name}")
    final = os.path.join(root, name)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "meta": meta or {}, "codec": codec, "leaves": []}
    for i, (path, leaf) in enumerate(_leaf_paths(host)):
        fname = f"leaf_{i:06d}.bin"
        use_fz = (codec == "fz" and leaf.dtype.kind == "f" and leaf.size >= _MIN_FZ_SIZE)
        raw = _serialize_fz(leaf) if use_fz else leaf.tobytes()
        with open(os.path.join(tmp, fname), "wb") as f:
            f.write(raw)
        manifest["leaves"].append({
            "path": path, "file": fname, "shape": list(leaf.shape),
            "dtype": leaf.dtype.name if leaf.dtype.kind != "V" else str(leaf.dtype),
            "codec": "fz" if use_fz else "raw",
            "crc32": zlib.crc32(raw), "bytes": len(raw),
            "raw_bytes": int(leaf.nbytes),
        })
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic publish
    latest_tmp = os.path.join(root, ".LATEST_tmp")
    with open(latest_tmp, "w") as f:
        f.write(name)
    os.replace(latest_tmp, os.path.join(root, "LATEST"))
    _gc(root, keep_last)
    return final


def _gc(root: str, keep_last: int) -> None:
    steps = sorted(d for d in os.listdir(root) if d.startswith("step_"))
    for d in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(os.path.join(root, d), ignore_errors=True)


def latest_step(root: str) -> int | None:
    try:
        with open(os.path.join(root, "LATEST")) as f:
            return int(f.read().strip().split("_")[1])
    except (FileNotFoundError, IndexError, ValueError):
        return None


def restore(root: str, tree_like: Any, *, step: int | None = None,
            shardings: Any | None = None) -> tuple[Any, dict]:
    """Restore into the structure of ``tree_like`` (shape/dtype template).

    ``shardings``: optional matching pytree of NamedShardings — leaves are
    device_put directly to their shards (elastic restore onto any mesh).
    """
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {root}")
    d = os.path.join(root, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves_meta = manifest["leaves"]
    flat, treedef = jax.tree.flatten(tree_like)
    assert len(flat) == len(leaves_meta), (len(flat), len(leaves_meta))
    sh_flat = treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(flat)
    out = []
    for meta_l, like, sh in zip(leaves_meta, flat, sh_flat):
        with open(os.path.join(d, meta_l["file"]), "rb") as f:
            raw = f.read()
        if zlib.crc32(raw) != meta_l["crc32"]:
            raise IOError(f"checksum mismatch in {meta_l['file']} (corrupt checkpoint)")
        if meta_l["codec"] == "fz":
            arr = _deserialize_fz(raw, meta_l["shape"], np.dtype(meta_l["dtype"]))
        else:
            arr = np.frombuffer(raw, np.dtype(meta_l["dtype"])).reshape(meta_l["shape"])
        out.append(jax.device_put(arr, sh) if sh is not None else jnp.asarray(arr))
    return treedef.unflatten(out), manifest["meta"] | {"step": manifest["step"]}


def compression_report(root: str, step: int) -> dict:
    d = os.path.join(root, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    raw = sum(l["raw_bytes"] for l in manifest["leaves"])
    stored = sum(l["bytes"] for l in manifest["leaves"])
    return {"raw_bytes": raw, "stored_bytes": stored, "ratio": raw / max(stored, 1)}
