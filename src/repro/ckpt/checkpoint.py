"""Checkpoint/restart: atomic, checksummed, FZ-compressible, keep-last-k.

Layout (one directory per step):
    <root>/step_000123/
        manifest.json     # leaf paths, shapes, dtypes, checksums, codec, meta
        <leaf-000...>.bin # raw little-endian bytes or a serialized FZ container
    <root>/LATEST         # atomically-renamed pointer file

Fault-tolerance contract (exercised by tests/test_ckpt.py):
  * atomic publish: a crash mid-save never corrupts LATEST (tmp dir + rename);
  * integrity: every leaf carries a crc32; restore verifies before use;
  * resume: (step, data cursor, rng) round-trip bitwise; training continues
    exactly (same loss sequence) after restart;
  * keep-last-k garbage collection;
  * codec "fz": error-bounded lossy compression of float leaves (the paper's
    GPU->disk use case, §2.4) with exact outliers ON; small/int leaves stay
    raw. The manifest records exact compressed bytes for the ratio report.

FZ leaves are stored as the versioned byte container of
``fz.to_bytes`` (spec: docs/CONTAINER_FORMAT.md) with the second-stage
entropy coder in ``"auto"`` mode — checkpoints are the canonical cold tier
(arXiv 2507.11165's lossy-lossless orchestration: save latency buys extra
ratio; the probe skips leaves the Huffman stage cannot shrink). Restore
routes on the container header, so checkpoints written *before* the format
was versioned (the headerless pre-v1 stream) restore unchanged via
``fz.from_bytes``'s legacy fallback; the whole-checkpoint achieved ratio
feeds the ``ckpt`` tier EWMA (`repro.obs.sentinels`).
"""
from __future__ import annotations

import json
import os
import shutil
import zlib
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

try:  # register "bfloat16" et al. with numpy's dtype registry
    import ml_dtypes  # noqa: F401
except ImportError:
    pass

from repro import obs
from repro.core import fz

_FZ_CKPT = fz.FZConfig(eb=1e-5, eb_mode="rel", exact_outliers=True,
                       outlier_frac=1 / 64, use_kernels=False)
_MIN_FZ_SIZE = 65_536


def _leaf_paths(tree: Any) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(kp), leaf) for kp, leaf in flat]


def _serialize_fz(arr: np.ndarray) -> bytes:
    """One float leaf -> a serialized v1 FZ container, entropy-probe gated
    (docs/CONTAINER_FORMAT.md). Flattened: the container records shape (n,);
    the manifest keeps the real shape/dtype for reconstruction."""
    x = jnp.asarray(arr.reshape(-1), jnp.float32)
    c = fz.compress(x, _FZ_CKPT)
    return fz.to_bytes(c, _FZ_CKPT, entropy="auto", tier="ckpt")


def _deserialize_fz(raw: bytes, shape, dtype) -> np.ndarray:
    """Reconstruct a leaf from any supported container version — v1 (with or
    without the entropy stage, routed by the header flag) or the legacy
    headerless pre-versioning stream."""
    rec = np.asarray(fz.decompress_bytes(raw, tier="ckpt"))
    return rec.astype(dtype).reshape(shape)


def save(root: str, step: int, tree: Any, *, meta: dict | None = None,
         codec: str = "raw", keep_last: int = 3) -> str:
    """Atomic checkpoint write. codec: "raw" | "fz"."""
    host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
    name = f"step_{step:08d}"
    tmp = os.path.join(root, f".tmp_{name}")
    final = os.path.join(root, name)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "meta": meta or {}, "codec": codec, "leaves": []}
    for i, (path, leaf) in enumerate(_leaf_paths(host)):
        fname = f"leaf_{i:06d}.bin"
        use_fz = (codec == "fz" and leaf.dtype.kind == "f" and leaf.size >= _MIN_FZ_SIZE)
        raw = _serialize_fz(leaf) if use_fz else leaf.tobytes()
        with open(os.path.join(tmp, fname), "wb") as f:
            f.write(raw)
        manifest["leaves"].append({
            "path": path, "file": fname, "shape": list(leaf.shape),
            "dtype": leaf.dtype.name if leaf.dtype.kind != "V" else str(leaf.dtype),
            "codec": "fz" if use_fz else "raw",
            "crc32": zlib.crc32(raw), "bytes": len(raw),
            "raw_bytes": int(leaf.nbytes),
        })
    if codec == "fz":
        # one whole-checkpoint ratio sample per save: stable across saves of
        # the same model, unlike per-leaf ratios (embeddings vs layernorms
        # legitimately differ by more than the drift factor)
        obs.note_ratio("ckpt",
                       sum(l["raw_bytes"] for l in manifest["leaves"])
                       / max(sum(l["bytes"] for l in manifest["leaves"]), 1))
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic publish
    latest_tmp = os.path.join(root, ".LATEST_tmp")
    with open(latest_tmp, "w") as f:
        f.write(name)
    os.replace(latest_tmp, os.path.join(root, "LATEST"))
    _gc(root, keep_last)
    return final


def _gc(root: str, keep_last: int) -> None:
    steps = sorted(d for d in os.listdir(root) if d.startswith("step_"))
    for d in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(os.path.join(root, d), ignore_errors=True)


def latest_step(root: str) -> int | None:
    try:
        with open(os.path.join(root, "LATEST")) as f:
            return int(f.read().strip().split("_")[1])
    except (FileNotFoundError, IndexError, ValueError):
        return None


def restore(root: str, tree_like: Any, *, step: int | None = None,
            shardings: Any | None = None) -> tuple[Any, dict]:
    """Restore into the structure of ``tree_like`` (shape/dtype template).

    ``shardings``: optional matching pytree of NamedShardings — leaves are
    device_put directly to their shards (elastic restore onto any mesh).
    """
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {root}")
    d = os.path.join(root, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves_meta = manifest["leaves"]
    flat, treedef = jax.tree.flatten(tree_like)
    assert len(flat) == len(leaves_meta), (len(flat), len(leaves_meta))
    sh_flat = treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(flat)
    out = []
    for meta_l, like, sh in zip(leaves_meta, flat, sh_flat):
        with open(os.path.join(d, meta_l["file"]), "rb") as f:
            raw = f.read()
        if zlib.crc32(raw) != meta_l["crc32"]:
            raise IOError(f"checksum mismatch in {meta_l['file']} (corrupt checkpoint)")
        if meta_l["codec"] == "fz":
            arr = _deserialize_fz(raw, meta_l["shape"], np.dtype(meta_l["dtype"]))
        else:
            arr = np.frombuffer(raw, np.dtype(meta_l["dtype"])).reshape(meta_l["shape"])
        out.append(jax.device_put(arr, sh) if sh is not None else jnp.asarray(arr))
    return treedef.unflatten(out), manifest["meta"] | {"step": manifest["step"]}


def compression_report(root: str, step: int) -> dict:
    d = os.path.join(root, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    raw = sum(l["raw_bytes"] for l in manifest["leaves"])
    stored = sum(l["bytes"] for l in manifest["leaves"])
    return {"raw_bytes": raw, "stored_bytes": stored, "ratio": raw / max(stored, 1)}
