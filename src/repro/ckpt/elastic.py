"""Elastic rescaling: move a training state onto a different mesh.

Node failures / capacity changes are handled by re-instantiating the mesh at
the new device count and re-laying-out the checkpointed state:

    new_mesh  = make_mesh(new_shape, axes)
    new_shard = tree_shardings(logical_specs, abstract, new_mesh)
    state     = ckpt.restore(root, template, shardings=new_shard)

Because shardings are *resolved from logical axis names per mesh* (dist/
sharding.py), no model or optimizer code changes across mesh shapes; the only
constraint is divisibility, which resolve_spec relaxes to replication when
violated. Data-stream determinism across rescaling is provided by
data/tokens.py (shard assignment is a pure function of step and index).

Codec-independent: ``ckpt.restore`` decodes each leaf on the host (raw bytes
or an FZ byte container of any supported version — docs/CONTAINER_FORMAT.md)
before ``device_put`` to the new shards, so rescaling works identically for
raw and fz-codec checkpoints, including pre-versioning ones.
"""
from __future__ import annotations

from typing import Any

import jax

from repro.dist import sharding as shd


def reshard(tree: Any, logical: Any, new_mesh) -> Any:
    """Live reshard (device-to-device) of a pytree onto a new mesh."""
    abstract = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    new_sh = shd.tree_shardings(logical, abstract, new_mesh)
    return jax.device_put(tree, new_sh)


def replan_batch(global_batch: int, new_mesh) -> dict:
    """Recompute per-host batch assignment after a topology change."""
    dp = 1
    for ax in ("pod", "data"):
        if ax in new_mesh.shape:
            dp *= new_mesh.shape[ax]
    if global_batch % dp:
        raise ValueError(f"global batch {global_batch} not divisible by dp={dp}")
    return {"dp_shards": dp, "per_shard": global_batch // dp}
