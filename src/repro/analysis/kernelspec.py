"""KernelSpec: the declarative contract between Pallas call sites and the
static analyzer.

Every ``pl.pallas_call`` site in :mod:`repro.kernels` declares a *spec
builder* — a plain-Python function that, given a concrete geometry point
(shape, dtype itemsize, capacity fraction, attention dims, ...), returns a
:class:`KernelSpec` describing exactly what that call would launch: grid,
per-grid-step block shapes and dtypes, scratch shapes and memory spaces,
``dimension_semantics``, and the kernel body function itself. Builders are
required to route through the same geometry helpers the real wrapper uses
(``plan_stream``, ``band_for``, the module TILE constants), so a spec cannot
silently drift from the launch it describes.

This module is import-light on purpose: kernels import it (to register their
builders) and the analysis passes import the kernels (to collect them), so
nothing here may import the passes or jax. Dtypes are therefore carried as
``(name, itemsize)`` pairs, not jnp dtypes.

The three analysis passes consume specs as follows:

  * ``analysis.resources`` pads each block/scratch buffer to the TPU tile
    grid for its dtype and sums the per-grid-step VMEM/SMEM footprint;
  * ``analysis.carry``  classifies scratch refs (and revisited output
    blocks) as cross-step carries via AST inspection of ``kernel_fn`` and
    checks them against ``dimension_semantics``;
  * both report through ``analysis.report`` keyed by ``KernelSpec.name``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

VMEM = "vmem"
SMEM = "smem"

# dtype name -> itemsize, for declaring buffers without importing jnp
ITEMSIZE = {
    "float32": 4, "int32": 4, "uint32": 4,
    "bfloat16": 2, "float16": 2, "uint16": 2, "int16": 2,
    "int8": 1, "uint8": 1, "bool": 1,
}


@dataclasses.dataclass(frozen=True)
class BlockDecl:
    """One input or output BlockSpec of a pallas_call.

    ``index_map`` is the real (or faithfully re-stated) BlockSpec index map;
    the passes probe it with integer grid coordinates to learn which grid
    axes it ignores (a revisited block) and whether it varies at all (a
    varying block is double-buffered by the Pallas pipeline; a resident one
    is not).
    """
    name: str
    shape: tuple[int, ...]
    dtype: str
    memory: str = VMEM
    index_map: Callable | None = None

    @property
    def itemsize(self) -> int:
        return ITEMSIZE[self.dtype]

    @property
    def elems(self) -> int:
        return math.prod(self.shape) if self.shape else 1


@dataclasses.dataclass(frozen=True)
class ScratchDecl:
    """One scratch_shapes entry (VMEM or SMEM) of a pallas_call."""
    name: str
    shape: tuple[int, ...]
    dtype: str
    memory: str = VMEM

    @property
    def itemsize(self) -> int:
        return ITEMSIZE[self.dtype]

    @property
    def elems(self) -> int:
        return math.prod(self.shape) if self.shape else 1


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """Static declaration of one pallas_call launch at one geometry point.

    ``critical_lanes`` names in-kernel lane-width-critical dimensions that
    are *not* the trailing axis of any block (e.g. flash-decode's KV tile,
    which lands on the lane axis of the score matrix after the einsum); the
    resource pass flags entries below the 128-lane VPU width.

    ``point`` is a short deterministic description of the geometry point the
    spec was built at; it feeds finding messages, never finding keys, so the
    committed baseline stays stable as the evaluated space grows.
    """
    name: str                                  # unique call-site name
    module: str                                # defining module (repro.kernels.*)
    grid: tuple[int, ...]
    in_blocks: tuple[BlockDecl, ...]
    out_blocks: tuple[BlockDecl, ...]
    scratch: tuple[ScratchDecl, ...] = ()
    dimension_semantics: tuple[str, ...] | None = None
    kernel_fn: Callable | None = None          # body, for carry AST analysis
    critical_lanes: tuple[tuple[str, int], ...] = ()
    point: str = ""

    def blocks(self) -> tuple[BlockDecl, ...]:
        return self.in_blocks + self.out_blocks


# --------------------------------------------------------------------------
# Builder registry
# --------------------------------------------------------------------------

_BUILDERS: dict[str, Callable] = {}


def register_spec(name: str):
    """Decorator: register ``fn(**point) -> KernelSpec`` under ``name``.

    One registration per pallas_call site; re-registering a name overwrites
    (module reload safety), never accumulates.
    """
    def deco(fn):
        _BUILDERS[name] = fn
        return fn
    return deco


def spec_builders() -> dict[str, Callable]:
    """name -> builder for every registered pallas_call site.

    Importing :mod:`repro.kernels` is what populates the registry; callers
    (the analysis passes) do that import themselves so this module stays
    jax-free.
    """
    return dict(_BUILDERS)


def probe_index_map(index_map: Callable | None,
                    grid: Sequence[int]) -> tuple[tuple[int, ...], bool]:
    """(ignored_axes, varies): which grid axes the map ignores, and whether
    the block address varies over the grid at all.

    Probes with small in-range integer coordinates; index maps built from
    jnp ops return arrays, which compare fine under ``int()``.
    """
    if index_map is None or not grid:
        return (), True

    def at(coords):
        out = index_map(*coords)
        if not isinstance(out, tuple):
            out = (out,)
        return tuple(int(c) for c in out)

    base = [0] * len(grid)
    ignored = []
    for ax, extent in enumerate(grid):
        seen = {at(tuple(base[:ax] + [i] + base[ax + 1:]))
                for i in range(min(int(extent), 3))}
        if len(seen) == 1:
            ignored.append(ax)
    varies = len(ignored) < len(grid)
    return tuple(ignored), varies
