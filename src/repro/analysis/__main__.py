"""CLI: ``python -m repro.analysis [--check] [--json PATH] [--passes ...]``.

Exit status: 0 when the tree is clean modulo the committed baseline
(``analysis/baseline.json``); 1 under ``--check`` when any new finding
appears (this is the ``scripts/ci.sh analyze`` gate). ``--update-baseline``
rewrites the allowlist from the current findings — a deliberate, reviewed
act, never done in CI.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

from . import report as _report

PASSES = ("resources", "carry", "jitlint", "style")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static kernel-resource + jit-discipline analyzer")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 on findings not in the committed baseline")
    ap.add_argument("--json", metavar="PATH",
                    help="write the full report as JSON ('-' for stdout)")
    ap.add_argument("--passes", default=",".join(PASSES),
                    help=f"comma-separated subset of {PASSES}")
    ap.add_argument("--baseline", metavar="PATH",
                    help="alternate baseline file (default: committed)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from current findings")
    args = ap.parse_args(argv)

    passes = tuple(p.strip() for p in args.passes.split(",") if p.strip())
    unknown = set(passes) - set(PASSES)
    if unknown:
        ap.error(f"unknown pass(es) {sorted(unknown)}; choose from {PASSES}")
    bp = pathlib.Path(args.baseline) if args.baseline else None

    rep = _report.run_all(passes, baseline_path=bp)

    if args.update_baseline:
        reasons = _report.load_baseline(bp)
        _report.save_baseline(rep.findings, bp, reasons=reasons)
        print(f"baseline updated: {len(rep.findings)} accepted finding(s)")
        return 0

    if args.json == "-":
        json.dump(rep.to_json(), sys.stdout, indent=2)
        print()
    else:
        if args.json:
            pathlib.Path(args.json).write_text(
                json.dumps(rep.to_json(), indent=2) + "\n")
        print(rep.render_text())

    if args.check and not rep.clean:
        print(f"FAIL: {len(rep.new)} finding(s) not in baseline "
              f"(accept deliberately via --update-baseline)",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
