"""jit-discipline linter: retrace and trace-poison hazards, before merge.

PR 7's ``span_traces`` counter detects retraces *after the fact* — at
runtime, on whatever shapes the run happened to see. This pass moves the
same discipline to an AST check over ``src/repro`` so the bug classes that
cause retraces (or silently wrong trace-time work) fail CI before a kernel
ever launches.

Traced contexts are found syntactically: functions decorated with
``jax.jit`` (bare, called, or via ``functools.partial(jax.jit, ...)``) and
Pallas kernel bodies (functions whose positional params end in ``_ref``),
plus any ``def`` nested inside either. Within a traced context:

  * ``traced-branch``   — a Python ``if``/``while`` whose condition uses a
    traced parameter *value* (bare name or subscript). Exempt, because they
    are trace-constant: params named in ``static_argnames``; ``is`` /
    ``is not`` tests (None-ness is static under tracing); and any attribute
    access (``x.shape``, ``x.ndim``, ``plan.kern_nd`` — array metadata and
    config-dataclass fields, not traced values).
  * ``host-call``       — ``np.*`` / ``numpy.*`` calls, ``.item()`` /
    ``.tolist()`` / ``.block_until_ready()``, or ``float()/int()/bool()``
    applied directly to a traced param: all execute at trace time on
    tracers (TracerArrayConversionError at best, silent trace-time
    constant-folding at worst).
  * ``eager-obs-in-trace`` — ``obs.counter/histogram/gauge`` calls: these
    mutate the process-wide registry *per compilation*, not per dispatch
    (``obs.span`` is trace-safe by design and allowed).

And independent of context:

  * ``unknown-static-arg``   — ``static_argnames`` naming a parameter the
    function doesn't have (silent: jax ignores unknown names).
  * ``unhashable-static-arg`` — a static parameter whose default is a
    list/dict/set literal (TypeError on first call).

A style pass (``unused-import``, F401-equivalent, honoring ``# noqa``)
rides along so the tree keeps a lint floor even where the ruff wheel is
unavailable; ``[tool.ruff]`` in pyproject.toml is the full config when it
is.
"""
from __future__ import annotations

import ast
import pathlib

from .report import Finding

SRC_ROOT = pathlib.Path(__file__).resolve().parents[2]   # .../src
HOST_METHODS = {"item", "tolist", "block_until_ready", "copy_to_host_async"}
EAGER_OBS = {"counter", "histogram", "gauge"}
CASTS = {"float", "int", "bool"}


def _dotted(node: ast.expr) -> str:
    """'a.b.c' for Name/Attribute chains, '' otherwise."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _jit_decoration(node: ast.FunctionDef) -> tuple[bool, set[str]]:
    """(is_jitted, static_argnames) from the decorator list."""
    static: set[str] = set()
    jitted = False
    for dec in node.decorator_list:
        target, kwargs = dec, []
        if isinstance(dec, ast.Call):
            name = _dotted(dec.func)
            if name.endswith("partial") and dec.args:
                target, kwargs = dec.args[0], dec.keywords
            else:
                target, kwargs = dec.func, dec.keywords
        name = _dotted(target)
        if name in ("jax.jit", "jit"):
            jitted = True
            for kw in kwargs:
                if kw.arg == "static_argnames":
                    for el in ast.walk(kw.value):
                        if isinstance(el, ast.Constant) \
                                and isinstance(el.value, str):
                            static.add(el.value)
    return jitted, static


def _is_kernel_body(node: ast.FunctionDef) -> bool:
    args = [a.arg for a in node.args.args]
    if node.args.vararg is not None and node.args.vararg.arg == "refs":
        return True
    return len(args) >= 2 and sum(a.endswith("_ref") for a in args) >= 2


def _param_names(node: ast.FunctionDef) -> set[str]:
    names = {a.arg for a in node.args.args + node.args.kwonlyargs}
    if node.args.vararg:
        names.add(node.args.vararg.arg)
    return names


def _traced_value_names(cond: ast.expr, traced: set[str]) -> list[str]:
    """Traced params whose *value* (not a static attr) the expression uses."""
    hits = []

    class V(ast.NodeVisitor):
        def visit_Compare(self, node: ast.Compare):
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
                return                       # `x is None`: static trace-time
            self.generic_visit(node)

        def visit_Attribute(self, node: ast.Attribute):
            return              # x.shape / plan.kern_nd: trace-constant

        def visit_Name(self, node: ast.Name):
            if node.id in traced:
                hits.append(node.id)

    V().visit(cond)
    return hits


class _FileLinter(ast.NodeVisitor):
    def __init__(self, path: pathlib.Path, rel: str, discipline: bool):
        self.path, self.rel = path, rel
        self.discipline = discipline
        self.findings: list[Finding] = []
        self._ctx: list[tuple[str, set[str]]] = []   # (qualname, traced names)

    def _emit(self, rule: str, obj: str, msg: str, node: ast.AST,
              severity: str = "error"):
        self.findings.append(Finding(
            "jitlint", rule, f"{self.rel}:{obj}", msg, severity=severity,
            location=f"{self.rel}:{node.lineno}"))

    def visit_FunctionDef(self, node: ast.FunctionDef):
        jitted, static = _jit_decoration(node)
        params = _param_names(node)
        if jitted:
            unknown = static - params
            if unknown:
                self._emit("unknown-static-arg", node.name,
                           f"static_argnames {sorted(unknown)} not in "
                           f"signature {sorted(params)}", node)
            for a, default in _defaults(node):
                if a in static and isinstance(
                        default, (ast.List, ast.Dict, ast.Set)):
                    self._emit("unhashable-static-arg", node.name,
                               f"static arg {a!r} has an unhashable "
                               f"{type(default).__name__.lower()} default",
                               node)
        enters = jitted or _is_kernel_body(node) or bool(self._ctx)
        if enters and self.discipline:
            traced = params - static if (jitted or _is_kernel_body(node)) \
                else set()
            self._ctx.append((node.name, traced))
            self._lint_traced_body(node, traced)
            self.generic_visit(node)
            self._ctx.pop()
        else:
            self.generic_visit(node)

    visit_AsyncFunctionDef = visit_FunctionDef

    def _lint_traced_body(self, fn: ast.FunctionDef, traced: set[str]):
        qual = fn.name
        for node in ast.walk(fn):
            if isinstance(node, (ast.If, ast.While)):
                hits = _traced_value_names(node.test, traced)
                if hits:
                    kind = "while" if isinstance(node, ast.While) else "if"
                    self._emit("traced-branch", f"{qual}:{node.lineno}",
                               f"Python {kind} on traced value(s) "
                               f"{sorted(set(hits))} inside a jitted body — "
                               f"use jnp.where / lax.cond", node)
            elif isinstance(node, ast.Call):
                name = _dotted(node.func)
                root = name.split(".")[0] if name else ""
                if root in ("np", "numpy") and name.count(".") >= 1:
                    self._emit("host-call", f"{qual}:{node.lineno}",
                               f"host numpy call {name}() inside a jitted "
                               f"body executes at trace time", node)
                elif (isinstance(node.func, ast.Attribute)
                        and node.func.attr in HOST_METHODS):
                    self._emit("host-call", f"{qual}:{node.lineno}",
                               f".{node.func.attr}() inside a jitted body "
                               f"forces a host sync at trace time", node)
                elif (name in CASTS and node.args
                        and isinstance(node.args[0], ast.Name)
                        and node.args[0].id in traced):
                    self._emit("host-call", f"{qual}:{node.lineno}",
                               f"{name}() on traced param "
                               f"{node.args[0].id!r} raises under tracing",
                               node)
                elif name.startswith("obs.") \
                        and name.split(".")[1] in EAGER_OBS:
                    self._emit("eager-obs-in-trace", f"{qual}:{node.lineno}",
                               f"{name}() inside a jitted body records "
                               f"per-compilation, not per-dispatch — hoist "
                               f"to the eager wrapper (obs.span is the "
                               f"trace-safe primitive)", node)


def _defaults(node: ast.FunctionDef):
    args = node.args
    pos = args.args
    out = list(zip([a.arg for a in pos[len(pos) - len(args.defaults):]],
                   args.defaults))
    out += [(a.arg, d) for a, d in zip(args.kwonlyargs, args.kw_defaults)
            if d is not None]
    return out


# --------------------------------------------------------------------------
# Style pass: unused imports (F401-equivalent), honoring `# noqa`
# --------------------------------------------------------------------------

def _unused_imports(tree: ast.Module, source: str, rel: str) -> list[Finding]:
    lines = source.splitlines()
    imported: list[tuple[str, str, int]] = []    # (bound name, shown, lineno)
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for al in node.names:
                bound = al.asname or al.name.split(".")[0]
                imported.append((bound, al.name, node.lineno))
        elif isinstance(node, ast.ImportFrom):
            if node.module == "__future__":
                continue
            for al in node.names:
                if al.name == "*":
                    continue
                bound = al.asname or al.name
                imported.append((bound, al.name, node.lineno))
    used: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            used.add(node.id)
        elif isinstance(node, ast.Attribute):
            root = node
            while isinstance(root, ast.Attribute):
                root = root.value
            if isinstance(root, ast.Name):
                used.add(root.id)
    # names re-exported via __all__ count as used
    for node in ast.walk(tree):
        if (isinstance(node, ast.Assign)
                and any(isinstance(t, ast.Name) and t.id == "__all__"
                        for t in node.targets)):
            for el in ast.walk(node.value):
                if isinstance(el, ast.Constant) and isinstance(el.value, str):
                    used.add(el.value)
    out = []
    for bound, shown, lineno in imported:
        if bound in used or bound == "_":
            continue
        line = lines[lineno - 1] if lineno - 1 < len(lines) else ""
        if "noqa" in line:
            continue
        out.append(Finding(
            "style", "unused-import", f"{rel}:{lineno}:{bound}",
            f"{shown!r} imported but unused", location=f"{rel}:{lineno}"))
    return out


# --------------------------------------------------------------------------
# Entry points
# --------------------------------------------------------------------------

def lint_source(source: str, rel: str = "<string>", *,
                style: bool = False, discipline: bool = True
                ) -> list[Finding]:
    """Lint one module's source text (the unit the tests fixture against)."""
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Finding("jitlint", "syntax-error", rel, str(e),
                        location=f"{rel}:{e.lineno}")]
    out: list[Finding] = []
    if discipline:
        linter = _FileLinter(pathlib.Path(rel), rel, discipline=True)
        linter.visit(tree)
        out += linter.findings
    else:
        linter = _FileLinter(pathlib.Path(rel), rel, discipline=False)
        linter.visit(tree)
        out += [f for f in linter.findings
                if f.rule in ("unknown-static-arg", "unhashable-static-arg")]
    if style:
        out += _unused_imports(tree, source, rel)
    return out


def analyze(root: pathlib.Path | None = None, *, style: bool = True,
            discipline: bool = True) -> list[Finding]:
    root = root or (SRC_ROOT / "repro")
    out: list[Finding] = []
    for path in sorted(root.rglob("*.py")):
        rel = str(path.relative_to(root.parent))
        out += lint_source(path.read_text(), rel, style=style,
                           discipline=discipline)
    return out
