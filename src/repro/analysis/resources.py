"""Kernel resource analyzer: dtype-aware VMEM/SMEM footprints per grid step.

Model (matches the Mosaic vector-memory layout rules in the Pallas TPU
guide): a VMEM buffer is padded to the tile grid for its dtype — the
trailing axis to the 128-lane VPU width, the second-to-last axis to the
dtype's sublane count (32 bytes / itemsize: f32 -> 8, bf16/u16 -> 16,
int8/u8 -> 32); rank-1 buffers live on the lane axis, rank-0 on one tile.
A *varying* block (its index map moves across the grid) is double-buffered
by the Pallas pipeline; a *resident* block (constant index map, e.g. the
megakernels' payload output) and scratch buffers are single copies. SMEM
buffers are scalar memory: raw bytes, no tile padding.

Checks per spec, evaluated over every point :mod:`.space` ships:

  * ``vmem-overflow``  — per-grid-step VMEM footprint exceeds the per-core
    budget (16 MiB, with a pipelining reserve);
  * ``smem-overflow``  — scalar memory above the (tiny) SMEM budget;
  * ``lane-underfill`` — a large buffer whose trailing axis fills < 128
    lanes (16x-padded payload rows), or a declared ``critical_lanes`` entry
    below 128 (the paged flash-decode ps<128 case — a tracked finding, not
    folklore);
  * ``pad-waste``      — tile padding more than doubles a large buffer.

Plus the helper cross-check the satellite task asks for:
``check_band_helpers`` pins ``lorenzo_quant.band_for`` (and the fused
band sizing) against this module's own footprint model — the band a helper
picks must actually fit the budget it claims to enforce, at every itemsize.
"""
from __future__ import annotations

import math

from .kernelspec import (SMEM, VMEM, BlockDecl, KernelSpec,
                         probe_index_map)
from .report import Finding

LANE = 128
SUBLANE_BYTES = 32                   # sublane count = SUBLANE_BYTES / itemsize
VMEM_BUDGET = 16 << 20               # bytes per core (v4/v5e class)
VMEM_RESERVE = 0.25                  # compiler/pipeline headroom fraction
SMEM_BUDGET = 16 << 10               # scalar memory is tiny
BIG_BUFFER = 64 << 10                # lane/pad checks only bite above this
PAD_WASTE_FACTOR = 2.0


def sublanes(itemsize: int) -> int:
    return max(1, SUBLANE_BYTES // itemsize)


def padded_bytes(shape: tuple[int, ...], itemsize: int,
                 memory: str = VMEM) -> int:
    """Bytes one buffer occupies after tile padding (VMEM) or raw (SMEM)."""
    if memory == SMEM or not shape:
        return max(1, math.prod(shape) if shape else 1) * itemsize
    dims = list(shape)
    if len(dims) == 1:
        dims = [1] + dims
    dims[-1] = -(-dims[-1] // LANE) * LANE
    sl = sublanes(itemsize)
    dims[-2] = -(-dims[-2] // sl) * sl
    return math.prod(dims) * itemsize


def _buffer_copies(spec: KernelSpec, b: BlockDecl) -> int:
    _, varies = probe_index_map(b.index_map, spec.grid)
    return 2 if varies else 1


def footprint(spec: KernelSpec) -> dict:
    """Per-grid-step memory footprint of one spec, by space and by buffer."""
    vmem = smem = 0
    rows = []
    for b in spec.blocks():
        copies = _buffer_copies(spec, b)
        by = padded_bytes(b.shape, b.itemsize, b.memory) * copies
        rows.append((b.name, b.memory, b.shape, b.dtype, copies, by))
        if b.memory == SMEM:
            smem += by
        else:
            vmem += by
    for s in spec.scratch:
        by = padded_bytes(s.shape, s.itemsize, s.memory)
        rows.append((s.name, s.memory, s.shape, s.dtype, 1, by))
        if s.memory == SMEM:
            smem += by
        else:
            vmem += by
    return {"vmem": vmem, "smem": smem, "rows": rows}


def _fmt_bytes(n: int) -> str:
    if n >= 1 << 20:
        return f"{n / (1 << 20):.1f}MiB"
    if n >= 1 << 10:
        return f"{n / (1 << 10):.1f}KiB"
    return f"{n}B"


def analyze_spec(spec: KernelSpec) -> list[Finding]:
    fp = footprint(spec)
    out = []
    budget = int(VMEM_BUDGET * (1 - VMEM_RESERVE))
    if fp["vmem"] > budget:
        worst = max((r for r in fp["rows"] if r[1] == VMEM), key=lambda r: r[5])
        out.append(Finding(
            "resources", "vmem-overflow", spec.name,
            f"per-step VMEM {_fmt_bytes(fp['vmem'])} > budget "
            f"{_fmt_bytes(budget)} at {spec.point}; dominant buffer "
            f"{worst[0]} {worst[2]} {worst[3]} x{worst[4]} = "
            f"{_fmt_bytes(worst[5])}"))
    if fp["smem"] > SMEM_BUDGET:
        out.append(Finding(
            "resources", "smem-overflow", spec.name,
            f"per-step SMEM {_fmt_bytes(fp['smem'])} > "
            f"{_fmt_bytes(SMEM_BUDGET)} at {spec.point}"))
    for b in spec.blocks():
        raw = b.elems * b.itemsize
        if raw < BIG_BUFFER or b.memory != VMEM or not b.shape:
            continue
        pad = padded_bytes(b.shape, b.itemsize, b.memory)
        if b.shape[-1] < LANE:
            out.append(Finding(
                "resources", "lane-underfill", f"{spec.name}.{b.name}",
                f"trailing axis {b.shape[-1]} < {LANE} lanes on "
                f"{_fmt_bytes(raw)} buffer {b.shape} {b.dtype} "
                f"(pads to {_fmt_bytes(pad)}) at {spec.point}"))
        elif pad > raw * PAD_WASTE_FACTOR:
            out.append(Finding(
                "resources", "pad-waste", f"{spec.name}.{b.name}",
                f"tile padding inflates {b.shape} {b.dtype} "
                f"{_fmt_bytes(raw)} -> {_fmt_bytes(pad)} at {spec.point}"))
    for dim_name, size in spec.critical_lanes:
        if size < LANE:
            out.append(Finding(
                "resources", "lane-underfill", f"{spec.name}.{dim_name}",
                f"lane-critical dim {dim_name}={size} < {LANE} "
                f"at {spec.point}"))
    return out


def check_band_helpers() -> list[Finding]:
    """Cross-check the in-code band-sizing helpers against this model.

    ``lorenzo_quant.band_for(trailing, itemsize)`` promises the band's
    *input* stays within ``VMEM_BAND_BUDGET``; verify that promise with the
    model's own padded-bytes math at every itemsize the pipeline can feed
    it, and that the helper is maximal (one more row would bust budget or
    MAX_BAND) so bf16 inputs actually get the doubled bands the dtype
    allows.
    """
    from repro.kernels import fused_compress as fc
    from repro.kernels import lorenzo_quant as lq
    out = []
    for trailing in (64, 1024, 4096, 1 << 16, 1 << 20):
        for dtype, itemsize in (("float32", 4), ("bfloat16", 2)):
            band = lq.band_for(trailing, itemsize=itemsize)
            used = band * trailing * itemsize
            if band > 1 and used > lq.VMEM_BAND_BUDGET:
                out.append(Finding(
                    "resources", "band-helper-overbudget",
                    "lorenzo_quant.band_for",
                    f"band_for({trailing}, itemsize={itemsize}) = {band} "
                    f"uses {_fmt_bytes(used)} > VMEM_BAND_BUDGET "
                    f"{_fmt_bytes(lq.VMEM_BAND_BUDGET)}"))
            grown = (band + 1) * trailing * itemsize
            if band < lq.MAX_BAND and grown <= lq.VMEM_BAND_BUDGET:
                out.append(Finding(
                    "resources", "band-helper-underfill",
                    "lorenzo_quant.band_for",
                    f"band_for({trailing}, itemsize={itemsize}) = {band} "
                    f"leaves budget headroom for band {band + 1} "
                    f"({_fmt_bytes(grown)} <= "
                    f"{_fmt_bytes(lq.VMEM_BAND_BUDGET)}) — band sizing is "
                    f"not dtype-aware"))
            fband = fc._fused_band(trailing, itemsize=itemsize)
            if fband * trailing * itemsize > lq.VMEM_BAND_BUDGET \
                    and fband > -(-2 * fc.TILE // trailing):
                out.append(Finding(
                    "resources", "band-helper-overbudget",
                    "fused_compress._fused_band",
                    f"_fused_band({trailing}, itemsize={itemsize}) = {fband} "
                    f"busts the band budget"))
    return out


def analyze(specs: list[KernelSpec]) -> list[Finding]:
    out = []
    for spec in specs:
        out += analyze_spec(spec)
    out += check_band_helpers()
    return out
