"""repro.analysis — static kernel-resource + jit-discipline analyzer.

Three passes and one reporting spine, all dependency-free (stdlib + the
repo's own geometry helpers; no jax execution):

  * **resources** (:mod:`.resources`): evaluates every registered
    :class:`~.kernelspec.KernelSpec` over the shipped config space
    (:mod:`.space`) and computes per-grid-step VMEM/SMEM footprints with
    dtype-aware TPU tile padding (f32 (8,128), bf16 (16,128), int8/u8
    (32,128) sublane rules, 128-lane trailing axis, pipeline
    double-buffering for varying blocks). Budget overflows and lane
    under-fills become findings — the known megakernel capacity-payload
    blow-up and the ps<128 paged-decode under-fill are *tracked* entries in
    ``baseline.json`` instead of folklore. Also cross-checks
    ``lorenzo_quant.band_for`` against the footprint model.
  * **carry** (:mod:`.carry`): a race detector for the sequential-grid
    scratch pipeline. Classifies each scratch ref (and revisited output
    block) of a kernel body as cross-step carry vs per-step via AST
    inspection, then asserts carry ⇒ ``dimension_semantics`` declares the
    carried axes ``"arbitrary"`` — the exact bug class the fused
    megakernels' SMEM running-offset depends on.
  * **jitlint** (:mod:`.jitlint`): an AST linter over ``src/repro`` flagging
    Python-level branching on traced values, host/np calls inside jitted
    bodies, unknown or unhashable static args, and eager-only ``obs``
    metric calls reachable from inside a trace — the pre-merge twin of the
    runtime ``span_traces`` retrace detector. A small style pass (unused
    imports, F401-style) rides along so the tree lints clean even where the
    ruff wheel is unavailable.

``python -m repro.analysis`` runs everything and renders findings as human
text or JSON; ``--check`` fails on any finding not in the committed
allowlist ``baseline.json`` (known-accepted findings are explicit, new ones
fail CI — wired as ``scripts/ci.sh analyze``).

This package intentionally imports nothing heavy at package level:
:mod:`repro.kernels` imports :mod:`.kernelspec` to declare its specs, so the
passes live in submodules and are imported lazily (via ``__main__``/tests).
"""
from .kernelspec import (BlockDecl, KernelSpec,  # noqa: F401
                         ScratchDecl, register_spec, spec_builders)
