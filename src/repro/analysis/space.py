"""The shipped config space the resource analyzer evaluates specs over.

Static analysis is only as honest as the geometry points it checks. This
module enumerates the shapes the repo actually ships — not hypotheticals:

  * **FZ payload shapes**: the property-suite/bench 1D leaves, the
    gradient-bucket leaves ``dist/bucketed_reduce`` produces (bucket_bytes
    default / 4B), 2D/3D scientific fields at the paper's scales, and the
    flattened KV page slabs every shipped ``PoolConfig`` geometry parks
    (examples, benchmarks, and the serve launcher defaults) — each crossed
    with the ``capacity_frac`` values in the tree (1.0 default, 0.75 bench,
    0.5 tests) and both f32 and bf16 itemsizes (KV pages are bf16).
  * **flash-decode geometries**: every assigned arch config (full + smoke)
    from :mod:`repro.configs` at the contiguous ``KV_TILE`` and at the
    shipped paged page sizes (8 from benchmarks/examples, 16 the PoolConfig
    default, 128 the lane-aligned target) — the sub-lane page sizes are
    exactly the tracked ``lane-underfill`` findings.

``build_specs()`` imports :mod:`repro.kernels` (which registers every
call-site builder) and materializes one :class:`KernelSpec` per
(site, point).
"""
from __future__ import annotations

from .kernelspec import KernelSpec, spec_builders

# (shape, dtype) FZ inputs: property/bench leaves, gradient buckets
# (4 MiB default bucket -> 1M f32 elems), fields, flattened KV page slabs
FZ_SHAPES: tuple[tuple[tuple[int, ...], str], ...] = (
    ((4096,), "float32"),                 # one-tile leaf (property suite)
    ((65536,), "float32"),                # small gradient leaf
    ((1 << 20,), "float32"),              # 4 MiB reduce bucket
    ((1024, 1024), "float32"),            # 2D field (paper-scale plane)
    ((96, 96, 96), "float32"),            # 3D field (NYX-class subcube)
    ((2048,), "bfloat16"),                # KV page slab: ps=8 x KVH=2 x hd=128
    ((1 << 20,), "bfloat16"),             # bf16 activation leaf
)

CAPACITY_FRACS = (1.0, 0.75, 0.5)         # default / bench / test values

# paged-decode page sizes shipped in the tree (PoolConfig default 16,
# benches/examples 8, lane-aligned target 128)
PAGE_SIZES = (8, 16, 128)

# decode batch sizes and contiguous KV lengths from configs/base.SHAPES
DECODE_BATCH = 8
CONTIG_KV = 4096


def _arch_points():
    """(label, B, S, KVH, G, D) attention geometries from the arch registry."""
    from repro import configs
    pts = []
    for arch_id in configs.ARCH_IDS:
        for smoke in (False, True):
            cfg = configs.get(arch_id, smoke=smoke)
            if cfg.attention_free or cfg.n_kv_heads <= 0:
                continue
            kvh = cfg.n_kv_heads
            g = max(1, cfg.n_heads // kvh)
            pts.append((f"{cfg.arch_id}", DECODE_BATCH, CONTIG_KV,
                        kvh, g, cfg.hd))
    return pts


def build_specs() -> list[KernelSpec]:
    """One KernelSpec per (registered call site, shipped geometry point)."""
    import repro.kernels  # noqa: F401  -- registers every spec builder
    builders = spec_builders()
    specs: list[KernelSpec] = []

    for shape, dtype in FZ_SHAPES:
        specs.append(builders["lorenzo_quant"](shape=shape, dtype=dtype))
        for frac in CAPACITY_FRACS:
            specs.append(builders["fused_compress"](
                shape=shape, dtype=dtype, capacity_frac=frac))
            specs.append(builders["fused_decode"](
                shape=shape, capacity_frac=frac))
        n = 1
        for s in shape:
            n *= s
        n_tiles = -(-n // 4096)
        specs.append(builders["bitshuffle_flag.shuffle"](n_tiles=n_tiles))
        specs.append(builders["bitshuffle_flag.unshuffle"](n_tiles=n_tiles))
        specs.append(builders["fused_shuffle_encode"](
            n_tiles=n_tiles, capacity_frac=1.0))

    for label, b, s, kvh, g, d in _arch_points():
        specs.append(builders["flash_decode"](
            B=b, S=s, KVH=kvh, G=g, D=d, kv_tile=None,
            point=f"{label} contiguous"))
        for ps in PAGE_SIZES:
            specs.append(builders["flash_decode"](
                B=b, S=s, KVH=kvh, G=g, D=d, kv_tile=ps,
                point=f"{label} paged ps={ps}"))
    return specs
