"""Grid-carry hazard detector: carry ⇒ sequential grid, statically.

The fused megakernels thread state across grid steps — the SMEM running
payload offset, the right-aligned VMEM code carry, the inverse-Lorenzo
row carry — which is only sound because the TPU grid executes
*sequentially* when every axis is declared ``dimension_semantics
("arbitrary",)``. Mark an axis ``"parallel"`` (or leave semantics to
compiler defaults) and the same kernel silently miscompiles: steps race on
the scratch and the payload offsets interleave. This pass turns that prose
invariant into a checked one.

Classification is by AST inspection of the kernel body (``KernelSpec.
kernel_fn``), not by trusting a declared flag:

  * a **scratch ref** is a *carry* if the body reads it (including passing
    the ref to a helper) before an *unguarded* write — writes inside a
    ``@pl.when(program_id == 0)`` block are step-0 initialization, so any
    later-step read sees the previous step's value;
  * an **output block** whose index map ignores some grid axes (a
    *revisited* block, per ``probe_index_map``) is a carry across exactly
    those axes under the same read-before-unguarded-write test — the
    flash-decode online-softmax accumulators are the canonical case.

Rules:

  * ``carry-under-parallel``  — a carried axis is declared ``"parallel"``;
  * ``carry-default-semantics`` — the kernel carries state but the call
    site declares no ``dimension_semantics`` at all (compiler defaults are
    not a contract);
  * ``missing-semantics`` (warn) — no carries, but semantics omitted:
    parallelism should be declared deliberately, not by omission.
"""
from __future__ import annotations

import ast
import inspect
import textwrap

from .kernelspec import KernelSpec, probe_index_map
from .report import Finding


def _body_ast(fn) -> ast.FunctionDef | None:
    try:
        src = textwrap.dedent(inspect.getsource(fn))
    except (OSError, TypeError):
        return None
    try:
        tree = ast.parse(src)
    except SyntaxError:
        return None
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef):
            return node
    return None


def kernel_param_names(fn_def: ast.FunctionDef, expected: int) -> list[str]:
    """Positional ref names of a kernel body.

    Handles the ``def kernel(*refs)`` + tuple-unpack idiom (fused_decode):
    when the body star-packs its refs, the names come from an unpacking
    assignment ``(a_ref, b_ref, ...) = refs`` whose arity matches
    ``expected``.
    """
    args = [a.arg for a in fn_def.args.args]
    if fn_def.args.vararg is None:
        return args
    var = fn_def.args.vararg.arg
    for node in ast.walk(fn_def):
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Tuple)
                and isinstance(node.value, ast.Name)
                and node.value.id == var):
            names = [e.id for e in node.targets[0].elts
                     if isinstance(e, ast.Name)]
            if len(names) == expected:
                return args + names
    return args


def _is_first_step_guard(dec: ast.expr) -> bool:
    """True for ``@pl.when(<something> == 0)`` decorators (step-0 init)."""
    if not (isinstance(dec, ast.Call) and isinstance(dec.func, ast.Attribute)
            and dec.func.attr == "when"):
        return False
    return any(isinstance(a, ast.Compare)
               and any(isinstance(op, ast.Eq) for op in a.ops)
               for a in dec.args)


class _RefAccess(ast.NodeVisitor):
    """Orders reads vs unguarded writes of one ref name in a kernel body."""

    def __init__(self, name: str):
        self.name = name
        self.events: list[str] = []     # "read" | "write" in source order
        self._guard_depth = 0

    def visit_FunctionDef(self, node: ast.FunctionDef):
        guarded = any(_is_first_step_guard(d) for d in node.decorator_list)
        self._guard_depth += guarded
        self.generic_visit(node)
        self._guard_depth -= guarded

    def _hits(self, node: ast.expr) -> bool:
        return isinstance(node, ast.Name) and node.id == self.name

    def visit_Subscript(self, node: ast.Subscript):
        if self._hits(node.value):
            if isinstance(node.ctx, ast.Store):
                if not self._guard_depth:
                    self.events.append("write")
            else:
                self.events.append("read")
            # the inner Name is this same access, not a separate read
            self.visit(node.slice)
            return
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign):
        # ref[i] += x  is a read-modify-write: the read happens first
        tgt = node.target
        if isinstance(tgt, ast.Subscript) and self._hits(tgt.value):
            self.events.append("read")
            if not self._guard_depth:
                self.events.append("write")
            self.visit(tgt.slice)
            self.visit(node.value)
            return
        self.generic_visit(node)

    def visit_Name(self, node: ast.Name):
        # a bare ref passed to a helper (or concatenated) escapes: treat as
        # read — conservative, and exactly right for the qcarry/sm helpers
        if node.id == self.name and isinstance(node.ctx, ast.Load):
            self.events.append("read")


def _is_carry(fn_def: ast.FunctionDef, ref_name: str) -> bool:
    v = _RefAccess(ref_name)
    for stmt in fn_def.body:
        v.visit(stmt)
    if "read" not in v.events:
        return False
    return v.events.index("read") <= (
        v.events.index("write") if "write" in v.events else len(v.events))


def classify(spec: KernelSpec) -> dict[str, list]:
    """{"scratch": [names], "outputs": [(name, carried_axes)]} of carries."""
    result: dict[str, list] = {"scratch": [], "outputs": []}
    fn_def = _body_ast(spec.kernel_fn) if spec.kernel_fn else None
    if fn_def is None:
        return result
    expected = (len(spec.in_blocks) + len(spec.out_blocks)
                + len(spec.scratch))
    names = kernel_param_names(fn_def, expected)
    if len(names) != expected:
        return result
    n_in, n_out = len(spec.in_blocks), len(spec.out_blocks)
    out_names = names[n_in:n_in + n_out]
    scratch_names = names[n_in + n_out:]
    for decl, name in zip(spec.scratch, scratch_names):
        if _is_carry(fn_def, name):
            result["scratch"].append(decl.name)
    for decl, name in zip(spec.out_blocks, out_names):
        ignored, _ = probe_index_map(decl.index_map, spec.grid)
        if ignored and _is_carry(fn_def, name):
            result["outputs"].append((decl.name, ignored))
    return result


def analyze_spec(spec: KernelSpec) -> list[Finding]:
    carries = classify(spec)
    out = []
    sem = spec.dimension_semantics
    # a scratch carry persists across the entire grid walk -> every axis
    # must be sequential; an output revisit only pins its ignored axes
    carried_axes: set[int] = set()
    if carries["scratch"]:
        carried_axes.update(range(len(spec.grid)))
    for _, axes in carries["outputs"]:
        carried_axes.update(axes)
    what = ", ".join(carries["scratch"]
                     + [n for n, _ in carries["outputs"]])
    if carried_axes:
        if sem is None:
            out.append(Finding(
                "carry", "carry-default-semantics", spec.name,
                f"carried state ({what}) but no dimension_semantics "
                f"declared — the sequential-grid requirement rests on a "
                f"compiler default"))
        else:
            for ax in sorted(carried_axes):
                if ax < len(sem) and sem[ax] != "arbitrary":
                    out.append(Finding(
                        "carry", "carry-under-parallel", spec.name,
                        f"grid axis {ax} is '{sem[ax]}' but carried state "
                        f"({what}) needs sequential execution — "
                        f"declare it 'arbitrary'"))
    elif sem is None and spec.grid:
        out.append(Finding(
            "carry", "missing-semantics", spec.name,
            "no dimension_semantics declared; mark parallel axes "
            "'parallel' deliberately, not by omission", severity="warn"))
    return out


def analyze(specs: list[KernelSpec]) -> list[Finding]:
    # one spec per call site is enough for carry analysis (the body doesn't
    # change across geometry points) — dedup by site name
    seen: set[str] = set()
    out = []
    for spec in specs:
        if spec.name in seen:
            continue
        seen.add(spec.name)
        out += analyze_spec(spec)
    return out
