"""Reporting spine: findings, baselines, and the run-everything entry point.

A :class:`Finding` is one defect the analyzer can state statically. Its
``key`` — ``pass:rule:obj`` — is deliberately *point-free*: the resource pass
evaluates each kernel over many geometry points, and all points that trip
the same rule on the same object fold into one finding (worst point quoted
in the message). That keeps ``baseline.json`` small and stable as the
evaluated space grows.

The baseline is the committed allowlist: every accepted finding is explicit
(key + reason), anything new fails ``--check``. Stale baseline entries
(accepted findings the tree no longer produces) are reported too, so the
allowlist can only shrink deliberately.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib

BASELINE_PATH = pathlib.Path(__file__).with_name("baseline.json")

SEVERITIES = ("error", "warn")


@dataclasses.dataclass(frozen=True)
class Finding:
    pass_name: str          # resources | carry | jitlint | style
    rule: str               # e.g. vmem-overflow, carry-under-parallel
    obj: str                # kernel site / file:qualname the finding is on
    message: str            # human sentence, may quote the worst point
    severity: str = "error"
    location: str = ""      # file:line when known

    @property
    def key(self) -> str:
        return f"{self.pass_name}:{self.rule}:{self.obj}"


def merge_findings(findings: list[Finding]) -> list[Finding]:
    """Fold same-key findings into one (first message wins, count appended)."""
    by_key: dict[str, list[Finding]] = {}
    for f in findings:
        by_key.setdefault(f.key, []).append(f)
    out = []
    for key, group in sorted(by_key.items()):
        f = group[0]
        if len(group) > 1:
            f = dataclasses.replace(
                f, message=f"{f.message} [{len(group)} config points]")
        out.append(f)
    return out


def load_baseline(path: pathlib.Path | None = None) -> dict[str, str]:
    """key -> reason for every accepted finding."""
    p = path or BASELINE_PATH
    if not p.exists():
        return {}
    doc = json.loads(p.read_text())
    return {e["key"]: e.get("reason", "") for e in doc.get("accepted", [])}


def save_baseline(findings: list[Finding], path: pathlib.Path | None = None,
                  reasons: dict[str, str] | None = None) -> None:
    reasons = reasons or {}
    doc = {"accepted": [{"key": f.key,
                         "reason": reasons.get(f.key, f.message)}
                        for f in merge_findings(findings)]}
    (path or BASELINE_PATH).write_text(json.dumps(doc, indent=2) + "\n")


@dataclasses.dataclass
class Report:
    findings: list[Finding]
    new: list[Finding]          # not in baseline -> fail --check
    stale: list[str]            # baseline keys the tree no longer produces

    @property
    def clean(self) -> bool:
        return not self.new

    def to_json(self) -> dict:
        return {
            "clean": self.clean,
            "counts": {"total": len(self.findings), "new": len(self.new),
                       "baselined": len(self.findings) - len(self.new),
                       "stale_baseline": len(self.stale)},
            "findings": [dict(dataclasses.asdict(f), key=f.key,
                              baselined=f not in self.new)
                         for f in self.findings],
            "stale_baseline": self.stale,
        }

    def render_text(self) -> str:
        lines = []
        for f in self.findings:
            mark = "NEW " if f in self.new else "ok  "
            loc = f" ({f.location})" if f.location else ""
            lines.append(f"{mark}[{f.pass_name}/{f.rule}] {f.obj}{loc}\n"
                         f"      {f.message}")
        for key in self.stale:
            lines.append(f"stale baseline entry (no longer produced): {key}")
        c = self.to_json()["counts"]
        lines.append(f"analysis: {c['total']} finding(s), {c['new']} new, "
                     f"{c['baselined']} baselined, "
                     f"{c['stale_baseline']} stale baseline entr(ies)")
        return "\n".join(lines)


def run_all(passes: tuple[str, ...] = ("resources", "carry", "jitlint",
                                       "style"),
            baseline_path: pathlib.Path | None = None) -> Report:
    """Run the selected passes and diff against the committed baseline.

    Imports the passes lazily so ``repro.kernels`` (which imports
    ``analysis.kernelspec``) never pulls them in transitively.
    """
    findings: list[Finding] = []
    if "resources" in passes or "carry" in passes:
        from . import space
        specs = space.build_specs()
        if "resources" in passes:
            from . import resources
            findings += resources.analyze(specs)
        if "carry" in passes:
            from . import carry
            findings += carry.analyze(specs)
    if "jitlint" in passes or "style" in passes:
        from . import jitlint
        findings += jitlint.analyze(
            style="style" in passes, discipline="jitlint" in passes)
    findings = merge_findings(findings)
    baseline = load_baseline(baseline_path)
    produced = {f.key for f in findings}
    new = [f for f in findings if f.key not in baseline]
    stale = sorted(k for k in baseline if k not in produced)
    return Report(findings=findings, new=new, stale=stale)
