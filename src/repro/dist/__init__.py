"""repro.dist — the distribution layer: FZ containers as a wire format.

The paper's §2.4 pitch is that error-bounded compression pays off wherever
scientific data is movement-bound, not compute-bound. This package deploys
that idea inside the training/serving stack, one module per use case:

  * ``sharding`` — logical-axis resolution. Models declare logical axes
    ("fsdp"/"tp"/"dp"/None); this module resolves them against any concrete
    mesh (laptop, (data, model) single-pod, (pod, data, model) multi-pod)
    with divisibility-fallback-to-replication, so the same model definition
    is elastic across topologies (ckpt/elastic.py builds on this).
  * ``compressed_allreduce`` — §2.4 "wire compression": the cross-pod
    gradient mean crosses the slow inter-pod link as capacity-sized FZ
    containers instead of raw f32, with error feedback carrying the lossy
    residual into the next step (train/step.py pod-compress path). This is
    the end-of-step barrier form, retained as the bit-parity oracle.
  * ``bucketed_reduce`` — the same reduce restructured for overlap: leaves
    packed into deterministic size-targeted buckets, one compress ->
    all_gather("pod") -> decompress-mean hop per bucket issued in backward
    production order, plus the ``grad_boundary`` custom_vjp taps that pin
    parameter-group cotangents as schedulable units (train/step.py overlap
    path, ``launch/train.py --overlap-reduce``).
  * ``flash_decode`` — sequence-sharded decode attention for serving: each
    KV shard produces flash-decoding partials that are renormalized across
    the sharding axis, so a parked-and-resharded cache (§2.4 "in-memory
    compression", serve/engine.py) never has to be regathered on one device.
    The jnp partials are the oracle; ``use_kernels`` swaps in the Pallas
    KV-tile kernel (``repro.kernels.flash_decode``) per shard.
  * ``compat`` — version-portability shims for the mesh / shard_map APIs so
    the same code runs on the pinned jax as well as current releases.
"""
from . import (bucketed_reduce, compat, compressed_allreduce,  # noqa: F401
               flash_decode, sharding)
