"""FZ-compressed cross-pod gradient mean with error feedback (§2.4 "wire").

Gradients crossing the slow inter-pod (DCN) link are the framework's most
movement-bound tensor stream, so they get the paper's wire-compression
treatment: each pod compresses its local gradient (plus the carried
error-feedback residual) into the fixed-shape FZ container, the containers —
not the raw f32 tensors — cross the pod boundary, every pod decompresses all
containers locally, and the reduced gradient is the exact mean of the
reconstructions. The per-pod quantization error is stored back into the
error state and replayed into the next round's input, so the *time-averaged*
reduced gradient converges to the exact mean (standard error-feedback
compression; verified in tests/test_dist.py).

Execution model: hybrid — the loss/backward stays fully XLA-automatic (the
step builder vmaps it over a stacked leading pod dimension), and only the
reduce hops run as manual shard_map regions over the ``pod`` axis:
compress locally, ``all_gather`` the container leaves over ``pod``,
decompress all pods on every device, mean. The reduce comes in two issue
granularities sharing this per-leaf math bit-for-bit:

  * this module's ``reduce_stacked`` — ONE region per leaf, all issued
    after the full backward pass (a barrier at the end of the step). It is
    the parity ORACLE: simple, and bit-identical to the bucketed path.
  * ``bucketed_reduce.reduce_stacked_bucketed`` — leaves grouped into
    size-targeted buckets, one region per bucket issued in backward
    production order, so each bucket's DCN transfer can overlap the
    remaining backward compute (``train/step.py`` overlap path,
    ``launch/train.py --overlap-reduce``).

Two reasons the hop is manual:
(1) the wire format is structural — the only tensors that can cross the
pod boundary are the capacity-sized container buffers, independent of any
partitioner choice; (2) the FZ pipeline (integer prefix sums, bit packing,
gather compaction) must not be sliced by the SPMD partitioner at all —
under sharding pressure from the optimizer's param-sharded outputs the
partitioner is free to split the scan axis of ``cumsum``/gather chains,
which (observed on the pinned XLA CPU backend) silently corrupts the
decoded stream. Inside shard_map each device runs the whole per-pod
pipeline redundantly on its replica — compression math is elementwise/
O(n log n), cheap next to the backward pass that produced the gradient.

Wire accounting (``wire_bytes_per_leaf``) is shape-static by construction:
the container's leaves are capacity-sized, so bytes-on-the-wire depend only
on the element count and the config, never on the data. It agrees exactly
with ``FZCompressed.wire_bytes()`` and upper-bounds ``used_bytes()``
(tests/test_wire_accounting.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import fz


@dataclasses.dataclass(frozen=True)
class GradCompressionConfig:
    """Static configuration for the compressed cross-pod reduce."""
    enabled: bool = False
    eb: float = 1e-4               # error bound on each pod's gradient
    eb_mode: str = "rel"           # relative to the leaf's value range
    code_mode: str = "sign_mag"
    capacity_frac: float = 1.0     # container payload capacity vs worst case
    min_leaf_size: int = 4096      # elements; smaller leaves reduce exactly
    # bucketed/overlapped issue (dist/bucketed_reduce.py): when ``overlap``
    # is on, the step builder routes the reduce through per-bucket hops
    # (``bucket_bytes`` of wire traffic each) interleaved with the backward
    # pass; off keeps the legacy single-barrier reduce below.
    overlap: bool = False
    bucket_bytes: int = 4 << 20
    # per-leaf FZ hops through the Pallas kernels ("fused" = single-launch
    # megakernels, "staged" = per-stage oracle); off keeps the jnp reference.
    # Both reduces (barrier reduce_stacked and the bucketed hops) share this
    # config, so the bit-parity oracle relationship between them holds under
    # every kernel flavor.
    use_kernels: bool = False
    kernel_mode: str = "auto"

    def fz_config(self) -> fz.FZConfig:
        # exact_outliers off: saturation error (like dropped blocks when
        # capacity_frac < 1) is absorbed by the error-feedback residual.
        return fz.FZConfig(eb=self.eb, eb_mode=self.eb_mode,
                           code_mode=self.code_mode,
                           capacity_frac=self.capacity_frac,
                           exact_outliers=False,
                           use_kernels=self.use_kernels,
                           kernel_mode=self.kernel_mode)


def _compressible(shape: tuple[int, ...], dtype, cfg: GradCompressionConfig) -> bool:
    n = 1
    for s in shape:
        n *= s
    return bool(jnp.issubdtype(dtype, jnp.floating)) and n >= cfg.min_leaf_size


def wire_bytes_per_leaf(n_elems: int, cfg: GradCompressionConfig) -> dict:
    """Bytes a single f32 leaf of ``n_elems`` puts on the cross-pod link.

    Derived from the abstract container itself (``eval_shape`` of
    ``fz.compress``), so it equals ``FZCompressed.wire_bytes()`` by
    construction: the container's leaves are capacity-sized, making the
    wire cost a pure function of element count and config.
    """
    fzc = cfg.fz_config()
    raw = 4 * n_elems
    c_abs = jax.eval_shape(lambda x: fz.compress(x, fzc),
                           jax.ShapeDtypeStruct((n_elems,), jnp.float32))
    compressed = sum(int(leaf.size) * jnp.dtype(leaf.dtype).itemsize
                     for leaf in jax.tree.leaves(c_abs))
    return {"raw": raw, "compressed": compressed, "reduction": raw / compressed}


def init_error_state(grads_abstract: Any, n_pods: int,
                     cfg: GradCompressionConfig) -> Any:
    """Zero error-feedback residuals, stacked over the leading pod dim.

    Bypass leaves (small / non-float: reduced exactly) carry an empty f32
    placeholder so the error state mirrors the gradient structure without
    spending memory on leaves that never accumulate error.
    """
    if not cfg.enabled:
        return {}

    def one(ab):
        if _compressible(tuple(ab.shape), ab.dtype, cfg):
            return jnp.zeros((n_pods,) + tuple(ab.shape), jnp.float32)
        return jnp.zeros((0,), jnp.float32)
    return jax.tree.map(one, grads_abstract)


def error_state_shardings(grads_abstract: Any, cfg: GradCompressionConfig,
                          mesh) -> Any:
    """Shardings for the error state: stacked pod dim on the pod axis."""
    if not cfg.enabled:
        return {}
    has_pod = "pod" in tuple(mesh.axis_names)

    def one(ab):
        if _compressible(tuple(ab.shape), ab.dtype, cfg) and has_pod:
            return NamedSharding(mesh, P("pod"))
        return NamedSharding(mesh, P())
    return jax.tree.map(one, grads_abstract)


def _roundtrip_per_pod(x: jax.Array, fzc: fz.FZConfig) -> jax.Array:
    """(n_pods, n) -> per-pod compress+decompress reconstruction, stacked.

    Python loop over the (static, small) pod count; the no-mesh reference
    path for tests and single-device numerics.
    """
    d = [fz.decompress(fz.compress(x[p], fzc), fzc) for p in range(x.shape[0])]
    return jnp.stack(d)


def reference_hop(x: jax.Array, fzc: fz.FZConfig) -> tuple[jax.Array, jax.Array]:
    """No-mesh reduce hop: (n_pods, n) -> (mean (n,), residual (n_pods, n))."""
    d = _roundtrip_per_pod(x, fzc)
    return jnp.mean(d, axis=0), x - d


def pod_hop_body(xi: jax.Array, fzc: fz.FZConfig) -> tuple[jax.Array, jax.Array]:
    """One leaf's wire hop, to be called INSIDE a shard_map over ``pod``.

    ``xi``: this pod's (n,) f32 slice (gradient + replayed residual).
    Compress locally, ``all_gather`` the container leaves over ``pod`` (the
    only tensors that cross the pod boundary), decompress every pod's
    container, mean; the residual is against this pod's own reconstruction.
    Shared by the barrier reduce below and the bucketed reduce
    (dist/bucketed_reduce.py) — their bit parity is by construction because
    this is the single definition of the per-leaf math.
    """
    c = fz.compress(xi, fzc)
    c_all = jax.tree.map(lambda leaf: jax.lax.all_gather(leaf, "pod"), c)
    d = jax.vmap(lambda ci: fz.decompress(ci, fzc))(c_all)   # (n_pods, n)
    red = jnp.mean(d, axis=0)
    mine = jax.lax.dynamic_index_in_dim(
        d, jax.lax.axis_index("pod"), 0, keepdims=False)
    return red, (xi - mine)[None]


def reduce_stacked(g_stack: Any, err_state: Any, cfg: GradCompressionConfig,
                   mesh=None) -> tuple[Any, Any]:
    """Compressed mean over a stacked leading pod dimension.

    ``g_stack`` leaves are ``(n_pods, *leaf_shape)``; returns the reduced
    ``(*leaf_shape)`` tree plus the updated error state. Leaves below
    ``min_leaf_size`` (and non-float leaves) are reduced exactly and their
    error placeholder passes through untouched.

    With a multi-pod ``mesh`` the reduce hop runs as a manual shard_map
    over ``pod`` (see module docstring); without one (single-device tests,
    reference numerics) the identical math runs inline.
    """
    if not cfg.enabled:
        red = jax.tree.map(lambda g: jnp.mean(g.astype(jnp.float32), axis=0)
                           .astype(g.dtype), g_stack)
        return red, err_state

    fzc = cfg.fz_config()
    has_pod = mesh is not None and "pod" in tuple(mesh.axis_names)

    def sharded_roundtrip(x):
        """x: (n_pods, n) -> (mean (n,), residual (n_pods, n)) via shard_map."""
        from repro.dist import compat

        def body(x_sh):
            return pod_hop_body(x_sh[0], fzc)   # x_sh[0]: this pod's slice

        # fully manual (axis_names=None): data/model must also be manual so
        # the partitioner can never slice the FZ pipeline's scan axis — the
        # body is replicated across them (in/out specs only use "pod")
        return compat.shard_map(
            body, mesh=mesh, in_specs=(P("pod"),),
            out_specs=(P(), P("pod")))(x)

    def one(g, e):
        n_pods = g.shape[0]
        leaf_shape = g.shape[1:]
        if not _compressible(leaf_shape, g.dtype, cfg):
            return (jnp.mean(g.astype(jnp.float32), axis=0).astype(g.dtype), e)
        x = g.astype(jnp.float32).reshape(n_pods, -1) + e.reshape(n_pods, -1)
        if has_pod:
            red, new_e = sharded_roundtrip(x)
        else:
            red, new_e = reference_hop(x, fzc)
        return (red.reshape(leaf_shape).astype(g.dtype),
                new_e.reshape((n_pods,) + leaf_shape))

    pairs = jax.tree.map(one, g_stack, err_state)
    # explicit outer treedef: safe even when g_stack itself contains tuples
    red, new_err = jax.tree.transpose(
        jax.tree.structure(g_stack), jax.tree.structure((0, 0)), pairs)
    return red, new_err
