"""Logical-axis sharding resolution: model declarations -> mesh layouts.

Models (models/nn.py) declare per-dimension *logical* axes and never see a
mesh. This module owns the logical vocabulary and resolves it against any
concrete mesh:

  * ``fsdp`` — parameter/optimizer sharding (ZeRO-style);
  * ``tp``   — tensor parallel (heads / ffn / vocab / experts);
  * ``dp``   — batch parallelism for activations and inputs; spans
    ``(pod, data)`` on multi-pod meshes so the global batch covers both
    the DCN and the in-pod FSDP axes;
  * ``None`` — replicated.

Resolution rules (pinned by tests/test_dist.py):
  * a dimension shards only if its size is divisible by the product of the
    assigned mesh axes; otherwise the assignment falls back toward
    replication by dropping leading mesh axes (so ``dp`` degrades from
    ``(pod, data)`` to ``(data,)`` to replicated);
  * a mesh axis is used at most once per spec (first dimension wins);
  * unknown logical names and mesh axes absent from the mesh resolve to
    replication, never to an error — elastic resharding (ckpt/elastic.py)
    depends on every (spec, mesh) pair being resolvable.

``set_profile`` flips the parameter-layout profile the dry-run measures:
``"tp"`` (default) keeps tensor parallelism on the model axis; ``"zero3"``
turns the model axis into extra fully-sharded data parallelism (params
sharded over (data, model), tp dims replicated, batch over every axis).
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_PROFILES = ("tp", "zero3")
_profile = "tp"


def set_profile(name: str) -> None:
    """Select the parameter-layout profile ("tp" | "zero3")."""
    global _profile
    if name not in _PROFILES:
        raise ValueError(f"unknown sharding profile {name!r}; want one of {_PROFILES}")
    _profile = name


def get_profile() -> str:
    return _profile


def logical_to_mesh_axes(mesh) -> dict[str, tuple[str, ...]]:
    """The logical-name -> mesh-axes table for ``mesh`` under the profile.

    Only axis *names* are consulted, so this works on abstract stand-in
    meshes as well as real ones.
    """
    names = tuple(mesh.axis_names)
    dp = ("pod", "data") if "pod" in names else ("data",)
    if _profile == "zero3":
        table = {"fsdp": ("data", "model"), "tp": (), "dp": dp + ("model",)}
    else:
        table = {"fsdp": ("data",), "tp": ("model",), "dp": dp}
    return {k: tuple(a for a in v if a in names) for k, v in table.items()}


def _mesh_axis_size(mesh, axis: str) -> int:
    return int(mesh.shape[axis])


def resolve_spec(logical: Sequence[str | None], shape: Sequence[int], mesh) -> P:
    """Resolve a per-dimension logical spec into a PartitionSpec for ``mesh``.

    Divisibility fallback: for each dimension, the longest suffix of the
    assigned mesh-axis tuple whose total size divides the dimension is used
    (suffix, so ``dp`` prefers the large in-pod ``data`` axis over ``pod``
    when the full span does not divide); no suffix divides -> replicated.
    """
    table = logical_to_mesh_axes(mesh)
    used: set[str] = set()
    entries: list[Any] = []
    for i, dim in enumerate(shape):
        name = logical[i] if i < len(logical) else None
        if name is None:
            entries.append(None)
            continue
        axes = tuple(a for a in table.get(name, ()) if a not in used)
        chosen: tuple[str, ...] = ()
        for start in range(len(axes)):
            cand = axes[start:]
            size = 1
            for a in cand:
                size *= _mesh_axis_size(mesh, a)
            if size > 1 and dim % size == 0:
                chosen = cand
                break
        if not chosen:
            entries.append(None)
            continue
        used.update(chosen)
        entries.append(chosen[0] if len(chosen) == 1 else chosen)
    return P(*entries)


def _is_logical_leaf(x) -> bool:
    return x is None or (isinstance(x, tuple)
                         and all(e is None or isinstance(e, str) for e in x))


def tree_shardings(logical_tree: Any, abstract_tree: Any, mesh) -> Any:
    """NamedSharding pytree for ``abstract_tree`` laid out per ``logical_tree``.

    ``logical_tree`` mirrors ``abstract_tree`` with tuple-of-logical-names
    leaves (``()`` for scalars); ``abstract_tree`` carries anything with a
    ``.shape`` (arrays or ShapeDtypeStructs).
    """
    def one(spec, ab):
        spec = () if spec is None else tuple(spec)
        return NamedSharding(mesh, resolve_spec(spec, tuple(ab.shape), mesh))
    return jax.tree.map(one, logical_tree, abstract_tree, is_leaf=_is_logical_leaf)
