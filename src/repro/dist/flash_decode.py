"""Sequence-sharded decode attention (flash-decoding partials + combine).

Decode attention over a long KV cache is a pure gather/reduce — exactly the
movement-bound serving path where the cache is worth keeping sharded (and,
parked, FZ-compressed: serve/engine.py). Each shard of the sequence axis
computes the standard flash-decoding partials over its local KV slice —
running max, exp-sum denominator, and weighted-value numerator — then the
partials are renormalized to the global max and combined with psum over the
sharding axis. Matches models/attention.decode_attention to float32
round-off (pinned at 2e-4 in tests/test_dist.py).

This is the jnp reference. The Pallas block-parallel kernel
(``repro.kernels.flash_decode``) computes the same partials tile-by-tile with
the cross-tile combine fused on-chip; ``flash_decode_shard(use_kernels=True)``
routes through it inside shard_map, keeping this module as its oracle
(parity pinned at 2e-4 in tests/test_dist.py and tests/test_kernels.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Same finite -inf stand-in as models/attention.py (kept local: the dist
# layer must not import the model zoo). Finite so that an entirely-masked
# shard yields 0/0-free partials: NEG_INF - NEG_INF == 0.
NEG_INF = -1e30


def decode_partials(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                    length: jax.Array, *,
                    shard_offset: jax.Array | int) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Flash-decoding partials over one contiguous slice of the KV sequence.

    q: (B, H, D); k_cache/v_cache: (B, S_slice, KVH, D); length: (B,) global
    valid prefix; ``shard_offset``: global position of this slice's first
    cache slot. Returns ``(m_local, num, den)`` with shapes
    (B, KVH, G), (B, KVH, G, D), (B, KVH, G) — the running max, weighted-value
    numerator and exp-sum denominator of the online softmax, renormalizable
    against any global max. An entirely-masked slice yields m_local == NEG_INF
    and zero num/den: against a *finite* global max its renorm weight
    ``exp(NEG_INF - m_global)`` underflows to exactly 0, and when every slice
    is empty the weight is ``exp(NEG_INF - NEG_INF) == 1`` — the combined
    output is still 0, but only because num and den are both 0.

    Shared by the sequence-sharded path below (combine = pmax/psum over a mesh
    axis) and by serve/kvpool's paged decode attention (combine = max/sum over
    the page axis); both keep models/attention.decode_attention as the oracle.
    """
    B, H, D = q.shape
    S_slice, KVH = k_cache.shape[1], k_cache.shape[2]
    G = H // KVH
    qf = q.reshape(B, KVH, G, D).astype(jnp.float32) * D ** -0.5
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, k_cache.astype(jnp.float32))
    pos = shard_offset + jnp.arange(S_slice)
    valid = pos[None, :] < length[:, None]                       # (B, S_slice)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)

    m_local = jnp.max(s, axis=-1)                                # (B, KVH, G)
    p = jnp.exp(s - m_local[..., None])
    p = jnp.where(valid[:, None, None, :], p, 0.0)               # empty-slice safety
    num = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    den = jnp.sum(p, axis=-1)
    return m_local, num, den


def flash_decode_shard(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                       length: jax.Array, *, axis: str,
                       shard_offset: jax.Array | int,
                       use_kernels: bool = False) -> jax.Array:
    """One shard of sequence-sharded decode attention; call inside shard_map.

    q: (B, H, D) replicated; k_cache/v_cache: (B, S_shard, KVH, D) — the
    local slice of the sequence axis; length: (B,) global valid prefix;
    ``shard_offset``: global position of this shard's first cache slot
    (e.g. ``lax.axis_index(axis) * S_shard``). Returns (B, H, D) replicated
    over ``axis``. With ``use_kernels`` the per-shard partials run through the
    Pallas KV-tile kernel (kernels/flash_decode, interpret mode off-TPU); the
    cross-shard pmax/psum combine is identical either way.
    """
    B, H, D = q.shape
    if use_kernels:
        # tuned dispatch (repro.tune): the cached per-backend winner can
        # override the kernel request back to the jnp partials where the
        # oracle measured faster; untuned, the kernel path is honored.
        # Shapes are trace-static, so the resolution is too.
        from repro import tune
        s_shard, kvh = k_cache.shape[1], k_cache.shape[2]
        n_attn = tune.attn_cache_elems(s_shard, kvh, k_cache.shape[3])
        use_kernels = tune.decode_attention_impl(
            n_attn, str(k_cache.dtype)) == "kernel"
    if use_kernels:
        from repro.kernels import flash_decode as _fdk  # local: mirror fz._stages
        m_local, num, den = _fdk.decode_partials(q, k_cache, v_cache, length,
                                                 shard_offset=shard_offset)
    else:
        m_local, num, den = decode_partials(q, k_cache, v_cache, length,
                                            shard_offset=shard_offset)
    m_global = jax.lax.pmax(m_local, axis)
    # weight underflows to 0 for an empty shard when any shard is non-empty;
    # if ALL shards are empty corr == exp(0) == 1 and the output is 0 anyway
    # because num and den are both 0 (see decode_partials)
    corr = jnp.exp(m_local - m_global)
    num = jax.lax.psum(num * corr[..., None], axis)
    den = jax.lax.psum(den * corr, axis)
    out = num / jnp.maximum(den, 1e-30)[..., None]
    return out.reshape(B, H, D).astype(q.dtype)
