"""Bucketed, overlap-ready FZ-compressed cross-pod gradient reduce.

The barrier reduce in ``compressed_allreduce`` compresses every leaf inside
one region issued after the whole backward pass — the DCN transfers cannot
start until the last gradient exists, so the wire time adds to the step
instead of hiding inside it (the paper's §2.4 argument is exactly that
compression only pays when it hides inside the movement it saves). This
module restructures the same math into independently schedulable pieces:

  * Per-leaf FZ hops inherit ``GradCompressionConfig.use_kernels`` /
    ``kernel_mode`` through the shared ``fz_config()``: with kernels on,
    every bucket hop's compress and decompress run as the single-launch
    megakernels (kernels/fused_compress, kernels/fused_decode) inside the
    shard_map region. Compression stays strictly per leaf, so the barrier
    ``reduce_stacked`` remains a bit-parity oracle under every kernel
    flavor — the fused/staged/reference paths produce identical containers.
  * ``assign_buckets`` partitions the gradient pytree into size-targeted
    buckets (``GradCompressionConfig.bucket_bytes`` of *wire* bytes each).
    The assignment is a pure function of the abstract gradient tree and the
    config — deterministic and stable across steps — so the error-feedback
    residuals stay aligned with their leaves for the whole run.
  * Leaves are ordered by backward *production* order (unembed first,
    final norm, then the scanned layer stack, embedding last) and buckets
    are contiguous ranges of that order, so the first hops issued are the
    ones whose inputs exist first.
  * ``reduce_stacked_bucketed`` issues one manual ``shard_map`` region per
    bucket (compress -> ``all_gather("pod")`` -> decompress -> mean, with
    error feedback), in production order. Each region depends only on its
    own leaves' cotangents, so XLA's latency-hiding scheduler (flags
    promoted into ``launch/train.py --overlap-reduce``) can run a bucket's
    DCN transfer while the remaining backward compute is still producing
    later buckets.
  * ``grad_boundary`` is a ``custom_vjp`` identity installed on the model's
    parameter-group boundaries (``models/transformer.py`` via
    ``nn.grad_tap``). Its backward applies an ``optimization_barrier`` to
    the cotangents, pinning each group's gradients as a distinct scheduling
    unit instead of letting XLA fuse them into later backward clusters —
    the point where a bucket's input is "ready" is then a real boundary in
    the schedule.

Compression stays strictly per leaf inside a bucket (each leaf keeps its
own relative error bound, container, and residual), so the arithmetic is
*identical* to the barrier path: same buckets or not, the reduced gradients
and the error state are bit-identical to ``reduce_stacked`` — the barrier
reduce is retained as the parity oracle (tests/test_dist.py,
tests/test_bucketed_reduce.py).

Wire accounting: every bucket hop all-gathers its leaves' container
buffers, so per-bucket cross-pod bytes are analytic. ``launch/hlo_cost``
attributes cross-pod collectives to buckets via the ``bucket<i>_reduce``
named-scope tag that wraps each hop; ``expected_cross_pod_bytes`` is the
model it must match (the compiled HLO drops the container's ``nnz_blocks``
/ ``n_outliers`` bookkeeping scalars, which the mean hop never reads —
``gathered_bytes_per_leaf`` accounts for exactly the leaves that survive).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import obs
from repro.core import fz
from . import compat
from .compressed_allreduce import (GradCompressionConfig, _compressible,
                                   pod_hop_body, reference_hop,
                                   wire_bytes_per_leaf)

# Backward production order of the transformer's top-level parameter groups
# (models/transformer.py): the unembed cotangent exists first (closest to
# the loss), the scanned layer stack finishes next-to-last, the embedding
# gather's backward runs last. Unknown groups (other model families) slot in
# with the layer stack; ties break on the leaf path, so the order is total
# and deterministic for any tree.
_PRODUCTION_RANK = {"unembed": 0, "final_norm": 1, "layers": 2, "embed": 4}
_DEFAULT_RANK = 2


def _top_level_name(path) -> str:
    for entry in path:
        key = getattr(entry, "key", None)
        if key is not None:
            return str(key)
        name = getattr(entry, "name", None)
        if name is not None:
            return str(name)
    return ""


@dataclasses.dataclass(frozen=True)
class Bucket:
    """One reduce hop: a contiguous production-order run of leaves."""
    index: int
    keys: tuple[str, ...]          # leaf paths (jax.tree_util.keystr form)
    n_elems: tuple[int, ...]       # flattened element count per leaf
    wire_bytes: int                # one pod's compressed bytes on the link

    @property
    def tag(self) -> str:
        """Named-scope tag wrapping this bucket's hop (hlo_cost attribution)."""
        return f"bucket{self.index}_reduce"


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    buckets: tuple[Bucket, ...]
    bypass: tuple[str, ...]        # small/non-float leaves: reduced exactly

    @property
    def n_buckets(self) -> int:
        return len(self.buckets)


def assign_buckets(grads_abstract: Any, cfg: GradCompressionConfig) -> BucketPlan:
    """Deterministic leaf -> bucket assignment from the abstract grad tree.

    Pure in (abstract shapes/dtypes, config): rebuilding the plan on any
    step, host, or process yields the same buckets, which is what keeps the
    error-feedback state aligned with its leaves across restarts. Leaves are
    greedily packed in production order until the next leaf would push the
    bucket past ``cfg.bucket_bytes`` of wire traffic; a single leaf larger
    than the target gets its own bucket.
    """
    leaves = jax.tree_util.tree_flatten_with_path(grads_abstract)[0]
    ordered, bypass = [], []
    wire_cache: dict[int, int] = {}
    for path, ab in leaves:
        key = jax.tree_util.keystr(path)
        shape, dtype = tuple(ab.shape), ab.dtype
        if not _compressible(shape, dtype, cfg):
            bypass.append(key)
            continue
        n = 1
        for s in shape:
            n *= s
        if n not in wire_cache:
            wire_cache[n] = int(wire_bytes_per_leaf(n, cfg)["compressed"])
        rank = _PRODUCTION_RANK.get(_top_level_name(path), _DEFAULT_RANK)
        ordered.append((rank, key, n, wire_cache[n]))
    ordered.sort(key=lambda t: (t[0], t[1]))

    buckets: list[Bucket] = []
    cur_keys: list[str] = []
    cur_ns: list[int] = []
    cur_bytes = 0

    def flush():
        nonlocal cur_keys, cur_ns, cur_bytes
        if cur_keys:
            buckets.append(Bucket(index=len(buckets), keys=tuple(cur_keys),
                                  n_elems=tuple(cur_ns), wire_bytes=cur_bytes))
            cur_keys, cur_ns, cur_bytes = [], [], 0

    for _, key, n, wb in ordered:
        if cur_keys and cur_bytes + wb > cfg.bucket_bytes:
            flush()
        cur_keys.append(key)
        cur_ns.append(n)
        cur_bytes += wb
    flush()
    plan = BucketPlan(buckets=tuple(buckets), bypass=tuple(sorted(bypass)))
    # analytic wire bytes are known at plan time (the hop itself runs inside
    # jit, where nothing may be recorded) — publish them as per-bucket gauges
    # so step_report can join bytes onto the bucket spans without an HLO pass
    if jax.core.trace_state_clean():
        obs.gauge("dist_n_buckets").set(plan.n_buckets)
        for b in plan.buckets:
            obs.gauge("dist_bucket_wire_bytes", bucket=b.tag).set(b.wire_bytes)
    return plan


# ---------------------------------------------------------------------------
# Wire accounting: what each bucket's hop puts on the cross-pod link
# ---------------------------------------------------------------------------

def gathered_bytes_per_leaf(n_elems: int, cfg: GradCompressionConfig) -> int:
    """Bytes of one leaf's container that actually cross the pod boundary.

    The hop all-gathers the whole container pytree, but the decompress-mean
    consumer only reads ``bitflags``, ``payload`` and ``eb_abs`` (plus the
    outlier leaves in ``exact_outliers`` mode), so XLA dead-code-eliminates
    the gathers of the ``nnz_blocks`` / ``n_outliers`` bookkeeping scalars.
    This is the byte model the compiled HLO matches exactly; it differs from
    ``wire_bytes_per_leaf`` only by those scalars (8 bytes at the gradient
    config), which a real serialized wire format would still carry.
    """
    fzc = cfg.fz_config()
    c = jax.eval_shape(lambda x: fz.compress(x, fzc),
                       jax.ShapeDtypeStruct((n_elems,), jnp.float32))
    fields = ["bitflags", "payload", "eb_abs"]
    if fzc.exact_outliers:
        fields += ["outlier_idx", "outlier_val", "n_outliers"]
    return sum(int(getattr(c, f).size) * jnp.dtype(getattr(c, f).dtype).itemsize
               for f in fields)


def expected_cross_pod_bytes(plan: BucketPlan, cfg: GradCompressionConfig,
                             n_pods: int) -> dict[str, int]:
    """Per-bucket all-gather bytes the compiled HLO must show cross-pod.

    Ring model (launch/hlo_cost): an all-gather costs its *output* bytes, so
    each leaf's container contributes ``n_pods *`` its gathered bytes. Keyed
    by the bucket's named-scope tag, matching ``hlo_cost.analyze``'s
    ``cross_pod_by_tag`` with ``tag_pattern=BUCKET_TAG_PATTERN``.
    """
    out = {}
    for b in plan.buckets:
        out[b.tag] = n_pods * sum(gathered_bytes_per_leaf(n, cfg)
                                  for n in b.n_elems)
    return out


BUCKET_TAG_PATTERN = r"(bucket\d+_reduce)"


# ---------------------------------------------------------------------------
# Gradient-boundary taps (installed via models/nn.set_grad_tap)
# ---------------------------------------------------------------------------

@jax.custom_vjp
def _boundary(tree):
    return tree


def _boundary_fwd(tree):
    return tree, None


def _boundary_bwd(_, ct):
    return (compat.optimization_barrier(ct),)


_boundary.defvjp(_boundary_fwd, _boundary_bwd)


def grad_boundary(tree: Any, name: str = "") -> Any:
    """custom_vjp identity marking a parameter-group gradient boundary.

    Forward is the identity (bit-exact, so enabling overlap cannot change
    the loss). Backward routes the cotangents through an
    ``optimization_barrier``: the group's gradients become one schedulable
    unit finalized at the boundary, instead of being fused into whatever
    backward cluster XLA builds next — which is what lets the per-bucket
    hops (and their DCN all-gathers) start as soon as their inputs exist.
    """
    with jax.named_scope(f"grad_boundary_{name}" if name else "grad_boundary"):
        return _boundary(tree)


# ---------------------------------------------------------------------------
# The bucketed reduce
# ---------------------------------------------------------------------------

def _bucket_hop(xs: list[jax.Array], fzc: fz.FZConfig, mesh, tag: str):
    """One bucket's wire hop: per-leaf compress -> all_gather -> mean.

    ``xs``: the bucket's leaves as ``(n_pods, n)`` f32 arrays (gradient plus
    replayed residual). Returns (means, residuals) lists. Each leaf runs the
    shared ``compressed_allreduce.pod_hop_body`` — one shard_map region per
    *bucket* instead of per leaf is the only difference from the barrier
    oracle, so the parity is bit-exact by construction. Fully manual over
    every mesh axis for the same partitioner-safety reasons (see that
    module's docstring).
    """
    def body(*xs_sh):
        outs = [pod_hop_body(x_sh[0], fzc) for x_sh in xs_sh]
        return tuple(r for r, _ in outs), tuple(e for _, e in outs)

    fn = compat.shard_map(
        body, mesh=mesh,
        in_specs=tuple(P("pod") for _ in xs),
        out_specs=(tuple(P() for _ in xs), tuple(P("pod") for _ in xs)))
    # the span installs a named scope containing the bucket tag — that is
    # what hlo_cost's tag_pattern keys cross-pod bytes on (and what lets
    # step_report join dist_bucket_wire_bytes onto this span's timing); the
    # hop runs under jit, so the span itself is a trace-time no-op
    with obs.span(f"dist.{tag}", leaves=len(xs)):
        reds, resids = fn(*xs)
    return list(reds), list(resids)


def reduce_stacked_bucketed(g_stack: Any, err_state: Any,
                            cfg: GradCompressionConfig, mesh=None,
                            plan: BucketPlan | None = None) -> tuple[Any, Any]:
    """Bucketed compressed mean over a stacked leading pod dimension.

    Drop-in for ``compressed_allreduce.reduce_stacked`` — same signature
    plus an optional precomputed ``plan`` (the step builder computes it once
    from the abstract gradients; passing None rebuilds it, which is cheap
    and deterministic). Bit-identical outputs to the barrier oracle: per
    leaf the math is unchanged, only the issue granularity differs.
    """
    if not cfg.enabled:
        red = jax.tree.map(lambda g: jnp.mean(g.astype(jnp.float32), axis=0)
                           .astype(g.dtype), g_stack)
        return red, err_state

    fzc = cfg.fz_config()
    has_pod = mesh is not None and "pod" in tuple(mesh.axis_names)
    if plan is None:
        abstract = jax.tree.map(
            lambda g: jax.ShapeDtypeStruct(tuple(g.shape[1:]), g.dtype), g_stack)
        plan = assign_buckets(abstract, cfg)

    g_leaves, g_treedef = jax.tree_util.tree_flatten_with_path(g_stack)
    e_leaves, e_treedef = jax.tree_util.tree_flatten_with_path(err_state)
    g_map = {jax.tree_util.keystr(p): v for p, v in g_leaves}
    e_map = {jax.tree_util.keystr(p): v for p, v in e_leaves}

    red_map: dict[str, jax.Array] = {}
    new_e_map: dict[str, jax.Array] = {}
    for key in plan.bypass:
        g = g_map[key]
        red_map[key] = jnp.mean(g.astype(jnp.float32), axis=0).astype(g.dtype)
        new_e_map[key] = e_map[key]          # empty placeholder, untouched

    # issue hops in production order: bucket 0's all-gathers are the first
    # in the instruction stream, free to overlap the rest of the backward
    for bucket in plan.buckets:
        xs, leaf_shapes, leaf_dtypes = [], [], []
        for key in bucket.keys:
            g, e = g_map[key], e_map[key]
            n_pods = g.shape[0]
            leaf_shapes.append(g.shape[1:])
            leaf_dtypes.append(g.dtype)
            xs.append(g.astype(jnp.float32).reshape(n_pods, -1)
                      + e.reshape(n_pods, -1))
        if has_pod:
            reds, resids = _bucket_hop(xs, fzc, mesh, bucket.tag)
        else:   # reference numerics: the shared no-mesh hop per leaf
            outs = [reference_hop(x, fzc) for x in xs]
            reds = [r for r, _ in outs]
            resids = [e for _, e in outs]
        for key, red, res, shp, dt in zip(bucket.keys, reds, resids,
                                          leaf_shapes, leaf_dtypes):
            red_map[key] = red.reshape(shp).astype(dt)
            new_e_map[key] = res.reshape((res.shape[0],) + tuple(shp))

    red = jax.tree_util.tree_unflatten(
        g_treedef, [red_map[jax.tree_util.keystr(p)] for p, _ in g_leaves])
    new_err = jax.tree_util.tree_unflatten(
        e_treedef, [new_e_map[jax.tree_util.keystr(p)] for p, _ in e_leaves])
    return red, new_err
