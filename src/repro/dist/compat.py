"""Version-portability shims for mesh and shard_map construction.

The library targets the modern surface (``jax.shard_map`` with
``axis_names``, ``jax.make_mesh`` with ``axis_types``), but the container
pins jax 0.4.x where shard_map lives in ``jax.experimental.shard_map`` and
meshes take no axis types. Every mesh / shard_map construction in the
library and tests goes through these two helpers so the difference is
confined to this module.
"""
from __future__ import annotations

import inspect
from typing import Callable

import jax


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """``jax.make_mesh`` with all axes auto-partitioned.

    Auto is the modern default, so no ``axis_types`` argument is needed on
    either side of the version split; this wrapper exists so call sites
    never spell the kwarg that 0.4.x rejects.
    """
    if devices is not None:
        return jax.make_mesh(axis_shapes, axis_names, devices=devices)
    return jax.make_mesh(axis_shapes, axis_names)


_OB_BATCHING_DONE = False


def optimization_barrier(x):
    """``jax.lax.optimization_barrier`` that also works under ``vmap``.

    jax 0.4.x ships the primitive without a batching rule, but the
    bucketed-reduce grad taps run inside the step builder's vmap over the
    pod dimension (train/step.py). The barrier is an identity per operand,
    so the rule is trivial: bind the batched operands, keep the batch dims.
    Registered once, only if the running jax lacks it.
    """
    global _OB_BATCHING_DONE
    if not _OB_BATCHING_DONE:
        from jax.interpreters import batching
        prim = getattr(jax.lax, "optimization_barrier_p", None)
        if prim is None:
            from jax._src.lax.lax import optimization_barrier_p as prim
        if prim not in batching.primitive_batchers:
            def _identity_batcher(args, dims):
                return prim.bind(*args), dims
            batching.primitive_batchers[prim] = _identity_batcher
        _OB_BATCHING_DONE = True
    return jax.lax.optimization_barrier(x)


def shard_map(f: Callable, *, mesh, in_specs, out_specs, axis_names=None):
    """Manual-collectives map, portable across the shard_map API split.

    ``axis_names`` is the set of mesh axes the body addresses manually (the
    modern kwarg); ``None`` means every mesh axis is manual. On APIs without
    ``axis_names`` the body runs fully manual over every mesh axis with
    replication checking off — equivalent as long as the in/out specs simply
    do not use the non-addressed axes, which all callers here follow.
    Kwarg support is detected from the signature, never by retrying on
    ``TypeError`` (which would swallow unrelated errors from the body).
    """
    impl = getattr(jax, "shard_map", None)
    if impl is None:
        from jax.experimental.shard_map import shard_map as impl
    params = inspect.signature(impl).parameters
    kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    if axis_names is not None and "axis_names" in params:
        kwargs["axis_names"] = set(axis_names)
    if "check_vma" in params:
        kwargs["check_vma"] = False
    elif "check_rep" in params:
        kwargs["check_rep"] = False
    return impl(f, **kwargs)
