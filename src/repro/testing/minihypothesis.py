"""Seeded random-search fallback for the ``hypothesis`` API subset we use.

CI installs the real ``hypothesis`` wheel (pyproject ``[test]`` extra) and
this module is never imported. In hermetic (no-network) environments,
``tests/conftest.py`` calls :func:`install`, which registers this module
under ``sys.modules["hypothesis"]`` so ``from hypothesis import given,
settings, strategies as st`` and ``pytest.importorskip("hypothesis")`` both
work and the property tier still executes.

This is deliberately NOT hypothesis: no shrinking, no example database, no
assume/target — just ``max_examples`` draws per test from a deterministic
per-test seed (stable across runs and processes, independent of test order).
It keeps the property checkers exercised; the real wheel remains the CI
source of truth.

Supported: ``given``, ``settings(max_examples=, deadline=)``, and the
strategies ``integers``, ``floats``, ``booleans``, ``sampled_from``,
``lists``, ``tuples``, ``just``, ``composite``.
"""
from __future__ import annotations

import sys
import types
import zlib

import numpy as np


class Strategy:
    """A draw rule: ``example(rng)`` produces one value."""

    def __init__(self, fn):
        self._fn = fn

    def example(self, rng: np.random.Generator):
        return self._fn(rng)

    def map(self, f):
        return Strategy(lambda rng: f(self._fn(rng)))


def integers(min_value: int, max_value: int) -> Strategy:
    return Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def floats(min_value: float, max_value: float, **_kw) -> Strategy:
    return Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


def booleans() -> Strategy:
    return Strategy(lambda rng: bool(rng.integers(0, 2)))


def sampled_from(seq) -> Strategy:
    seq = list(seq)
    return Strategy(lambda rng: seq[int(rng.integers(len(seq)))])


def lists(elements: Strategy, min_size: int = 0, max_size: int = 10) -> Strategy:
    return Strategy(lambda rng: [elements.example(rng)
                                 for _ in range(int(rng.integers(min_size,
                                                                 max_size + 1)))])


def tuples(*strategies: Strategy) -> Strategy:
    return Strategy(lambda rng: tuple(s.example(rng) for s in strategies))


def just(value) -> Strategy:
    return Strategy(lambda rng: value)


def composite(fn):
    """``@st.composite``: fn(draw, *args) -> value becomes a strategy factory."""
    def builder(*args, **kwargs):
        def draw_one(rng):
            return fn(lambda s: s.example(rng), *args, **kwargs)
        return Strategy(draw_one)
    return builder


def settings(max_examples: int = 20, deadline=None, **_kw):
    def deco(fn):
        fn._mh_max_examples = max_examples
        return fn
    return deco


def given(*strategies: Strategy):
    """Run the test ``max_examples`` times on deterministic seeded draws."""
    def deco(fn):
        def wrapper():
            n = getattr(fn, "_mh_max_examples", 20)
            base = zlib.adler32(fn.__qualname__.encode())
            for i in range(n):
                rng = np.random.default_rng((base, i))
                fn(*[s.example(rng) for s in strategies])
        # plain attributes only: functools.wraps would set __wrapped__ and
        # make pytest see the wrapped signature's params as fixtures
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        return wrapper
    return deco


def install() -> None:
    """Register this module as ``hypothesis`` (idempotent; no-op if the real
    wheel is importable — callers check that first)."""
    if "hypothesis" in sys.modules:
        return
    hyp = types.ModuleType("hypothesis")
    st_mod = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "sampled_from", "lists",
                 "tuples", "just", "composite"):
        setattr(st_mod, name, globals()[name])
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = st_mod
    hyp.__version__ = "0.0.minihypothesis"
    hyp.IS_MINIHYPOTHESIS = True
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st_mod
