"""Doctest-style runner for fenced ``python`` blocks in markdown docs.

CI's fast tier executes every fenced ``python`` block in ``README.md`` and
``docs/*.md`` (scripts/ci.sh) so the documentation examples cannot rot: a
renamed function or changed signature breaks the build, not the reader.

Rules:
  * only blocks fenced exactly as ```` ```python ```` run — use ```` ```text
    ````, ```` ```bash ```` or a plain fence for non-executable listings;
  * blocks within one file share a namespace, executing top to bottom, so a
    later snippet can build on names a previous one defined (doctest-style
    narrative docs);
  * any exception propagates with a filename + snippet index in the
    traceback's synthetic filename.

Usage::

    PYTHONPATH=src python -m repro.testing.docsnippets README.md docs/*.md
"""
from __future__ import annotations

import pathlib
import re
import sys

FENCE_RE = re.compile(r"^```python[ \t]*\r?\n(.*?)^```[ \t]*$",
                      re.MULTILINE | re.DOTALL)


def extract_blocks(text: str) -> list[str]:
    """Source of every fenced ```python block, in document order."""
    return [m.group(1) for m in FENCE_RE.finditer(text)]


def run_file(path: str | pathlib.Path) -> int:
    """Execute all python snippets in one markdown file (shared namespace);
    returns how many ran."""
    text = pathlib.Path(path).read_text()
    ns: dict = {"__name__": f"docsnippet:{path}"}
    blocks = extract_blocks(text)
    for i, src in enumerate(blocks):
        code = compile(src, f"{path}[snippet {i}]", "exec")
        exec(code, ns)  # noqa: S102 — executing our own docs is the point
    return len(blocks)


def main(argv: list[str]) -> None:
    if not argv:
        raise SystemExit("usage: python -m repro.testing.docsnippets "
                         "FILE.md [FILE.md ...]")
    total = 0
    for path in argv:
        n = run_file(path)
        print(f"{path}: {n} snippet(s) OK")
        total += n
    if total == 0:
        raise SystemExit("no fenced python snippets found in any input")
    print(f"docs check OK: {total} snippet(s) across {len(argv)} file(s)")


if __name__ == "__main__":
    main(sys.argv[1:])
