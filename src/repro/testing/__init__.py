"""Test-support utilities shipped with the package (no runtime dependents).

``minihypothesis`` is an API-compatible subset of ``hypothesis`` used as a
seeded-random-search fallback so the property tier runs even in hermetic
environments where the real wheel cannot be installed (tests/conftest.py).
"""
