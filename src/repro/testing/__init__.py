"""Test-support utilities shipped with the package (no runtime dependents).

``minihypothesis`` is an API-compatible subset of ``hypothesis`` used as a
seeded-random-search fallback so the property tier runs even in hermetic
environments where the real wheel cannot be installed (tests/conftest.py).

``docsnippets`` is the doctest-style markdown runner behind CI's docs check:
it executes every fenced ```python block in README.md / docs/*.md so the
documented examples cannot drift from the code (scripts/ci.sh fast tier).
"""
from .docsnippets import extract_blocks, run_file  # noqa: F401
