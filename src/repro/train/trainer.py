"""Trainer loop: data -> step -> metrics, with checkpoint/restart and a
straggler watchdog.

Fault-tolerance behaviours (all unit-tested):
  * restart: on construction the trainer restores the newest checkpoint
    (params, optimizer, error-feedback state, step, data cursor) and the loss
    sequence continues bitwise identically (tests/test_ckpt.py);
  * periodic + final checkpointing, atomic, keep-last-k;
  * straggler watchdog: per-step wall time tracked with an EWMA; steps
    slower than ``straggler_factor``x the EWMA are logged with a mitigation
    decision. On a real fleet the decision triggers the elastic path
    (ckpt/elastic.py) — on this single-host container it is a policy-level
    log, exercised by injecting artificial delays in tests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.ckpt import checkpoint as ckpt
from repro.data.tokens import TokenStream
from repro.models import zoo
from repro.obs import sentinels
from repro.optim import adamw_init
from .step import TrainConfig, build_train_step


@dataclasses.dataclass
class WatchdogEvent:
    step: int
    seconds: float
    ewma: float
    action: str


class StragglerWatchdog:
    def __init__(self, factor: float = 3.0, alpha: float = 0.2, warmup: int = 2):
        self.factor = factor
        self.alpha = alpha
        self.warmup = warmup          # ignore the first steps (jit compile time)
        self.seen = 0
        self.ewma: float | None = None
        self.events: list[WatchdogEvent] = []

    def observe(self, step: int, seconds: float) -> WatchdogEvent | None:
        self.seen += 1
        if self.seen <= self.warmup:
            return None
        if self.ewma is None:
            self.ewma = seconds
            return None
        flagged = seconds > self.factor * self.ewma
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * seconds
        if flagged:
            ev = WatchdogEvent(step, seconds, self.ewma,
                               "flag: candidate for elastic reshard / hot spare swap")
            self.events.append(ev)
            return ev
        return None


class Trainer:
    def __init__(self, model: zoo.Model, shape, mesh, tcfg: TrainConfig, *,
                 stream: TokenStream, ckpt_dir: str | None = None,
                 ckpt_every: int = 50, seed: int = 0,
                 ckpt_codec: str = "raw", keep_last: int = 3):
        self.model, self.shape, self.mesh, self.tcfg = model, shape, mesh, tcfg
        self.stream = stream
        self.ckpt_dir, self.ckpt_every = ckpt_dir, ckpt_every
        self.ckpt_codec, self.keep_last = ckpt_codec, keep_last
        self.watchdog = StragglerWatchdog()
        self.step_fn, self.info = build_train_step(model, shape, mesh, tcfg)

        params = model.init(jax.random.key(seed))
        opt = adamw_init(params)
        self.params = jax.device_put(params, self.info["params"])
        self.opt = jax.device_put(opt, self.info["opt"])
        grads_abs = jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), self.params)
        self.err = self._place_err(self.info["make_err_state"](grads_abs))
        self.step = 0
        self.history: list[dict] = []
        if ckpt_dir is not None and ckpt.latest_step(ckpt_dir) is not None:
            self._restore()

    # ------------------------------------------------------------------
    def _state(self):
        return {"params": self.params, "opt": self.opt, "err": self.err}

    def _place_err(self, err):
        """Stacked residuals live pod-sharded, not replicated."""
        if self.info["err_shardings"] is None:
            return err
        grads_abs = jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), self.params)
        return jax.device_put(err, self.info["err_shardings"](grads_abs))

    def _restore(self):
        template = jax.tree.map(lambda x: np.zeros(x.shape, x.dtype), self._state())
        state, meta = ckpt.restore(self.ckpt_dir, template)
        self.params = jax.device_put(state["params"], self.info["params"])
        self.opt = jax.device_put(state["opt"], self.info["opt"])
        self.err = self._place_err(jax.tree.map(jnp.asarray, state["err"]))
        self.step = int(meta["step"])

    def save(self):
        if self.ckpt_dir is None:
            return
        ckpt.save(self.ckpt_dir, self.step, self._state(),
                  meta={"data_seed": self.stream.seed},
                  codec=self.ckpt_codec, keep_last=self.keep_last)

    # ------------------------------------------------------------------
    def _batch(self, step: int) -> dict:
        arr = self.stream.shard_batch(step, shard=0, num_shards=1)
        return {"tokens": jnp.asarray(arr[:, :-1]), "labels": jnp.asarray(arr[:, 1:])}

    def run(self, n_steps: int, *, delay_injector: Callable[[int], float] | None = None):
        tokens_per_batch = None
        for _ in range(n_steps):
            t0 = time.perf_counter()
            with obs.span("train.step", step=self.step):
                batch = self._batch(self.step)
                self.params, self.opt, self.err, metrics = self.step_fn(
                    self.params, self.opt, self.err, jnp.int32(self.step), batch)
                metrics = {k: float(v) for k, v in metrics.items()}
            if delay_injector is not None:
                time.sleep(delay_injector(self.step))
            dt = time.perf_counter() - t0
            if tokens_per_batch is None:
                tokens_per_batch = int(batch["tokens"].size)
            obs.gauge("train_tokens_per_s").set(tokens_per_batch / max(dt, 1e-9))
            obs.counter("train_steps").inc()
            sentinels.assert_healthy()
            ev = self.watchdog.observe(self.step, dt)
            metrics.update(step=self.step, seconds=dt,
                           straggler=bool(ev))
            self.history.append(metrics)
            self.step += 1
            if self.ckpt_dir is not None and self.step % self.ckpt_every == 0:
                self.save()
        if self.ckpt_dir is not None:
            self.save()
        return self.history
