"""Train / serve step builders: sharded, jit-able, dry-run-lowerable.

``build_train_step`` assembles the full production step:
  microbatch gradient accumulation (scan) -> optional FZ-compressed cross-pod
  gradient all-reduce with error feedback (manual 'pod' axis via hybrid
  shard_map; in-pod collectives stay XLA-automatic) -> global-norm clip ->
  AdamW with f32 master/moments sharded like the params.

``build_prefill_step`` / ``build_decode_step`` are the serving analogues.
All builders return (fn, in_shardings, out_shardings, input_structs) so the
same artifacts serve training, serving, and the dry-run compiler.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ShapeConfig
from repro.dist import bucketed_reduce as bkt
from repro.dist import compressed_allreduce as car
from repro.dist import sharding as shd
from repro.models import nn, zoo
from repro.optim import AdamWConfig, adamw_init, adamw_update, global_norm, warmup_cosine


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    microbatches: int = 1              # gradient-accumulation steps
    adamw: AdamWConfig = AdamWConfig()
    grad_compress: car.GradCompressionConfig = car.GradCompressionConfig(enabled=False)


def _named(mesh, spec_tree_, abstract_tree):
    return shd.tree_shardings(spec_tree_, abstract_tree, mesh)


def _install_act_sharder(mesh) -> None:
    """Route model-side nn.shard_act calls to this mesh (trace-time global)."""

    def sharder(x, logical):
        spec = shd.resolve_spec(tuple(logical), x.shape, mesh)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    nn.set_act_sharder(sharder)


def _install_grad_tap(overlap: bool) -> None:
    """Arm (or disarm) the model-side gradient-boundary taps: with the
    overlapped bucketed reduce, each parameter group's cotangents pass
    through an optimization_barrier so the per-bucket hops see real
    boundaries. The tap is a trace-time global (same idiom as the act
    sharder) but jit traces lazily, so each step function calls this at the
    TOP OF ITS OWN BODY — building several steps in any order and calling
    them later still traces each with its own tap state."""
    nn.set_grad_tap(bkt.grad_boundary if overlap else None)


def _loss_and_grads(model: zoo.Model, params, batch, n_micro: int):
    """Gradient accumulation over ``n_micro`` microbatches via scan."""
    if n_micro == 1:
        (loss, aux), grads = jax.value_and_grad(model.train_loss, has_aux=True)(params, batch)
        return loss, grads

    def split(x):
        b = x.shape[0]
        return x.reshape((n_micro, b // n_micro) + x.shape[1:])

    micro = jax.tree.map(split, batch)

    def acc_step(carry, mb):
        loss_acc, g_acc = carry
        (loss, _), g = jax.value_and_grad(model.train_loss, has_aux=True)(params, mb)
        g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), g_acc, g)
        return (loss_acc + loss, g_acc), None

    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss_sum, grads), _ = jax.lax.scan(acc_step, (jnp.float32(0), g0), micro)
    inv = 1.0 / n_micro
    return loss_sum * inv, jax.tree.map(lambda g: g * inv, grads)


def build_train_step(model: zoo.Model, shape: ShapeConfig, mesh, tcfg: TrainConfig):
    """Returns (step_fn, state_shardings, input structs/shardings).

    step(params, opt_state, err_state, step_idx, batch)
      -> (params, opt_state, err_state, metrics)
    """
    _install_act_sharder(mesh)
    cfg = model.cfg
    specs = model.param_specs()
    abstract = model.abstract_params()
    param_sh = _named(mesh, specs, abstract)
    opt_abstract = jax.eval_shape(adamw_init, abstract)
    opt_specs = {
        "m": specs, "v": specs, "master": specs,
        "count": (),
    }
    opt_sh = {
        "m": _named(mesh, specs, opt_abstract["m"]),
        "v": _named(mesh, specs, opt_abstract["v"]),
        "master": _named(mesh, specs, opt_abstract["master"]),
        "count": NamedSharding(mesh, P()),
    }
    in_structs, in_logical = model.input_specs(shape)
    batch_sh = {k: NamedSharding(mesh, shd.resolve_spec(in_logical[k], v.shape, mesh))
                for k, v in in_structs.items()}

    use_pod_compress = tcfg.grad_compress.enabled and "pod" in mesh.axis_names
    overlap = use_pod_compress and tcfg.grad_compress.overlap
    n_pods = mesh.shape.get("pod", 1)

    def _finish(loss, grads, params, opt_state, step_idx):
        lr = warmup_cosine(step_idx, peak_lr=tcfg.peak_lr,
                           warmup_steps=tcfg.warmup_steps, total_steps=tcfg.total_steps)
        new_params, new_opt = adamw_update(grads, opt_state, lr, tcfg.adamw, params)
        metrics = {"loss": loss, "lr": lr, "grad_norm": global_norm(grads)}
        return new_params, new_opt, metrics

    if use_pod_compress:
        # per-pod gradients via vmap over a leading pod dim (loss/backward
        # stay pure-auto SPMD); the reduce hops themselves are manual
        # shard_maps over 'pod' with error feedback. Barrier form (one hop
        # per leaf after the full backward): dist/compressed_allreduce.py;
        # overlap form (size-targeted buckets issued in backward production
        # order, grad_boundary taps armed): dist/bucketed_reduce.py.
        plan = bkt.assign_buckets(abstract, tcfg.grad_compress) if overlap else None

        def step(params, opt_state, err_state, step_idx, batch):
            _install_grad_tap(overlap)   # runs at trace time, see helper

            def split(x):
                b = x.shape[0]
                return x.reshape((n_pods, b // n_pods) + x.shape[1:])

            pods_batch = jax.tree.map(split, batch)

            def pod_loss(p, b):
                l, g = _loss_and_grads(model, p, b, tcfg.microbatches)
                return l, g

            losses, grads_stacked = jax.vmap(pod_loss, in_axes=(None, 0))(params, pods_batch)
            if overlap:
                grads, err_state = bkt.reduce_stacked_bucketed(
                    grads_stacked, err_state, tcfg.grad_compress, mesh, plan=plan)
            else:
                grads, err_state = car.reduce_stacked(grads_stacked, err_state,
                                                      tcfg.grad_compress, mesh)
            p, o, m = _finish(jnp.mean(losses), grads, params, opt_state, step_idx)
            return p, o, err_state, m

        # batch leading dim shards over (pod, data); after the split-reshape the
        # pod factor aligns with the new leading axis
        err_sh_fn = lambda ga: car.error_state_shardings(ga, tcfg.grad_compress, mesh)
    else:
        def step(params, opt_state, err_state, step_idx, batch):
            _install_grad_tap(False)     # runs at trace time, see helper
            loss, grads = _loss_and_grads(model, params, batch, tcfg.microbatches)
            p, o, m = _finish(loss, grads, params, opt_state, step_idx)
            return p, o, err_state, m

        err_sh_fn = None

    def make_err_state(grads_abstract):
        if not use_pod_compress:   # no pod axis -> step never reads err
            return {}
        return car.init_error_state(grads_abstract, n_pods, tcfg.grad_compress)

    jitted = jax.jit(
        step,
        in_shardings=(param_sh, opt_sh, None, NamedSharding(mesh, P()), batch_sh),
        out_shardings=(param_sh, opt_sh, None, None),
        donate_argnums=(0, 1, 2),
    )
    return jitted, dict(params=param_sh, opt=opt_sh, batch=batch_sh,
                        input_structs=in_structs, make_err_state=make_err_state,
                        err_shardings=err_sh_fn)


def build_prefill_step(model: zoo.Model, shape: ShapeConfig, mesh):
    _install_act_sharder(mesh)
    cfg = model.cfg
    param_sh = _named(mesh, model.param_specs(), model.abstract_params())
    in_structs, in_logical = model.input_specs(shape)
    batch_sh = {k: NamedSharding(mesh, shd.resolve_spec(in_logical[k], v.shape, mesh))
                for k, v in in_structs.items()}
    cache_abs, cache_logical = model.cache_specs(shape)
    cache_sh = {k: NamedSharding(mesh, shd.resolve_spec(cache_logical[k], v.shape, mesh))
                for k, v in cache_abs.items()}

    def prefill(params, batch):
        return model.prefill(params, batch)

    jitted = jax.jit(prefill, in_shardings=(param_sh, batch_sh),
                     out_shardings=(None, cache_sh))
    return jitted, dict(params=param_sh, batch=batch_sh, cache=cache_sh,
                        input_structs=in_structs, cache_structs=cache_abs)


def build_decode_step(model: zoo.Model, shape: ShapeConfig, mesh):
    _install_act_sharder(mesh)
    cfg = model.cfg
    param_sh = _named(mesh, model.param_specs(), model.abstract_params())
    in_structs, in_logical = model.input_specs(shape)
    tok_sh = {k: NamedSharding(mesh, shd.resolve_spec(in_logical[k], v.shape, mesh))
              for k, v in in_structs.items()}
    cache_abs, cache_logical = model.cache_specs(shape)
    cache_sh = {k: NamedSharding(mesh, shd.resolve_spec(cache_logical[k], v.shape, mesh))
                for k, v in cache_abs.items()}

    def decode(params, cache, inputs):
        return model.decode(params, cache, inputs["token"], inputs.get("positions"))

    jitted = jax.jit(decode, in_shardings=(param_sh, cache_sh, tok_sh),
                     out_shardings=(None, cache_sh), donate_argnums=(1,))
    return jitted, dict(params=param_sh, cache=cache_sh, batch=tok_sh,
                        input_structs=in_structs, cache_structs=cache_abs)
