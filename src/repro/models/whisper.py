"""Whisper-tiny encoder-decoder backbone (audio frontend stubbed per brief).

The conv/mel frontend is a STUB: ``input_specs()`` supplies precomputed frame
embeddings (B, n_audio_ctx, d) directly to the encoder. Whisper-style
internals: pre-LayerNorm, GELU MLP, biasless simplification of projections,
sinusoidal encoder positions / learned decoder positions, MHA (kv = heads).

Decode shapes drive the DECODER at the assigned sequence length with cached
self-attention KV and precomputed cross-attention KV (DESIGN.md §4 notes the
departure from Whisper's released 448-token decoder window: the assigned
shape suite exercises the systems path, not the audio task).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from . import nn
from .attention import decode_attention, flash_attention

DP = "fsdp"
TP = "tp"

MAX_DEC_POS = 65_536  # learned decoder positions table (>= assigned 32k+margin)


def _attn_defs(L, d, heads, hd):
    return {
        "norm": nn.Param((L, d), (None, None), init="ones"),
        "wq": nn.Param((L, d, heads * hd), (None, DP, TP)),
        "wk": nn.Param((L, d, heads * hd), (None, DP, TP)),
        "wv": nn.Param((L, d, heads * hd), (None, DP, TP)),
        "wo": nn.Param((L, heads * hd, d), (None, TP, DP)),
    }


def _mlp_defs(L, d, f):
    return {
        "norm": nn.Param((L, d), (None, None), init="ones"),
        "w_up": nn.Param((L, d, f), (None, DP, TP)),
        "b_up": nn.Param((L, f), (None, TP), init="zeros"),
        "w_down": nn.Param((L, f, d), (None, TP, DP)),
        "b_down": nn.Param((L, d), (None, DP), init="zeros"),
    }


def model_defs(cfg: ArchConfig) -> dict:
    d, hd, H = cfg.d_model, cfg.hd, cfg.n_heads
    Le, Ld = cfg.n_enc_layers, cfg.n_layers
    return {
        "enc": {"self": _attn_defs(Le, d, H, hd), "mlp": _mlp_defs(Le, d, cfg.d_ff),
                "final_norm": nn.Param((d,), (None,), init="ones")},
        "dec": {"embed": nn.Param((cfg.vocab, d), (None, TP), init="embed"),
                "pos": nn.Param((MAX_DEC_POS, d), (None, TP), init="embed"),
                "self": _attn_defs(Ld, d, H, hd),
                "cross": _attn_defs(Ld, d, H, hd),
                "mlp": _mlp_defs(Ld, d, cfg.d_ff),
                "final_norm": nn.Param((d,), (None,), init="ones")},
    }


def _sin_pos(n: int, d: int) -> jax.Array:
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10_000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _attn(lp, x, kv_src, cfg, causal):
    B, S, _ = x.shape
    hd = cfg.hd
    h = nn.rms_norm(x, lp["norm"], cfg.norm_eps)
    hk = nn.rms_norm(kv_src, lp["norm"], cfg.norm_eps) if kv_src is not x else h
    q = nn.dense(h, lp["wq"]).reshape(B, S, cfg.n_heads, hd)
    k = nn.dense(hk, lp["wk"]).reshape(B, kv_src.shape[1], cfg.n_heads, hd)
    v = nn.dense(hk, lp["wv"]).reshape(B, kv_src.shape[1], cfg.n_heads, hd)
    o = flash_attention(q, k, v, causal=causal)
    return x + nn.dense(o.reshape(B, S, -1), lp["wo"]), (k, v)


def _mlp(lp, x, cfg):
    h = nn.rms_norm(x, lp["norm"], cfg.norm_eps)
    return x + nn.gelu_mlp(h, lp["w_up"], lp["b_up"], lp["w_down"], lp["b_down"])


def encode(params, cfg: ArchConfig, audio_embeds: jax.Array) -> jax.Array:
    """audio_embeds: (B, n_audio_ctx, d) from the stubbed conv frontend."""
    enc = params["enc"]
    x = audio_embeds + _sin_pos(audio_embeds.shape[1], cfg.d_model).astype(audio_embeds.dtype)

    def body(x, lp):
        sa, ml = lp
        x, _ = _attn(sa, x, x, cfg, causal=False)
        return nn.shard_act(_mlp(ml, x, cfg), ("dp", None, None)), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, (enc["self"], enc["mlp"]))
    return nn.rms_norm(x, enc["final_norm"], cfg.norm_eps)


def _decoder(params, cfg, tokens, enc_out, collect_cache=False, Smax=None):
    dec = params["dec"]
    B, S = tokens.shape
    x = nn.embed_lookup(tokens, dec["embed"]) + dec["pos"][:S].astype(jnp.bfloat16)

    def body(x, lp):
        sa, ca, ml = lp
        x = nn.shard_act(x, ("dp", None, None))
        x, (ks, vs) = _attn(sa, x, x, cfg, causal=True)
        x, (kc, vc) = _attn(ca, x, enc_out, cfg, causal=False)
        x = _mlp(ml, x, cfg)
        if collect_cache:
            pad = [(0, 0), (0, Smax - S), (0, 0), (0, 0)]
            return x, (jnp.pad(ks, pad).astype(jnp.bfloat16),
                       jnp.pad(vs, pad).astype(jnp.bfloat16),
                       kc.astype(jnp.bfloat16), vc.astype(jnp.bfloat16))
        return x, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, caches = jax.lax.scan(body_fn, x, (dec["self"], dec["cross"], dec["mlp"]))
    x = nn.rms_norm(x, dec["final_norm"], cfg.norm_eps)
    return x, caches


def forward_train(params, cfg: ArchConfig, batch):
    enc_out = encode(params, cfg, batch["audio_embeds"])
    x, _ = _decoder(params, cfg, batch["tokens"], enc_out)
    logits = nn.dense(x, params["dec"]["embed"].T)  # tied embeddings
    loss = nn.sharded_xent(logits, batch["labels"])
    return loss, {"xent": loss}


def init_cache(cfg: ArchConfig, B: int, S: int, dtype=jnp.bfloat16) -> dict:
    from .transformer import cache_len
    Smax = cache_len(S)
    L, H, hd = cfg.n_layers, cfg.n_heads, cfg.hd
    return {
        "k": jnp.zeros((L, B, Smax, H, hd), dtype),
        "v": jnp.zeros((L, B, Smax, H, hd), dtype),
        "xk": jnp.zeros((L, B, cfg.n_audio_ctx, H, hd), dtype),
        "xv": jnp.zeros((L, B, cfg.n_audio_ctx, H, hd), dtype),
        "length": jnp.zeros((B,), jnp.int32),
    }


def forward_prefill(params, cfg: ArchConfig, batch):
    from .transformer import cache_len
    tokens = batch["tokens"]
    B, S = tokens.shape
    enc_out = encode(params, cfg, batch["audio_embeds"])
    x, (ks, vs, xks, xvs) = _decoder(params, cfg, tokens, enc_out,
                                     collect_cache=True, Smax=cache_len(S))
    logits = nn.dense(x[:, -1], params["dec"]["embed"].T)
    cache = {"k": ks, "v": vs, "xk": xks, "xv": xvs,
             "length": jnp.full((B,), S, jnp.int32)}
    return logits, cache


def forward_decode(params, cfg: ArchConfig, cache, token, positions=None):
    dec = params["dec"]
    B = token.shape[0]
    length = cache["length"]
    x = nn.embed_lookup(token, dec["embed"]) + \
        jnp.take(dec["pos"], length, axis=0).astype(jnp.bfloat16)
    hd = cfg.hd

    def body(x, inp):
        sa, ca, ml, kc, vc, xk, xv = inp
        h = nn.rms_norm(x[:, None], sa["norm"], cfg.norm_eps)
        q = nn.dense(h, sa["wq"]).reshape(B, 1, cfg.n_heads, hd)
        k = nn.dense(h, sa["wk"]).reshape(B, 1, cfg.n_heads, hd)
        v = nn.dense(h, sa["wv"]).reshape(B, 1, cfg.n_heads, hd)
        onehot = (jnp.arange(kc.shape[1])[None, :] == length[:, None])
        kc = jnp.where(onehot[:, :, None, None], k[:, 0][:, None].astype(kc.dtype), kc)
        vc = jnp.where(onehot[:, :, None, None], v[:, 0][:, None].astype(vc.dtype), vc)
        o = decode_attention(q[:, 0], kc, vc, length + 1)
        x = x + nn.dense(o.reshape(B, -1), sa["wo"])
        # cross attention over the fixed encoder context
        h = nn.rms_norm(x[:, None], ca["norm"], cfg.norm_eps)
        q = nn.dense(h, ca["wq"]).reshape(B, cfg.n_heads, hd)
        full = jnp.full((B,), xk.shape[1], jnp.int32)
        o = decode_attention(q, xk, xv, full)
        x = x + nn.dense(o.reshape(B, -1), ca["wo"])
        h = nn.rms_norm(x[:, None], ml["norm"], cfg.norm_eps)
        x = x + nn.gelu_mlp(h, ml["w_up"], ml["b_up"], ml["w_down"], ml["b_down"])[:, 0]
        return x, (kc, vc)

    x, (ks, vs) = jax.lax.scan(
        body, x, (dec["self"], dec["cross"], dec["mlp"],
                  cache["k"], cache["v"], cache["xk"], cache["xv"]))
    x = nn.rms_norm(x, dec["final_norm"], cfg.norm_eps)
    logits = nn.dense(x, dec["embed"].T)
    return logits, {"k": ks, "v": vs, "xk": cache["xk"], "xv": cache["xv"],
                    "length": length + 1}
