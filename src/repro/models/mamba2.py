"""Mamba2 (SSD) blocks — zamba2 backbone.

Chunked state-space-dual algorithm: scalar per-head decay means the
intra-chunk kernel is a (c, c) decay-masked attention-like matmul and the
inter-chunk state is carried by a lax.scan — O(S·c) memory, exact.

All decay exponents within the algorithm are <= 0 (cumulative log-decays and
their ordered differences), so the chunked form is numerically safe in f32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from . import nn

DP = "fsdp"
TP = "tp"

CHUNK = 128


def dims(cfg: ArchConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    nh = d_inner // cfg.ssm_head_dim
    return d_inner, nh, cfg.ssm_head_dim, cfg.ssm_state


def mamba_defs(cfg: ArchConfig, n_layers: int) -> dict:
    d = cfg.d_model
    d_inner, nh, hd, ds = dims(cfg)
    conv_ch = d_inner + 2 * ds
    L = n_layers
    return {
        "norm": nn.Param((L, d), (None, None), init="ones"),
        "in_proj": nn.Param((L, d, 2 * d_inner + 2 * ds + nh), (None, DP, TP)),
        "conv_w": nn.Param((L, cfg.conv_width, conv_ch), (None, None, TP), dtype=jnp.float32),
        "conv_b": nn.Param((L, conv_ch), (None, TP), init="zeros", dtype=jnp.float32),
        "A_log": nn.Param((L, nh), (None, TP), init="zeros", dtype=jnp.float32),
        "dt_bias": nn.Param((L, nh), (None, TP), init="zeros", dtype=jnp.float32),
        "D": nn.Param((L, nh), (None, TP), init="ones", dtype=jnp.float32),
        "ssm_norm": nn.Param((L, d_inner), (None, TP), init="ones"),
        "out_proj": nn.Param((L, d_inner, d), (None, TP, DP)),
    }


def _split(lp, x, cfg):
    d_inner, nh, hd, ds = dims(cfg)
    zxbcdt = nn.dense(x, lp["in_proj"])
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * ds], axis=-1)
    return z, xbc, dt


def _conv(xbc: jax.Array, w: jax.Array, b: jax.Array, state: jax.Array | None):
    """Causal depthwise conv width W via shifted adds. xbc: (B, S, C).
    state: (B, W-1, C) trailing context (decode) or None (zero history).
    Returns (out, new_state)."""
    W = w.shape[0]
    B, S, C = xbc.shape
    hist = jnp.zeros((B, W - 1, C), xbc.dtype) if state is None else state.astype(xbc.dtype)
    ext = jnp.concatenate([hist, xbc], axis=1)  # (B, S+W-1, C)
    out = jnp.zeros((B, S, C), jnp.float32)
    for i in range(W):
        out = out + ext[:, i:i + S].astype(jnp.float32) * w[i]
    new_state = ext[:, S:]  # last W-1 inputs
    return jax.nn.silu(out + b).astype(xbc.dtype), new_state


def _ssd_chunked(xh, bmat, cmat, dt, A_log, D, h0):
    """Chunked SSD scan.

    xh: (B,S,nh,hd); bmat/cmat: (B,S,ds); dt: (B,S,nh) raw; h0: (B,nh,hd,ds).
    Returns (y (B,S,nh,hd), h_final).
    """
    B, S, nh, hd = xh.shape
    ds = bmat.shape[-1]
    c = min(CHUNK, S)
    pad = (-S) % c
    if pad:
        # state-neutral padding: dt -> -30 makes softplus(dt) ~ 0 (no decay,
        # no input contribution); padded outputs sliced off below
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)), constant_values=-30.0)
        S = S + pad
    n = S // c
    dt = jax.nn.softplus(dt.astype(jnp.float32))            # (B,S,nh)
    la = -jnp.exp(A_log)[None, None, :] * dt                 # log decay, <= 0
    xf = (xh.astype(jnp.float32) * dt[..., None]).reshape(B, n, c, nh, hd)
    bf = bmat.astype(jnp.float32).reshape(B, n, c, ds)
    cf = cmat.astype(jnp.float32).reshape(B, n, c, ds)
    laf = la.reshape(B, n, c, nh)

    def chunk_step(h, inp):
        xc, bc, cc, lac = inp  # (B,c,nh,hd), (B,c,ds), (B,c,ds), (B,c,nh)
        La = jnp.cumsum(lac, axis=1)                         # (B,c,nh) inclusive
        # intra-chunk: decay-masked attention
        diff = La[:, :, None, :] - La[:, None, :, :]         # (B,c,c,nh) t,s
        tri = jnp.tril(jnp.ones((c, c), bool))
        M = jnp.where(tri[None, :, :, None], jnp.exp(diff), 0.0)
        score = jnp.einsum("btd,bsd->bts", cc, bc)           # (B,c,c)
        y = jnp.einsum("bts,btsh,bshe->bthe", score, M, xc)  # (B,c,nh,hd)
        # inter-chunk: contribution of the carried state
        y = y + jnp.einsum("btd,bth,bhed->bthe", cc, jnp.exp(La), h)
        # state update
        decay_to_end = jnp.exp(La[:, -1:, :] - La)           # (B,c,nh)
        dh = jnp.einsum("bsh,bshe,bsd->bhed", decay_to_end, xc, bc)
        h = jnp.exp(La[:, -1])[:, :, None, None] * h + dh
        return h, y

    h, ys = jax.lax.scan(chunk_step, h0.astype(jnp.float32),
                         (xf.swapaxes(0, 1), bf.swapaxes(0, 1),
                          cf.swapaxes(0, 1), laf.swapaxes(0, 1)))
    y = ys.swapaxes(0, 1).reshape(B, S, nh, hd)
    y = y + xh.astype(jnp.float32) * D[None, None, :, None]
    return y, h


def mamba_block(lp: dict, x: jax.Array, cfg: ArchConfig,
                ssm_state=None, conv_state=None):
    """One Mamba2 block. x: (B, S, d). Returns (out, (ssm_state, conv_state))."""
    B, S, d = x.shape
    d_inner, nh, hd, ds = dims(cfg)
    h = nn.rms_norm(x, lp["norm"], cfg.norm_eps)
    z, xbc, dt = _split(lp, h, cfg)
    xbc, conv_state = _conv(xbc, lp["conv_w"], lp["conv_b"], conv_state)
    xs, bmat, cmat = jnp.split(xbc, [d_inner, d_inner + ds], axis=-1)
    xh = xs.reshape(B, S, nh, hd)
    h0 = jnp.zeros((B, nh, hd, ds), jnp.float32) if ssm_state is None else ssm_state
    y, h_final = _ssd_chunked(xh, bmat, cmat, dt, lp["A_log"], lp["D"], h0)
    y = y.reshape(B, S, d_inner).astype(x.dtype)
    y = nn.rms_norm(y, lp["ssm_norm"], cfg.norm_eps) * jax.nn.silu(z)
    return x + nn.dense(y, lp["out_proj"]), (h_final, conv_state)


def mamba_decode_step(lp: dict, x: jax.Array, cfg: ArchConfig, ssm_state, conv_state):
    """Single-token recurrence. x: (B, d). States as in mamba_block."""
    B, d = x.shape
    d_inner, nh, hd, ds = dims(cfg)
    h = nn.rms_norm(x[:, None], lp["norm"], cfg.norm_eps)
    z, xbc, dt = _split(lp, h, cfg)
    xbc, conv_state = _conv(xbc, lp["conv_w"], lp["conv_b"], conv_state)
    xs, bmat, cmat = jnp.split(xbc[:, 0], [d_inner, d_inner + ds], axis=-1)
    xh = xs.reshape(B, nh, hd).astype(jnp.float32)
    dtf = jax.nn.softplus(dt[:, 0].astype(jnp.float32))      # (B,nh)
    a = jnp.exp(-jnp.exp(lp["A_log"])[None] * dtf)           # (B,nh)
    upd = jnp.einsum("bhe,bd->bhed", xh * dtf[..., None], bmat.astype(jnp.float32))
    ssm_state = a[:, :, None, None] * ssm_state + upd
    y = jnp.einsum("bd,bhed->bhe", cmat.astype(jnp.float32), ssm_state)
    y = y + xh * lp["D"][None, :, None]
    y = y.reshape(B, d_inner).astype(x.dtype)
    y = nn.rms_norm(y, lp["ssm_norm"], cfg.norm_eps) * jax.nn.silu(z[:, 0])
    return x + nn.dense(y, lp["out_proj"]), (ssm_state, conv_state)
