"""Zamba2: Mamba2 backbone + a weight-SHARED attention block every K layers.

Structure (per the Zamba2 papers, simplified to systems-relevant shape):
the backbone is ``n_layers`` Mamba2 blocks; before every
``shared_attn_every``-th group, one shared transformer block (attention +
SwiGLU MLP, ONE set of weights reused at every invocation) runs on
concat(hidden, original embedding) projected back to d_model by a
per-invocation linear. KV caches exist per invocation site (weights are
shared; caches are not).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from . import mamba2, nn
from .attention import apply_rope, decode_attention, flash_attention

DP = "fsdp"
TP = "tp"


def n_invocations(cfg: ArchConfig) -> int:
    return cfg.n_layers // cfg.shared_attn_every


def model_defs(cfg: ArchConfig) -> dict:
    d, hd = cfg.d_model, cfg.hd
    K = n_invocations(cfg)
    shared = {
        "attn_norm": nn.Param((d,), (None,), init="ones"),
        "wq": nn.Param((d, cfg.n_heads * hd), (DP, TP)),
        "wk": nn.Param((d, cfg.n_kv_heads * hd), (DP, TP)),
        "wv": nn.Param((d, cfg.n_kv_heads * hd), (DP, TP)),
        "wo": nn.Param((cfg.n_heads * hd, d), (TP, DP)),
        "mlp_norm": nn.Param((d,), (None,), init="ones"),
        "w_gate": nn.Param((d, cfg.d_ff), (DP, TP)),
        "w_up": nn.Param((d, cfg.d_ff), (DP, TP)),
        "w_down": nn.Param((cfg.d_ff, d), (TP, DP)),
    }
    return {
        "embed": nn.Param((cfg.vocab, cfg.d_model), (None, TP), init="embed"),
        "shared": shared,
        "fuse_proj": nn.Param((K, 2 * d, d), (None, DP, TP)),
        "mamba": mamba2.mamba_defs(cfg, cfg.n_layers),
        "final_norm": nn.Param((d,), (None,), init="ones"),
        "unembed": nn.Param((d, cfg.vocab), (DP, TP)),
    }


def _shared_block_train(sp, h, cfg, pos):
    B, S, _ = h.shape
    hd = cfg.hd
    a = nn.rms_norm(h, sp["attn_norm"], cfg.norm_eps)
    q = apply_rope(nn.dense(a, sp["wq"]).reshape(B, S, cfg.n_heads, hd), pos, cfg.rope_theta)
    k = apply_rope(nn.dense(a, sp["wk"]).reshape(B, S, cfg.n_kv_heads, hd), pos, cfg.rope_theta)
    v = nn.dense(a, sp["wv"]).reshape(B, S, cfg.n_kv_heads, hd)
    o = flash_attention(q, k, v, causal=True)
    h = h + nn.dense(o.reshape(B, S, -1), sp["wo"])
    m = nn.rms_norm(h, sp["mlp_norm"], cfg.norm_eps)
    return h + nn.swiglu(m, sp["w_gate"], sp["w_up"], sp["w_down"]), (k, v)


def _stack_mamba(params_mamba: dict, K: int):
    """(L, ...) stacked mamba params -> (K, per, ...) for the superblock scan."""
    return jax.tree.map(lambda a: a.reshape((K, a.shape[0] // K) + a.shape[1:]), params_mamba)


def forward_train(params, cfg: ArchConfig, batch):
    tokens = batch["tokens"]
    B, S = tokens.shape
    K = n_invocations(cfg)
    x = nn.shard_act(nn.embed_lookup(tokens, params["embed"]), ("dp", None, None))
    e0 = x
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    sp = params["shared"]
    mamba_k = _stack_mamba(params["mamba"], K)

    def superblock(x, inp):
        fuse, mp = inp
        x = nn.shard_act(x, ("dp", None, None))
        h = nn.dense(jnp.concatenate([x, e0], axis=-1), fuse)
        h, _ = _shared_block_train(sp, h, cfg, pos)
        x = x + h

        def mamba_step(x, lp):
            y, _ = mamba2.mamba_block(lp, x, cfg)
            return nn.shard_act(y, ("dp", None, None)), None

        x, _ = jax.lax.scan(mamba_step, x, mp)
        return x, None

    sb = jax.checkpoint(superblock) if cfg.remat else superblock
    x, _ = jax.lax.scan(sb, x, (params["fuse_proj"], mamba_k))
    x = nn.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = nn.dense(x, params["unembed"])
    loss = nn.sharded_xent(logits, batch["labels"])
    return loss, {"xent": loss}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, B: int, S: int, dtype=jnp.bfloat16) -> dict:
    from .transformer import cache_len
    K = n_invocations(cfg)
    d_inner, nh, hd_s, ds = mamba2.dims(cfg)
    Smax = cache_len(S)
    return {
        "k": jnp.zeros((K, B, Smax, cfg.n_kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((K, B, Smax, cfg.n_kv_heads, cfg.hd), dtype),
        "ssm": jnp.zeros((cfg.n_layers, B, nh, hd_s, ds), jnp.float32),
        "conv": jnp.zeros((cfg.n_layers, B, cfg.conv_width - 1, d_inner + 2 * ds), jnp.float32),
        "length": jnp.zeros((B,), jnp.int32),
    }


def forward_prefill(params, cfg: ArchConfig, batch):
    from .transformer import cache_len
    tokens = batch["tokens"]
    B, S = tokens.shape
    K = n_invocations(cfg)
    Smax = cache_len(S)
    x = nn.shard_act(nn.embed_lookup(tokens, params["embed"]), ("dp", None, None))
    e0 = x
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    sp = params["shared"]
    mamba_k = _stack_mamba(params["mamba"], K)

    def superblock(x, inp):
        fuse, mp = inp
        h = nn.dense(jnp.concatenate([x, e0], axis=-1), fuse)
        h, (k, v) = _shared_block_train(sp, h, cfg, pos)
        x = x + h

        def mamba_step(x, lp):
            y, (ssm, conv) = mamba2.mamba_block(lp, x, cfg)
            return y, (ssm, conv)

        x, (ssms, convs) = jax.lax.scan(mamba_step, x, mp)
        pad = [(0, 0), (0, Smax - S), (0, 0), (0, 0)]
        return x, (jnp.pad(k, pad).astype(jnp.bfloat16),
                   jnp.pad(v, pad).astype(jnp.bfloat16), ssms, convs)

    sb = jax.checkpoint(superblock) if cfg.remat else superblock
    x, (ks, vs, ssms, convs) = jax.lax.scan(sb, x, (params["fuse_proj"], mamba_k))
    x = nn.rms_norm(x[:, -1], params["final_norm"], cfg.norm_eps)
    logits = nn.dense(x, params["unembed"])
    L = cfg.n_layers
    cache = {"k": ks, "v": vs,
             "ssm": ssms.reshape((L,) + ssms.shape[2:]),
             "conv": convs.reshape((L,) + convs.shape[2:]),
             "length": jnp.full((B,), S, jnp.int32)}
    return logits, cache


def forward_decode(params, cfg: ArchConfig, cache, token, positions=None):
    B = token.shape[0]
    K = n_invocations(cfg)
    per = cfg.shared_attn_every
    x = nn.embed_lookup(token, params["embed"])
    e0 = x
    length = cache["length"]
    pos = length[:, None]
    sp = params["shared"]
    mamba_k = _stack_mamba(params["mamba"], K)
    ssm_k = cache["ssm"].reshape((K, per) + cache["ssm"].shape[1:])
    conv_k = cache["conv"].reshape((K, per) + cache["conv"].shape[1:])
    hd = cfg.hd

    def superblock(x, inp):
        fuse, mp, kc, vc, ssm_p, conv_p = inp
        h = nn.dense(jnp.concatenate([x, e0], axis=-1), fuse)
        a = nn.rms_norm(h[:, None], sp["attn_norm"], cfg.norm_eps)
        q = apply_rope(nn.dense(a, sp["wq"]).reshape(B, 1, cfg.n_heads, hd), pos, cfg.rope_theta)
        k = apply_rope(nn.dense(a, sp["wk"]).reshape(B, 1, cfg.n_kv_heads, hd), pos, cfg.rope_theta)
        v = nn.dense(a, sp["wv"]).reshape(B, 1, cfg.n_kv_heads, hd)
        onehot = (jnp.arange(kc.shape[1])[None, :] == length[:, None])
        kc = jnp.where(onehot[:, :, None, None], k[:, 0][:, None].astype(kc.dtype), kc)
        vc = jnp.where(onehot[:, :, None, None], v[:, 0][:, None].astype(vc.dtype), vc)
        o = decode_attention(q[:, 0], kc, vc, length + 1)
        h = h + nn.dense(o.reshape(B, -1), sp["wo"])
        m = nn.rms_norm(h[:, None], sp["mlp_norm"], cfg.norm_eps)
        h = h + nn.swiglu(m, sp["w_gate"], sp["w_up"], sp["w_down"])[:, 0]
        x = x + h

        def mamba_step(x, inp2):
            lp, ssm, conv = inp2
            y, (ssm, conv) = mamba2.mamba_decode_step(lp, x, cfg, ssm, conv)
            return y, (ssm, conv)

        x, (ssms, convs) = jax.lax.scan(mamba_step, x, (mp, ssm_p, conv_p))
        return x, (kc, vc, ssms, convs)

    x, (ks, vs, ssms, convs) = jax.lax.scan(
        superblock, x, (params["fuse_proj"], mamba_k, cache["k"], cache["v"], ssm_k, conv_k))
    x = nn.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = nn.dense(x, params["unembed"])
    L = cfg.n_layers
    new_cache = {"k": ks, "v": vs,
                 "ssm": ssms.reshape((L,) + ssms.shape[2:]),
                 "conv": convs.reshape((L,) + convs.shape[2:]),
                 "length": length + 1}
    return logits, new_cache
