"""Attention machinery: RoPE / M-RoPE, chunked flash attention, decode path.

Pure-JAX chunked attention (lax.scan over q- and kv-chunks with online
softmax) keeps HLO size and activation memory O(chunk) at 32k contexts.
Known cost: causal masking is applied with ``where`` rather than skipping
upper-triangle chunk pairs, so attention HLO FLOPs are ~2x the ideal causal
count — recorded in the roofline notes; the block-skip belongs to a TPU
splash-attention kernel (a §Perf hillclimb item), not the reference path.

Decode attention is a single-token stable-softmax gather over the KV cache;
its sequence-sharded variant (flash-decoding with psum-combine) lives in
dist/flash_decode.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _pick_chunk(s: int, target: int) -> int:
    """Largest divisor of s that is <= target (whisper's 1500-frame encoder
    context is not a power of two; 1500 -> 500)."""
    c = min(target, s)
    while s % c:
        c -= 1
    return c


# §Perf knob: skip strictly-upper-triangle chunk pairs in causal attention.
# The masked-`where` reference computes all nq*nk chunk pairs (~2x the ideal
# causal FLOPs); skip mode unrolls the outer q loop (HLO grows O(nq)) and
# scans only the <= qi kv chunks, halving attention FLOPs + dot traffic.
_CAUSAL_SKIP = False


def set_causal_skip(on: bool) -> None:
    global _CAUSAL_SKIP
    _CAUSAL_SKIP = on


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return theta ** (-jnp.arange(0, head_dim // 2, dtype=jnp.float32) / (head_dim // 2))


def apply_rope(x: jax.Array, pos: jax.Array, theta: float = 1e4) -> jax.Array:
    """x: (B, S, H, D), pos: (B, S) int32."""
    d2 = x.shape[-1] // 2
    ang = pos[:, :, None].astype(jnp.float32) * rope_freqs(x.shape[-1], theta)  # (B,S,d2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :d2].astype(jnp.float32), x[..., d2:].astype(jnp.float32)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def apply_mrope(x: jax.Array, pos3: jax.Array, sections: tuple[int, int, int],
                theta: float = 1e4) -> jax.Array:
    """Qwen2-VL multimodal RoPE. pos3: (B, 3, S) (t/h/w position ids); the
    head_dim/2 frequency slots are split into ``sections`` (t,h,w), each slot
    rotating by its section's position id."""
    d2 = x.shape[-1] // 2
    assert sum(sections) == d2, (sections, d2)
    sec_of = jnp.repeat(jnp.arange(3), jnp.array(sections), total_repeat_length=d2)  # (d2,)
    pos = jnp.take_along_axis(pos3, sec_of[None, :, None].repeat(pos3.shape[0], 0),
                              axis=1)  # -> (B, d2, S) gathering per-slot section
    ang = pos.transpose(0, 2, 1).astype(jnp.float32) * rope_freqs(x.shape[-1], theta)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :d2].astype(jnp.float32), x[..., d2:].astype(jnp.float32)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked flash attention (train / prefill)
# ---------------------------------------------------------------------------

def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
                    q_chunk: int = 512, kv_chunk: int = 512) -> jax.Array:
    """q: (B, Sq, H, D); k, v: (B, Sk, KVH, D) with H % KVH == 0 (GQA).

    Online-softmax double scan; f32 accumulators; output (B, Sq, H, D) in
    q.dtype.
    """
    from . import nn as _nn
    B, Sq, H, D = q.shape
    _, Sk, KVH, _ = k.shape
    G = H // KVH
    scale = D ** -0.5
    q_chunk = _pick_chunk(Sq, q_chunk)
    kv_chunk = _pick_chunk(Sk, kv_chunk)
    nq, nk = Sq // q_chunk, Sk // kv_chunk

    # §Perf bf16 mode: q/k/v and the probability operand of the second dot
    # stay bf16 (halving dot-adjacent HBM traffic and any kv replication
    # gathers); softmax statistics and the context accumulator remain f32.
    bf16 = _nn.bf16_matmul_output() and q.dtype == jnp.bfloat16
    cdt = jnp.bfloat16 if bf16 else jnp.float32

    qs = q.reshape(B, nq, q_chunk, KVH, G, D).astype(cdt)
    ks = k.reshape(B, nk, kv_chunk, KVH, D).astype(cdt)
    vs = v.reshape(B, nk, kv_chunk, KVH, D).astype(cdt)

    q_pos = jnp.arange(Sq).reshape(nq, q_chunk)
    k_pos = jnp.arange(Sk).reshape(nk, kv_chunk)

    def kv_step_for(qb, qp):
        def kv_step(carry, ki):
            m, l, acc = carry
            kb, vb, kp = ki
            s = jnp.einsum("bqhgd,bkhd->bqhgk", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            if causal:
                s = jnp.where(qp[None, :, None, None, None] >= kp[None, None, None, None, :],
                              s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bqhgk,bkhd->bqhgd", p.astype(cdt), vb,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None
        return kv_step

    def init_carry():
        return (jnp.full((B, q_chunk, KVH, G), NEG_INF, jnp.float32),
                jnp.zeros((B, q_chunk, KVH, G), jnp.float32),
                jnp.zeros((B, q_chunk, KVH, G, D), jnp.float32))

    if causal and _CAUSAL_SKIP and nq == nk and Sq == Sk:
        # unrolled outer loop: q chunk qi only visits kv chunks <= qi
        outs = []
        for qi in range(nq):
            qb, qp = qs[:, qi], q_pos[qi]
            (m, l, acc), _ = jax.lax.scan(
                kv_step_for(qb, qp), init_carry(),
                (ks[:, : qi + 1].swapaxes(0, 1), vs[:, : qi + 1].swapaxes(0, 1),
                 k_pos[: qi + 1]))
            outs.append(acc / jnp.maximum(l, 1e-30)[..., None])
        return jnp.stack(outs, 1).reshape(B, Sq, H, D).astype(q.dtype)

    def q_step(_, qi):
        qb, qp = qi  # (B, qc, KVH, G, D), (qc,)
        (m, l, acc), _ = jax.lax.scan(
            kv_step_for(qb, qp), init_carry(),
            (ks.swapaxes(0, 1), vs.swapaxes(0, 1), k_pos))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out

    _, outs = jax.lax.scan(q_step, None, (qs.swapaxes(0, 1), q_pos))  # (nq, B, qc, KVH, G, D)
    return outs.swapaxes(0, 1).reshape(B, Sq, H, D).astype(q.dtype)


def attention_reference(q, k, v, *, causal=True):
    """Naive O(S^2) oracle for tests."""
    B, Sq, H, D = q.shape
    _, Sk, KVH, _ = k.shape
    G = H // KVH
    qf = q.reshape(B, Sq, KVH, G, D).astype(jnp.float32) * D ** -0.5
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qf, k.astype(jnp.float32))
    if causal:
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqhgk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, D).astype(q.dtype)


def prefix_attention(q: jax.Array, k_pre: jax.Array, v_pre: jax.Array,
                     prefix_len: jax.Array, k_suf: jax.Array,
                     v_suf: jax.Array) -> jax.Array:
    """Suffix-prefill attention against a cached prefix (prefix sharing).

    q: (B, Sq, H, D) — queries for the *suffix* tokens, already RoPE'd at
    their absolute positions ``prefix_len + i``; k_pre/v_pre:
    (B, Sk, KVH, D) — the gathered prefix KV cache, valid below
    ``prefix_len`` (B,); k_suf/v_suf: (B, Sq, KVH, D) — the suffix's own
    fresh K/V. One softmax over [masked prefix | causal suffix]. Suffixes
    are a page bucket long, so the naive masked O(Sq*(Sk+Sq)) f32 form is
    the right tool — no chunking.
    """
    B, Sq, H, D = q.shape
    KVH = k_suf.shape[2]
    G = H // KVH
    qf = q.reshape(B, Sq, KVH, G, D).astype(jnp.float32) * D ** -0.5
    s_pre = jnp.einsum("bqhgd,bkhd->bqhgk", qf, k_pre.astype(jnp.float32))
    pre_valid = jnp.arange(k_pre.shape[1])[None, :] < prefix_len[:, None]
    s_pre = jnp.where(pre_valid[:, None, None, None, :], s_pre, NEG_INF)
    s_suf = jnp.einsum("bqhgd,bkhd->bqhgk", qf, k_suf.astype(jnp.float32))
    causal = jnp.arange(Sq)[:, None] >= jnp.arange(Sq)[None, :]
    s_suf = jnp.where(causal[None, :, None, None, :], s_suf, NEG_INF)
    p = jax.nn.softmax(jnp.concatenate([s_pre, s_suf], axis=-1), axis=-1)
    vcat = jnp.concatenate([v_pre, v_suf], axis=1).astype(jnp.float32)
    o = jnp.einsum("bqhgk,bkhd->bqhgd", p, vcat)
    return o.reshape(B, Sq, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode attention (single new token vs. KV cache)
# ---------------------------------------------------------------------------

def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     length: jax.Array) -> jax.Array:
    """q: (B, H, D); caches: (B, Smax, KVH, D); length: (B,) valid prefix.

    Stable softmax over the valid prefix only. Returns (B, H, D).
    """
    B, H, D = q.shape
    KVH = k_cache.shape[2]
    G = H // KVH
    qf = q.reshape(B, KVH, G, D).astype(jnp.float32) * D ** -0.5
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, k_cache.astype(jnp.float32))
    valid = jnp.arange(k_cache.shape[1])[None, :] < length[:, None]  # (B, Smax)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    o = o / jnp.sum(p, axis=-1, keepdims=True)
    return o.reshape(B, H, D).astype(q.dtype)
