"""Mixture-of-Experts FFN (dbrx 16e/top-4, llama4-scout 16e/top-1).

GSPMD-style grouped dispatch/combine einsums with capacity limiting:
tokens are partitioned into groups (one group per data shard at production
batch sizes), each group dispatches into per-expert capacity slots
C = ceil(g * top_k * cf / E); overflow tokens drop (standard Switch-style).

Expert weights are sharded on the model axis (EP); dispatch tensors are
sharded on the data axis by construction of the grouping, so the all-to-all
pattern materializes as XLA-inserted collectives over the einsums.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from . import nn

DP = "fsdp"
TP = "tp"

GROUP_TOKENS = 2048


def moe_defs(cfg: ArchConfig) -> dict:
    L, d, f, E = cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": nn.Param((L, d, E), (None, DP, None), dtype=jnp.float32),
        "we_gate": nn.Param((L, E, d, f), (None, TP, DP, None)),
        "we_up": nn.Param((L, E, d, f), (None, TP, DP, None)),
        "we_down": nn.Param((L, E, f, d), (None, TP, None, DP)),
    }


def _group_size(T: int) -> int:
    g = min(GROUP_TOKENS, T)
    while T % g:
        g -= 1
    return g


def moe_apply(lp: dict, h: jax.Array, cfg: ArchConfig):
    """h: (B, S, d) -> (out (B, S, d), aux load-balance loss)."""
    B, S, d = h.shape
    E, k, cf = cfg.n_experts, cfg.top_k, cfg.capacity_factor
    T = B * S
    g = _group_size(T)
    G = T // g
    C = max(1, int(g * k * cf / E))

    x = h.reshape(G, g, d)
    scores = jax.nn.softmax(nn.dense(x, lp["router"]).astype(jnp.float32), axis=-1)  # (G,g,E)
    vals, idx = jax.lax.top_k(scores, k)                       # (G,g,k)
    vals = vals / jnp.maximum(jnp.sum(vals, -1, keepdims=True), 1e-9)
    m = jax.nn.one_hot(idx, E, dtype=jnp.float32)              # (G,g,k,E)

    # position of each (token, slot) within its expert's capacity
    flat = m.reshape(G, g * k, E)
    pos = (jnp.cumsum(flat, axis=1) - flat).reshape(G, g, k, E)
    pos = jnp.sum(pos * m, axis=-1)                            # (G,g,k)
    keep = (pos < C).astype(jnp.float32)
    oh_pos = jax.nn.one_hot(pos, C, dtype=jnp.float32) * keep[..., None]  # (G,g,k,C)

    dispatch = jnp.einsum("gtke,gtkc->gtec", m, oh_pos)
    combine = jnp.einsum("gtk,gtke,gtkc->gtec", vals, m, oh_pos)

    xin = jnp.einsum("gtec,gtd->egcd", dispatch.astype(h.dtype), x)      # (E,G,C,d)
    h1 = jnp.einsum("egcd,edf->egcf", xin, lp["we_gate"].astype(h.dtype))
    h2 = jnp.einsum("egcd,edf->egcf", xin, lp["we_up"].astype(h.dtype))
    act = jax.nn.silu(h1) * h2
    out_e = jnp.einsum("egcf,efd->egcd", act, lp["we_down"].astype(h.dtype))
    y = jnp.einsum("gtec,egcd->gtd", combine.astype(h.dtype), out_e)

    # Switch-style load-balance loss
    frac_tokens = jnp.mean(m.sum(2), axis=1)                   # (G,E)
    frac_probs = jnp.mean(scores, axis=1)                      # (G,E)
    aux = E * jnp.mean(jnp.sum(frac_tokens * frac_probs, axis=-1))
    return y.reshape(B, S, d), aux
