"""Minimal functional NN substrate: pytree params + logical sharding specs.

No flax/haiku on this box, so the framework carries its own module system:

  * a model is a pair of pure functions over a nested-dict param pytree;
  * every parameter is declared as a :class:`Param` (shape, dtype, init,
    *logical* axis names); ``init_tree`` materializes arrays, ``spec_tree``
    materializes the matching PartitionSpec pytree;
  * logical axes ("fsdp", "tp", None) are resolved against a concrete mesh by
    dist/sharding.py, with replicate-if-indivisible fallbacks, so the same
    model definition runs on a laptop mesh and the (pod, data, model)
    production mesh.

Layers are stacked [L, ...] and applied with lax.scan so compiled HLO size is
independent of depth (critical for 88-layer dry-runs).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Initializer = Callable[[jax.Array, tuple[int, ...], Any], jax.Array]

# ---------------------------------------------------------------------------
# Activation sharding constraints
#
# Model code is mesh-agnostic; the step builder installs a sharder that
# resolves logical axes ("dp"/"tp") against the concrete mesh. Without these
# constraints the auto-partitioner is free to pick batch-replicated layouts
# (observed: full-batch f32 FFN partial-sum all-reduces over the FSDP axis).
# ---------------------------------------------------------------------------

_ACT_SHARDER: Callable[[jax.Array, tuple], jax.Array] | None = None

# Matmul output precision policy (§Perf optimization, default = baseline):
# f32 dot outputs put the TP partial-sum all-reduces and all flash-attention
# score/context tensors on the wire/HBM in 4 bytes; bf16 outputs halve both
# (MXU accumulation stays f32 internally on TPU). Set by the step builders /
# dry-run --opt flag so baseline and optimized variants are both measurable.
_BF16_MATMUL_OUT = False


def set_bf16_matmul_output(on: bool) -> None:
    global _BF16_MATMUL_OUT
    _BF16_MATMUL_OUT = on


def bf16_matmul_output() -> bool:
    return _BF16_MATMUL_OUT


def set_act_sharder(fn: Callable[[jax.Array, tuple], jax.Array] | None) -> None:
    global _ACT_SHARDER
    _ACT_SHARDER = fn


def shard_act(x: jax.Array, logical: tuple) -> jax.Array:
    """Apply an activation sharding constraint (identity when no mesh)."""
    if _ACT_SHARDER is None:
        return x
    return _ACT_SHARDER(x, logical)


# ---------------------------------------------------------------------------
# Gradient-boundary taps
#
# Model code marks where each parameter group's cotangents become final
# (grad_tap at the use sites); the overlap-reduce step builder installs
# dist/bucketed_reduce.grad_boundary here so those cotangents are pinned as
# independent scheduling units for the per-bucket compressed reduce. With no
# tap installed (the default, and every non-overlap path) it is a no-op.
# ---------------------------------------------------------------------------

_GRAD_TAP: Callable[[Any, str], Any] | None = None


def set_grad_tap(fn: Callable[[Any, str], Any] | None) -> None:
    global _GRAD_TAP
    _GRAD_TAP = fn


def grad_tap(tree: Any, name: str = "") -> Any:
    """Mark a parameter-group gradient boundary (identity unless installed)."""
    if _GRAD_TAP is None:
        return tree
    return _GRAD_TAP(tree, name)


@dataclasses.dataclass(frozen=True)
class Param:
    shape: tuple[int, ...]
    logical: tuple[str | None, ...]      # logical axis per dim: "fsdp"|"tp"|None
    init: str = "normal"                 # "normal"|"zeros"|"ones"|"embed"|"scaled"
    dtype: Any = jnp.bfloat16
    fan_in_axes: tuple[int, ...] | None = None  # for "scaled": which dims are fan-in

    def materialize(self, key: jax.Array) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        if self.init == "embed":
            return (jax.random.normal(key, self.shape, jnp.float32) * 0.02).astype(self.dtype)
        if self.init in ("normal", "scaled"):
            fan_axes = self.fan_in_axes
            if fan_axes is None:
                fan_axes = (len(self.shape) - 2,) if len(self.shape) >= 2 else (0,)
            fan_in = 1
            for a in fan_axes:
                fan_in *= self.shape[a]
            std = (2.0 / max(fan_in, 1)) ** 0.5 if self.init == "scaled" else fan_in ** -0.5
            return (jax.random.normal(key, self.shape, jnp.float32) * std).astype(self.dtype)
        raise ValueError(f"unknown init {self.init!r}")


def is_param(x: Any) -> bool:
    return isinstance(x, Param)


def init_tree(defs: Any, key: jax.Array) -> Any:
    """Materialize a nested dict of Param declarations into arrays."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_param)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, [p.materialize(k) for p, k in zip(leaves, keys)])


def spec_tree(defs: Any) -> Any:
    """Matching pytree of logical-axis tuples (resolved to PartitionSpec later)."""
    return jax.tree.map(lambda p: p.logical, defs, is_leaf=is_param)


def param_count(params: Any) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# Stateless layer math
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale.astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * scale.astype(x.dtype) + bias.astype(x.dtype)


def dense(x: jax.Array, w: jax.Array) -> jax.Array:
    """x (..., d_in) @ w (d_in, d_out) in the param dtype, f32 accumulation."""
    pref = (jnp.bfloat16 if (_BF16_MATMUL_OUT and x.dtype == jnp.bfloat16)
            else jnp.float32)
    return jax.lax.dot_general(x, w.astype(x.dtype),
                               (((x.ndim - 1,), (0,)), ((), ())),
                               preferred_element_type=pref).astype(x.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    return dense(jax.nn.silu(dense(x, w_gate)) * dense(x, w_up), w_down)


def gelu_mlp(x: jax.Array, w_up: jax.Array, b_up: jax.Array,
             w_down: jax.Array, b_down: jax.Array) -> jax.Array:
    h = jax.nn.gelu(dense(x, w_up) + b_up.astype(x.dtype))
    return dense(h, w_down) + b_down.astype(x.dtype)


def embed_lookup(tokens: jax.Array, table: jax.Array) -> jax.Array:
    """Gather-based embedding (vocab sharded on tp -> XLA turns this into
    a masked one-hot + psum under SPMD; fine for the dry-run)."""
    return jnp.take(table, tokens, axis=0)


def sharded_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Token-mean cross entropy, numerically stable in f32.

    Written with plain reductions so XLA inserts the tp-axis collectives for
    vocab-sharded logits automatically.
    """
    lf = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(lf, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)
