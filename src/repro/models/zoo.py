"""Uniform model API over all assigned architecture families.

``Model`` bundles the family's init/apply functions behind one interface:

    model = zoo.build(cfg)
    params = model.init(key)
    loss, aux = model.train_loss(params, batch)
    logits, cache = model.prefill(params, batch)
    logits, cache = model.decode(params, cache, token)
    specs = model.input_specs(shape)       # ShapeDtypeStructs + logical shardings

``input_specs`` implements the brief's stub rule: vlm/audio frontends supply
precomputed embeddings / position ids as inputs rather than raw pixels/audio.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from . import nn, rwkv6, transformer, whisper, zamba2

DP = "dp"    # batch/activation axis -> ("pod", "data")
TP = "tp"


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    defs: Callable[[], dict]
    train_loss: Callable[..., Any]
    prefill: Callable[..., Any]
    decode: Callable[..., Any]
    make_cache: Callable[..., dict]
    # page-native decode over the serve/kvpool layout (transformer families
    # with plain k/v/length caches only; None elsewhere)
    decode_paged: Callable[..., Any] | None = None
    # suffix prefill against cached prefix K/V (the kvpool prefix-sharing
    # admission path); same family gate as decode_paged
    prefill_suffix: Callable[..., Any] | None = None

    def init(self, key: jax.Array) -> dict:
        return nn.init_tree(self.defs(), key)

    def param_specs(self) -> dict:
        return nn.spec_tree(self.defs())

    def abstract_params(self) -> dict:
        return jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), self.defs(),
            is_leaf=nn.is_param)

    def param_count(self) -> int:
        import math
        return sum(math.prod(p.shape)
                   for p in jax.tree.leaves(self.defs(), is_leaf=nn.is_param))

    def active_param_count(self) -> int:
        """MoE: params touched per token (for MODEL_FLOPS = 6·N_active·D)."""
        import math
        cfg = self.cfg
        total = self.param_count()
        if not cfg.n_experts:
            return total
        defs = self.defs()
        expert = sum(math.prod(p.shape)
                     for name, p in defs["layers"].items()
                     if name in ("we_gate", "we_up", "we_down"))
        return total - expert + expert * cfg.top_k // cfg.n_experts

    # ------------------------------------------------------------------
    # input specs (ShapeDtypeStruct stand-ins; the dry-run lowers on these)
    # ------------------------------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> tuple[dict, dict]:
        """Returns (structs, logical shardings) for the step inputs."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32, bf16 = jnp.int32, jnp.bfloat16
        if shape.kind in ("train", "prefill"):
            specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
            shards = {"tokens": (DP, None)}
            if shape.kind == "train":
                specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
                shards["labels"] = (DP, None)
            if cfg.mrope_sections is not None:
                specs["positions"] = jax.ShapeDtypeStruct((B, 3, S), i32)
                shards["positions"] = (DP, None, None)
            if cfg.family == "audio":
                specs["audio_embeds"] = jax.ShapeDtypeStruct((B, cfg.n_audio_ctx, cfg.d_model), bf16)
                shards["audio_embeds"] = (DP, None, None)
            return specs, shards
        # decode: one new token against a cache of length S
        specs = {"token": jax.ShapeDtypeStruct((B,), i32)}
        shards = {"token": (DP,)}
        if cfg.mrope_sections is not None:
            specs["positions"] = jax.ShapeDtypeStruct((B, 3, 1), i32)
            shards["positions"] = (DP, None, None)
        return specs, shards

    def cache_specs(self, shape: ShapeConfig) -> tuple[dict, dict]:
        """Abstract cache (ShapeDtypeStruct) + logical shardings for decode."""
        cache = jax.eval_shape(lambda: self.make_cache(shape.global_batch, shape.seq_len))
        return cache, cache_shardings(self.cfg, cache)


def cache_shardings(cfg: ArchConfig, cache: dict) -> dict:
    """Logical shardings for cache pytrees.

    KV caches are sequence-sharded on the TP axis (flash-decoding layout,
    DESIGN.md §5) and batch-sharded on DP; recurrent states shard heads on TP.
    """
    out = {}
    for name, leaf in cache.items():
        nd = leaf.ndim if hasattr(leaf, "ndim") else 0
        if name in ("k", "v"):           # (L, B, Smax, KVH, hd)
            out[name] = (None, DP, TP, None, None)
        elif name in ("xk", "xv"):       # whisper cross KV (L, B, ctx, H, hd)
            out[name] = (None, DP, None, TP, None)
        elif name == "ssm":              # (L, B, nh, hd, ds)
            out[name] = (None, DP, TP, None, None)
        elif name == "wkv":              # (L, B, nh, hdk, hdv)
            out[name] = (None, DP, TP, None, None)
        elif name == "conv":             # (L, B, W-1, C)
            out[name] = (None, DP, None, TP)
        elif name in ("sh_a", "sh_f"):   # (L, B, d)
            out[name] = (None, DP, None)
        elif name == "length":
            out[name] = (DP,)
        else:
            out[name] = tuple([None] * nd)
    return out


def build(cfg: ArchConfig) -> Model:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        mod = transformer
        defs = lambda: transformer.model_defs(cfg)
        make_cache = lambda B, S: transformer.init_cache(cfg, B, S)
    elif fam == "hybrid":
        mod = zamba2
        defs = lambda: zamba2.model_defs(cfg)
        make_cache = lambda B, S: zamba2.init_cache(cfg, B, S)
    elif fam == "ssm":
        mod = rwkv6
        defs = lambda: rwkv6.model_defs(cfg)
        make_cache = lambda B, S: rwkv6.init_cache(cfg, B, S)
    elif fam == "audio":
        mod = whisper
        defs = lambda: whisper.model_defs(cfg)
        make_cache = lambda B, S: whisper.init_cache(cfg, B, S)
    else:
        raise ValueError(f"unknown family {fam!r}")

    decode_paged = None
    prefill_suffix = None
    if fam in ("dense", "moe") or (fam == "vlm" and cfg.mrope_sections is None):
        decode_paged = (lambda params, pages, token, use_kernels=False:
                        transformer.forward_decode_paged(
                            params, cfg, pages, token, use_kernels=use_kernels))
        prefill_suffix = (lambda params, prefix, batch:
                          transformer.forward_prefill_suffix(params, cfg,
                                                             prefix, batch))

    return Model(
        cfg=cfg,
        defs=defs,
        train_loss=lambda params, batch: mod.forward_train(params, cfg, batch),
        prefill=lambda params, batch: mod.forward_prefill(params, cfg, batch),
        decode=lambda params, cache, token, positions=None:
            mod.forward_decode(params, cfg, cache, token, positions),
        make_cache=make_cache,
        decode_paged=decode_paged,
        prefill_suffix=prefill_suffix,
    )
