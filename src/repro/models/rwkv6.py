"""RWKV-6 "Finch": attention-free with data-dependent per-channel decay.

Time-mix WKV state is per head S in R^{hd x hd}:
    S_t = diag(w_t) S_{t-1} + k_t^T v_t,      w_t = exp(-exp(lora(x_t)))
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

Chunked evaluation (chunk c=16): within a chunk the pairwise decay factors
exp(La[t-1] - La[s]) (s <= t-1, cumulative log-decay La) are formed as an
explicit (c, c, hd) tensor — exponents are ordered differences of a
monotonically decreasing sequence, hence <= 0 and exp is safe/exact in f32.
This is the TPU-shaped analogue of FLA's tiled CUDA kernels; c=16 keeps the
pairwise tensor small and MXU-aligned.

Simplifications vs. the full release (faithfulness ledger, DESIGN.md):
static learned token-shift mixing coefficients (RWKV-5 style) for r/k/v/g;
the decay w keeps its full data-dependent LoRA (the Finch hallmark).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from . import nn

DP = "fsdp"
TP = "tp"

CHUNK = 16
LORA_R = 64


def dims(cfg: ArchConfig):
    hd = cfg.rwkv_head_dim
    return cfg.d_model // hd, hd


def rwkv_defs(cfg: ArchConfig) -> dict:
    L, d = cfg.n_layers, cfg.d_model
    nh, hd = dims(cfg)
    return {
        "ln_att": nn.Param((L, d), (None, None), init="ones"),
        "mix_r": nn.Param((L, d), (None, None), init="zeros", dtype=jnp.float32),
        "mix_k": nn.Param((L, d), (None, None), init="zeros", dtype=jnp.float32),
        "mix_v": nn.Param((L, d), (None, None), init="zeros", dtype=jnp.float32),
        "mix_g": nn.Param((L, d), (None, None), init="zeros", dtype=jnp.float32),
        "mix_w": nn.Param((L, d), (None, None), init="zeros", dtype=jnp.float32),
        "wr": nn.Param((L, d, d), (None, DP, TP)),
        "wk": nn.Param((L, d, d), (None, DP, TP)),
        "wv": nn.Param((L, d, d), (None, DP, TP)),
        "wg": nn.Param((L, d, d), (None, DP, TP)),
        "w_base": nn.Param((L, d), (None, TP), init="zeros", dtype=jnp.float32),
        "w_lora_a": nn.Param((L, d, LORA_R), (None, DP, None)),
        "w_lora_b": nn.Param((L, LORA_R, d), (None, None, TP), init="zeros"),
        "bonus_u": nn.Param((L, nh, hd), (None, TP, None), init="zeros", dtype=jnp.float32),
        "ln_out": nn.Param((L, d), (None, TP), init="ones"),
        "wo": nn.Param((L, d, d), (None, TP, DP)),
        "ln_ffn": nn.Param((L, d), (None, None), init="ones"),
        "mix_fk": nn.Param((L, d), (None, None), init="zeros", dtype=jnp.float32),
        "mix_fr": nn.Param((L, d), (None, None), init="zeros", dtype=jnp.float32),
        "fk": nn.Param((L, d, cfg.d_ff), (None, DP, TP)),
        "fv": nn.Param((L, cfg.d_ff, d), (None, TP, DP)),
        "fr": nn.Param((L, d, d), (None, DP, TP)),
    }


def _shift(x: jax.Array, prev: jax.Array | None) -> jax.Array:
    """Token shift: x_{t-1} with carry-in ``prev`` (B, d) or zeros."""
    first = jnp.zeros_like(x[:, :1]) if prev is None else prev[:, None].astype(x.dtype)
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def _mix(x, xp, mu):
    m = jax.nn.sigmoid(mu)[None, None, :]
    return (x.astype(jnp.float32) * m + xp.astype(jnp.float32) * (1 - m)).astype(x.dtype)


def _wkv_chunked(r, k, v, la, u, s0):
    """r/k/v: (B,S,nh,hd); la: (B,S,nh,hd) log-decay (<=0); u: (nh,hd);
    s0: (B,nh,hd,hd). Returns (o (B,S,nh,hd), s_final)."""
    B, S, nh, hd = r.shape
    c = min(CHUNK, S)
    pad = (-S) % c
    if pad:
        # state-neutral padding: k=0 contributes nothing, log-decay 0 keeps
        # the state unchanged; padded outputs are sliced off below
        zero = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v, la = zero(r), zero(k), zero(v), zero(la)
        S = S + pad
    n = S // c
    rs = r.astype(jnp.float32).reshape(B, n, c, nh, hd)
    ks = k.astype(jnp.float32).reshape(B, n, c, nh, hd)
    vs = v.astype(jnp.float32).reshape(B, n, c, nh, hd)
    las = la.reshape(B, n, c, nh, hd)

    def chunk_step(s, inp):
        rc, kc, vc, lac = inp  # (B,c,nh,hd)
        La = jnp.cumsum(lac, axis=1)                         # inclusive
        La_ex = La - lac                                     # exclusive (= La[t-1])
        # inter-chunk: o_t += (r_t * exp(La_ex[t])) @ s
        r_dec = rc * jnp.exp(La_ex)
        o = jnp.einsum("bthd,bhde->bthe", r_dec, s)
        # intra-chunk strict lower triangle, pairwise per channel
        diff = La_ex[:, :, None] - La[:, None, :]            # (B,t,s,nh,hd)
        tri = jnp.tril(jnp.ones((c, c), bool), k=-1)
        P = jnp.where(tri[None, :, :, None, None], jnp.exp(diff), 0.0)
        score = jnp.einsum("bthd,bshd,btshd->btsh", rc, kc, P)
        o = o + jnp.einsum("btsh,bshe->bthe", score, vc)
        # current-token bonus
        diag = jnp.einsum("bthd,hd,bthd->bth", rc, u, kc)
        o = o + diag[..., None] * vc
        # carry state to chunk end
        decay_to_end = jnp.exp(La[:, -1:] - La)              # (B,c,nh,hd)
        s = jnp.exp(La[:, -1])[..., None] * s + \
            jnp.einsum("bshd,bshe->bhde", kc * decay_to_end, vc)
        return s, o

    s, os_ = jax.lax.scan(chunk_step, s0.astype(jnp.float32),
                          (rs.swapaxes(0, 1), ks.swapaxes(0, 1),
                           vs.swapaxes(0, 1), las.swapaxes(0, 1)))
    out = os_.swapaxes(0, 1).reshape(B, S, nh, hd)
    return (out[:, : S - pad] if pad else out), s


def _decay_log(lp, xw: jax.Array) -> jax.Array:
    """Data-dependent log-decay (<= 0): -exp(base + lora(x))."""
    lora = nn.dense(jnp.tanh(nn.dense(xw, lp["w_lora_a"])), lp["w_lora_b"])
    return -jnp.exp(jnp.clip(lp["w_base"][None, None] + lora.astype(jnp.float32), -8.0, 4.0))


def time_mix(lp, x, cfg, wkv_state=None, shift_state=None):
    """x: (B,S,d) -> (out, (wkv_state, last_token))."""
    B, S, d = x.shape
    nh, hd = dims(cfg)
    h = nn.layer_norm(x, lp["ln_att"], jnp.zeros_like(lp["ln_att"]), cfg.norm_eps)
    hp = _shift(h, shift_state)
    r = nn.dense(_mix(h, hp, lp["mix_r"]), lp["wr"]).reshape(B, S, nh, hd)
    k = nn.dense(_mix(h, hp, lp["mix_k"]), lp["wk"]).reshape(B, S, nh, hd)
    v = nn.dense(_mix(h, hp, lp["mix_v"]), lp["wv"]).reshape(B, S, nh, hd)
    g = nn.dense(_mix(h, hp, lp["mix_g"]), lp["wg"])
    la = _decay_log(lp, _mix(h, hp, lp["mix_w"])).reshape(B, S, nh, hd)
    s0 = jnp.zeros((B, nh, hd, hd), jnp.float32) if wkv_state is None else wkv_state
    o, s = _wkv_chunked(r, k, v, la, lp["bonus_u"], s0)
    o = o.reshape(B, S, d).astype(x.dtype)
    o = nn.rms_norm(o, lp["ln_out"], cfg.norm_eps) * jax.nn.silu(g)
    return x + nn.dense(o, lp["wo"]), (s, h[:, -1])


def channel_mix(lp, x, cfg, shift_state=None):
    h = nn.layer_norm(x, lp["ln_ffn"], jnp.zeros_like(lp["ln_ffn"]), cfg.norm_eps)
    hp = _shift(h, shift_state)
    kx = _mix(h, hp, lp["mix_fk"])
    rx = _mix(h, hp, lp["mix_fr"])
    kk = jnp.square(jax.nn.relu(nn.dense(kx, lp["fk"])))
    out = jax.nn.sigmoid(nn.dense(rx, lp["fr"])) * nn.dense(kk, lp["fv"])
    return x + out, h[:, -1]


def rwkv_block(lp, x, cfg, states=None):
    """states: (wkv, att_shift, ffn_shift) or None."""
    wkv, sh_a, sh_f = states if states is not None else (None, None, None)
    x, (wkv, sh_a) = time_mix(lp, x, cfg, wkv, sh_a)
    x, sh_f = channel_mix(lp, x, cfg, sh_f)
    return x, (wkv, sh_a, sh_f)


def rwkv_decode_step(lp, x, cfg, states):
    """Single token: x (B, d); exact recurrence via the chunked path with S=1."""
    wkv, sh_a, sh_f = states
    y, (wkv, sh_a) = time_mix(lp, x[:, None], cfg, wkv, sh_a)
    y, sh_f = channel_mix(lp, y, cfg, sh_f)
    return y[:, 0], (wkv, sh_a, sh_f)


# ---------------------------------------------------------------------------
# Full-model wrappers (embed + blocks + head)
# ---------------------------------------------------------------------------

def model_defs(cfg: ArchConfig) -> dict:
    return {
        "embed": nn.Param((cfg.vocab, cfg.d_model), (None, TP), init="embed"),
        "ln0": nn.Param((cfg.d_model,), (None,), init="ones"),
        "blocks": rwkv_defs(cfg),
        "final_norm": nn.Param((cfg.d_model,), (None,), init="ones"),
        "unembed": nn.Param((cfg.d_model, cfg.vocab), (DP, TP)),
    }


def forward_train(params, cfg: ArchConfig, batch):
    tokens = batch["tokens"]
    x = nn.rms_norm(nn.embed_lookup(tokens, params["embed"]), params["ln0"], cfg.norm_eps)
    x = nn.shard_act(x, ("dp", None, None))

    def body(x, lp):
        y, _ = rwkv_block(lp, x, cfg)
        return nn.shard_act(y, ("dp", None, None)), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["blocks"])
    x = nn.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = nn.dense(x, params["unembed"])
    loss = nn.sharded_xent(logits, batch["labels"])
    return loss, {"xent": loss}


def init_cache(cfg: ArchConfig, B: int, S: int, dtype=jnp.bfloat16) -> dict:
    nh, hd = dims(cfg)
    L, d = cfg.n_layers, cfg.d_model
    return {
        "wkv": jnp.zeros((L, B, nh, hd, hd), jnp.float32),
        "sh_a": jnp.zeros((L, B, d), jnp.float32),
        "sh_f": jnp.zeros((L, B, d), jnp.float32),
        "length": jnp.zeros((B,), jnp.int32),
    }


def forward_prefill(params, cfg: ArchConfig, batch):
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = nn.rms_norm(nn.embed_lookup(tokens, params["embed"]), params["ln0"], cfg.norm_eps)
    x = nn.shard_act(x, ("dp", None, None))

    def body(x, lp):
        y, (wkv, sh_a, sh_f) = rwkv_block(lp, x, cfg)
        return (nn.shard_act(y, ("dp", None, None)),
                (wkv, sh_a.astype(jnp.float32), sh_f.astype(jnp.float32)))

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, (wkvs, sas, sfs) = jax.lax.scan(body_fn, x, params["blocks"])
    x = nn.rms_norm(x[:, -1], params["final_norm"], cfg.norm_eps)
    logits = nn.dense(x, params["unembed"])
    cache = {"wkv": wkvs, "sh_a": sas, "sh_f": sfs,
             "length": jnp.full((B,), S, jnp.int32)}
    return logits, cache


def forward_decode(params, cfg: ArchConfig, cache, token, positions=None):
    x = nn.rms_norm(nn.embed_lookup(token, params["embed"]), params["ln0"], cfg.norm_eps)

    def body(x, inp):
        lp, wkv, sa, sf = inp
        y, (wkv, sa, sf) = rwkv_decode_step(lp, x, cfg, (wkv, sa, sf))
        return y, (wkv, sa.astype(jnp.float32), sf.astype(jnp.float32))

    x, (wkvs, sas, sfs) = jax.lax.scan(
        body, x, (params["blocks"], cache["wkv"], cache["sh_a"], cache["sh_f"]))
    x = nn.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = nn.dense(x, params["unembed"])
    return logits, {"wkv": wkvs, "sh_a": sas, "sh_f": sfs, "length": cache["length"] + 1}
