from . import attention, mamba2, moe, nn, rwkv6, transformer, whisper, zamba2, zoo  # noqa: F401
from .zoo import Model, build  # noqa: F401
