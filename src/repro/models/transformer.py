"""Unified decoder-only transformer: dense GQA, MoE FFN, and M-RoPE variants.

Covers the dense (glm4/internlm2/yi/mistral-large), vlm (qwen2-vl backbone)
and moe (dbrx/llama4-scout) assigned families. Configs capture the published
macro-architecture (depth/width/GQA/ff/vocab/experts); micro-variations that
do not affect systems behaviour (e.g. GLM4 partial-rotary fraction) are
normalized to a modern pre-RMSNorm + SwiGLU + full-RoPE decoder and noted in
DESIGN.md's faithfulness ledger.

Layer params are stacked [L, ...]; the forward pass scans over layers
(optionally rematerialized) so HLO size is depth-independent.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from . import moe as moe_mod
from . import nn
from .attention import (apply_mrope, apply_rope, decode_attention,
                        flash_attention, prefix_attention)

DP = "fsdp"
TP = "tp"


# ---------------------------------------------------------------------------
# Parameter declarations
# ---------------------------------------------------------------------------

def layer_defs(cfg: ArchConfig) -> dict:
    L, d, hd = cfg.n_layers, cfg.d_model, cfg.hd
    qd, kvd = cfg.n_heads * hd, cfg.n_kv_heads * hd
    defs = {
        "attn_norm": nn.Param((L, d), (None, None), init="ones"),
        "wq": nn.Param((L, d, qd), (None, DP, TP)),
        "wk": nn.Param((L, d, kvd), (None, DP, TP)),
        "wv": nn.Param((L, d, kvd), (None, DP, TP)),
        "wo": nn.Param((L, qd, d), (None, TP, DP)),
        "mlp_norm": nn.Param((L, d), (None, None), init="ones"),
    }
    if cfg.n_experts:
        defs.update(moe_mod.moe_defs(cfg))
        if cfg.shared_expert:
            defs.update({
                "ws_gate": nn.Param((L, d, cfg.d_ff), (None, DP, TP)),
                "ws_up": nn.Param((L, d, cfg.d_ff), (None, DP, TP)),
                "ws_down": nn.Param((L, cfg.d_ff, d), (None, TP, DP)),
            })
    else:
        defs.update({
            "w_gate": nn.Param((L, d, cfg.d_ff), (None, DP, TP)),
            "w_up": nn.Param((L, d, cfg.d_ff), (None, DP, TP)),
            "w_down": nn.Param((L, cfg.d_ff, d), (None, TP, DP)),
        })
    return defs


def model_defs(cfg: ArchConfig) -> dict:
    return {
        # vocab dim NOT sharded: XLA SPMD gather partitioning of a
        # vocab-sharded table CHECK-fails on the CPU backend; d-on-tp is the
        # robust layout (DESIGN.md faithfulness ledger)
        "embed": nn.Param((cfg.vocab, cfg.d_model), (None, TP), init="embed"),
        "layers": layer_defs(cfg),
        "final_norm": nn.Param((cfg.d_model,), (None,), init="ones"),
        "unembed": nn.Param((cfg.d_model, cfg.vocab), (DP, TP)),
    }


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _ffn(lp: dict, h: jax.Array, cfg: ArchConfig):
    """Returns (out, aux_loss)."""
    if cfg.n_experts:
        out, aux = moe_mod.moe_apply(lp, h, cfg)
        if cfg.shared_expert:
            out = out + nn.swiglu(h, lp["ws_gate"], lp["ws_up"], lp["ws_down"])
        return out, aux
    return nn.swiglu(h, lp["w_gate"], lp["w_up"], lp["w_down"]), jnp.float32(0)


def _qkv(lp: dict, h: jax.Array, cfg: ArchConfig, pos):
    B, S, _ = h.shape
    hd = cfg.hd
    q = nn.shard_act(nn.dense(h, lp["wq"]).reshape(B, S, cfg.n_heads, hd),
                     ("dp", None, "tp", None))
    k = nn.shard_act(nn.dense(h, lp["wk"]).reshape(B, S, cfg.n_kv_heads, hd),
                     ("dp", None, "tp", None))
    v = nn.shard_act(nn.dense(h, lp["wv"]).reshape(B, S, cfg.n_kv_heads, hd),
                     ("dp", None, "tp", None))
    if cfg.mrope_sections is not None:
        q = apply_mrope(q, pos, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, pos, cfg.mrope_sections, cfg.rope_theta)
    else:
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    return q, k, v


def _block_train(lp: dict, x: jax.Array, cfg: ArchConfig, pos) -> tuple[jax.Array, jax.Array]:
    B, S, _ = x.shape
    x = nn.shard_act(x, ("dp", None, None))
    h = nn.rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    q, k, v = _qkv(lp, h, cfg, pos)
    o = flash_attention(q, k, v, causal=True)
    x = x + nn.dense(o.reshape(B, S, -1), lp["wo"])
    h = nn.rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
    f, aux = _ffn(lp, h, cfg)
    return x + f, aux


def _positions(cfg: ArchConfig, batch: dict, S: int, B: int):
    if cfg.mrope_sections is not None:
        return batch.get("positions")  # (B, 3, S) provided by input_specs
    return jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))


def forward_train(params: dict, cfg: ArchConfig, batch: dict):
    """batch: tokens (B,S) [+ positions for vlm]. Returns (loss, aux).

    Parameter groups pass through ``nn.grad_tap`` at their use sites — the
    layer-boundary hooks of the overlapped bucketed reduce. The scanned
    layer stack is one boundary (its stacked cotangents all materialize when
    the backward scan finishes); embed / final_norm / unembed are their own
    (their cotangents exist last / early / first in the backward pass).
    Identity unless the overlap step builder installs a tap.
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = nn.shard_act(nn.embed_lookup(tokens, nn.grad_tap(params["embed"], "embed")),
                     ("dp", None, None))
    pos = _positions(cfg, batch, S, B)

    def body(x, lp):
        y, aux = _block_train(lp, x, cfg, pos)
        return nn.shard_act(y, ("dp", None, None)), aux

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, auxs = jax.lax.scan(body_fn, x, nn.grad_tap(params["layers"], "layers"))
    x = nn.rms_norm(x, nn.grad_tap(params["final_norm"], "final_norm"), cfg.norm_eps)
    logits = nn.shard_act(nn.dense(x, nn.grad_tap(params["unembed"], "unembed")),
                          ("dp", None, "tp"))
    loss = nn.sharded_xent(logits, batch["labels"])
    return loss + 0.01 * jnp.sum(auxs), {"xent": loss}


# ---------------------------------------------------------------------------
# Serving: prefill (cache build) and decode (single token)
# ---------------------------------------------------------------------------

CACHE_MARGIN = 128  # decode slots past the prefill length


def cache_len(S: int) -> int:
    return S + CACHE_MARGIN


def init_cache(cfg: ArchConfig, B: int, S: int, dtype=jnp.bfloat16) -> dict:
    Smax = cache_len(S)
    kv = (cfg.n_layers, B, Smax, cfg.n_kv_heads, cfg.hd)
    return {
        "k": jnp.zeros(kv, dtype),
        "v": jnp.zeros(kv, dtype),
        "length": jnp.zeros((B,), jnp.int32),
    }


def forward_prefill(params: dict, cfg: ArchConfig, batch: dict):
    """Returns (last-position logits, populated cache).

    Optional ``batch["lengths"]`` (B,) marks the true prompt lengths when
    ``tokens`` is right-padded (serve/kvpool pads prompts to page multiples
    so prefill compiles once per bucket, not once per prompt length): logits
    are taken at position ``lengths-1`` and the cache length is ``lengths``.
    Padded positions still write K/V, but causal masking keeps true-position
    outputs exact and decode masks the tail by length.
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    Smax = cache_len(S)
    x = nn.shard_act(nn.embed_lookup(tokens, params["embed"]), ("dp", None, None))
    pos = _positions(cfg, batch, S, B)

    def body(x, lp):
        Bq, Sq, _ = x.shape
        x = nn.shard_act(x, ("dp", None, None))
        h = nn.rms_norm(x, lp["attn_norm"], cfg.norm_eps)
        q, k, v = _qkv(lp, h, cfg, pos)
        o = flash_attention(q, k, v, causal=True)
        x = x + nn.dense(o.reshape(Bq, Sq, -1), lp["wo"])
        h = nn.rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
        f, _ = _ffn(lp, h, cfg)
        pad = [(0, 0), (0, Smax - Sq), (0, 0), (0, 0)]
        out = nn.shard_act(x + f, ("dp", None, None))
        return out, (nn.shard_act(jnp.pad(k, pad).astype(jnp.bfloat16),
                                  ("dp", "tp", None, None)),
                     nn.shard_act(jnp.pad(v, pad).astype(jnp.bfloat16),
                                  ("dp", "tp", None, None)))

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, (ks, vs) = jax.lax.scan(body_fn, x, params["layers"])
    if "lengths" in batch:
        lengths = batch["lengths"].astype(jnp.int32)
        idx = (lengths - 1)[:, None, None]
        x_last = jnp.take_along_axis(x, jnp.broadcast_to(idx, (B, 1, x.shape[-1])),
                                     axis=1)[:, 0]
    else:
        lengths = jnp.full((B,), S, jnp.int32)
        x_last = x[:, -1]
    x = nn.rms_norm(x_last, params["final_norm"], cfg.norm_eps)
    logits = nn.dense(x, params["unembed"])
    cache = {"k": ks, "v": vs, "length": lengths}
    return logits, cache


def forward_prefill_suffix(params: dict, cfg: ArchConfig, prefix: dict,
                           batch: dict):
    """Prefill only a prompt *suffix* against cached prefix K/V (the
    prefix-sharing admission path in serve/kvpool).

    prefix: ``{"k": (L, B, Sk, KVH, hd), "v": ..., "length": (B,)}`` — a
    gathered KV view valid below ``length`` (absolute positions 0..length);
    batch: ``tokens`` (B, Ssuf) right-padded suffix, ``lengths`` (B,) true
    suffix lengths. Suffix queries are RoPE'd at their absolute positions
    ``prefix_len + i`` — sharing is only valid for position-aligned
    prefixes, which is exactly what the radix index guarantees.

    Returns (last-true-position logits, cache with the *suffix's own* K/V
    (L, B, Ssuf, KVH, hd) and total length prefix+suffix) — the pool writes
    the suffix K/V into pages; the prefix pages already exist. Padded
    positions write K/V but never influence true positions (prefix keys are
    length-masked, suffix keys causally behind them).
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = nn.shard_act(nn.embed_lookup(tokens, params["embed"]), ("dp", None, None))
    prefix_len = prefix["length"].astype(jnp.int32)
    pos = prefix_len[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]

    def body(x, per_layer):
        lp, kc, vc = per_layer                     # kc/vc: (B, Sk, KVH, hd)
        Bq, Sq, _ = x.shape
        x = nn.shard_act(x, ("dp", None, None))
        h = nn.rms_norm(x, lp["attn_norm"], cfg.norm_eps)
        q, k, v = _qkv(lp, h, cfg, pos)
        o = prefix_attention(q, kc, vc, prefix_len, k, v)
        x = x + nn.dense(o.reshape(Bq, Sq, -1), lp["wo"])
        h = nn.rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
        f, _ = _ffn(lp, h, cfg)
        out = nn.shard_act(x + f, ("dp", None, None))
        return out, (nn.shard_act(k.astype(jnp.bfloat16),
                                  ("dp", "tp", None, None)),
                     nn.shard_act(v.astype(jnp.bfloat16),
                                  ("dp", "tp", None, None)))

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, (ks, vs) = jax.lax.scan(body_fn, x,
                               (params["layers"], prefix["k"], prefix["v"]))
    lengths = batch["lengths"].astype(jnp.int32)
    idx = (lengths - 1)[:, None, None]
    x_last = jnp.take_along_axis(x, jnp.broadcast_to(idx, (B, 1, x.shape[-1])),
                                 axis=1)[:, 0]
    x = nn.rms_norm(x_last, params["final_norm"], cfg.norm_eps)
    logits = nn.dense(x, params["unembed"])
    return logits, {"k": ks, "v": vs, "length": prefix_len + lengths}


def forward_decode(params: dict, cfg: ArchConfig, cache: dict, token: jax.Array,
                   positions: jax.Array | None = None):
    """One decode step. token: (B,) int32. Returns (logits, new cache).

    Writes this step's K/V at index ``length`` then attends over the valid
    prefix (flash-decoding sharded variant in dist/flash_decode.py swaps in
    via the same interface).
    """
    B = token.shape[0]
    x = nn.embed_lookup(token, params["embed"])  # (B, d)
    length = cache["length"]
    if cfg.mrope_sections is not None:
        pos = positions if positions is not None else jnp.repeat(length[:, None], 3, 1)[:, :, None]
    else:
        pos = length[:, None]  # (B, 1)

    def body(x, per_layer):
        lp, kc, vc = per_layer
        h = nn.rms_norm(x[:, None], lp["attn_norm"], cfg.norm_eps)  # (B,1,d)
        q, k, v = _qkv(lp, h, cfg, pos)
        # insert new kv at position `length`
        onehot = (jnp.arange(kc.shape[1])[None, :] == length[:, None])  # (B,Smax)
        kc = jnp.where(onehot[:, :, None, None], k.astype(kc.dtype), kc)
        vc = jnp.where(onehot[:, :, None, None], v.astype(vc.dtype), vc)
        o = decode_attention(q[:, 0], kc, vc, length + 1)
        x = x + nn.dense(o.reshape(B, -1), lp["wo"])
        h = nn.rms_norm(x[:, None], lp["mlp_norm"], cfg.norm_eps)
        f, _ = _ffn(lp, h, cfg)
        return x + f[:, 0], (kc, vc)

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    x = nn.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = nn.dense(x, params["unembed"])
    return logits, {"k": ks, "v": vs, "length": length + 1}


def forward_decode_paged(params: dict, cfg: ArchConfig, pages: dict,
                         token: jax.Array, *, use_kernels: bool = False):
    """One decode step over a page-native KV view (serve/kvpool layout).

    pages: ``{"k": (L, B, P, ps, KVH, hd), "v": ..., "length": (B,)}`` as
    emitted by ``PagePool.gather_pages`` — attention runs per page via the
    flash-decoding partials (Pallas KV-tile kernel when ``use_kernels``),
    never materializing the contiguous ``seq_capacity``-wide cache. This
    step's K/V is folded into the softmax analytically (it sits at position
    ``length``, past every page) and returned to the caller for the pool
    append instead of being scattered into the gathered view.

    token: (B,) int32. Returns ``(logits, (k_new, v_new))`` with k_new/v_new
    (L, B, KVH, hd).
    """
    # runtime import: serve.kvpool imports the model zoo at package-import
    # time, so a module-level import here would be circular
    from repro.serve.kvpool import attention as paged_attn

    B = token.shape[0]
    x = nn.embed_lookup(token, params["embed"])  # (B, d)
    length = pages["length"]
    pos = length[:, None]  # (B, 1); mrope families are rejected by make_pool

    def body(x, per_layer):
        lp, kp, vp = per_layer          # kp/vp: (B, P, ps, KVH, hd)
        h = nn.rms_norm(x[:, None], lp["attn_norm"], cfg.norm_eps)  # (B,1,d)
        q, k, v = _qkv(lp, h, cfg, pos)
        o = paged_attn.paged_decode_attention(
            q[:, 0], kp, vp, length, k_new=k[:, 0], v_new=v[:, 0],
            use_kernels=use_kernels)
        x = x + nn.dense(o.reshape(B, -1), lp["wo"])
        h = nn.rms_norm(x[:, None], lp["mlp_norm"], cfg.norm_eps)
        f, _ = _ffn(lp, h, cfg)
        return x + f[:, 0], (k[:, 0], v[:, 0])

    x, (k_new, v_new) = jax.lax.scan(body, x,
                                     (params["layers"], pages["k"], pages["v"]))
    x = nn.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = nn.dense(x, params["unembed"])
    return logits, (k_new, v_new)
