"""repro.serve — the serving stack over FZ in-memory compression.

Architecture (paper §2.4, "in-memory compression" deployed):

  * ``engine.Engine`` — jit-cached prefill/decode steps plus two KV regimes:
      - the **whole-cache path** (``park``/``resume``: one monolithic FZ
        roundtrip per cache) — retained as the *parity oracle*: page-granular
        compression at a shared absolute bound reconstructs bit-identically
        to it (tests/test_kvpool.py);
      - the **paged pool path** (``Engine.serve``) — production-shaped.
  * ``kvpool`` — the pool subsystem:
      - *page size*: fixed token pages (``PoolConfig.page_size``) over all
        layers, stored in one preallocated device slab of
        ``PoolConfig.num_pages`` physical slots;
      - *tiering policy*: hot pages raw; pages unwritten for
        ``cold_after`` scheduler steps are FZ-compressed in place (fixed-shape
        containers, one shared absolute error bound, single jit trace), which
        frees their slots — reads decompress transiently, writes promote back
        to raw;
      - *scheduler states*: WAITING -> RUNNING (admit = prefill into raw
        pages) -> PARKED (preempt = compress-park, nothing recomputed) ->
        RUNNING (resume = promote tail page) -> FINISHED, driven by
        ``ContinuousBatcher`` with priority-aware admission and
        lowest-priority/latest-arrival victim selection under memory pressure.

Capacity accounting is built on the FZ container's ``used_bytes()`` (actual
payload) and ``wire_bytes()`` (capacity-sized footprint); the pool reports
both against the raw demand of the same live pages.
"""
from . import kvpool  # noqa: F401
from .engine import (Engine, KVCompressionConfig, cache_bytes,  # noqa: F401
                     compress_cache, compressed_cache_bytes, decompress_cache)
from .kvpool import (ContinuousBatcher, PagePool, PoolConfig,  # noqa: F401
                     Request, TieredPolicy, TraceStats)
