from .engine import Engine, KVCompressionConfig, compress_cache, decompress_cache  # noqa: F401
