"""Serving engine: batched prefill + greedy decode with FZ-compressed KV.

Two cache regimes, both the paper's "in-memory compression" use case (§2.4 —
FZ is fast enough to (de)compress live device-resident state at serving
latency, which cuSZ-class compressors cannot do):

  * **whole-cache parking** (``park``/``resume``): one monolithic cache is
    FZ-compressed between decode sessions. This is the original toy path,
    kept as the *parity oracle* for the pool below — at a shared absolute
    error bound a page-granular roundtrip reconstructs bit-identically to it.
  * **paged pool** (``serve``): production-shaped path. KV lives as
    fixed-size token pages in a preallocated slab (serve/kvpool); cold pages
    are FZ-compressed in place, preemption is compress-park, and a
    continuous-batching scheduler drives admit/step/preempt/resume. Decode
    gathers a sequence's pages into the fixed-width cache and runs the
    model's decode step on it — or, with ``PoolConfig.use_kernels``, keeps
    the page layout and runs the Pallas flash-decode kernel end-to-end
    (``decode_step_paged`` over ``PagePool.gather_pages``).

Measured in benchmarks/bench_kvcache.py: memory ratio, park/resume latency,
and the logit deviation of decode steps running on a reconstructed cache.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro import obs
from repro.core import fz
from repro.models import zoo

from . import kvpool


@dataclasses.dataclass(frozen=True)
class KVCompressionConfig:
    enabled: bool = False
    eb: float = 1e-3               # error bound on K/V values
    eb_mode: str = "rel"           # "rel" (per-leaf range) | "abs"
    min_leaf_size: int = 65_536
    use_kernels: bool = False      # route FZ hot stages through Pallas kernels
    kernel_mode: str = "auto"      # "auto" tuned | "fused" megakernels | "staged"

    def fz_config(self) -> fz.FZConfig:
        return fz.FZConfig(eb=self.eb, eb_mode=self.eb_mode,
                           exact_outliers=False, use_kernels=self.use_kernels,
                           kernel_mode=self.kernel_mode)


def compress_cache(cache: dict, kcfg: KVCompressionConfig) -> dict:
    """Compress the float KV leaves (k/v/xk/xv/wkv/ssm); bookkeeping stays raw.

    Leaves keep their own dtype on the way in: ``fz.compress`` casts to
    float32 internally but records the source dtype, so a bfloat16 cache's
    containers report bfloat16 ``raw_bytes`` (honest compression ratios)."""
    fzc = kcfg.fz_config()
    out = {}
    for name, leaf in cache.items():
        if (hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype, jnp.floating)
                and leaf.size >= kcfg.min_leaf_size):
            flat = leaf.reshape(-1)
            out[name] = ("fz", fz.compress(flat, fzc), leaf.shape, str(leaf.dtype))
        else:
            out[name] = ("raw", leaf, None, None)
    return out


def decompress_cache(comp: dict, kcfg: KVCompressionConfig) -> dict:
    fzc = kcfg.fz_config()
    out = {}
    for name, (codec, payload, shape, dtype) in comp.items():
        if codec == "fz":
            out[name] = fz.decompress(payload, fzc).reshape(shape).astype(dtype)
        else:
            out[name] = payload
    return out


def cache_bytes(cache: dict) -> int:
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(cache))


def compressed_cache_bytes(comp: dict) -> int:
    total = 0
    for name, (codec, payload, _, _) in comp.items():
        if codec == "fz":
            total += int(payload.used_bytes())
        else:
            total += payload.size * payload.dtype.itemsize
    return total


class Engine:
    """Batched serving session: whole-cache oracle path + paged pool path."""

    def __init__(self, model: zoo.Model, params, *,
                 kv_compress: KVCompressionConfig | None = None,
                 pool: kvpool.PoolConfig | None = None):
        self.model = model
        self.params = params
        self.kcfg = kv_compress or KVCompressionConfig()
        self.pool_cfg = pool
        # all step functions are jitted once here; re-wrapping per call
        # (the old prefill bug) would retrace on every request
        self._decode = jax.jit(lambda p, c, t: model.decode(p, c, t))
        self._prefill = jax.jit(lambda p, b: model.prefill(p, b))
        # suffix prefill for the kvpool prefix-sharing admission path; the
        # scheduler probes `callable(engine.prefill_suffix)`, so families
        # without it leave the attribute None and sharing degrades to full
        # prefill. One trace per (prefix width, suffix bucket) pair.
        self.prefill_suffix = None
        if model.prefill_suffix is not None:
            self._prefill_suffix = jax.jit(
                lambda p, prefix, b: model.prefill_suffix(p, prefix, b))
            self.prefill_suffix = (
                lambda prefix, batch:
                self._prefill_suffix(self.params, prefix, batch))
        self._decode_paged = None
        if pool is not None and model.decode_paged is not None:
            uk = pool.use_kernels          # static: one trace per knob value
            if uk:
                # tuned dispatch: the repro.tune cached winner (or, untuned,
                # the kernel fallback) decides whether paged decode runs the
                # Pallas flash-decode kernel or the jnp partials — resolved
                # here, once, so the jit below keys on the concrete choice
                from repro import tune
                n_attn = tune.attn_cache_elems(
                    pool.seq_capacity, model.cfg.n_kv_heads, model.cfg.hd)
                uk = tune.decode_attention_impl(n_attn, pool.dtype) == "kernel"
            self._decode_paged = jax.jit(
                lambda p, pages, t: model.decode_paged(p, pages, t,
                                                       use_kernels=uk))

    @property
    def paged_decode_enabled(self) -> bool:
        """True when ``serve`` should decode page-natively through the Pallas
        flash-decode kernel (PoolConfig.use_kernels mirrors FZConfig: it
        routes both the FZ hot stages and decode attention)."""
        return self._decode_paged is not None and self.pool_cfg.use_kernels

    def prefill(self, batch: dict):
        with obs.span("engine.prefill"):
            logits, cache = self._prefill(self.params, batch)
        return logits, cache

    def decode_step(self, cache: dict, tokens: jax.Array):
        """One decode step on an explicit cache (the pool's gathered view)."""
        with obs.span("engine.decode_step"):
            return self._decode(self.params, cache, tokens)

    def decode_step_paged(self, pages: dict, tokens: jax.Array):
        """One decode step on the page-native view (``PagePool.gather_pages``).

        Returns ``(logits, (k_new, v_new))`` — the step's K/V (L, B, KVH, hd)
        comes back to the caller for the pool append; it was already folded
        into the softmax analytically, so nothing is scattered into the
        gathered pages."""
        if self._decode_paged is None:
            raise ValueError("model/pool combination has no paged decode")
        with obs.span("engine.decode_step_paged"):
            return self._decode_paged(self.params, pages, tokens)

    # -- whole-cache parking (parity oracle for the pool) ----------------------

    def park(self, cache: dict) -> dict:
        """Compress a cache for in-memory parking (request preempted)."""
        assert self.kcfg.enabled
        with obs.span("engine.park"):
            return compress_cache(cache, self.kcfg)

    def resume(self, parked: dict) -> dict:
        with obs.span("engine.resume"):
            return decompress_cache(parked, self.kcfg)

    def generate(self, batch: dict, n_tokens: int, *, park_between: bool = False):
        """Greedy generation; optionally park/resume the cache each step to
        exercise the compressed path end-to-end."""
        logits, cache = self.prefill(batch)
        tokens = [jnp.argmax(logits, -1).astype(jnp.int32)]
        for _ in range(n_tokens - 1):
            if park_between and self.kcfg.enabled:
                cache = self.resume(self.park(cache))
            logits, cache = self._decode(self.params, cache, tokens[-1])
            tokens.append(jnp.argmax(logits, -1).astype(jnp.int32))
        return jnp.stack(tokens, axis=1), cache

    # -- paged pool path -------------------------------------------------------

    def make_pool(self) -> kvpool.PagePool:
        """Instantiate the paged KV pool for this model's cache geometry."""
        if self.pool_cfg is None:
            raise ValueError("Engine was built without a PoolConfig")
        cfg = self.model.cfg
        cache = jax.eval_shape(lambda: self.model.make_cache(1, 1))
        if set(cache) != {"k", "v", "length"} or cfg.mrope_sections is not None:
            raise NotImplementedError(
                f"paged KV pool supports plain k/v/length caches; "
                f"{cfg.arch_id} has {sorted(cache)}")
        return kvpool.PagePool(self.pool_cfg, n_layers=cfg.n_layers,
                               n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd)

    def serve(self, requests: list[kvpool.Request], *, max_batch: int = 2,
              pool: kvpool.PagePool | None = None):
        """Run a request trace through the pool with continuous batching.

        Returns ``(outputs, stats, pool)`` where outputs maps req_id to the
        generated token array.
        """
        pool = pool or self.make_pool()
        batcher = kvpool.ContinuousBatcher(self, pool, max_batch=max_batch)
        t0 = time.perf_counter()
        with obs.span("engine.serve", requests=len(requests)):
            outputs, stats = batcher.run(requests)
        dt = time.perf_counter() - t0
        n_tokens = sum(len(v) for v in outputs.values())
        obs.gauge("engine_serve_tokens").set(n_tokens)
        if dt > 0:
            obs.gauge("engine_serve_tokens_per_s").set(n_tokens / dt)
        return outputs, stats, pool
