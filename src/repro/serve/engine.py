"""Serving engine: batched prefill + greedy decode with optional FZ KV pages.

The KV-cache compression path is the paper's "in-memory compression" use case
(§2.4): after prefill the (huge) KV cache is FZ-compressed in device memory;
a decode session decompresses it once on resume. This models serve-time cache
parking / request swapping (vLLM-style preemption), where evicted sequences'
caches are held compressed instead of being recomputed.

Measured in benchmarks/bench_kvcache.py: memory ratio and the logit deviation
of decode steps running on a reconstructed cache.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import fz
from repro.models import zoo


@dataclasses.dataclass(frozen=True)
class KVCompressionConfig:
    enabled: bool = False
    eb: float = 1e-3               # relative error bound on K/V values
    min_leaf_size: int = 65_536

    def fz_config(self) -> fz.FZConfig:
        return fz.FZConfig(eb=self.eb, eb_mode="rel", exact_outliers=False,
                           use_kernels=False)


def compress_cache(cache: dict, kcfg: KVCompressionConfig) -> dict:
    """Compress the float KV leaves (k/v/xk/xv/wkv/ssm); bookkeeping stays raw."""
    fzc = kcfg.fz_config()
    out = {}
    for name, leaf in cache.items():
        if (hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype, jnp.floating)
                and leaf.size >= kcfg.min_leaf_size):
            flat = leaf.astype(jnp.float32).reshape(-1)
            out[name] = ("fz", fz.compress(flat, fzc), leaf.shape, str(leaf.dtype))
        else:
            out[name] = ("raw", leaf, None, None)
    return out


def decompress_cache(comp: dict, kcfg: KVCompressionConfig) -> dict:
    fzc = kcfg.fz_config()
    out = {}
    for name, (codec, payload, shape, dtype) in comp.items():
        if codec == "fz":
            out[name] = fz.decompress(payload, fzc).reshape(shape).astype(dtype)
        else:
            out[name] = payload
    return out


def cache_bytes(cache: dict) -> int:
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(cache))


def compressed_cache_bytes(comp: dict) -> int:
    total = 0
    for name, (codec, payload, _, _) in comp.items():
        if codec == "fz":
            total += int(payload.used_bytes())
        else:
            total += payload.size * payload.dtype.itemsize
    return total


class Engine:
    """Minimal batched serving session."""

    def __init__(self, model: zoo.Model, params, *, kv_compress: KVCompressionConfig | None = None):
        self.model = model
        self.params = params
        self.kcfg = kv_compress or KVCompressionConfig()
        self._decode = jax.jit(lambda p, c, t: model.decode(p, c, t))

    def prefill(self, batch: dict):
        logits, cache = jax.jit(self.model.prefill)(self.params, batch)
        return logits, cache

    def park(self, cache: dict) -> dict:
        """Compress a cache for in-memory parking (request preempted)."""
        assert self.kcfg.enabled
        return compress_cache(cache, self.kcfg)

    def resume(self, parked: dict) -> dict:
        return decompress_cache(parked, self.kcfg)

    def generate(self, batch: dict, n_tokens: int, *, park_between: bool = False):
        """Greedy generation; optionally park/resume the cache each step to
        exercise the compressed path end-to-end."""
        logits, cache = self.prefill(batch)
        tokens = [jnp.argmax(logits, -1).astype(jnp.int32)]
        for _ in range(n_tokens - 1):
            if park_between and self.kcfg.enabled:
                cache = self.resume(self.park(cache))
            logits, cache = self._decode(self.params, cache, tokens[-1])
            tokens.append(jnp.argmax(logits, -1).astype(jnp.int32))
        return jnp.stack(tokens, axis=1), cache
