"""Seeded trace-driven load generation for the kvpool serving benchmarks.

Replaces hand-built request lists with a reproducible model of production
traffic: Poisson arrivals (exponential inter-arrival gaps in scheduler
steps), a pool of prompt *templates* (system prompts / few-shot prefixes)
that a configurable fraction of requests reuse with a fresh per-user suffix
— the prefix-skewed mix the radix pool is built for — plus per-request
priorities and latency SLOs.

Everything is derived from one ``numpy`` generator seeded by
``TraceGenConfig.seed``: the same config always produces byte-identical
prompts, arrival times and priorities, so a trace replayed against pools in
different ``prefix_mode``\\ s isolates exactly the storage discipline
(scheduling is deterministic too — see policy/scheduler tie-breaks).

``latency_summary`` turns a finished trace's :class:`TraceStats` into the
report the benchmarks publish: p50/p99 time-to-first-token and inter-token
latency (in scheduler steps — the unit preemption stretches), and SLO
attainment when the config sets bounds.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .scheduler import Request, TraceStats


@dataclasses.dataclass(frozen=True)
class TraceGenConfig:
    seed: int = 0
    n_requests: int = 16
    vocab: int = 512
    arrival_rate: float = 1.0           # mean arrivals per scheduler step
    n_templates: int = 2
    template_len: tuple[int, int] = (12, 16)   # inclusive token range
    template_reuse: float = 0.6         # P(request starts from a template)
    suffix_len: tuple[int, int] = (2, 6)       # per-user tokens after template
    n_new: tuple[int, int] = (4, 8)            # decode lengths
    priorities: tuple[int, ...] = (0,)
    ttft_slo: int | None = None         # max acceptable TTFT (steps)
    itl_slo: int | None = None          # max acceptable per-token gap (steps)


def generate(cfg: TraceGenConfig) -> list[Request]:
    """One reproducible request trace: ``n_requests`` timed, prefix-skewed
    requests ordered by arrival."""
    rng = np.random.default_rng(cfg.seed)
    templates = [rng.integers(0, cfg.vocab,
                              (int(rng.integers(cfg.template_len[0],
                                                cfg.template_len[1] + 1)),),
                              dtype=np.int32)
                 for _ in range(cfg.n_templates)]
    reqs = []
    clock = 0.0
    for i in range(cfg.n_requests):
        clock += rng.exponential(1.0 / cfg.arrival_rate)
        suffix = rng.integers(0, cfg.vocab,
                              (int(rng.integers(cfg.suffix_len[0],
                                                cfg.suffix_len[1] + 1)),),
                              dtype=np.int32)
        if rng.random() < cfg.template_reuse:
            prompt = np.concatenate(
                [templates[int(rng.integers(len(templates)))], suffix])
        else:
            fresh = rng.integers(0, cfg.vocab,
                                 (int(rng.integers(cfg.template_len[0],
                                                   cfg.template_len[1] + 1)),),
                                 dtype=np.int32)
            prompt = np.concatenate([fresh, suffix])
        reqs.append(Request(
            req_id=i, tokens=prompt,
            n_new=int(rng.integers(cfg.n_new[0], cfg.n_new[1] + 1)),
            priority=int(rng.choice(np.asarray(cfg.priorities))),
            arrive_at=1 + int(clock)))
    return reqs


def _pct(xs: list[int], q: float) -> float:
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs else 0.0


def latency_summary(stats: TraceStats,
                    cfg: TraceGenConfig | None = None) -> dict:
    """p50/p99 TTFT + inter-token latency (scheduler steps) and, when the
    config carries SLOs, the fraction of requests meeting them."""
    ttfts = [t for t in stats.ttft_steps.values() if t is not None]
    itls = [g for gaps in stats.itl_steps.values() for g in gaps]
    out = {
        "ttft_p50": _pct(ttfts, 50), "ttft_p99": _pct(ttfts, 99),
        "itl_p50": _pct(itls, 50), "itl_p99": _pct(itls, 99),
    }
    if cfg is not None and cfg.ttft_slo is not None:
        out["ttft_slo_attained"] = (
            float(np.mean([t <= cfg.ttft_slo for t in ttfts])) if ttfts else 1.0)
    if cfg is not None and cfg.itl_slo is not None:
        per_req = [max(gaps) <= cfg.itl_slo
                   for gaps in stats.itl_steps.values() if gaps]
        out["itl_slo_attained"] = (float(np.mean(per_req)) if per_req else 1.0)
    return out
