"""repro.serve.kvpool — paged, prefix-shared, FZ-compressed KV-cache pool.

The subsystem that turns the compressor into serving capacity (paper §2.4,
"in-memory compression"), twice over: KV state lives as fixed-size token
pages in a preallocated device slab, cold pages FZ-compressed in place
(compression multiplier), and pages holding shared prompt prefixes are
*refcounted* and mapped into every reader at once (dedup multiplier).

Page states and refcount rules (pool.py holds the full contract):

  * a physical page is ``raw`` (backed by a slab slot) or ``compressed``
    (a fixed-shape FZ container, no slot); slots not backing a page are
    ``free`` — the three states partition the slab at all times;
  * ``Page.refs`` counts sequence mappings plus the radix tree's reference;
    a page with refs > 1 is immutable — any write (suffix prefill into a
    partially-matched tail, decode append to a tree-cached tail) first
    forks a private copy of just that page (copy-on-write);
  * the physical page is released when its last reference drops; the radix
    cache's references are dropped explicitly at end-of-trace drain.

Admission walks a radix tree over prompt token IDs (radix.py): the longest
position-aligned cached prefix maps onto existing pages — raw or
compressed, reads are tier-transparent — and only the unmatched suffix is
prefilled (``engine.prefill_suffix`` attends to the cached prefix K/V).
``PoolConfig.prefix_mode`` selects "radix" (shared pages), "copy" (same
matching, private page copies — the bit-parity twin), or "off" (the
non-shared pool).

The dedup read path: ``gather``/``gather_pages`` deduplicate cold page IDs
across all decode lanes before the single vmapped FZ decode, so a shared
cold container is decompressed at most once per scheduler step and fanned
out to every reader lane.

Modules:
  * ``pool``      — refcounted block allocator + page table
                    (:class:`PagePool`), CoW, dedup reads, byte accounting
                    that counts shared physical state once;
  * ``radix``     — the prefix tree (:class:`RadixIndex`), LRU eviction;
  * ``policy``    — tiering (cold-after-N), forced reclaim, victim selection
                    (:class:`TieredPolicy`), all deterministically ordered;
  * ``scheduler`` — :class:`ContinuousBatcher`: timed admission
                    (``Request.arrive_at``), suffix-prefill on prefix hits,
                    preempt/resume, per-request TTFT/ITL tracking;
  * ``tracegen``  — seeded Poisson/template load generator
                    (:func:`generate`) + SLO/percentile reporting
                    (:func:`latency_summary`);
  * ``attention`` — page-native decode attention built on the same
                    flash-decoding partials as ``dist.flash_decode``; with
                    ``use_kernels`` it runs the Pallas KV-tile kernel
                    (``kernels/flash_decode``) directly over the pool's
                    page layout (``PagePool.gather_pages``).

The whole-cache park/resume in ``serve.engine`` (compress_cache /
decompress_cache) is retained as the parity oracle: at a shared absolute
error bound, page-granular park -> resume is bit-identical to the
whole-cache roundtrip, and the "copy" pool is bit-identical to "radix" on
any trace (tests/test_kvpool.py, tests/test_kvpool_radix.py).
"""
from .attention import paged_decode_attention, pages_from_cache  # noqa: F401
from .policy import TieredPolicy  # noqa: F401
from .pool import (COMPRESSED, FREE, RAW, Page, PagePool, PoolConfig,  # noqa: F401
                   PoolStats)
from .radix import PrefixMatch, RadixIndex  # noqa: F401
from .scheduler import ContinuousBatcher, Request, SeqRecord, TraceStats  # noqa: F401
from .tracegen import TraceGenConfig, generate, latency_summary  # noqa: F401
