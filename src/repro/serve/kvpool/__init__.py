"""repro.serve.kvpool — paged, FZ-compressed KV-cache pool.

The subsystem that turns the compressor into serving capacity (paper §2.4,
"in-memory compression"): KV state lives as fixed-size token pages in a
preallocated device slab, hot pages raw, cold pages FZ-compressed in place,
and a continuous-batching scheduler whose preemption path is compress-park
rather than drop-and-recompute.

Modules:
  * ``pool``      — block allocator + page table (:class:`PagePool`), page
                    states raw|compressed|free, capacity accounting on
                    ``used_bytes()`` / ``wire_bytes()``;
  * ``policy``    — tiering (cold-after-N), forced reclaim, victim selection
                    (:class:`TieredPolicy`);
  * ``scheduler`` — :class:`ContinuousBatcher`: admit / step / preempt /
                    resume over a request trace;
  * ``attention`` — page-native decode attention built on the same
                    flash-decoding partials as ``dist.flash_decode``; with
                    ``use_kernels`` it runs the Pallas KV-tile kernel
                    (``kernels/flash_decode``) directly over the pool's
                    page layout (``PagePool.gather_pages``).

The whole-cache park/resume in ``serve.engine`` (compress_cache /
decompress_cache) is retained as the parity oracle: at a shared absolute
error bound, page-granular park -> resume is bit-identical to the
whole-cache roundtrip (tests/test_kvpool.py).
"""
from .attention import paged_decode_attention, pages_from_cache  # noqa: F401
from .policy import TieredPolicy  # noqa: F401
from .pool import COMPRESSED, FREE, RAW, Page, PagePool, PoolConfig, PoolStats  # noqa: F401
from .scheduler import ContinuousBatcher, Request, SeqRecord, TraceStats  # noqa: F401
