"""Continuous batching over the paged FZ KV pool.

vLLM-style serving loop at the scale of this repo: requests are admitted into
a fixed number of decode *lanes* (the decode batch width, so the decode step
compiles once), every step decodes one token for every running sequence, and
memory pressure is resolved by *compress-parking* — a preempted sequence's
pages are FZ-compressed in place and its lane freed; nothing is recomputed on
resume. State machine per request:

    WAITING --admit(prefill -> raw pages)--> RUNNING
    RUNNING --preempt(compress all pages)--> PARKED
    PARKED  --resume(promote tail page)----> RUNNING
    RUNNING --n_new tokens emitted---------> FINISHED

Scheduling order is (priority desc, arrival asc) for admission/resume and
lowest-priority / latest-arrival for preemption (policy.TieredPolicy.victim).
Every step also runs the routine cooling pass: pages unwritten for
``cold_after`` steps tier down to compressed, which is what creates capacity
for more concurrent sequences than the raw slab could hold.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .policy import TieredPolicy
from .pool import PagePool

WAITING, RUNNING, PARKED, FINISHED = "waiting", "running", "parked", "finished"


@dataclasses.dataclass(frozen=True)
class Request:
    req_id: int
    tokens: np.ndarray          # (S,) int32 prompt
    n_new: int                  # tokens to generate (incl. the prefill argmax)
    priority: int = 0           # higher wins admission / survives preemption


@dataclasses.dataclass
class SeqRecord:
    req: Request
    state: str = WAITING
    lane: int | None = None
    arrival: int = 0
    generated: list[int] = dataclasses.field(default_factory=list)
    last_token: int = 0


@dataclasses.dataclass
class TraceStats:
    decode_steps: int = 0
    admissions: int = 0
    preemptions: int = 0
    resumes: int = 0
    completed: int = 0
    tiered_pages: int = 0
    high_water_used_bytes: int = 0     # raw slab in use + compressed payloads
    high_water_demand_bytes: int = 0   # same live pages if held fully raw
    pool_compressions: int = 0
    pool_decompressions: int = 0


@jax.jit
def _extract_token(ks, vs, lane, pos):
    """Pull one lane's step-written K/V (L, KVH, hd) out of the decode cache."""
    return ks[:, lane, pos], vs[:, lane, pos]


@jax.jit
def _lane_kv(k_new, v_new, lane):
    """One lane's freshly-computed K/V (L, KVH, hd) from the paged decode."""
    return k_new[:, lane], v_new[:, lane]


class ContinuousBatcher:
    """admit / step / preempt / resume over a synthetic request trace."""

    def __init__(self, engine, pool: PagePool, *, max_batch: int = 2,
                 policy: TieredPolicy | None = None, max_steps: int = 10_000):
        self.engine = engine
        self.pool = pool
        self.max_batch = max_batch
        self.policy = policy or TieredPolicy(cold_after=pool.cfg.cold_after)
        self.max_steps = max_steps
        self.paged_decode = bool(getattr(engine, "paged_decode_enabled", False))
        self.lanes: list[int | None] = [None] * max_batch
        self.recs: dict[int, SeqRecord] = {}
        self.stats = TraceStats()

    # -- bookkeeping ----------------------------------------------------------

    def _running(self) -> dict[int, tuple[int, int]]:
        return {seq: (rec.req.priority, rec.arrival)
                for seq, rec in self.recs.items() if rec.state == RUNNING}

    def _protect(self) -> set[int]:
        return self.policy.tail_pages(self.pool, self.lanes)

    def _free_lane(self) -> int | None:
        for i, seq in enumerate(self.lanes):
            if seq is None:
                return i
        return None

    def _park(self, seq: int, step: int) -> None:
        rec = self.recs[seq]
        self.policy.park(self.pool, seq)
        self.lanes[rec.lane] = None
        rec.lane, rec.state = None, PARKED
        self.stats.preemptions += 1

    def _finish(self, seq: int, outputs: dict) -> None:
        rec = self.recs[seq]
        outputs[rec.req.req_id] = np.asarray(rec.generated[: rec.req.n_new],
                                             np.int32)
        self.pool.free_seq(seq)
        if rec.lane is not None:
            self.lanes[rec.lane] = None
        rec.lane, rec.state = None, FINISHED
        self.stats.completed += 1

    def _preempt_for(self, step: int, *, admitting_priority: int | None = None) -> bool:
        """Park the policy victim to relieve pressure; returns True if parked.

        ``admitting_priority`` set: pressure comes from *admission*, and only
        running sequences with strictly lower priority are eligible victims.
        ``None``: pressure comes from a running sequence's tail write — every
        running sequence is eligible, including (as a last resort) the one
        that needs the slot.
        """
        running = self._running()
        if admitting_priority is not None:
            running = {s: pa for s, pa in running.items()
                       if pa[0] < admitting_priority}
        victim = self.policy.victim(running)
        if victim is None:
            return False
        self._park(victim, step)
        return True

    # -- admission / resume ---------------------------------------------------

    def _admit(self, rec: SeqRecord, step: int, outputs: dict) -> bool:
        prompt = np.asarray(rec.req.tokens, np.int32)
        ps = self.pool.cfg.page_size
        n_pages = max(1, -(-len(prompt) // ps))
        while not self.policy.reclaim(self.pool, n_pages, self._protect()):
            if not self._preempt_for(step, admitting_priority=rec.req.priority):
                return False
        # pad the prompt to its page bucket so prefill compiles once per
        # bucket (max_pages_per_seq shapes), not once per prompt length;
        # "lengths" makes the model take logits at the true last position
        padded = np.zeros(n_pages * ps, np.int32)
        padded[: len(prompt)] = prompt
        logits, cache = self.engine.prefill(
            {"tokens": jnp.asarray(padded)[None],
             "lengths": jnp.asarray([len(prompt)], jnp.int32)})
        seq = rec.req.req_id
        if not self.pool.write_prefill(seq, cache["k"], cache["v"],
                                       len(prompt), step):
            return False
        lane = self._free_lane()
        tok = int(jnp.argmax(logits[0]))
        rec.generated, rec.last_token = [tok], tok
        rec.lane, rec.state, rec.arrival = lane, RUNNING, step
        self.lanes[lane] = seq
        self.stats.admissions += 1
        if len(rec.generated) >= rec.req.n_new:
            self._finish(seq, outputs)
        return True

    def _try_resume(self, rec: SeqRecord, step: int) -> bool:
        seq = rec.req.req_id
        if not self.policy.reclaim(self.pool, 1, self._protect()):
            return False
        lane = self._free_lane()
        rec.lane, rec.state = lane, RUNNING
        self.lanes[lane] = seq
        self.stats.resumes += 1
        return True

    # -- the step -------------------------------------------------------------

    def _secure_tails(self, step: int) -> None:
        """Guarantee every running sequence can take this step's token write."""
        while True:
            # each pending append consumes at most one slot (fresh tail page
            # or promotion of a compressed tail); reserve them all at once
            reserve = sum(self.pool.tail_slot_demand(seq)
                          for seq in self.lanes if seq is not None)
            if reserve == 0 or self.policy.reclaim(self.pool, reserve,
                                                   self._protect()):
                return
            if not self._preempt_for(step):
                return                    # stall guard in run() handles this

    def step(self, step: int, outputs: dict) -> bool:
        """One scheduler iteration; returns True if any progress was made."""
        progress = False
        # 1. routine cooling
        self.stats.tiered_pages += self.policy.tier(self.pool, step,
                                                    self._protect())
        # 2. resume parked, highest priority / oldest first
        for rec in sorted((r for r in self.recs.values() if r.state == PARKED),
                          key=lambda r: (-r.req.priority, r.arrival)):
            if self._free_lane() is None:
                break
            progress |= self._try_resume(rec, step)
        # 3. admit waiting
        for rec in sorted((r for r in self.recs.values() if r.state == WAITING),
                          key=lambda r: (-r.req.priority, r.req.req_id)):
            if self._free_lane() is None:
                break
            progress |= self._admit(rec, step, outputs)
        # 4. secure tail capacity (may compress-park under pressure)
        self._secure_tails(step)
        # 5. decode one token for every running lane. Two wirings:
        #    * reference — gather the contiguous fixed-width cache, run the
        #      model's own decode (writes K/V in place), extract the token;
        #    * paged kernel (engine.paged_decode_enabled) — keep the page
        #      layout (gather_pages), run the Pallas flash-decode step, and
        #      append the returned fresh K/V; nothing is ever scattered into
        #      a seq_capacity-wide cache.
        active = [(i, seq) for i, seq in enumerate(self.lanes) if seq is not None]
        if active:
            tokens = jnp.asarray(
                [self.recs[s].last_token if s is not None else 0
                 for s in self.lanes], jnp.int32)
            if self.paged_decode:
                pages = self.pool.gather_pages(self.lanes)
                logits, (k_new, v_new) = self.engine.decode_step_paged(pages,
                                                                       tokens)
            else:
                cache = self.pool.gather(self.lanes)
                logits, new_cache = self.engine.decode_step(cache, tokens)
            for lane, seq in active:
                rec = self.recs[seq]
                pos = self.pool.seq_len[seq]
                if self.paged_decode:
                    k_vec, v_vec = _lane_kv(k_new, v_new, lane)
                else:
                    k_vec, v_vec = _extract_token(new_cache["k"], new_cache["v"],
                                                  lane, pos)
                if not self.pool.append_token(seq, k_vec, v_vec, step):
                    raise RuntimeError("kvpool invariant: tail write failed "
                                       "after _secure_tails")
                tok = int(jnp.argmax(logits[lane]))
                rec.generated.append(tok)
                rec.last_token = tok
                if len(rec.generated) >= rec.req.n_new:
                    self._finish(seq, outputs)
            self.stats.decode_steps += 1
            progress = True
        # 6. accounting: the pool samples peaks at alloc/promote time (the
        # true maxima); mirror them into the trace stats
        self.stats.high_water_used_bytes = self.pool.stats.high_water_bytes
        self.stats.high_water_demand_bytes = self.pool.stats.high_water_demand_bytes
        return progress

    def run(self, requests: list[Request]) -> tuple[dict[int, np.ndarray],
                                                    TraceStats]:
        """Drive the full trace; returns ({req_id: tokens}, stats)."""
        ids = [r.req_id for r in requests]
        if len(set(ids)) != len(ids):
            raise ValueError("request ids must be unique")
        cfg = self.pool.cfg
        for r in requests:
            need = len(np.asarray(r.tokens)) + r.n_new - 1
            if need > cfg.seq_capacity:
                raise ValueError(
                    f"request {r.req_id}: prompt + n_new needs {need} token "
                    f"slots > seq_capacity {cfg.seq_capacity}")
            if -(-len(np.asarray(r.tokens)) // cfg.page_size) > cfg.num_pages:
                raise ValueError(
                    f"request {r.req_id}: prompt alone needs more pages than "
                    f"the {cfg.num_pages}-slot slab")
        self.recs = {r.req_id: SeqRecord(req=r) for r in requests}
        outputs: dict[int, np.ndarray] = {}
        stalled = 0
        for step in range(1, self.max_steps + 1):
            if all(r.state == FINISHED for r in self.recs.values()):
                break
            stalled = 0 if self.step(step, outputs) else stalled + 1
            if stalled > 2:
                raise RuntimeError(
                    "kvpool scheduler stalled: pool too small for this trace "
                    f"({self.pool.cfg.num_pages} pages, "
                    f"{len(self.recs)} requests)")
        else:
            raise RuntimeError("kvpool scheduler exceeded max_steps")
        self.stats.pool_compressions = self.pool.stats.compressions
        self.stats.pool_decompressions = self.pool.stats.decompressions
        return outputs, self.stats
