"""Continuous batching over the paged, prefix-shared FZ KV pool.

vLLM-style serving loop at the scale of this repo: requests arrive over time
(``Request.arrive_at``, in scheduler steps), are admitted into a fixed number
of decode *lanes* (the decode batch width, so the decode step compiles once),
every step decodes one token for every running sequence, and memory pressure
is resolved by *compress-parking* — a preempted sequence's pages are
FZ-compressed in place and its lane freed; nothing is recomputed on resume.
State machine per request:

    WAITING --admit(prefill -> raw pages)--> RUNNING
    RUNNING --preempt(compress all pages)--> PARKED
    PARKED  --resume(promote tail page)----> RUNNING
    RUNNING --n_new tokens emitted---------> FINISHED

Admission first walks the pool's radix prefix index: a hit maps the matched
prefix onto existing (possibly shared, possibly compressed) pages and only
the *suffix* is prefilled — ``engine.prefill_suffix`` computes K/V for the
unmatched tokens attending to the cached prefix, and the prompt's pages are
then cached in the tree for the next arrival. A miss (or ``prefix_mode
"off"``, or an engine without suffix prefill) takes the full-prefill path,
byte-for-byte the non-shared scheduler.

Scheduling order is fully deterministic, including under equal priority and
equal arrival: admission sorts by (priority desc, arrive_at asc, req_id asc),
resume by (priority desc, arrival asc, req_id asc), and preemption picks the
lowest priority / latest arrival / highest seq id victim — so trace-driven
benchmarks reproduce run-to-run.

Every step also runs the routine cooling pass: pages unwritten for
``cold_after`` steps tier down to compressed, which is what creates capacity
for more concurrent sequences than the raw slab could hold. Latency is
tracked in scheduler steps: TTFT (admission step minus arrival) and
inter-token gaps (preemption stretches them) land in ``TraceStats`` per
request for the SLO accounting in ``tracegen.latency_summary``.
"""
from __future__ import annotations

import dataclasses
import itertools

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.obs import sentinels

from .policy import TieredPolicy
from .pool import PagePool
from .radix import EMPTY_MATCH, PrefixMatch

WAITING, RUNNING, PARKED, FINISHED = "waiting", "running", "parked", "finished"


@dataclasses.dataclass(frozen=True)
class Request:
    req_id: int
    tokens: np.ndarray          # (S,) int32 prompt
    n_new: int                  # tokens to generate (incl. the prefill argmax)
    priority: int = 0           # higher wins admission / survives preemption
    arrive_at: int = 0          # scheduler step the request becomes admissible


@dataclasses.dataclass
class SeqRecord:
    req: Request
    state: str = WAITING
    lane: int | None = None
    arrival: int = 0            # admission step (preemption recency key)
    generated: list[int] = dataclasses.field(default_factory=list)
    last_token: int = 0
    ttft: int | None = None     # steps from arrive_at to the first token
    last_emit: int = 0          # step of the most recent token (ITL clock)
    itl: list[int] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class TraceStats:
    """Point-in-time snapshot of one batcher's serving counters.

    Like ``PoolStats``, no longer a live accumulator: the scheduler's
    counters live in the :mod:`repro.obs` registry (labeled
    ``batcher=<instance>``) and ``ContinuousBatcher.stats`` materializes
    this view — pool-derived fields straight from the pool's own snapshot,
    per-request latency dicts from plain batcher attrs.
    """
    decode_steps: int = 0
    admissions: int = 0
    preemptions: int = 0
    resumes: int = 0
    completed: int = 0
    tiered_pages: int = 0
    high_water_used_bytes: int = 0     # raw slab in use + compressed payloads
    high_water_demand_bytes: int = 0   # same live pages if held fully raw
    high_water_logical_bytes: int = 0  # per-seq mappings if raw and private
    pool_compressions: int = 0
    pool_decompressions: int = 0
    # prefix sharing
    prefix_hits: int = 0               # admissions that matched a cached prefix
    prefill_tokens: int = 0            # tokens actually pushed through prefill
    prefill_tokens_saved: int = 0      # prompt tokens served from the radix cache
    cow_promotions: int = 0            # shared-page writes forked to a copy
    shared_cold_reads_deduped: int = 0  # per-step cold decodes avoided by dedup
    decompress_dispatches: int = 0     # vmapped cold-read dispatches issued
    # latency (scheduler steps), per req_id — joined with SLOs in tracegen
    ttft_steps: dict[int, int] = dataclasses.field(default_factory=dict)
    itl_steps: dict[int, list[int]] = dataclasses.field(default_factory=dict)


# TraceStats fields backed by per-batcher registry counters
_SCHED_METRICS = {
    "decode_steps": "sched_decode_steps",
    "admissions": "sched_admissions",
    "preemptions": "sched_preemptions",
    "resumes": "sched_resumes",
    "completed": "sched_completed",
    "tiered_pages": "sched_tiered_pages",
    "prefix_hits": "sched_prefix_hits",
    "prefill_tokens": "sched_prefill_tokens",
    "prefill_tokens_saved": "sched_prefill_tokens_saved",
}

_batcher_ids = itertools.count()


@jax.jit
def _extract_token(ks, vs, lane, pos):
    """Pull one lane's step-written K/V (L, KVH, hd) out of the decode cache."""
    return ks[:, lane, pos], vs[:, lane, pos]


@jax.jit
def _lane_kv(k_new, v_new, lane):
    """One lane's freshly-computed K/V (L, KVH, hd) from the paged decode."""
    return k_new[:, lane], v_new[:, lane]


class ContinuousBatcher:
    """admit / step / preempt / resume over a (possibly timed) request trace."""

    def __init__(self, engine, pool: PagePool, *, max_batch: int = 2,
                 policy: TieredPolicy | None = None, max_steps: int = 10_000):
        self.engine = engine
        self.pool = pool
        self.max_batch = max_batch
        self.policy = policy or TieredPolicy(cold_after=pool.cfg.cold_after)
        self.max_steps = max_steps
        self.paged_decode = bool(getattr(engine, "paged_decode_enabled", False))
        # prefix sharing needs both the pool's radix index and an engine that
        # can prefill a suffix against cached prefix K/V; without either, the
        # loop is byte-for-byte the non-shared scheduler
        self.prefix = (pool.radix is not None
                       and callable(getattr(engine, "prefill_suffix", None)))
        self.lanes: list[int | None] = [None] * max_batch
        self.recs: dict[int, SeqRecord] = {}
        self._obs_id = f"batcher{next(_batcher_ids)}"
        self._ttft_steps: dict[int, int] = {}
        self._itl_steps: dict[int, list[int]] = {}

    # -- telemetry ------------------------------------------------------------

    def _count(self, name: str, n: int = 1) -> None:
        obs.counter(name, batcher=self._obs_id).inc(n)

    @property
    def stats(self) -> TraceStats:
        """Derived snapshot: registry counters + the pool's own snapshot."""
        vals = {}
        for field, name in _SCHED_METRICS.items():
            m = obs.DEFAULT.find(name, batcher=self._obs_id)
            vals[field] = int(m.value) if m is not None else 0
        ps = self.pool.stats
        return TraceStats(
            **vals,
            high_water_used_bytes=ps.high_water_bytes,
            high_water_demand_bytes=ps.high_water_demand_bytes,
            high_water_logical_bytes=ps.high_water_logical_bytes,
            pool_compressions=ps.compressions,
            pool_decompressions=ps.decompressions,
            cow_promotions=ps.cow_promotions,
            shared_cold_reads_deduped=ps.shared_cold_reads_deduped,
            decompress_dispatches=ps.decompress_dispatches,
            ttft_steps=dict(self._ttft_steps),
            itl_steps={k: list(v) for k, v in self._itl_steps.items()})

    # -- bookkeeping ----------------------------------------------------------

    def _running(self) -> dict[int, tuple[int, int]]:
        return {seq: (rec.req.priority, rec.arrival)
                for seq, rec in self.recs.items() if rec.state == RUNNING}

    def _protect(self) -> set[int]:
        return self.policy.tail_pages(self.pool, self.lanes)

    def _free_lane(self) -> int | None:
        for i, seq in enumerate(self.lanes):
            if seq is None:
                return i
        return None

    def _park(self, seq: int, step: int) -> None:
        rec = self.recs[seq]
        self.policy.park(self.pool, seq)
        self.lanes[rec.lane] = None
        rec.lane, rec.state = None, PARKED
        self._count("sched_preemptions")

    def _emit(self, rec: SeqRecord, tok: int, step: int) -> None:
        """Record one generated token + its latency sample."""
        if not rec.generated:
            rec.ttft = step - rec.req.arrive_at
        else:
            rec.itl.append(step - rec.last_emit)
        rec.generated.append(tok)
        rec.last_token, rec.last_emit = tok, step

    def _finish(self, seq: int, outputs: dict) -> None:
        rec = self.recs[seq]
        outputs[rec.req.req_id] = np.asarray(rec.generated[: rec.req.n_new],
                                             np.int32)
        self._ttft_steps[rec.req.req_id] = rec.ttft
        self._itl_steps[rec.req.req_id] = rec.itl[: rec.req.n_new - 1]
        self.pool.free_seq(seq)
        if rec.lane is not None:
            self.lanes[rec.lane] = None
        rec.lane, rec.state = None, FINISHED
        self._count("sched_completed")

    def _preempt_for(self, step: int, *, admitting_priority: int | None = None) -> bool:
        """Park the policy victim to relieve pressure; returns True if parked.

        ``admitting_priority`` set: pressure comes from *admission*, and only
        running sequences with strictly lower priority are eligible victims.
        ``None``: pressure comes from a running sequence's tail write — every
        running sequence is eligible, including (as a last resort) the one
        that needs the slot.
        """
        running = self._running()
        if admitting_priority is not None:
            running = {s: pa for s, pa in running.items()
                       if pa[0] < admitting_priority}
        victim = self.policy.victim(running)
        if victim is None:
            return False
        self._park(victim, step)
        return True

    # -- admission / resume ---------------------------------------------------

    def _start_running(self, rec: SeqRecord, logits, step: int,
                       outputs: dict) -> None:
        """Common admission tail: lane assignment + first token + finish."""
        seq = rec.req.req_id
        lane = self._free_lane()
        self._emit(rec, int(jnp.argmax(logits[0])), step)
        rec.lane, rec.state, rec.arrival = lane, RUNNING, step
        self.lanes[lane] = seq
        self._count("sched_admissions")
        if len(rec.generated) >= rec.req.n_new:
            self._finish(seq, outputs)

    def _admit(self, rec: SeqRecord, step: int, outputs: dict) -> bool:
        prompt = np.asarray(rec.req.tokens, np.int32)
        match = self.pool.match_prefix(prompt) if self.prefix else EMPTY_MATCH
        if match.matched_tokens:
            return self._admit_suffix(rec, prompt, match, step, outputs)
        ps = self.pool.cfg.page_size
        n_pages = max(1, -(-len(prompt) // ps))
        while not self.policy.reclaim(self.pool, n_pages, self._protect()):
            if not self._preempt_for(step, admitting_priority=rec.req.priority):
                return False
        # pad the prompt to its page bucket so prefill compiles once per
        # bucket (max_pages_per_seq shapes), not once per prompt length;
        # "lengths" makes the model take logits at the true last position
        padded = np.zeros(n_pages * ps, np.int32)
        padded[: len(prompt)] = prompt
        logits, cache = self.engine.prefill(
            {"tokens": jnp.asarray(padded)[None],
             "lengths": jnp.asarray([len(prompt)], jnp.int32)})
        seq = rec.req.req_id
        if not self.pool.write_prefill(seq, cache["k"], cache["v"],
                                       len(prompt), step):
            return False
        if self.prefix:
            self.pool.insert_prompt(seq, prompt, step)
        self._count("sched_prefill_tokens", len(prompt))
        self._start_running(rec, logits, step, outputs)
        return True

    def _admit_suffix(self, rec: SeqRecord, prompt: np.ndarray,
                      match: PrefixMatch, step: int, outputs: dict) -> bool:
        """Prefix-hit admission: map the matched pages, prefill only the
        suffix against the cached prefix K/V, cache the new pages."""
        seq = rec.req.req_id
        ps = self.pool.cfg.page_size
        matched = match.matched_tokens
        demand = self.pool.admit_slot_demand(match, len(prompt))
        while not self.policy.reclaim(self.pool, demand, self._protect()):
            if not self._preempt_for(step, admitting_priority=rec.req.priority):
                return False
        if not self.pool.map_prefix(seq, match, step):
            return False
        # suffix padded to its page bucket: one prefill_suffix trace per
        # bucket shape, logits taken at the true last suffix position
        suffix = prompt[matched:]
        n_pages = max(1, -(-len(suffix) // ps))
        padded = np.zeros(n_pages * ps, np.int32)
        padded[: len(suffix)] = suffix
        prefix_view = self.pool.gather([seq])       # length == matched tokens
        logits, cache = self.engine.prefill_suffix(
            prefix_view,
            {"tokens": jnp.asarray(padded)[None],
             "lengths": jnp.asarray([len(suffix)], jnp.int32)})
        if not self.pool.write_suffix(seq, cache["k"], cache["v"],
                                      len(suffix), step):
            self.pool.free_seq(seq)
            return False
        self.pool.insert_prompt(seq, prompt, step)
        self._count("sched_prefix_hits")
        self._count("sched_prefill_tokens", len(suffix))
        self._count("sched_prefill_tokens_saved", matched)
        self._start_running(rec, logits, step, outputs)
        return True

    def _try_resume(self, rec: SeqRecord, step: int) -> bool:
        seq = rec.req.req_id
        if not self.policy.reclaim(self.pool, 1, self._protect()):
            return False
        lane = self._free_lane()
        rec.lane, rec.state = lane, RUNNING
        self.lanes[lane] = seq
        self._count("sched_resumes")
        return True

    # -- the step -------------------------------------------------------------

    def _secure_tails(self, step: int) -> None:
        """Guarantee every running sequence can take this step's token write."""
        while True:
            # each pending append consumes at most one slot (fresh tail page,
            # CoW fork of a shared tail, or promotion of a compressed tail);
            # reserve them all at once
            reserve = sum(self.pool.tail_slot_demand(seq)
                          for seq in self.lanes if seq is not None)
            if reserve == 0 or self.policy.reclaim(self.pool, reserve,
                                                   self._protect()):
                return
            if not self._preempt_for(step):
                return                    # stall guard in run() handles this

    def step(self, step: int, outputs: dict) -> bool:
        """One scheduler iteration; returns True if any progress was made."""
        with obs.span("sched.step", step=step):
            return self._step(step, outputs)

    def _step(self, step: int, outputs: dict) -> bool:
        progress = False
        # 1. routine cooling
        self._count("sched_tiered_pages",
                    self.policy.tier(self.pool, step, self._protect()))
        # 2. resume parked: highest priority, oldest, then req_id
        for rec in sorted((r for r in self.recs.values() if r.state == PARKED),
                          key=lambda r: (-r.req.priority, r.arrival,
                                         r.req.req_id)):
            if self._free_lane() is None:
                break
            progress |= self._try_resume(rec, step)
        # 3. admit arrived waiting: priority, arrival time, then req_id
        for rec in sorted((r for r in self.recs.values()
                           if r.state == WAITING and r.req.arrive_at <= step),
                          key=lambda r: (-r.req.priority, r.req.arrive_at,
                                         r.req.req_id)):
            if self._free_lane() is None:
                break
            progress |= self._admit(rec, step, outputs)
        # 4. secure tail capacity (may compress-park under pressure)
        self._secure_tails(step)
        # 5. decode one token for every running lane. Two wirings:
        #    * reference — gather the contiguous fixed-width cache, run the
        #      model's own decode (writes K/V in place), extract the token;
        #    * paged kernel (engine.paged_decode_enabled) — keep the page
        #      layout (gather_pages), run the Pallas flash-decode step, and
        #      append the returned fresh K/V; nothing is ever scattered into
        #      a seq_capacity-wide cache.
        active = [(i, seq) for i, seq in enumerate(self.lanes) if seq is not None]
        if active:
            tokens = jnp.asarray(
                [self.recs[s].last_token if s is not None else 0
                 for s in self.lanes], jnp.int32)
            if self.paged_decode:
                pages = self.pool.gather_pages(self.lanes)
                logits, (k_new, v_new) = self.engine.decode_step_paged(pages,
                                                                       tokens)
            else:
                cache = self.pool.gather(self.lanes)
                logits, new_cache = self.engine.decode_step(cache, tokens)
            for lane, seq in active:
                rec = self.recs[seq]
                pos = self.pool.seq_len[seq]
                if self.paged_decode:
                    k_vec, v_vec = _lane_kv(k_new, v_new, lane)
                else:
                    k_vec, v_vec = _extract_token(new_cache["k"], new_cache["v"],
                                                  lane, pos)
                if not self.pool.append_token(seq, k_vec, v_vec, step):
                    raise RuntimeError("kvpool invariant: tail write failed "
                                       "after _secure_tails")
                self._emit(rec, int(jnp.argmax(logits[lane])), step)
                if len(rec.generated) >= rec.req.n_new:
                    self._finish(seq, outputs)
            self._count("sched_decode_steps")
            progress = True
        # 6. health: queue-depth/starvation gauges for the sentinels, then the
        # per-step health gate (raises on any error-bound violation)
        waiting = [r for r in self.recs.values()
                   if r.state == WAITING and r.req.arrive_at <= step]
        sentinels.note_scheduler(
            waiting=len(waiting),
            running=sum(1 for r in self.recs.values() if r.state == RUNNING),
            parked=sum(1 for r in self.recs.values() if r.state == PARKED),
            oldest_wait_steps=max((step - r.req.arrive_at for r in waiting),
                                  default=0))
        sentinels.assert_healthy()
        return progress

    def run(self, requests: list[Request]) -> tuple[dict[int, np.ndarray],
                                                    TraceStats]:
        """Drive the full trace; returns ({req_id: tokens}, stats)."""
        ids = [r.req_id for r in requests]
        if len(set(ids)) != len(ids):
            raise ValueError("request ids must be unique")
        cfg = self.pool.cfg
        for r in requests:
            need = len(np.asarray(r.tokens)) + r.n_new - 1
            if need > cfg.seq_capacity:
                raise ValueError(
                    f"request {r.req_id}: prompt + n_new needs {need} token "
                    f"slots > seq_capacity {cfg.seq_capacity}")
            if -(-len(np.asarray(r.tokens)) // cfg.page_size) > cfg.num_pages:
                raise ValueError(
                    f"request {r.req_id}: prompt alone needs more pages than "
                    f"the {cfg.num_pages}-slot slab")
        self.recs = {r.req_id: SeqRecord(req=r) for r in requests}
        outputs: dict[int, np.ndarray] = {}
        stalled = 0
        step = 0
        while step < self.max_steps:
            step += 1
            if all(r.state == FINISHED for r in self.recs.values()):
                break
            if self.step(step, outputs):
                stalled = 0
                continue
            # idle, not stalled: nothing live yet but arrivals are coming —
            # fast-forward the clock to the next arrival
            future = [r.req.arrive_at for r in self.recs.values()
                      if r.state == WAITING and r.req.arrive_at > step]
            if future and not any(r.state in (RUNNING, PARKED)
                                  for r in self.recs.values()):
                step = min(future) - 1
                stalled = 0
                continue
            stalled += 1
            if stalled > 2:
                raise RuntimeError(
                    "kvpool scheduler stalled: pool too small for this trace "
                    f"({self.pool.cfg.num_pages} pages, "
                    f"{len(self.recs)} requests)")
        if not all(r.state == FINISHED for r in self.recs.values()):
            raise RuntimeError("kvpool scheduler exceeded max_steps")
        # end-of-trace drain: the radix cache's page references go last;
        # the stats property folds the pool's counters in on every read
        self.pool.release_prefix_cache()
        return outputs, self.stats
