"""Radix (prefix) index over token-ID pages for the shared KV pool.

A page-granular prefix tree: level ``i`` of the tree holds pages covering
token positions ``[i*ps, (i+1)*ps)``, and each node's ``key`` is the run of
prompt token IDs resident in its page (``ps`` tokens for interior pages, a
partial run for a prompt's tail page). Prefix sharing is valid because the
shared tokens occupy identical absolute positions in every reader — RoPE'd
K/V at position ``p`` is position-dependent, so only position-aligned
prefixes (system prompts, few-shot templates) can alias physical pages.

The index never owns page *data* — it holds page IDs plus one refcount on
each referenced page (taken via the ``ref``/``unref`` callbacks the pool
passes in), so a cached prefix outlives the sequence that produced it and
the pool's allocator remains the single owner of slots and containers.

Matching (:meth:`RadixIndex.match`) walks the levels picking, per level, the
child with the longest common prefix against the remaining prompt; a full
node match descends, a partial match stops (the reader maps that page with a
valid length < ``ps`` — reads are length-masked, so mapping a page beyond
its matched run is safe). The match is capped at ``len(tokens) - 1`` so
admission always has at least one token to prefill (the last prompt token
must be computed to produce the first output logits), and matches shorter
than ``min_match`` are discarded (accidental one-token collisions are not
worth a copy-on-write).

Siblings may share key prefixes (a partial template-tail node next to a
full page that diverged into user tokens); ties on common-prefix length
prefer the fully-matched node (it allows descent), then the older node —
everything here is deterministic for a deterministic trace.

Insertion (:meth:`insert`) adds one node per prompt page that is not already
cached, referencing the sequence's own (private) pages; pages already
reachable by exact key are never inserted twice, so each physical page has
at most one node. Eviction is LRU over leaf nodes (``evict_lru``), dropping
the tree's reference only — live readers of the page are unaffected.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable


def _lcp(a, b) -> int:
    n = min(len(a), len(b))
    for i in range(n):
        if a[i] != b[i]:
            return i
    return n


@dataclasses.dataclass
class RadixNode:
    key: tuple[int, ...]            # prompt-token run resident in the page
    page_id: int
    parent: "RadixNode | None"
    node_id: int
    last_access: int = 0
    children: list["RadixNode"] = dataclasses.field(default_factory=list)

    def find_exact(self, run: tuple[int, ...]) -> "RadixNode | None":
        for c in self.children:
            if c.key == run:
                return c
        return None


@dataclasses.dataclass(frozen=True)
class PrefixMatch:
    """One admission's resolved prefix: shared pages in order, per-page valid
    token counts (== page key length except possibly the capped last entry),
    and the nodes to touch for LRU."""
    pids: tuple[int, ...]
    valids: tuple[int, ...]
    nodes: tuple[RadixNode, ...]

    @property
    def matched_tokens(self) -> int:
        return sum(self.valids)


EMPTY_MATCH = PrefixMatch((), (), ())


class RadixIndex:
    """Prefix tree over pages; refcounts pages via pool callbacks."""

    def __init__(self, ref: Callable[[int], None], unref: Callable[[int], None],
                 *, min_match: int = 1, max_cached_pages: int | None = None):
        self.ref = ref
        self.unref = unref
        self.min_match = min_match
        self.max_cached_pages = max_cached_pages
        self.root = RadixNode(key=(), page_id=-1, parent=None, node_id=-1)
        self._ids = itertools.count()
        self.size = 0               # nodes (== cached pages)

    # -- lookup ---------------------------------------------------------------

    def match(self, tokens) -> PrefixMatch:
        """Longest cached prefix of ``tokens``, capped at ``len(tokens) - 1``
        and discarded entirely below ``min_match``. Pure: no refcounts or
        LRU stamps change until the pool applies the match."""
        tokens = tuple(int(t) for t in tokens)
        pids: list[int] = []
        valids: list[int] = []
        nodes: list[RadixNode] = []
        node, rem = self.root, tokens
        while rem:
            best, best_lcp = None, 0
            for c in node.children:
                l = _lcp(rem, c.key)
                if l > best_lcp or (l == best_lcp and l and best is not None
                                    and l == len(c.key) and l < len(best.key)):
                    best, best_lcp = c, l
            if best is None or best_lcp == 0:
                break
            pids.append(best.page_id)
            valids.append(best_lcp)
            nodes.append(best)
            if best_lcp < len(best.key):
                break               # diverged mid-page: partial map, stop
            node, rem = best, rem[best_lcp:]
        # always leave >= 1 token to prefill (logits come from computing it)
        overshoot = sum(valids) - (len(tokens) - 1)
        if overshoot > 0:
            valids[-1] -= overshoot
            if valids[-1] <= 0:
                pids.pop(), valids.pop(), nodes.pop()
        if sum(valids) < self.min_match:
            return EMPTY_MATCH
        return PrefixMatch(tuple(pids), tuple(valids), tuple(nodes))

    # -- insertion / eviction -------------------------------------------------

    def insert_runs(self, runs: list[tuple[int, ...]], pids: list[int],
                    step: int) -> int:
        """``runs[i]`` is the prompt-token run of page ``pids[i]``; create
        nodes for uncached runs, descend through cached ones."""
        node, created = self.root, 0
        for run, pid in zip(runs, pids):
            child = node.find_exact(run)
            if child is None:
                child = RadixNode(key=run, page_id=pid, parent=node,
                                  node_id=next(self._ids), last_access=step)
                node.children.append(child)
                self.ref(pid)
                self.size += 1
                created += 1
            else:
                child.last_access = step
            node = child
        if self.max_cached_pages is not None:
            self.evict_lru(keep=self.max_cached_pages)
        return created

    def touch(self, match: PrefixMatch, step: int) -> None:
        for n in match.nodes:
            n.last_access = step

    def _leaves(self) -> list[RadixNode]:
        out = []

        def walk(n):
            for c in n.children:
                walk(c)
            if n is not self.root and not n.children:
                out.append(n)

        walk(self.root)
        return out

    def _drop(self, node: RadixNode) -> None:
        node.parent.children.remove(node)
        self.unref(node.page_id)
        self.size -= 1

    def evict_lru(self, *, keep: int) -> int:
        """Drop least-recently-accessed leaves until ``size <= keep``;
        removing a leaf may expose its parent for the next round. Live
        readers keep their mappings — only the tree's ref is released."""
        evicted = 0
        while self.size > keep:
            leaves = self._leaves()
            if not leaves:
                break
            self._drop(min(leaves, key=lambda n: (n.last_access, n.node_id)))
            evicted += 1
        return evicted

    def release_all(self) -> int:
        """Drop every cached page (end-of-trace drain)."""
        n = self.evict_lru(keep=0)
        return n
