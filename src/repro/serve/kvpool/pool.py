"""Paged KV-cache pool: block allocator + page table + FZ compression tiers.

The device-resident half of the kvpool subsystem. A ``PagePool`` owns one
preallocated slab of physical page slots

    slots : (num_pages, 2, L, page_size, KVH, hd)     # [k|v] x layers x tokens

and a host-side page table mapping each sequence to a list of logical pages.
Every logical page is in exactly one of two states:

  * ``raw``        — backed by a physical slot in the slab (hot tier);
  * ``compressed`` — held as a fixed-shape :class:`repro.core.fz.FZCompressed`
                     container with *no* slot (cold tier); reads decompress
                     transiently, writes require promotion back to raw.

Physical slots not backing any page are ``free``. Compressing a page frees
its slot — that is the capacity mechanism: a pool of N raw slots can hold far
more than N pages' worth of live KV state, which is exactly the paper's §2.4
in-memory-compression pitch (FZ is fast enough to (de)compress device-resident
state at serving latency, so cold pages are *storage*, not tombstones).

Error-bound discipline: all pages compress against one shared absolute bound
(``fz.compress_with_eb``), resolved once from the first KV data the pool sees
(or taken verbatim in ``eb_mode="abs"``). A shared bound makes the
reconstruction grid ``round(x / 2eb) * 2eb`` independent of page chunking, so
park -> resume through pages is bit-identical to a whole-cache
``serve.engine.compress_cache`` / ``decompress_cache`` roundtrip at the same
bound (pinned in tests/test_kvpool.py) — and every page shares a single jit
trace because the bound is traced, not baked into the static config.

Dispatch batching: same-shaped pages tier down / decompress through one
vmapped FZ dispatch (``compress_pages`` / the batched cold-read inside
``gather``) instead of one Python-loop dispatch per page; single-page results
are bit-identical (pinned in tests/test_kvpool.py). Byte accounting is
charged against the slab dtype: a container built from a bfloat16 page
reports ``raw_bytes() == n * 2``, so ``compression_ratio()`` and ``PoolStats``
never inflate by the internal float32 cast.

Reads come in two shapes: ``gather`` materializes the contiguous fixed-width
(L, B, seq_capacity, KVH, hd) cache for the model's reference decode, and
``gather_pages`` keeps the (L, B, P, ps, KVH, hd) page layout that the Pallas
flash-decode kernel (kernels/flash_decode) consumes directly.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import fz

FREE = "free"
RAW = "raw"
COMPRESSED = "compressed"


@dataclasses.dataclass(frozen=True)
class PoolConfig:
    """Static pool configuration.

    ``num_pages`` bounds the *raw* (hot) tier only; total live state can
    exceed it via the compressed tier. ``seq_capacity`` is the fixed gather
    width: decode always sees a (L, B, seq_capacity, KVH, hd) cache so the
    decode step compiles exactly once per lane count.
    """
    num_pages: int = 16
    page_size: int = 16            # tokens per page
    seq_capacity: int = 256        # max tokens per sequence (gather width)
    cold_after: int = 4            # steps without a write before a page tiers down
    eb: float = 1e-4               # error bound for parked pages
    eb_mode: str = "rel"           # "rel": resolved once from first KV data; "abs"
    # route the hot paths through Pallas kernels (mirrors FZConfig): FZ
    # quant/shuffle stages AND page-native decode attention — the engine's
    # serve loop then decodes via gather_pages + kernels/flash_decode instead
    # of materializing the contiguous cache (interpret mode off-TPU).
    # kernel_mode picks the FZ flavor: "fused" single-launch megakernels
    # (default — page park/resume and transient cold reads each cost one
    # kernel launch) or "staged" per-stage kernels (the second oracle). The
    # vmapped batched dispatches below stay bit-identical to single-page
    # under both modes (fused path pinned in tests/test_kvpool.py via
    # use_kernels; the full three-way vmap pin is
    # tests/test_fz_properties.py::test_three_way_shared_eb_vmap_seeded).
    use_kernels: bool = False
    kernel_mode: str = "fused"
    exact_outliers: bool = False   # match serve.KVCompressionConfig default
    dtype: str = "bfloat16"

    def __post_init__(self):
        if self.seq_capacity % self.page_size:
            raise ValueError("seq_capacity must be a multiple of page_size")
        if self.num_pages < 2:
            raise ValueError("need at least 2 physical pages")

    @property
    def max_pages_per_seq(self) -> int:
        return self.seq_capacity // self.page_size

    def fz_config(self) -> fz.FZConfig:
        # eb/eb_mode here are only a fallback identity; page compression goes
        # through compress_with_eb with the pool's shared resolved bound.
        return fz.FZConfig(eb=self.eb, eb_mode="abs",
                           exact_outliers=self.exact_outliers,
                           use_kernels=self.use_kernels,
                           kernel_mode=self.kernel_mode)


@dataclasses.dataclass
class Page:
    """Page-table entry (host side)."""
    page_id: int
    seq: int
    index: int                     # page index within its sequence
    slot: int | None = None        # physical slot when raw
    comp: fz.FZCompressed | None = None
    last_write: int = 0            # scheduler step of the last write

    @property
    def state(self) -> str:
        return RAW if self.slot is not None else COMPRESSED


@dataclasses.dataclass
class PoolStats:
    compressions: int = 0
    decompressions: int = 0        # transient cold reads + promotions
    high_water_slots: int = 0      # max physical slots simultaneously raw
    high_water_bytes: int = 0      # max raw-slab-in-use + compressed used_bytes
    high_water_demand_bytes: int = 0  # max live pages held fully raw


# ---------------------------------------------------------------------------
# jit data plane (traced indices -> one trace per shape, not per call site)
# ---------------------------------------------------------------------------

@jax.jit
def _zero_slot(slots, slot):
    return slots.at[slot].set(jnp.zeros((), slots.dtype))


@jax.jit
def _set_slot(slots, slot, page):
    return slots.at[slot].set(page.astype(slots.dtype))


@jax.jit
def _set_token(slots, slot, off, k_vec, v_vec):
    """Write one token's K/V (each (L, KVH, hd)) into a page at ``off``."""
    slots = slots.at[slot, 0, :, off].set(k_vec.astype(slots.dtype))
    return slots.at[slot, 1, :, off].set(v_vec.astype(slots.dtype))


@partial(jax.jit, static_argnames=("cfg",))
def _compress_pages_batch(pages_flat, eb_abs, cfg: fz.FZConfig):
    """vmap ``compress_with_eb`` over same-shaped pages: one dispatch for the
    whole cold set. Elementwise math at a shared traced bound — each row is
    bit-identical to a single-page ``compress_with_eb`` call."""
    return jax.vmap(lambda d: fz.compress_with_eb(d, eb_abs, cfg))(pages_flat)


@partial(jax.jit, static_argnames=("cfg",))
def _decompress_pages_batch(comp: fz.FZCompressed, cfg: fz.FZConfig):
    """vmap ``decompress`` over a leaf-stacked container batch."""
    return jax.vmap(lambda c: fz.decompress(c, cfg))(comp)


@partial(jax.jit, static_argnames=("ps", "n_pages"))
def _paginate(k, v, ps: int, n_pages: int):
    """Chop a prefill cache (L, 1, Smax, KVH, hd) into (P, 2, L, ps, KVH, hd)."""
    L, _, S, KVH, hd = k.shape
    if n_pages * ps > S:
        pad = n_pages * ps - S
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    kp = k[:, 0, : n_pages * ps].reshape(L, n_pages, ps, KVH, hd)
    vp = v[:, 0, : n_pages * ps].reshape(L, n_pages, ps, KVH, hd)
    return jnp.stack([kp, vp], axis=2).transpose(1, 2, 0, 3, 4, 5)


class PagePool:
    """Block allocator + page table over one preallocated KV slab."""

    def __init__(self, cfg: PoolConfig, *, n_layers: int, n_kv_heads: int,
                 head_dim: int):
        self.cfg = cfg
        self.page_shape = (2, n_layers, cfg.page_size, n_kv_heads, head_dim)
        dt = jnp.dtype(cfg.dtype)
        self.slots = jnp.zeros((cfg.num_pages, *self.page_shape), dt)
        self._zero_page = jnp.zeros(self.page_shape, dt)
        self.free_slots: list[int] = list(range(cfg.num_pages))
        self.pages: dict[int, Page] = {}
        self.seq_pages: dict[int, list[int]] = {}
        self.seq_len: dict[int, int] = {}
        self._next_page = 0
        self.eb_abs: jax.Array | None = None
        self._fzc = cfg.fz_config()
        self.stats = PoolStats()

    # -- geometry / accounting ------------------------------------------------

    @property
    def slot_bytes(self) -> int:
        return math.prod(self.page_shape) * self.slots.dtype.itemsize

    def n_free_slots(self) -> int:
        return len(self.free_slots)

    def slot_states(self) -> list[str]:
        """Per physical slot: free|raw (compressed pages hold no slot)."""
        out = [FREE] * self.cfg.num_pages
        for p in self.pages.values():
            if p.slot is not None:
                out[p.slot] = RAW
        return out

    def pages_of(self, seq: int) -> list[Page]:
        return [self.pages[i] for i in self.seq_pages.get(seq, [])]

    def raw_bytes_in_use(self) -> int:
        return (self.cfg.num_pages - len(self.free_slots)) * self.slot_bytes

    def compressed_used_bytes(self) -> int:
        return sum(int(p.comp.used_bytes()) for p in self.pages.values()
                   if p.comp is not None)

    def compressed_wire_bytes(self) -> int:
        """Capacity-sized footprint if containers sit in fixed-shape arenas."""
        return sum(p.comp.wire_bytes() for p in self.pages.values()
                   if p.comp is not None)

    def used_bytes(self) -> int:
        """Raw slab in use + actual compressed payload bytes."""
        return self.raw_bytes_in_use() + self.compressed_used_bytes()

    def live_demand_bytes(self) -> int:
        """What the same live pages would occupy held fully raw."""
        return len(self.pages) * self.slot_bytes

    def note_high_water(self) -> None:
        """Sample peaks at allocation/promotion time (the true maxima —
        end-of-step sampling would miss admit-then-park within one step)."""
        self.stats.high_water_slots = max(
            self.stats.high_water_slots,
            self.cfg.num_pages - len(self.free_slots))
        self.stats.high_water_bytes = max(self.stats.high_water_bytes,
                                          self.used_bytes())
        self.stats.high_water_demand_bytes = max(
            self.stats.high_water_demand_bytes, self.live_demand_bytes())

    # -- error bound ----------------------------------------------------------

    def _ensure_eb(self, sample: jax.Array) -> None:
        if self.eb_abs is None:
            rcfg = fz.FZConfig(eb=self.cfg.eb, eb_mode=self.cfg.eb_mode)
            self.eb_abs = fz.resolve_eb(
                sample.astype(jnp.float32).reshape(-1), rcfg)

    # -- allocator ------------------------------------------------------------

    def alloc_page(self, seq: int, step: int) -> int | None:
        """Allocate (and zero) a fresh raw page for ``seq``; None if no slot."""
        if not self.free_slots:
            return None
        slot = self.free_slots.pop()
        self.slots = _zero_slot(self.slots, slot)
        pid = self._next_page
        self._next_page += 1
        self.pages[pid] = Page(pid, seq, len(self.seq_pages.setdefault(seq, [])),
                               slot=slot, last_write=step)
        self.seq_pages[seq].append(pid)
        self.seq_len.setdefault(seq, 0)
        self.note_high_water()
        return pid

    def free_seq(self, seq: int) -> None:
        for pid in self.seq_pages.pop(seq, []):
            page = self.pages.pop(pid)
            if page.slot is not None:
                self.free_slots.append(page.slot)
        self.seq_len.pop(seq, None)

    # -- tiering --------------------------------------------------------------

    def compress_page(self, pid: int) -> None:
        """Raw -> compressed: FZ the page contents, release the slot.

        The slab dtype flows into the container (not the pipeline's internal
        float32), so ``raw_bytes``/``compression_ratio`` stay honest for
        bfloat16 slabs."""
        page = self.pages[pid]
        if page.slot is None:
            return
        flat = self.slots[page.slot].reshape(-1)
        self._ensure_eb(flat)
        page.comp = fz.compress_with_eb(flat, self.eb_abs, self._fzc)
        self.free_slots.append(page.slot)
        page.slot = None
        self.stats.compressions += 1

    def compress_pages(self, pids: list[int]) -> None:
        """Batched raw -> compressed: one vmapped FZ dispatch for the whole
        set (ROADMAP "kvpool batched tiering"); bit-identical per page to
        ``compress_page``. Duplicate, already-compressed and freed pids are
        skipped."""
        pids = [pid for pid in dict.fromkeys(pids)
                if pid in self.pages and self.pages[pid].slot is not None]
        if len(pids) <= 1:
            for pid in pids:
                self.compress_page(pid)
            return
        flats = jnp.stack([self.slots[self.pages[pid].slot].reshape(-1)
                           for pid in pids])
        self._ensure_eb(flats[0])
        batch = _compress_pages_batch(flats, self.eb_abs, self._fzc)
        for i, pid in enumerate(pids):
            page = self.pages[pid]
            page.comp = jax.tree.map(lambda leaf, i=i: leaf[i], batch)
            self.free_slots.append(page.slot)
            page.slot = None
            self.stats.compressions += 1

    def promote_page(self, pid: int, step: int) -> bool:
        """Compressed -> raw (needed before a write); False if no free slot."""
        page = self.pages[pid]
        if page.slot is not None:
            return True
        if not self.free_slots:
            return False
        data = self._decompress(page)
        slot = self.free_slots.pop()
        self.slots = _set_slot(self.slots, slot, data)
        page.slot, page.comp, page.last_write = slot, None, step
        self.note_high_water()
        return True

    def _decompress(self, page: Page) -> jax.Array:
        return self._decompress_many([page])[0]

    def _decompress_many(self, pages: list[Page]) -> list[jax.Array]:
        """Transient cold reads, one vmapped dispatch for the whole set
        (single-page results bit-identical to ``fz.decompress``). The
        reconstruction lands back in the slab dtype the page was built from."""
        if not pages:
            return []
        self.stats.decompressions += len(pages)
        if len(pages) == 1:
            rec = fz.decompress(pages[0].comp, self._fzc)[None]
        else:
            stacked = jax.tree.map(lambda *leaves: jnp.stack(leaves),
                                   *[p.comp for p in pages])
            rec = _decompress_pages_batch(stacked, self._fzc)
        return [rec[i].reshape(self.page_shape).astype(self.slots.dtype)
                for i in range(len(pages))]

    def page_data(self, pid: int) -> jax.Array:
        """Page contents (2, L, ps, KVH, hd); cold pages decompress transiently."""
        page = self.pages[pid]
        if page.slot is not None:
            return self.slots[page.slot]
        return self._decompress(page)

    # -- writes ---------------------------------------------------------------

    def write_prefill(self, seq: int, k: jax.Array, v: jax.Array, length: int,
                      step: int) -> bool:
        """Ingest a prefill cache (L, 1, Smax, KVH, hd) as raw pages."""
        ps = self.cfg.page_size
        n_pages = max(1, -(-length // ps))
        if length > self.cfg.seq_capacity:
            raise ValueError(f"prompt of {length} tokens exceeds seq_capacity "
                             f"{self.cfg.seq_capacity}")
        if n_pages > len(self.free_slots):
            return False
        self._ensure_eb(k)
        pages = _paginate(k, v, ps, n_pages)
        for j in range(n_pages):
            pid = self.alloc_page(seq, step)
            assert pid is not None
            self.slots = _set_slot(self.slots, self.pages[pid].slot, pages[j])
        self.seq_len[seq] = length
        return True

    def append_token(self, seq: int, k_vec: jax.Array, v_vec: jax.Array,
                     step: int) -> bool:
        """Write one decode step's K/V (each (L, KVH, hd)) at the tail.

        The caller must have secured tail capacity (``tail_writable``); returns
        False when it has not (no slot for a fresh page / promotion).
        """
        ps = self.cfg.page_size
        pos = self.seq_len[seq]
        if pos >= self.cfg.seq_capacity:
            raise ValueError(f"sequence {seq} exceeds seq_capacity")
        if pos % ps == 0:
            if self.alloc_page(seq, step) is None:
                return False
        pid = self.seq_pages[seq][pos // ps]
        page = self.pages[pid]
        if page.slot is None and not self.promote_page(pid, step):
            return False
        self.slots = _set_token(self.slots, page.slot, pos % ps, k_vec, v_vec)
        page.last_write = step
        self.seq_len[seq] = pos + 1
        return True

    def tail_slot_demand(self, seq: int) -> int:
        """Physical slots the next ``append_token`` for ``seq`` will consume:
        1 if it opens a fresh page or must promote a compressed tail, else 0."""
        pos = self.seq_len[seq]
        if pos % self.cfg.page_size == 0:       # next write opens a new page
            return 1
        pid = self.seq_pages[seq][pos // self.cfg.page_size]
        return 0 if self.pages[pid].slot is not None else 1

    def tail_writable(self, seq: int) -> bool:
        """Can the next ``append_token`` for ``seq`` proceed right now?"""
        return self.tail_slot_demand(seq) <= len(self.free_slots)

    # -- reads ----------------------------------------------------------------

    def _lane_pages(self, lane_seqs: list[int | None]):
        """Stack every lane's pages: (B, P, 2, L, ps, KVH, hd) + (B,) lengths.

        Cold pages across ALL lanes decompress in one vmapped dispatch
        (transiently — reading never changes a page's tier); empty lanes are
        zero-filled at length 0.
        """
        P = self.cfg.max_pages_per_seq
        lane_pids = [self.seq_pages.get(seq, []) if seq is not None else []
                     for seq in lane_seqs]
        cold = [pid for pids in lane_pids for pid in pids
                if self.pages[pid].slot is None]
        cold_data = dict(zip(cold, self._decompress_many(
            [self.pages[pid] for pid in cold])))
        lanes = []
        lengths = []
        for seq, pids in zip(lane_seqs, lane_pids):
            tensors = [self.slots[self.pages[pid].slot]
                       if self.pages[pid].slot is not None else cold_data[pid]
                       for pid in pids]
            tensors += [self._zero_page] * (P - len(tensors))
            lanes.append(jnp.stack(tensors))            # (P, 2, L, ps, KVH, hd)
            lengths.append(self.seq_len.get(seq, 0) if seq is not None else 0)
        return jnp.stack(lanes), jnp.asarray(lengths, jnp.int32)

    def gather(self, lane_seqs: list[int | None]):
        """Assemble the fixed-width contiguous decode cache for a set of lanes.

        Returns ``{"k": (L, B, seq_capacity, KVH, hd), "v": ..., "length": (B,)}``
        with empty lanes zero-filled at length 0. This is the reference-decode
        view; the kernel path reads ``gather_pages`` and skips the P*ps merge.
        """
        arr, lengths = self._lane_pages(lane_seqs)      # (B, P, 2, L, ps, KVH, hd)
        B, P, _, L, ps, KVH, hd = arr.shape
        kv = arr.transpose(2, 3, 0, 1, 4, 5, 6).reshape(2, L, B, P * ps, KVH, hd)
        return {"k": kv[0], "v": kv[1], "length": lengths}

    def gather_pages(self, lane_seqs: list[int | None]):
        """Page-native decode view: ``{"k": (L, B, P, ps, KVH, hd), "v": ...,
        "length": (B,)}`` — exactly the tile layout
        ``kernels/flash_decode.decode_partials_pages`` consumes, so decode
        never materializes the contiguous ``seq_capacity``-wide cache."""
        arr, lengths = self._lane_pages(lane_seqs)      # (B, P, 2, L, ps, KVH, hd)
        kv = arr.transpose(2, 3, 0, 1, 4, 5, 6)         # (2, L, B, P, ps, KVH, hd)
        return {"k": kv[0], "v": kv[1], "length": lengths}

    def materialize(self, seq: int):
        """One sequence's cache (L, 1, seq_capacity, KVH, hd) k/v + length."""
        cache = self.gather([seq])
        return cache["k"], cache["v"], self.seq_len[seq]
