"""Paged KV-cache pool: refcounted block allocator, radix prefix sharing,
copy-on-write pages, and FZ compression tiers.

The device-resident half of the kvpool subsystem. A ``PagePool`` owns one
preallocated slab of physical page slots

    slots : (num_pages, 2, L, page_size, KVH, hd)     # [k|v] x layers x tokens

a host-side page table, and (when prefix sharing is on) a
:class:`repro.serve.kvpool.radix.RadixIndex` over prompt token IDs. Every
physical page is in exactly one of two states:

  * ``raw``        — backed by a physical slot in the slab (hot tier);
  * ``compressed`` — no slot (cold tier): a fixed-shape
                     :class:`repro.core.fz.FZCompressed` container, or — with
                     ``PoolConfig.cold_entropy`` — the serialized
                     entropy-coded byte container (``Page.blob``,
                     docs/CONTAINER_FORMAT.md), which deserializes to a
                     leaf-identical container before decode. Reads decompress
                     transiently, writes require promotion back to raw.

Physical slots not backing any page are ``free``. Compressing a page frees
its slot — that is the capacity mechanism: a pool of N raw slots can hold far
more than N pages' worth of live KV state (paper §2.4 — FZ is fast enough to
(de)compress device-resident state at serving latency, so cold pages are
*storage*, not tombstones).

Sharing multiplies capacity a second time. Pages carry a refcount = number
of sequence mappings + (0|1) radix-tree reference; one physical page (or one
compressed container) can back the same prefix in many sequences at once:

  * admission walks the radix tree (``match_prefix``) and maps the matched
    prefix onto existing pages (``map_prefix``) instead of re-prefilling —
    raw or compressed, it does not matter, reads are tier-transparent;
  * pages are append-only and reads are masked by each reader's own valid
    length, so a shared page is safe to read below the reader's matched
    length no matter what else it holds;
  * any *write* to a page with refs > 1 first promotes a private copy of
    just that page (copy-on-write: ``_cow_page``). Shared pages are
    therefore immutable — two triggers exist: admission writing a suffix
    into a partially-matched tail page, and a sequence appending a decode
    token into a page the radix tree also references;
  * ``free_seq`` and tree eviction only drop references; the physical page
    (slot or container) is released when the last reference goes.

Error-bound discipline is unchanged from the non-shared pool: all pages
compress against one shared absolute bound (``fz.compress_with_eb``), so
park -> resume through pages is bit-identical to the whole-cache oracle at
the same bound, and a shared container decodes to the same values for every
reader.

Dispatch batching + the dedup read path: same-shaped pages tier down /
decompress through one vmapped FZ dispatch. The per-step read path
(``gather`` / ``gather_pages``) first dedups cold page IDs across *all*
lanes — a cold container shared by many readers is decoded exactly once per
scheduler step and the reconstruction fanned out to every lane
(``PoolStats.shared_cold_reads_deduped`` counts the decodes this avoids).

Byte accounting counts physical state once, however many sequences map it:
``used_bytes`` is raw-slab-in-use plus each distinct container's payload;
``logical_demand_bytes`` is what the live page-table *mappings* would cost
held raw and private, so ``compression_ratio()`` reports the honest
dedup x compression capacity multiplier.

``PoolConfig.prefix_mode`` selects the storage discipline — ``"radix"``
(shared refcounted pages, the production path), ``"copy"`` (same radix
matching and suffix prefill, but matched pages are *copied* into private
slots: the bit-parity twin that isolates what sharing changes — nothing,
numerically), or ``"off"`` (the PR-2 pool: no tree, full prefill always).
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from functools import partial

import jax
import jax.numpy as jnp

from repro import obs
from repro.core import fz
from repro.obs import sentinels

from .radix import EMPTY_MATCH, PrefixMatch, RadixIndex

FREE = "free"
RAW = "raw"
COMPRESSED = "compressed"

PREFIX_MODES = ("radix", "copy", "off")

# gap-array chunk size for entropy-coded page blobs: page payloads are small,
# and the lockstep chunk-parallel decode runs ~chunk_bytes steps, so a small
# chunk keeps per-read host latency bounded (core/entropy.py)
_COLD_CHUNK = 512


@dataclasses.dataclass(frozen=True)
class PoolConfig:
    """Static pool configuration.

    ``num_pages`` bounds the *raw* (hot) tier only; total live state can
    exceed it via the compressed tier. ``seq_capacity`` is the fixed gather
    width: decode always sees a (L, B, seq_capacity, KVH, hd) cache so the
    decode step compiles exactly once per lane count.
    """
    num_pages: int = 16
    page_size: int = 16            # tokens per page
    seq_capacity: int = 256        # max tokens per sequence (gather width)
    cold_after: int = 4            # steps without a write before a page tiers down
    eb: float = 1e-4               # error bound for parked pages
    eb_mode: str = "rel"           # "rel": resolved once from first KV data; "abs"
    # route the hot paths through Pallas kernels (mirrors FZConfig): FZ
    # quant/shuffle stages AND page-native decode attention — the engine's
    # serve loop then decodes via gather_pages + kernels/flash_decode instead
    # of materializing the contiguous cache (interpret mode off-TPU).
    # kernel_mode picks the FZ flavor: "auto" (default; the repro.tune
    # cached winner, else the backend-aware static fallback — see
    # core/fz.py), "fused" single-launch megakernels, or "staged" per-stage
    # kernels (the second oracle); batched vmapped dispatches stay
    # bit-identical to single-page under all of them.
    use_kernels: bool = False
    kernel_mode: str = "auto"
    exact_outliers: bool = False   # match serve.KVCompressionConfig default
    dtype: str = "bfloat16"
    # prefix sharing: "radix" shares refcounted pages (CoW on write),
    # "copy" matches but duplicates pages (storage-parity baseline),
    # "off" disables matching entirely (the PR-2 pool).
    prefix_mode: str = "radix"
    # matches shorter than this many tokens are ignored (None -> page_size;
    # filters accidental sub-page token collisions on small vocabularies)
    min_match_tokens: int | None = None
    # radix-cached pages kept past their readers (None = unbounded; the
    # scheduler releases the whole cache at end-of-trace drain)
    max_cached_pages: int | None = None
    # entropy-coded cold tier (lossy-lossless orchestration,
    # docs/CONTAINER_FORMAT.md): parked containers are serialized to the
    # versioned byte format with the second-stage Huffman coder
    # (core/entropy.py, probe-gated per container). Reads stay tier- and
    # bit-transparent — the blob deserializes to a leaf-identical container
    # before the same vmapped decode — at the cost of a host-side
    # entropy decode per cold read. Hot pages and promotion are untouched:
    # this is strictly a park-path trade of latency for ratio.
    cold_entropy: bool = False

    def __post_init__(self):
        if self.seq_capacity % self.page_size:
            raise ValueError("seq_capacity must be a multiple of page_size")
        if self.num_pages < 2:
            raise ValueError("need at least 2 physical pages")
        if self.prefix_mode not in PREFIX_MODES:
            raise ValueError(f"prefix_mode must be one of {PREFIX_MODES}")

    @property
    def max_pages_per_seq(self) -> int:
        return self.seq_capacity // self.page_size

    @property
    def min_match(self) -> int:
        return (self.page_size if self.min_match_tokens is None
                else self.min_match_tokens)

    def fz_config(self) -> fz.FZConfig:
        # eb/eb_mode here are only a fallback identity; page compression goes
        # through compress_with_eb with the pool's shared resolved bound.
        return fz.FZConfig(eb=self.eb, eb_mode="abs",
                           exact_outliers=self.exact_outliers,
                           use_kernels=self.use_kernels,
                           kernel_mode=self.kernel_mode)


@dataclasses.dataclass
class Page:
    """Physical page (host-side table entry). ``refs`` counts sequence
    mappings plus the radix tree's reference (0 or 1); the page is released
    when it reaches zero. Shared pages (refs > 1) are immutable — writers
    go through copy-on-write."""
    page_id: int
    slot: int | None = None        # physical slot when raw
    comp: fz.FZCompressed | None = None
    blob: bytes | None = None      # entropy-coded serialized container
    refs: int = 1
    last_write: int = 0            # scheduler step of the last write

    @property
    def state(self) -> str:
        return RAW if self.slot is not None else COMPRESSED


@dataclasses.dataclass
class PoolStats:
    """Point-in-time snapshot of one pool's counters.

    Not a live accumulator: since the obs refactor the single source of truth
    is the :mod:`repro.obs` registry (metrics labeled ``pool=<instance>``),
    and ``PagePool.stats`` materializes this view on every read. Parity with
    the raw registry snapshot is pinned in tests/test_obs_integration.py.
    """
    compressions: int = 0
    compress_dispatches: int = 0   # FZ launches issued for parking (batched)
    decompressions: int = 0        # containers actually decoded
    decompress_dispatches: int = 0  # vmapped decode dispatches issued
    cow_promotions: int = 0        # shared-page writes that forked a copy
    prefix_hit_pages: int = 0      # pages mapped from the radix cache
    prefix_hit_tokens: int = 0     # tokens those mappings covered
    shared_cold_reads_deduped: int = 0  # per-step cold decodes avoided by dedup
    high_water_slots: int = 0      # max physical slots simultaneously raw
    high_water_bytes: int = 0      # max raw-slab-in-use + compressed used_bytes
    high_water_demand_bytes: int = 0   # max live physical pages held fully raw
    high_water_logical_bytes: int = 0  # max per-seq mappings held raw + private


# maps PoolStats fields to registry metric names (all labeled pool=<id>);
# (kind, name): counters read .value, gauges read int(.value)
_POOL_METRICS = {
    "compressions": ("counter", "kvpool_compressions"),
    "compress_dispatches": ("counter", "kvpool_compress_dispatches"),
    "decompressions": ("counter", "kvpool_decompressions"),
    "decompress_dispatches": ("counter", "kvpool_decompress_dispatches"),
    "cow_promotions": ("counter", "kvpool_cow_promotions"),
    "prefix_hit_pages": ("counter", "kvpool_prefix_hit_pages"),
    "prefix_hit_tokens": ("counter", "kvpool_prefix_hit_tokens"),
    "shared_cold_reads_deduped": ("counter", "kvpool_shared_cold_reads_deduped"),
    "high_water_slots": ("gauge", "kvpool_high_water_slots"),
    "high_water_bytes": ("gauge", "kvpool_high_water_bytes"),
    "high_water_demand_bytes": ("gauge", "kvpool_high_water_demand_bytes"),
    "high_water_logical_bytes": ("gauge", "kvpool_high_water_logical_bytes"),
}

_pool_ids = itertools.count()


# ---------------------------------------------------------------------------
# jit data plane (traced indices -> one trace per shape, not per call site)
# ---------------------------------------------------------------------------

@jax.jit
def _zero_slot(slots, slot):
    return slots.at[slot].set(jnp.zeros((), slots.dtype))


@jax.jit
def _set_slot(slots, slot, page):
    return slots.at[slot].set(page.astype(slots.dtype))


@jax.jit
def _copy_slot(slots, dst, src):
    return slots.at[dst].set(slots[src])


@jax.jit
def _set_token(slots, slot, off, k_vec, v_vec):
    """Write one token's K/V (each (L, KVH, hd)) into a page at ``off``."""
    slots = slots.at[slot, 0, :, off].set(k_vec.astype(slots.dtype))
    return slots.at[slot, 1, :, off].set(v_vec.astype(slots.dtype))


@partial(jax.jit, static_argnames=("off",))
def _write_span(slots, slot, off: int, chunk):
    """Write ``chunk`` (2, L, n, KVH, hd) into a page at token offsets
    [off, off + n) — the mid-page landing zone of a suffix prefill."""
    n = chunk.shape[2]
    return slots.at[slot, :, :, off:off + n].set(chunk.astype(slots.dtype))


@partial(jax.jit, static_argnames=("ps", "n_pages"))
def _paginate(k, v, ps: int, n_pages: int):
    """Chop a prefill cache (L, 1, Smax, KVH, hd) into (P, 2, L, ps, KVH, hd)."""
    L, _, S, KVH, hd = k.shape
    if n_pages * ps > S:
        pad = n_pages * ps - S
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    kp = k[:, 0, : n_pages * ps].reshape(L, n_pages, ps, KVH, hd)
    vp = v[:, 0, : n_pages * ps].reshape(L, n_pages, ps, KVH, hd)
    return jnp.stack([kp, vp], axis=2).transpose(1, 2, 0, 3, 4, 5)


class PagePool:
    """Refcounted block allocator + page table + radix prefix index over one
    preallocated KV slab."""

    def __init__(self, cfg: PoolConfig, *, n_layers: int, n_kv_heads: int,
                 head_dim: int):
        self.cfg = cfg
        self.page_shape = (2, n_layers, cfg.page_size, n_kv_heads, head_dim)
        dt = jnp.dtype(cfg.dtype)
        self.slots = jnp.zeros((cfg.num_pages, *self.page_shape), dt)
        self._zero_page = jnp.zeros(self.page_shape, dt)
        self.free_slots: list[int] = list(range(cfg.num_pages))
        self.pages: dict[int, Page] = {}
        self.seq_pages: dict[int, list[int]] = {}
        self.seq_len: dict[int, int] = {}
        self._next_page = 0
        self.eb_abs: jax.Array | None = None
        self._fzc = cfg.fz_config()
        # the pool's fixed container capacities: blob-backed pages must
        # deserialize to exactly these shapes to stack into vmapped decodes
        n_flat = math.prod(self.page_shape)
        self._page_capacity = self._fzc.payload_capacity(n_flat)
        self._page_ocap = self._fzc.outlier_capacity(n_flat)
        # all this pool's metrics carry a per-instance label so several pools
        # in one process (tests, A/B batchers) never cross-count
        self._obs_id = f"pool{next(_pool_ids)}"
        self.radix: RadixIndex | None = None
        if cfg.prefix_mode != "off":
            self.radix = RadixIndex(self._ref, self._unref,
                                    min_match=cfg.min_match,
                                    max_cached_pages=cfg.max_cached_pages)

    # -- telemetry ------------------------------------------------------------

    def _count(self, name: str, n: int = 1) -> None:
        obs.counter(name, pool=self._obs_id).inc(n)

    def _water(self, name: str, v: float) -> None:
        obs.gauge(name, pool=self._obs_id).max(v)

    @property
    def stats(self) -> PoolStats:
        """Derived snapshot of this pool's registry metrics (see PoolStats)."""
        out = {}
        for field, (kind, name) in _POOL_METRICS.items():
            m = obs.DEFAULT.find(name, pool=self._obs_id)
            out[field] = int(m.value) if m is not None else 0
        return PoolStats(**out)

    # -- geometry / accounting ------------------------------------------------

    @property
    def slot_bytes(self) -> int:
        return math.prod(self.page_shape) * self.slots.dtype.itemsize

    def n_free_slots(self) -> int:
        return len(self.free_slots)

    def slot_states(self) -> list[str]:
        """Per physical slot: free|raw (compressed pages hold no slot)."""
        out = [FREE] * self.cfg.num_pages
        for p in self.pages.values():
            if p.slot is not None:
                out[p.slot] = RAW
        return out

    def pages_of(self, seq: int) -> list[Page]:
        return [self.pages[i] for i in self.seq_pages.get(seq, [])]

    def raw_bytes_in_use(self) -> int:
        return (self.cfg.num_pages - len(self.free_slots)) * self.slot_bytes

    def compressed_used_bytes(self) -> int:
        """Each distinct container counted once, however many sequences map
        its page (pinned in tests — sharing must not inflate this).
        Blob-backed pages (``cold_entropy``) cost their exact serialized
        length — the entropy stage's ratio win shows up here."""
        return sum(len(p.blob) if p.blob is not None
                   else int(p.comp.used_bytes())
                   for p in self.pages.values()
                   if p.comp is not None or p.blob is not None)

    def compressed_wire_bytes(self) -> int:
        """Capacity-sized footprint if containers sit in fixed-shape arenas
        (a serialized blob IS its own wire form — exact length)."""
        return sum(len(p.blob) if p.blob is not None else p.comp.wire_bytes()
                   for p in self.pages.values()
                   if p.comp is not None or p.blob is not None)

    def used_bytes(self) -> int:
        """Raw slab in use + actual compressed payload bytes (physical —
        shared pages once)."""
        return self.raw_bytes_in_use() + self.compressed_used_bytes()

    def live_demand_bytes(self) -> int:
        """What the live *physical* pages would occupy held fully raw."""
        return len(self.pages) * self.slot_bytes

    def logical_page_refs(self) -> int:
        """Per-sequence page mappings (a page shared by 3 readers counts 3)."""
        return sum(len(pids) for pids in self.seq_pages.values())

    def logical_demand_bytes(self) -> int:
        """What the live page-table mappings would cost raw AND private —
        the no-compression, no-sharing baseline."""
        return self.logical_page_refs() * self.slot_bytes

    def compression_ratio(self) -> float:
        """Effective capacity multiplier: logical demand / physical bytes.
        Honest under sharing because both terms count a shared physical page
        exactly once in the denominator and once per reader in the numerator."""
        return self.logical_demand_bytes() / max(1, self.used_bytes())

    def note_high_water(self) -> None:
        """Sample peaks at allocation/promotion time (the true maxima —
        end-of-step sampling would miss admit-then-park within one step)."""
        self._water("kvpool_high_water_slots",
                    self.cfg.num_pages - len(self.free_slots))
        self._water("kvpool_high_water_bytes", self.used_bytes())
        self._water("kvpool_high_water_demand_bytes", self.live_demand_bytes())
        self._water("kvpool_high_water_logical_bytes",
                    self.logical_demand_bytes())

    # -- error bound ----------------------------------------------------------

    def _ensure_eb(self, sample: jax.Array) -> None:
        if self.eb_abs is None:
            rcfg = fz.FZConfig(eb=self.cfg.eb, eb_mode=self.cfg.eb_mode)
            self.eb_abs = fz.resolve_eb(
                sample.astype(jnp.float32).reshape(-1), rcfg)

    # -- allocator / refcounts ------------------------------------------------

    def _ref(self, pid: int) -> None:
        self.pages[pid].refs += 1

    def _unref(self, pid: int) -> None:
        page = self.pages[pid]
        page.refs -= 1
        if page.refs <= 0:
            if page.slot is not None:
                self.free_slots.append(page.slot)
            del self.pages[pid]

    def alloc_page(self, seq: int, step: int) -> int | None:
        """Allocate (and zero) a fresh raw page for ``seq``; None if no slot."""
        if not self.free_slots:
            return None
        slot = self.free_slots.pop()
        self.slots = _zero_slot(self.slots, slot)
        pid = self._next_page
        self._next_page += 1
        self.pages[pid] = Page(pid, slot=slot, last_write=step)
        self.seq_pages.setdefault(seq, []).append(pid)
        self.seq_len.setdefault(seq, 0)
        self.note_high_water()
        return pid

    def free_seq(self, seq: int) -> None:
        """Drop ``seq``'s mappings; physical pages survive while the radix
        tree (or another reader) still references them."""
        for pid in self.seq_pages.pop(seq, []):
            self._unref(pid)
        self.seq_len.pop(seq, None)

    def _cow_page(self, seq: int, idx: int, step: int) -> bool:
        """Copy-on-write: replace ``seq``'s page at ``idx`` with a private raw
        copy (decompressing a cold donor transiently); the donor keeps its
        other references untouched. False if no free slot."""
        old_pid = self.seq_pages[seq][idx]
        old = self.pages[old_pid]
        if not self.free_slots:
            return False
        slot = self.free_slots.pop()
        if old.slot is not None:
            self.slots = _copy_slot(self.slots, slot, old.slot)
        else:
            self.slots = _set_slot(self.slots, slot, self._decompress(old))
        pid = self._next_page
        self._next_page += 1
        self.pages[pid] = Page(pid, slot=slot, last_write=step)
        self.seq_pages[seq][idx] = pid
        self._unref(old_pid)
        self._count("kvpool_cow_promotions")
        self.note_high_water()
        return True

    # -- prefix sharing -------------------------------------------------------

    def match_prefix(self, tokens) -> PrefixMatch:
        """Longest radix-cached prefix of ``tokens`` (pure; no state change)."""
        if self.radix is None:
            return EMPTY_MATCH
        return self.radix.match(tokens)

    def admit_slot_demand(self, match: PrefixMatch, prompt_len: int) -> int:
        """Physical slots an admission with this match will consume: fresh
        suffix pages, plus the CoW copy of a partially-matched tail, plus
        (copy mode) a private duplicate of every matched page."""
        ps = self.cfg.page_size
        matched = match.matched_tokens
        if matched == 0:
            return max(1, -(-prompt_len // ps))
        need = -(-prompt_len // ps) - len(match.pids)   # fresh suffix pages
        if matched % ps:
            need += 1                                   # CoW of the tail page
        if self.cfg.prefix_mode == "copy":
            need += len(match.pids)
        return need

    def map_prefix(self, seq: int, match: PrefixMatch, step: int) -> bool:
        """Attach a matched prefix to ``seq``: shared mappings (refs++) in
        radix mode, private duplicates in copy mode. Partially-matched tails
        are CoW'd immediately — the suffix prefill writes into them. The
        caller must have reserved ``admit_slot_demand`` slots."""
        assert seq not in self.seq_pages, f"seq {seq} already has pages"
        matched = match.matched_tokens
        if matched == 0:
            return True
        if self.cfg.prefix_mode == "copy":
            datas = self._page_datas([self.pages[p] for p in match.pids])
            pids = []
            for data in datas:
                if not self.free_slots:
                    for p in pids:      # roll back partial allocation
                        self._unref(p)
                    self.seq_pages.pop(seq, None)
                    return False
                slot = self.free_slots.pop()
                self.slots = _set_slot(self.slots, slot, data)
                pid = self._next_page
                self._next_page += 1
                self.pages[pid] = Page(pid, slot=slot, last_write=step)
                pids.append(pid)
            self.seq_pages[seq] = pids
        else:
            for pid in match.pids:
                self._ref(pid)
            self.seq_pages[seq] = list(match.pids)
        self.seq_len[seq] = matched
        self.radix.touch(match, step)
        self._count("kvpool_prefix_hit_pages", len(match.pids))
        self._count("kvpool_prefix_hit_tokens", matched)
        self.note_high_water()
        if matched % self.cfg.page_size and self.cfg.prefix_mode != "copy":
            if not self._cow_page(seq, len(match.pids) - 1, step):
                self.free_seq(seq)
                return False
        return True

    def insert_prompt(self, seq: int, tokens, step: int) -> int:
        """Cache ``seq``'s prompt pages in the radix tree (token runs keyed
        per page; the partial tail run too). Pages already cached by exact
        run are skipped, so each physical page gets at most one node."""
        if self.radix is None:
            return 0
        tokens = [int(t) for t in tokens]
        ps = self.cfg.page_size
        n = -(-len(tokens) // ps)
        runs = [tuple(tokens[i * ps: min((i + 1) * ps, len(tokens))])
                for i in range(n)]
        return self.radix.insert_runs(runs, self.seq_pages[seq][:n], step)

    def release_prefix_cache(self) -> int:
        """Drop every radix-cached page reference (end-of-trace drain)."""
        if self.radix is None:
            return 0
        return self.radix.release_all()

    # -- tiering --------------------------------------------------------------

    def compress_page(self, pid: int) -> None:
        """Raw -> compressed: FZ the page contents, release the slot. Safe on
        shared pages — every reader sees the same container, and writers CoW
        before touching it anyway.

        The slab dtype flows into the container (not the pipeline's internal
        float32), so ``raw_bytes``/``compression_ratio`` stay honest for
        bfloat16 slabs."""
        page = self.pages[pid]
        if page.slot is None:
            return
        with obs.span("kvpool.park", pages=1):
            flat = self.slots[page.slot].reshape(-1)
            self._ensure_eb(flat)
            self._park_store(page, fz.compress_with_eb(flat, self.eb_abs,
                                                       self._fzc))
            self.free_slots.append(page.slot)
            page.slot = None
            self._count("kvpool_compressions")
            self._count("kvpool_compress_dispatches")
            self._sentinel_check(flat, page)

    def compress_pages(self, pids: list[int]) -> None:
        """Batched raw -> compressed: one vmapped FZ dispatch for the whole
        set; bit-identical per page to ``compress_page``. Duplicate,
        already-compressed and freed pids are skipped."""
        pids = [pid for pid in dict.fromkeys(pids)
                if pid in self.pages and self.pages[pid].slot is not None]
        if len(pids) <= 1:
            for pid in pids:
                self.compress_page(pid)
            return
        with obs.span("kvpool.park", pages=len(pids)):
            flats = jnp.stack([self.slots[self.pages[pid].slot].reshape(-1)
                               for pid in pids])
            self._ensure_eb(flats[0])
            batch = fz.compress_batch_with_eb(flats, self.eb_abs, self._fzc)
            for i, pid in enumerate(pids):
                page = self.pages[pid]
                self._park_store(page, jax.tree.map(lambda leaf, i=i: leaf[i],
                                                    batch))
                self.free_slots.append(page.slot)
                page.slot = None
                self._count("kvpool_compressions")
            self._count("kvpool_compress_dispatches")
            self._sentinel_check(flats[0], self.pages[pids[0]])

    def _park_store(self, page: Page, comp: fz.FZCompressed) -> None:
        """Hold a freshly-parked container in the configured cold form:
        the fixed-shape pytree, or (``cold_entropy``) the serialized
        entropy-coded byte container (probe-gated — incompressible pages
        store the plain v1 stream, the header flag routes either way)."""
        if self.cfg.cold_entropy:
            page.blob = fz.to_bytes(comp, self._fzc, entropy="auto",
                                    chunk_bytes=_COLD_CHUNK,
                                    tier="kv_cold_entropy")
            page.comp = None
        else:
            page.comp = comp

    def _container(self, page: Page) -> fz.FZCompressed:
        """A cold page's container, deserializing blob-backed pages at the
        pool's fixed capacities so every cold page — blob or pytree — stacks
        into the same vmapped decode (bit-identical by the from_bytes fill
        contract)."""
        if page.comp is not None:
            return page.comp
        c, _ = fz.from_bytes(page.blob, capacity=self._page_capacity,
                             outlier_capacity=self._page_ocap,
                             tier="kv_cold_entropy")
        return c

    def _sentinel_check(self, flat: jax.Array, page: Page) -> None:
        """Sampled park-time health check: transiently decompress the fresh
        container *from its stored form* (unpacking the entropy blob when the
        cold tier is entropy-coded, via the unmetered path so dispatch
        accounting is not perturbed), verify the error bound, and feed the
        achieved ratio into the tier's drift EWMA. The device sync this costs
        is only paid on sampled parks (first, then every Nth — see
        obs.sentinels.CONFIG)."""
        tier = "kv_cold_entropy" if page.blob is not None else "kv_cold"
        if not sentinels.should_check_eb(tier):
            return
        comp = self._container(page)
        src = flat.astype(jnp.float32)
        rec = fz.decompress_unmetered(comp, self._fzc)
        max_err = float(jnp.max(jnp.abs(src - rec)))
        max_abs = float(jnp.max(jnp.abs(src)))
        sentinels.check_error_bound(tier, max_err, float(self.eb_abs),
                                    max_abs)
        stored = (len(page.blob) if page.blob is not None
                  else float(comp.used_bytes()))
        sentinels.note_ratio(tier, comp.raw_bytes() / max(1.0, stored))

    def promote_page(self, pid: int, step: int) -> bool:
        """Compressed -> raw in place (needed before a write to a *private*
        page); False if no free slot. Shared pages are never promoted in
        place — writers fork via ``_cow_page`` instead."""
        page = self.pages[pid]
        if page.slot is not None:
            return True
        if not self.free_slots:
            return False
        data = self._decompress(page)
        slot = self.free_slots.pop()
        self.slots = _set_slot(self.slots, slot, data)
        page.slot, page.comp, page.blob, page.last_write = slot, None, None, step
        self.note_high_water()
        return True

    def _decompress(self, page: Page) -> jax.Array:
        return self._decompress_many([page])[0]

    def _decompress_many(self, pages: list[Page]) -> list[jax.Array]:
        """Transient cold reads, one vmapped dispatch for the whole set
        (single-page results bit-identical to ``fz.decompress``). The
        reconstruction lands back in the slab dtype the page was built from."""
        if not pages:
            return []
        self._count("kvpool_decompressions", len(pages))
        self._count("kvpool_decompress_dispatches")
        with obs.span("kvpool.cold_read", pages=len(pages)):
            comps = [self._container(p) for p in pages]
            if len(pages) == 1:
                rec = fz.decompress(comps[0], self._fzc)[None]
            else:
                stacked = jax.tree.map(lambda *leaves: jnp.stack(leaves),
                                       *comps)
                rec = fz.decompress_batch(stacked, self._fzc)
            return [rec[i].reshape(self.page_shape).astype(self.slots.dtype)
                    for i in range(len(pages))]

    def _page_datas(self, pages: list[Page]) -> list[jax.Array]:
        """Contents of a mixed raw/cold page list (cold ones in one batched
        transient decode)."""
        cold = [p for p in pages if p.slot is None]
        cold_data = dict(zip((p.page_id for p in cold),
                             self._decompress_many(cold)))
        return [self.slots[p.slot] if p.slot is not None
                else cold_data[p.page_id] for p in pages]

    def page_data(self, pid: int) -> jax.Array:
        """Page contents (2, L, ps, KVH, hd); cold pages decompress transiently."""
        page = self.pages[pid]
        if page.slot is not None:
            return self.slots[page.slot]
        return self._decompress(page)

    # -- writes ---------------------------------------------------------------

    def write_prefill(self, seq: int, k: jax.Array, v: jax.Array, length: int,
                      step: int) -> bool:
        """Ingest a prefill cache (L, 1, Smax, KVH, hd) as raw pages."""
        ps = self.cfg.page_size
        n_pages = max(1, -(-length // ps))
        if length > self.cfg.seq_capacity:
            raise ValueError(f"prompt of {length} tokens exceeds seq_capacity "
                             f"{self.cfg.seq_capacity}")
        if n_pages > len(self.free_slots):
            return False
        self._ensure_eb(k)
        pages = _paginate(k, v, ps, n_pages)
        for j in range(n_pages):
            pid = self.alloc_page(seq, step)
            assert pid is not None
            self.slots = _set_slot(self.slots, self.pages[pid].slot, pages[j])
        self.seq_len[seq] = length
        return True

    def write_suffix(self, seq: int, k: jax.Array, v: jax.Array,
                     suffix_len: int, step: int) -> bool:
        """Ingest a suffix prefill (L, 1, Ssuf_pad, KVH, hd) covering token
        positions [seq_len, seq_len + suffix_len): the tail of the mapped
        prefix fills first (that page was CoW'd private by ``map_prefix``),
        then fresh pages. Slot demand was reserved via ``admit_slot_demand``."""
        ps = self.cfg.page_size
        start = self.seq_len.get(seq, 0)
        end = start + suffix_len
        if end > self.cfg.seq_capacity:
            raise ValueError(f"suffix overruns seq_capacity for seq {seq}")
        need_fresh = -(-end // ps) - len(self.seq_pages.get(seq, []))
        if need_fresh > len(self.free_slots):
            return False
        self._ensure_eb(k)
        kv = jnp.stack([k[:, 0], v[:, 0]])        # (2, L, Ssuf_pad, KVH, hd)
        cursor = 0
        while cursor < suffix_len:
            pos = start + cursor
            idx, off = pos // ps, pos % ps
            n = min(ps - off, suffix_len - cursor)
            if idx >= len(self.seq_pages.get(seq, [])):
                pid = self.alloc_page(seq, step)
                assert pid is not None, "reserved slots exhausted mid-suffix"
            else:
                pid = self.seq_pages[seq][idx]
            page = self.pages[pid]
            assert page.slot is not None and page.refs == 1, \
                "suffix write target must be a private raw page"
            chunk = kv[:, :, cursor:cursor + n]
            self.slots = _write_span(self.slots, page.slot, off, chunk)
            page.last_write = step
            cursor += n
        self.seq_len[seq] = end
        return True

    def append_token(self, seq: int, k_vec: jax.Array, v_vec: jax.Array,
                     step: int) -> bool:
        """Write one decode step's K/V (each (L, KVH, hd)) at the tail.

        A shared tail (refs > 1 — e.g. the radix tree caches it) is CoW'd to
        a private copy first; a private compressed tail is promoted in place.
        The caller must have secured tail capacity (``tail_writable``);
        returns False when it has not.
        """
        ps = self.cfg.page_size
        pos = self.seq_len[seq]
        if pos >= self.cfg.seq_capacity:
            raise ValueError(f"sequence {seq} exceeds seq_capacity")
        if pos % ps == 0:
            if self.alloc_page(seq, step) is None:
                return False
        idx = pos // ps
        pid = self.seq_pages[seq][idx]
        page = self.pages[pid]
        if page.refs > 1:
            if not self._cow_page(seq, idx, step):
                return False
            page = self.pages[self.seq_pages[seq][idx]]
        elif page.slot is None and not self.promote_page(pid, step):
            return False
        self.slots = _set_token(self.slots, page.slot, pos % ps, k_vec, v_vec)
        page.last_write = step
        self.seq_len[seq] = pos + 1
        return True

    def tail_slot_demand(self, seq: int) -> int:
        """Physical slots the next ``append_token`` for ``seq`` will consume:
        1 if it opens a fresh page, CoWs a shared tail, or promotes a
        compressed private tail; else 0."""
        pos = self.seq_len[seq]
        if pos % self.cfg.page_size == 0:       # next write opens a new page
            return 1
        page = self.pages[self.seq_pages[seq][pos // self.cfg.page_size]]
        if page.refs > 1:
            return 1                            # copy-on-write fork
        return 0 if page.slot is not None else 1

    def tail_writable(self, seq: int) -> bool:
        """Can the next ``append_token`` for ``seq`` proceed right now?"""
        return self.tail_slot_demand(seq) <= len(self.free_slots)

    # -- reads ----------------------------------------------------------------

    def _lane_pages(self, lane_seqs: list[int | None]):
        """Stack every lane's pages: (B, P, 2, L, ps, KVH, hd) + (B,) lengths.

        The dedup read path: cold page IDs are deduplicated across ALL lanes
        before the one vmapped transient decode, so a shared cold container
        is decoded at most once per scheduler step and its reconstruction
        fanned out to every reader lane (reading never changes a page's
        tier). Empty lanes are zero-filled at length 0.
        """
        obs.gauge("kvpool_lanes", pool=self._obs_id).set(
            sum(1 for s in lane_seqs if s is not None))
        with obs.span("kvpool.gather", lanes=len(lane_seqs)):
            P = self.cfg.max_pages_per_seq
            lane_pids = [self.seq_pages.get(seq, []) if seq is not None else []
                         for seq in lane_seqs]
            cold_occurrences = [pid for pids in lane_pids for pid in pids
                                if self.pages[pid].slot is None]
            cold = list(dict.fromkeys(cold_occurrences))
            self._count("kvpool_shared_cold_reads_deduped",
                        len(cold_occurrences) - len(cold))
            cold_data = dict(zip(cold, self._decompress_many(
                [self.pages[pid] for pid in cold])))
            lanes = []
            lengths = []
            for seq, pids in zip(lane_seqs, lane_pids):
                tensors = [self.slots[self.pages[pid].slot]
                           if self.pages[pid].slot is not None else cold_data[pid]
                           for pid in pids]
                tensors += [self._zero_page] * (P - len(tensors))
                lanes.append(jnp.stack(tensors))        # (P, 2, L, ps, KVH, hd)
                lengths.append(self.seq_len.get(seq, 0) if seq is not None else 0)
            return jnp.stack(lanes), jnp.asarray(lengths, jnp.int32)

    def gather(self, lane_seqs: list[int | None]):
        """Assemble the fixed-width contiguous decode cache for a set of lanes.

        Returns ``{"k": (L, B, seq_capacity, KVH, hd), "v": ..., "length": (B,)}``
        with empty lanes zero-filled at length 0. This is the reference-decode
        view; the kernel path reads ``gather_pages`` and skips the P*ps merge.
        """
        arr, lengths = self._lane_pages(lane_seqs)      # (B, P, 2, L, ps, KVH, hd)
        B, P, _, L, ps, KVH, hd = arr.shape
        kv = arr.transpose(2, 3, 0, 1, 4, 5, 6).reshape(2, L, B, P * ps, KVH, hd)
        return {"k": kv[0], "v": kv[1], "length": lengths}

    def gather_pages(self, lane_seqs: list[int | None]):
        """Page-native decode view: ``{"k": (L, B, P, ps, KVH, hd), "v": ...,
        "length": (B,)}`` — exactly the tile layout
        ``kernels/flash_decode.decode_partials_pages`` consumes, so decode
        never materializes the contiguous ``seq_capacity``-wide cache."""
        arr, lengths = self._lane_pages(lane_seqs)      # (B, P, 2, L, ps, KVH, hd)
        kv = arr.transpose(2, 3, 0, 1, 4, 5, 6)         # (2, L, B, P, ps, KVH, hd)
        return {"k": kv[0], "v": kv[1], "length": lengths}

    def materialize(self, seq: int):
        """One sequence's cache (L, 1, seq_capacity, KVH, hd) k/v + length."""
        cache = self.gather([seq])
        return cache["k"], cache["v"], self.seq_len[seq]
