"""Tiered compression + preemption policy for the paged KV pool.

Three decisions live here, kept separate from the allocator (pool.py) and the
scheduler loop (scheduler.py) so they can be swapped/tuned independently:

  * ``tier``    — routine cooling: any raw page not written for
                  ``cold_after`` scheduler steps is FZ-compressed, releasing
                  its physical slot. Tail pages of running sequences are
                  protected (they take the next token write; compressing them
                  would just bounce).
  * ``reclaim`` — memory pressure: free at least ``n`` slots *now* by
                  compressing raw pages coldest-first regardless of age
                  (still honouring the protect set). Returns success.
  * ``victim``  — preemption: when reclaim cannot free enough (everything
                  cold is already compressed), the scheduler parks the
                  lowest-priority running sequence; ties break toward the
                  latest arrival so older work finishes first, then toward
                  the highest seq id so equal-priority equal-arrival traces
                  are deterministic run-to-run.

All tie-breaks here are total orders (priority, arrival/write recency, then
a stable id) — trace-driven benchmarks must reproduce exactly.

Parking a sequence (``park``) is compress-park, not drop-and-recompute: every
raw page it holds is compressed in place and its slots returned to the free
list; nothing about the sequence is lost, resume is a page promotion plus
(possibly) a fresh tail allocation.

The policy is agnostic to the parked representation: with
``PoolConfig.cold_entropy`` the pool stores tiered pages as entropy-coded
byte containers (docs/CONTAINER_FORMAT.md) instead of device-resident
pytrees, but tier/reclaim/park all flow through the same
``PagePool.compress_pages`` entry point and promotion is unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable

from .pool import PagePool


@dataclasses.dataclass(frozen=True)
class TieredPolicy:
    cold_after: int = 4

    def tier(self, pool: PagePool, step: int, protect: set[int]) -> int:
        """Compress pages cold for >= cold_after steps; returns count.

        The whole cold set goes down in one batched FZ dispatch
        (``PagePool.compress_pages``), not one dispatch per page."""
        cold = [page.page_id for page in pool.pages.values()
                if (page.slot is not None and page.page_id not in protect
                    and step - page.last_write >= self.cold_after)]
        pool.compress_pages(cold)
        return len(cold)

    def reclaim(self, pool: PagePool, n: int, protect: set[int]) -> bool:
        """Force-free >= n slots by compressing coldest raw pages first.

        Each compression frees exactly one slot, so the shortfall picks how
        many of the coldest candidates go down — in one batched dispatch."""
        need = n - pool.n_free_slots()
        if need <= 0:
            return True
        candidates = sorted(
            (p for p in pool.pages.values()
             if p.slot is not None and p.page_id not in protect),
            key=lambda p: (p.last_write, p.page_id))
        pool.compress_pages([p.page_id for p in candidates[:need]])
        return pool.n_free_slots() >= n

    @staticmethod
    def victim(running: dict[int, tuple[int, int]]) -> int | None:
        """Pick the sequence to preempt: lowest priority, then latest
        arrival, then highest seq id (a total order — equal-priority
        equal-arrival traces preempt deterministically).

        ``running`` maps seq id -> (priority, arrival_step).
        """
        if not running:
            return None
        return min(running, key=lambda s: (running[s][0], -running[s][1], -s))

    @staticmethod
    def park(pool: PagePool, seq: int) -> int:
        """Compress-park: every raw page of ``seq`` tiers down (one batched
        dispatch); returns count."""
        raw = [page.page_id for page in pool.pages_of(seq)
               if page.slot is not None]
        pool.compress_pages(raw)
        return len(raw)

    @staticmethod
    def tail_pages(pool: PagePool, seqs: Iterable[int | None]) -> set[int]:
        """Protect set: the page each running sequence will write next."""
        out = set()
        for seq in seqs:
            if seq is None or seq not in pool.seq_pages:
                continue
            pos = pool.seq_len[seq]
            idx = pos // pool.cfg.page_size
            pids = pool.seq_pages[seq]
            if idx < len(pids):
                out.add(pids[idx])
            elif pids:               # next write opens a new page; protect the
                out.add(pids[-1])    # current tail anyway (freshest data)
        return out
