"""Paged decode attention: flash-decoding partials per page, combined locally.

Reuses ``repro.dist.flash_decode.decode_partials`` — the same per-slice
(running max, exp-sum denominator, weighted-value numerator) math that the
sequence-sharded serving path combines with pmax/psum across a mesh axis —
but combines over the *page* axis on one device. Pages past a sequence's
valid length contribute exactly zero: whenever any page is non-empty the
empty page's renormalization weight ``exp(NEG_INF - m_global)`` underflows
to 0, and when *every* page is empty (a length-0 lane) the weight is
``exp(0) == 1`` but the output is still 0 because num and den are both 0.
That is what lets the pool gather fixed-width page lists with zero padding.

Two execution paths, selected by ``use_kernels`` (mirroring ``FZConfig``):

  * jnp reference — vmap of ``decode_partials`` over the page axis, then a
    max/sum combine; the oracle;
  * Pallas KV-tile kernel (``kernels/flash_decode.decode_partials_pages``) —
    consumes the pool's (B, P, ps, KVH, hd) page layout directly, one page
    per grid step, online-softmax combine fused on-chip (interpret mode on
    CPU, Mosaic on TPU).

``k_new``/``v_new`` (each (B, KVH, D)) fold one just-computed decode token
into the softmax without it ever touching the paged cache — the page-native
engine decode path appends it to the pool *after* attention, so gather never
has to materialize the contiguous ``seq_capacity``-wide cache.

``models.attention.decode_attention`` over the contiguous gathered cache is
the oracle for non-empty lanes; parity for both paths is pinned in
tests/test_kvpool.py (length-0 lanes return 0 here, while the oracle's
unmasked softmax degenerates to a mean — pinned explicitly).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist import flash_decode


def _combine(m_a, num_a, den_a, m_b, num_b, den_b):
    """Merge two online-softmax partial triples (same shapes, elementwise)."""
    m = jnp.maximum(m_a, m_b)
    ca, cb = jnp.exp(m_a - m), jnp.exp(m_b - m)
    return m, num_a * ca[..., None] + num_b * cb[..., None], den_a * ca + den_b * cb


def paged_decode_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                           length: jax.Array, *,
                           k_new: jax.Array | None = None,
                           v_new: jax.Array | None = None,
                           use_kernels: bool = False) -> jax.Array:
    """q: (B, H, D); k_pages/v_pages: (B, P, ps, KVH, D); length: (B,) global
    valid prefix over the concatenated pages. Optional ``k_new``/``v_new``
    (B, KVH, D) are this step's token at position ``length`` (always valid).
    Returns (B, H, D) in q.dtype."""
    B, P, ps, KVH, D = k_pages.shape
    H = q.shape[1]
    G = H // KVH
    if use_kernels:
        from repro.kernels import flash_decode as _fdk  # local: mirror fz._stages
        m, num, den = _fdk.decode_partials_pages(q, k_pages, v_pages, length)
    else:
        offsets = jnp.arange(P, dtype=jnp.int32) * ps

        def per_page(kp, vp, off):       # kp/vp: (B, ps, KVH, D)
            return flash_decode.decode_partials(q, kp, vp, length,
                                                shard_offset=off)

        ms, nums, dens = jax.vmap(per_page, in_axes=(1, 1, 0))(k_pages, v_pages,
                                                               offsets)
        m = jnp.max(ms, axis=0)                     # (B, KVH, G)
        corr = jnp.exp(ms - m)                      # 0 for empty pages (if any
        num = jnp.sum(nums * corr[..., None], axis=0)   # page is non-empty)
        den = jnp.sum(dens * corr, axis=0)
    if k_new is not None:
        qf = q.reshape(B, KVH, G, D).astype(jnp.float32) * D ** -0.5
        m_t = jnp.einsum("bhgd,bhd->bhg", qf, k_new.astype(jnp.float32))
        num_t = jnp.broadcast_to(v_new.astype(jnp.float32)[:, :, None, :],
                                 (B, KVH, G, D))
        m, num, den = _combine(m, num, den, m_t, num_t, jnp.ones_like(m_t))
    out = num / jnp.maximum(den, 1e-30)[..., None]
    return out.reshape(B, H, D).astype(q.dtype)


def pages_from_cache(k_cache: jax.Array, v_cache: jax.Array, page_size: int):
    """Reshape contiguous caches (B, S, KVH, D) into (B, P, ps, KVH, D)."""
    B, S, KVH, D = k_cache.shape
    if S % page_size:
        raise ValueError(f"cache length {S} not a multiple of page_size")
    P = S // page_size
    return (k_cache.reshape(B, P, page_size, KVH, D),
            v_cache.reshape(B, P, page_size, KVH, D))
