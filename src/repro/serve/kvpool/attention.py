"""Paged decode attention: flash-decoding partials per page, combined locally.

Reuses ``repro.dist.flash_decode.decode_partials`` — the same per-slice
(running max, exp-sum denominator, weighted-value numerator) math that the
sequence-sharded serving path combines with pmax/psum across a mesh axis —
but combines over the *page* axis on one device. Pages past a sequence's
valid length contribute exactly zero (their local max is the finite NEG_INF
stand-in, so the renormalization weight underflows to 0), which is what lets
the pool gather fixed-width page lists with zero padding.

``models.attention.decode_attention`` over the contiguous gathered cache is
the oracle; parity is pinned in tests/test_kvpool.py. The engine's decode
path runs the model's own (contiguous) attention on the gathered cache — this
module is the page-native formulation that a future Pallas paged-attention
kernel must match.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist import flash_decode


def paged_decode_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                           length: jax.Array) -> jax.Array:
    """q: (B, H, D); k_pages/v_pages: (B, P, ps, KVH, D); length: (B,) global
    valid prefix over the concatenated pages. Returns (B, H, D) in q.dtype."""
    B, P, ps, KVH, D = k_pages.shape
    offsets = jnp.arange(P, dtype=jnp.int32) * ps

    def per_page(kp, vp, off):       # kp/vp: (B, ps, KVH, D)
        return flash_decode.decode_partials(q, kp, vp, length,
                                            shard_offset=off)

    m, num, den = jax.vmap(per_page, in_axes=(1, 1, 0))(k_pages, v_pages,
                                                        offsets)
    m_global = jnp.max(m, axis=0)                       # (B, KVH, G)
    corr = jnp.exp(m - m_global)                        # 0 for empty pages
    num = jnp.sum(num * corr[..., None], axis=0)
    den = jnp.sum(den * corr, axis=0)
    out = num / jnp.maximum(den, 1e-30)[..., None]
    H = q.shape[1]
    return out.reshape(B, H, D).astype(q.dtype)


def pages_from_cache(k_cache: jax.Array, v_cache: jax.Array, page_size: int):
    """Reshape contiguous caches (B, S, KVH, D) into (B, P, ps, KVH, D)."""
    B, S, KVH, D = k_cache.shape
    if S % page_size:
        raise ValueError(f"cache length {S} not a multiple of page_size")
    P = S // page_size
    return (k_cache.reshape(B, P, page_size, KVH, D),
            v_cache.reshape(B, P, page_size, KVH, D))
