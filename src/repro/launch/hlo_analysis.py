"""Roofline term computation (hardware model + memory summary).

Inputs come from launch/hlo_cost.py (trip-count-aware per-device FLOPs /
dot-adjacent bytes / ring-model collective bytes with pod attribution) and
``compiled.memory_analysis()``.

Hardware model (TPU v5e targets, per brief): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI, 6.25 GB/s/chip cross-pod DCN. HLO shapes in a partitioned
module are per-device, so the terms are per-device seconds:
    compute    = flops_per_device / PEAK_FLOPS
    memory     = bytes_per_device / HBM_BW
    collective = in_pod_bytes / LINK_BW + cross_pod_bytes / DCN_BW
"""
from __future__ import annotations

from typing import Any

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
LINK_BW = 50e9           # bytes/s / link (ICI; 1 link assumed, ~4 available)
DCN_BW = 6.25e9          # bytes/s / chip across pods (50 Gbps effective DCN)

def roofline_terms(flops: float, bytes_accessed: float, coll_bytes: float,
                   cross_pod_bytes: float = 0.0) -> dict:
    compute = flops / PEAK_FLOPS
    memory = bytes_accessed / HBM_BW
    collective = coll_bytes / LINK_BW + cross_pod_bytes / DCN_BW
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": collective,
             "cross_pod_s": cross_pod_bytes / DCN_BW}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    terms["bottleneck"] = dom.replace("_s", "")
    terms["step_time_lower_bound_s"] = bound
    # fraction of roofline achieved if the dominant term were the only cost
    terms["roofline_fraction_compute"] = compute / bound if bound > 0 else 0.0
    return terms


def model_flops(n_params_active: int, tokens: int, kind: str) -> float:
    """6ND for training, 2ND for forward-only (prefill/decode)."""
    if kind == "train":
        return 6.0 * n_params_active * tokens
    return 2.0 * n_params_active * tokens


def memory_summary(mem: Any) -> dict:
    return {
        "argument_bytes": mem.argument_size_in_bytes,
        "output_bytes": mem.output_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
        "alias_bytes": mem.alias_size_in_bytes,
        "peak_device_bytes": (mem.argument_size_in_bytes + mem.output_size_in_bytes
                              + mem.temp_size_in_bytes - mem.alias_size_in_bytes),
    }
