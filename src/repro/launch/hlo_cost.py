"""Trip-count-aware cost model over compiled (post-SPMD) HLO text.

XLA's built-in ``cost_analysis()`` visits every while body ONCE, so any
scan-over-layers / scan-over-chunks program under-reports FLOPs, bytes and
collectives by the trip counts. This module re-derives the three roofline
inputs from the HLO text itself:

  * parse every computation into (op, output shape, operands, attributes);
  * FLOPs: 2 * prod(output dims) * prod(contracted dims) per ``dot``
    (convolutions are not used by this framework's models);
  * bytes: DOT-ADJACENT traffic model — for every ``dot``, operand bytes +
    output bytes (each matmul reads its inputs from and writes its result to
    HBM once). Naive fusion-boundary models fail on scan programs: while
    bodies thread full stacked [L, ...] parameter arrays and loop-carry
    tuples through every iteration, so counting fusion outputs/operands
    overstates traffic by orders of magnitude. Matmuls dominate transformer
    traffic at these shapes; elementwise fusion flows are the same order as
    the dot outputs they consume (documented approximation);
  * collective bytes: ring-model cost per op — all-gather: output bytes
    (each device receives ~the full gathered array); all-reduce: 2x output
    (ring = reduce-scatter + all-gather); reduce-scatter: operand bytes
    (~full input transits each device); all-to-all / collective-permute:
    output bytes. Start/done pairs counted once;
  * call-graph multipliers: while bodies/conditions multiply by the trip
    count recovered from the condition's ``compare(counter, constant)``;
    fusion/call computations inherit the caller's multiplier.

Shapes in a partitioned module are per-device, so all totals are per-device.
Validated against analytic 6*N*D in tests/test_hlo_cost.py.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\s*\{\s*$")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_SHAPE_TOKEN = re.compile(r"(\w+)\[([\d,]*)\]")
_OPERAND = re.compile(r"%([\w.\-]+)")
_ATTR_CALLS = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_ATTR_BODY = re.compile(r"body=%?([\w.\-]+)")
_ATTR_COND = re.compile(r"condition=%?([\w.\-]+)")
_CONSTANT = re.compile(r"constant\((-?\d+)\)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OP_NAME = re.compile(r'op_name="([^"]*)"')


def _shape_dims(shape_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for m in _SHAPE_TOKEN.finditer(shape_str):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((dt, dims))
    return out


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def compiled_memory_traffic(compiled) -> dict:
    """Buffer-assignment HBM-traffic proxy for a compiled executable.

    Model: arguments are read once, outputs written once, and every temp
    (XLA-materialized intermediate) is written once and read once — so
    ``traffic = args + outputs + 2 * temps``. ``traffic_ratio`` normalizes by
    the unavoidable ``args + outputs``; a perfectly fused program scores ~1.0,
    a program that round-trips an input-sized intermediate scores >= ~3.0.
    Used by tests/test_kernels.py to pin the fused-decompress data-movement
    claim and by benchmarks/bench_breakdown.py's measured-traffic column.

    Caveat (documented, load-bearing for how the fused-compress claim is
    pinned): under the Pallas *interpreter* a kernel becomes an XLA loop whose
    carried operands double-buffer the kernel's full outputs, so temp bytes
    overstate a megakernel's real HBM traffic by O(outputs). The compress-side
    pin therefore uses :func:`materialized_shapes` (no code-stream-sized
    buffer exists at all) instead of this byte model.
    """
    ma = compiled.memory_analysis()
    args = int(ma.argument_size_in_bytes)
    out = int(ma.output_size_in_bytes)
    temp = int(ma.temp_size_in_bytes)
    traffic = args + out + 2 * temp
    return {"argument_bytes": args, "output_bytes": out, "temp_bytes": temp,
            "traffic_bytes": traffic,
            "traffic_ratio": traffic / max(args + out, 1)}


def materialized_shapes(hlo_text: str, *, dtype: str = "u16",
                        min_elems: int = 0) -> set[tuple[int, ...]]:
    """Distinct ``dtype`` buffer shapes with >= ``min_elems`` elements in an
    optimized-HLO dump. ``min_elems = padded stream length`` makes this a
    direct mechanical check of the §3.5 fusion claim: a pipeline that
    round-trips the u16 code (or shuffled-word) stream through HBM must
    materialize a u16 buffer of at least that many elements somewhere."""
    out: set[tuple[int, ...]] = set()
    for m in re.finditer(rf"{re.escape(dtype)}\[([\d,]+)\]", hlo_text):
        dims = tuple(int(d) for d in m.group(1).split(",") if d)
        n = 1
        for d in dims:
            n *= d
        if n >= min_elems:
            out.add(dims)
    return out


@dataclasses.dataclass
class Op:
    name: str
    out_shape: str
    opcode: str
    rest: str          # everything after the opening paren (operands + attrs)


@dataclasses.dataclass
class Computation:
    name: str
    ops: list[Op]
    shapes: dict       # op name -> output shape string


def parse_computations(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        h = _COMP_HEADER.match(line.strip()) if line and not line.startswith(" ") else None
        if h and "{" in line:
            cur = Computation(h.group(1), [], {})
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_LINE.match(line)
        if not m:
            continue
        name, shape, opcode, rest = m.groups()
        cur.ops.append(Op(name, shape, opcode, rest))
        cur.shapes[name] = shape
    return comps


def _operand_names(rest: str) -> list[str]:
    """Operand refs before the attribute section (first ')' closes the args)."""
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return _OPERAND.findall(rest[:i])
    return _OPERAND.findall(rest)


def _dot_flops(op: Op, comp: Computation) -> float:
    out_elems = 1
    for _, dims in _shape_dims(op.out_shape):
        for d in dims:
            out_elems *= d
    names = _operand_names(op.rest)
    if not names:
        return 0.0
    lhs_shape = comp.shapes.get(names[0], "")
    lhs_dims_list = _shape_dims(lhs_shape)
    if not lhs_dims_list:
        return 0.0
    lhs_dims = lhs_dims_list[0][1]
    mc = _CONTRACT.search(op.rest)
    k = 1
    if mc:
        for idx in mc.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                k *= lhs_dims[int(idx)]
    return 2.0 * out_elems * k


def _is_promoted_16bit(comp: Computation, ar_name: str) -> bool:
    """True if the all-reduce ``ar_name`` is a 16-bit reduction promoted to
    f32 by XLA-CPU's AllReducePromotion pass (on TPU it would run at 16-bit).

    Signature: its value is converted straight back to a 16-bit type — either
    a direct consumer, or (tuple ARs) a consumer of a get-tuple-element of it.
    The pre-convert is often absorbed into the producing dot, so we look
    downstream, not upstream.
    """
    layer1 = {ar_name}
    # include get-tuple-element wrappers
    for op in comp.ops:
        if op.opcode == "get-tuple-element" and ar_name in _operand_names(op.rest):
            layer1.add(op.name)
    for op in comp.ops:
        if not op.out_shape.lstrip("(").startswith(("bf16", "f16", "u16", "s16")):
            continue
        if op.opcode in ("convert", "fusion", "copy") and \
                any(nm in layer1 for nm in _operand_names(op.rest)):
            return True
    return False


_RG_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
_RG_LIST = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def crosses_pod(rest: str, devices_per_pod: int) -> bool:
    """True if this collective's replica groups span a pod boundary.

    Handles both the explicit ``{{0,256},...}`` and the iota
    ``[G,S]<=[dims]T(perm)`` forms (decode the iota, reshape to groups, and
    check whether any group mixes device-id // devices_per_pod)."""
    m = _RG_IOTA.search(rest)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(d) for d in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            ids = ids.transpose([int(p) for p in m.group(4).split(",")])
        groups = ids.reshape(g, s)
        pods = groups // devices_per_pod
        return bool((pods != pods[:, :1]).any())
    m = _RG_LIST.search(rest)
    if m:
        ids = np.array([int(d) for d in m.group(1).split(",")])
        return bool((ids // devices_per_pod != ids[0] // devices_per_pod).any())
    return False


def _trip_count(cond: Computation) -> int:
    """Recover scan trip count from compare(counter, constant) in the cond."""
    consts: dict[str, int] = {}
    for op in cond.ops:
        mc = _CONSTANT.search(op.opcode + "(" + op.rest)
        if op.opcode == "constant":
            m2 = re.search(r"constant\((-?\d+)\)", "constant(" + op.rest)
            if m2:
                consts[op.name] = int(m2.group(1))
    for op in cond.ops:
        if op.opcode == "compare" and "direction=LT" in op.rest:
            for nm in _operand_names(op.rest):
                if nm in consts and consts[nm] > 0:
                    return consts[nm]
    # fallback: any positive s32 constant
    pos = [v for v in consts.values() if v > 0]
    return max(pos) if pos else 1


def analyze(text: str, devices_per_pod: int | None = None,
            tag_pattern: str | None = None) -> dict:
    """``devices_per_pod``: when set (multi-pod mesh), collectives whose
    replica groups span pods are accounted separately as cross-pod bytes
    (they ride DCN, not ICI — see hlo_analysis.roofline_terms).

    ``tag_pattern``: optional regex run over each cross-pod collective's
    ``op_name`` metadata (which carries the jax ``named_scope`` stack
    through compilation). Matching ops are additionally grouped under
    ``cross_pod_by_tag[tag][collective]`` — this is how per-bucket wire
    bytes of the bucketed compressed reduce are attributed and verified
    against the analytic container model
    (``dist.bucketed_reduce.expected_cross_pod_bytes``, tag pattern
    ``dist.bucketed_reduce.BUCKET_TAG_PATTERN``)."""
    comps = parse_computations(text)
    tag_re = re.compile(tag_pattern) if tag_pattern else None

    entry = None
    for name, c in comps.items():
        if re.match(r"main", name) or name.startswith("main"):
            entry = name
    if entry is None:  # ENTRY computation name fallback: the last one
        entry = list(comps)[-1] if comps else None
    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, "collective_bytes": 0.0}

    # which computations are fusion-internal (compute-only, no byte traffic)
    fusion_called: set[str] = set()
    for c in comps.values():
        for op in c.ops:
            if op.opcode == "fusion":
                m = _ATTR_CALLS.search(op.rest)
                if m:
                    fusion_called.add(m.group(1))

    # static weighted call edges: caller -> [(callee, weight)]
    edges: dict[str, list[tuple[str, float]]] = defaultdict(list)
    for cname, c in comps.items():
        for op in c.ops:
            if op.opcode == "while":
                mb = _ATTR_BODY.search(op.rest)
                mc = _ATTR_COND.search(op.rest)
                trips = 1
                if mc and mc.group(1) in comps:
                    trips = _trip_count(comps[mc.group(1)])
                if mb:
                    edges[cname].append((mb.group(1), float(trips)))
                if mc:
                    edges[cname].append((mc.group(1), float(trips + 1)))
            else:
                for attr in (_ATTR_CALLS, _ATTR_BODY, _ATTR_COND):
                    m2 = attr.search(op.rest)
                    if m2 and m2.group(1) in comps:
                        edges[cname].append((m2.group(1), 1.0))

    # topological accumulation (HLO call graphs are DAGs)
    order: list[str] = []
    state: dict[str, int] = {}

    def dfs(n: str):
        if state.get(n) == 2:
            return
        state[n] = 1
        for child, _ in edges.get(n, []):
            if state.get(child) != 1:
                dfs(child)
        state[n] = 2
        order.append(n)

    dfs(entry)
    mult: dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    for n in reversed(order):
        for child, w in edges.get(n, []):
            mult[child] += mult[n] * w

    flops = 0.0
    bytes_ = 0.0
    coll_bytes = 0.0
    cross_pod_bytes = 0.0
    coll_detail: dict[str, float] = defaultdict(float)
    cross_pod_by_tag: dict[str, dict[str, float]] = defaultdict(
        lambda: defaultdict(float))
    for cname, c in comps.items():
        m_here = mult.get(cname, 0.0)
        if m_here == 0.0:
            continue
        for op in c.ops:
            if op.opcode == "dot":
                flops += m_here * _dot_flops(op, c)
                opnd = sum(_shape_bytes(c.shapes.get(nm, ""))
                           for nm in _operand_names(op.rest))
                bytes_ += m_here * (opnd + _shape_bytes(op.out_shape))
            base = op.opcode[:-6] if op.opcode.endswith("-start") else op.opcode
            if base in COLLECTIVES:
                if base == "all-reduce":        # ring: RS + AG
                    b = 2 * _shape_bytes(op.out_shape)
                elif base == "reduce-scatter":  # ~full input transits
                    b = sum(_shape_bytes(c.shapes.get(nm, ""))
                            for nm in _operand_names(op.rest))
                else:
                    b = _shape_bytes(op.out_shape)
                # XLA-CPU's AllReducePromotion rewrites 16-bit all-reduces to
                # convert->f32-all-reduce->convert; on TPU they stay 16-bit.
                # See through the promotion (detected via the convert-back
                # consumer) and cost the op at half width.
                if base in ("all-reduce", "reduce-scatter") and \
                        _is_promoted_16bit(c, op.name):
                    b //= 2
                if devices_per_pod and crosses_pod(op.rest, devices_per_pod):
                    cross_pod_bytes += m_here * b
                    coll_detail[base + "@pod"] += m_here * b
                    if tag_re is not None:
                        mo = _OP_NAME.search(op.rest)
                        mt = tag_re.search(mo.group(1)) if mo else None
                        if mt:
                            tag = mt.group(1) if mt.groups() else mt.group(0)
                            cross_pod_by_tag[tag][base] += m_here * b
                else:
                    coll_bytes += m_here * b
                    coll_detail[base] += m_here * b
    return {
        "flops": flops,
        "bytes": bytes_,
        "collective_bytes": coll_bytes,
        "cross_pod_bytes": cross_pod_bytes,
        "collective_detail": dict(coll_detail),
        "cross_pod_by_tag": {t: dict(d) for t, d in cross_pod_by_tag.items()},
        "n_computations": len(comps),
    }
