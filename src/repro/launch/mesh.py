"""Mesh construction (production + local).

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state; the dry-run sets
XLA_FLAGS before first jax init and only then calls it.

Axis roles:
  * pod   — inter-pod (DCN, slow links): batch parallelism + the compressed
            gradient all-reduce hop (dist/compressed_allreduce.py);
  * data  — in-pod FSDP axis: parameter/optimizer sharding + batch;
  * model — tensor parallel: heads / ffn / vocab / experts / KV-sequence.
"""
from __future__ import annotations

import jax

from repro.dist import compat


def _mk(shape, axes):
    # all axes auto-partitioned; compat owns the jax-version split
    return compat.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Elastic entry point: any (pod, data, model) / (data, model) layout."""
    return _mk(shape, axes)


def make_local_mesh(model_parallel: int = 1, pods: int = 1):
    """Mesh over whatever devices exist (tests / CPU examples)."""
    n = jax.device_count()
    data = n // (model_parallel * pods)
    assert data * model_parallel * pods == n, (n, pods, data, model_parallel)
    if pods > 1:
        return _mk((pods, data, model_parallel), ("pod", "data", "model"))
    return _mk((data, model_parallel), ("data", "model"))
