"""Training launcher: --arch <id> --shape <name> on any mesh.

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \
        --steps 20 --ckpt-dir /tmp/run1

On a real fleet this process runs per host under the cluster scheduler
(jax.distributed.initialize picks up the coordinator from the environment);
on this box it drives the local mesh.

``--overlap-reduce`` turns on the overlapped bucketed compressed-gradient
reduce (dist/bucketed_reduce.py): it implies ``--compressed-grads``, routes
the step through per-bucket compress/all_gather/decompress hops issued in
backward production order, and exports the XLA latency-hiding-scheduler
flags below (TPU compute/communication overlap; harmless on CPU) so the
async all-gathers can actually hide inside the remaining backward compute.
Off, the legacy end-of-step barrier reduce runs unchanged.
"""
from __future__ import annotations

import argparse
import os

# Latency-hiding-scheduler flags exported by --overlap-reduce (must land in
# the environment before jax/libtpu initialize, hence before the imports in
# main()). Async collective fusion lets the per-bucket all-gather-start /
# -done pairs split around independent backward compute.
OVERLAP_XLA_FLAGS = (
    "--xla_tpu_enable_async_collective_fusion=true "
    "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true "
    "--xla_enable_async_all_gather=true"
)


def enable_overlap_scheduler_flags() -> None:
    """Append the latency-hiding flags to LIBTPU_INIT_ARGS.

    Idempotent by flag NAME: a flag the operator already set — to either
    value, e.g. ``--xla_enable_async_all_gather=false`` to work around a
    scheduler bug — is left alone rather than overridden with a conflicting
    duplicate.
    """
    cur = os.environ.get("LIBTPU_INIT_ARGS", "")
    missing = [f for f in OVERLAP_XLA_FLAGS.split()
               if f.split("=", 1)[0] not in cur]
    if missing:
        os.environ["LIBTPU_INIT_ARGS"] = " ".join([cur, *missing]).strip()


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--shape", default=None, help="assigned shape name (defaults to a local shape)")
    p.add_argument("--smoke", action="store_true", help="reduced config")
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--model-parallel", type=int, default=1)
    p.add_argument("--pods", type=int, default=1)
    p.add_argument("--compressed-grads", action="store_true")
    p.add_argument("--overlap-reduce", action="store_true",
                   help="bucketed overlapped compressed reduce + latency-hiding "
                        "scheduler flags (implies --compressed-grads)")
    p.add_argument("--bucket-bytes", type=int, default=4 << 20,
                   help="wire-byte target per reduce bucket (--overlap-reduce)")
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-codec", choices=["raw", "fz"], default="raw")
    # telemetry flags duplicated from repro.obs.cli.add_args: importing
    # repro.obs pulls in jax, which must wait for the env setup below
    p.add_argument("--trace-out", default=None, metavar="PATH")
    p.add_argument("--metrics-out", default=None, metavar="PATH")
    p.add_argument("--profile-dir", default=None, metavar="DIR")
    args = p.parse_args()

    if args.overlap_reduce:
        enable_overlap_scheduler_flags()   # before jax initializes below

    from repro import configs
    from repro.configs.base import SHAPES, ShapeConfig
    from repro.obs import cli as obs_cli
    from repro.data.tokens import TokenStream
    from repro.dist.compressed_allreduce import GradCompressionConfig
    from repro.launch.mesh import make_local_mesh
    from repro.models import zoo
    from repro.train import TrainConfig, Trainer

    cfg = configs.get(args.arch, smoke=args.smoke)
    model = zoo.build(cfg)
    if args.shape:
        shape = SHAPES[args.shape]
    else:
        shape = ShapeConfig("local", args.seq, args.batch, "train")
    mesh = make_local_mesh(model_parallel=args.model_parallel, pods=args.pods)
    tcfg = TrainConfig(
        microbatches=args.microbatches, total_steps=args.steps,
        warmup_steps=max(args.steps // 10, 1),
        grad_compress=GradCompressionConfig(
            enabled=args.compressed_grads or args.overlap_reduce,
            overlap=args.overlap_reduce, bucket_bytes=args.bucket_bytes))
    stream = TokenStream(vocab_size=cfg.vocab, seq_len=shape.seq_len,
                         global_batch=shape.global_batch, seed=0)
    trainer = Trainer(model, shape, mesh, tcfg, stream=stream,
                      ckpt_dir=args.ckpt_dir, ckpt_codec=args.ckpt_codec)
    reduce_mode = ("bucketed-overlap" if args.overlap_reduce else
                   "barrier" if tcfg.grad_compress.enabled else "exact")
    print(f"{cfg.arch_id}: {model.param_count()/1e6:.1f}M params, "
          f"mesh={dict(mesh.shape)}, reduce={reduce_mode}, "
          f"resume_step={trainer.step}")
    obs_cli.start(args)
    hist = trainer.run(args.steps - trainer.step)
    for m in hist[:: max(len(hist) // 10, 1)]:
        print(f"step {m['step']:5d} loss {m['loss']:.4f} ({m['seconds']:.2f}s)")
    obs_cli.finish(args, metadata={"arch": cfg.arch_id, "mode": "train",
                                   "reduce": reduce_mode})


if __name__ == "__main__":
    main()
