"""Training launcher: --arch <id> --shape <name> on any mesh.

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \
        --steps 20 --ckpt-dir /tmp/run1

On a real fleet this process runs per host under the cluster scheduler
(jax.distributed.initialize picks up the coordinator from the environment);
on this box it drives the local mesh. XLA latency-hiding-scheduler flags for
compute/communication overlap on TPU (documented here, harmless on CPU):

    LIBTPU_INIT_ARGS="--xla_tpu_enable_async_collective_fusion=true
        --xla_tpu_enable_async_collective_fusion_fuse_all_gather=true
        --xla_enable_async_all_gather=true"
"""
from __future__ import annotations

import argparse


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--shape", default=None, help="assigned shape name (defaults to a local shape)")
    p.add_argument("--smoke", action="store_true", help="reduced config")
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--model-parallel", type=int, default=1)
    p.add_argument("--pods", type=int, default=1)
    p.add_argument("--compressed-grads", action="store_true")
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-codec", choices=["raw", "fz"], default="raw")
    args = p.parse_args()

    from repro import configs
    from repro.configs.base import SHAPES, ShapeConfig
    from repro.data.tokens import TokenStream
    from repro.dist.compressed_allreduce import GradCompressionConfig
    from repro.launch.mesh import make_local_mesh
    from repro.models import zoo
    from repro.train import TrainConfig, Trainer

    cfg = configs.get(args.arch, smoke=args.smoke)
    model = zoo.build(cfg)
    if args.shape:
        shape = SHAPES[args.shape]
    else:
        shape = ShapeConfig("local", args.seq, args.batch, "train")
    mesh = make_local_mesh(model_parallel=args.model_parallel, pods=args.pods)
    tcfg = TrainConfig(
        microbatches=args.microbatches, total_steps=args.steps,
        warmup_steps=max(args.steps // 10, 1),
        grad_compress=GradCompressionConfig(enabled=args.compressed_grads))
    stream = TokenStream(vocab_size=cfg.vocab, seq_len=shape.seq_len,
                         global_batch=shape.global_batch, seed=0)
    trainer = Trainer(model, shape, mesh, tcfg, stream=stream,
                      ckpt_dir=args.ckpt_dir, ckpt_codec=args.ckpt_codec)
    print(f"{cfg.arch_id}: {model.param_count()/1e6:.1f}M params, "
          f"mesh={dict(mesh.shape)}, resume_step={trainer.step}")
    hist = trainer.run(args.steps - trainer.step)
    for m in hist[:: max(len(hist) // 10, 1)]:
        print(f"step {m['step']:5d} loss {m['loss']:.4f} ({m['seconds']:.2f}s)")


if __name__ == "__main__":
    main()
