import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any jax import (device count locks at
first init), which is why this module must never be imported by tests or the
library — it is a CLI entry point only:

    python -m repro.launch.dryrun --arch yi-6b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all --out results/dryrun   # every cell

Per cell it:
  1. builds the production mesh ((16,16) single-pod / (2,16,16) multi-pod);
  2. builds the step fn (train_step / prefill / decode per the shape kind)
     with full FSDPxTP shardings and abstract (ShapeDtypeStruct) inputs;
  3. ``.lower().compile()`` at FULL depth — the pass/fail gate; records
     ``memory_analysis()`` (per-device fit) and raw ``cost_analysis()``;
  4. runs the trip-count-aware HLO cost model (launch/hlo_cost.py) over the
     compiled text — XLA's own cost analysis counts while bodies once, so
     scan-over-layers/chunks programs need the corrected walk — giving
     per-device FLOPs / fusion-boundary bytes / collective bytes;
  5. writes one JSON per cell under --out (resumable: existing files skip).
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback


def _build(arch: str, shape_name: str, multi_pod: bool, *, depth_override=None,
           compressed_grads: bool = False, microbatches: int = 1,
           opt: str = "none"):
    import jax
    from repro.models import attention as _attn
    from repro.models import nn as _nn
    from repro.dist import sharding as _shd
    _nn.set_bf16_matmul_output("bf16" in opt)
    _shd.set_profile("zero3" if "zero3" in opt else "tp")
    _attn.set_causal_skip("cskip" in opt)
    from repro import configs
    from repro.configs.base import SHAPES
    from repro.dist.compressed_allreduce import GradCompressionConfig
    from repro.launch.mesh import make_production_mesh
    from repro.models import zoo
    from repro.optim import adamw_init
    from repro.train.step import TrainConfig, build_decode_step, build_prefill_step, build_train_step

    cfg = configs.get(arch)
    if depth_override is not None:
        if cfg.shared_attn_every:   # zamba2: depth knob = superblock count
            depth_override = depth_override * cfg.shared_attn_every
        cfg = dataclasses.replace(cfg, n_layers=depth_override)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = zoo.build(cfg)

    if shape.kind == "train":
        tcfg = TrainConfig(
            microbatches=microbatches,
            grad_compress=GradCompressionConfig(enabled=compressed_grads))
        step, info = build_train_step(model, shape, mesh, tcfg)
        params_abs = model.abstract_params()
        opt_abs = jax.eval_shape(adamw_init, params_abs)
        err_abs = jax.eval_shape(info["make_err_state"], params_abs)
        args = (params_abs, opt_abs, err_abs,
                jax.ShapeDtypeStruct((), jax.numpy.int32), info["input_structs"])
    elif shape.kind == "prefill":
        step, info = build_prefill_step(model, shape, mesh)
        args = (model.abstract_params(), info["input_structs"])
    else:  # decode
        step, info = build_decode_step(model, shape, mesh)
        args = (model.abstract_params(), info["cache_structs"], info["input_structs"])
    return model, mesh, step, args


def run_cell(arch: str, shape_name: str, mesh_name: str, *,
             compressed_grads: bool = False, microbatches: int = 1,
             opt: str = "none") -> dict:
    from repro import configs
    from repro.configs.base import SHAPES
    from repro.launch import hlo_analysis as ha
    from repro.launch import hlo_cost

    multi_pod = mesh_name == "multi"
    t0 = time.time()
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "compressed_grads": compressed_grads, "microbatches": microbatches,
              "opt": opt}
    shape = SHAPES[shape_name]

    # --- full-depth compile: the pass/fail gate + memory + cost model
    model, mesh, step, args = _build(arch, shape_name, multi_pod,
                                     compressed_grads=compressed_grads,
                                     microbatches=microbatches, opt=opt)
    lowered = step.lower(*args)
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    text = compiled.as_text()
    dpp = 256 if multi_pod else None   # devices per pod on the (2,16,16) mesh
    parsed = hlo_cost.analyze(text, devices_per_pod=dpp)
    result["full"] = {
        "memory": ha.memory_summary(mem),
        "flops_raw_xla": cost.get("flops", 0.0),
        "bytes_raw_xla": cost.get("bytes accessed", 0.0),
        "hlo_text_bytes": len(text),
    }
    n_dev = 1
    for v in mesh.shape.values():
        n_dev *= v
    result["devices"] = n_dev
    result["param_count"] = model.param_count()
    result["active_param_count"] = model.active_param_count()
    result["cost_model"] = parsed

    flops = parsed["flops"]
    bytes_ = parsed["bytes"]
    coll = parsed["collective_bytes"]
    terms = ha.roofline_terms(flops, bytes_, coll, parsed.get("cross_pod_bytes", 0.0))
    tokens = shape.tokens if shape.kind != "decode" else shape.global_batch
    mf = ha.model_flops(result["active_param_count"], tokens, shape.kind)
    terms["model_flops"] = mf
    terms["useful_flops_ratio"] = mf / max(flops * n_dev, 1.0)
    result["roofline"] = terms
    result["elapsed_s"] = time.time() - t0
    return result


def main() -> None:
    from repro import configs
    from repro.configs.base import cells_for

    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=list(configs.ARCH_IDS))
    p.add_argument("--shape")
    p.add_argument("--mesh", choices=["single", "multi"], default="single")
    p.add_argument("--out", default=None, help="JSON output path (or dir with --all)")
    p.add_argument("--compressed-grads", action="store_true")
    p.add_argument("--opt", default="none",  # comma list: bf16,zero3,cskip
                   help="beyond-paper perf variant (see EXPERIMENTS.md §Perf)")
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--all", action="store_true", help="run every assigned cell")
    args = p.parse_args()

    if args.all:
        out_dir = args.out or "results/dryrun"
        os.makedirs(out_dir, exist_ok=True)
        failures = []
        for arch in configs.ARCH_IDS:
            for shape_name in cells_for(configs.get(arch)):
                for mesh_name in ("single", "multi"):
                    tag = f"{arch}_{shape_name}_{mesh_name}"
                    path = os.path.join(out_dir, tag + ".json")
                    if os.path.exists(path):
                        print(f"[skip] {tag}", flush=True)
                        continue
                    try:
                        r = run_cell(arch, shape_name, mesh_name)
                        with open(path, "w") as f:
                            json.dump(r, f, indent=1)
                        print(f"[ok]   {tag} ({r['elapsed_s']:.0f}s) "
                              f"bottleneck={r['roofline']['bottleneck']}", flush=True)
                    except Exception as e:
                        failures.append((tag, repr(e)))
                        print(f"[FAIL] {tag}: {e}", flush=True)
                        traceback.print_exc()
        if failures:
            sys.exit(1)
        return

    r = run_cell(args.arch, args.shape, args.mesh,
                 compressed_grads=args.compressed_grads,
                 microbatches=args.microbatches, opt=args.opt)
    mem = r.get("full", {}).get("memory")
    if mem:
        print("memory_analysis:", json.dumps(mem, indent=1))
        print("cost_analysis flops (raw xla, once-per-while-body):", r["full"]["flops_raw_xla"])
    print("cost_model:", json.dumps({k: v for k, v in r["cost_model"].items()
                                     if k != "collective_detail"}, indent=1))
    print("roofline:", json.dumps(r["roofline"], indent=1))
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(r, f, indent=1)


if __name__ == "__main__":
    main()
