"""Serving launcher: batched prefill + greedy decode, optional FZ KV parking.

    PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --smoke \
        --prompt-len 128 --tokens 16 --kv-compress
"""
from __future__ import annotations

import argparse


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--batch", type=int, default=2)
    p.add_argument("--prompt-len", type=int, default=128)
    p.add_argument("--tokens", type=int, default=16)
    p.add_argument("--kv-compress", action="store_true")
    p.add_argument("--kv-eb", type=float, default=1e-4)
    args = p.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro import configs
    from repro.models import zoo
    from repro.serve import Engine, KVCompressionConfig
    from repro.serve.engine import cache_bytes, compressed_cache_bytes

    cfg = configs.get(args.arch, smoke=args.smoke)
    model = zoo.build(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len), dtype=np.int32))}
    if cfg.mrope_sections is not None:
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(args.prompt_len, dtype=jnp.int32), (args.batch, 3, args.prompt_len))
    if cfg.family == "audio":
        batch["audio_embeds"] = jnp.zeros(
            (args.batch, cfg.n_audio_ctx, cfg.d_model), jnp.bfloat16)

    eng = Engine(model, params, kv_compress=KVCompressionConfig(
        enabled=args.kv_compress, eb=args.kv_eb))
    toks, cache = eng.generate(batch, args.tokens,
                               park_between=args.kv_compress)
    print(f"{cfg.arch_id}: generated {toks.shape} tokens")
    print("first sequence:", np.asarray(toks[0]))
    if args.kv_compress:
        parked = eng.park(cache)
        print(f"KV parked: {cache_bytes(cache)/1e6:.1f} MB -> "
              f"{compressed_cache_bytes(parked)/1e6:.1f} MB")


if __name__ == "__main__":
    main()
