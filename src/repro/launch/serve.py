"""Serving launcher: batched prefill + greedy decode, optional FZ KV parking
or the paged FZ KV pool with continuous batching.

    PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --smoke \
        --prompt-len 128 --tokens 16 --kv-compress
    PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --smoke \
        --prompt-len 64 --tokens 16 --paged --pool-pages 8 --page-size 16
"""
from __future__ import annotations

import argparse


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--batch", type=int, default=2)
    p.add_argument("--prompt-len", type=int, default=128)
    p.add_argument("--tokens", type=int, default=16)
    p.add_argument("--kv-compress", action="store_true")
    p.add_argument("--kv-eb", type=float, default=1e-4)
    p.add_argument("--paged", action="store_true",
                   help="serve through the paged KV pool (repro.serve.kvpool)")
    p.add_argument("--pool-pages", type=int, default=8)
    p.add_argument("--page-size", type=int, default=16)
    p.add_argument("--cold-after", type=int, default=2)
    from repro.obs import cli as obs_cli
    obs_cli.add_args(p)
    args = p.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro import configs
    from repro.models import zoo
    from repro.serve import Engine, KVCompressionConfig, PoolConfig, Request
    from repro.serve.engine import cache_bytes, compressed_cache_bytes

    cfg = configs.get(args.arch, smoke=args.smoke)
    model = zoo.build(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    obs_cli.start(args)

    if args.paged:
        cap = args.page_size * -(-(args.prompt_len + args.tokens + 1)
                                 // args.page_size)
        pool_cfg = PoolConfig(num_pages=args.pool_pages,
                              page_size=args.page_size,
                              seq_capacity=cap, cold_after=args.cold_after,
                              eb=args.kv_eb)
        eng = Engine(model, params, pool=pool_cfg)
        reqs = [Request(req_id=i,
                        tokens=rng.integers(0, cfg.vocab, (args.prompt_len,),
                                            dtype=np.int32),
                        n_new=args.tokens, priority=i % 2)
                for i in range(args.batch)]
        outputs, stats, pool = eng.serve(reqs, max_batch=min(args.batch, 4))
        print(f"{cfg.arch_id}: {stats.completed} requests in "
              f"{stats.decode_steps} decode steps "
              f"({stats.preemptions} preempt / {stats.resumes} resume / "
              f"{stats.tiered_pages} tiered)")
        print(f"pool high-water {stats.high_water_used_bytes / 1e6:.2f} MB vs "
              f"{stats.high_water_demand_bytes / 1e6:.2f} MB raw demand")
        print("first sequence:", outputs[0])
        obs_cli.finish(args, metadata={"arch": cfg.arch_id, "mode": "serve-paged"})
        return

    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len), dtype=np.int32))}
    if cfg.mrope_sections is not None:
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(args.prompt_len, dtype=jnp.int32), (args.batch, 3, args.prompt_len))
    if cfg.family == "audio":
        batch["audio_embeds"] = jnp.zeros(
            (args.batch, cfg.n_audio_ctx, cfg.d_model), jnp.bfloat16)

    eng = Engine(model, params, kv_compress=KVCompressionConfig(
        enabled=args.kv_compress, eb=args.kv_eb))
    toks, cache = eng.generate(batch, args.tokens,
                               park_between=args.kv_compress)
    print(f"{cfg.arch_id}: generated {toks.shape} tokens")
    print("first sequence:", np.asarray(toks[0]))
    if args.kv_compress:
        parked = eng.park(cache)
        print(f"KV parked: {cache_bytes(cache)/1e6:.1f} MB -> "
              f"{compressed_cache_bytes(parked)/1e6:.1f} MB")
    obs_cli.finish(args, metadata={"arch": cfg.arch_id, "mode": "serve"})


if __name__ == "__main__":
    main()
