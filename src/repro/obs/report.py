"""StepReport: join span timings with HLO byte attribution.

The question the ROADMAP's hardware items keep asking — "did the per-bucket
all-gather actually hide under backward?" — needs two datasets side by side:
wall-clock per named region (the span histograms) and bytes moved per named
region (``launch.hlo_cost.analyze``'s ``cross_pod_by_tag``, which keys
cross-pod collective bytes on the same ``named_scope`` names the spans
install). :func:`step_report` performs that join: one row per span name with
call count, p50/p99/max milliseconds, total time, and — where a byte tag
matches the span name (exact, or the tag appearing in the span's dotted
name) — the attributed bytes and the implied effective bandwidth.

Rendered with :meth:`StepReport.render` as an aligned text table, or shipped
machine-readable via :meth:`StepReport.to_dict` (this is what
``--metrics-out`` embeds next to the raw registry snapshot).
"""
from __future__ import annotations

import dataclasses
import re

from . import registry as _reg

_SPAN_KEY = re.compile(r"^span_ms\{span=(.+)\}$")


@dataclasses.dataclass
class StepReport:
    rows: list[dict]
    meta: dict

    def to_dict(self) -> dict:
        return {"rows": self.rows, "meta": self.meta}

    def render(self) -> str:
        if not self.rows:
            return "(no spans recorded)"
        hdr = f"{'span':40s} {'calls':>7s} {'p50ms':>9s} {'p99ms':>9s} " \
              f"{'maxms':>9s} {'totalms':>10s} {'bytes':>12s} {'GB/s':>7s}"
        lines = [hdr, "-" * len(hdr)]
        for r in self.rows:
            by = f"{r['bytes']:.3e}" if r.get("bytes") else ""
            bw = f"{r['gbps']:.2f}" if r.get("gbps") else ""
            lines.append(
                f"{r['span'][:40]:40s} {r['calls']:7d} {r['p50_ms']:9.3f} "
                f"{r['p99_ms']:9.3f} {r['max_ms']:9.3f} {r['total_ms']:10.1f} "
                f"{by:>12s} {bw:>7s}")
        return "\n".join(lines)


def _find_bytes(span_name: str, bytes_by_tag: dict) -> float | None:
    if span_name in bytes_by_tag:
        v = bytes_by_tag[span_name]
    else:
        hits = [v for t, v in bytes_by_tag.items() if t in span_name]
        if not hits:
            return None
        v = sum(hits)
    if isinstance(v, dict):          # hlo_cost cross_pod_by_tag leaf form
        v = sum(v.values())
    return float(v)


def step_report(registry: _reg.Registry | None = None,
                bytes_by_tag: dict | None = None,
                meta: dict | None = None) -> StepReport:
    """Build the per-span table from a registry snapshot.

    ``bytes_by_tag``: optional ``{tag: bytes}`` (or hlo_cost's
    ``cross_pod_by_tag`` ``{tag: {collective: bytes}}``) to join byte counts
    onto span rows; pass ``hlo_cost.analyze(...)["cross_pod_by_tag"]`` or
    ``dist.bucketed_reduce.expected_cross_pod_bytes(...)``.
    """
    snap = (registry or _reg.DEFAULT).snapshot()
    bytes_by_tag = bytes_by_tag or {}
    rows = []
    for key, h in sorted(snap["histograms"].items()):
        m = _SPAN_KEY.match(key)
        if not m:
            continue
        name = m.group(1)
        calls = snap["counters"].get(f"span_calls{{span={name}}}", h["count"])
        row = {"span": name, "calls": calls, "p50_ms": h["p50"],
               "p99_ms": h["p99"], "max_ms": h["max"], "total_ms": h["sum"]}
        b = _find_bytes(name, bytes_by_tag)
        if b is not None:
            # ``b`` is bytes per execution of the tagged region; the span's
            # total covers ``calls`` executions
            row["bytes"] = b
            if h["sum"] > 0:
                row["gbps"] = b * calls / (h["sum"] / 1e3) / 1e9
        rows.append(row)
    return StepReport(rows=rows, meta=meta or {})
