"""Exporters: Chrome ``trace_event`` JSON (Perfetto-loadable) and JSONL.

The span layer's event ring is exporter-agnostic; this module turns it into
artifacts:

  * :func:`chrome_trace` / :func:`write_chrome_trace` — the Chrome trace
    format (``chrome://tracing`` / https://ui.perfetto.dev): complete events
    (``ph="X"``) with microsecond ``ts``/``dur``, one row per thread.
    Eager spans export under category ``span``; per-compilation trace-time
    spans under ``jit-trace`` (they appear once, nested inside the eager
    span that triggered compilation).
  * :func:`write_jsonl` — one JSON object per line, for ad-hoc grepping and
    downstream joins.

Both take an explicit event list or default to the live ring.
"""
from __future__ import annotations

import json

from . import spans as _spans

_META_KEYS = ("pid", "tid")


def chrome_trace(events: list[dict] | None = None,
                 metadata: dict | None = None) -> dict:
    """Build the ``{"traceEvents": [...]}`` document from span events."""
    events = _spans.events() if events is None else events
    out = []
    threads = {}
    for ev in events:
        out.append({
            "name": ev["name"], "cat": ev["cat"], "ph": "X",
            "ts": ev["ts"], "dur": ev["dur"],
            "pid": ev["pid"], "tid": ev["tid"],
            "args": {**ev.get("args", {}),
                     "depth": ev.get("depth", 0),
                     "parent": ev.get("parent")},
        })
        threads.setdefault((ev["pid"], ev["tid"]), len(threads))
    for (pid, tid), i in threads.items():
        out.append({"name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
                    "args": {"name": f"obs-{i}"}})
    doc = {"traceEvents": out, "displayTimeUnit": "ms"}
    if metadata:
        doc["otherData"] = metadata
    return doc


def write_chrome_trace(path: str, events: list[dict] | None = None,
                       metadata: dict | None = None) -> str:
    with open(path, "w") as f:
        json.dump(chrome_trace(events, metadata), f)
    return path


def write_jsonl(path: str, events: list[dict] | None = None) -> str:
    events = _spans.events() if events is None else events
    with open(path, "w") as f:
        for ev in events:
            f.write(json.dumps(ev) + "\n")
    return path
