"""Timed, nested spans that are safe inside ``jax.jit``.

``with span("kvpool.park", pages=n):`` does three things at once:

  * **metrics** — wall-clock duration lands in the log-bucketed histogram
    ``span_ms{span=<name>}`` and bumps ``span_calls{span=<name>}``;
  * **events** — a completed span appends one event to the bounded in-memory
    ring (``events()``), which the exporters in ``obs.trace`` turn into a
    Chrome ``trace_event`` JSON / JSONL log;
  * **profiler hooks** — the body runs under ``jax.named_scope(name)`` (the
    span name lands in XLA op metadata, so ``hlo_cost.analyze`` tag patterns
    and real XLA profiles see the same names) and, when running eagerly,
    ``jax.profiler.TraceAnnotation(name)`` (the span shows up in
    ``jax.profiler`` traces captured via ``--profile-dir`` on hardware).

jit discipline (load-bearing; pinned in tests/test_obs.py): a span entered
while a trace is in progress (``jax.core.trace_state_clean()`` is False)
records **no runtime timing** — it contributes only the named_scope metadata
plus a single ``cat="jit-trace"`` ring event measuring how long *tracing*
that region took. Nothing is staged into the traced program: no ops, no
tracers captured, no Python state the jit cache key could see — so spans
compile to no-ops inside jit-traced regions, cannot cause retraces, and the
``span_traces{span=...}`` counter doubles as a retrace detector (it should
stick at the number of distinct compiled shapes).

Nesting is tracked with a ``contextvars`` stack: every event carries its
depth and parent span name, and the stack is restored on exit even under
reentrancy or exceptions.
"""
from __future__ import annotations

import contextvars
import functools
import os
import threading
import time
from collections import deque

import jax

from . import registry as _reg

_stack: contextvars.ContextVar[tuple] = contextvars.ContextVar(
    "obs_span_stack", default=())

DEFAULT_RING_CAPACITY = 65_536

_ring_lock = threading.Lock()
_ring: deque = deque(maxlen=DEFAULT_RING_CAPACITY)


def events() -> list[dict]:
    """Snapshot of the event ring, oldest first."""
    with _ring_lock:
        return list(_ring)


def clear_events() -> None:
    with _ring_lock:
        _ring.clear()


def ring_capacity() -> int:
    return _ring.maxlen


def set_ring_capacity(n: int) -> None:
    """Rebound the ring (keeps the newest events that still fit)."""
    global _ring
    with _ring_lock:
        _ring = deque(_ring, maxlen=int(n))


def current_stack() -> tuple:
    """The active span-name stack for this context (outermost first)."""
    return _stack.get()


def _clean_attrs(attrs: dict) -> dict:
    """JSON-safe args: scalars pass, everything else (incl. tracers) is
    stringified and truncated — never retains a reference to a tracer."""
    out = {}
    for k, v in attrs.items():
        if isinstance(v, (bool, int, float, str)) or v is None:
            out[k] = v
        else:
            out[k] = str(v)[:64]
    return out


def _record(name: str, cat: str, t0_us: float, dur_us: float,
            depth: int, parent: str | None, attrs: dict) -> None:
    ev = {"name": name, "cat": cat, "ts": t0_us, "dur": dur_us,
          "pid": os.getpid(), "tid": threading.get_ident(),
          "depth": depth, "parent": parent, "args": _clean_attrs(attrs)}
    with _ring_lock:
        _ring.append(ev)


class span:
    """Context manager / decorator for one named scope. Reentrant: the same
    instance can be entered recursively (each entry keeps its own frame)."""

    __slots__ = ("name", "attrs", "_frames")

    def __init__(self, name: str, **attrs):
        self.name = name
        self.attrs = attrs
        self._frames: list[tuple] = []

    def __call__(self, fn):
        @functools.wraps(fn)
        def wrapped(*args, **kwargs):
            with span(self.name, **self.attrs):
                return fn(*args, **kwargs)
        return wrapped

    def __enter__(self):
        if not _reg.enabled():
            self._frames.append(None)
            return self
        eager = jax.core.trace_state_clean()
        stack = _stack.get()
        token = _stack.set(stack + (self.name,))
        scope = jax.named_scope(self.name)
        scope.__enter__()
        annot = None
        if eager:
            annot = jax.profiler.TraceAnnotation(self.name)
            annot.__enter__()
        parent = stack[-1] if stack else None
        self._frames.append((eager, token, scope, annot, parent,
                             len(stack), time.perf_counter_ns()))
        return self

    def __exit__(self, exc_type, exc, tb):
        frame = self._frames.pop()
        if frame is None:
            return False
        eager, token, scope, annot, parent, depth, t0 = frame
        dur_us = (time.perf_counter_ns() - t0) / 1e3
        if annot is not None:
            annot.__exit__(exc_type, exc, tb)
        scope.__exit__(exc_type, exc, tb)
        _stack.reset(token)
        if eager:
            _reg.counter("span_calls", span=self.name).inc()
            _reg.histogram("span_ms", span=self.name).observe(dur_us / 1e3)
            _record(self.name, "span", t0 / 1e3, dur_us, depth, parent,
                    self.attrs)
        else:
            # trace-time span: one event per compilation — a retrace detector
            # and the only (intentional) footprint inside jit
            _reg.counter("span_traces", span=self.name).inc()
            _record(self.name, "jit-trace", t0 / 1e3, dur_us, depth, parent,
                    self.attrs)
        return False
