"""Shared CLI plumbing for telemetry artifacts.

Every launcher (launch/train.py, launch/serve.py, examples, benchmarks)
grows the same three flags; this module keeps the parser wiring and the
end-of-run export logic in one place:

  --trace-out PATH     write the span event ring as a Chrome trace_event
                       JSON (load in Perfetto / chrome://tracing)
  --metrics-out PATH   dump the metric registry snapshot as JSON
  --profile-dir DIR    bracket the run with jax.profiler.start_trace /
                       stop_trace (TensorBoard-loadable XLA profile)

Usage::

    from repro.obs import cli as obs_cli
    obs_cli.add_args(parser)
    args = parser.parse_args()
    obs_cli.start(args)
    ...                      # run
    obs_cli.finish(args)     # writes whatever was requested
"""
from __future__ import annotations

import json

from . import registry, sentinels, trace


def add_args(p) -> None:
    """Attach the telemetry flags to an argparse parser."""
    p.add_argument("--trace-out", default=None, metavar="PATH",
                   help="write span events as Chrome trace JSON (Perfetto)")
    p.add_argument("--metrics-out", default=None, metavar="PATH",
                   help="write the metric registry snapshot as JSON")
    p.add_argument("--profile-dir", default=None, metavar="DIR",
                   help="capture a jax.profiler trace into DIR")


def start(args) -> None:
    """Begin any capture that must bracket the run (jax profiler)."""
    if getattr(args, "profile_dir", None):
        import jax
        jax.profiler.start_trace(args.profile_dir)


def finish(args, *, metadata: dict | None = None) -> None:
    """Write the requested artifacts; safe to call when no flag was set."""
    if getattr(args, "profile_dir", None):
        import jax
        jax.profiler.stop_trace()
    if getattr(args, "trace_out", None):
        trace.write_chrome_trace(args.trace_out, metadata=metadata)
        print(f"chrome trace -> {args.trace_out}")
    if getattr(args, "metrics_out", None):
        snap = registry.snapshot()
        snap["sentinel_violations"] = sentinels.violations()
        with open(args.metrics_out, "w") as f:
            json.dump(snap, f, indent=1, sort_keys=True)
        print(f"metrics snapshot -> {args.metrics_out}")
