"""repro.obs — unified metrics / span / sentinel telemetry for the stack.

One dependency-free layer that every subsystem (core FZ, kernels, kvpool,
bucketed reduce, trainer, engine, launchers) reports into, replacing the
per-module ad-hoc counters. Four pieces:

  * :mod:`registry`  — counters / gauges / log-bucketed histograms, labeled,
    process-wide, snapshot-able to a plain dict (``obs.snapshot()``);
  * :mod:`spans`     — ``with obs.span("kvpool.park", pages=n):`` nested
    timed scopes feeding the histograms, a bounded event ring, and
    ``jax.named_scope`` + ``jax.profiler.TraceAnnotation`` so the same names
    appear in real XLA profiles;
  * :mod:`trace`     — exporters: Chrome ``trace_event`` JSON and JSONL;
  * :mod:`sentinels` — always-on health monitors (error-bound violations,
    ratio drift, scheduler starvation) behind ``obs.assert_healthy()``.

How to read a StepReport
------------------------
``obs.step_report()`` returns one row per span name: call count, p50/p99/max
milliseconds, and total time; pass ``bytes_by_tag=`` (from
``hlo_cost.analyze(...)["cross_pod_by_tag"]`` or
``bucketed_reduce.expected_cross_pod_bytes``) and rows whose span name
carries a matching tag (e.g. ``dist.bucket0_reduce``) gain a bytes column
and the implied GB/s. That turns "did the per-bucket all-gather hide under
backward?" into a table scan: a hidden transfer's span time is small while
its bytes are large (high effective GB/s because the wall-clock was paid by
overlapped compute); a serialized one shows GB/s near the raw link rate.
``report.render()`` prints it; ``--metrics-out`` JSONs it.

How to open the trace in Perfetto
---------------------------------
Run any launcher (or ``examples/serve_compressed_kv.py``) with
``--trace-out trace.json``, then load the file at https://ui.perfetto.dev
(or ``chrome://tracing``). Eager spans are complete events nested by
timestamp on one track per thread; category ``jit-trace`` marks
once-per-compilation spans recorded while jax was tracing a region (they
sit inside the eager span that triggered compilation — that is where the
``engine -> kvpool -> fz -> kernel-stage`` nesting comes from, since the
kernel stages only execute inside ``jit``). On real hardware add
``--profile-dir`` to capture a full ``jax.profiler`` trace with the same
span names as XLA annotations.

What each sentinel means
------------------------
  * ``sentinel_eb_violations{tier=...}`` — a sampled container decompressed
    to more than the configured error bound (plus the documented f32
    rounding allowance). Always a bug: the compressor's contract is broken.
    ``assert_healthy()`` raises on it; the scheduler and trainer call that
    hook every step.
  * ``sentinel_ratio_drift{tier=...}`` — the achieved compression ratio
    moved more than ``ratio_drift_factor``x from its EWMA for a tier
    (``wire`` gradient hops / ``kv_cold`` parked pages /
    ``kv_cold_entropy`` entropy-coded parked blobs / ``ckpt``
    checkpoints). A flag, not a failure: it usually means the data
    distribution changed (warmup gradients, new workload), but a sudden
    drift is the first symptom of a mis-resolved bound.

Cold-tier entropy counters
--------------------------
``fz.to_bytes`` / ``fz.from_bytes`` bump
``entropy_stage{op=encode|decode, selected=true|false, tier=...}`` — one
increment per serialized container, labeled with whether the probe selected
the entropy stage and which tier asked (``kv_cold_entropy``, ``ckpt``, or
``adhoc`` for untiered calls). The serializers deliberately do *not* feed
the ratio EWMAs; callers sample ``note_ratio`` at their own cadence (the
pool inside its sentinel check, the checkpointer once per save) so
legitimate per-container variance cannot trip the drift sentinel.
  * ``sched_waiting / sched_running / sched_parked / sched_max_wait_steps``
    — serving queue depths and the starvation high-water (longest any
    request waited for admission), sampled every scheduler step.

jit discipline: spans entered while jax is tracing record no runtime state
(see :mod:`spans`); instrumented hot paths stay retrace-free and the
compiled programs are bit-identical with obs on or off. ``obs.disabled()``
suspends all recording — the bench tier uses it to pin the instrumentation
overhead under 5%.
"""
from .registry import (DEFAULT, Registry, counter, disabled, enabled,  # noqa: F401
                       gauge, histogram, reset, set_enabled, snapshot)
from .report import StepReport, step_report  # noqa: F401
from .sentinels import (CONFIG, HealthError, SentinelConfig,  # noqa: F401
                        assert_healthy, check_error_bound, configure,
                        note_ratio, note_scheduler, should_check_eb,
                        violations)
from .spans import (clear_events, current_stack, events,  # noqa: F401
                    ring_capacity, set_ring_capacity, span)
from .trace import chrome_trace, write_chrome_trace, write_jsonl  # noqa: F401
