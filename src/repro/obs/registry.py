"""Metric registry: counters, gauges, log-bucketed histograms.

Dependency-free process-wide telemetry primitives. Every metric is keyed by
``(name, labels)`` — asking the registry for the same key returns the same
instance, so instrumented code can re-resolve its metrics on every call
without double counting. ``snapshot()`` flattens the whole registry into a
plain dict (JSON-ready) keyed ``name{k=v,...}``; that dict is the single
source of truth the legacy stat views (``PoolStats`` / ``TraceStats``) are
derived from.

Histograms are log-bucketed (base ``2**(1/8)``, ~9% relative resolution):
``observe`` is O(1), quantiles walk the sparse bucket table and interpolate
inside the winning bucket, and the exact min/max/sum/count ride along so
``p100`` is exact. Good enough to rank kernel stages and spot tail
regressions; not a replacement for a real profile (that is what the span
layer's ``jax.profiler`` hooks are for).

``enabled()`` / ``set_enabled(False)`` gate every mutation: disabling turns
``inc``/``set``/``observe`` into early returns, which is how the bench tier
pins the instrumentation overhead (<5%) without a separate build.
"""
from __future__ import annotations

import math
import threading

_LOG_BASE = 2.0 ** 0.125          # ~9% relative bucket resolution
_INV_LOG = 1.0 / math.log(_LOG_BASE)

_state_lock = threading.Lock()
_enabled = True


def enabled() -> bool:
    return _enabled


def set_enabled(value: bool) -> bool:
    """Flip global metric recording; returns the previous value."""
    global _enabled
    with _state_lock:
        prev = _enabled
        _enabled = bool(value)
    return prev


class disabled:
    """Context manager: suspend all metric/span recording inside the block."""

    def __enter__(self):
        self._prev = set_enabled(False)
        return self

    def __exit__(self, *exc):
        set_enabled(self._prev)
        return False


def _label_key(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return "{" + inner + "}"


class Counter:
    """Monotonic int counter."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: dict):
        self.name, self.labels = name, labels
        self.value = 0

    def inc(self, n: int = 1) -> None:
        if _enabled:
            self.value += n


class Gauge:
    """Last-write-wins scalar."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: dict):
        self.name, self.labels = name, labels
        self.value = 0.0

    def set(self, v: float) -> None:
        if _enabled:
            self.value = float(v)

    def max(self, v: float) -> None:
        """High-water update: keep the larger of current and ``v``."""
        if _enabled:
            v = float(v)
            if v > self.value:
                self.value = v


class Histogram:
    """Sparse log-bucketed histogram with exact count/sum/min/max."""

    __slots__ = ("name", "labels", "buckets", "count", "sum", "min", "max")

    def __init__(self, name: str, labels: dict):
        self.name, self.labels = name, labels
        self.buckets: dict[int, int] = {}
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    @staticmethod
    def _index(v: float) -> int:
        # clamp to a tiny positive floor so zero/negative observations land
        # in the lowest bucket instead of blowing up the log
        return int(math.floor(math.log(max(v, 1e-12)) * _INV_LOG))

    def observe(self, v: float) -> None:
        if not _enabled:
            return
        v = float(v)
        idx = self._index(v)
        self.buckets[idx] = self.buckets.get(idx, 0) + 1
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def percentile(self, q: float) -> float:
        """Approximate quantile (q in [0, 100]); exact at the endpoints."""
        if self.count == 0:
            return 0.0
        if q <= 0:
            return self.min
        if q >= 100:
            return self.max
        target = q / 100.0 * self.count
        seen = 0
        for idx in sorted(self.buckets):
            n = self.buckets[idx]
            if seen + n >= target:
                lo = _LOG_BASE ** idx
                hi = lo * _LOG_BASE
                frac = (target - seen) / n
                est = lo + (hi - lo) * frac
                return min(max(est, self.min), self.max)
            seen += n
        return self.max

    def summary(self) -> dict:
        if self.count == 0:
            return {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                    "p50": 0.0, "p99": 0.0}
        return {"count": self.count, "sum": self.sum,
                "min": self.min, "max": self.max,
                "p50": self.percentile(50), "p99": self.percentile(99)}


class Registry:
    """(name, labels) -> metric instance; snapshot-able to a plain dict."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[tuple[str, str], object] = {}

    def _get(self, cls, name: str, labels: dict):
        key = (name, _label_key(labels))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = cls(name, dict(labels))
                self._metrics[key] = m
            elif not isinstance(m, cls):
                raise TypeError(f"metric {key} already registered as "
                                f"{type(m).__name__}, not {cls.__name__}")
            return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get(Histogram, name, labels)

    def find(self, name: str, **labels):
        """Existing metric or None (read-side: never creates)."""
        return self._metrics.get((name, _label_key(labels)))

    def snapshot(self) -> dict:
        """Flatten to ``{"counters": {...}, "gauges": {...}, "histograms":
        {...}}`` with ``name{label=value,...}`` string keys — JSON-ready."""
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        with self._lock:
            items = list(self._metrics.items())
        for (name, lk), m in items:
            key = name + lk
            if isinstance(m, Counter):
                out["counters"][key] = m.value
            elif isinstance(m, Gauge):
                out["gauges"][key] = m.value
            else:
                out["histograms"][key] = m.summary()
        return out

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()


# the process-wide default registry; module-level helpers below bind to it
DEFAULT = Registry()


def counter(name: str, **labels) -> Counter:
    return DEFAULT.counter(name, **labels)


def gauge(name: str, **labels) -> Gauge:
    return DEFAULT.gauge(name, **labels)


def histogram(name: str, **labels) -> Histogram:
    return DEFAULT.histogram(name, **labels)


def snapshot() -> dict:
    return DEFAULT.snapshot()


def reset() -> None:
    DEFAULT.reset()
