"""Always-on health monitors over the metric registry.

Three sentinel families, all cheap enough to leave on in production:

  * **error-bound violations** — the compressor's one hard promise is
    ``|x - D(C(x))|_inf <= eb`` (+ documented f32-rounding allowance; strict
    only with exact outliers, see core/quant.py). Instrumented call sites
    (the kvpool cold tier today) sample a just-written container every
    ``eb_sample_every``-th compression — the first one always, so short
    smoke traces still exercise the check — decompress it transiently, and
    compare the max abs error against the configured bound.
    ``sentinel_eb_violations{tier=...}`` must stay 0; ``assert_healthy``
    raises otherwise.
  * **compression-ratio drift** — per tier (``wire`` gradient hops,
    ``kv_cold`` parked pages, ``ckpt`` checkpoints) an EWMA of the achieved
    ratio; a sample further than ``ratio_drift_factor``x from the EWMA (after
    warmup) bumps ``sentinel_ratio_drift{tier=...}``. Drift is a flag, not a
    failure (a workload shift legitimately moves the ratio): it is reported
    by ``violations()`` but only fails ``assert_healthy(strict_drift=True)``.
  * **scheduler health** — queue-depth gauges (waiting/running/parked
    lanes), preemption counters, and a starvation gauge (oldest waiting
    request's age in steps), fed by the kvpool scheduler each step.

``assert_healthy()`` is the one hook callers need: the serving scheduler and
the trainer call it per step; it reads only counters, so it is O(#tiers).
"""
from __future__ import annotations

import dataclasses

from . import registry as _reg


class HealthError(RuntimeError):
    """A sentinel recorded a violation (see ``violations()``)."""


@dataclasses.dataclass(frozen=True)
class SentinelConfig:
    eb_sample_every: int = 16      # check the 1st, then every Nth compression
    eb_slack: float = 1e-3         # multiplicative slack on eb_abs
    ratio_drift_factor: float = 4.0
    ratio_ewma_alpha: float = 0.2
    ratio_warmup: int = 3          # samples before drift can flag


CONFIG = SentinelConfig()


def configure(cfg: SentinelConfig) -> None:
    global CONFIG
    CONFIG = cfg


# -- error-bound violations ---------------------------------------------------

def should_check_eb(tier: str) -> bool:
    """Deterministic sampling decision; bumps the per-tier consideration
    counter. The first compression of a tier is always checked."""
    if not _reg.enabled():
        return False
    c = _reg.counter("sentinel_eb_considered", tier=tier)
    sample = c.value % max(CONFIG.eb_sample_every, 1) == 0
    c.inc()
    return sample


def check_error_bound(tier: str, max_err: float, eb_abs: float,
                      max_abs: float = 0.0) -> bool:
    """Record one sampled roundtrip check; True if the bound held.

    ``max_err`` is the measured ``|src - rec|_inf`` (caller computes it — the
    sentinel never touches device arrays itself), ``eb_abs`` the resolved
    absolute bound, ``max_abs`` the source's ``|x|_inf`` for the f32-rounding
    allowance (the same ``|x| * 2^-22`` term the property suite documents).
    """
    if not _reg.enabled():
        return True
    max_err, eb_abs = float(max_err), float(eb_abs)
    tol = eb_abs * (1.0 + CONFIG.eb_slack) + float(max_abs) * 2.0 ** -22 + 1e-30
    _reg.counter("sentinel_eb_checks", tier=tier).inc()
    _reg.gauge("sentinel_eb_last_max_err", tier=tier).set(max_err)
    ok = max_err <= tol
    if not ok:
        _reg.counter("sentinel_eb_violations", tier=tier).inc()
        _reg.gauge("sentinel_eb_worst_excess", tier=tier).max(max_err - tol)
    return ok


# -- compression-ratio drift --------------------------------------------------

def note_ratio(tier: str, ratio: float) -> None:
    """Feed one achieved compression-ratio sample into the tier's EWMA."""
    if not _reg.enabled():
        return
    ratio = float(ratio)
    n = _reg.counter("sentinel_ratio_samples", tier=tier)
    ewma = _reg.gauge("sentinel_ratio_ewma", tier=tier)
    _reg.gauge("sentinel_ratio_last", tier=tier).set(ratio)
    if n.value == 0:
        ewma.set(ratio)
    else:
        if n.value >= CONFIG.ratio_warmup and ewma.value > 0:
            f = CONFIG.ratio_drift_factor
            if ratio > ewma.value * f or ratio < ewma.value / f:
                _reg.counter("sentinel_ratio_drift", tier=tier).inc()
        a = CONFIG.ratio_ewma_alpha
        ewma.set((1 - a) * ewma.value + a * ratio)
    n.inc()


# -- scheduler health ---------------------------------------------------------

def note_scheduler(waiting: int, running: int, parked: int,
                   oldest_wait_steps: int) -> None:
    """Per-step queue-depth / starvation gauges from the serving scheduler."""
    if not _reg.enabled():
        return
    _reg.gauge("sched_waiting", subsystem="kvpool").set(waiting)
    _reg.gauge("sched_running", subsystem="kvpool").set(running)
    _reg.gauge("sched_parked", subsystem="kvpool").set(parked)
    _reg.gauge("sched_oldest_wait_steps", subsystem="kvpool").set(
        oldest_wait_steps)
    _reg.gauge("sched_max_wait_steps", subsystem="kvpool").max(
        oldest_wait_steps)


# -- the health hook ----------------------------------------------------------

def violations(registry: _reg.Registry | None = None) -> dict:
    """All nonzero violation/drift counters, keyed by metric{labels}."""
    snap = (registry or _reg.DEFAULT).snapshot()
    return {k: v for k, v in snap["counters"].items()
            if v and (k.startswith("sentinel_eb_violations")
                      or k.startswith("sentinel_ratio_drift"))}


def assert_healthy(*, strict_drift: bool = False) -> None:
    """Raise :class:`HealthError` on any error-bound violation (and, with
    ``strict_drift``, on ratio drift). The engine/trainer per-step hook."""
    bad = violations()
    if not strict_drift:
        bad = {k: v for k, v in bad.items()
               if k.startswith("sentinel_eb_violations")}
    if bad:
        raise HealthError(f"sentinel violations: {bad}")
