"""Bitshuffle (FZ-GPU §3.3), pure-JAX reference semantics.

Reorganizes a stream of uint16 quantization codes into contiguous bit-planes
so that small magnitudes become long zero runs for the zero-block encoder.

TPU adaptation (see DESIGN.md §2): the CUDA ``__ballot_sync`` warp vote is
replaced by a Hacker's-Delight masked-swap 16x16 bit-matrix transpose — four
shift/mask/select stages, fully vectorized over VPU lanes. The resulting bit
convention is the involution

    (element e, bit b)  ->  (element 15-b, bit 15-e)

i.e. output word p of a 16-element group holds bit-plane 15-p, bit-reversed
within the word. Compression ratio is invariant to any fixed bit permutation;
the convention is documented and pinned by tests.

Tile layout: codes are processed in tiles of ``TILE`` = 4096 codes (8 KiB).
Within a tile the per-group planes are transposed to plane-major order so each
bit-plane of the whole tile is contiguous (256 u16 words per plane), which is
what lets an all-zero high plane produce 32 consecutive zero 16-byte blocks.

These functions are the oracles for kernels/bitshuffle_flag.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

TILE = 4096            # codes per shuffle tile (8 KiB of u16)
GROUP = 16             # codes per bit-matrix transpose group
GROUPS_PER_TILE = TILE // GROUP  # 256

_STAGES = ((8, 0xFF00), (4, 0xF0F0), (2, 0xCCCC), (1, 0xAAAA))


def transpose16(x: jax.Array) -> jax.Array:
    """Bit-matrix transpose of (..., 16) uint16 groups (involution).

    Four masked-swap stages; every op is a dense lane-wise shift/mask/select,
    the TPU-native analogue of the paper's warp ballot.
    """
    if x.shape[-1] != GROUP:
        raise ValueError(f"last dim must be {GROUP}, got {x.shape}")
    idx = jnp.arange(GROUP)
    for delta, mask in _STAGES:
        m = jnp.uint16(mask)
        lo = jnp.uint16(~mask & 0xFFFF)
        partner = x[..., idx ^ delta]
        hi_val = (x & m) | ((partner & m) >> delta)
        lo_val = ((partner & lo) << delta) | (x & lo)
        x = jnp.where((idx & delta) == 0, hi_val, lo_val)
    return x


def pad_to_tiles(codes_flat: jax.Array) -> jax.Array:
    """Zero-pad a flat u16 code stream to a whole number of tiles."""
    n = codes_flat.size
    padded = (n + TILE - 1) // TILE * TILE
    return jnp.pad(codes_flat, (0, padded - n))


def bitshuffle(codes: jax.Array) -> jax.Array:
    """Flat (multiple-of-TILE) u16 codes -> plane-major bitshuffled u16 words."""
    if codes.size % TILE:
        raise ValueError(f"size {codes.size} not a multiple of TILE={TILE}; pad first")
    g = codes.reshape(-1, GROUPS_PER_TILE, GROUP)
    t = transpose16(g)                       # (tiles, 256 groups, 16 planes)
    return t.transpose(0, 2, 1).reshape(-1)  # plane-major within each tile


def bitunshuffle(shuffled: jax.Array) -> jax.Array:
    """Inverse of :func:`bitshuffle` (word transpose back, then bit transpose)."""
    if shuffled.size % TILE:
        raise ValueError(f"size {shuffled.size} not a multiple of TILE={TILE}")
    t = shuffled.reshape(-1, GROUP, GROUPS_PER_TILE).transpose(0, 2, 1)
    return transpose16(t).reshape(-1)
