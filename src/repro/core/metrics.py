"""Distortion / ratio metrics used by the paper's evaluation (§4.2)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def psnr(orig: jax.Array, rec: jax.Array) -> jax.Array:
    """Range-based PSNR in dB (the paper's primary distortion metric)."""
    orig = orig.astype(jnp.float32)
    rec = rec.astype(jnp.float32)
    rng = jnp.max(orig) - jnp.min(orig)
    mse = jnp.mean((orig - rec) ** 2)
    return 20.0 * jnp.log10(rng) - 10.0 * jnp.log10(jnp.maximum(mse, 1e-30))


def max_abs_err(orig: jax.Array, rec: jax.Array) -> jax.Array:
    return jnp.max(jnp.abs(orig.astype(jnp.float32) - rec.astype(jnp.float32)))


def nrmse(orig: jax.Array, rec: jax.Array) -> jax.Array:
    rng = jnp.max(orig) - jnp.min(orig)
    return jnp.sqrt(jnp.mean((orig - rec) ** 2)) / jnp.maximum(rng, 1e-30)


def bitrate(raw_bytes: float, compressed_bytes: jax.Array,
            dtype=jnp.float32) -> jax.Array:
    """Average bits per source value.

    ``dtype`` (or an explicit element width via ``itemsize_bits``-style
    callers) names the *source* element type: a bfloat16 field at the same
    compressed size costs twice the bits per value of a float32 one.
    Defaults to float32 — the paper's datasets — so existing call sites are
    unchanged; pass the real dtype when the source is not f32.
    """
    bits = jnp.dtype(dtype).itemsize * 8
    return bits * compressed_bytes / raw_bytes


def _window_mean(x: jax.Array, k: int) -> jax.Array:
    """Uniform kxk window mean of a 2D array via two separable box filters."""
    kern = jnp.ones((k,), x.dtype) / k
    x = jax.vmap(lambda r: jnp.convolve(r, kern, mode="valid"))(x)
    x = jax.vmap(lambda c: jnp.convolve(c, kern, mode="valid"), in_axes=1, out_axes=1)(x)
    return x


def ssim2d(orig: jax.Array, rec: jax.Array, k: int = 7) -> jax.Array:
    """SSIM over a 2D field (uniform window; the paper's secondary fidelity metric)."""
    orig = orig.astype(jnp.float32)
    rec = rec.astype(jnp.float32)
    rng = jnp.max(orig) - jnp.min(orig)
    c1 = (0.01 * rng) ** 2 + 1e-12
    c2 = (0.03 * rng) ** 2 + 1e-12
    mu_x, mu_y = _window_mean(orig, k), _window_mean(rec, k)
    xx, yy, xy = _window_mean(orig * orig, k), _window_mean(rec * rec, k), _window_mean(orig * rec, k)
    var_x = jnp.maximum(xx - mu_x ** 2, 0.0)
    var_y = jnp.maximum(yy - mu_y ** 2, 0.0)
    cov = xy - mu_x * mu_y
    s = ((2 * mu_x * mu_y + c1) * (2 * cov + c2)) / ((mu_x ** 2 + mu_y ** 2 + c1) * (var_x + var_y + c2))
    return jnp.mean(s)
