"""Optimized dual-quantization (FZ-GPU §3.2), pure-JAX reference semantics.

The only lossy stage of the pipeline:

    q_i   = round(d_i / (2 * eb))          # pre-quantization (error <= eb)
    delta = Lorenzo(q)                      # integer finite differences (exact)
    code  = sign_magnitude_u16(delta)       # MSB = sign, no radius shift,
                                            # no separate outlier stream

FZ-GPU's departures from cuSZ (all reproduced here):
  * no +radius shift of quantization codes,
  * no separate outlier handling path (saturating codes instead),
  * sign carried in the MSB of an unsigned 16-bit code rather than
    2's complement, so small +/- values have mostly-zero high bits.

Beyond-paper option (``exact_outliers``): a fixed-capacity side channel of
(flat index, int32 residual) pairs restores the strict error bound even when
|delta| > 32767 (saturation would otherwise propagate through the Lorenzo
integration at decompression). Default ON for framework integrations, OFF for
the paper-faithful benchmark mode.

The functions here are the *oracles* for kernels/lorenzo_quant.py.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

MAX_MAG = 0x7FFF  # largest representable |delta| in a sign-magnitude u16
SIGN_BIT = 0x8000


# ---------------------------------------------------------------------------
# Lorenzo predictor (on quantized integers -> integer deltas, exact)
# ---------------------------------------------------------------------------

def lorenzo_delta(q: jax.Array) -> jax.Array:
    """Forward Lorenzo transform: per-axis backward differences.

    For the Lorenzo predictor of any dimension, the prediction residual
    equals the composition of first differences along every axis
    (1D: v-W; 2D: v-N-W+NW; 3D: 7-point), with zero boundary conditions.
    Exact over int32.
    """
    if q.ndim > 3:
        raise ValueError(f"Lorenzo supports 1-3D, got {q.ndim}D")
    d = q
    for ax in range(q.ndim):
        d = jnp.diff(d, axis=ax, prepend=jnp.zeros_like(jax.lax.slice_in_dim(d, 0, 1, axis=ax)))
    return d


def lorenzo_inverse(delta: jax.Array) -> jax.Array:
    """Inverse Lorenzo transform: per-axis prefix sums (exact over int32)."""
    if delta.ndim > 3:
        raise ValueError(f"Lorenzo supports 1-3D, got {delta.ndim}D")
    q = delta
    for ax in range(delta.ndim):
        q = jnp.cumsum(q, axis=ax, dtype=delta.dtype)
    return q


# ---------------------------------------------------------------------------
# Integer delta <-> u16 code
# ---------------------------------------------------------------------------

def to_codes(delta: jax.Array, *, code_mode: str = "sign_mag"):
    """int32 delta -> (u16 code, overflow mask, int32 residual).

    ``sign_mag``  (paper-faithful): code = |d| & 0x7FFF | (d<0)<<15, saturating.
    ``zigzag``    (beyond-paper ablation): code = zigzag(d) saturated to u16;
                  maps the sign into the LSB which empirically yields denser
                  zero bit-planes after bitshuffle.
    residual = delta - decode(code): nonzero only where overflow.
    """
    d = delta.astype(jnp.int32)
    if code_mode == "sign_mag":
        mag = jnp.abs(d)
        over = mag > MAX_MAG
        sat = jnp.minimum(mag, MAX_MAG)
        code = sat.astype(jnp.uint16) | jnp.where(d < 0, jnp.uint16(SIGN_BIT), jnp.uint16(0))
        rec = jnp.where(d < 0, -sat, sat)
    elif code_mode == "zigzag":
        z = (d << 1) ^ (d >> 31)  # zigzag: 0,-1,1,-2,2 -> 0,1,2,3,4
        over = z > 0xFFFF
        zs = jnp.minimum(z, 0xFFFF)
        code = zs.astype(jnp.uint16)
        rec = (zs >> 1) ^ -(zs & 1)
    else:
        raise ValueError(f"unknown code_mode {code_mode!r}")
    return code, over, d - rec


def from_codes(code: jax.Array, *, code_mode: str = "sign_mag") -> jax.Array:
    """u16 code -> int32 delta (saturated value; residuals re-added separately)."""
    c = code.astype(jnp.int32)
    if code_mode == "sign_mag":
        mag = c & MAX_MAG
        return jnp.where(c & SIGN_BIT, -mag, mag)
    elif code_mode == "zigzag":
        return (c >> 1) ^ -(c & 1)
    raise ValueError(f"unknown code_mode {code_mode!r}")


# ---------------------------------------------------------------------------
# Full dual-quantization forward / inverse
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("code_mode", "outlier_capacity"))
def dual_quantize(data: jax.Array, eb: jax.Array, *, code_mode: str = "sign_mag",
                  outlier_capacity: int = 0):
    """float data -> (u16 codes, outlier_idx, outlier_val, n_outliers).

    ``outlier_capacity`` == 0 reproduces the paper exactly (saturate & forget).
    With capacity K > 0, up to K overflowing deltas get exact int32 residuals
    recorded against their flat index (beyond-paper strict-error-bound mode).

    Preconditions (shared with SZ-family quantizers operating in float32):
      * codes fit int32: ``max|d| / (2*eb) < 2**31`` (else q wraps; no outlier
        channel can repair that);
      * strict error bound additionally needs ``range/(2*eb) < ~2**21`` so the
        f32 divide/rint/multiply round-trip stays within 1 q-unit. The paper's
        own evaluation range (rel eb 1e-2..1e-4, q <= 5000) sits far inside;
        beyond it the bound degrades gracefully to eb + O(ulp(data)).
    """
    q = jnp.rint(data.astype(jnp.float32) / (2.0 * eb)).astype(jnp.int32)
    delta = lorenzo_delta(q)
    codes, over, resid = to_codes(delta, code_mode=code_mode)
    n = codes.size
    n_over = jnp.sum(over, dtype=jnp.int32)
    if outlier_capacity > 0:
        (idx,) = jnp.nonzero(over.ravel(), size=outlier_capacity, fill_value=n)
        val = jnp.where(idx < n, resid.ravel()[jnp.minimum(idx, n - 1)], 0)
        idx = idx.astype(jnp.int32)
    else:
        idx = jnp.zeros((0,), jnp.int32)
        val = jnp.zeros((0,), jnp.int32)
    return codes, idx, val, n_over


@partial(jax.jit, static_argnames=("shape", "code_mode"))
def dual_dequantize(codes: jax.Array, eb: jax.Array, shape: tuple[int, ...], *,
                    code_mode: str = "sign_mag",
                    outlier_idx: jax.Array | None = None,
                    outlier_val: jax.Array | None = None) -> jax.Array:
    """u16 codes (+ optional outlier residuals) -> reconstructed float32."""
    delta = from_codes(codes, code_mode=code_mode).ravel()
    if outlier_idx is not None and outlier_idx.size:
        delta = delta.at[jnp.minimum(outlier_idx, delta.size - 1)].add(
            jnp.where(outlier_idx < delta.size, outlier_val, 0), mode="drop")
    q = lorenzo_inverse(delta.reshape(shape))
    return q.astype(jnp.float32) * (2.0 * eb)
