"""Second-stage lossless entropy coder for serialized FZ containers.

FZ-GPU's bitshuffle + zero-flag pipeline (PAPER.md §3.4) deliberately trades
compression ratio for throughput.  This module recovers that ratio where
latency does not matter — parked KV pages and checkpoints — with a canonical
Huffman coder over the compacted payload *bytes*:

* **Canonical, length-limited codes.**  Code lengths come from a package-free
  Huffman build (`huffman_code_lengths`, shared with `core.baselines`);
  lengths are capped at ``MAX_CODE_LEN`` (count-halving until the tree fits)
  so decode can use a single ``2**MAX_CODE_LEN``-entry lookup table.
* **Gap-array chunked layout.**  The bitstream is cut into fixed-size source
  chunks and the *bit offset of every chunk start* is stored in the blob
  header ("gap array", arXiv 2201.09118).  Decoding is then embarrassingly
  parallel across chunks: the decoder walks all chunks in lockstep, one
  symbol per step, vectorized across the chunk axis — the same structure a
  GPU block-parallel Huffman decoder exploits.
* **Skip probe.**  ``plan()`` computes the *exact* encoded size from a byte
  histogram (bincount + code lengths) without touching the bitstream, so
  callers can skip incompressible containers for the cost of one histogram.

Selection is recorded per-container in the FZ container header
(`docs/CONTAINER_FORMAT.md`), so ``fz.from_bytes`` routes transparently.
Everything here is host-side numpy: variable-length codes do not fit
fixed-shape jit programs, and the cold tier is latency-insensitive by
definition — the hot path (`core/fz.py` compress/decompress) never calls
into this module.

Blob layout (all little-endian, offsets in bytes)::

    0    u64   n_bytes      source length
    8    u32   chunk_bytes  source bytes per chunk
    12   u32   n_chunks     ceil(n_bytes / chunk_bytes)
    16   u64   total_bits   bitstream length in bits
    24   u8[256]            canonical code length per byte symbol
    280  u64[n_chunks]      gap array: bit offset of each chunk start
    ...  u8[ceil(total_bits / 8)]   bitstream, MSB-first within each byte
"""
from __future__ import annotations

import heapq
import struct

import numpy as np

MAX_CODE_LEN = 15          # decode table is 2**MAX_CODE_LEN entries (32 K)
DEFAULT_CHUNK = 4096       # source bytes per gap-array chunk
_HEADER = struct.Struct("<QIIQ")
_FIXED_OVERHEAD = _HEADER.size + 256  # header + code-length table

__all__ = [
    "MAX_CODE_LEN", "DEFAULT_CHUNK", "EntropyError",
    "huffman_code_lengths", "limit_code_lengths", "canonical_codes",
    "plan", "encode", "decode", "overhead_bytes",
]


class EntropyError(ValueError):
    """Raised on malformed / truncated entropy blobs."""


# ---------------------------------------------------------------------------
# code construction
# ---------------------------------------------------------------------------

def huffman_code_lengths(counts: np.ndarray) -> np.ndarray:
    """Code lengths of a Huffman code for symbol counts (package-free).

    O(k log k) over the nonzero alphabet; also used by the cuSZ baseline in
    `core.baselines`.
    """
    counts = np.asarray(counts)
    sym = np.nonzero(counts)[0]
    if sym.size == 0:
        return np.zeros_like(counts)
    if sym.size == 1:
        lengths = np.zeros_like(counts)
        lengths[sym[0]] = 1
        return lengths
    heap = [(int(counts[s]), i, [int(s)]) for i, s in enumerate(sym)]
    heapq.heapify(heap)
    lengths = np.zeros_like(counts)
    uid = len(heap)
    while len(heap) > 1:
        c1, _, s1 = heapq.heappop(heap)
        c2, _, s2 = heapq.heappop(heap)
        for s in s1 + s2:
            lengths[s] += 1
        heapq.heappush(heap, (c1 + c2, uid, s1 + s2))
        uid += 1
    return lengths


def limit_code_lengths(counts: np.ndarray,
                       max_len: int = MAX_CODE_LEN) -> np.ndarray:
    """Huffman code lengths capped at ``max_len`` via count-halving.

    Halving skewed counts flattens the tree; the fixed point (all counts 1)
    is the balanced tree of depth ceil(log2 k) <= 8 for byte symbols, so the
    loop always terminates well under any ``max_len`` >= 8.
    """
    counts = np.asarray(counts, np.int64)
    lengths = huffman_code_lengths(counts)
    while int(lengths.max(initial=0)) > max_len:
        counts = np.where(counts > 0, (counts + 1) // 2, 0)
        lengths = huffman_code_lengths(counts)
    return lengths


def canonical_codes(lengths: np.ndarray) -> np.ndarray:
    """Canonical Huffman codewords (MSB-aligned ints) from code lengths.

    Symbols are ordered by (length, symbol); consecutive codewords tile the
    ``[0, 2**max_len)`` prefix space contiguously, which is what lets decode
    use a flat lookup table built with two ``np.repeat`` calls.
    """
    lengths = np.asarray(lengths, np.int64)
    codes = np.zeros_like(lengths)
    code = 0
    prev_len = 0
    for s in np.lexsort((np.arange(lengths.size), lengths)):
        l = int(lengths[s])
        if l == 0:
            continue
        code <<= (l - prev_len)
        codes[s] = code
        code += 1
        prev_len = l
    return codes


# ---------------------------------------------------------------------------
# skip probe
# ---------------------------------------------------------------------------

def overhead_bytes(n_chunks: int) -> int:
    """Fixed blob overhead: header + length table + gap array."""
    return _FIXED_OVERHEAD + 8 * n_chunks


def plan(counts: np.ndarray, n_bytes: int,
         chunk_bytes: int = DEFAULT_CHUNK) -> tuple[np.ndarray, int]:
    """(code lengths, exact encoded blob size) from a byte histogram.

    This is the skip probe: one ``np.bincount`` plus a 256-symbol Huffman
    build gives the *exact* size ``encode`` would produce, without the
    O(total_bits) bit expansion — callers compare it against ``n_bytes``
    and skip incompressible containers.
    """
    lengths = limit_code_lengths(counts)
    total_bits = int((np.asarray(counts, np.int64) * lengths).sum())
    n_chunks = -(-n_bytes // chunk_bytes) if n_bytes else 0
    return lengths, overhead_bytes(n_chunks) + (total_bits + 7) // 8


# ---------------------------------------------------------------------------
# encode / decode
# ---------------------------------------------------------------------------

_ENC_SEGMENT = 1 << 16  # source bytes bit-expanded per vectorized pass


def encode(data: bytes | np.ndarray, chunk_bytes: int = DEFAULT_CHUNK,
           lengths: np.ndarray | None = None) -> bytes:
    """Encode bytes into a self-describing gap-array Huffman blob."""
    arr = np.frombuffer(bytes(data), np.uint8) if isinstance(
        data, (bytes, bytearray, memoryview)) else np.asarray(data, np.uint8)
    n = arr.size
    if chunk_bytes <= 0:
        raise ValueError(f"chunk_bytes must be positive, got {chunk_bytes}")
    if lengths is None:
        lengths = limit_code_lengths(np.bincount(arr, minlength=256))
    lengths = np.asarray(lengths, np.int64)
    if n == 0:
        return _HEADER.pack(0, chunk_bytes, 0, 0) + bytes(256)
    codes = canonical_codes(lengths).astype(np.uint32)
    sym_len = lengths[arr]
    ends = np.cumsum(sym_len)
    starts = ends - sym_len
    total_bits = int(ends[-1])
    offsets = starts[np.arange(0, n, chunk_bytes)]
    # MSB-first bit expansion, segmented to bound peak memory at
    # ~MAX_CODE_LEN * _ENC_SEGMENT int64 temporaries per pass
    bits = np.empty(total_bits, np.uint8)
    for s0 in range(0, n, _ENC_SEGMENT):
        s1 = min(n, s0 + _ENC_SEGMENT)
        seg_len = sym_len[s0:s1]
        seg_bits = int(seg_len.sum())
        if seg_bits == 0:
            continue
        base = int(starts[s0])
        rel = np.repeat(np.arange(s1 - s0), seg_len)
        k = (np.arange(seg_bits, dtype=np.int64)
             - (starts[s0:s1] - base)[rel])
        c = codes[arr[s0:s1]][rel]
        bits[base:base + seg_bits] = (
            (c >> (seg_len[rel] - 1 - k)) & 1).astype(np.uint8)
    stream = np.packbits(bits)
    return (_HEADER.pack(n, chunk_bytes, offsets.size, total_bits)
            + lengths.astype(np.uint8).tobytes()
            + offsets.astype("<u8").tobytes()
            + stream.tobytes())


def _decode_table(lengths: np.ndarray) -> tuple[np.ndarray, np.ndarray, int]:
    """Flat 2**M lookup: next-M-bits window -> (symbol, code length)."""
    m = int(lengths.max(initial=1))
    order = np.lexsort((np.arange(256), lengths))
    order = order[lengths[order] > 0]
    widths = 1 << (m - lengths[order])
    table_sym = np.repeat(order.astype(np.uint8), widths)
    table_len = np.repeat(lengths[order].astype(np.int64), widths)
    pad = (1 << m) - table_sym.size  # nonzero for incomplete (1-symbol) codes
    if pad:
        table_sym = np.concatenate([table_sym, np.zeros(pad, np.uint8)])
        table_len = np.concatenate([table_len, np.zeros(pad, np.int64)])
    return table_sym, table_len, m


def decode(blob: bytes | memoryview) -> bytes:
    """Decode a blob produced by :func:`encode`.

    Chunks are walked in lockstep — one symbol per step for *every* chunk,
    vectorized across the chunk axis from the gap-array offsets — i.e. the
    block-parallel structure of arXiv 2201.09118 expressed in numpy.
    """
    blob = memoryview(blob)
    if len(blob) < _FIXED_OVERHEAD:
        raise EntropyError(f"entropy blob truncated: {len(blob)} bytes")
    n, chunk_bytes, n_chunks, total_bits = _HEADER.unpack_from(blob, 0)
    if n == 0:
        return b""
    lengths = np.frombuffer(blob, np.uint8, 256, _HEADER.size).astype(np.int64)
    body = _FIXED_OVERHEAD + 8 * n_chunks
    need = body + (total_bits + 7) // 8
    if len(blob) < need or n_chunks != -(-n // chunk_bytes):
        raise EntropyError(
            f"entropy blob inconsistent: {len(blob)} bytes, need {need} "
            f"({n_chunks} chunks of {chunk_bytes})")
    offsets = np.frombuffer(blob, "<u8", n_chunks, _FIXED_OVERHEAD
                            ).astype(np.int64)
    stream = np.frombuffer(blob, np.uint8, (total_bits + 7) // 8, body)
    stream = np.concatenate([stream, np.zeros(4, np.uint8)])  # window slack
    table_sym, table_len, m = _decode_table(lengths)

    out = np.empty(n, np.uint8)
    pos = offsets.copy()
    base = np.arange(n_chunks, dtype=np.int64) * chunk_bytes
    last_size = n - int(base[-1])  # only the final chunk may be short
    for step in range(chunk_bytes):
        if step >= last_size and n_chunks == 1:
            break
        act = slice(0, n_chunks if step < last_size else n_chunks - 1)
        if act.stop == 0:
            break
        p = pos[act]
        b = np.minimum(p >> 3, stream.size - 3)  # stay in-bounds if corrupt
        window = ((stream[b].astype(np.uint32) << 16)
                  | (stream[b + 1].astype(np.uint32) << 8)
                  | stream[b + 2].astype(np.uint32))
        idx = (window >> (24 - m - (p & 7))) & ((1 << m) - 1)
        ln = table_len[idx]
        if not ln.all():
            raise EntropyError("corrupt entropy stream: unassigned codeword")
        out[base[act] + step] = table_sym[idx]
        pos[act] = p + ln
    # every chunk must land exactly on the next chunk's gap-array offset
    expected_ends = np.concatenate([offsets[1:], [total_bits]])
    if not np.array_equal(pos, expected_ends):
        raise EntropyError("corrupt entropy stream: chunk boundary mismatch")
    return out.tobytes()
