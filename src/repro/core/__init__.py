"""FZ core: the paper's compression pipeline as a composable JAX module."""
from . import baselines, metrics  # noqa: F401
from . import encode as encode_mod  # noqa: F401  (zero-block encoder stage)
from . import quant as quant_mod  # noqa: F401
from . import shuffle as shuffle_mod  # noqa: F401
from .encode import BLOCK_BYTES, BLOCK_WORDS  # noqa: F401
from .fz import (FZCompressed, FZConfig, compress, decompress, roundtrip,  # noqa: F401
                 tree_compress, tree_decompress)
from .quant import dual_dequantize, dual_quantize, lorenzo_delta, lorenzo_inverse  # noqa: F401
from .shuffle import TILE, bitshuffle, bitunshuffle, transpose16  # noqa: F401
