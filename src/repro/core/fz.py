"""FZ public API: jit-safe error-bounded lossy (de)compression containers.

Pipeline (paper Fig. 1):  optimized dual-quantization -> bitshuffle ->
zero-block encoding. All stages are fixed-shape jnp programs, so a compressed
tensor is an ordinary pytree that can flow through jit / shard_map /
collectives — this is what makes the compressor a first-class distributed
feature (gradient compression, KV-cache pages, checkpoint payloads).

Three execution paths, selected by ``FZConfig.use_kernels`` /
``FZConfig.kernel_mode``:

  * ``use_kernels=False`` — pure-jnp reference (core.quant/shuffle/encode),
    the oracle everything else is pinned against;
  * ``use_kernels=True, kernel_mode="staged"`` — the per-stage Pallas kernels
    (fused quant kernel, fused shuffle+flag kernel, XLA ``cumsum``/``nonzero``
    phase-2 epilogue); the u16 code stream round-trips HBM between launches.
    Retained as a second oracle next to the reference;
  * ``use_kernels=True, kernel_mode="fused"`` — one compress megakernel and
    one decompress megakernel
    (kernels/fused_compress.py, kernels/fused_decode.py): quant + Lorenzo +
    bitshuffle + flagging + phase-2 compaction in a single launch (and the
    full inverse pipeline in another), with the code stream, shuffled words
    and payload offsets living entirely in VMEM/SMEM scratch. With the
    exact-outlier channel on, quantization routes through the reference to
    harvest residuals and the rest stays fused (see
    kernels/ops.py:fused_compress_stages for the documented reason).

All three produce bit-identical containers and reconstructions (pinned by
the three-way property suite in tests/test_fz_properties.py).

``kernel_mode="auto"`` (the default) resolves to one of the concrete paths
per workload via :mod:`repro.tune`: the persistently cached, parity-gated
winner of an empirical sweep when one exists for this
``(backend, op, shape-bucket, dtype, arch)``, else a **backend-aware static
fallback ordering**. The ordering matters and is deliberate: under the
Pallas interpreter (every non-TPU backend today) the fused megakernels'
sequential grid executes in Python and ``BENCH_ci.json`` measures fused
compress ~4x *slower* than staged — so interpret-class backends fall back
staged-before-fused, while TPU keeps fused-first (single launch, no HBM
round-trip for the code stream). Resolution happens in the *eager* public
wrappers before the jitted inner is entered, so every jit cache key is a
concrete resolved config — a later cache update can never leave a stale
"auto" trace behind.

Telemetry: the public entry points are thin eager wrappers over the jitted
pipelines. When called eagerly they bump ``fz_dispatches{op=...}`` counters
and compressed-stream size histograms in :mod:`repro.obs` and open an
``fz.<op>`` span; when reached from inside an enclosing trace they fall
straight through to the jitted inner (a trace is not a dispatch — counting
there would tally compilations, not work). The batched page entry points
(``compress_batch_with_eb`` / ``decompress_batch``) live here for the same
reason: one vmapped launch is one dispatch, and keeping the counting next to
the launch is what lets the kvpool's ``decompress_dispatches`` stat and the
fz-level dispatch counter agree exactly. ``decompress_unmetered`` bypasses
the counters — it exists for the error-bound sentinels, whose sampled
roundtrip checks must not pollute the dispatch accounting they audit.
"""
from __future__ import annotations

import dataclasses
import struct
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs

from . import encode as enc
from . import entropy as ent
from . import quant, shuffle


@dataclasses.dataclass(frozen=True)
class FZConfig:
    """Static compressor configuration (hashable; safe as a jit static arg)."""
    eb: float = 1e-3               # error bound (absolute, or relative to range)
    eb_mode: str = "rel"           # "abs" | "rel" (relative to value range, paper-style)
    code_mode: str = "sign_mag"    # "sign_mag" (paper) | "zigzag" (beyond-paper)
    capacity_frac: float = 1.0     # payload capacity as fraction of worst case
    outlier_frac: float = 1 / 256  # exact-outlier side-channel capacity fraction
    exact_outliers: bool = True    # strict error bound (beyond-paper); False = paper-faithful
    use_kernels: bool = False      # route hot stages through Pallas kernels
    kernel_mode: str = "auto"      # "auto" tuned | "fused" megakernels | "staged"

    def __post_init__(self):
        if self.kernel_mode not in ("auto", "fused", "staged"):
            raise ValueError(f"unknown kernel_mode {self.kernel_mode!r}")

    def payload_capacity(self, n: int) -> int:
        n_blocks = self.n_blocks(n)
        return max(1, int(n_blocks * self.capacity_frac))

    def outlier_capacity(self, n: int) -> int:
        if not self.exact_outliers:
            return 0
        return max(1, int(n * self.outlier_frac))

    @staticmethod
    def padded_n(n: int) -> int:
        return (n + shuffle.TILE - 1) // shuffle.TILE * shuffle.TILE

    @classmethod
    def n_blocks(cls, n: int) -> int:
        return cls.padded_n(n) // enc.BLOCK_WORDS


@partial(jax.tree_util.register_dataclass,
         data_fields=("bitflags", "payload", "nnz_blocks", "outlier_idx",
                      "outlier_val", "n_outliers", "eb_abs"),
         meta_fields=("shape", "dtype_name"))
@dataclasses.dataclass
class FZCompressed:
    """Fixed-shape compressed tensor (a pytree; jit/collective-safe)."""
    bitflags: jax.Array        # u32[ceil(n_blocks/32)]
    payload: jax.Array         # u16[capacity, 8]
    nnz_blocks: jax.Array      # i32[] — used payload prefix
    outlier_idx: jax.Array     # i32[K]
    outlier_val: jax.Array     # i32[K]
    n_outliers: jax.Array      # i32[]
    eb_abs: jax.Array          # f32[] — resolved absolute error bound
    shape: tuple[int, ...]     # static: original tensor shape
    dtype_name: str            # static: original dtype

    @property
    def n(self) -> int:
        out = 1
        for s in self.shape:
            out *= s
        return out

    def used_bytes(self) -> jax.Array:
        return enc.used_bytes(FZConfig.n_blocks(self.n), self.nnz_blocks, self.n_outliers)

    def raw_bytes(self) -> int:
        return self.n * jnp.dtype(self.dtype_name).itemsize

    def compression_ratio(self) -> jax.Array:
        return self.raw_bytes() / self.used_bytes().astype(jnp.float32)

    def wire_bytes(self) -> int:
        """Bytes actually moved if this container crosses a link (capacity-sized)."""
        return int(sum(leaf.size * leaf.dtype.itemsize
                       for leaf in jax.tree.leaves(self)))


def resolve_eb(data: jax.Array, cfg: FZConfig) -> jax.Array:
    if cfg.eb_mode == "abs":
        return jnp.float32(cfg.eb)
    if cfg.eb_mode == "rel":
        rng = jnp.max(data) - jnp.min(data)
        # floor at eb*max|x|: keeps constant fields finite (range == 0) and
        # bounds pre-quantization codes by 1/(2*eb) — no int32 overflow
        maxabs = jnp.max(jnp.abs(data))
        eb = cfg.eb * jnp.maximum(rng, maxabs).astype(jnp.float32)
        return jnp.maximum(eb, jnp.float32(1e-30))
    raise ValueError(f"unknown eb_mode {cfg.eb_mode!r}")


def _fused(cfg: FZConfig) -> bool:
    return cfg.use_kernels and cfg.kernel_mode == "fused"


def _resolved(cfg: FZConfig, direction: str, n: int, dtype_name: str) -> FZConfig:
    """Resolve ``kernel_mode="auto"`` to a concrete execution path.

    Called by every eager public entry point *before* the jitted inner, so
    jit caches key on the resolved config. The tuned winner comes from
    :func:`repro.tune.resolve_fz` (cache hit) or its backend-aware static
    fallback (cache miss): staged-before-fused on interpret-class backends
    — the measured 4x fused-compress interpreter regression — fused-first
    on TPU. See the module docstring for the full ordering rationale.
    """
    if not (cfg.use_kernels and cfg.kernel_mode == "auto"):
        return cfg
    from repro import tune
    impl = tune.resolve_fz(direction, n, dtype_name)
    if impl == "reference":
        return dataclasses.replace(cfg, use_kernels=False, kernel_mode="staged")
    return dataclasses.replace(cfg, kernel_mode=impl)


def _static_auto(cfg: FZConfig) -> FZConfig:
    """Last-ditch "auto" resolution for internal callers that bypass the
    public wrappers (direct ``_*_jit`` use): static backend fallback only —
    deterministic per backend, no cache lookup, so a jit trace keyed on an
    "auto" config can never go stale against a cache update."""
    if not (cfg.use_kernels and cfg.kernel_mode == "auto"):
        return cfg
    from repro.tune import dispatch
    return dataclasses.replace(cfg, kernel_mode=dispatch.fz_fallback_mode())


def _stages(cfg: FZConfig):
    """Pick reference vs staged-Pallas implementations of the hot stages.

    The fused megakernel path doesn't decompose into these three stages —
    ``_compress_core`` / ``decompress`` route it wholesale via ``_fused``.
    """
    if cfg.use_kernels:
        from repro.kernels import ops as kops
        return kops.lorenzo_quantize, kops.bitshuffle_flag_encode, kops.bitunshuffle
    def ref_quant(data, eb, *, code_mode, outlier_capacity):
        with obs.span("fz.stage.quantize", backend="reference"):
            return quant.dual_quantize(data, eb, code_mode=code_mode,
                                       outlier_capacity=outlier_capacity)
    def ref_shuffle_encode(codes_flat, *, capacity):
        with obs.span("fz.stage.shuffle_encode", backend="reference"):
            shuffled = shuffle.bitshuffle(codes_flat)
            return enc.encode(shuffled, capacity=capacity)
    def ref_unshuffle(words_flat):
        with obs.span("fz.stage.unshuffle", backend="reference"):
            return shuffle.bitunshuffle(words_flat)
    return ref_quant, ref_shuffle_encode, ref_unshuffle


def _source_dtype_name(data: jax.Array) -> str:
    """Dtype the container's byte accounting is charged against.

    Captured from the *incoming* array before the pipeline's internal
    float32 cast, so a bfloat16 KV page reports ``raw_bytes() == n * 2``
    (not the 2x-inflated float32 figure) and ``compression_ratio()`` is
    honest. Non-float inputs are charged as the float32 they become.
    """
    return str(data.dtype) if jnp.issubdtype(data.dtype, jnp.floating) \
        else "float32"


def _path(cfg: FZConfig) -> str:
    """Execution-path label for metrics/spans."""
    if _fused(cfg):
        return "fused"
    return "staged" if cfg.use_kernels else "reference"


def _count_dispatch(op: str, cfg: FZConfig, out: FZCompressed | None = None) -> None:
    """One eager jit launch = one dispatch. Callers gate on
    ``jax.core.trace_state_clean()`` so traces are never tallied as work."""
    obs.counter("fz_dispatches", op=op, path=_path(cfg)).inc()
    if out is not None:
        obs.histogram("fz_raw_bytes", op=op).observe(out.raw_bytes())
        obs.histogram("fz_wire_bytes", op=op).observe(out.wire_bytes())


@partial(jax.jit, static_argnames=("cfg",))
def _compress_jit(data: jax.Array, cfg: FZConfig) -> FZCompressed:
    cfg = _static_auto(cfg)
    dtype_name = _source_dtype_name(data)
    data = data.astype(jnp.float32)
    eb = resolve_eb(data, cfg)
    return _compress_core(data, eb, cfg, dtype_name)


def compress(data: jax.Array, cfg: FZConfig) -> FZCompressed:
    """Error-bounded lossy compression of a 1-3D float array.

    The source dtype is recorded in the container (``dtype_name``) for byte
    accounting; the quantization math itself always runs in float32.
    """
    cfg = _resolved(cfg, "compress", int(data.size), _source_dtype_name(data))
    if not jax.core.trace_state_clean():
        return _compress_jit(data, cfg)
    with obs.span("fz.compress", n=int(data.size), path=_path(cfg)):
        out = _compress_jit(data, cfg)
    _count_dispatch("compress", cfg, out)
    return out


@partial(jax.jit, static_argnames=("cfg",))
def _compress_with_eb_jit(data: jax.Array, eb_abs: jax.Array,
                          cfg: FZConfig) -> FZCompressed:
    cfg = _static_auto(cfg)
    dtype_name = _source_dtype_name(data)
    data = data.astype(jnp.float32)
    eb = jnp.maximum(jnp.asarray(eb_abs, jnp.float32), jnp.float32(1e-30))
    return _compress_core(data, eb, cfg, dtype_name)


def compress_with_eb(data: jax.Array, eb_abs: jax.Array, cfg: FZConfig) -> FZCompressed:
    """Compress with a caller-supplied *absolute* error bound (traced scalar).

    Page-granular compression (serve/kvpool) needs every chunk of a tensor
    quantized against one shared bound: the reconstruction grid is then
    ``round(x / 2eb) * 2eb`` independent of how the tensor was chunked, so
    per-page roundtrips are bit-identical to a whole-tensor roundtrip. Because
    ``eb_abs`` is traced (not baked into ``cfg``), all same-shaped pages share
    a single jit trace.
    """
    cfg = _resolved(cfg, "compress", int(data.size), _source_dtype_name(data))
    if not jax.core.trace_state_clean():
        return _compress_with_eb_jit(data, eb_abs, cfg)
    with obs.span("fz.compress", n=int(data.size), path=_path(cfg)):
        out = _compress_with_eb_jit(data, eb_abs, cfg)
    _count_dispatch("compress", cfg, out)
    return out


def _compress_core(data: jax.Array, eb: jax.Array, cfg: FZConfig,
                   dtype_name: str = "float32") -> FZCompressed:
    if _fused(cfg):
        from repro.kernels import ops as kops
        bitflags, payload, nnz, oidx, oval, n_over = kops.fused_compress_stages(
            data, eb, code_mode=cfg.code_mode,
            capacity=cfg.payload_capacity(data.size),
            outlier_capacity=cfg.outlier_capacity(data.size))
        return FZCompressed(bitflags=bitflags, payload=payload, nnz_blocks=nnz,
                            outlier_idx=oidx, outlier_val=oval,
                            n_outliers=jnp.minimum(n_over, oidx.size).astype(jnp.int32),
                            eb_abs=eb, shape=tuple(data.shape), dtype_name=dtype_name)
    quantize, shuffle_encode, _ = _stages(cfg)
    codes, oidx, oval, n_over = quantize(
        data, eb, code_mode=cfg.code_mode,
        outlier_capacity=cfg.outlier_capacity(data.size))
    flat = shuffle.pad_to_tiles(codes.reshape(-1))
    bitflags, payload, nnz = shuffle_encode(flat, capacity=cfg.payload_capacity(data.size))
    return FZCompressed(bitflags=bitflags, payload=payload, nnz_blocks=nnz,
                        outlier_idx=oidx, outlier_val=oval,
                        n_outliers=jnp.minimum(n_over, oidx.size).astype(jnp.int32),
                        eb_abs=eb, shape=tuple(data.shape), dtype_name=dtype_name)


@partial(jax.jit, static_argnames=("cfg",))
def _decompress_jit(c: FZCompressed, cfg: FZConfig) -> jax.Array:
    cfg = _static_auto(cfg)
    if _fused(cfg):
        from repro.kernels import ops as kops
        return kops.fused_decompress(
            c.bitflags, c.payload, c.eb_abs, shape=c.shape,
            code_mode=cfg.code_mode,
            outlier_idx=c.outlier_idx if cfg.exact_outliers else None,
            outlier_val=c.outlier_val if cfg.exact_outliers else None)
    _, _, unshuffle = _stages(cfg)
    words = enc.decode(c.bitflags, c.payload, n_blocks=FZConfig.n_blocks(c.n))
    codes = unshuffle(words)[: c.n]
    oidx = c.outlier_idx if cfg.exact_outliers else None
    oval = c.outlier_val if cfg.exact_outliers else None
    return quant.dual_dequantize(codes, c.eb_abs, c.shape, code_mode=cfg.code_mode,
                                 outlier_idx=oidx, outlier_val=oval)


def decompress(c: FZCompressed, cfg: FZConfig) -> jax.Array:
    """Inverse pipeline: decode -> bit-unshuffle -> inverse Lorenzo -> dequant."""
    cfg = _resolved(cfg, "decompress", c.n, c.dtype_name)
    if not jax.core.trace_state_clean():
        return _decompress_jit(c, cfg)
    with obs.span("fz.decompress", n=c.n, path=_path(cfg)):
        out = _decompress_jit(c, cfg)
    _count_dispatch("decompress", cfg)
    return out


def decompress_unmetered(c: FZCompressed, cfg: FZConfig) -> jax.Array:
    """``decompress`` without dispatch counting/spans — for the error-bound
    sentinels' sampled roundtrip checks, which must not perturb the dispatch
    accounting they audit (same compiled program, bit-identical output)."""
    return _decompress_jit(c, _resolved(cfg, "decompress", c.n, c.dtype_name))


def roundtrip(data: jax.Array, cfg: FZConfig):
    """compress + decompress; returns (reconstruction, container)."""
    c = compress(data, cfg)
    return decompress(c, cfg), c


# ---------------------------------------------------------------------------
# Batched page entry points (one vmapped launch = one counted dispatch)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("cfg",))
def _compress_batch_jit(pages_flat, eb_abs, cfg: FZConfig):
    cfg = _static_auto(cfg)
    return jax.vmap(lambda d: _compress_with_eb_jit(d, eb_abs, cfg))(pages_flat)


def compress_batch_with_eb(pages_flat: jax.Array, eb_abs: jax.Array,
                           cfg: FZConfig) -> FZCompressed:
    """vmap ``compress_with_eb`` over same-shaped rows: one dispatch for the
    whole set. Elementwise math at a shared traced bound — each row is
    bit-identical to a single-row ``compress_with_eb`` call. This is the
    kvpool cold tier's batched park path."""
    cfg = _resolved(cfg, "compress", int(pages_flat.size // pages_flat.shape[0]),
                    _source_dtype_name(pages_flat))
    if not jax.core.trace_state_clean():
        return _compress_batch_jit(pages_flat, eb_abs, cfg)
    with obs.span("fz.compress_batch", rows=int(pages_flat.shape[0]),
                  path=_path(cfg)):
        out = _compress_batch_jit(pages_flat, eb_abs, cfg)
    _count_dispatch("compress", cfg)
    obs.histogram("fz_wire_bytes", op="compress").observe(out.wire_bytes())
    return out


@partial(jax.jit, static_argnames=("cfg",))
def _decompress_batch_jit(comp: FZCompressed, cfg: FZConfig):
    cfg = _static_auto(cfg)
    return jax.vmap(lambda c: _decompress_jit(c, cfg))(comp)


def decompress_batch(comp: FZCompressed, cfg: FZConfig) -> jax.Array:
    """vmap ``decompress`` over a leaf-stacked container batch (one counted
    dispatch) — the kvpool's batched transient cold read."""
    cfg = _resolved(cfg, "decompress", comp.n, comp.dtype_name)
    if not jax.core.trace_state_clean():
        return _decompress_batch_jit(comp, cfg)
    with obs.span("fz.decompress_batch", rows=int(comp.payload.shape[0]),
                  path=_path(cfg)):
        out = _decompress_batch_jit(comp, cfg)
    _count_dispatch("decompress", cfg)
    return out


# ---------------------------------------------------------------------------
# Pytree helpers (gradients, optimizer states, checkpoints)
# ---------------------------------------------------------------------------

def tree_compress(tree: Any, cfg: FZConfig) -> Any:
    """Compress every float leaf of a pytree (leaves >= 1 tile; small leaves pass through)."""
    def leaf_fn(x):
        if isinstance(x, jax.Array) and jnp.issubdtype(x.dtype, jnp.floating) \
                and x.size >= shuffle.TILE and x.ndim <= 3:
            return compress(x, cfg)
        return x
    return jax.tree.map(leaf_fn, tree)


def tree_decompress(tree: Any, cfg: FZConfig, dtypes: Any | None = None) -> Any:
    def leaf_fn(x):
        return decompress(x, cfg) if isinstance(x, FZCompressed) else x
    out = jax.tree.map(leaf_fn, tree, is_leaf=lambda x: isinstance(x, FZCompressed))
    if dtypes is not None:
        out = jax.tree.map(lambda x, d: x.astype(d), out, dtypes)
    return out


# ---------------------------------------------------------------------------
# Serialized byte containers (cold tier / checkpoints)
#
# The pytree container above is the hot-path wire format: fixed shapes,
# jit/collective-safe, capacity-padded. When a container leaves the compute
# graph — parked KV pages, checkpoint leaves — it is serialized to the exact
# versioned byte stream below, optionally with the second-stage entropy coder
# (core/entropy.py) over the payload bytes. Byte-level spec + version
# history: docs/CONTAINER_FORMAT.md. Everything here is host-side numpy and
# must never be called from inside a trace.
# ---------------------------------------------------------------------------

CONTAINER_MAGIC = b"FZGC"
CONTAINER_VERSION = 1

FLAG_ENTROPY = 1 << 0    # payload section is a core.entropy blob
FLAG_ZIGZAG = 1 << 1     # zigzag quantization codes (else sign-magnitude)
FLAG_OUTLIERS = 1 << 2   # exact-outlier channel present in the stream

ENTROPY_MIN_GAIN = 0.02      # probe must predict >= 2% saving to encode
_MIN_ENTROPY_BYTES = 256     # below this the blob overhead can't win

_DTYPE_CODES = {"float32": 0, "bfloat16": 1, "float16": 2, "float64": 3}
_DTYPE_NAMES = {v: k for k, v in _DTYPE_CODES.items()}

_HDR = struct.Struct("<4sHHBBH")   # magic, version, flags, ndim, dtype, rsvd
_TAIL = struct.Struct("<QQfQ")     # nnz, n_outliers, eb_abs, payload_len
_LEGACY_HDR_BYTES = 28             # i64 n/nnz/n_out + f32 eb (pre-v1 streams)


class FZFormatError(ValueError):
    """Raised for malformed, truncated, or unsupported serialized containers."""


def to_bytes(c: FZCompressed, cfg: FZConfig, *, entropy: bool | str = "auto",
             chunk_bytes: int = ent.DEFAULT_CHUNK,
             tier: str | None = None) -> bytes:
    """Serialize a container to the exact v1 byte stream.

    ``entropy``: ``"auto"`` probes the payload byte histogram
    (`core.entropy.plan`) and entropy-codes only when the *exact* predicted
    blob is >= ``ENTROPY_MIN_GAIN`` smaller; ``True``/``False`` force the
    choice. The selection is recorded in the header flags so
    :func:`from_bytes` routes transparently. ``tier`` labels the
    ``entropy_stage`` counters. Ratio-EWMA feeding (`obs.note_ratio`) is
    deliberately left to callers — they know their sampling discipline; a
    per-call EWMA here would let ordinary page-to-page variance trip the
    ratio-drift sentinel.
    """
    if entropy not in (True, False, "auto"):
        raise ValueError(f"entropy must be True/False/'auto', got {entropy!r}")
    if c.dtype_name not in _DTYPE_CODES:
        raise FZFormatError(f"unserializable container dtype {c.dtype_name!r}")
    nnz = int(c.nnz_blocks)
    rows = min(nnz, int(c.payload.shape[0]))
    n_out = int(c.n_outliers)
    payload = np.asarray(c.payload)[:rows].astype("<u2").tobytes()

    selected = False
    body = payload
    if entropy is True or (entropy == "auto"
                           and len(payload) >= _MIN_ENTROPY_BYTES):
        counts = np.bincount(np.frombuffer(payload, np.uint8), minlength=256)
        lengths, est = ent.plan(counts, len(payload), chunk_bytes)
        if entropy is True or est <= len(payload) * (1.0 - ENTROPY_MIN_GAIN):
            blob = ent.encode(payload, chunk_bytes, lengths=lengths)
            if entropy is True or len(blob) < len(payload):
                selected, body = True, blob
    obs.counter("entropy_stage", op="encode",
                selected=str(selected).lower(), tier=tier or "adhoc").inc()

    flags = ((FLAG_ENTROPY if selected else 0)
             | (FLAG_ZIGZAG if cfg.code_mode == "zigzag" else 0)
             | (FLAG_OUTLIERS if cfg.exact_outliers else 0))
    parts = [
        _HDR.pack(CONTAINER_MAGIC, CONTAINER_VERSION, flags, len(c.shape),
                  _DTYPE_CODES[c.dtype_name], 0),
        np.asarray(c.shape, "<u8").tobytes(),
        _TAIL.pack(nnz, n_out, float(c.eb_abs), len(body)),
        np.asarray(c.bitflags).astype("<u4").tobytes(),
        body,
    ]
    if flags & FLAG_OUTLIERS:
        parts.append(np.asarray(c.outlier_idx)[:n_out].astype("<i4").tobytes())
        parts.append(np.asarray(c.outlier_val)[:n_out].astype("<i4").tobytes())
    return b"".join(parts)


def _np_slice(raw: memoryview, dtype: str, count: int, offset: int,
              what: str) -> np.ndarray:
    itemsize = np.dtype(dtype).itemsize
    if offset + count * itemsize > len(raw):
        raise FZFormatError(f"container truncated in {what} section "
                            f"({len(raw)} bytes)")
    return np.frombuffer(raw, dtype, count, offset)


def from_bytes(raw: bytes, *, capacity: int | None = None,
               outlier_capacity: int | None = None,
               tier: str | None = None) -> tuple[FZCompressed, FZConfig]:
    """Parse a serialized container back into the fixed-shape pytree form.

    Reconstruction is *bit-exact*: payload rows past ``nnz`` are zero,
    outlier index slots past ``n_outliers`` hold ``n`` and value slots 0 —
    the same fill conventions ``compress`` produces — so a deserialized
    container is leaf-identical to the one serialized (at equal capacities)
    and safe to stack into vmapped batch decodes. ``capacity`` /
    ``outlier_capacity`` override the padded sizes (the kvpool passes its
    pool-wide capacities so blob-backed pages stack with slot-backed ones);
    defaults are the tightest sizes that decode exactly.

    Streams without the ``FZGC`` magic are parsed as the legacy headerless
    checkpoint stream written before the format was versioned; a version
    newer than ``CONTAINER_VERSION`` raises :class:`FZFormatError`.

    Returns ``(container, cfg)`` where ``cfg`` carries the decode-relevant
    statics (code_mode, exact_outliers) — its ``eb`` field is fixed at 0.0
    (the real bound travels in ``container.eb_abs``; keeping ``cfg`` constant
    avoids a retrace per distinct bound).
    """
    raw = memoryview(raw)
    if bytes(raw[:4]) != CONTAINER_MAGIC:
        return _from_legacy_bytes(raw, capacity=capacity,
                                  outlier_capacity=outlier_capacity, tier=tier)
    if len(raw) < _HDR.size + _TAIL.size:
        raise FZFormatError(f"container truncated: {len(raw)} bytes")
    _, version, flags, ndim, dtcode, _ = _HDR.unpack_from(raw, 0)
    if version != CONTAINER_VERSION:
        raise FZFormatError(
            f"FZ container version {version} is not supported by this build "
            f"(max {CONTAINER_VERSION}); upgrade repro or re-serialize with "
            f"a matching version")
    if dtcode not in _DTYPE_NAMES:
        raise FZFormatError(f"unknown container dtype code {dtcode}")
    off = _HDR.size
    shape = tuple(int(v) for v in _np_slice(raw, "<u8", ndim, off, "shape"))
    off += 8 * ndim
    nnz, n_out, eb_abs, payload_len = _TAIL.unpack_from(raw, off)
    off += _TAIL.size
    n = 1
    for s in shape:
        n *= s
    fw = enc.flag_words(FZConfig.n_blocks(n))
    bitflags = _np_slice(raw, "<u4", fw, off, "bitflags").copy()
    off += 4 * fw
    if off + payload_len > len(raw):
        raise FZFormatError(f"container truncated in payload section "
                            f"({len(raw)} bytes)")
    body = bytes(raw[off:off + payload_len])
    off += payload_len
    if flags & FLAG_ENTROPY:
        body = ent.decode(body)
    obs.counter("entropy_stage", op="decode",
                selected=str(bool(flags & FLAG_ENTROPY)).lower(),
                tier=tier or "adhoc").inc()

    rows = len(body) // enc.BLOCK_BYTES
    cap = max(rows, 1) if capacity is None else capacity
    if cap < rows:
        raise FZFormatError(f"capacity {cap} < {rows} stored payload rows")
    payload = np.zeros((cap, enc.BLOCK_WORDS), np.uint16)
    payload[:rows] = np.frombuffer(body, "<u2").reshape(rows, enc.BLOCK_WORDS)

    if flags & FLAG_OUTLIERS:
        oidx = _np_slice(raw, "<i4", n_out, off, "outlier idx")
        off += 4 * n_out
        oval = _np_slice(raw, "<i4", n_out, off, "outlier val")
        ocap = max(n_out, 1) if outlier_capacity is None else outlier_capacity
        if ocap < n_out:
            raise FZFormatError(f"outlier_capacity {ocap} < {n_out} stored")
    else:
        oidx = oval = np.zeros(0, np.int32)
        ocap = outlier_capacity or 0
    oi = np.full((ocap,), n, np.int32)
    oi[:n_out if flags & FLAG_OUTLIERS else 0] = oidx
    ov = np.zeros((ocap,), np.int32)
    ov[:n_out if flags & FLAG_OUTLIERS else 0] = oval

    c = FZCompressed(
        bitflags=jnp.asarray(bitflags), payload=jnp.asarray(payload),
        nnz_blocks=jnp.int32(nnz), outlier_idx=jnp.asarray(oi),
        outlier_val=jnp.asarray(ov), n_outliers=jnp.int32(n_out),
        eb_abs=jnp.float32(eb_abs), shape=shape,
        dtype_name=_DTYPE_NAMES[dtcode])
    cfg = FZConfig(eb=0.0, eb_mode="abs",
                   code_mode="zigzag" if flags & FLAG_ZIGZAG else "sign_mag",
                   exact_outliers=bool(flags & FLAG_OUTLIERS),
                   use_kernels=False)
    return c, cfg


def _from_legacy_bytes(raw: memoryview, *, capacity: int | None,
                       outlier_capacity: int | None,
                       tier: str | None) -> tuple[FZCompressed, FZConfig]:
    """Parse the headerless pre-v1 checkpoint stream (ckpt/checkpoint.py
    before the container format was versioned): i64 [n, nnz, n_outliers],
    f32 eb_abs, u32 bitflags, u16 payload rows, i32 outlier idx + val."""
    if len(raw) < _LEGACY_HDR_BYTES:
        raise FZFormatError(f"not an FZ container: {len(raw)} bytes, no magic")
    n, nnz, n_out = (int(v) for v in np.frombuffer(raw, "<i8", 3, 0))
    eb_abs = float(np.frombuffer(raw, "<f4", 1, 24)[0])
    if n <= 0 or nnz < 0 or n_out < 0:
        raise FZFormatError("not an FZ container: no magic and implausible "
                            "legacy header")
    fw = enc.flag_words(FZConfig.n_blocks(n))
    expect = _LEGACY_HDR_BYTES + 4 * fw + enc.BLOCK_BYTES * nnz + 8 * n_out
    if len(raw) != expect:
        raise FZFormatError(
            f"not an FZ container: no magic and legacy stream length "
            f"mismatch ({len(raw)} bytes, expected {expect})")
    off = _LEGACY_HDR_BYTES
    bitflags = np.frombuffer(raw, "<u4", fw, off).copy()
    off += 4 * fw
    rows = np.frombuffer(raw, "<u2", enc.BLOCK_WORDS * nnz, off
                         ).reshape(nnz, enc.BLOCK_WORDS)
    off += enc.BLOCK_BYTES * nnz
    oidx = np.frombuffer(raw, "<i4", n_out, off)
    off += 4 * n_out
    oval = np.frombuffer(raw, "<i4", n_out, off)

    cap = max(nnz, 1) if capacity is None else capacity
    payload = np.zeros((cap, enc.BLOCK_WORDS), np.uint16)
    payload[:nnz] = rows
    ocap = max(n_out, 1) if outlier_capacity is None else outlier_capacity
    oi = np.full((ocap,), n, np.int32)
    oi[:n_out] = oidx
    ov = np.zeros((ocap,), np.int32)
    ov[:n_out] = oval
    obs.counter("entropy_stage", op="decode", selected="false",
                tier=tier or "adhoc").inc()
    c = FZCompressed(
        bitflags=jnp.asarray(bitflags), payload=jnp.asarray(payload),
        nnz_blocks=jnp.int32(nnz), outlier_idx=jnp.asarray(oi),
        outlier_val=jnp.asarray(ov), n_outliers=jnp.int32(n_out),
        eb_abs=jnp.float32(eb_abs), shape=(n,), dtype_name="float32")
    return c, FZConfig(eb=0.0, eb_mode="abs", exact_outliers=True,
                       use_kernels=False)


def decompress_bytes(raw: bytes, *, tier: str | None = None) -> jax.Array:
    """One-call reconstruction from a serialized container (any supported
    version): parse, entropy-decode if flagged, run the jitted inverse
    pipeline. The decode routes transparently — callers never inspect the
    entropy flag themselves."""
    c, cfg = from_bytes(raw, tier=tier)
    return decompress(c, cfg)
