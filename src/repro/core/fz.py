"""FZ public API: jit-safe error-bounded lossy (de)compression containers.

Pipeline (paper Fig. 1):  optimized dual-quantization -> bitshuffle ->
zero-block encoding. All stages are fixed-shape jnp programs, so a compressed
tensor is an ordinary pytree that can flow through jit / shard_map /
collectives — this is what makes the compressor a first-class distributed
feature (gradient compression, KV-cache pages, checkpoint payloads).

Three execution paths, selected by ``FZConfig.use_kernels`` /
``FZConfig.kernel_mode``:

  * ``use_kernels=False`` — pure-jnp reference (core.quant/shuffle/encode),
    the oracle everything else is pinned against;
  * ``use_kernels=True, kernel_mode="staged"`` — the per-stage Pallas kernels
    (fused quant kernel, fused shuffle+flag kernel, XLA ``cumsum``/``nonzero``
    phase-2 epilogue); the u16 code stream round-trips HBM between launches.
    Retained as a second oracle next to the reference;
  * ``use_kernels=True, kernel_mode="fused"`` (the kernel default) — one
    compress megakernel and one decompress megakernel
    (kernels/fused_compress.py, kernels/fused_decode.py): quant + Lorenzo +
    bitshuffle + flagging + phase-2 compaction in a single launch (and the
    full inverse pipeline in another), with the code stream, shuffled words
    and payload offsets living entirely in VMEM/SMEM scratch. With the
    exact-outlier channel on, quantization routes through the reference to
    harvest residuals and the rest stays fused (see
    kernels/ops.py:fused_compress_stages for the documented reason).

All three produce bit-identical containers and reconstructions (pinned by
the three-way property suite in tests/test_fz_properties.py).

Telemetry: the public entry points are thin eager wrappers over the jitted
pipelines. When called eagerly they bump ``fz_dispatches{op=...}`` counters
and compressed-stream size histograms in :mod:`repro.obs` and open an
``fz.<op>`` span; when reached from inside an enclosing trace they fall
straight through to the jitted inner (a trace is not a dispatch — counting
there would tally compilations, not work). The batched page entry points
(``compress_batch_with_eb`` / ``decompress_batch``) live here for the same
reason: one vmapped launch is one dispatch, and keeping the counting next to
the launch is what lets the kvpool's ``decompress_dispatches`` stat and the
fz-level dispatch counter agree exactly. ``decompress_unmetered`` bypasses
the counters — it exists for the error-bound sentinels, whose sampled
roundtrip checks must not pollute the dispatch accounting they audit.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro import obs

from . import encode as enc
from . import quant, shuffle


@dataclasses.dataclass(frozen=True)
class FZConfig:
    """Static compressor configuration (hashable; safe as a jit static arg)."""
    eb: float = 1e-3               # error bound (absolute, or relative to range)
    eb_mode: str = "rel"           # "abs" | "rel" (relative to value range, paper-style)
    code_mode: str = "sign_mag"    # "sign_mag" (paper) | "zigzag" (beyond-paper)
    capacity_frac: float = 1.0     # payload capacity as fraction of worst case
    outlier_frac: float = 1 / 256  # exact-outlier side-channel capacity fraction
    exact_outliers: bool = True    # strict error bound (beyond-paper); False = paper-faithful
    use_kernels: bool = False      # route hot stages through Pallas kernels
    kernel_mode: str = "fused"     # "fused" megakernels | "staged" per-stage oracle

    def __post_init__(self):
        if self.kernel_mode not in ("fused", "staged"):
            raise ValueError(f"unknown kernel_mode {self.kernel_mode!r}")

    def payload_capacity(self, n: int) -> int:
        n_blocks = self.n_blocks(n)
        return max(1, int(n_blocks * self.capacity_frac))

    def outlier_capacity(self, n: int) -> int:
        if not self.exact_outliers:
            return 0
        return max(1, int(n * self.outlier_frac))

    @staticmethod
    def padded_n(n: int) -> int:
        return (n + shuffle.TILE - 1) // shuffle.TILE * shuffle.TILE

    @classmethod
    def n_blocks(cls, n: int) -> int:
        return cls.padded_n(n) // enc.BLOCK_WORDS


@partial(jax.tree_util.register_dataclass,
         data_fields=("bitflags", "payload", "nnz_blocks", "outlier_idx",
                      "outlier_val", "n_outliers", "eb_abs"),
         meta_fields=("shape", "dtype_name"))
@dataclasses.dataclass
class FZCompressed:
    """Fixed-shape compressed tensor (a pytree; jit/collective-safe)."""
    bitflags: jax.Array        # u32[ceil(n_blocks/32)]
    payload: jax.Array         # u16[capacity, 8]
    nnz_blocks: jax.Array      # i32[] — used payload prefix
    outlier_idx: jax.Array     # i32[K]
    outlier_val: jax.Array     # i32[K]
    n_outliers: jax.Array      # i32[]
    eb_abs: jax.Array          # f32[] — resolved absolute error bound
    shape: tuple[int, ...]     # static: original tensor shape
    dtype_name: str            # static: original dtype

    @property
    def n(self) -> int:
        out = 1
        for s in self.shape:
            out *= s
        return out

    def used_bytes(self) -> jax.Array:
        return enc.used_bytes(FZConfig.n_blocks(self.n), self.nnz_blocks, self.n_outliers)

    def raw_bytes(self) -> int:
        return self.n * jnp.dtype(self.dtype_name).itemsize

    def compression_ratio(self) -> jax.Array:
        return self.raw_bytes() / self.used_bytes().astype(jnp.float32)

    def wire_bytes(self) -> int:
        """Bytes actually moved if this container crosses a link (capacity-sized)."""
        return int(sum(leaf.size * leaf.dtype.itemsize
                       for leaf in jax.tree.leaves(self)))


def resolve_eb(data: jax.Array, cfg: FZConfig) -> jax.Array:
    if cfg.eb_mode == "abs":
        return jnp.float32(cfg.eb)
    if cfg.eb_mode == "rel":
        rng = jnp.max(data) - jnp.min(data)
        # floor at eb*max|x|: keeps constant fields finite (range == 0) and
        # bounds pre-quantization codes by 1/(2*eb) — no int32 overflow
        maxabs = jnp.max(jnp.abs(data))
        eb = cfg.eb * jnp.maximum(rng, maxabs).astype(jnp.float32)
        return jnp.maximum(eb, jnp.float32(1e-30))
    raise ValueError(f"unknown eb_mode {cfg.eb_mode!r}")


def _fused(cfg: FZConfig) -> bool:
    return cfg.use_kernels and cfg.kernel_mode == "fused"


def _stages(cfg: FZConfig):
    """Pick reference vs staged-Pallas implementations of the hot stages.

    The fused megakernel path doesn't decompose into these three stages —
    ``_compress_core`` / ``decompress`` route it wholesale via ``_fused``.
    """
    if cfg.use_kernels:
        from repro.kernels import ops as kops
        return kops.lorenzo_quantize, kops.bitshuffle_flag_encode, kops.bitunshuffle
    def ref_quant(data, eb, *, code_mode, outlier_capacity):
        with obs.span("fz.stage.quantize", backend="reference"):
            return quant.dual_quantize(data, eb, code_mode=code_mode,
                                       outlier_capacity=outlier_capacity)
    def ref_shuffle_encode(codes_flat, *, capacity):
        with obs.span("fz.stage.shuffle_encode", backend="reference"):
            shuffled = shuffle.bitshuffle(codes_flat)
            return enc.encode(shuffled, capacity=capacity)
    def ref_unshuffle(words_flat):
        with obs.span("fz.stage.unshuffle", backend="reference"):
            return shuffle.bitunshuffle(words_flat)
    return ref_quant, ref_shuffle_encode, ref_unshuffle


def _source_dtype_name(data: jax.Array) -> str:
    """Dtype the container's byte accounting is charged against.

    Captured from the *incoming* array before the pipeline's internal
    float32 cast, so a bfloat16 KV page reports ``raw_bytes() == n * 2``
    (not the 2x-inflated float32 figure) and ``compression_ratio()`` is
    honest. Non-float inputs are charged as the float32 they become.
    """
    return str(data.dtype) if jnp.issubdtype(data.dtype, jnp.floating) \
        else "float32"


def _path(cfg: FZConfig) -> str:
    """Execution-path label for metrics/spans."""
    if _fused(cfg):
        return "fused"
    return "staged" if cfg.use_kernels else "reference"


def _count_dispatch(op: str, cfg: FZConfig, out: FZCompressed | None = None) -> None:
    """One eager jit launch = one dispatch. Callers gate on
    ``jax.core.trace_state_clean()`` so traces are never tallied as work."""
    obs.counter("fz_dispatches", op=op, path=_path(cfg)).inc()
    if out is not None:
        obs.histogram("fz_raw_bytes", op=op).observe(out.raw_bytes())
        obs.histogram("fz_wire_bytes", op=op).observe(out.wire_bytes())


@partial(jax.jit, static_argnames=("cfg",))
def _compress_jit(data: jax.Array, cfg: FZConfig) -> FZCompressed:
    dtype_name = _source_dtype_name(data)
    data = data.astype(jnp.float32)
    eb = resolve_eb(data, cfg)
    return _compress_core(data, eb, cfg, dtype_name)


def compress(data: jax.Array, cfg: FZConfig) -> FZCompressed:
    """Error-bounded lossy compression of a 1-3D float array.

    The source dtype is recorded in the container (``dtype_name``) for byte
    accounting; the quantization math itself always runs in float32.
    """
    if not jax.core.trace_state_clean():
        return _compress_jit(data, cfg)
    with obs.span("fz.compress", n=int(data.size), path=_path(cfg)):
        out = _compress_jit(data, cfg)
    _count_dispatch("compress", cfg, out)
    return out


@partial(jax.jit, static_argnames=("cfg",))
def _compress_with_eb_jit(data: jax.Array, eb_abs: jax.Array,
                          cfg: FZConfig) -> FZCompressed:
    dtype_name = _source_dtype_name(data)
    data = data.astype(jnp.float32)
    eb = jnp.maximum(jnp.asarray(eb_abs, jnp.float32), jnp.float32(1e-30))
    return _compress_core(data, eb, cfg, dtype_name)


def compress_with_eb(data: jax.Array, eb_abs: jax.Array, cfg: FZConfig) -> FZCompressed:
    """Compress with a caller-supplied *absolute* error bound (traced scalar).

    Page-granular compression (serve/kvpool) needs every chunk of a tensor
    quantized against one shared bound: the reconstruction grid is then
    ``round(x / 2eb) * 2eb`` independent of how the tensor was chunked, so
    per-page roundtrips are bit-identical to a whole-tensor roundtrip. Because
    ``eb_abs`` is traced (not baked into ``cfg``), all same-shaped pages share
    a single jit trace.
    """
    if not jax.core.trace_state_clean():
        return _compress_with_eb_jit(data, eb_abs, cfg)
    with obs.span("fz.compress", n=int(data.size), path=_path(cfg)):
        out = _compress_with_eb_jit(data, eb_abs, cfg)
    _count_dispatch("compress", cfg, out)
    return out


def _compress_core(data: jax.Array, eb: jax.Array, cfg: FZConfig,
                   dtype_name: str = "float32") -> FZCompressed:
    if _fused(cfg):
        from repro.kernels import ops as kops
        bitflags, payload, nnz, oidx, oval, n_over = kops.fused_compress_stages(
            data, eb, code_mode=cfg.code_mode,
            capacity=cfg.payload_capacity(data.size),
            outlier_capacity=cfg.outlier_capacity(data.size))
        return FZCompressed(bitflags=bitflags, payload=payload, nnz_blocks=nnz,
                            outlier_idx=oidx, outlier_val=oval,
                            n_outliers=jnp.minimum(n_over, oidx.size).astype(jnp.int32),
                            eb_abs=eb, shape=tuple(data.shape), dtype_name=dtype_name)
    quantize, shuffle_encode, _ = _stages(cfg)
    codes, oidx, oval, n_over = quantize(
        data, eb, code_mode=cfg.code_mode,
        outlier_capacity=cfg.outlier_capacity(data.size))
    flat = shuffle.pad_to_tiles(codes.reshape(-1))
    bitflags, payload, nnz = shuffle_encode(flat, capacity=cfg.payload_capacity(data.size))
    return FZCompressed(bitflags=bitflags, payload=payload, nnz_blocks=nnz,
                        outlier_idx=oidx, outlier_val=oval,
                        n_outliers=jnp.minimum(n_over, oidx.size).astype(jnp.int32),
                        eb_abs=eb, shape=tuple(data.shape), dtype_name=dtype_name)


@partial(jax.jit, static_argnames=("cfg",))
def _decompress_jit(c: FZCompressed, cfg: FZConfig) -> jax.Array:
    if _fused(cfg):
        from repro.kernels import ops as kops
        return kops.fused_decompress(
            c.bitflags, c.payload, c.eb_abs, shape=c.shape,
            code_mode=cfg.code_mode,
            outlier_idx=c.outlier_idx if cfg.exact_outliers else None,
            outlier_val=c.outlier_val if cfg.exact_outliers else None)
    _, _, unshuffle = _stages(cfg)
    words = enc.decode(c.bitflags, c.payload, n_blocks=FZConfig.n_blocks(c.n))
    codes = unshuffle(words)[: c.n]
    oidx = c.outlier_idx if cfg.exact_outliers else None
    oval = c.outlier_val if cfg.exact_outliers else None
    return quant.dual_dequantize(codes, c.eb_abs, c.shape, code_mode=cfg.code_mode,
                                 outlier_idx=oidx, outlier_val=oval)


def decompress(c: FZCompressed, cfg: FZConfig) -> jax.Array:
    """Inverse pipeline: decode -> bit-unshuffle -> inverse Lorenzo -> dequant."""
    if not jax.core.trace_state_clean():
        return _decompress_jit(c, cfg)
    with obs.span("fz.decompress", n=c.n, path=_path(cfg)):
        out = _decompress_jit(c, cfg)
    _count_dispatch("decompress", cfg)
    return out


def decompress_unmetered(c: FZCompressed, cfg: FZConfig) -> jax.Array:
    """``decompress`` without dispatch counting/spans — for the error-bound
    sentinels' sampled roundtrip checks, which must not perturb the dispatch
    accounting they audit (same compiled program, bit-identical output)."""
    return _decompress_jit(c, cfg)


def roundtrip(data: jax.Array, cfg: FZConfig):
    """compress + decompress; returns (reconstruction, container)."""
    c = compress(data, cfg)
    return decompress(c, cfg), c


# ---------------------------------------------------------------------------
# Batched page entry points (one vmapped launch = one counted dispatch)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("cfg",))
def _compress_batch_jit(pages_flat, eb_abs, cfg: FZConfig):
    return jax.vmap(lambda d: _compress_with_eb_jit(d, eb_abs, cfg))(pages_flat)


def compress_batch_with_eb(pages_flat: jax.Array, eb_abs: jax.Array,
                           cfg: FZConfig) -> FZCompressed:
    """vmap ``compress_with_eb`` over same-shaped rows: one dispatch for the
    whole set. Elementwise math at a shared traced bound — each row is
    bit-identical to a single-row ``compress_with_eb`` call. This is the
    kvpool cold tier's batched park path."""
    if not jax.core.trace_state_clean():
        return _compress_batch_jit(pages_flat, eb_abs, cfg)
    with obs.span("fz.compress_batch", rows=int(pages_flat.shape[0]),
                  path=_path(cfg)):
        out = _compress_batch_jit(pages_flat, eb_abs, cfg)
    _count_dispatch("compress", cfg)
    obs.histogram("fz_wire_bytes", op="compress").observe(out.wire_bytes())
    return out


@partial(jax.jit, static_argnames=("cfg",))
def _decompress_batch_jit(comp: FZCompressed, cfg: FZConfig):
    return jax.vmap(lambda c: _decompress_jit(c, cfg))(comp)


def decompress_batch(comp: FZCompressed, cfg: FZConfig) -> jax.Array:
    """vmap ``decompress`` over a leaf-stacked container batch (one counted
    dispatch) — the kvpool's batched transient cold read."""
    if not jax.core.trace_state_clean():
        return _decompress_batch_jit(comp, cfg)
    with obs.span("fz.decompress_batch", rows=int(comp.payload.shape[0]),
                  path=_path(cfg)):
        out = _decompress_batch_jit(comp, cfg)
    _count_dispatch("decompress", cfg)
    return out


# ---------------------------------------------------------------------------
# Pytree helpers (gradients, optimizer states, checkpoints)
# ---------------------------------------------------------------------------

def tree_compress(tree: Any, cfg: FZConfig) -> Any:
    """Compress every float leaf of a pytree (leaves >= 1 tile; small leaves pass through)."""
    def leaf_fn(x):
        if isinstance(x, jax.Array) and jnp.issubdtype(x.dtype, jnp.floating) \
                and x.size >= shuffle.TILE and x.ndim <= 3:
            return compress(x, cfg)
        return x
    return jax.tree.map(leaf_fn, tree)


def tree_decompress(tree: Any, cfg: FZConfig, dtypes: Any | None = None) -> Any:
    def leaf_fn(x):
        return decompress(x, cfg) if isinstance(x, FZCompressed) else x
    out = jax.tree.map(leaf_fn, tree, is_leaf=lambda x: isinstance(x, FZCompressed))
    if dtypes is not None:
        out = jax.tree.map(lambda x, d: x.astype(d), out, dtypes)
    return out
