"""Baseline compressors the paper compares against (§4.1 Baselines).

* ``cusz_like``  — cuSZ: same dual-quantization front end, radius-clipped
  quantization codes + canonical Huffman encoding (+ raw outliers). Huffman
  codebook built host-side (numpy), mirroring cuSZ's coarse-grained encoder.
  Compression ratio is exact for the emitted stream; the CR ceiling of 32
  noted by the paper emerges naturally (>=1 bit per 4-byte value).
* ``cuszx_like`` — cuSZx: block constant/non-constant splitting. Constant
  blocks (max-min <= 2eb) store one float mean; others store raw values.
* ``cuzfp_like`` — cuZFP: fixed-rate transform coding proxy — block-floating-
  point + ZFP's decorrelating lifting transform per axis + bit-plane
  truncation to the requested rate. Error-bounded mode is NOT provided,
  faithfully to cuZFP (§2.4).

These exist so every paper table/figure has both sides implemented in-repo.
They are deliberately host-side / proxy-grade: the real system under test is
`core/fz.py` (+ the optional `core/entropy.py` cold-tier stage); the
baselines only have to be ratio-exact for `benchmarks/bench_rate_distortion`
(docs/ARCHITECTURE.md maps which bench pins which layer).  The Huffman
builder used by ``cusz_like`` is the same one the entropy cold tier uses —
it lives in `core.entropy.huffman_code_lengths`.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.entropy import huffman_code_lengths as _huffman_code_lengths


# ---------------------------------------------------------------------------
# cuSZ-like: dual-quantization + canonical Huffman
# ---------------------------------------------------------------------------

CUSZ_RADIUS = 512  # cuSZ default dictionary radius (1024 bins)


@dataclasses.dataclass
class CuszLikeResult:
    reconstruction: np.ndarray
    compressed_bytes: int
    n_outliers: int

    def compression_ratio(self, raw_bytes: int) -> float:
        return raw_bytes / self.compressed_bytes


def cusz_like(data: np.ndarray, eb_abs: float) -> CuszLikeResult:
    """cuSZ-style compression (host-side; ratio-exact stream accounting)."""
    data = np.asarray(data, np.float32)
    q = np.rint(data / (2 * eb_abs)).astype(np.int64)
    delta = q.copy()
    for ax in range(q.ndim):
        delta = np.diff(delta, axis=ax, prepend=0)
    # radius clip: in-range codes -> histogram bins, out-of-range -> outliers
    inr = np.abs(delta) < CUSZ_RADIUS
    bins = (delta[inr] + CUSZ_RADIUS).astype(np.int64)
    counts = np.bincount(bins, minlength=2 * CUSZ_RADIUS)
    lengths = _huffman_code_lengths(counts)
    # stream: huffman bits for every value (outliers emit the escape bin 0)
    esc = np.count_nonzero(~inr)
    payload_bits = int((counts * lengths).sum()) + esc * max(int(lengths.max()), 1)
    codebook_bytes = 2 * CUSZ_RADIUS * 4 // 8 + 1024  # canonical lengths + header
    outlier_bytes = esc * 8  # 4B index + 4B value
    total = payload_bits // 8 + codebook_bytes + outlier_bytes + 32
    # reconstruction (outliers kept exact, as cuSZ does)
    rec_q = delta
    for ax in range(q.ndim):
        rec_q = np.cumsum(rec_q, axis=ax)
    rec = rec_q.astype(np.float32) * (2 * eb_abs)
    return CuszLikeResult(rec, total, esc)


# ---------------------------------------------------------------------------
# cuSZx-like: constant / non-constant blocks
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("block",))
def cuszx_like(data: jax.Array, eb_abs: jax.Array, block: int = 256):
    """Returns (reconstruction, compressed_bytes)."""
    flat = data.reshape(-1).astype(jnp.float32)
    pad = (-flat.size) % block
    x = jnp.pad(flat, (0, pad)).reshape(-1, block)
    lo = jnp.min(x, axis=-1, keepdims=True)
    hi = jnp.max(x, axis=-1, keepdims=True)
    const = (hi - lo) <= 2 * eb_abs
    mean = (hi + lo) / 2
    rec = jnp.where(const, mean, x).reshape(-1)[: flat.size].reshape(data.shape)
    nblocks = x.shape[0]
    n_const = jnp.sum(const, dtype=jnp.int32)
    bytes_ = (nblocks + 7) // 8 + n_const * 4 + (nblocks - n_const) * block * 4 + 32
    return rec, bytes_


# ---------------------------------------------------------------------------
# cuZFP-like: fixed-rate block transform coding (proxy)
# ---------------------------------------------------------------------------

def _zfp_lift(x: jax.Array, axis: int) -> jax.Array:
    """ZFP forward decorrelating lifting on length-4 groups along ``axis``."""
    x = jnp.moveaxis(x, axis, -1)
    a, b, c, d = x[..., 0], x[..., 1], x[..., 2], x[..., 3]
    a = a + d; a = a >> 1; d = d - a
    c = c + b; c = c >> 1; b = b - c
    a = a + c; a = a >> 1; c = c - a
    d = d + b; d = d >> 1; b = b - d
    d = d + (b >> 1); b = b - (d >> 1)
    return jnp.moveaxis(jnp.stack([a, b, c, d], axis=-1), -1, axis)


def _zfp_unlift(x: jax.Array, axis: int) -> jax.Array:
    x = jnp.moveaxis(x, axis, -1)
    a, b, c, d = x[..., 0], x[..., 1], x[..., 2], x[..., 3]
    b = b + (d >> 1); d = d - (b >> 1)
    b = b + d; d = d << 1; d = d - b
    c = c + a; a = a << 1; a = a - c
    b = b + c; c = c << 1; c = c - b
    d = d + a; a = a << 1; a = a - d
    return jnp.moveaxis(jnp.stack([a, b, c, d], axis=-1), -1, axis)


@partial(jax.jit, static_argnames=("rate_bits",))
def cuzfp_like(data: jax.Array, rate_bits: int):
    """Fixed-rate transform coder on 4^d blocks. Returns (rec, bytes).

    Block-floating-point -> lifting transform -> keep the top ``rate_bits``
    bit-planes of each 30-bit coefficient (sign-magnitude truncation).
    """
    nd = data.ndim
    shape = data.shape
    pads = [(0, (-s) % 4) for s in shape]
    x = jnp.pad(data.astype(jnp.float32), pads)
    padded = x.shape
    # gather 4^d blocks: (n0,4,n1,4,...) -> (n0,n1,...,4,4,...)
    x = x.reshape([v for s in padded for v in (s // 4, 4)])
    x = x.transpose(list(range(0, 2 * nd, 2)) + list(range(1, 2 * nd, 2)))
    block_axes = tuple(range(nd, 2 * nd))
    emax = jnp.max(jnp.abs(x), axis=block_axes, keepdims=True)
    scale = jnp.where(emax > 0, 2.0 ** (jnp.floor(jnp.log2(jnp.maximum(emax, 1e-38))) ), 1.0)
    xi = jnp.clip(jnp.rint(x / scale * (1 << 28)), -(1 << 30), (1 << 30) - 1).astype(jnp.int32)
    for ax in block_axes:
        xi = _zfp_lift(xi, ax)
    # truncate to rate_bits of 30-bit magnitude
    drop = jnp.maximum(30 - rate_bits, 0)
    mag = jnp.abs(xi)
    trunc = (mag >> drop) << drop
    xi_t = jnp.where(xi < 0, -trunc, trunc)
    for ax in reversed(block_axes):
        xi_t = _zfp_unlift(xi_t, ax)
    rec = xi_t.astype(jnp.float32) / (1 << 28) * scale
    # scatter blocks back: (n0,n1,...,4,4,...) -> (n0,4,n1,4,...) -> padded
    inv = [None] * (2 * nd)
    for i in range(nd):
        inv[2 * i] = i
        inv[2 * i + 1] = nd + i
    rec = rec.transpose(inv).reshape(padded)
    rec = rec[tuple(slice(0, s) for s in shape)]
    n_blocks = xi.size // (4 ** nd)
    bytes_ = n_blocks * (2 + (rate_bits * 4 ** nd + 7) // 8)  # exponent + planes
    return rec, bytes_
