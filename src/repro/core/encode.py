"""Fast zero-block lossless encoder (FZ-GPU §3.4), pure-JAX reference semantics.

Phase 1: partition the bitshuffled u16 stream into 16-byte blocks (8 words),
flag non-zero blocks, and pack the flags into a bit-flag array (max CR = 128,
matching the paper). In the production path phase 1 is fused into the
bitshuffle Pallas kernel exactly as the paper fuses it into the CUDA kernel.

Phase 2: exclusive prefix-sum of the flags gives each surviving block its
output offset; compaction copies surviving blocks to the payload. TPU
adaptation: CUB ``ExclusiveSum`` -> XLA parallel scan (``jnp.cumsum``); the
scatter-style CUDA compaction -> gather-based compaction
(``jnp.nonzero(size=...)`` + ``take``), which is the TPU-friendly direction.

JAX static shapes require a fixed payload *capacity*; ``nnz_blocks`` reports
the used prefix, and byte accounting uses exact used bytes.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

BLOCK_WORDS = 8          # u16 words per zero-detection block (16 bytes)
BLOCK_BYTES = 2 * BLOCK_WORDS
FLAGS_PER_WORD = 32      # bit flags packed per u32


def flag_words(n_blocks: int) -> int:
    """u32 words in the packed bit-flag array: ceil(n_blocks / 32).

    This is the stored form — what ``pack_bitflags`` produces and what v1
    serialized containers carry verbatim (docs/CONTAINER_FORMAT.md);
    ``used_bytes`` models the ideal (n_blocks+7)//8 bit packing for ratio
    accounting.
    """
    return -(-n_blocks // FLAGS_PER_WORD)


def block_flags(shuffled: jax.Array) -> jax.Array:
    """(n_words,) u16 -> (n_blocks,) bool non-zero flags."""
    if shuffled.size % BLOCK_WORDS:
        raise ValueError(f"{shuffled.size} words not a multiple of {BLOCK_WORDS}")
    return jnp.any(shuffled.reshape(-1, BLOCK_WORDS) != 0, axis=-1)


def pack_bitflags(flags: jax.Array) -> jax.Array:
    """(n_blocks,) bool -> (ceil(n/32),) u32 bit-flag array (LSB-first)."""
    n = flags.size
    pad = (-n) % FLAGS_PER_WORD
    f = jnp.pad(flags, (0, pad)).reshape(-1, FLAGS_PER_WORD).astype(jnp.uint32)
    return jnp.sum(f << jnp.arange(FLAGS_PER_WORD, dtype=jnp.uint32), axis=-1, dtype=jnp.uint32)


def unpack_bitflags(bitflags: jax.Array, n_blocks: int) -> jax.Array:
    """(W,) u32 -> (n_blocks,) bool."""
    bits = (bitflags[:, None] >> jnp.arange(FLAGS_PER_WORD, dtype=jnp.uint32)) & 1
    return bits.reshape(-1)[:n_blocks].astype(bool)


def compact_blocks(flags: jax.Array, blocks: jax.Array, *, capacity: int):
    """XLA phase-2 compaction: (flags bool[n_blocks], blocks u16[n_blocks, 8])
    -> (bitflags u32[W], payload u16[capacity, 8], nnz i32[]).

    The gather-based scan+take formulation, shared by :func:`encode` and the
    staged kernel path (``kernels.ops.bitshuffle_flag_encode``). The fused
    megakernel (kernels/fused_compress.py) replaces this wholesale with an
    in-kernel running-offset scatter; this stays as its oracle.
    """
    nnz = jnp.sum(flags, dtype=jnp.int32)
    (src,) = jnp.nonzero(flags, size=capacity, fill_value=0)
    payload = blocks[src]
    # slots past nnz replicate block 0; zero them so payload is deterministic
    payload = jnp.where(jnp.arange(capacity)[:, None] < nnz, payload, 0)
    return pack_bitflags(flags), payload.astype(jnp.uint16), nnz


@partial(jax.jit, static_argnames=("capacity",))
def encode(shuffled: jax.Array, *, capacity: int):
    """Compact non-zero blocks.

    Returns (bitflags u32[W], payload u16[capacity, 8], nnz i32[]).
    Blocks beyond ``capacity`` are dropped (callers size capacity = n_blocks
    for lossless-by-construction, or smaller for bounded wire formats with a
    raw fallback; the dropped count is nnz - capacity when positive).
    """
    blocks = shuffled.reshape(-1, BLOCK_WORDS)
    flags = jnp.any(blocks != 0, axis=-1)
    return compact_blocks(flags, blocks, capacity=capacity)


@partial(jax.jit, static_argnames=("n_blocks",))
def decode(bitflags: jax.Array, payload: jax.Array, *, n_blocks: int) -> jax.Array:
    """Inverse of :func:`encode` -> flat u16 word stream (n_blocks * 8 words).

    Offsets are the exclusive prefix sum of the unpacked flags; each flagged
    block gathers its payload slot, unflagged blocks are zero. Blocks whose
    offset exceeded capacity at encode time decode to zero (bounded-capacity
    wire mode; exact when capacity >= nnz).
    """
    flags = unpack_bitflags(bitflags, n_blocks)
    offsets = jnp.cumsum(flags.astype(jnp.int32)) - flags.astype(jnp.int32)  # exclusive
    cap = payload.shape[0]
    in_cap = flags & (offsets < cap)
    blocks = jnp.where(in_cap[:, None], payload[jnp.minimum(offsets, cap - 1)], 0)
    return blocks.reshape(-1).astype(jnp.uint16)


def used_bytes(n_blocks: int, nnz: jax.Array, n_outliers: jax.Array | None = None,
               header_bytes: int = 32) -> jax.Array:
    """Exact compressed size in bytes (header + bitflags + blocks + outliers).

    int32 arithmetic: valid for per-leaf tensors < 2 GiB compressed, which the
    tree helpers guarantee by compressing leaf-wise.
    """
    flag_bytes = (n_blocks + 7) // 8
    out = header_bytes + flag_bytes + nnz.astype(jnp.int32) * BLOCK_BYTES
    if n_outliers is not None:
        out = out + n_outliers.astype(jnp.int32) * 8  # 4B idx + 4B residual
    return out
