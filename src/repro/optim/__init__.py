from .adamw import AdamWConfig, adamw_init, adamw_update, global_norm  # noqa: F401
from .schedules import warmup_cosine  # noqa: F401
