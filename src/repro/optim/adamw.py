"""AdamW with f32 master weights, built for sharded pytrees.

Optimizer state mirrors parameter sharding exactly (m/v/master are
tree-mapped copies), so FSDP placement follows from the param shardings with
no extra annotations. Global-norm clipping is a tree-wide psum-free reduction
(XLA inserts the collectives from the shardings).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def adamw_init(params: Any) -> dict:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(f32, params),
        "v": jax.tree.map(f32, params),
        "master": jax.tree.map(lambda p: p.astype(jnp.float32), params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(grads: Any, state: dict, lr: jax.Array,
                 cfg: AdamWConfig, params: Any) -> tuple[Any, dict]:
    """Returns (new params in their original dtypes, new state)."""
    count = state["count"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9))
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        step = (m / c1) / (jnp.sqrt(v / c2) + cfg.eps) + cfg.weight_decay * p
        return m, v, p - lr * step

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_p = treedef.flatten_up_to(state["master"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_m = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    new_master = treedef.unflatten([o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "master": new_master, "count": count}
    new_params = jax.tree.map(lambda mst, p: mst.astype(p.dtype), new_master, params)
    return new_params, new_state
