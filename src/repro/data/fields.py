"""Synthetic scientific-field generators (SDRBench proxies).

SDRBench datasets (HACC/CESM/Nyx/Hurricane/QMCPACK/RTM) are not
redistributable offline, so benchmarks run on synthetic fields whose
qualitative structure matches the classes the paper evaluates:

  * ``smooth``     — multiscale band-limited fields (CESM / Hurricane-like):
                     sums of low-frequency separable harmonics + mild noise.
  * ``turbulent``  — power-law spectrum fields (Nyx / RTM-like): spectral
                     synthesis with k^-alpha amplitude decay.
  * ``particle``   — heavy-tailed, rough point data after log transform
                     (HACC-like; the paper log-transforms HACC, §4.1).
  * ``wavefront``  — propagating-front snapshot with large zero regions
                     (RTM-like; exercises the zero-block encoder's best case).

All generators are deterministic in (kind, shape, seed).
"""
from __future__ import annotations

import numpy as np

FIELD_KINDS = ("smooth", "turbulent", "particle", "wavefront")


def _grid(shape):
    axes = [np.linspace(0.0, 1.0, s, dtype=np.float32) for s in shape]
    return np.meshgrid(*axes, indexing="ij")


def make_field(kind: str, shape=(128, 128, 128), seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    if kind == "smooth":
        gs = _grid(shape)
        out = np.zeros(shape, np.float32)
        for _ in range(6):
            freqs = rng.uniform(0.5, 4.0, size=len(shape))
            phase = rng.uniform(0, 2 * np.pi, size=len(shape))
            amp = rng.uniform(0.2, 1.0)
            term = amp * np.ones(shape, np.float32)
            for g, f, p in zip(gs, freqs, phase):
                term = term * np.sin(2 * np.pi * f * g + p, dtype=np.float32)
            out += term
        out += 0.01 * rng.standard_normal(shape).astype(np.float32)
        return out
    if kind == "turbulent":
        white = rng.standard_normal(shape).astype(np.float32)
        spec = np.fft.rfftn(white)
        k2 = np.zeros_like(spec, dtype=np.float32)
        for ax, s in enumerate(shape):
            k = np.fft.fftfreq(s) * s if ax < len(shape) - 1 else np.fft.rfftfreq(s) * s
            sl = [None] * len(shape)
            sl[ax] = slice(None)
            k2 = k2 + (k[tuple(sl)] ** 2).astype(np.float32)
        amp = (1.0 + k2) ** (-11.0 / 12.0)  # ~Kolmogorov-ish slope
        return np.fft.irfftn(spec * amp, s=shape,
                             axes=list(range(len(shape)))).astype(np.float32)
    if kind == "particle":
        x = rng.lognormal(mean=0.0, sigma=2.0, size=shape).astype(np.float32)
        return np.log1p(x)  # the paper compresses log-transformed HACC
    if kind == "wavefront":
        gs = _grid(shape)
        r = np.zeros(shape, np.float32)
        for g in gs:
            r += (g - 0.4) ** 2
        r = np.sqrt(r)
        front = np.exp(-((r - 0.25) ** 2) / 2e-3, dtype=np.float32) * np.sin(80 * r, dtype=np.float32)
        front[r > 0.45] = 0.0  # untouched region: exact zeros, RTM-style
        return front
    raise ValueError(f"unknown field kind {kind!r}; options {FIELD_KINDS}")
