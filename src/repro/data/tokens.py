"""Deterministic sharded synthetic token pipeline.

Produces reproducible LM batches keyed by (seed, step, shard) so that:
  * every data-parallel host reads only its shard (no coordination),
  * checkpoint/restart resumes the stream exactly (state = step counter),
  * elastic rescaling re-partitions the *same* global stream deterministically
    (shard assignment is a pure function of step and global batch index).

Synthetic distribution: Zipfian token draw + a Markov blend so batches have
non-trivial predictable structure (loss actually decreases in examples/).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TokenStream:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3

    def _rng(self, step: int, index: int) -> np.random.Generator:
        # Philox keyed by (seed, step, global index): order-independent
        return np.random.Generator(np.random.Philox(key=self.seed, counter=[0, 0, step, index]))

    def global_batch_at(self, step: int) -> np.ndarray:
        return self.shard_batch(step, shard=0, num_shards=1)

    def shard_batch(self, step: int, shard: int, num_shards: int) -> np.ndarray:
        """(global_batch/num_shards, seq_len+1) int32 — inputs||next-token labels."""
        if self.global_batch % num_shards:
            raise ValueError(f"global_batch {self.global_batch} % shards {num_shards} != 0")
        per = self.global_batch // num_shards
        out = np.empty((per, self.seq_len + 1), np.int32)
        v = self.vocab_size
        for i in range(per):
            g = shard * per + i
            rng = self._rng(step, g)
            z = rng.zipf(self.zipf_a, size=self.seq_len + 1).astype(np.int64)
            base = (z - 1) % v
            # Markov-ish smoothing: with p=0.5 repeat previous token + 1 (predictable)
            rep = rng.random(self.seq_len + 1) < 0.5
            seq = base.copy()
            for t in range(1, seq.size):
                if rep[t]:
                    seq[t] = (seq[t - 1] + 1) % v
            out[i] = seq.astype(np.int32)
        return out
