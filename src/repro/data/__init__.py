from .fields import make_field, FIELD_KINDS  # noqa: F401
from .tokens import TokenStream  # noqa: F401
