#!/usr/bin/env python
"""Dry-run profiler: top collective / dot contributors for one cell.

    PYTHONPATH=src python scripts/profile_cell.py --arch yi-6b --shape train_4k \
        --mesh single [--compressed-grads] [--microbatches N]

This is the §Perf "profile" on a CPU-only box: the lowered-and-partitioned
HLO is the ground truth for what moves and what multiplies.

Phases run under repro.obs spans (``profile.build`` / ``profile.compile`` /
``profile.attribute``), so the script doubles as a telemetry exerciser: a
phase-timing StepReport prints at the end, and ``--trace-out`` /
``--metrics-out`` / ``--profile-dir`` export the run's artifacts.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
from collections import defaultdict


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--shape", required=True)
    p.add_argument("--mesh", choices=["single", "multi"], default="single")
    p.add_argument("--compressed-grads", action="store_true")
    p.add_argument("--opt", default="none")
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--top", type=int, default=12)
    from repro.obs import cli as obs_cli
    obs_cli.add_args(p)
    args = p.parse_args()
    obs_cli.start(args)

    from repro import obs
    from repro.launch.dryrun import _build
    from repro.launch import hlo_cost as hc

    with obs.span("profile.build", arch=args.arch, shape=args.shape):
        model, mesh, step, sargs = _build(
            args.arch, args.shape, args.mesh == "multi",
            compressed_grads=args.compressed_grads,
            microbatches=args.microbatches, opt=args.opt)
    with obs.span("profile.compile"):
        text = step.lower(*sargs).compile().as_text()
    with obs.span("profile.parse"):
        comps = hc.parse_computations(text)
    entry = [n for n in comps if n.startswith("main")][0]

    edges = defaultdict(list)
    for cname, c in comps.items():
        for op in c.ops:
            if op.opcode == "while":
                mb, mc = hc._ATTR_BODY.search(op.rest), hc._ATTR_COND.search(op.rest)
                trips = hc._trip_count(comps[mc.group(1)]) if mc and mc.group(1) in comps else 1
                if mb:
                    edges[cname].append((mb.group(1), float(trips)))
                if mc:
                    edges[cname].append((mc.group(1), float(trips + 1)))
            else:
                for attr in (hc._ATTR_CALLS, hc._ATTR_BODY, hc._ATTR_COND):
                    m2 = attr.search(op.rest)
                    if m2 and m2.group(1) in comps:
                        edges[cname].append((m2.group(1), 1.0))
    order, state = [], {}

    def dfs(n):
        if state.get(n) == 2:
            return
        state[n] = 1
        for ch, _ in edges.get(n, []):
            if state.get(ch) != 1:
                dfs(ch)
        state[n] = 2
        order.append(n)

    dfs(entry)
    mult = defaultdict(float)
    mult[entry] = 1.0
    for n in reversed(order):
        for ch, w in edges.get(n, []):
            mult[ch] += mult[n] * w

    colls, dots = [], []
    for cname, c in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0:
            continue
        for op in c.ops:
            base = op.opcode[:-6] if op.opcode.endswith("-start") else op.opcode
            if base in hc.COLLECTIVES:
                b = hc._shape_bytes(op.out_shape)
                colls.append((m * b, m, b, base, op.out_shape[:64], cname[:44]))
            elif op.opcode == "dot":
                f = hc._dot_flops(op, c)
                opnd = sum(hc._shape_bytes(c.shapes.get(nm, ""))
                           for nm in hc._operand_names(op.rest))
                dots.append((m * (opnd + hc._shape_bytes(op.out_shape)), m * f,
                             m, op.out_shape[:64], cname[:44]))

    total_coll = sum(t[0] for t in colls)
    print(f"== collectives (total {total_coll:.3e} B/device) ==")
    for t in sorted(colls, key=lambda x: -x[0])[: args.top]:
        print(f"{t[0]:11.3e}B ({100*t[0]/max(total_coll,1):4.1f}%) mult={t[1]:7.0f} "
              f"{t[3]:18s} {t[4]}  @{t[5]}")
    total_bytes = sum(t[0] for t in dots)
    total_flops = sum(t[1] for t in dots)
    print(f"\n== dots (traffic {total_bytes:.3e} B, flops {total_flops:.3e}) ==")
    for t in sorted(dots, key=lambda x: -x[0])[: args.top]:
        print(f"{t[0]:11.3e}B flops={t[1]:9.3e} mult={t[2]:7.0f} {t[3]}  @{t[4]}")

    # phase timings (build / compile / parse) from the span histograms
    print()
    print(obs.step_report(meta={"arch": args.arch, "shape": args.shape,
                                "mesh": args.mesh}).render())
    obs_cli.finish(args, metadata={"arch": args.arch, "shape": args.shape})


if __name__ == "__main__":
    main()
