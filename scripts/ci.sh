#!/usr/bin/env bash
# CI entry point: `scripts/ci.sh fast|slow|bench|analyze|all` (default fast).
#
# XLA flags are pinned so the fake-device tests are deterministic: the main
# pytest process keeps a single CPU device (tests/test_dist.py spawns its own
# 8-fake-device subprocess and overrides XLA_FLAGS there).
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
export XLA_FLAGS="${XLA_FLAGS:---xla_force_host_platform_device_count=1}"

tier="${1:-fast}"
case "$tier" in
  fast)
    # static analysis gates first: cheapest tier, catches kernel budget /
    # carry / jit-discipline regressions before any interpret-mode kernel
    # spins up
    bash "$0" analyze
    # property tier: prefer the real hypothesis wheel (pyproject [test]
    # extra); hermetic boxes fall back to the bundled minihypothesis shim
    # (tests/conftest.py), so the tier runs either way
    python -c "import hypothesis" 2>/dev/null \
      || pip install --quiet hypothesis 2>/dev/null \
      || echo "hypothesis wheel unavailable; property tier uses the bundled fallback"
    python -m pytest -q -m "not slow"
    # kvpool smoke: tiny model, 3-page pool, seeded template-sharing trace —
    # drives the full continuous-batching scheduler (admit/tier/preempt/
    # resume) AND the prefix-sharing path (radix hits, suffix prefill, CoW,
    # deduped shared cold reads) on every PR; asserts hits/CoW/preemptions
    # plus (in-script) fz-vs-pool dispatch-count parity and zero sentinel
    # violations, and exports the serving telemetry as a Chrome trace
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python examples/serve_compressed_kv.py --smoke \
        --trace-out /tmp/serve_smoke_trace.json
    # the exported trace must be a Perfetto-loadable Chrome trace with the
    # engine -> scheduler -> kvpool -> fz span nesting intact
    python - <<'PY'
import json
doc = json.load(open("/tmp/serve_smoke_trace.json"))
evs = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
assert evs, "empty trace"
for e in evs:
    assert {"name", "cat", "ph", "ts", "dur", "pid", "tid"} <= set(e), e
names = {e["name"] for e in evs}
for expect in ("engine.serve", "sched.step", "fz.compress"):
    assert any(n.startswith(expect) for n in names), f"missing {expect} spans"
parents = {e["args"].get("parent") for e in evs if e["name"] == "sched.step"}
assert "engine.serve" in parents, "sched.step not nested under engine.serve"
print(f"serve smoke trace OK: {len(evs)} events, {len(names)} span names")
PY
    # kernel-parity smoke: the same trace end-to-end through the
    # interpret-mode Pallas flash-decode kernel (page-native gather) + FZ
    # kernel stages; asserts >= 90% token agreement with the oracle
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python examples/serve_compressed_kv.py --smoke --kernels
    # docs check: execute every fenced ```python block in the README and the
    # docs pages (repro.testing.docsnippets) — documented examples are part
    # of the test surface, so a renamed API breaks CI, not the reader
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m repro.testing.docsnippets \
        README.md docs/ARCHITECTURE.md docs/CONTAINER_FORMAT.md
    ;;
  slow) exec python -m pytest -q -m slow ;;
  analyze)
    # static-analysis tier: kernel VMEM/SMEM budgets over the shipped config
    # space, grid-carry vs dimension_semantics hazards, jit-discipline +
    # style lint — fails on any finding not in the committed allowlist
    # (src/repro/analysis/baseline.json) and on stale allowlist entries
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m repro.analysis --check
    # the full lint config is [tool.ruff] in pyproject.toml; the wheel is
    # optional — the analyzer's built-in style pass above is the hermetic
    # lint floor either way
    if command -v ruff >/dev/null 2>&1; then
      ruff check src tests
    else
      echo "ruff wheel unavailable; built-in style pass is the lint floor"
    fi
    ;;
  bench)
    # perf-trajectory smoke: tiny-shape kvcache decode, the barrier-vs-
    # bucketed overlap sweep, compressor throughput (compress/decompress
    # GB/s + ratio for the reference / staged / fused execution paths over a
    # small shape grid), AND the rate-distortion frontier with the entropy
    # cold tier — one machine-readable BENCH_ci.json at the repo root
    # (the workflow uploads it as an artifact — every CI run appends a
    # datapoint to the trajectory instead of leaving BENCH_* empty)
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m benchmarks.run \
        --only throughput,kvcache,overlap,rate_distortion --smoke --json-out BENCH_ci.json
    python - <<'PY'
import json
doc = json.load(open("BENCH_ci.json"))
rows = doc["sections"]["overlap"]["rows"]
modes = {r["mode"] for r in rows}
assert {"barrier", "bucketed"} <= modes, f"missing reduce modes: {modes}"
assert doc["sections"]["kvcache"]["decode_ms"], "kvcache decode rows missing"
trows = doc["sections"]["throughput"]["rows"]
paths = {r["path"] for r in trows}
assert {"reference", "staged", "fused"} <= paths, f"missing FZ paths: {paths}"
# hot-path-unchanged guard: the throughput section must stay pure-FZ — the
# entropy stage is cold-tier only and must never appear as a hot path
assert not any("entropy" in p for p in paths), f"entropy leaked into hot paths: {paths}"
for d in ("compress", "decompress"):
    n = sum(1 for r in trows if r["direction"] == d and r["path"] in
            ("reference", "staged", "fused"))
    assert n >= 6, f"too few {d} throughput rows: {n}"
assert all(r["gbps"] > 0 and r["ratio"] > 0 for r in trows), "bad rows"
# serving rows: the seeded prefix-skewed trace through all three pool
# storage modes, with the radix-vs-off wins the PR trajectory tracks
srows = doc["sections"]["kvcache"]["serving"]
by_mode = {r["mode"]: r for r in srows}
assert {"radix", "copy", "off"} <= set(by_mode), f"missing modes: {set(by_mode)}"
radix, copy, off = by_mode["radix"], by_mode["copy"], by_mode["off"]
assert off["prefill_tokens"] >= 2 * radix["prefill_tokens"], \
    f"radix prefill win < 2x: {radix['prefill_tokens']} vs {off['prefill_tokens']}"
assert radix["prefill_tokens"] == copy["prefill_tokens"], "radix/copy matching diverged"
assert radix["high_water_bytes"] <= off["high_water_bytes"], \
    f"radix high-water regressed: {radix['high_water_bytes']} vs {off['high_water_bytes']}"
assert radix["shared_cold_reads_deduped"] > 0, "dedup path never exercised"
assert radix["decompressions"] < copy["decompressions"], \
    "dedup did not reduce cold decodes vs private copies"
# entropy-coded cold tier: the radix_entropy replay (same trace, cold pages
# stored as entropy-coded byte containers) must be numerically invisible
rent = by_mode["radix_entropy"]
assert rent["bit_identical_to_radix"] is True, "entropy cold tier changed tokens"
assert rent["prefill_tokens"] == radix["prefill_tokens"], \
    "entropy cold tier changed prefix sharing"
for r in srows:
    for f in ("ttft_p50", "ttft_p99", "itl_p50", "itl_p99",
              "ttft_slo_attained", "itl_slo_attained"):
        assert f in r, f"serving row {r['mode']} missing {f}"
# entropy cold tier frontier: on >= 2 field kinds the entropy-coded container
# must be strictly smaller than the plain container at bit-exact-equal PSNR
# (fz_cold_psnr is measured from the decoded blob, so equality IS the
# bit-exactness proof); the skip probe must reject incompressible noise and
# cost a bounded fraction of the encode it avoids
rd = doc["sections"]["rate_distortion"]
better = {r["kind"] for r in rd["rows"]
          if r["entropy_selected"]
          and r["fz_cold_bitrate"] < r["fz_plain_bitrate"]
          and r["fz_cold_psnr"] == r["fz_psnr"]}
assert len(better) >= 2, f"entropy cold tier won on too few field kinds: {better}"
probe = rd["probe"]
assert probe["skew"]["selected"], "probe rejected a compressible buffer"
assert not probe["noise"]["selected"], "probe accepted incompressible noise"
assert probe["noise"]["probe_ms"] < probe["noise"]["encode_ms"], \
    "skip probe costs more than the encode it avoids"
# telemetry: the embedded registry snapshot must be schema-complete, carry
# the FZ dispatch counters the run produced, and report zero sentinel
# violations; the eager-wrapper instrumentation overhead is pinned < 5%
snap = doc["metrics_snapshot"]
assert {"counters", "gauges", "histograms", "sentinel_violations"} <= set(snap)
assert any(k.startswith("fz_dispatches{") for k in snap["counters"]), \
    "no FZ dispatch counters in metrics_snapshot"
assert any(k.startswith("span_ms{") for k in snap["histograms"]), \
    "no span histograms in metrics_snapshot"
for k, h in snap["histograms"].items():
    assert {"count", "sum", "min", "max", "p50", "p99"} <= set(h), k
assert not snap["sentinel_violations"], snap["sentinel_violations"]
assert any(k.startswith("entropy_stage{") for k in snap["counters"]), \
    "no entropy_stage counters in metrics_snapshot"
oh = doc["sections"]["throughput"]["obs_overhead"]
assert oh["overhead_frac"] < 0.05, \
    f"obs overhead {oh['overhead_frac']:.1%} exceeds the 5% pin"
print(f"BENCH_ci.json OK: sections={sorted(doc['sections'])}, "
      f"{len(rows)} overlap rows, {len(trows)} compressor rows, "
      f"{len(srows)} serving rows "
      f"(radix {radix['prefill_tokens']} vs off {off['prefill_tokens']} "
      f"prefill tokens, radix_entropy bit-identical); "
      f"entropy cold tier better on {sorted(better)}; "
      f"probe frac {probe['noise']['probe_frac']:.2f}; "
      f"obs overhead {oh['overhead_frac']:.2%}, "
      f"{sum(1 for k in snap['counters'] if k.startswith('fz_dispatches'))} "
      f"fz dispatch counters, 0 sentinel violations")
PY
    ;;
  all)  exec python -m pytest -q ;;
  *)    echo "usage: $0 [fast|slow|bench|analyze|all]" >&2; exit 2 ;;
esac
