#!/usr/bin/env bash
# CI entry point: `scripts/ci.sh fast|slow|all` (default fast).
#
# XLA flags are pinned so the fake-device tests are deterministic: the main
# pytest process keeps a single CPU device (tests/test_dist.py spawns its own
# 8-fake-device subprocess and overrides XLA_FLAGS there).
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
export XLA_FLAGS="${XLA_FLAGS:---xla_force_host_platform_device_count=1}"

tier="${1:-fast}"
case "$tier" in
  fast)
    python -m pytest -q -m "not slow"
    # kvpool smoke: tiny model, 2-page pool, 8-step trace — drives the full
    # continuous-batching scheduler (admit/tier/preempt/resume) on every PR
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python examples/serve_compressed_kv.py --smoke
    ;;
  slow) exec python -m pytest -q -m slow ;;
  all)  exec python -m pytest -q ;;
  *)    echo "usage: $0 [fast|slow|all]" >&2; exit 2 ;;
esac
