#!/usr/bin/env bash
# CI entry point: `scripts/ci.sh fast|slow|bench|analyze|all` (default fast).
#
# XLA flags are pinned so the fake-device tests are deterministic: the main
# pytest process keeps a single CPU device (tests/test_dist.py spawns its own
# 8-fake-device subprocess and overrides XLA_FLAGS there).
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
export XLA_FLAGS="${XLA_FLAGS:---xla_force_host_platform_device_count=1}"

tier="${1:-fast}"
case "$tier" in
  fast)
    # tuning cache for this CI run: the tune tier below populates it, the
    # serve smokes then dispatch from it (kernel_mode="auto" reads winners)
    export REPRO_TUNE_CACHE="${REPRO_TUNE_CACHE:-/tmp/repro_tune_ci.json}"
    # static analysis gates first: cheapest tier, catches kernel budget /
    # carry / jit-discipline regressions before any interpret-mode kernel
    # spins up
    bash "$0" analyze
    # property tier: prefer the real hypothesis wheel (pyproject [test]
    # extra); hermetic boxes fall back to the bundled minihypothesis shim
    # (tests/conftest.py), so the tier runs either way
    python -c "import hypothesis" 2>/dev/null \
      || pip install --quiet hypothesis 2>/dev/null \
      || echo "hypothesis wheel unavailable; property tier uses the bundled fallback"
    python -m pytest -q -m "not slow"
    # autotuner determinism: measure once, then dispatch from the cache
    bash "$0" tune
    # kvpool smoke: tiny model, 3-page pool, seeded template-sharing trace —
    # drives the full continuous-batching scheduler (admit/tier/preempt/
    # resume) AND the prefix-sharing path (radix hits, suffix prefill, CoW,
    # deduped shared cold reads) on every PR; asserts hits/CoW/preemptions
    # plus (in-script) fz-vs-pool dispatch-count parity and zero sentinel
    # violations, and exports the serving telemetry as a Chrome trace
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python examples/serve_compressed_kv.py --smoke \
        --trace-out /tmp/serve_smoke_trace.json
    # the exported trace must be a Perfetto-loadable Chrome trace with the
    # engine -> scheduler -> kvpool -> fz span nesting intact
    python - <<'PY'
import json
doc = json.load(open("/tmp/serve_smoke_trace.json"))
evs = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
assert evs, "empty trace"
for e in evs:
    assert {"name", "cat", "ph", "ts", "dur", "pid", "tid"} <= set(e), e
names = {e["name"] for e in evs}
for expect in ("engine.serve", "sched.step", "fz.compress"):
    assert any(n.startswith(expect) for n in names), f"missing {expect} spans"
parents = {e["args"].get("parent") for e in evs if e["name"] == "sched.step"}
assert "engine.serve" in parents, "sched.step not nested under engine.serve"
print(f"serve smoke trace OK: {len(evs)} events, {len(names)} span names")
PY
    # kernel-parity smoke: the same trace end-to-end through the
    # interpret-mode Pallas flash-decode kernel (page-native gather) + FZ
    # kernel stages; asserts >= 90% token agreement with the oracle
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python examples/serve_compressed_kv.py --smoke --kernels
    # docs check: execute every fenced ```python block in the README and the
    # docs pages (repro.testing.docsnippets) — documented examples are part
    # of the test surface, so a renamed API breaks CI, not the reader
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m repro.testing.docsnippets \
        README.md docs/ARCHITECTURE.md docs/CONTAINER_FORMAT.md
    ;;
  slow) exec python -m pytest -q -m slow ;;
  tune)
    # empirical-tuner gate: two `python -m repro.tune --smoke` runs against
    # a fresh cache. The first must measure every workload point; the second
    # must be pure cache hits with ZERO re-measurements (the "tuning cost is
    # paid once" contract), pinned both structurally and via the
    # tune_cache{result=hit} counters the process reports. On the interpret
    # backend the compress winner must never be the fused megakernel (the
    # measured ~4x interpreter regression the fallback ordering encodes).
    TUNE_CACHE="${REPRO_TUNE_CACHE:-/tmp/repro_tune_ci.json}"
    rm -f "$TUNE_CACHE"
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m repro.tune --smoke \
        --cache "$TUNE_CACHE" --json > /tmp/tune_run1.json
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m repro.tune --smoke \
        --cache "$TUNE_CACHE" --json > /tmp/tune_run2.json
    python - <<'PY'
import json
r1 = json.load(open("/tmp/tune_run1.json"))
r2 = json.load(open("/tmp/tune_run2.json"))
n = len(r1["results"])
assert n >= 5, f"tune smoke covered too few workloads: {n}"
assert r1["misses"] == n and r1["measurements"] > 0, \
    f"first run on a fresh cache must measure everything: {r1['misses']}/{n}"
assert r2["hits"] == n and r2["misses"] == 0, \
    f"second run not all hits: {r2['hits']} hits / {r2['misses']} misses"
assert r2["measurements"] == 0, \
    f"second tuner run re-measured {r2['measurements']} candidate(s)"
hits = {k: v for k, v in r2["counters"].items()
        if k.startswith("tune_cache{") and "result=hit" in k}
assert sum(hits.values()) == n, f"tune_cache hit counters disagree: {hits}"
w1 = {(r["op"], r["n"], r["dtype"]): r["impl"] for r in r1["results"]}
w2 = {(r["op"], r["n"], r["dtype"]): r["impl"] for r in r2["results"]}
assert w1 == w2, f"cached winners diverged: {w1} vs {w2}"
if r1["backend"] == "interpret":
    bad = [r for r in r1["results"]
           if r["op"] == "fz.compress" and r["impl"] == "fused"]
    assert not bad, f"interpret backend selected fused compress: {bad}"
print(f"tune OK: {n} workloads, {r1['measurements']} measurements on run 1, "
      f"0 on run 2 (pure cache hits); winners "
      + ", ".join(f"{op}@{n_}/{dt}={i}" for (op, n_, dt), i in sorted(w1.items())))
PY
    ;;
  analyze)
    # static-analysis tier: kernel VMEM/SMEM budgets over the shipped config
    # space, grid-carry vs dimension_semantics hazards, jit-discipline +
    # style lint — fails on any finding not in the committed allowlist
    # (src/repro/analysis/baseline.json) and on stale allowlist entries
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m repro.analysis --check
    # the full lint config is [tool.ruff] in pyproject.toml; the wheel is
    # optional — the analyzer's built-in style pass above is the hermetic
    # lint floor either way
    if command -v ruff >/dev/null 2>&1; then
      ruff check src tests
    else
      echo "ruff wheel unavailable; built-in style pass is the lint floor"
    fi
    ;;
  bench)
    # perf-trajectory smoke: tiny-shape kvcache decode, the barrier-vs-
    # bucketed overlap sweep, compressor throughput (compress/decompress
    # GB/s + ratio for the reference / staged / fused execution paths over a
    # small shape grid), AND the rate-distortion frontier with the entropy
    # cold tier — one machine-readable BENCH_ci.json at the repo root
    # (the workflow uploads it as an artifact — every CI run appends a
    # datapoint to the trajectory instead of leaving BENCH_* empty).
    # The throughput section pre-tunes in-process against a fresh cache and
    # adds tuned kernel_mode="auto" rows next to the three static paths.
    export REPRO_TUNE_CACHE="${REPRO_TUNE_CACHE:-/tmp/repro_tune_bench.json}"
    rm -f "$REPRO_TUNE_CACHE"
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m benchmarks.run \
        --only throughput,kvcache,overlap,rate_distortion --smoke --json-out BENCH_ci.json
    python - <<'PY'
import json
doc = json.load(open("BENCH_ci.json"))
rows = doc["sections"]["overlap"]["rows"]
modes = {r["mode"] for r in rows}
assert {"barrier", "bucketed"} <= modes, f"missing reduce modes: {modes}"
assert doc["sections"]["kvcache"]["decode_ms"], "kvcache decode rows missing"
trows = doc["sections"]["throughput"]["rows"]
paths = {r["path"] for r in trows}
assert {"reference", "staged", "fused"} <= paths, f"missing FZ paths: {paths}"
# hot-path-unchanged guard: the throughput section must stay pure-FZ — the
# entropy stage is cold-tier only and must never appear as a hot path
assert not any("entropy" in p for p in paths), f"entropy leaked into hot paths: {paths}"
for d in ("compress", "decompress"):
    n = sum(1 for r in trows if r["direction"] == d and r["path"] in
            ("reference", "staged", "fused"))
    assert n >= 6, f"too few {d} throughput rows: {n}"
assert all(r["gbps"] > 0 and r["ratio"] > 0 for r in trows), "bad rows"
# serving rows: the seeded prefix-skewed trace through all three pool
# storage modes, with the radix-vs-off wins the PR trajectory tracks
srows = doc["sections"]["kvcache"]["serving"]
by_mode = {r["mode"]: r for r in srows}
assert {"radix", "copy", "off"} <= set(by_mode), f"missing modes: {set(by_mode)}"
radix, copy, off = by_mode["radix"], by_mode["copy"], by_mode["off"]
assert off["prefill_tokens"] >= 2 * radix["prefill_tokens"], \
    f"radix prefill win < 2x: {radix['prefill_tokens']} vs {off['prefill_tokens']}"
assert radix["prefill_tokens"] == copy["prefill_tokens"], "radix/copy matching diverged"
assert radix["high_water_bytes"] <= off["high_water_bytes"], \
    f"radix high-water regressed: {radix['high_water_bytes']} vs {off['high_water_bytes']}"
assert radix["shared_cold_reads_deduped"] > 0, "dedup path never exercised"
assert radix["decompressions"] < copy["decompressions"], \
    "dedup did not reduce cold decodes vs private copies"
# entropy-coded cold tier: the radix_entropy replay (same trace, cold pages
# stored as entropy-coded byte containers) must be numerically invisible
rent = by_mode["radix_entropy"]
assert rent["bit_identical_to_radix"] is True, "entropy cold tier changed tokens"
assert rent["prefill_tokens"] == radix["prefill_tokens"], \
    "entropy cold tier changed prefix sharing"
for r in srows:
    for f in ("ttft_p50", "ttft_p99", "itl_p50", "itl_p99",
              "ttft_slo_attained", "itl_slo_attained"):
        assert f in r, f"serving row {r['mode']} missing {f}"
# entropy cold tier frontier: on >= 2 field kinds the entropy-coded container
# must be strictly smaller than the plain container at bit-exact-equal PSNR
# (fz_cold_psnr is measured from the decoded blob, so equality IS the
# bit-exactness proof); the skip probe must reject incompressible noise and
# cost a bounded fraction of the encode it avoids
rd = doc["sections"]["rate_distortion"]
better = {r["kind"] for r in rd["rows"]
          if r["entropy_selected"]
          and r["fz_cold_bitrate"] < r["fz_plain_bitrate"]
          and r["fz_cold_psnr"] == r["fz_psnr"]}
assert len(better) >= 2, f"entropy cold tier won on too few field kinds: {better}"
probe = rd["probe"]
assert probe["skew"]["selected"], "probe rejected a compressible buffer"
assert not probe["noise"]["selected"], "probe accepted incompressible noise"
assert probe["noise"]["probe_ms"] < probe["noise"]["encode_ms"], \
    "skip probe costs more than the encode it avoids"
# telemetry: the embedded registry snapshot must be schema-complete, carry
# the FZ dispatch counters the run produced, and report zero sentinel
# violations; the eager-wrapper instrumentation overhead is pinned < 5%
snap = doc["metrics_snapshot"]
assert {"counters", "gauges", "histograms", "sentinel_violations"} <= set(snap)
assert any(k.startswith("fz_dispatches{") for k in snap["counters"]), \
    "no FZ dispatch counters in metrics_snapshot"
assert any(k.startswith("span_ms{") for k in snap["histograms"]), \
    "no span histograms in metrics_snapshot"
for k, h in snap["histograms"].items():
    assert {"count", "sum", "min", "max", "p50", "p99"} <= set(h), k
assert not snap["sentinel_violations"], snap["sentinel_violations"]
assert any(k.startswith("entropy_stage{") for k in snap["counters"]), \
    "no entropy_stage counters in metrics_snapshot"
oh = doc["sections"]["throughput"]["obs_overhead"]
assert oh["overhead_frac"] < 0.05, \
    f"obs overhead {oh['overhead_frac']:.1%} exceeds the 5% pin"
# tuned dispatch (repro.tune): auto rows present for both directions, every
# winner is the argmin of its own parity-gated measurements, and on the
# interpret backend compress never selects the fused megakernel (BENCH
# history: fused compress ~4x slower than staged under the interpreter) —
# i.e. tuned dispatch tracks the best static path instead of a bad default
arows = [r for r in trows if r["path"] == "auto"]
for d in ("compress", "decompress"):
    assert any(r["direction"] == d for r in arows), f"no tuned {d} rows"
tsum = doc["sections"]["throughput"]["tune"]
for res in tsum["results"]:
    if res["measured_us"]:
        best = min(res["measured_us"], key=res["measured_us"].get)
        assert res["impl"] == best, \
            f"tuner selected {res['impl']} but measured {res['measured_us']}"
if tsum["backend"] == "interpret":
    badc = [r for r in arows
            if r["direction"] == "compress" and r["selected"] == "fused"]
    assert not badc, f"interpret tuned compress picked fused: {badc}"
for r in arows:
    static = [s["us"] for s in trows
              if s["path"] in ("reference", "staged", "fused")
              and (s["direction"], s["kind"], s["eb"]) ==
                  (r["direction"], r["kind"], r["eb"])]
    assert static and r["us"] <= 2.0 * min(static), \
        (f"tuned {r['direction']} {r['us']:.0f}us not tracking best static "
         f"{min(static):.0f}us")
print(f"BENCH_ci.json OK: sections={sorted(doc['sections'])}, "
      f"{len(rows)} overlap rows, {len(trows)} compressor rows, "
      f"{len(srows)} serving rows "
      f"(radix {radix['prefill_tokens']} vs off {off['prefill_tokens']} "
      f"prefill tokens, radix_entropy bit-identical); "
      f"entropy cold tier better on {sorted(better)}; "
      f"probe frac {probe['noise']['probe_frac']:.2f}; "
      f"obs overhead {oh['overhead_frac']:.2%}, "
      f"{sum(1 for k in snap['counters'] if k.startswith('fz_dispatches'))} "
      f"fz dispatch counters, 0 sentinel violations; "
      f"{len(arows)} tuned-dispatch rows "
      f"(compress -> {[r['selected'] for r in arows if r['direction'] == 'compress'][0]})")
PY
    # perf trajectory: append this run's compact summary row to
    # BENCH_history.jsonl and soft-gate >25% drops vs the previous
    # comparable row (warn-only: CI boxes differ; the line is the evidence)
    python -m benchmarks.history BENCH_ci.json --history BENCH_history.jsonl
    ;;
  all)  exec python -m pytest -q ;;
  *)    echo "usage: $0 [fast|slow|bench|analyze|all]" >&2; exit 2 ;;
esac
