#!/usr/bin/env python
"""Serial/parallel dry-run driver: one subprocess per (arch, shape, mesh) cell.

Subprocess isolation keeps a single cell's compile crash (or OOM) from
taking down the sweep; JSONs are resumable (existing files skip).
"""
import os, subprocess, sys, time
from concurrent.futures import ThreadPoolExecutor

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src"))
from repro import configs  # noqa: E402
from repro.configs.base import cells_for  # noqa: E402

OUT = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
PAR = int(os.environ.get("DRYRUN_PAR", "1"))
os.makedirs(OUT, exist_ok=True)

cells = []
for arch in configs.ARCH_IDS:
    for shape in cells_for(configs.get(arch)):
        for mesh in ("single", "multi"):
            cells.append((arch, shape, mesh))

def run(cell):
    arch, shape, mesh = cell
    tag = f"{arch}_{shape}_{mesh}"
    path = os.path.join(OUT, tag + ".json")
    if os.path.exists(path):
        return tag, "skip", 0.0
    t0 = time.time()
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    try:
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
             "--shape", shape, "--mesh", mesh, "--out", path],
            env=env, capture_output=True, text=True, timeout=3000,
            cwd=os.path.join(os.path.dirname(os.path.abspath(__file__)), ".."))
    except subprocess.TimeoutExpired:
        with open(path + ".err", "w") as f:
            f.write("TIMEOUT")
        return tag, "TIMEOUT", time.time() - t0
    dt = time.time() - t0
    if r.returncode != 0:
        with open(path + ".err", "w") as f:
            f.write(r.stdout[-4000:] + "\n===STDERR===\n" + r.stderr[-8000:])
        return tag, "FAIL", dt
    return tag, "ok", dt

with ThreadPoolExecutor(max_workers=PAR) as ex:
    for tag, status, dt in ex.map(run, cells):
        print(f"[{status}] {tag} ({dt:.0f}s)", flush=True)
