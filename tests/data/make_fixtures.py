"""Regenerate the frozen container-format fixtures (tests/data/*.bin|*.npy).

Run from the repo root:

    PYTHONPATH=src python tests/data/make_fixtures.py

The fixtures pin the *serialized byte format*, not just the codec logic: the
.bin files are containers written by the format version current at generation
time and must keep decompressing bit-exactly forever (docs/CONTAINER_FORMAT.md
version history). Only regenerate them when intentionally revving the format,
alongside a version bump — never to "fix" a failing test.

    container_v1_plain.bin    v1, raw payload
    container_v1_entropy.bin  v1, entropy-coded payload (forced)
    legacy_stream.bin         pre-v1 headerless checkpoint stream
    expected_v1.npy           reconstruction both v1 containers must produce
    expected_legacy.npy       reconstruction the legacy stream must produce
"""
import pathlib
import struct

import jax.numpy as jnp
import numpy as np

from repro.core import fz
from repro.data import make_field

HERE = pathlib.Path(__file__).parent


def main():
    field = jnp.asarray(make_field("smooth", (16, 16, 16), seed=5))
    cfg = fz.FZConfig(eb=1e-3, eb_mode="rel")
    comp = fz.compress(field, cfg)
    rec = np.asarray(fz.decompress(comp, cfg))

    (HERE / "container_v1_plain.bin").write_bytes(
        fz.to_bytes(comp, cfg, entropy=False))
    (HERE / "container_v1_entropy.bin").write_bytes(
        fz.to_bytes(comp, cfg, entropy=True))
    np.save(HERE / "expected_v1.npy", rec)

    # legacy pre-v1 stream: the exact layout ckpt/checkpoint.py wrote before
    # the format was versioned (flat f32, exact outliers always present)
    lcfg = fz.FZConfig(eb=1e-4, eb_mode="rel", exact_outliers=True)
    lcomp = fz.compress(field.reshape(-1), lcfg)
    nnz, n_out = int(lcomp.nnz_blocks), int(lcomp.n_outliers)
    legacy = b"".join([
        np.asarray([lcomp.n, nnz, n_out], "<i8").tobytes(),
        struct.pack("<f", float(lcomp.eb_abs)),
        np.asarray(lcomp.bitflags).astype("<u4").tobytes(),
        np.asarray(lcomp.payload)[:nnz].astype("<u2").tobytes(),
        np.asarray(lcomp.outlier_idx)[:n_out].astype("<i4").tobytes(),
        np.asarray(lcomp.outlier_val)[:n_out].astype("<i4").tobytes(),
    ])
    (HERE / "legacy_stream.bin").write_bytes(legacy)
    np.save(HERE / "expected_legacy.npy",
            np.asarray(fz.decompress(lcomp, lcfg)))
    for p in sorted(HERE.glob("*.bin")) + sorted(HERE.glob("*.npy")):
        print(f"{p.name}: {p.stat().st_size} bytes")


if __name__ == "__main__":
    main()
