"""Unit tests for the cold-tier entropy coder (core/entropy.py).

Pins: bit-exact roundtrip across sizes/chunkings/distributions, the exact
size model behind the skip probe (``plan`` == actual blob size), the code
length limit, canonical-code invariants (Kraft inequality, prefix-freeness),
and the corruption errors block-parallel decode must surface.
"""
import numpy as np
import pytest

from repro.core import entropy as ent


def _buf(kind: str, n: int, seed: int = 0) -> bytes:
    rng = np.random.default_rng(seed)
    if kind == "skew":
        return np.minimum(rng.geometric(0.2, n) - 1, 255).astype(np.uint8).tobytes()
    if kind == "uniform":
        return rng.integers(0, 256, n, dtype=np.uint8).tobytes()
    if kind == "const":
        return bytes(n)
    raise ValueError(kind)


@pytest.mark.parametrize("n", [0, 1, 5, 255, 4096, 40000])
@pytest.mark.parametrize("chunk", [1, 7, 512, ent.DEFAULT_CHUNK])
@pytest.mark.parametrize("kind", ["skew", "uniform", "const"])
def test_roundtrip(n, chunk, kind):
    data = _buf(kind, n)
    blob = ent.encode(data, chunk)
    assert ent.decode(blob) == data


@pytest.mark.parametrize("kind", ["skew", "uniform", "const"])
def test_plan_size_is_exact(kind):
    """The probe's size estimate must be the byte-exact blob size — that is
    what makes the auto-selection gate in fz.to_bytes trustworthy."""
    data = _buf(kind, 10_000, seed=3)
    counts = np.bincount(np.frombuffer(data, np.uint8), minlength=256)
    lengths, est = ent.plan(counts, len(data), 512)
    assert est == len(ent.encode(data, 512, lengths=lengths))


def test_code_length_limit():
    # Fibonacci-like counts force maximally skewed Huffman depths; the
    # count-halving limiter must cap them at MAX_CODE_LEN for the flat
    # 2^M decode table to stay small
    counts = np.zeros(256, np.int64)
    a, b = 1, 1
    for i in range(40):
        counts[i] = a
        a, b = b, a + b
    lengths = ent.limit_code_lengths(counts, ent.MAX_CODE_LEN)
    used = lengths[counts > 0]
    assert used.max() <= ent.MAX_CODE_LEN
    # Kraft inequality: the limited lengths still describe a prefix code
    assert np.sum(np.where(lengths > 0, 2.0 ** -lengths.astype(float), 0)) <= 1 + 1e-12
    data = np.repeat(np.arange(40, dtype=np.uint8), 50).tobytes()
    blob = ent.encode(data, 512)
    assert ent.decode(blob) == data


def test_canonical_codes_are_prefix_free():
    counts = np.bincount(np.frombuffer(_buf("skew", 5000, 7), np.uint8),
                         minlength=256)
    lengths = ent.limit_code_lengths(counts, ent.MAX_CODE_LEN)
    codes = ent.canonical_codes(lengths)
    seen = set()
    for sym in np.nonzero(lengths)[0]:
        bits = format(codes[sym], f"0{lengths[sym]}b")
        for p in seen:
            assert not bits.startswith(p) and not p.startswith(bits)
        seen.add(bits)


def test_compresses_skewed_data():
    data = _buf("skew", 1 << 16, seed=1)
    blob = ent.encode(data)
    assert len(blob) < len(data)
    # overhead accounting: the blob is header + lengths + gaps + bitstream
    n_chunks = -(-len(data) // ent.DEFAULT_CHUNK)
    assert len(blob) >= ent.overhead_bytes(n_chunks)


def test_truncated_blob_raises():
    blob = ent.encode(_buf("skew", 4096, 2), 512)
    with pytest.raises(ent.EntropyError):
        ent.decode(blob[:-8])


def test_corrupt_bitstream_raises():
    blob = bytearray(ent.encode(_buf("skew", 4096, 4), 512))
    blob[-1] ^= 0xFF  # flip tail bits: chunk-boundary check must catch it
    with pytest.raises(ent.EntropyError):
        ent.decode(bytes(blob))


def test_encode_rejects_bad_chunk():
    with pytest.raises(ValueError):
        ent.encode(b"abc", 0)
