"""End-to-end behaviour tests reproducing the paper's claims at test scale.

Each test pins one qualitative result from the FZ-GPU evaluation (§4):
  * error-boundedness at the paper's relative bounds (Fig. 2 semantics);
  * FZ ~ cuSZ-like compression ratio at the same PSNR (Fig. 7), since the
    lossy stage is shared;
  * FZ >> cuSZx-like ratio at the same bound (§4.3);
  * higher compression on smooth/zero-heavy (RTM-like) data (§4.3 RTM);
  * overall-throughput model favours higher CR at low link bandwidth (§4.6).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines, fz, metrics
from repro.data import make_field

EBS = [1e-2, 1e-3, 1e-4]  # the paper's range-relative bounds (subset)


@pytest.fixture(scope="module")
def fields():
    return {k: jnp.asarray(make_field(k, (48, 48, 48), seed=11))
            for k in ("smooth", "turbulent", "particle", "wavefront")}


@pytest.mark.parametrize("eb", EBS)
def test_error_bounded_all_fields(fields, eb):
    for name, f in fields.items():
        cfg = fz.FZConfig(eb=eb, eb_mode="rel")
        rec, c = fz.roundtrip(f, cfg)
        err = float(metrics.max_abs_err(f, rec))
        assert err <= float(c.eb_abs) * 1.001 + 1e-30, (name, eb, err)


def test_psnr_identical_to_cusz_like(fields):
    """Same lossy stage => same reconstruction quality as the cuSZ baseline."""
    f = fields["smooth"]
    cfg = fz.FZConfig(eb=1e-3)
    rec, c = fz.roundtrip(f, cfg)
    cz = baselines.cusz_like(np.asarray(f), float(c.eb_abs))
    psnr_fz = float(metrics.psnr(f, rec))
    psnr_cz = float(metrics.psnr(f, jnp.asarray(cz.reconstruction)))
    assert abs(psnr_fz - psnr_cz) < 0.6, (psnr_fz, psnr_cz)


def test_ratio_close_to_cusz_like(fields):
    """Fig. 7: FZ bitrate is close to cuSZ's (within ~2x, typically closer)."""
    for name, f in fields.items():
        cfg = fz.FZConfig(eb=1e-3)
        c = fz.compress(f, cfg)
        cz = baselines.cusz_like(np.asarray(f), float(c.eb_abs))
        raw = f.size * 4
        r_fz = raw / float(c.used_bytes())
        r_cz = raw / cz.compressed_bytes
        assert r_fz > 0.5 * r_cz, (name, r_fz, r_cz)


def test_beats_cuszx_like_ratio(fields):
    """§4.3: much higher ratio than the constant-block compressor."""
    wins = 0
    for name, f in fields.items():
        cfg = fz.FZConfig(eb=1e-3)
        c = fz.compress(f, cfg)
        _, bx = baselines.cuszx_like(f, c.eb_abs)
        if float(c.compression_ratio()) > 1.3 * (f.size * 4 / float(bx)):
            wins += 1
    assert wins >= 3, wins


def test_beats_cuzfp_like_quality_at_matched_rate(fields):
    """Fig. 7: at a matched bitrate, FZ PSNR >> fixed-rate transform coding."""
    f = fields["turbulent"]
    cfg = fz.FZConfig(eb=1e-3)
    rec, c = fz.roundtrip(f, cfg)
    bits = float(32 * c.used_bytes() / (f.size * 4))
    rec_z, bz = baselines.cuzfp_like(f, max(int(bits), 1))
    assert float(metrics.psnr(f, rec)) > float(metrics.psnr(f, rec_z)) + 3.0


def test_rtm_like_best_case(fields):
    """§4.3: zero-heavy smooth data compresses far better than rough data."""
    cfg = fz.FZConfig(eb=1e-3)
    cr_wave = float(fz.compress(fields["wavefront"], cfg).compression_ratio())
    cr_part = float(fz.compress(fields["particle"], cfg).compression_ratio())
    assert cr_wave > 2.0 * cr_part, (cr_wave, cr_part)


def test_overall_throughput_model():
    """§4.6: T = ((BW*CR)^-1 + T_c^-1)^-1 — on a slow link the higher-CR
    compressor wins even with lower kernel throughput."""
    def overall(bw, cr, t_compr):
        return 1.0 / (1.0 / (bw * cr) + 1.0 / t_compr)
    slow_link = 11.4  # GB/s, the paper's contended PCIe figure
    fz_like = overall(slow_link, 10.0, 100.0)     # high CR, moderate speed
    cuszx_like_ = overall(slow_link, 2.5, 250.0)  # low CR, high speed
    assert fz_like > cuszx_like_


def test_decompression_symmetry():
    """§4.4 note: decompression pipeline mirrors compression (same stages,
    inverse order) — verified by exact roundtrip through every stage pair."""
    rng = np.random.default_rng(5)
    x = jnp.asarray(np.cumsum(rng.standard_normal(20_000)).astype(np.float32) * 0.1)
    cfg = fz.FZConfig(eb=1e-3)
    rec, c = fz.roundtrip(x, cfg)
    rec2, _ = fz.roundtrip(rec, cfg)
    # idempotence on already-quantized data: second pass is lossless
    np.testing.assert_allclose(np.asarray(rec2), np.asarray(rec), atol=float(c.eb_abs) * 1e-3)
