"""Prefix-sharing (radix/CoW) kvpool tests: shared-page byte accounting,
copy-on-write isolation, allocator invariants under random op traces
(property tier), radix-vs-copy bitwise decode parity, the prefill-token
win on a seeded prefix-skewed trace, and deterministic scheduling
tie-breaks under full ties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import zoo
from repro.serve import Engine
from repro.serve.kvpool import (ContinuousBatcher, PagePool, PoolConfig,
                                Request, TieredPolicy, TraceGenConfig,
                                generate)
from repro.serve.kvpool.pool import COMPRESSED, RAW
from repro.serve.kvpool.scheduler import SeqRecord

hyp = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

L, KVH, HD = 2, 2, 8     # tiny cache geometry for pool-only tests


def make_pool(num_pages=8, ps=4, cap=32, **kw) -> PagePool:
    cfg = PoolConfig(num_pages=num_pages, page_size=ps, seq_capacity=cap,
                     eb=1e-3, eb_mode="abs", dtype="float32", **kw)
    return PagePool(cfg, n_layers=L, n_kv_heads=KVH, head_dim=HD)


def seq_kv(seed: int, S: int):
    rng = np.random.default_rng(seed)
    shp = (L, 1, S, KVH, HD)
    return (jnp.asarray(rng.standard_normal(shp), dtype=jnp.float32),
            jnp.asarray(rng.standard_normal(shp), dtype=jnp.float32))


def tree_pids(pool: PagePool) -> list[int]:
    out = []

    def walk(n):
        for c in n.children:
            out.append(c.page_id)
            walk(c)

    walk(pool.radix.root)
    return out


# ---------------------------------------------------------------------------
# byte accounting under sharing
# ---------------------------------------------------------------------------

def test_shared_pages_counted_once_in_used_bytes():
    """Three readers of one physical prefix: ``used_bytes`` (raw and
    compressed) charges the page once, ``logical_demand_bytes`` charges it
    per mapping — the dedup multiplier the pool reports."""
    pool = make_pool(num_pages=8, ps=4, cap=16, prefix_mode="radix")
    prompt = np.arange(8, dtype=np.int32)
    k, v = seq_kv(0, 8)
    assert pool.write_prefill(0, k, v, 8, step=0)
    pool.insert_prompt(0, prompt, step=0)
    sb = pool.slot_bytes
    assert pool.used_bytes() == 2 * sb

    # two more readers map the same two pages (page-aligned: no CoW)
    for seq in (1, 2):
        m = pool.match_prefix(np.concatenate([prompt, [seq + 100]]))
        assert m.matched_tokens == 8 and len(m.pids) == 2
        assert pool.admit_slot_demand(m, 9) == 1      # just the suffix page
        assert pool.map_prefix(seq, m, step=1)
    assert all(pool.pages[p].refs == 4 for p in pool.seq_pages[0])  # 3 seqs + tree
    assert pool.logical_page_refs() == 6
    assert pool.used_bytes() == 2 * sb                # physical unchanged
    assert pool.logical_demand_bytes() == 6 * sb
    assert pool.stats.cow_promotions == 0

    # tier the shared pages down: one container each, still counted once
    pool.compress_pages(list(pool.seq_pages[0]))
    comp = pool.compressed_used_bytes()
    assert 0 < comp < 2 * sb
    assert pool.used_bytes() == comp
    assert pool.logical_demand_bytes() == 6 * sb      # mappings unchanged
    assert pool.compression_ratio() == 6 * sb / comp  # dedup x compression

    # physical pages survive until the LAST reference (tree's) is dropped
    for seq in (0, 1, 2):
        pool.free_seq(seq)
    assert len(pool.pages) == 2 and pool.radix.size == 2
    assert pool.release_prefix_cache() == 2
    assert not pool.pages and pool.n_free_slots() == 8


def test_partial_tail_match_cows_and_isolates_writers():
    """A mid-page divergence CoWs only the tail page; the suffix write lands
    in the private copy and the donor sequence's bytes are untouched."""
    pool = make_pool(num_pages=8, ps=4, cap=16, prefix_mode="radix")
    prompt0 = np.arange(8, dtype=np.int32)
    k0, v0 = seq_kv(0, 8)
    assert pool.write_prefill(0, k0, v0, 8, step=0)
    pool.insert_prompt(0, prompt0, step=0)
    donor_k = np.asarray(pool.materialize(0)[0])

    # shares 6 of 8 tokens: full page 0 + 2 tokens of page 1
    prompt1 = np.concatenate([prompt0[:6], [77, 78, 79]]).astype(np.int32)
    m = pool.match_prefix(prompt1)
    assert m.matched_tokens == 6 and m.valids == (4, 2)
    assert pool.admit_slot_demand(m, len(prompt1)) == 2  # CoW tail + 1 fresh
    assert pool.map_prefix(1, m, step=1)
    assert pool.stats.cow_promotions == 1
    assert pool.seq_pages[1][0] == pool.seq_pages[0][0]      # head shared
    assert pool.seq_pages[1][1] != pool.seq_pages[0][1]      # tail forked

    ks, vs = seq_kv(9, 3)
    assert pool.write_suffix(1, ks, vs, 3, step=1)
    assert pool.seq_len[1] == 9
    got_k = np.asarray(pool.materialize(1)[0])
    np.testing.assert_array_equal(got_k[:, 0, :6], np.asarray(k0)[:, 0, :6])
    np.testing.assert_array_equal(got_k[:, 0, 6:9], np.asarray(ks)[:, 0])
    # the donor never sees the fork
    np.testing.assert_array_equal(np.asarray(pool.materialize(0)[0]), donor_k)


def test_append_into_tree_cached_tail_cows():
    """Decode-appending into a page the radix tree references forks it first
    (the cached prompt must stay immutable for future matchers)."""
    pool = make_pool(num_pages=8, ps=4, cap=16, prefix_mode="radix")
    prompt = np.arange(6, dtype=np.int32)          # partial tail page (2/4)
    k, v = seq_kv(0, 6)
    assert pool.write_prefill(0, k, v, 6, step=0)
    pool.insert_prompt(0, prompt, step=0)
    tail = pool.seq_pages[0][1]
    assert pool.pages[tail].refs == 2              # seq + tree
    kv1 = jnp.ones((L, KVH, HD), jnp.float32)
    assert pool.append_token(0, kv1, 2 * kv1, step=1)
    assert pool.stats.cow_promotions == 1
    assert pool.seq_pages[0][1] != tail
    assert pool.pages[tail].refs == 1              # tree keeps the original
    m = pool.match_prefix(np.concatenate([prompt, [99]]))
    assert m.matched_tokens == 6                   # cached prompt intact


# ---------------------------------------------------------------------------
# property tier: allocator invariants under random admit/append/park/finish
# ---------------------------------------------------------------------------

TEMPLATES = (tuple(range(100, 106)), tuple(range(200, 206)))   # 6 tokens each

OPS = st.lists(st.tuples(st.sampled_from(("admit", "append", "park", "finish")),
                         st.integers(0, 7)),
               min_size=4, max_size=28)


def _check_invariants(pool: PagePool):
    n = pool.cfg.num_pages
    raw_slots = [p.slot for p in pool.pages.values() if p.slot is not None]
    # slot states partition the slab: every slot free xor raw, no aliasing
    assert len(raw_slots) == len(set(raw_slots))
    assert len(pool.free_slots) == len(set(pool.free_slots))
    assert set(raw_slots).isdisjoint(pool.free_slots)
    assert len(raw_slots) + len(pool.free_slots) == n
    assert all(0 <= s < n for s in raw_slots + pool.free_slots)
    # raw xor compressed, never both
    for p in pool.pages.values():
        assert (p.slot is None) != (p.comp is None)
        assert p.state in (RAW, COMPRESSED)
    # refcounts == live readers: per-seq mappings + the radix tree's refs
    expected: dict[int, int] = {}
    for pids in pool.seq_pages.values():
        for pid in pids:
            expected[pid] = expected.get(pid, 0) + 1
    for pid in tree_pids(pool):
        expected[pid] = expected.get(pid, 0) + 1
    assert set(expected) == set(pool.pages)
    for pid, refs in expected.items():
        assert pool.pages[pid].refs == refs, pid
    # page-table geometry
    for seq, pids in pool.seq_pages.items():
        assert len(pids) == -(-pool.seq_len[seq] // pool.cfg.page_size)


def _make_room(pool: PagePool, need: int, protect: set[int]) -> bool:
    while pool.n_free_slots() < need:
        cands = sorted(p.page_id for p in pool.pages.values()
                       if p.slot is not None and p.page_id not in protect)
        if not cands:
            return False
        pool.compress_page(cands[0])
    return True


@settings(max_examples=12, deadline=None)
@given(OPS)
def test_allocator_invariants_random_traces(ops):
    """Random admit/append/park/finish traces with template-sharing prompts:
    after every op the slab partitions into free|raw slots, refcounts equal
    live readers (seq mappings + tree), and the drain leaks nothing."""
    pool = make_pool(num_pages=6, ps=4, cap=16, prefix_mode="radix")
    live: list[int] = []
    next_seq = 0
    for op, arg in ops:
        if op == "admit":
            seq = next_seq
            prompt = np.asarray(TEMPLATES[arg % 2] + (300 + seq, 301 + seq),
                                np.int32)
            m = pool.match_prefix(prompt)
            demand = pool.admit_slot_demand(m, len(prompt))
            if not _make_room(pool, demand, set()):
                continue
            if m.matched_tokens:
                if not pool.map_prefix(seq, m, step=seq):
                    continue
                suf = len(prompt) - m.matched_tokens
                ks, vs = seq_kv(50 + seq, suf)
                assert pool.write_suffix(seq, ks, vs, suf, step=seq)
            else:
                k, v = seq_kv(50 + seq, len(prompt))
                if not pool.write_prefill(seq, k, v, len(prompt), step=seq):
                    continue
            pool.insert_prompt(seq, prompt, step=seq)
            live.append(seq)
            next_seq += 1
        elif op == "append" and live:
            seq = live[arg % len(live)]
            if pool.seq_len[seq] >= pool.cfg.seq_capacity:
                continue
            if not pool.tail_writable(seq) and not _make_room(
                    pool, pool.tail_slot_demand(seq), set()):
                continue
            kv1 = jnp.full((L, KVH, HD), float(arg), jnp.float32)
            pool.append_token(seq, kv1, -kv1, step=100 + arg)
        elif op == "park" and live:
            seq = live[arg % len(live)]
            pool.compress_pages(list(pool.seq_pages[seq]))
        elif op == "finish" and live:
            seq = live.pop(arg % len(live))
            pool.free_seq(seq)
        _check_invariants(pool)
    # drain: finish everything, then drop the radix cache — no leaks
    for seq in live:
        pool.free_seq(seq)
    pool.release_prefix_cache()
    _check_invariants(pool)
    assert not pool.pages and not pool.seq_pages
    assert sorted(pool.free_slots) == list(range(pool.cfg.num_pages))


# ---------------------------------------------------------------------------
# end-to-end parity + the prefill win (real engine)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_engine():
    cfg = configs.get("glm4-9b", smoke=True)
    model = zoo.build(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _template_requests(cfg, n=3, seed=0):
    """n requests sharing one 16-token template with distinct 3-token tails."""
    rng = np.random.default_rng(seed)
    template = rng.integers(0, cfg.vocab, (16,), dtype=np.int32)
    return [Request(req_id=i,
                    tokens=np.concatenate(
                        [template,
                         rng.integers(0, cfg.vocab, (3,), dtype=np.int32)]),
                    n_new=4)
            for i in range(n)]


def _pool_cfg(**kw):
    base = dict(num_pages=12, page_size=8, seq_capacity=48, cold_after=100,
                eb=1e-4)
    base.update(kw)
    return PoolConfig(**base)


def test_radix_copy_bitwise_parity(tiny_engine):
    """With ample slots, the shared pool's decode cache is bit-identical per
    step to the copy pool's (same matching, private duplicates) — sharing
    changes storage, never values. Lockstep-compared via ``gather``."""
    cfg, model, params = tiny_engine
    reqs = _template_requests(cfg)
    engines, batchers, outs = {}, {}, {}
    for mode in ("radix", "copy"):
        eng = Engine(model, params, pool=_pool_cfg(prefix_mode=mode))
        pool = eng.make_pool()
        b = ContinuousBatcher(eng, pool, max_batch=3)
        b.recs = {r.req_id: SeqRecord(req=r) for r in reqs}
        engines[mode], batchers[mode], outs[mode] = eng, b, {}
    for step in range(1, 10):
        done = True
        for mode in ("radix", "copy"):
            batchers[mode].step(step, outs[mode])
            done &= all(r.state == "finished"
                        for r in batchers[mode].recs.values())
        br, bc = batchers["radix"], batchers["copy"]
        assert br.lanes == bc.lanes                 # identical scheduling
        gr = br.pool.gather(br.lanes)
        gc = bc.pool.gather(bc.lanes)
        np.testing.assert_array_equal(np.asarray(gr["length"]),
                                      np.asarray(gc["length"]))
        np.testing.assert_array_equal(np.asarray(gr["k"]), np.asarray(gc["k"]))
        np.testing.assert_array_equal(np.asarray(gr["v"]), np.asarray(gc["v"]))
        if done:
            break
    assert done
    assert batchers["radix"].stats.prefix_hits >= 2        # sharing really ran
    assert batchers["copy"].stats.prefix_hits >= 2
    # the shared pool held fewer physical raw pages at peak
    assert (br.pool.stats.high_water_slots < bc.pool.stats.high_water_slots)
    for r in reqs:
        np.testing.assert_array_equal(outs["radix"][r.req_id],
                                      outs["copy"][r.req_id])


def test_radix_below_min_match_is_the_off_scheduler(tiny_engine):
    """With ``min_match_tokens`` above every prompt, the radix pool never
    matches and serves the trace token-identically to ``prefix_mode="off"``
    — the fallback really is the non-shared scheduler."""
    cfg, model, params = tiny_engine
    reqs = _template_requests(cfg)
    outs = {}
    for name, pc in (("gated", _pool_cfg(prefix_mode="radix",
                                         min_match_tokens=10_000)),
                     ("off", _pool_cfg(prefix_mode="off"))):
        eng = Engine(model, params, pool=pc)
        outputs, stats, _ = eng.serve(list(reqs), max_batch=3)
        assert stats.prefix_hits == 0 and stats.prefill_tokens_saved == 0
        outs[name] = outputs
    for r in reqs:
        np.testing.assert_array_equal(outs["gated"][r.req_id],
                                      outs["off"][r.req_id])


def test_prefix_sharing_prefill_and_memory_win(tiny_engine):
    """The seeded prefix-skewed trace: radix admits >= 2x fewer prefill
    tokens than the non-shared pool and peaks no higher on physical bytes,
    with every prompt token accounted prefilled-or-saved."""
    cfg, model, params = tiny_engine
    tg = TraceGenConfig(seed=7, n_requests=6, vocab=cfg.vocab,
                        arrival_rate=1.5, n_templates=1, template_len=(16, 22),
                        template_reuse=0.75, suffix_len=(2, 5), n_new=(3, 4))
    reqs = generate(tg)
    total_prompt = sum(len(r.tokens) for r in reqs)
    stats = {}
    for mode in ("radix", "off"):
        eng = Engine(model, params,
                     pool=_pool_cfg(num_pages=6, cold_after=2, prefix_mode=mode,
                                    max_cached_pages=6))
        outputs, st_, _ = eng.serve(list(reqs), max_batch=3)
        assert len(outputs) == len(reqs)
        assert st_.prefill_tokens + st_.prefill_tokens_saved == total_prompt
        stats[mode] = st_
    radix, off = stats["radix"], stats["off"]
    assert off.prefill_tokens == total_prompt
    assert off.prefill_tokens_saved == 0
    assert radix.prefix_hits >= 2
    assert off.prefill_tokens >= 2 * radix.prefill_tokens
    assert radix.high_water_used_bytes <= off.high_water_used_bytes


# ---------------------------------------------------------------------------
# deterministic tie-breaks (scheduler bugfix ride-along)
# ---------------------------------------------------------------------------

def test_victim_total_order_under_full_ties():
    """Equal priority AND equal arrival: the victim is the highest seq id,
    deterministically — not dict-iteration order."""
    running = {3: (0, 5), 1: (0, 5), 2: (0, 5)}
    assert TieredPolicy.victim(running) == 3
    assert TieredPolicy.victim(dict(sorted(running.items()))) == 3
    # arrival still dominates the id tie-break
    assert TieredPolicy.victim({1: (0, 7), 2: (0, 5)}) == 1   # latest arrival
    # priority dominates everything
    assert TieredPolicy.victim({1: (0, 9), 2: (1, 1)}) == 1


def test_reclaim_compresses_in_page_id_order_on_write_ties():
    """Pages with identical last_write reclaim lowest page_id first."""
    pool = make_pool(num_pages=4, ps=4, cap=16, prefix_mode="off")
    for seq in range(4):
        k, v = seq_kv(seq, 4)
        assert pool.write_prefill(seq, k, v, 4, step=0)   # all last_write=0
    assert TieredPolicy().reclaim(pool, 2, protect=set())
    comp = sorted(p.page_id for p in pool.pages.values() if p.comp is not None)
    assert comp == [0, 1]


def test_admission_tie_break_is_req_id(tiny_engine):
    """Two requests, same priority, same arrive_at, one lane: req_id admits
    first — and the whole trace replays identically."""
    cfg, model, params = tiny_engine
    rng = np.random.default_rng(3)
    reqs = [Request(req_id=i,
                    tokens=rng.integers(0, cfg.vocab, (8,), dtype=np.int32),
                    n_new=3, priority=1, arrive_at=1)
            for i in (0, 1)]
    runs = []
    for _ in range(2):
        eng = Engine(model, params, pool=_pool_cfg(prefix_mode="off"))
        pool = eng.make_pool()
        b = ContinuousBatcher(eng, pool, max_batch=1)
        b.recs = {r.req_id: SeqRecord(req=r) for r in reqs}
        outs = {}
        b.step(1, outs)
        assert b.recs[0].state == "running"      # req_id 0 wins the lane
        assert b.recs[1].state == "waiting"
        while not all(r.state == "finished" for r in b.recs.values()):
            b.step(b.stats.decode_steps + 2, outs)
        runs.append({k: np.asarray(v) for k, v in outs.items()})
    for k in runs[0]:
        np.testing.assert_array_equal(runs[0][k], runs[1][k])
