"""Distribution-layer tests: sharding resolution + multi-device semantics.

Multi-device checks run in a subprocess with XLA_FLAGS=8 fake devices so the
main pytest process keeps the default single-device view (per the brief, the
512-device override belongs to the dry-run ONLY).
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_resolve_spec_divisibility():
    from jax.sharding import PartitionSpec as P
    from repro.dist.sharding import resolve_spec

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    mesh = FakeMesh()
    # divisible dims shard; indivisible fall back to replication
    assert resolve_spec(("fsdp", "tp"), (64, 32), mesh) == P("data", "model")
    assert resolve_spec(("fsdp", "tp"), (64, 10), mesh) == P("data", None)
    assert resolve_spec((None, "tp"), (7, 48), mesh) == P(None, "model")
    # dp spans (pod, data) when present and falls back to a single axis
    class PodMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}
    assert resolve_spec(("dp",), (64,), PodMesh()) == P(("pod", "data"))
    assert resolve_spec(("dp",), (16,), PodMesh()) == P("data")


def test_logical_table_single_vs_multi_pod():
    from repro.dist.sharding import logical_to_mesh_axes

    class FakeMesh:
        def __init__(self, names):
            self.axis_names = names
    t1 = logical_to_mesh_axes(FakeMesh(("data", "model")))
    assert t1["fsdp"] == ("data",) and t1["dp"] == ("data",) and t1["tp"] == ("model",)
    t2 = logical_to_mesh_axes(FakeMesh(("pod", "data", "model")))
    assert t2["fsdp"] == ("data",) and t2["dp"] == ("pod", "data")


def test_flash_decode_shard_kernel_partials_contract():
    """Single-process check of the kernel's per-shard contract: partials at a
    non-zero shard_offset match the jnp reference, including a shard that
    lies entirely past every sequence's length (all-empty => m == NEG_INF,
    num == den == 0, so the psum combine contributes nothing)."""
    import jax.numpy as jnp
    from repro.dist import flash_decode as fdr
    from repro.kernels import flash_decode as fdk

    rng = np.random.default_rng(3)
    B, S_shard, H, KVH, D = 3, 16, 8, 4, 16
    q = jnp.asarray(rng.standard_normal((B, H, D)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, S_shard, KVH, D)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, S_shard, KVH, D)).astype(np.float32))
    length = jnp.asarray([5, 30, 17], jnp.int32)
    for offset in (0, 16, 32):        # 32: fully past every length
        got = fdk.decode_partials(q, k, v, length, shard_offset=offset,
                                  interpret=True)
        want = fdr.decode_partials(q, k, v, length, shard_offset=offset)
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       atol=2e-4)
    m, num, den = fdk.decode_partials(q, k, v, length, shard_offset=32,
                                      interpret=True)
    assert np.all(np.asarray(m) == fdr.NEG_INF)
    assert np.all(np.asarray(num) == 0.0) and np.all(np.asarray(den) == 0.0)


def test_wire_bytes_accounting():
    from repro.dist.compressed_allreduce import GradCompressionConfig, wire_bytes_per_leaf
    cfg = GradCompressionConfig(capacity_frac=0.5)
    acc = wire_bytes_per_leaf(1 << 20, cfg)
    assert acc["raw"] == 4 << 20
    assert 0 < acc["compressed"] < acc["raw"]
    assert acc["reduction"] > 1.9


MULTIDEV = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

# ---- 1) flash-decoding: sequence-sharded decode == unsharded reference
from repro.models.attention import decode_attention
from repro.dist import compat
from repro.dist.flash_decode import flash_decode_shard
mesh = compat.make_mesh((2, 4), ("data", "model"))
B, S, H, KVH, D = 4, 64, 8, 4, 16
rng = np.random.default_rng(0)
q = jnp.asarray(rng.standard_normal((B, H, D)).astype(np.float32))
k = jnp.asarray(rng.standard_normal((B, S, KVH, D)).astype(np.float32))
v = jnp.asarray(rng.standard_normal((B, S, KVH, D)).astype(np.float32))
length = jnp.array([60, 33, 64, 1], jnp.int32)
ref = decode_attention(q, k, v, length)
S_shard = S // 4

def body(q, k_sh, v_sh, length):
    idx = jax.lax.axis_index("model")
    return flash_decode_shard(q, k_sh, v_sh, length, axis="model",
                              shard_offset=idx * S_shard)

sm = compat.shard_map(body, mesh=mesh,
                      in_specs=(P(), P(None, "model"), P(None, "model"), P()),
                      out_specs=P(), axis_names={"model"})
out = jax.jit(sm)(q, k, v, length)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)
print("flash_decode OK")

# ---- 1b) same combine, per-shard partials through the Pallas KV-tile kernel
def body_k(q, k_sh, v_sh, length):
    idx = jax.lax.axis_index("model")
    return flash_decode_shard(q, k_sh, v_sh, length, axis="model",
                              shard_offset=idx * S_shard, use_kernels=True)

sm_k = compat.shard_map(body_k, mesh=mesh,
                        in_specs=(P(), P(None, "model"), P(None, "model"), P()),
                        out_specs=P(), axis_names={"model"})
out_k = jax.jit(sm_k)(q, k, v, length)
np.testing.assert_allclose(np.asarray(out_k), np.asarray(ref), rtol=2e-4, atol=2e-4)
print("flash_decode_kernel OK")

# ---- 2) compressed cross-pod reduce ~= exact mean within error bound
from repro.dist.compressed_allreduce import (GradCompressionConfig, init_error_state,
                                             reduce_stacked)
mesh3 = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
gc = GradCompressionConfig(enabled=True, eb=1e-4, min_leaf_size=1024)
g_stack = {"w": jnp.asarray(rng.standard_normal((2, 64, 64)).astype(np.float32)),
           "b": jnp.asarray(rng.standard_normal((2, 8)).astype(np.float32))}
g_abs = {"w": jax.ShapeDtypeStruct((64, 64), jnp.float32),
         "b": jax.ShapeDtypeStruct((8,), jnp.float32)}
err = init_error_state(g_abs, 2, gc)
red, new_err = jax.jit(lambda g, e: reduce_stacked(g, e, gc, mesh3))(g_stack, err)
exact = jax.tree.map(lambda x: jnp.mean(x, 0), g_stack)
w_rng = float(jnp.max(g_stack["w"]) - jnp.min(g_stack["w"]))
assert float(jnp.max(jnp.abs(red["w"] - exact["w"]))) <= 2 * 1e-4 * w_rng, "compress err"
np.testing.assert_allclose(np.asarray(red["b"]), np.asarray(exact["b"]), rtol=1e-6)
# error feedback: residuals stored, replayed next round -> 2-round mean converges
red2, _ = jax.jit(lambda g, e: reduce_stacked(g, e, gc, mesh3))(g_stack, new_err)
err1 = float(jnp.max(jnp.abs(red["w"] - exact["w"])))
two_round = (np.asarray(red["w"]) + np.asarray(red2["w"])) / 2
err2 = float(np.max(np.abs(two_round - np.asarray(exact["w"]))))
assert err2 <= err1 + 1e-7, (err1, err2)
print("compressed_reduce OK")

# ---- 2b) bucketed reduce == barrier oracle, bit-identical over 3 steps
from repro.dist import bucketed_reduce as bkt
g_stack3 = {"layers": {"wq": g_stack["w"],
                       "wk": jnp.asarray(rng.standard_normal((2, 32, 64)).astype(np.float32))},
            "unembed": jnp.asarray(rng.standard_normal((2, 64, 64)).astype(np.float32)),
            "b": g_stack["b"]}
g_abs3 = jax.tree.map(lambda g: jax.ShapeDtypeStruct(g.shape[1:], g.dtype), g_stack3)
from repro.dist.compressed_allreduce import wire_bytes_per_leaf
wire1 = wire_bytes_per_leaf(64 * 64, gc)["compressed"]
for bucket_bytes in (wire1 + 1, 1 << 30):      # one leaf per bucket / all-in-one
    gcb = GradCompressionConfig(enabled=True, eb=1e-4, min_leaf_size=1024,
                                overlap=True, bucket_bytes=bucket_bytes)
    plan = bkt.assign_buckets(g_abs3, gcb)
    err_a = init_error_state(g_abs3, 2, gc)
    err_b = init_error_state(g_abs3, 2, gcb)
    f_bar = jax.jit(lambda g, e: reduce_stacked(g, e, gc, mesh3))
    f_bkt = jax.jit(lambda g, e: bkt.reduce_stacked_bucketed(g, e, gcb, mesh3, plan=plan))
    for step in range(3):
        gs = jax.tree.map(lambda x: x * (1.0 + 0.25 * step), g_stack3)
        red_a, err_a = f_bar(gs, err_a)
        red_b, err_b = f_bkt(gs, err_b)
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
                     red_a, red_b)
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
                     err_a, err_b)
print("bucketed_parity OK")

# ---- 2c) hlo_cost per-bucket cross-pod bytes == analytic container model
# (the last f_bkt/plan from 2b: the single all-in-one bucket)
from repro.launch import hlo_cost as hc
compiled = f_bkt.lower(g_stack3, init_error_state(g_abs3, 2, gcb)).compile()
r = hc.analyze(compiled.as_text(), devices_per_pod=4,
               tag_pattern=bkt.BUCKET_TAG_PATTERN)
expect = bkt.expected_cross_pod_bytes(plan, gcb, n_pods=2)
assert set(expect) <= set(r["cross_pod_by_tag"]), (expect, r["cross_pod_by_tag"])
for tag, want in expect.items():
    got = r["cross_pod_by_tag"][tag]["all-gather"]
    assert got == want, (tag, got, want)
print("bucket_wire_bytes OK")

# ---- 2d) full train step: overlap path (taps + bucketed hops under the pod
# vmap) bit-identical to the barrier path after 2 optimizer steps
from repro import configs as rconfigs
from repro.configs.base import ShapeConfig
from repro.models import zoo as rzoo
from repro.optim import adamw_init
from repro.train.step import TrainConfig, build_train_step
cfg_m = rconfigs.get("glm4-9b", smoke=True)
model_m = rzoo.build(cfg_m)
shape_m = ShapeConfig("t", 32, 4, "train")
batch_m = {"tokens": jnp.asarray(rng.integers(0, cfg_m.vocab, (4, 32)).astype(np.int32)),
           "labels": jnp.asarray(rng.integers(0, cfg_m.vocab, (4, 32)).astype(np.int32))}
params0 = jax.tree.map(np.asarray, model_m.init(jax.random.key(0)))
opt0 = jax.tree.map(np.asarray, adamw_init(params0))
step_out = {}
for name, gc_m in (("barrier", GradCompressionConfig(enabled=True, min_leaf_size=1024)),
                   ("overlap", GradCompressionConfig(enabled=True, min_leaf_size=1024,
                                                     overlap=True, bucket_bytes=1 << 16))):
    step_fn, info = build_train_step(model_m, shape_m, mesh3,
                                     TrainConfig(grad_compress=gc_m, total_steps=10))
    params = jax.device_put(params0, info["params"])
    opt = jax.device_put(opt0, info["opt"])
    ga = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), params)
    err = info["make_err_state"](ga)
    for i in range(2):
        params, opt, err, metrics = step_fn(params, opt, err, jnp.int32(i), batch_m)
    step_out[name] = (jax.tree.map(np.asarray, params), jax.tree.map(np.asarray, err))
jax.tree.map(np.testing.assert_array_equal, step_out["barrier"][0], step_out["overlap"][0])
jax.tree.map(np.testing.assert_array_equal, step_out["barrier"][1], step_out["overlap"][1])
print("overlap_step_parity OK")

# ---- 3) elastic reshard: state moves between meshes, values identical
from repro.ckpt.elastic import reshard
tree = {"w": jnp.asarray(rng.standard_normal((32, 16)).astype(np.float32))}
logical = {"w": ("fsdp", "tp")}
from jax.sharding import Mesh
m_a = compat.make_mesh((4, 2), ("data", "model"))
m_b = Mesh(np.array(jax.devices()[:4]).reshape(2, 2), ("data", "model"))
t_a = reshard(tree, logical, m_a)
t_b = reshard(t_a, logical, m_b)
np.testing.assert_array_equal(np.asarray(t_b["w"]), np.asarray(tree["w"]))
print("elastic OK")

# ---- 4) hlo_cost detects collectives in a sharded program
from repro.launch import hlo_cost
s = NamedSharding(mesh, P("data", "model"))
f = jax.jit(lambda x, w: jnp.sum((x @ w) ** 2),
            in_shardings=(s, NamedSharding(mesh, P("model", None))))
c = f.lower(jax.ShapeDtypeStruct((512, 512), jnp.bfloat16),
            jax.ShapeDtypeStruct((512, 256), jnp.bfloat16)).compile()
r = hlo_cost.analyze(c.as_text())
assert r["flops"] == 2 * 512 * 512 * 256 / 8, r["flops"]   # per-device
assert r["collective_bytes"] > 0
print("hlo_cost OK")
print("ALL OK")
"""


@pytest.mark.slow
def test_multidevice_semantics():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", MULTIDEV], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout[-3000:]}\nstderr:\n{r.stderr[-3000:]}"
    assert "ALL OK" in r.stdout
