"""Numerics of the sequence mixers vs. brute-force oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as attn
from repro.models import mamba2, rwkv6

RNG = np.random.default_rng(7)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("S,H,KVH,D", [(128, 4, 2, 32), (256, 8, 8, 16), (96, 4, 1, 32)])
def test_flash_vs_reference(causal, S, H, KVH, D):
    B = 2
    q = jnp.asarray(RNG.standard_normal((B, S, H, D)).astype(np.float32))
    k = jnp.asarray(RNG.standard_normal((B, S, KVH, D)).astype(np.float32))
    v = jnp.asarray(RNG.standard_normal((B, S, KVH, D)).astype(np.float32))
    out = attn.flash_attention(q, k, v, causal=causal, q_chunk=32, kv_chunk=32)
    ref = attn.attention_reference(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


def test_decode_attention_vs_full():
    """Single-token decode over a cache == last row of full causal attention."""
    B, S, H, KVH, D = 2, 64, 4, 2, 32
    q_all = jnp.asarray(RNG.standard_normal((B, S, H, D)).astype(np.float32))
    k = jnp.asarray(RNG.standard_normal((B, S, KVH, D)).astype(np.float32))
    v = jnp.asarray(RNG.standard_normal((B, S, KVH, D)).astype(np.float32))
    full = attn.attention_reference(q_all, k, v, causal=True)
    out = attn.decode_attention(q_all[:, -1], k, v, jnp.full((B,), S, jnp.int32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(full[:, -1]), rtol=2e-4, atol=2e-4)


def test_rope_preserves_norm_and_relative_phase():
    B, S, H, D = 1, 16, 2, 32
    x = jnp.asarray(RNG.standard_normal((B, S, H, D)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    y = attn.apply_rope(x, pos)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-4)


def test_mrope_matches_rope_when_positions_equal():
    """With t=h=w position ids, M-RoPE degenerates to plain RoPE."""
    B, S, H, D = 1, 8, 2, 32
    x = jnp.asarray(RNG.standard_normal((B, S, H, D)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    pos3 = jnp.broadcast_to(pos[:, None, :], (B, 3, S))
    y1 = attn.apply_rope(x, pos, theta=1e4)
    y2 = attn.apply_mrope(x, pos3, (6, 5, 5), theta=1e4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Mamba2 chunked SSD vs step-by-step recurrence
# ---------------------------------------------------------------------------

def _ssd_stepwise(xh, bmat, cmat, dt, A_log, D):
    B, S, nh, hd = xh.shape
    ds = bmat.shape[-1]
    dtf = jax.nn.softplus(dt.astype(jnp.float32))
    h = jnp.zeros((B, nh, hd, ds), jnp.float32)
    ys = []
    for t in range(S):
        a = jnp.exp(-jnp.exp(A_log)[None] * dtf[:, t])        # (B,nh)
        upd = jnp.einsum("bhe,bd->bhed", xh[:, t].astype(jnp.float32) * dtf[:, t][..., None],
                         bmat[:, t].astype(jnp.float32))
        h = a[:, :, None, None] * h + upd
        y = jnp.einsum("bd,bhed->bhe", cmat[:, t].astype(jnp.float32), h)
        ys.append(y + xh[:, t].astype(jnp.float32) * D[None, :, None])
    return jnp.stack(ys, axis=1), h


def test_mamba2_chunked_equals_stepwise():
    B, S, nh, hd, ds = 2, 256, 4, 16, 8
    xh = jnp.asarray(RNG.standard_normal((B, S, nh, hd)).astype(np.float32))
    bmat = jnp.asarray(RNG.standard_normal((B, S, ds)).astype(np.float32))
    cmat = jnp.asarray(RNG.standard_normal((B, S, ds)).astype(np.float32))
    dt = jnp.asarray(RNG.standard_normal((B, S, nh)).astype(np.float32))
    A_log = jnp.asarray(RNG.standard_normal((nh,)).astype(np.float32) * 0.5)
    D = jnp.asarray(RNG.standard_normal((nh,)).astype(np.float32))
    h0 = jnp.zeros((B, nh, hd, ds), jnp.float32)
    y_chunk, h_chunk = mamba2._ssd_chunked(xh, bmat, cmat, dt, A_log, D, h0)
    y_step, h_step = _ssd_stepwise(xh, bmat, cmat, dt, A_log, D)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_step), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(h_chunk), np.asarray(h_step), rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# RWKV6 chunked WKV vs brute force
# ---------------------------------------------------------------------------

def _wkv_stepwise(r, k, v, la, u):
    B, S, nh, hd = r.shape
    s = jnp.zeros((B, nh, hd, hd), jnp.float32)
    os_ = []
    for t in range(S):
        kv = jnp.einsum("bhd,bhe->bhde", k[:, t], v[:, t])
        o = jnp.einsum("bhd,bhde->bhe", r[:, t], s + u[None, :, :, None] * kv)
        os_.append(o)
        s = jnp.exp(la[:, t])[..., None] * s + kv
    return jnp.stack(os_, axis=1), s


def test_rwkv6_chunked_equals_stepwise():
    B, S, nh, hd = 2, 64, 2, 8
    r = jnp.asarray(RNG.standard_normal((B, S, nh, hd)).astype(np.float32))
    k = jnp.asarray(RNG.standard_normal((B, S, nh, hd)).astype(np.float32))
    v = jnp.asarray(RNG.standard_normal((B, S, nh, hd)).astype(np.float32))
    la = -jnp.exp(jnp.asarray(RNG.standard_normal((B, S, nh, hd)).astype(np.float32)))
    u = jnp.asarray(RNG.standard_normal((nh, hd)).astype(np.float32))
    s0 = jnp.zeros((B, nh, hd, hd), jnp.float32)
    o_chunk, s_chunk = rwkv6._wkv_chunked(r, k, v, la, u, s0)
    o_step, s_step = _wkv_stepwise(r, k, v, la, u)
    np.testing.assert_allclose(np.asarray(o_chunk), np.asarray(o_step), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(s_chunk), np.asarray(s_step), rtol=2e-3, atol=2e-3)


def test_rwkv6_prefill_then_decode_matches_full():
    """State carried out of prefill continues exactly (chunked == stepwise)."""
    B, S, nh, hd = 1, 32, 2, 8
    r = jnp.asarray(RNG.standard_normal((B, S + 1, nh, hd)).astype(np.float32))
    k = jnp.asarray(RNG.standard_normal((B, S + 1, nh, hd)).astype(np.float32))
    v = jnp.asarray(RNG.standard_normal((B, S + 1, nh, hd)).astype(np.float32))
    la = -jnp.exp(jnp.asarray(RNG.standard_normal((B, S + 1, nh, hd)).astype(np.float32)))
    u = jnp.asarray(RNG.standard_normal((nh, hd)).astype(np.float32))
    s0 = jnp.zeros((B, nh, hd, hd), jnp.float32)
    o_full, _ = rwkv6._wkv_chunked(r, k, v, la, u, s0)
    o_pre, s_mid = rwkv6._wkv_chunked(r[:, :S], k[:, :S], v[:, :S], la[:, :S], u, s0)
    o_one, _ = rwkv6._wkv_chunked(r[:, S:], k[:, S:], v[:, S:], la[:, S:], u, s_mid)
    np.testing.assert_allclose(np.asarray(o_one[:, 0]), np.asarray(o_full[:, S]),
                               rtol=2e-3, atol=2e-3)
