"""Container-format tests: serialization roundtrips, versioning, fixtures.

The frozen fixtures under tests/data/ are *checked-in bytes* written by the
format version current at their generation time (see tests/data/
make_fixtures.py). They must keep decompressing bit-exactly forever: a
failure here means the format changed without a version bump — fix the
reader, never the fixture. Byte layout: docs/CONTAINER_FORMAT.md.
"""
import pathlib
import struct

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fz
from repro.data import make_field
from repro.serve.kvpool import PagePool, PoolConfig

DATA = pathlib.Path(__file__).parent / "data"


def _field(shape=(20, 20, 10), kind="smooth", seed=9):
    return jnp.asarray(make_field(kind, shape, seed=seed))


@pytest.mark.parametrize("code_mode", ["sign_mag", "zigzag"])
@pytest.mark.parametrize("exact_outliers", [True, False])
@pytest.mark.parametrize("entropy", [False, True, "auto"])
def test_roundtrip_matrix(code_mode, exact_outliers, entropy):
    f = _field()
    cfg = fz.FZConfig(eb=1e-3, eb_mode="rel", code_mode=code_mode,
                      exact_outliers=exact_outliers)
    comp = fz.compress(f, cfg)
    raw = fz.to_bytes(comp, cfg, entropy=entropy)
    back, back_cfg = fz.from_bytes(raw)
    assert back_cfg.code_mode == code_mode
    assert back_cfg.exact_outliers == exact_outliers
    assert back.shape == comp.shape and back.dtype_name == comp.dtype_name
    assert jnp.array_equal(fz.decompress_bytes(raw), fz.decompress(comp, cfg))


def test_deserialized_container_is_leaf_identical():
    """from_bytes at the original capacities reproduces the compressed pytree
    leaf-for-leaf — the property that lets blob-backed pages vmap-stack next
    to never-serialized ones in the kvpool."""
    f = _field()
    cfg = fz.FZConfig(eb=1e-3, eb_mode="rel")
    comp = fz.compress(f, cfg)
    raw = fz.to_bytes(comp, cfg, entropy=True)
    back, _ = fz.from_bytes(raw, capacity=int(comp.payload.shape[0]),
                            outlier_capacity=int(comp.outlier_idx.shape[0]))
    for a, b in zip(jax.tree.leaves(comp), jax.tree.leaves(back)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_entropy_flag_recorded_and_routed():
    f = _field()
    cfg = fz.FZConfig(eb=1e-3, eb_mode="rel")
    comp = fz.compress(f, cfg)
    for entropy, expect in ((False, False), (True, True)):
        raw = fz.to_bytes(comp, cfg, entropy=entropy)
        flags = struct.unpack_from("<H", raw, 6)[0]
        assert bool(flags & fz.FLAG_ENTROPY) is expect


def test_auto_probe_skips_incompressible():
    """Near-uniform payload bytes: the exact-size probe must refuse the
    entropy stage and keep the raw payload."""
    rng = np.random.default_rng(0)
    noise = jnp.asarray(rng.standard_normal(8192), jnp.float32)
    # white noise at a moderate bound: the compacted payload's byte histogram
    # is flat enough that the exact-size probe predicts < ENTROPY_MIN_GAIN
    cfg = fz.FZConfig(eb=1e-4, eb_mode="rel")
    comp = fz.compress(noise, cfg)
    raw = fz.to_bytes(comp, cfg, entropy="auto")
    assert not struct.unpack_from("<H", raw, 6)[0] & fz.FLAG_ENTROPY
    assert jnp.array_equal(fz.decompress_bytes(raw), fz.decompress(comp, cfg))


def test_auto_probe_selects_on_field_and_shrinks():
    f = _field(shape=(32, 32, 16))
    cfg = fz.FZConfig(eb=1e-3, eb_mode="rel")
    comp = fz.compress(f, cfg)
    plain = fz.to_bytes(comp, cfg, entropy=False)
    auto = fz.to_bytes(comp, cfg, entropy="auto")
    assert struct.unpack_from("<H", auto, 6)[0] & fz.FLAG_ENTROPY
    assert len(auto) < len(plain)


def test_bf16_dtype_accounting_survives_serialization():
    f = _field().astype(jnp.bfloat16)
    cfg = fz.FZConfig(eb=1e-3, eb_mode="rel")
    comp = fz.compress(f, cfg)
    back, _ = fz.from_bytes(fz.to_bytes(comp, cfg))
    assert back.dtype_name == "bfloat16"
    assert int(back.raw_bytes()) == f.size * 2


def test_future_version_raises():
    f = _field(shape=(16, 16))
    cfg = fz.FZConfig(eb=1e-3, eb_mode="rel")
    raw = bytearray(fz.to_bytes(fz.compress(f, cfg), cfg))
    struct.pack_into("<H", raw, 4, fz.CONTAINER_VERSION + 1)
    with pytest.raises(fz.FZFormatError, match="not supported"):
        fz.from_bytes(bytes(raw))


@pytest.mark.parametrize("junk", [b"", b"abc", b"\x00" * 64, b"FZGC"])
def test_garbage_raises(junk):
    with pytest.raises(fz.FZFormatError):
        fz.from_bytes(junk)


def test_truncated_container_raises():
    f = _field(shape=(16, 16))
    cfg = fz.FZConfig(eb=1e-3, eb_mode="rel")
    raw = fz.to_bytes(fz.compress(f, cfg), cfg)
    with pytest.raises(fz.FZFormatError, match="truncated"):
        fz.from_bytes(raw[: len(raw) // 2])


def test_frozen_v1_fixtures_decode_bit_exactly():
    expected = np.load(DATA / "expected_v1.npy")
    for name in ("container_v1_plain.bin", "container_v1_entropy.bin"):
        raw = (DATA / name).read_bytes()
        rec = np.asarray(fz.decompress_bytes(raw))
        assert np.array_equal(rec, expected), name
    plain = (DATA / "container_v1_plain.bin").read_bytes()
    entro = (DATA / "container_v1_entropy.bin").read_bytes()
    assert not struct.unpack_from("<H", plain, 6)[0] & fz.FLAG_ENTROPY
    assert struct.unpack_from("<H", entro, 6)[0] & fz.FLAG_ENTROPY


def test_frozen_legacy_stream_decodes_bit_exactly():
    raw = (DATA / "legacy_stream.bin").read_bytes()
    expected = np.load(DATA / "expected_legacy.npy")
    c, cfg = fz.from_bytes(raw)
    assert cfg.exact_outliers and c.dtype_name == "float32"
    assert np.array_equal(np.asarray(fz.decompress(c, cfg)), expected)


def test_pool_cold_entropy_parity():
    """A cold_entropy pool must gather bit-identically to a plain pool: the
    blob tier may change storage, never numerics."""
    rng = np.random.default_rng(1)
    L, kvh, d, S = 1, 2, 16, 24
    k = jnp.asarray(rng.standard_normal((L, 1, 32, kvh, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((L, 1, 32, kvh, d)), jnp.float32)
    gathers = {}
    for cold_entropy in (False, True):
        cfg = PoolConfig(num_pages=4, page_size=8, seq_capacity=32,
                         cold_after=1, eb=1e-4, cold_entropy=cold_entropy)
        pool = PagePool(cfg, n_layers=L, n_kv_heads=kvh, head_dim=d)
        assert pool.write_prefill(0, k, v, S, step=0)
        pool.compress_pages([p.page_id for p in pool.pages_of(0)])
        out = pool.gather([0])
        gathers[cold_entropy] = (np.asarray(out["k"]), np.asarray(out["v"]))
        blob_pages = [p for p in pool.pages.values() if p.blob is not None]
        assert (len(blob_pages) > 0) is cold_entropy
    assert np.array_equal(gathers[False][0], gathers[True][0])
    assert np.array_equal(gathers[False][1], gathers[True][1])
