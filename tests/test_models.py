"""Per-architecture smoke tests: reduced configs, 1 fwd/train step on CPU,
shape + finiteness asserts (brief deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import zoo

B, S = 2, 64


def _batch(cfg, rng):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S), dtype=np.int32)),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S), dtype=np.int32))}
    if cfg.mrope_sections is not None:
        batch["positions"] = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, 3, S))
    if cfg.family == "audio":
        batch["audio_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_audio_ctx, cfg.d_model)).astype(np.float32)
        ).astype(jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = configs.get(arch, smoke=True)
    model = zoo.build(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = _batch(cfg, rng)
    loss, aux = jax.jit(model.train_loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    grads = jax.grad(lambda p: model.train_loss(p, batch)[0])(params)
    gnorm = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32)))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, f"{arch}: bad grads"


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_smoke_prefill_decode(arch):
    cfg = configs.get(arch, smoke=True)
    model = zoo.build(cfg)
    params = model.init(jax.random.key(1))
    rng = np.random.default_rng(1)
    batch = _batch(cfg, rng)
    batch.pop("labels")
    logits, cache = jax.jit(model.prefill)(params, batch)
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    pos = (jnp.full((B, 3, 1), S, jnp.int32) if cfg.mrope_sections is not None else None)
    logits2, cache2 = jax.jit(lambda p, c, t: model.decode(p, c, t, pos))(params, cache, tok)
    assert logits2.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits2.astype(jnp.float32))))
    if "length" in cache2:
        assert int(cache2["length"][0]) == S + 1


def test_exact_configs_match_brief():
    """Pin the published dims (vs. the assignment table)."""
    expect = {
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
        "glm4-9b": (40, 4096, 32, 2, 13696, 151552),
        "internlm2-20b": (48, 6144, 48, 8, 16384, 92544),
        "yi-6b": (32, 4096, 32, 4, 11008, 64000),
        "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
    }
    for arch, (L, d, H, KV, ff, V) in expect.items():
        c = configs.get(arch)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == \
            (L, d, H, KV, ff, V), arch


def test_moe_configs():
    dbrx = configs.get("dbrx-132b")
    assert (dbrx.n_experts, dbrx.top_k) == (16, 4)
    scout = configs.get("llama4-scout-17b-a16e")
    assert (scout.n_experts, scout.top_k, scout.shared_expert) == (16, 1, True)


def test_long_context_skip_list():
    """long_500k only for sub-quadratic archs (DESIGN.md §4)."""
    from repro.configs.base import cells_for
    runs_long = {a for a in configs.ARCH_IDS
                 if "long_500k" in cells_for(configs.get(a))}
    assert runs_long == {"zamba2-2.7b", "rwkv6-3b"}


def test_param_counts_in_published_ballpark():
    """Total parameters within ~20% of the names' advertised sizes."""
    expect_b = {"yi-6b": 6.1, "mistral-large-123b": 123, "glm4-9b": 9.4,
                "internlm2-20b": 19.9, "qwen2-vl-72b": 72,
                "dbrx-132b": 132, "rwkv6-3b": 3.1, "zamba2-2.7b": 2.7}
    for arch, target in expect_b.items():
        n = zoo.build(configs.get(arch)).param_count() / 1e9
        assert abs(n - target) / target < 0.35, f"{arch}: {n:.1f}B vs {target}B"
