"""repro.obs unit tier: registry semantics, histogram quantile accuracy vs
numpy, span nesting/reentrancy (including the jit discipline: spans compile
to no-ops inside traced regions and ``span_traces`` counts compilations),
Chrome trace schema, sentinel triggering, and ring bounding."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.obs import sentinels, spans


@pytest.fixture(autouse=True)
def clean_obs():
    """Each test sees a fresh registry + event ring (process-global state)."""
    obs.reset()
    obs.clear_events()
    yield
    obs.reset()
    obs.clear_events()


# -- registry ----------------------------------------------------------------

def test_counter_gauge_identity_and_labels():
    c1 = obs.counter("reqs", op="compress")
    c1.inc()
    c1.inc(4)
    # same (name, labels) -> same instance; different labels -> different
    assert obs.counter("reqs", op="compress") is c1
    assert obs.counter("reqs", op="decompress") is not c1
    assert c1.value == 5
    g = obs.gauge("depth")
    g.set(3)
    g.max(1)          # high-water keeps the larger
    assert g.value == 3.0
    g.max(9)
    assert g.value == 9.0
    snap = obs.snapshot()
    assert snap["counters"]["reqs{op=compress}"] == 5
    assert snap["counters"]["reqs{op=decompress}"] == 0
    assert snap["gauges"]["depth"] == 9.0
    json.dumps(snap)   # snapshot must be JSON-ready


def test_metric_kind_collision_raises():
    obs.counter("x")
    with pytest.raises(TypeError):
        obs.gauge("x")


def test_disabled_suspends_all_recording():
    with obs.disabled():
        obs.counter("c").inc()
        obs.gauge("g").set(5)
        obs.histogram("h").observe(1.0)
        with obs.span("quiet"):
            pass
    snap = obs.snapshot()
    assert snap["counters"]["c"] == 0
    assert snap["gauges"]["g"] == 0.0
    assert snap["histograms"]["h"]["count"] == 0
    assert spans.events() == []
    obs.counter("c").inc()     # re-enabled on exit
    assert obs.counter("c").value == 1


def test_histogram_percentiles_vs_numpy():
    rng = np.random.default_rng(0)
    for name, data in [
        ("lognorm", rng.lognormal(0.0, 2.0, 5000)),
        ("uniform", rng.uniform(0.5, 100.0, 5000)),
        ("heavy", rng.pareto(1.5, 5000) + 1.0),
    ]:
        h = obs.histogram(name)
        for v in data:
            h.observe(v)
        assert h.count == len(data)
        assert h.min == data.min() and h.max == data.max()
        assert h.sum == pytest.approx(data.sum())
        for q in (10, 50, 90, 99):
            exact = float(np.percentile(data, q))
            est = h.percentile(q)
            # log-bucketed at base 2**(1/8) -> ~9% relative resolution
            assert est == pytest.approx(exact, rel=0.12), (name, q)
        assert h.percentile(0) == data.min()
        assert h.percentile(100) == data.max()


def test_histogram_zero_and_negative_do_not_blow_up():
    h = obs.histogram("edge")
    h.observe(0.0)
    h.observe(-3.0)
    h.observe(2.0)
    assert h.count == 3
    assert h.percentile(100) == 2.0
    assert h.percentile(0) == -3.0


# -- spans -------------------------------------------------------------------

def test_span_nesting_depth_parent_and_timing():
    with obs.span("outer", job=1):
        assert spans.current_stack() == ("outer",)
        with obs.span("inner"):
            assert spans.current_stack() == ("outer", "inner")
    assert spans.current_stack() == ()
    evs = spans.events()
    # inner closes first
    assert [e["name"] for e in evs] == ["inner", "outer"]
    inner, outer = evs
    assert inner["depth"] == 1 and inner["parent"] == "outer"
    assert outer["depth"] == 0 and outer["parent"] is None
    assert outer["dur"] >= inner["dur"] > 0
    # temporal nesting: inner's window sits inside outer's
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-6
    assert outer["args"]["job"] == 1
    assert obs.counter("span_calls", span="outer").value == 1
    h = obs.DEFAULT.find("span_ms", span="outer")
    assert h is not None and h.count == 1


def test_span_reentrant_and_exception_safe():
    s = obs.span("recurse")

    def go(n):
        with s:
            if n:
                go(n - 1)

    go(3)
    assert obs.counter("span_calls", span="recurse").value == 4
    assert spans.current_stack() == ()
    with pytest.raises(ValueError):
        with obs.span("boom"):
            raise ValueError("x")
    assert spans.current_stack() == ()       # stack restored on exception
    assert obs.counter("span_calls", span="boom").value == 1


def test_span_decorator():
    @obs.span("deco")
    def f(x):
        return x + 1

    assert f(1) == 2 and f(2) == 3
    assert obs.counter("span_calls", span="deco").value == 2


def test_span_attrs_never_retain_tracers():
    @jax.jit
    def f(x):
        with obs.span("traced", val=x):     # x is a tracer here
            return x * 2

    f(jnp.ones(4))
    (ev,) = [e for e in spans.events() if e["name"] == "traced"]
    assert isinstance(ev["args"]["val"], str)    # stringified, not retained


def test_span_jit_discipline_no_runtime_events_and_retrace_detector():
    @jax.jit
    def f(x):
        with obs.span("jit.body"):
            return x * 2 + 1

    x = jnp.arange(8, dtype=jnp.float32)
    np.testing.assert_allclose(f(x), 2 * x + 1)   # compile #1
    evs = [e for e in spans.events() if e["name"] == "jit.body"]
    assert len(evs) == 1 and evs[0]["cat"] == "jit-trace"
    assert obs.counter("span_traces", span="jit.body").value == 1
    assert obs.counter("span_calls", span="jit.body").value == 0

    # executing the compiled program records nothing: span is a no-op at
    # runtime, so repeated calls add no events and bump no counters
    for _ in range(5):
        f(x)
    assert len([e for e in spans.events() if e["name"] == "jit.body"]) == 1
    assert obs.counter("span_traces", span="jit.body").value == 1

    # a new shape retraces: span_traces is the retrace detector
    f(jnp.arange(16, dtype=jnp.float32))
    assert obs.counter("span_traces", span="jit.body").value == 2


def test_span_eager_wrapper_contains_trace_time_events():
    """The acceptance-criteria nesting: an eager wrapper span triggering a
    compilation temporally contains the jit-trace event of its inner span."""
    @jax.jit
    def inner(x):
        with obs.span("stage"):
            return x + 1

    with obs.span("wrapper"):
        inner(jnp.ones(4))
    evs = {e["name"]: e for e in spans.events()}
    w, s = evs["wrapper"], evs["stage"]
    assert w["cat"] == "span" and s["cat"] == "jit-trace"
    assert w["ts"] <= s["ts"]
    assert s["ts"] + s["dur"] <= w["ts"] + w["dur"] + 1e-6


def test_ring_bounded_under_flood():
    spans.set_ring_capacity(512)
    try:
        n = 1_000_000
        for i in range(n):
            spans._record(f"e{i}", "span", float(i), 1.0, 0, None, {})
        evs = spans.events()
        assert len(evs) == 512 == spans.ring_capacity()
        # ring keeps the newest events
        assert evs[0]["name"] == f"e{n - 512}"
        assert evs[-1]["name"] == f"e{n - 1}"
    finally:
        spans.set_ring_capacity(spans.DEFAULT_RING_CAPACITY)


# -- chrome trace ------------------------------------------------------------

def test_chrome_trace_schema(tmp_path):
    with obs.span("a"):
        with obs.span("b"):
            pass
    path = tmp_path / "trace.json"
    obs.write_chrome_trace(str(path), metadata={"run": "unit"})
    doc = json.loads(path.read_text())
    assert doc["displayTimeUnit"] == "ms"
    assert doc["otherData"] == {"run": "unit"}
    evs = doc["traceEvents"]
    xs = [e for e in evs if e["ph"] == "X"]
    ms = [e for e in evs if e["ph"] == "M"]
    assert {e["name"] for e in xs} == {"a", "b"}
    for e in xs:
        for k in ("name", "cat", "ph", "ts", "dur", "pid", "tid", "args"):
            assert k in e
        assert isinstance(e["ts"], float) and isinstance(e["dur"], float)
        assert e["dur"] >= 0
    assert ms and all(e["name"] == "thread_name" for e in ms)
    # every X event's (pid, tid) has a thread_name metadata row
    assert {(e["pid"], e["tid"]) for e in xs} <= {(e["pid"], e["tid"])
                                                  for e in ms}


def test_write_jsonl(tmp_path):
    with obs.span("x"):
        pass
    p = tmp_path / "events.jsonl"
    obs.write_jsonl(str(p))
    lines = [json.loads(l) for l in p.read_text().splitlines()]
    assert len(lines) == 1 and lines[0]["name"] == "x"


# -- sentinels ---------------------------------------------------------------

def test_sentinel_eb_sampling_first_then_every_nth():
    old = sentinels.CONFIG
    sentinels.configure(sentinels.SentinelConfig(eb_sample_every=4))
    try:
        picks = [sentinels.should_check_eb("t") for _ in range(9)]
        assert picks == [True, False, False, False,
                         True, False, False, False, True]
    finally:
        sentinels.configure(old)


def test_sentinel_eb_violation_trips_assert_healthy():
    assert sentinels.check_error_bound("kv_cold", max_err=1e-4, eb_abs=1e-3)
    sentinels.assert_healthy()               # in-bound check: healthy
    assert not sentinels.check_error_bound("kv_cold", max_err=5e-3,
                                           eb_abs=1e-3)
    assert obs.violations() == {"sentinel_eb_violations{tier=kv_cold}": 1}
    with pytest.raises(sentinels.HealthError):
        sentinels.assert_healthy()


def test_sentinel_eb_f32_rounding_allowance():
    # max_err just over eb but within the |x|*2^-22 rounding allowance
    eb = 1e-3
    max_abs = 1e4
    allowance = max_abs * 2.0 ** -22
    assert sentinels.check_error_bound("t", eb * 1.0005 + allowance * 0.5,
                                       eb, max_abs)
    assert not sentinels.check_error_bound("t", eb + allowance * 3, eb,
                                           max_abs)


def test_sentinel_ratio_drift_flags_after_warmup_only():
    for _ in range(5):
        sentinels.note_ratio("wire", 4.0)
    assert obs.violations() == {}
    sentinels.note_ratio("wire", 100.0)      # >4x the EWMA -> drift
    assert obs.violations() == {"sentinel_ratio_drift{tier=wire}": 1}
    sentinels.assert_healthy()               # drift alone is not fatal...
    with pytest.raises(sentinels.HealthError):
        sentinels.assert_healthy(strict_drift=True)   # ...unless strict


def test_sentinel_scheduler_gauges():
    sentinels.note_scheduler(waiting=3, running=2, parked=1,
                             oldest_wait_steps=7)
    sentinels.note_scheduler(waiting=0, running=2, parked=0,
                             oldest_wait_steps=2)
    snap = obs.snapshot()["gauges"]
    assert snap["sched_waiting{subsystem=kvpool}"] == 0
    assert snap["sched_oldest_wait_steps{subsystem=kvpool}"] == 2
    assert snap["sched_max_wait_steps{subsystem=kvpool}"] == 7  # high-water


# -- step report -------------------------------------------------------------

def test_step_report_joins_spans_with_bytes():
    with obs.span("dist.bucket0_reduce"):
        pass
    rep = obs.step_report(bytes_by_tag={"bucket0_reduce": 1 << 20},
                          meta={"step": 3})
    (row,) = [r for r in rep.rows if r["span"] == "dist.bucket0_reduce"]
    assert row["calls"] == 1
    assert row["bytes"] == 1 << 20
    assert row["gbps"] > 0
    assert "dist.bucket0_reduce" in rep.render()
