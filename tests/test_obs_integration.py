"""Telemetry integration tier: the obs registry against the real serving
stack.

Pins the acceptance invariants of the obs refactor:

  * **snapshot-view parity** — ``PoolStats`` / ``TraceStats`` are derived
    views of the registry (referenced from their docstrings): every field
    must equal the raw registry counter/gauge it is materialized from;
  * **dispatch-count agreement** — the eager FZ launch counters
    (``fz_dispatches{op=...}``) must exactly match the pool's own
    ``*_dispatches`` accounting over a full serve trace (the paper-honesty
    check: what fz says it launched is what the pool says it asked for);
  * **sentinels live on the real path** — a serve trace samples at least one
    park-time error-bound roundtrip and finishes with zero violations;
  * **span nesting on the real path** — the event ring shows
    engine.serve > sched.step > kvpool.* > fz.* containment;
  * **multi-instance isolation** — two pools in one process never
    cross-count (per-instance ``pool=`` labels).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, obs
from repro.models import zoo
from repro.obs import spans
from repro.serve import Engine, PoolConfig
from repro.serve.kvpool import PagePool, Request
from repro.serve.kvpool.pool import _POOL_METRICS
from repro.serve.kvpool.scheduler import _SCHED_METRICS

L, KVH, HD = 2, 2, 8


def _fz_count(snap, op):
    return sum(v for k, v in snap["counters"].items()
               if k.startswith(f"fz_dispatches{{op={op},"))


@pytest.fixture(scope="module")
def served():
    """One full serve trace (tight pool: parking + cold reads + resumes)
    against a fresh registry; every test below reads this run."""
    obs.reset()
    obs.clear_events()
    cfg = configs.get("glm4-9b", smoke=True)
    model = zoo.build(cfg)
    params = model.init(jax.random.key(0))
    pool_cfg = PoolConfig(num_pages=6, page_size=8, seq_capacity=48,
                          cold_after=2, eb=1e-4)
    eng = Engine(model, params, pool=pool_cfg)
    rng = np.random.default_rng(0)
    reqs = [Request(req_id=i,
                    tokens=rng.integers(0, cfg.vocab, (s,), dtype=np.int32),
                    n_new=5, priority=p)
            for i, (s, p) in enumerate(zip([5, 11, 8, 16, 3],
                                           [0, 1, 0, 2, 1]))]
    outputs, stats, pool = eng.serve(reqs, max_batch=2)
    return outputs, stats, pool, obs.snapshot(), spans.events()


def test_trace_completes_with_compression_exercised(served):
    outputs, stats, pool, snap, _ = served
    assert stats.completed == 5
    assert stats.pool_compressions >= 1, "trace never parked a page"
    assert stats.pool_decompressions >= 1, "trace never read a cold page"


def test_pool_stats_parity_with_registry(served):
    _, _, pool, snap, _ = served
    ps = pool.stats
    for field, (kind, name) in _POOL_METRICS.items():
        kinds = snap["counters"] if kind == "counter" else snap["gauges"]
        reg_val = kinds.get(f"{name}{{pool={pool._obs_id}}}", 0)
        assert getattr(ps, field) == int(reg_val), field


def test_trace_stats_parity_with_registry(served):
    _, stats, pool, snap, _ = served
    # at most one batcher ran against this registry epoch (a counter the
    # trace never touched is simply absent -> the snapshot field must be 0)
    for field, name in _SCHED_METRICS.items():
        reg_vals = [v for k, v in snap["counters"].items()
                    if k.startswith(f"{name}{{batcher=")]
        assert len(reg_vals) <= 1, name
        assert getattr(stats, field) == (reg_vals[0] if reg_vals else 0), field
    # pool-derived mirror fields
    ps = pool.stats
    assert stats.pool_compressions == ps.compressions
    assert stats.pool_decompressions == ps.decompressions
    assert stats.decompress_dispatches == ps.decompress_dispatches
    assert stats.cow_promotions == ps.cow_promotions
    assert stats.high_water_used_bytes == ps.high_water_bytes


def test_fz_dispatch_counters_match_pool_accounting(served):
    _, stats, pool, snap, _ = served
    ps = pool.stats
    assert _fz_count(snap, "decompress") == ps.decompress_dispatches
    assert _fz_count(snap, "compress") == ps.compress_dispatches
    # per-container counts are >= dispatches (batching) and > 0
    assert ps.decompressions >= ps.decompress_dispatches > 0
    assert ps.compressions >= ps.compress_dispatches > 0


def test_sentinels_sampled_and_healthy_on_real_path(served):
    _, _, _, snap, _ = served
    assert snap["counters"].get(
        "sentinel_eb_checks{tier=kv_cold}", 0) >= 1, \
        "no park-time roundtrip was ever sampled"
    assert obs.violations() == {}
    obs.assert_healthy()
    # the sampled roundtrips also fed the ratio drift EWMA
    assert snap["counters"].get(
        "sentinel_ratio_samples{tier=kv_cold}", 0) >= 1
    # scheduler health gauges were written
    assert "sched_running{subsystem=kvpool}" in snap["gauges"]


def test_span_nesting_on_real_path(served):
    _, _, _, _, events = served
    parents = {}
    for ev in events:
        parents.setdefault(ev["name"], set()).add(ev["parent"])
    assert "engine.serve" in parents
    assert "engine.serve" in parents.get("sched.step", set())
    # pool work happens inside a scheduler step
    pool_spans = {n for n in parents
                  if n.startswith("kvpool.")} & {"kvpool.park",
                                                 "kvpool.cold_read",
                                                 "kvpool.gather"}
    assert pool_spans, "no pool spans recorded"
    # cold reads issued by a gather nest under it; everything pool-side
    # ultimately hangs off a scheduler step
    for n in pool_spans:
        assert parents[n] <= {"sched.step", "kvpool.gather"}, (n, parents[n])
    assert "sched.step" in parents["kvpool.gather" if "kvpool.gather"
                                   in pool_spans else next(iter(pool_spans))]
    # eager fz wrapper spans nest under the pool spans that issued them
    fz_parents = set().union(*(parents.get(n, set()) for n in parents
                               if n.startswith("fz.") and
                               not n.startswith("fz.stage")))
    assert fz_parents & {"kvpool.park", "kvpool.cold_read"}
    # stage spans only fire at compile time, so under a jit cache warmed by
    # earlier tests there may be none in this fixture's window — but any
    # that did land must be trace-time events, never runtime ones (the
    # guaranteed-fresh-compile case is pinned in test_obs.py)
    stage_events = [e for e in events if e["name"].startswith("fz.stage")]
    assert all(e["cat"] == "jit-trace" for e in stage_events)


def test_chrome_trace_export_of_real_run(served, tmp_path):
    import json
    _, _, _, _, events = served
    path = str(tmp_path / "serve_trace.json")
    obs.write_chrome_trace(path, events=events)
    doc = json.loads(open(path).read())
    names = {e["name"] for e in doc["traceEvents"] if e["ph"] == "X"}
    assert {"engine.serve", "sched.step"} <= names


def test_two_pools_never_cross_count():
    """Per-instance pool labels: work in one pool is invisible to another."""
    cfg = PoolConfig(num_pages=4, page_size=4, seq_capacity=16,
                     eb=1e-3, eb_mode="abs", dtype="float32")
    a = PagePool(cfg, n_layers=L, n_kv_heads=KVH, head_dim=HD)
    b = PagePool(cfg, n_layers=L, n_kv_heads=KVH, head_dim=HD)
    assert a._obs_id != b._obs_id
    rng = np.random.default_rng(0)
    k = jnp.asarray(rng.standard_normal((L, 1, 8, KVH, HD)), jnp.float32)
    a.write_prefill(seq=0, k=k, v=-k, length=8, step=0)
    a.compress_pages([p.page_id for p in a.pages_of(0)])
    assert a.stats.compressions >= 1
    assert b.stats.compressions == 0
    assert b.stats.compress_dispatches == 0
