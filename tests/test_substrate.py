"""Substrate tests: optimizer, schedules, data pipeline, serving engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.tokens import TokenStream
from repro.optim import AdamWConfig, adamw_init, adamw_update, warmup_cosine


def test_adamw_optimizes_quadratic():
    params = {"w": jnp.ones((16,), jnp.float32) * 5.0}
    opt = adamw_init(params)
    cfg = AdamWConfig(weight_decay=0.0)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    p = params
    for _ in range(200):
        g = jax.grad(loss)(p)
        p, opt = adamw_update(g, opt, jnp.float32(0.05), cfg, p)
    assert float(loss(p)) < 1e-2


def test_adamw_preserves_dtypes_and_clips():
    params = {"w": jnp.ones((8, 8), jnp.bfloat16), "c": jnp.ones((4,), jnp.float32)}
    opt = adamw_init(params)
    grads = {"w": jnp.full((8, 8), 1e6, jnp.bfloat16), "c": jnp.ones((4,), jnp.float32)}
    newp, opt = adamw_update(grads, opt, jnp.float32(1e-3), AdamWConfig(clip_norm=1.0), params)
    assert newp["w"].dtype == jnp.bfloat16 and newp["c"].dtype == jnp.float32
    # clipped update magnitude stays bounded
    assert float(jnp.max(jnp.abs(newp["c"] - 1.0))) < 0.1


def test_warmup_cosine_shape():
    lrs = [float(warmup_cosine(jnp.int32(s), peak_lr=1.0, warmup_steps=10,
                               total_steps=100)) for s in range(100)]
    assert lrs[0] == 0.0
    assert abs(lrs[10] - 1.0) < 0.11
    assert lrs[99] < 0.2
    assert np.argmax(lrs) in range(9, 13)


def test_token_stream_deterministic_and_shardable():
    ts = TokenStream(vocab_size=1000, seq_len=32, global_batch=8, seed=3)
    full = ts.global_batch_at(step=7)
    again = ts.global_batch_at(step=7)
    np.testing.assert_array_equal(full, again)
    # sharded reads reassemble the same global stream (elastic invariance)
    parts = [ts.shard_batch(7, shard=i, num_shards=4) for i in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts, 0), full)
    parts2 = [ts.shard_batch(7, shard=i, num_shards=2) for i in range(2)]
    np.testing.assert_array_equal(np.concatenate(parts2, 0), full)
    # different steps differ
    assert not np.array_equal(ts.global_batch_at(8), full)


def test_engine_generate_and_kv_parking():
    from repro import configs
    from repro.models import zoo
    from repro.serve import Engine, KVCompressionConfig
    from repro.serve.engine import cache_bytes, compressed_cache_bytes

    cfg = configs.get("glm4-9b", smoke=True)
    model = zoo.build(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 32), dtype=np.int32))}
    eng = Engine(model, params,
                 kv_compress=KVCompressionConfig(enabled=True, eb=1e-4, min_leaf_size=1024))
    toks, cache = eng.generate(batch, 4)
    assert toks.shape == (2, 4)
    parked = eng.park(cache)
    ratio = cache_bytes(cache) / compressed_cache_bytes(parked)
    assert ratio > 1.5, ratio
    resumed = eng.resume(parked)
    assert int(resumed["length"][0]) == int(cache["length"][0])
    # decode continuation on the reconstructed cache produces the same tokens
    # at this error bound
    toks2, _ = eng.generate(batch, 4, park_between=True)
    np.testing.assert_array_equal(np.asarray(toks), np.asarray(toks2))


def test_moe_routing_respects_capacity():
    from repro.configs.base import ArchConfig
    from repro.models import moe, nn
    cfg = ArchConfig(arch_id="t", family="moe", n_layers=1, d_model=32, n_heads=2,
                     n_kv_heads=2, d_ff=64, vocab=128, n_experts=4, top_k=2,
                     capacity_factor=1.0)
    defs = moe.moe_defs(cfg)
    params = nn.init_tree(defs, jax.random.key(0))
    lp = jax.tree.map(lambda x: x[0], params)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 16, 32)).astype(np.float32)).astype(jnp.bfloat16)
    y, aux = moe.moe_apply(lp, x, cfg)
    assert y.shape == x.shape
    assert float(aux) >= 1.0 - 1e-3  # load-balance loss lower bound at E*mean
