"""kvpool invariants: allocator aliasing, park/resume bit-parity with the
whole-cache oracle, priority preemption, paged-attention parity, and a full
synthetic trace with mixed sequence lengths."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import zoo
from repro.models.attention import decode_attention
from repro.serve import (Engine, KVCompressionConfig, compress_cache,
                         decompress_cache)
from repro.serve.kvpool import (ContinuousBatcher, PagePool, PoolConfig,
                                Request, TieredPolicy, paged_decode_attention,
                                pages_from_cache)

L, KVH, HD = 2, 2, 8     # tiny cache geometry for pool-only tests


def make_pool(num_pages=8, ps=4, cap=32, dtype="float32", **kw) -> PagePool:
    cfg = PoolConfig(num_pages=num_pages, page_size=ps, seq_capacity=cap,
                     eb=1e-3, eb_mode="abs", dtype=dtype, **kw)
    return PagePool(cfg, n_layers=L, n_kv_heads=KVH, head_dim=HD)


def seq_kv(seed: int, S: int, fill=None):
    """Synthetic prefill-shaped k/v: (L, 1, S, KVH, HD)."""
    rng = np.random.default_rng(seed)
    shp = (L, 1, S, KVH, HD)
    if fill is not None:
        return (jnp.full(shp, fill, jnp.float32),
                jnp.full(shp, -fill, jnp.float32))
    return (jnp.asarray(rng.standard_normal(shp), dtype=jnp.float32),
            jnp.asarray(rng.standard_normal(shp), dtype=jnp.float32))


# ---------------------------------------------------------------------------
# allocator
# ---------------------------------------------------------------------------

def test_alloc_free_never_aliases_live_pages():
    pool = make_pool(num_pages=4, ps=4, cap=16)
    ka, va = seq_kv(0, 8, fill=1.0)
    kb, vb = seq_kv(1, 8, fill=2.0)
    assert pool.write_prefill(0, ka, va, 8, step=0)
    assert pool.write_prefill(1, kb, vb, 8, step=0)
    assert pool.n_free_slots() == 0
    b_before = np.asarray(pool.materialize(1)[0])

    pool.free_seq(0)
    assert pool.n_free_slots() == 2
    # reuse the freed slots for a third sequence; seq 1 must be untouched
    kc, vc = seq_kv(2, 8, fill=3.0)
    assert pool.write_prefill(2, kc, vc, 8, step=1)
    np.testing.assert_array_equal(np.asarray(pool.materialize(1)[0]), b_before)
    # live slots are disjoint
    slots = [p.slot for p in pool.pages.values() if p.slot is not None]
    assert len(slots) == len(set(slots)) == 4
    # and the new sequence really landed in the recycled slots
    assert np.asarray(pool.materialize(2)[0][:, :, :8]).max() == 3.0


def test_append_respects_page_boundaries():
    pool = make_pool(num_pages=4, ps=4, cap=16)
    k, v = seq_kv(0, 6)
    assert pool.write_prefill(0, k, v, 6, step=0)
    assert len(pool.seq_pages[0]) == 2          # ceil(6/4)
    kv = jnp.ones((L, KVH, HD), jnp.float32)
    assert pool.append_token(0, kv, 2 * kv, step=1)   # fills slot 6 (page 1)
    assert pool.append_token(0, kv, 2 * kv, step=2)   # fills slot 7 (page 1)
    assert len(pool.seq_pages[0]) == 2
    assert pool.append_token(0, kv, 2 * kv, step=3)   # opens page 2
    assert len(pool.seq_pages[0]) == 3
    kmat, vmat, length = pool.materialize(0)
    assert length == 9
    np.testing.assert_array_equal(np.asarray(kmat[:, 0, 8]), np.asarray(kv))
    np.testing.assert_array_equal(np.asarray(vmat[:, 0, 8]), 2 * np.asarray(kv))


# ---------------------------------------------------------------------------
# park -> resume parity with the whole-cache oracle
# ---------------------------------------------------------------------------

def test_park_resume_bit_identical_to_whole_cache():
    """Page-granular compress/park at a shared absolute bound reconstructs
    bit-identically to serve.engine.compress_cache/decompress_cache."""
    eb = 1e-3
    S = 16                                       # 4 pages of 4
    pool = make_pool(num_pages=8, ps=4, cap=16)
    k, v = seq_kv(7, S)
    assert pool.write_prefill(0, k, v, S, step=0)
    for page in pool.pages_of(0):                # park: every page tiers down
        pool.compress_page(page.page_id)
    assert pool.n_free_slots() == 8
    krec, vrec, _ = pool.materialize(0)          # resume via decompress

    kcfg = KVCompressionConfig(enabled=True, eb=eb, eb_mode="abs",
                               min_leaf_size=1)
    whole = decompress_cache(compress_cache(
        {"k": k, "v": v, "length": jnp.full((1,), S, jnp.int32)}, kcfg), kcfg)
    np.testing.assert_array_equal(np.asarray(krec[:, :, :S]),
                                  np.asarray(whole["k"]))
    np.testing.assert_array_equal(np.asarray(vrec[:, :, :S]),
                                  np.asarray(whole["v"]))


def test_pool_accounting():
    pool = make_pool(num_pages=4, ps=4, cap=16)
    k, v = seq_kv(3, 16)
    # smooth data so compression actually wins
    k = jnp.cumsum(k, axis=2) * 0.01
    v = jnp.cumsum(v, axis=2) * 0.01
    assert pool.write_prefill(0, k, v, 16, step=0)
    raw = pool.raw_bytes_in_use()
    assert raw == 4 * pool.slot_bytes == pool.used_bytes()
    for page in pool.pages_of(0):
        pool.compress_page(page.page_id)
    assert pool.raw_bytes_in_use() == 0
    assert 0 < pool.compressed_used_bytes() < raw
    assert pool.compressed_wire_bytes() >= pool.compressed_used_bytes()
    assert pool.live_demand_bytes() == raw       # live pages unchanged
    assert pool.stats.high_water_slots == 4


# ---------------------------------------------------------------------------
# byte accounting: containers are charged against the slab dtype
# ---------------------------------------------------------------------------

def test_bf16_page_raw_bytes_honest():
    """A container built from a bfloat16 slab reports bfloat16 raw bytes —
    n*2, not the float32-cast n*4 that inflated compression_ratio ~2x."""
    from repro.core import fz
    pool = make_pool(num_pages=4, ps=4, cap=16, dtype="bfloat16")
    k, v = seq_kv(5, 8)
    assert pool.write_prefill(0, k, v, 8, step=0)
    for page in pool.pages_of(0):
        pool.compress_page(page.page_id)
    for page in pool.pages_of(0):
        assert int(page.comp.raw_bytes()) == page.comp.n * 2
    # direct fz roundtrip: source dtype flows through compress / compress_with_eb
    x16 = jnp.asarray(np.random.default_rng(0).standard_normal(4096),
                      dtype=jnp.bfloat16)
    cfg = fz.FZConfig(eb=1e-3, eb_mode="abs", exact_outliers=False)
    rec, c = fz.roundtrip(x16, cfg)
    assert int(c.raw_bytes()) == x16.size * 2
    c2 = fz.compress_with_eb(x16, jnp.float32(1e-3), cfg)
    assert int(c2.raw_bytes()) == x16.size * 2
    # float32 sources still report n*4
    c3 = fz.compress(x16.astype(jnp.float32), cfg)
    assert int(c3.raw_bytes()) == x16.size * 4


def _pool_pair(**kw):
    pools = []
    for _ in range(2):
        pool = make_pool(num_pages=8, ps=4, cap=32, **kw)
        k, v = seq_kv(9, 16)
        assert pool.write_prefill(0, k, v, 16, step=0)
        pools.append(pool)
    return pools


def test_batched_tiering_bit_identical_to_single_page():
    """compress_pages (one vmapped dispatch) == compress_page per page, bit
    for bit; ditto the batched cold-read in gather vs one-at-a-time."""
    one, batch = _pool_pair(dtype="bfloat16")
    pids_one = [p.page_id for p in one.pages_of(0)]
    for pid in pids_one:
        one.compress_page(pid)
    batch.compress_pages([p.page_id for p in batch.pages_of(0)])
    assert batch.stats.compressions == len(pids_one)
    for p1, p2 in zip(one.pages_of(0), batch.pages_of(0)):
        for l1, l2 in zip(jax.tree.leaves(p1.comp), jax.tree.leaves(p2.comp)):
            np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
        assert p1.comp.shape == p2.comp.shape
        assert p1.comp.dtype_name == p2.comp.dtype_name
    # batched transient decompress (4 cold pages in one dispatch) == singles
    k1 = np.asarray(one.materialize(0)[0])
    singles = [np.asarray(one._decompress(p)) for p in one.pages_of(0)]
    many = [np.asarray(t) for t in
            batch._decompress_many(batch.pages_of(0))]
    for s, m in zip(singles, many):
        np.testing.assert_array_equal(s, m)
    np.testing.assert_array_equal(k1, np.asarray(batch.materialize(0)[0]))


def test_compress_pages_dedupes_and_skips():
    """Duplicate / already-compressed / unknown pids never corrupt the free
    list or double-count compressions."""
    pool = make_pool(num_pages=8, ps=4, cap=32)
    k, v = seq_kv(17, 8)
    assert pool.write_prefill(0, k, v, 8, step=0)
    pids = [p.page_id for p in pool.pages_of(0)]
    pool.compress_pages([pids[0], pids[0], pids[1], 10_000])
    assert pool.stats.compressions == 2
    assert None not in pool.free_slots
    assert pool.n_free_slots() == 8 - len(pids) + 2
    pool.compress_pages(pids)                     # re-run: both already cold
    assert pool.stats.compressions == 2


def test_gather_pages_is_unmerged_gather():
    """gather_pages is the same data as gather, minus the P*ps merge."""
    pool = make_pool(num_pages=8, ps=4, cap=16)
    k, v = seq_kv(13, 10)
    assert pool.write_prefill(0, k, v, 10, step=0)
    pool.compress_page(pool.pages_of(0)[0].page_id)    # one cold page
    cache = pool.gather([0, None])
    pages = pool.gather_pages([0, None])
    L, B, P, ps, KVH, hd = pages["k"].shape
    np.testing.assert_array_equal(
        np.asarray(pages["k"].reshape(L, B, P * ps, KVH, hd)),
        np.asarray(cache["k"]))
    np.testing.assert_array_equal(np.asarray(pages["length"]),
                                  np.asarray(cache["length"]))


# ---------------------------------------------------------------------------
# paged decode attention vs the contiguous oracle
# ---------------------------------------------------------------------------

def test_paged_attention_matches_decode_attention():
    rng = np.random.default_rng(11)
    B, H, KVHn, D, S, ps = 3, 8, 2, 16, 64, 16
    q = jnp.asarray(rng.standard_normal((B, H, D)), dtype=jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KVHn, D)), dtype=jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KVHn, D)), dtype=jnp.float32)
    length = jnp.asarray([5, 64, 17], jnp.int32)  # partial / full / page-straddling
    kp, vp = pages_from_cache(k, v, ps)
    out = paged_decode_attention(q, kp, vp, length)
    ref = decode_attention(q, k, v, length)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4)


def test_paged_attention_kernel_path_matches_oracle():
    """use_kernels routes through kernels/flash_decode.decode_partials_pages
    (interpret mode on CPU); parity with the contiguous oracle at the same
    2e-4 pin, with and without the folded-in new token."""
    rng = np.random.default_rng(23)
    B, H, KVHn, D, S, ps = 3, 8, 2, 16, 64, 16
    q = jnp.asarray(rng.standard_normal((B, H, D)), dtype=jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KVHn, D)), dtype=jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KVHn, D)), dtype=jnp.float32)
    length = jnp.asarray([1, 64, 17], jnp.int32)
    kp, vp = pages_from_cache(k, v, ps)
    out_k = paged_decode_attention(q, kp, vp, length, use_kernels=True)
    ref = decode_attention(q, k, v, length)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(ref), atol=2e-4)

    # new-token fold-in: token K/V at position `length` without touching
    # pages == oracle with the token scattered into the contiguous cache
    lengths2 = jnp.asarray([0, 40, 17], jnp.int32)   # all < S; incl. empty
    k_new = jnp.asarray(rng.standard_normal((B, KVHn, D)), dtype=jnp.float32)
    v_new = jnp.asarray(rng.standard_normal((B, KVHn, D)), dtype=jnp.float32)
    onehot = (jnp.arange(S)[None, :] == lengths2[:, None])
    k_ins = jnp.where(onehot[:, :, None, None], k_new[:, None], k)
    v_ins = jnp.where(onehot[:, :, None, None], v_new[:, None], v)
    ref_new = decode_attention(q, k_ins, v_ins, lengths2 + 1)
    for uk in (False, True):
        out_new = paged_decode_attention(q, kp, vp, lengths2, k_new=k_new,
                                         v_new=v_new, use_kernels=uk)
        np.testing.assert_allclose(np.asarray(out_new), np.asarray(ref_new),
                                   atol=2e-4)


def test_paged_attention_all_lanes_empty_returns_zero():
    """Length-0 lanes (and the all-lanes-empty batch) return exactly 0 on
    both paths: num == den == 0, even though the renormalization weight is
    exp(0) == 1 when every page is empty — the corrected combine contract."""
    rng = np.random.default_rng(29)
    B, H, KVHn, D, S, ps = 2, 4, 2, 8, 32, 8
    q = jnp.asarray(rng.standard_normal((B, H, D)), dtype=jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KVHn, D)), dtype=jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KVHn, D)), dtype=jnp.float32)
    kp, vp = pages_from_cache(k, v, ps)
    zero = jnp.zeros((B,), jnp.int32)
    for uk in (False, True):
        out = paged_decode_attention(q, kp, vp, zero, use_kernels=uk)
        assert np.all(np.asarray(out) == 0.0), f"use_kernels={uk}"
    # mixed batch: lane 0 empty, lane 1 live — lane 0 still exactly 0
    mixed = jnp.asarray([0, 20], jnp.int32)
    for uk in (False, True):
        out = paged_decode_attention(q, kp, vp, mixed, use_kernels=uk)
        assert np.all(np.asarray(out[0]) == 0.0)
        ref = decode_attention(q, k, v, mixed)
        np.testing.assert_allclose(np.asarray(out[1]), np.asarray(ref[1]),
                                   atol=2e-4)


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_engine():
    cfg = configs.get("glm4-9b", smoke=True)
    model = zoo.build(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _requests(cfg, lens, n_new, priorities=None, seed=0):
    rng = np.random.default_rng(seed)
    priorities = priorities or [0] * len(lens)
    return [Request(req_id=i,
                    tokens=rng.integers(0, cfg.vocab, (s,), dtype=np.int32),
                    n_new=n_new, priority=p)
            for i, (s, p) in enumerate(zip(lens, priorities))]


def test_scheduler_preempts_lowest_priority(tiny_engine):
    cfg, model, params = tiny_engine
    pool_cfg = PoolConfig(num_pages=2, page_size=8, seq_capacity=32,
                          cold_after=100, eb=1e-4)  # no routine cooling
    eng = Engine(model, params, pool=pool_cfg)
    pool = eng.make_pool()
    batcher = ContinuousBatcher(eng, pool, max_batch=2)
    # page-aligned prompts: both lanes open a fresh page on the first decode
    # step; only preemption (compress-park) can free a slot
    reqs = _requests(cfg, [8, 8], n_new=6, priorities=[3, 1])
    from repro.serve.kvpool.scheduler import PARKED, RUNNING, SeqRecord
    batcher.recs = {r.req_id: SeqRecord(req=r) for r in reqs}
    outputs = {}
    batcher.step(1, outputs)
    assert batcher.stats.preemptions >= 1
    assert batcher.recs[1].state == PARKED      # the low-priority one
    assert batcher.recs[0].state == RUNNING
    # parked pages are compressed, not dropped
    assert all(p.comp is not None for p in pool.pages_of(1))


def test_full_trace_mixed_lengths_matches_oracle(tiny_engine):
    cfg, model, params = tiny_engine
    pool_cfg = PoolConfig(num_pages=6, page_size=8, seq_capacity=48,
                          cold_after=2, eb=1e-4)
    eng = Engine(model, params, pool=pool_cfg)
    reqs = _requests(cfg, [5, 11, 8, 16, 3], n_new=5, priorities=[0, 1, 0, 2, 1])
    outputs, stats, pool = eng.serve(reqs, max_batch=2)
    assert stats.completed == len(reqs)
    # the pool drains completely
    assert not pool.pages and pool.n_free_slots() == pool_cfg.num_pages
    # prompts are padded to page buckets: [5,11,8,16,3] -> shapes {8, 16}
    if hasattr(eng._prefill, "_cache_size"):
        assert eng._prefill._cache_size() <= 2
    agree = []
    for r in reqs:
        oracle, _ = eng.generate({"tokens": jnp.asarray(r.tokens)[None]}, r.n_new)
        assert outputs[r.req_id].shape == (r.n_new,)
        agree.append(float((np.asarray(oracle[0]) == outputs[r.req_id]).mean()))
    assert float(np.mean(agree)) >= 0.9, agree


def test_paging_without_compression_is_exact(tiny_engine):
    """Pure bookkeeping (no page ever tiers down) must match the oracle
    token-for-token — pins gather/append/extract correctness."""
    cfg, model, params = tiny_engine
    pool_cfg = PoolConfig(num_pages=16, page_size=8, seq_capacity=48,
                          cold_after=10**6, eb=1e-4)
    eng = Engine(model, params, pool=pool_cfg)
    reqs = _requests(cfg, [7, 12], n_new=6)
    outputs, stats, _ = eng.serve(reqs, max_batch=2)
    assert stats.pool_compressions == 0
    for r in reqs:
        oracle, _ = eng.generate({"tokens": jnp.asarray(r.tokens)[None]}, r.n_new)
        np.testing.assert_array_equal(np.asarray(oracle[0]), outputs[r.req_id])


def test_engine_paged_kernel_decode_end_to_end(tiny_engine):
    """PoolConfig.use_kernels routes the whole serve decode path through the
    Pallas flash-decode kernel: page-native gather (no contiguous cache),
    decode_step_paged, pool append of the returned K/V. Tokens track the
    never-paged oracle."""
    cfg, model, params = tiny_engine
    pool_cfg = PoolConfig(num_pages=16, page_size=8, seq_capacity=48,
                          cold_after=2, eb=1e-4, use_kernels=True)
    eng = Engine(model, params, pool=pool_cfg)
    assert eng.paged_decode_enabled
    reqs = _requests(cfg, [7, 12], n_new=5)
    outputs, stats, pool = eng.serve(reqs, max_batch=2)
    assert stats.completed == len(reqs)
    agree = []
    for r in reqs:
        oracle, _ = eng.generate({"tokens": jnp.asarray(r.tokens)[None]}, r.n_new)
        assert outputs[r.req_id].shape == (r.n_new,)
        agree.append(float((np.asarray(oracle[0]) == outputs[r.req_id]).mean()))
    assert float(np.mean(agree)) >= 0.9, agree


def test_prefill_jit_is_cached(tiny_engine):
    cfg, model, params = tiny_engine
    eng = Engine(model, params)
    batch = {"tokens": jnp.zeros((1, 8), jnp.int32)}
    eng.prefill(batch)
    if hasattr(eng._prefill, "_cache_size"):
        before = eng._prefill._cache_size()
        eng.prefill(batch)
        eng.prefill(batch)
        assert eng._prefill._cache_size() == before


def test_overlong_request_rejected_up_front(tiny_engine):
    cfg, model, params = tiny_engine
    eng = Engine(model, params,
                 pool=PoolConfig(num_pages=4, page_size=8, seq_capacity=16,
                                 eb=1e-4))
    reqs = _requests(cfg, [12], n_new=8)      # 12 + 8 - 1 > 16
    with pytest.raises(ValueError, match="seq_capacity"):
        eng.serve(reqs, max_batch=1)


def test_victim_selection():
    # lowest priority first; ties break toward the latest arrival
    running = {10: (2, 1), 11: (0, 3), 12: (0, 5), 13: (5, 0)}
    assert TieredPolicy.victim(running) == 12
    assert TieredPolicy.victim({}) is None
