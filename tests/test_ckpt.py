"""Fault tolerance: checkpoint/restart, integrity, FZ codec, resume."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ckpt


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.standard_normal((128, 64)).astype(np.float32)),
        "b": jnp.asarray(rng.standard_normal((64,)).astype(np.float32)),
        "emb": jnp.asarray(rng.standard_normal((1000, 128))).astype(jnp.bfloat16),
        "count": jnp.int32(17),
    }


def test_save_restore_bitwise(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 5, t, meta={"foo": 1})
    restored, meta = ckpt.restore(str(tmp_path), t)
    assert meta["step"] == 5 and meta["foo"] == 1
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_pointer_and_gc(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, t, keep_last=2)
    assert ckpt.latest_step(str(tmp_path)) == 5
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert kept == ["step_00000004", "step_00000005"]


def test_corruption_detected(tmp_path):
    t = _tree()
    d = ckpt.save(str(tmp_path), 1, t)
    victim = os.path.join(d, "leaf_000000.bin")
    raw = bytearray(open(victim, "rb").read())
    raw[10] ^= 0xFF
    open(victim, "wb").write(bytes(raw))
    with pytest.raises(IOError, match="checksum"):
        ckpt.restore(str(tmp_path), t)


def test_fz_codec_error_bounded(tmp_path):
    rng = np.random.default_rng(3)
    big = np.cumsum(rng.standard_normal((512, 256)).astype(np.float32), axis=0)
    t = {"big": jnp.asarray(big), "small": jnp.ones((8,), jnp.float32)}
    ckpt.save(str(tmp_path), 1, t, codec="fz")
    restored, _ = ckpt.restore(str(tmp_path), t)
    rng_ = big.max() - big.min()
    err = np.abs(np.asarray(restored["big"]) - big).max()
    # 1.01x + ulp slack: f32 divide/rint/multiply rounding at q ~ 5e4
    assert err <= 1e-5 * rng_ * 1.01 + rng_ * 2e-7, err
    np.testing.assert_array_equal(np.asarray(restored["small"]), np.ones(8, np.float32))
    rep = ckpt.compression_report(str(tmp_path), 1)
    assert rep["ratio"] > 1.5, rep


def test_atomicity_partial_write_ignored(tmp_path):
    """A stale tmp dir (simulated crash) never shadows a published step."""
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    os.makedirs(os.path.join(str(tmp_path), ".tmp_step_00000002"))
    assert ckpt.latest_step(str(tmp_path)) == 1
    restored, meta = ckpt.restore(str(tmp_path), t)
    assert meta["step"] == 1


def test_trainer_resume_bitwise(tmp_path):
    """Restart from checkpoint reproduces the exact loss sequence."""
    from repro import configs
    from repro.configs.base import ShapeConfig
    from repro.data.tokens import TokenStream
    from repro.launch.mesh import make_local_mesh
    from repro.models import zoo
    from repro.train import TrainConfig, Trainer

    cfg = configs.get("yi-6b", smoke=True)
    model = zoo.build(cfg)
    shape = ShapeConfig("tiny", 32, 4, "train")
    mesh = make_local_mesh()
    stream = TokenStream(vocab_size=cfg.vocab, seq_len=32, global_batch=4, seed=7)

    t1 = Trainer(model, shape, mesh, TrainConfig(), stream=stream,
                 ckpt_dir=str(tmp_path), ckpt_every=100)
    t1.run(4)
    t2 = Trainer(model, shape, mesh, TrainConfig(), stream=stream,
                 ckpt_dir=str(tmp_path), ckpt_every=100)
    assert t2.step == 4
    h2 = t2.run(2)
    t3 = Trainer(model, shape, mesh, TrainConfig(), stream=stream, ckpt_dir=None)
    h3 = t3.run(6)
    ref = [m["loss"] for m in h3][4:]
    got = [m["loss"] for m in h2]
    np.testing.assert_allclose(got, ref, rtol=0, atol=0)


def test_straggler_watchdog_flags_injected_delay(tmp_path):
    from repro.train.trainer import StragglerWatchdog
    wd = StragglerWatchdog(factor=3.0, warmup=1)
    wd.observe(0, 10.0)   # warmup (compile step)
    wd.observe(1, 0.1)
    wd.observe(2, 0.11)
    ev = wd.observe(3, 1.0)
    assert ev is not None and ev.step == 3
    assert wd.observe(4, 0.1) is None
