"""Bucketed overlapped reduce: plan stability, oracle parity, grad taps.

The multi-device (8 fake devices) parity and per-bucket HLO byte checks live
in tests/test_dist.py's slow subprocess; this file covers everything that
runs single-device: the deterministic bucket assignment (property-tested —
hypothesis wheel or the bundled minihypothesis fallback), bit parity of the
bucketed math against the ``reduce_stacked`` barrier oracle on the
reference (no-mesh) path, and the ``grad_boundary`` custom_vjp taps being
bit-exact identities under grad and vmap(grad).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis import given, settings, strategies as st

from repro.dist import bucketed_reduce as bkt
from repro.dist.compressed_allreduce import (GradCompressionConfig,
                                             init_error_state, reduce_stacked,
                                             wire_bytes_per_leaf)

SET = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# Bucket assignment
# ---------------------------------------------------------------------------

def _random_abstract_tree(seed: int):
    """Random nested dict of f32 ShapeDtypeStructs (mix of sizes/ranks)."""
    rng = np.random.default_rng(seed)
    tree = {}
    for i in range(int(rng.integers(1, 10))):
        nd = int(rng.integers(1, 4))
        shape = tuple(int(rng.integers(1, 33)) * (8 if d == 0 else 4)
                      for d in range(nd))
        tree[f"leaf{i:02d}"] = jax.ShapeDtypeStruct(shape, jnp.float32)
    if rng.integers(0, 2):      # sometimes a nested group
        tree["layers"] = {"w": jax.ShapeDtypeStruct((64, 64), jnp.float32)}
    return tree


@settings(**SET)
@given(st.integers(0, 10_000), st.sampled_from([1 << 12, 1 << 15, 1 << 20]))
def test_bucket_assignment_stable(seed, bucket_bytes):
    """Any leaf mix gets a deterministic, insertion-order-independent,
    exactly-once assignment that respects the byte target."""
    cfg = GradCompressionConfig(enabled=True, min_leaf_size=1024,
                                overlap=True, bucket_bytes=bucket_bytes)
    tree = _random_abstract_tree(seed)
    plan = bkt.assign_buckets(tree, cfg)
    # deterministic: same inputs -> identical plan (error feedback stays
    # aligned with its leaves across steps/restarts)
    assert plan == bkt.assign_buckets(tree, cfg)
    # dict insertion order is irrelevant (flatten sorts keys)
    shuffled = dict(reversed(list(tree.items())))
    assert plan == bkt.assign_buckets(shuffled, cfg)
    # every leaf lands exactly once: bucketed xor bypass
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    all_keys = {jax.tree_util.keystr(p) for p, _ in leaves}
    bucketed = [k for b in plan.buckets for k in b.keys]
    assert len(bucketed) == len(set(bucketed))
    assert set(bucketed) | set(plan.bypass) == all_keys
    assert not set(bucketed) & set(plan.bypass)
    # byte target: only a single oversized leaf may exceed it
    for b in plan.buckets:
        assert len(b.keys) == 1 or b.wire_bytes <= bucket_bytes
        assert b.wire_bytes == sum(
            wire_bytes_per_leaf(n, cfg)["compressed"] for n in b.n_elems)


def test_bucket_production_order():
    """Transformer top-level groups order unembed -> final_norm -> layers ->
    embed, and buckets are contiguous ranges of that order."""
    cfg = GradCompressionConfig(enabled=True, min_leaf_size=1024,
                                overlap=True, bucket_bytes=1)  # 1 leaf/bucket
    tree = {
        "embed": jax.ShapeDtypeStruct((256, 64), jnp.float32),
        "layers": {"wq": jax.ShapeDtypeStruct((2, 64, 64), jnp.float32)},
        "final_norm": jax.ShapeDtypeStruct((4096,), jnp.float32),
        "unembed": jax.ShapeDtypeStruct((64, 256), jnp.float32),
    }
    plan = bkt.assign_buckets(tree, cfg)
    order = [k for b in plan.buckets for k in b.keys]
    assert order == ["['unembed']", "['final_norm']", "['layers']['wq']",
                     "['embed']"]
    assert [b.index for b in plan.buckets] == list(range(plan.n_buckets))
    assert plan.buckets[0].tag == "bucket0_reduce"


def test_small_and_nonfloat_leaves_bypass():
    cfg = GradCompressionConfig(enabled=True, min_leaf_size=4096, overlap=True)
    tree = {"big": jax.ShapeDtypeStruct((4096,), jnp.float32),
            "small": jax.ShapeDtypeStruct((16,), jnp.float32),
            "ints": jax.ShapeDtypeStruct((8192,), jnp.int32)}
    plan = bkt.assign_buckets(tree, cfg)
    assert set(plan.bypass) == {"['small']", "['ints']"}
    assert [b.keys for b in plan.buckets] == [("['big']",)]


def test_gathered_bytes_vs_wire_bytes():
    """The DCE-aware byte model differs from the wire model by exactly the
    two bookkeeping scalars the mean hop never reads (grad config keeps
    exact_outliers off, so the outlier side-channel is empty)."""
    cfg = GradCompressionConfig(enabled=True)
    for n in (1 << 12, 1 << 16):
        wire = wire_bytes_per_leaf(n, cfg)["compressed"]
        gathered = bkt.gathered_bytes_per_leaf(n, cfg)
        assert gathered == wire - 8
    plan = bkt.assign_buckets({"w": jax.ShapeDtypeStruct((1 << 14,), jnp.float32)},
                              cfg)
    exp = bkt.expected_cross_pod_bytes(plan, cfg, n_pods=4)
    assert exp == {"bucket0_reduce": 4 * bkt.gathered_bytes_per_leaf(1 << 14, cfg)}


# ---------------------------------------------------------------------------
# Oracle parity (reference no-mesh path; the mesh path is in test_dist.py)
# ---------------------------------------------------------------------------

def _grad_tree(rng, step):
    scale = 1.0 + 0.25 * step
    return {"layers": {"wq": jnp.asarray(rng.standard_normal((2, 64, 64)).astype(np.float32)) * scale,
                       "wk": jnp.asarray(rng.standard_normal((2, 32, 64)).astype(np.float32)) * scale},
            "unembed": jnp.asarray(rng.standard_normal((2, 64, 64)).astype(np.float32)) * scale,
            "bias": jnp.asarray(rng.standard_normal((2, 8)).astype(np.float32)) * scale}


@pytest.mark.parametrize("bucket_bytes", [1, 1 << 30])
def test_bucketed_bit_identical_to_barrier_reference(bucket_bytes):
    """3 steps of error feedback: reduced grads AND error state bit-identical
    to the barrier oracle, whether every leaf gets its own bucket or all
    leaves share one — per-leaf math is unchanged by the issue granularity."""
    gc = GradCompressionConfig(enabled=True, eb=1e-4, min_leaf_size=1024)
    gcb = GradCompressionConfig(enabled=True, eb=1e-4, min_leaf_size=1024,
                                overlap=True, bucket_bytes=bucket_bytes)
    rng = np.random.default_rng(7)
    g0 = _grad_tree(rng, 0)
    g_abs = jax.tree.map(lambda g: jax.ShapeDtypeStruct(g.shape[1:], g.dtype), g0)
    plan = bkt.assign_buckets(g_abs, gcb)
    if bucket_bytes == 1:
        assert plan.n_buckets == 3       # one compressible leaf per bucket
    else:
        assert plan.n_buckets == 1
    err_a = init_error_state(g_abs, 2, gc)
    err_b = init_error_state(g_abs, 2, gcb)
    for step in range(3):
        g = _grad_tree(np.random.default_rng(7), step)
        red_a, err_a = reduce_stacked(g, err_a, gc)
        red_b, err_b = bkt.reduce_stacked_bucketed(g, err_b, gcb, plan=plan)
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), red_a, red_b)
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), err_a, err_b)


def test_disabled_config_is_exact_mean():
    gc = GradCompressionConfig(enabled=False)
    rng = np.random.default_rng(3)
    g = _grad_tree(rng, 0)
    red, err = bkt.reduce_stacked_bucketed(g, {}, gc)
    jax.tree.map(lambda r, x: np.testing.assert_allclose(
        np.asarray(r), np.asarray(jnp.mean(x, 0)), rtol=1e-6), red, g)
    assert err == {}


# ---------------------------------------------------------------------------
# grad_boundary taps
# ---------------------------------------------------------------------------

def test_grad_boundary_is_bit_exact_identity():
    """Arming the taps changes neither the loss nor any gradient bit: the
    boundary is a custom_vjp identity whose backward only pins scheduling
    (optimization_barrier), under plain grad and under vmap(grad) — the
    step builder's pod vmap relies on the compat batching rule."""
    from repro import configs
    from repro.models import nn, zoo

    cfg = configs.get("glm4-9b", smoke=True)
    model = zoo.build(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 16), dtype=np.int32)),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (4, 16), dtype=np.int32))}

    def loss(p, b):
        return model.train_loss(p, b)[0]

    split = jax.tree.map(lambda x: x.reshape((2, 2) + x.shape[1:]), batch)

    def run():
        # fresh jit wrappers each call: the tap is a trace-time global, so a
        # cached trace from the un-tapped run must not be reused
        l = jax.jit(loss)(params, batch)
        g = jax.jit(jax.grad(loss))(params, batch)
        v = jax.jit(jax.vmap(jax.grad(loss), in_axes=(None, 0)))(params, split)
        return l, g, v

    base_l, base_g, base_v = run()
    nn.set_grad_tap(bkt.grad_boundary)
    try:
        tap_l, tap_g, tap_v = run()
    finally:
        nn.set_grad_tap(None)
    np.testing.assert_array_equal(np.asarray(base_l), np.asarray(tap_l))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), base_g, tap_g)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), base_v, tap_v)
