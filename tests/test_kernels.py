"""Pallas kernels vs. pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import encode as enc
from repro.core import fz, metrics, quant, shuffle
from repro.kernels import bitshuffle_flag as bsf
from repro.kernels import fused_compress as fc
from repro.kernels import fused_decode as fd
from repro.kernels import lorenzo_quant as lq
from repro.kernels import ops, ref
from repro.launch import hlo_cost

RNG = np.random.default_rng(42)


@pytest.mark.parametrize("n_tiles", [1, 2, 8, 9, 17])
def test_bitshuffle_flag_matches_oracle(n_tiles):
    codes = jnp.asarray(RNG.integers(0, 1 << 16, size=(n_tiles, ref.TILE), dtype=np.uint16))
    sh_k, fl_k = bsf.bitshuffle_flag(codes, interpret=True)
    sh_r, fl_r = ref.bitshuffle_flag_ref(codes)
    np.testing.assert_array_equal(np.asarray(sh_k), np.asarray(sh_r))
    np.testing.assert_array_equal(np.asarray(fl_k), np.asarray(fl_r))


@pytest.mark.parametrize("n_tiles", [1, 3, 8])
def test_unshuffle_kernel_roundtrip(n_tiles):
    codes = jnp.asarray(RNG.integers(0, 1 << 16, size=(n_tiles, ref.TILE), dtype=np.uint16))
    sh, _ = bsf.bitshuffle_flag(codes, interpret=True)
    back = bsf.bitunshuffle_tiles(sh, interpret=True)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(codes))


def test_unshuffle_matches_reference_oracle():
    codes = jnp.asarray(RNG.integers(0, 1 << 16, size=(4, ref.TILE), dtype=np.uint16))
    sh_r, _ = ref.bitshuffle_flag_ref(codes)
    back = bsf.bitunshuffle_tiles(sh_r, interpret=True)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(ref.bitunshuffle_ref(sh_r)))


@pytest.mark.parametrize("shape", [(7,), (4096,), (10_001,), (64, 64), (33, 1000),
                                   (16, 32, 48), (65, 7, 129), (1, 1, 1)])
@pytest.mark.parametrize("code_mode", ["sign_mag", "zigzag"])
def test_lorenzo_quant_matches_oracle(shape, code_mode):
    x = jnp.asarray(RNG.standard_normal(shape).astype(np.float32))
    k = lq.lorenzo_quant(x, jnp.float32(1e-3), code_mode=code_mode, interpret=True)
    r = ref.lorenzo_quant_ref(x, jnp.float32(1e-3), code_mode=code_mode)
    np.testing.assert_array_equal(np.asarray(k), np.asarray(r))


@pytest.mark.parametrize("eb", [1e-2, 1e-4, 3.7e-3])
def test_lorenzo_quant_eb_sweep(eb):
    x = jnp.asarray(np.cumsum(RNG.standard_normal((50, 70)), axis=0).astype(np.float32))
    k = lq.lorenzo_quant(x, jnp.float32(eb), interpret=True)
    r = ref.lorenzo_quant_ref(x, jnp.float32(eb))
    np.testing.assert_array_equal(np.asarray(k), np.asarray(r))


def test_saturation_on_rough_data():
    """Kernel saturates exactly like the reference on outlier-heavy data."""
    x = jnp.asarray(RNG.standard_normal((100, 100)).astype(np.float32) * 1e4)
    k = lq.lorenzo_quant(x, jnp.float32(1e-4), interpret=True)
    r = ref.lorenzo_quant_ref(x, jnp.float32(1e-4))
    np.testing.assert_array_equal(np.asarray(k), np.asarray(r))


def test_fz_kernel_path_bit_identical_to_reference():
    x = jnp.asarray(np.cumsum(RNG.standard_normal((128, 128)), axis=1).astype(np.float32))
    cfg_k = fz.FZConfig(eb=1e-3, use_kernels=True, exact_outliers=False)
    cfg_r = fz.FZConfig(eb=1e-3, use_kernels=False, exact_outliers=False)
    rk, ck = fz.roundtrip(x, cfg_k)
    rr, cr = fz.roundtrip(x, cfg_r)
    np.testing.assert_array_equal(np.asarray(rk), np.asarray(rr))
    np.testing.assert_array_equal(np.asarray(ck.bitflags), np.asarray(cr.bitflags))
    np.testing.assert_array_equal(np.asarray(ck.payload), np.asarray(cr.payload))
    assert int(ck.nnz_blocks) == int(cr.nnz_blocks)


@pytest.mark.parametrize("kernel_mode", ["staged", "fused"])
def test_fz_kernel_hybrid_strict_mode(kernel_mode):
    """use_kernels + exact_outliers: quantization routes through the
    reference (documented in ops.lorenzo_quantize / fused_compress_stages),
    the rest stays kernels, and the strict bound holds."""
    x = jnp.asarray(RNG.standard_normal((64, 200)).astype(np.float32) * 50)
    cfg = fz.FZConfig(eb=1e-4, use_kernels=True, kernel_mode=kernel_mode,
                      exact_outliers=True, outlier_frac=1.0)
    rec, c = fz.roundtrip(x, cfg)
    assert float(metrics.max_abs_err(x, rec)) <= float(c.eb_abs) * (1 + 1e-5)


@pytest.mark.parametrize("kernel_mode", ["staged", "fused"])
def test_fz_kernel_strict_mode_with_real_saturation(kernel_mode):
    """Spiky field whose deltas overflow u16: the outlier side channel must
    actually fire (n_outliers > 0) and still restore the strict bound on the
    kernel paths — pins the explicit raise-or-route contract of the fused
    entry (exact outliers can never silently degrade to saturation)."""
    base = RNG.standard_normal(30_000).astype(np.float32) * 0.01
    spikes = (RNG.random(30_000) < 0.01) * \
        RNG.standard_normal(30_000).astype(np.float32) * 100.0
    x = jnp.asarray(base + spikes)
    cfg = fz.FZConfig(eb=1e-5, eb_mode="abs", use_kernels=True,
                      kernel_mode=kernel_mode, exact_outliers=True,
                      outlier_frac=1.0)
    rec, c = fz.roundtrip(x, cfg)
    assert int(c.n_outliers) > 0
    f32_round = float(jnp.max(jnp.abs(x))) * 2.0 ** -22
    assert float(metrics.max_abs_err(x, rec)) \
        <= float(c.eb_abs) * 1.001 + f32_round
    # and the reconstruction is bit-identical to the reference path
    rec_r, _ = fz.roundtrip(x, fz.FZConfig(
        eb=1e-5, eb_mode="abs", exact_outliers=True, outlier_frac=1.0))
    np.testing.assert_array_equal(np.asarray(rec), np.asarray(rec_r))


# ---------------------------------------------------------------------------
# flash-decode kernel vs the dist/flash_decode jnp partials (the oracle)
# ---------------------------------------------------------------------------

def _decode_case(seed, B=4, S=96, H=8, KVH=4, D=16):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((B, H, D)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, S, KVH, D)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, S, KVH, D)).astype(np.float32))
    return q, k, v


@pytest.mark.parametrize("kv_tile", [16, 32, 64, 128])  # 64: pads 96 -> 128;
def test_flash_decode_partials_match_jnp_oracle(kv_tile):  # 128 clamps to S=96
    from repro.dist import flash_decode as fdr
    from repro.kernels import flash_decode as fdk
    q, k, v = _decode_case(0)
    length = jnp.asarray([0, 1, 96, 37], jnp.int32)  # empty / one / full / ragged
    m_k, num_k, den_k = fdk.decode_partials(q, k, v, length, kv_tile=kv_tile,
                                            interpret=True)
    m_r, num_r, den_r = fdr.decode_partials(q, k, v, length, shard_offset=0)
    np.testing.assert_array_equal(np.asarray(m_k), np.asarray(m_r))  # max is exact
    np.testing.assert_allclose(np.asarray(num_k), np.asarray(num_r), atol=2e-4)
    np.testing.assert_allclose(np.asarray(den_k), np.asarray(den_r), atol=2e-4)


@pytest.mark.parametrize("offset", [0, 32, 80])      # 80: slice past every length
def test_flash_decode_shard_offset_matches_oracle(offset):
    """Offset slices (the shard_map per-shard view) mask identically."""
    from repro.dist import flash_decode as fdr
    from repro.kernels import flash_decode as fdk
    q, k, v = _decode_case(1)
    length = jnp.asarray([5, 40, 64, 96], jnp.int32)
    ksl, vsl = k[:, offset:], v[:, offset:]
    got = fdk.decode_partials(q, ksl, vsl, length, shard_offset=offset,
                              kv_tile=16, interpret=True)
    want = fdr.decode_partials(q, ksl, vsl, length, shard_offset=offset)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), atol=2e-4)


def test_flash_decode_padded_tile_with_overlong_length():
    """Regression: tile padding must stay masked even when the global length
    extends past this slice (a shard whose sequence continues in later
    shards). With S=96, kv_tile=64 the slice pads to 128; an unclamped
    ``pos < length`` mask would let the 32 zero-K pad rows into the softmax
    (each adds exp(-m) to den), skewing den by O(pad)."""
    from repro.dist import flash_decode as fdr
    from repro.kernels import flash_decode as fdk
    q, k, v = _decode_case(7)
    length = jnp.asarray([200, 96, 97, 5], jnp.int32)   # all >= or > slice end
    got = fdk.decode_partials(q, k, v, length, shard_offset=0, kv_tile=64,
                              interpret=True)
    want = fdr.decode_partials(q, k, v, length, shard_offset=0)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]), atol=2e-4)
    np.testing.assert_allclose(np.asarray(got[2]), np.asarray(want[2]), atol=2e-4)


def test_flash_decode_combined_matches_decode_attention():
    from repro.kernels import flash_decode as fdk
    from repro.models.attention import decode_attention
    q, k, v = _decode_case(2)
    length = jnp.asarray([1, 17, 96, 50], jnp.int32)
    out = fdk.flash_decode(q, k, v, length, kv_tile=32, interpret=True)
    ref = decode_attention(q, k, v, length)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4)


def test_flash_decode_paged_layout_matches_contiguous():
    """Page-native entry == contiguous entry == oracle (same data, two tilings)."""
    from repro.kernels import flash_decode as fdk
    from repro.models.attention import decode_attention
    q, k, v = _decode_case(3)
    length = jnp.asarray([0, 16, 96, 49], jnp.int32)  # page-aligned + straddling
    B, S, KVH, D = k.shape
    ps = 16
    kp = k.reshape(B, S // ps, ps, KVH, D)
    vp = v.reshape(B, S // ps, ps, KVH, D)
    m, num, den = fdk.decode_partials_pages(q, kp, vp, length, interpret=True)
    out = fdk.combine_partials(m, num, den, dtype=q.dtype)
    ref = decode_attention(q, k, v, length)
    np.testing.assert_allclose(np.asarray(out[1:]), np.asarray(ref[1:]), atol=2e-4)
    # length-0 lane: kernel returns exactly 0 (num == den == 0) — the
    # contiguous oracle's unmasked softmax degenerates to a mean there
    assert np.all(np.asarray(out[0]) == 0.0)


def test_flash_decode_all_lanes_empty_is_zero():
    """All slices empty: the renorm weight is exp(0) == 1, yet the output is
    exactly 0 because num and den are both 0 — the contract the combine
    comments document (dist/flash_decode.py, kvpool/attention.py)."""
    from repro.dist import flash_decode as fdr
    from repro.kernels import flash_decode as fdk
    q, k, v = _decode_case(4, B=2, S=32)
    length = jnp.zeros((2,), jnp.int32)
    out = fdk.flash_decode(q, k, v, length, kv_tile=16, interpret=True)
    assert np.all(np.asarray(out) == 0.0)
    m, num, den = fdk.decode_partials(q, k, v, length, kv_tile=16, interpret=True)
    assert np.all(np.asarray(m) == fdk.NEG_INF)
    assert np.all(np.asarray(num) == 0.0) and np.all(np.asarray(den) == 0.0)
    # and the jnp reference partials agree exactly on the empty contract
    m_r, num_r, den_r = fdr.decode_partials(q, k, v, length, shard_offset=0)
    np.testing.assert_array_equal(np.asarray(m), np.asarray(m_r))
    np.testing.assert_array_equal(np.asarray(num), np.asarray(num_r))


def test_ops_shuffle_encode_equals_core_encode():
    from repro.core import encode as enc, shuffle as shf
    codes = jnp.asarray(RNG.integers(0, 1 << 16, size=3 * ref.TILE, dtype=np.uint16))
    cap = codes.size // enc.BLOCK_WORDS
    bf_k, pl_k, nnz_k = ops.bitshuffle_flag_encode(codes, capacity=cap)
    bf_r, pl_r, nnz_r = enc.encode(shf.bitshuffle(codes), capacity=cap)
    np.testing.assert_array_equal(np.asarray(bf_k), np.asarray(bf_r))
    np.testing.assert_array_equal(np.asarray(pl_k), np.asarray(pl_r))
    assert int(nnz_k) == int(nnz_r)


# ---------------------------------------------------------------------------
# fused megakernels vs the composed reference stages (the heavy shape x mode
# coverage lives in the three-way property suite; these pin the kernel-level
# contracts directly)
# ---------------------------------------------------------------------------

def _ref_compress(x, eb, code_mode, capacity):
    codes, _, _, _ = quant.dual_quantize(x, eb, code_mode=code_mode,
                                         outlier_capacity=0)
    flat = shuffle.pad_to_tiles(codes.reshape(-1))
    return enc.encode(shuffle.bitshuffle(flat), capacity=capacity)


@pytest.mark.parametrize("shape", [(10_001,), (33, 1000), (16, 16, 16)])
@pytest.mark.parametrize("code_mode", ["sign_mag", "zigzag"])
def test_fused_compress_matches_composed_reference(shape, code_mode):
    x = jnp.asarray(np.cumsum(RNG.standard_normal(shape), axis=0)
                    .astype(np.float32) * 0.3)
    eb = jnp.float32(1e-3)
    cap = fc.plan_stream(shape).padded_n // enc.BLOCK_WORDS
    bf_r, pl_r, nnz_r = _ref_compress(x, eb, code_mode, cap)
    bf_k, pl_k, nnz_k = fc.fused_compress(x, eb, capacity=cap,
                                          code_mode=code_mode, interpret=True)
    np.testing.assert_array_equal(np.asarray(bf_k), np.asarray(bf_r))
    np.testing.assert_array_equal(np.asarray(pl_k), np.asarray(pl_r))
    assert int(nnz_k) == int(nnz_r)


def test_fused_compress_bounded_capacity_drops_like_reference():
    x = jnp.asarray(np.cumsum(RNG.standard_normal(20_000))
                    .astype(np.float32) * 0.3)
    eb = jnp.float32(1e-4)
    bf_r, pl_r, nnz_r = _ref_compress(x, eb, "sign_mag", 100)
    bf_k, pl_k, nnz_k = fc.fused_compress(x, eb, capacity=100, interpret=True)
    np.testing.assert_array_equal(np.asarray(bf_k), np.asarray(bf_r))
    np.testing.assert_array_equal(np.asarray(pl_k), np.asarray(pl_r))
    assert int(nnz_k) == int(nnz_r) and int(nnz_k) > 100


def test_fused_shuffle_encode_matches_core_encode():
    codes = jnp.asarray(RNG.integers(0, 1 << 16, size=9 * ref.TILE, dtype=np.uint16))
    codes = jnp.where(jnp.asarray(RNG.random(codes.size) < 0.7), 0,
                      codes).astype(jnp.uint16)
    cap = codes.size // enc.BLOCK_WORDS
    bf_r, pl_r, nnz_r = enc.encode(shuffle.bitshuffle(codes), capacity=cap)
    bf_k, pl_k, nnz_k = fc.fused_shuffle_encode(codes, capacity=cap,
                                                interpret=True)
    np.testing.assert_array_equal(np.asarray(bf_k), np.asarray(bf_r))
    np.testing.assert_array_equal(np.asarray(pl_k), np.asarray(pl_r))
    assert int(nnz_k) == int(nnz_r)


@pytest.mark.parametrize("shape", [(20_000,), (65, 7, 129)])
def test_fused_decompress_matches_composed_reference(shape):
    x = jnp.asarray(np.cumsum(RNG.standard_normal(shape), axis=0)
                    .astype(np.float32) * 0.3)
    eb = jnp.float32(1e-3)
    cap = fc.plan_stream(shape).padded_n // enc.BLOCK_WORDS
    bf, pld, _ = fc.fused_compress(x, eb, capacity=cap, interpret=True)
    words = enc.decode(bf, pld, n_blocks=fz.FZConfig.n_blocks(x.size))
    codes = shuffle.bitunshuffle(words)[: x.size]
    want = quant.dual_dequantize(codes, eb, tuple(shape))
    got = fd.fused_decompress(bf, pld, eb, shape=tuple(shape), interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fused_decompress_applies_outlier_residuals_in_kernel():
    base = RNG.standard_normal((120, 170)).astype(np.float32) * 0.01
    spikes = (RNG.random((120, 170)) < 0.01) * \
        RNG.standard_normal((120, 170)).astype(np.float32) * 100.0
    x = jnp.asarray(base + spikes)
    eb = jnp.float32(1e-5)
    K = x.size // 8
    codes, oidx, oval, n_over = quant.dual_quantize(x, eb, outlier_capacity=K)
    assert int(n_over) > 0
    cap = fc.plan_stream(x.shape).padded_n // enc.BLOCK_WORDS
    flat = shuffle.pad_to_tiles(codes.reshape(-1))
    bf, pld, _ = fc.fused_shuffle_encode(flat, capacity=cap, interpret=True)
    dec_codes = shuffle.bitunshuffle(
        enc.decode(bf, pld, n_blocks=fz.FZConfig.n_blocks(x.size)))[: x.size]
    want = quant.dual_dequantize(dec_codes, eb, x.shape,
                                 outlier_idx=oidx, outlier_val=oval)
    got = fd.fused_decompress(bf, pld, eb, shape=x.shape,
                              outlier_idx=oidx, outlier_val=oval,
                              interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# the data-movement claim, pinned mechanically (issue acceptance criterion)
# ---------------------------------------------------------------------------

_TRAFFIC_SHAPE = (256, 1024)     # 1 MiB f32, already a TILE multiple


def _traffic_cfg(kernel_mode, capacity_frac=1.0):
    return fz.FZConfig(eb=1e-3, use_kernels=True, kernel_mode=kernel_mode,
                       exact_outliers=False, capacity_frac=capacity_frac)


def test_fused_compress_materializes_no_code_stream_buffer():
    """§3.5 fusion claim, compress side: the staged path materializes the u16
    code stream AND the shuffled-word stream in HBM (XLA buffers of >= one
    full stream length); the fused megakernel's optimized HLO contains NO
    u16 buffer that large — the streams live in VMEM scratch. capacity_frac
    keeps the (legitimate, output) payload below the stream size so the scan
    is a pure intermediate-stream detector."""
    x = jnp.zeros(_TRAFFIC_SHAPE, jnp.float32)
    stream_elems = fz.FZConfig.padded_n(x.size)
    shapes = {}
    for mode in ("staged", "fused"):
        cfg = _traffic_cfg(mode, capacity_frac=0.5)
        txt = jax.jit(lambda d, cfg=cfg: fz.compress(d, cfg)) \
            .lower(x).compile().as_text()
        shapes[mode] = hlo_cost.materialized_shapes(
            txt, dtype="u16", min_elems=stream_elems)
    assert len(shapes["staged"]) >= 2, \
        f"staged path should round-trip code + word streams: {shapes['staged']}"
    assert not shapes["fused"], \
        f"fused compress materialized stream-sized buffers: {shapes['fused']}"


def test_fused_decompress_hbm_traffic_is_io_bound():
    """§3.5 fusion claim, decode side (the kvpool transient-read hot path):
    buffer-assignment traffic of the fused megakernel stays within ~1.3x of
    the unavoidable argument+output bytes, while the staged path (word and
    code streams through HBM) costs >= ~2.4x."""
    x = jnp.asarray(np.cumsum(RNG.standard_normal(_TRAFFIC_SHAPE), axis=1)
                    .astype(np.float32))
    ratios = {}
    for mode in ("staged", "fused"):
        cfg = _traffic_cfg(mode)
        c = fz.compress(x, cfg)
        compiled = jax.jit(lambda cc, cfg=cfg: fz.decompress(cc, cfg)) \
            .lower(c).compile()
        ratios[mode] = hlo_cost.compiled_memory_traffic(compiled)["traffic_ratio"]
    assert ratios["fused"] <= 1.3, ratios
    assert ratios["staged"] >= 2.4, ratios
    # decompressions agree bit-exactly while moving ~2x fewer bytes
    assert ratios["staged"] / ratios["fused"] >= 1.8, ratios
