"""Pallas kernels vs. pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fz, metrics
from repro.kernels import bitshuffle_flag as bsf
from repro.kernels import lorenzo_quant as lq
from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


@pytest.mark.parametrize("n_tiles", [1, 2, 8, 9, 17])
def test_bitshuffle_flag_matches_oracle(n_tiles):
    codes = jnp.asarray(RNG.integers(0, 1 << 16, size=(n_tiles, ref.TILE), dtype=np.uint16))
    sh_k, fl_k = bsf.bitshuffle_flag(codes, interpret=True)
    sh_r, fl_r = ref.bitshuffle_flag_ref(codes)
    np.testing.assert_array_equal(np.asarray(sh_k), np.asarray(sh_r))
    np.testing.assert_array_equal(np.asarray(fl_k), np.asarray(fl_r))


@pytest.mark.parametrize("n_tiles", [1, 3, 8])
def test_unshuffle_kernel_roundtrip(n_tiles):
    codes = jnp.asarray(RNG.integers(0, 1 << 16, size=(n_tiles, ref.TILE), dtype=np.uint16))
    sh, _ = bsf.bitshuffle_flag(codes, interpret=True)
    back = bsf.bitunshuffle_tiles(sh, interpret=True)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(codes))


def test_unshuffle_matches_reference_oracle():
    codes = jnp.asarray(RNG.integers(0, 1 << 16, size=(4, ref.TILE), dtype=np.uint16))
    sh_r, _ = ref.bitshuffle_flag_ref(codes)
    back = bsf.bitunshuffle_tiles(sh_r, interpret=True)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(ref.bitunshuffle_ref(sh_r)))


@pytest.mark.parametrize("shape", [(7,), (4096,), (10_001,), (64, 64), (33, 1000),
                                   (16, 32, 48), (65, 7, 129), (1, 1, 1)])
@pytest.mark.parametrize("code_mode", ["sign_mag", "zigzag"])
def test_lorenzo_quant_matches_oracle(shape, code_mode):
    x = jnp.asarray(RNG.standard_normal(shape).astype(np.float32))
    k = lq.lorenzo_quant(x, jnp.float32(1e-3), code_mode=code_mode, interpret=True)
    r = ref.lorenzo_quant_ref(x, jnp.float32(1e-3), code_mode=code_mode)
    np.testing.assert_array_equal(np.asarray(k), np.asarray(r))


@pytest.mark.parametrize("eb", [1e-2, 1e-4, 3.7e-3])
def test_lorenzo_quant_eb_sweep(eb):
    x = jnp.asarray(np.cumsum(RNG.standard_normal((50, 70)), axis=0).astype(np.float32))
    k = lq.lorenzo_quant(x, jnp.float32(eb), interpret=True)
    r = ref.lorenzo_quant_ref(x, jnp.float32(eb))
    np.testing.assert_array_equal(np.asarray(k), np.asarray(r))


def test_saturation_on_rough_data():
    """Kernel saturates exactly like the reference on outlier-heavy data."""
    x = jnp.asarray(RNG.standard_normal((100, 100)).astype(np.float32) * 1e4)
    k = lq.lorenzo_quant(x, jnp.float32(1e-4), interpret=True)
    r = ref.lorenzo_quant_ref(x, jnp.float32(1e-4))
    np.testing.assert_array_equal(np.asarray(k), np.asarray(r))


def test_fz_kernel_path_bit_identical_to_reference():
    x = jnp.asarray(np.cumsum(RNG.standard_normal((128, 128)), axis=1).astype(np.float32))
    cfg_k = fz.FZConfig(eb=1e-3, use_kernels=True, exact_outliers=False)
    cfg_r = fz.FZConfig(eb=1e-3, use_kernels=False, exact_outliers=False)
    rk, ck = fz.roundtrip(x, cfg_k)
    rr, cr = fz.roundtrip(x, cfg_r)
    np.testing.assert_array_equal(np.asarray(rk), np.asarray(rr))
    np.testing.assert_array_equal(np.asarray(ck.bitflags), np.asarray(cr.bitflags))
    np.testing.assert_array_equal(np.asarray(ck.payload), np.asarray(cr.payload))
    assert int(ck.nnz_blocks) == int(cr.nnz_blocks)


def test_fz_kernel_hybrid_strict_mode():
    """use_kernels + exact_outliers: quantize falls back to ref, bound holds."""
    x = jnp.asarray(RNG.standard_normal((64, 200)).astype(np.float32) * 50)
    cfg = fz.FZConfig(eb=1e-4, use_kernels=True, exact_outliers=True, outlier_frac=1.0)
    rec, c = fz.roundtrip(x, cfg)
    assert float(metrics.max_abs_err(x, rec)) <= float(c.eb_abs) * (1 + 1e-5)


# ---------------------------------------------------------------------------
# flash-decode kernel vs the dist/flash_decode jnp partials (the oracle)
# ---------------------------------------------------------------------------

def _decode_case(seed, B=4, S=96, H=8, KVH=4, D=16):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((B, H, D)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, S, KVH, D)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, S, KVH, D)).astype(np.float32))
    return q, k, v


@pytest.mark.parametrize("kv_tile", [16, 32, 64, 128])  # 64: pads 96 -> 128;
def test_flash_decode_partials_match_jnp_oracle(kv_tile):  # 128 clamps to S=96
    from repro.dist import flash_decode as fdr
    from repro.kernels import flash_decode as fdk
    q, k, v = _decode_case(0)
    length = jnp.asarray([0, 1, 96, 37], jnp.int32)  # empty / one / full / ragged
    m_k, num_k, den_k = fdk.decode_partials(q, k, v, length, kv_tile=kv_tile,
                                            interpret=True)
    m_r, num_r, den_r = fdr.decode_partials(q, k, v, length, shard_offset=0)
    np.testing.assert_array_equal(np.asarray(m_k), np.asarray(m_r))  # max is exact
    np.testing.assert_allclose(np.asarray(num_k), np.asarray(num_r), atol=2e-4)
    np.testing.assert_allclose(np.asarray(den_k), np.asarray(den_r), atol=2e-4)


@pytest.mark.parametrize("offset", [0, 32, 80])      # 80: slice past every length
def test_flash_decode_shard_offset_matches_oracle(offset):
    """Offset slices (the shard_map per-shard view) mask identically."""
    from repro.dist import flash_decode as fdr
    from repro.kernels import flash_decode as fdk
    q, k, v = _decode_case(1)
    length = jnp.asarray([5, 40, 64, 96], jnp.int32)
    ksl, vsl = k[:, offset:], v[:, offset:]
    got = fdk.decode_partials(q, ksl, vsl, length, shard_offset=offset,
                              kv_tile=16, interpret=True)
    want = fdr.decode_partials(q, ksl, vsl, length, shard_offset=offset)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), atol=2e-4)


def test_flash_decode_padded_tile_with_overlong_length():
    """Regression: tile padding must stay masked even when the global length
    extends past this slice (a shard whose sequence continues in later
    shards). With S=96, kv_tile=64 the slice pads to 128; an unclamped
    ``pos < length`` mask would let the 32 zero-K pad rows into the softmax
    (each adds exp(-m) to den), skewing den by O(pad)."""
    from repro.dist import flash_decode as fdr
    from repro.kernels import flash_decode as fdk
    q, k, v = _decode_case(7)
    length = jnp.asarray([200, 96, 97, 5], jnp.int32)   # all >= or > slice end
    got = fdk.decode_partials(q, k, v, length, shard_offset=0, kv_tile=64,
                              interpret=True)
    want = fdr.decode_partials(q, k, v, length, shard_offset=0)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]), atol=2e-4)
    np.testing.assert_allclose(np.asarray(got[2]), np.asarray(want[2]), atol=2e-4)


def test_flash_decode_combined_matches_decode_attention():
    from repro.kernels import flash_decode as fdk
    from repro.models.attention import decode_attention
    q, k, v = _decode_case(2)
    length = jnp.asarray([1, 17, 96, 50], jnp.int32)
    out = fdk.flash_decode(q, k, v, length, kv_tile=32, interpret=True)
    ref = decode_attention(q, k, v, length)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4)


def test_flash_decode_paged_layout_matches_contiguous():
    """Page-native entry == contiguous entry == oracle (same data, two tilings)."""
    from repro.kernels import flash_decode as fdk
    from repro.models.attention import decode_attention
    q, k, v = _decode_case(3)
    length = jnp.asarray([0, 16, 96, 49], jnp.int32)  # page-aligned + straddling
    B, S, KVH, D = k.shape
    ps = 16
    kp = k.reshape(B, S // ps, ps, KVH, D)
    vp = v.reshape(B, S // ps, ps, KVH, D)
    m, num, den = fdk.decode_partials_pages(q, kp, vp, length, interpret=True)
    out = fdk.combine_partials(m, num, den, dtype=q.dtype)
    ref = decode_attention(q, k, v, length)
    np.testing.assert_allclose(np.asarray(out[1:]), np.asarray(ref[1:]), atol=2e-4)
    # length-0 lane: kernel returns exactly 0 (num == den == 0) — the
    # contiguous oracle's unmasked softmax degenerates to a mean there
    assert np.all(np.asarray(out[0]) == 0.0)


def test_flash_decode_all_lanes_empty_is_zero():
    """All slices empty: the renorm weight is exp(0) == 1, yet the output is
    exactly 0 because num and den are both 0 — the contract the combine
    comments document (dist/flash_decode.py, kvpool/attention.py)."""
    from repro.dist import flash_decode as fdr
    from repro.kernels import flash_decode as fdk
    q, k, v = _decode_case(4, B=2, S=32)
    length = jnp.zeros((2,), jnp.int32)
    out = fdk.flash_decode(q, k, v, length, kv_tile=16, interpret=True)
    assert np.all(np.asarray(out) == 0.0)
    m, num, den = fdk.decode_partials(q, k, v, length, kv_tile=16, interpret=True)
    assert np.all(np.asarray(m) == fdk.NEG_INF)
    assert np.all(np.asarray(num) == 0.0) and np.all(np.asarray(den) == 0.0)
    # and the jnp reference partials agree exactly on the empty contract
    m_r, num_r, den_r = fdr.decode_partials(q, k, v, length, shard_offset=0)
    np.testing.assert_array_equal(np.asarray(m), np.asarray(m_r))
    np.testing.assert_array_equal(np.asarray(num), np.asarray(num_r))


def test_ops_shuffle_encode_equals_core_encode():
    from repro.core import encode as enc, shuffle as shf
    codes = jnp.asarray(RNG.integers(0, 1 << 16, size=3 * ref.TILE, dtype=np.uint16))
    cap = codes.size // enc.BLOCK_WORDS
    bf_k, pl_k, nnz_k = ops.bitshuffle_flag_encode(codes, capacity=cap)
    bf_r, pl_r, nnz_r = enc.encode(shf.bitshuffle(codes), capacity=cap)
    np.testing.assert_array_equal(np.asarray(bf_k), np.asarray(bf_r))
    np.testing.assert_array_equal(np.asarray(pl_k), np.asarray(pl_r))
    assert int(nnz_k) == int(nnz_r)
