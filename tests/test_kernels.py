"""Pallas kernels vs. pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fz, metrics
from repro.kernels import bitshuffle_flag as bsf
from repro.kernels import lorenzo_quant as lq
from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


@pytest.mark.parametrize("n_tiles", [1, 2, 8, 9, 17])
def test_bitshuffle_flag_matches_oracle(n_tiles):
    codes = jnp.asarray(RNG.integers(0, 1 << 16, size=(n_tiles, ref.TILE), dtype=np.uint16))
    sh_k, fl_k = bsf.bitshuffle_flag(codes, interpret=True)
    sh_r, fl_r = ref.bitshuffle_flag_ref(codes)
    np.testing.assert_array_equal(np.asarray(sh_k), np.asarray(sh_r))
    np.testing.assert_array_equal(np.asarray(fl_k), np.asarray(fl_r))


@pytest.mark.parametrize("n_tiles", [1, 3, 8])
def test_unshuffle_kernel_roundtrip(n_tiles):
    codes = jnp.asarray(RNG.integers(0, 1 << 16, size=(n_tiles, ref.TILE), dtype=np.uint16))
    sh, _ = bsf.bitshuffle_flag(codes, interpret=True)
    back = bsf.bitunshuffle_tiles(sh, interpret=True)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(codes))


def test_unshuffle_matches_reference_oracle():
    codes = jnp.asarray(RNG.integers(0, 1 << 16, size=(4, ref.TILE), dtype=np.uint16))
    sh_r, _ = ref.bitshuffle_flag_ref(codes)
    back = bsf.bitunshuffle_tiles(sh_r, interpret=True)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(ref.bitunshuffle_ref(sh_r)))


@pytest.mark.parametrize("shape", [(7,), (4096,), (10_001,), (64, 64), (33, 1000),
                                   (16, 32, 48), (65, 7, 129), (1, 1, 1)])
@pytest.mark.parametrize("code_mode", ["sign_mag", "zigzag"])
def test_lorenzo_quant_matches_oracle(shape, code_mode):
    x = jnp.asarray(RNG.standard_normal(shape).astype(np.float32))
    k = lq.lorenzo_quant(x, jnp.float32(1e-3), code_mode=code_mode, interpret=True)
    r = ref.lorenzo_quant_ref(x, jnp.float32(1e-3), code_mode=code_mode)
    np.testing.assert_array_equal(np.asarray(k), np.asarray(r))


@pytest.mark.parametrize("eb", [1e-2, 1e-4, 3.7e-3])
def test_lorenzo_quant_eb_sweep(eb):
    x = jnp.asarray(np.cumsum(RNG.standard_normal((50, 70)), axis=0).astype(np.float32))
    k = lq.lorenzo_quant(x, jnp.float32(eb), interpret=True)
    r = ref.lorenzo_quant_ref(x, jnp.float32(eb))
    np.testing.assert_array_equal(np.asarray(k), np.asarray(r))


def test_saturation_on_rough_data():
    """Kernel saturates exactly like the reference on outlier-heavy data."""
    x = jnp.asarray(RNG.standard_normal((100, 100)).astype(np.float32) * 1e4)
    k = lq.lorenzo_quant(x, jnp.float32(1e-4), interpret=True)
    r = ref.lorenzo_quant_ref(x, jnp.float32(1e-4))
    np.testing.assert_array_equal(np.asarray(k), np.asarray(r))


def test_fz_kernel_path_bit_identical_to_reference():
    x = jnp.asarray(np.cumsum(RNG.standard_normal((128, 128)), axis=1).astype(np.float32))
    cfg_k = fz.FZConfig(eb=1e-3, use_kernels=True, exact_outliers=False)
    cfg_r = fz.FZConfig(eb=1e-3, use_kernels=False, exact_outliers=False)
    rk, ck = fz.roundtrip(x, cfg_k)
    rr, cr = fz.roundtrip(x, cfg_r)
    np.testing.assert_array_equal(np.asarray(rk), np.asarray(rr))
    np.testing.assert_array_equal(np.asarray(ck.bitflags), np.asarray(cr.bitflags))
    np.testing.assert_array_equal(np.asarray(ck.payload), np.asarray(cr.payload))
    assert int(ck.nnz_blocks) == int(cr.nnz_blocks)


def test_fz_kernel_hybrid_strict_mode():
    """use_kernels + exact_outliers: quantize falls back to ref, bound holds."""
    x = jnp.asarray(RNG.standard_normal((64, 200)).astype(np.float32) * 50)
    cfg = fz.FZConfig(eb=1e-4, use_kernels=True, exact_outliers=True, outlier_frac=1.0)
    rec, c = fz.roundtrip(x, cfg)
    assert float(metrics.max_abs_err(x, rec)) <= float(c.eb_abs) * (1 + 1e-5)


def test_ops_shuffle_encode_equals_core_encode():
    from repro.core import encode as enc, shuffle as shf
    codes = jnp.asarray(RNG.integers(0, 1 << 16, size=3 * ref.TILE, dtype=np.uint16))
    cap = codes.size // enc.BLOCK_WORDS
    bf_k, pl_k, nnz_k = ops.bitshuffle_flag_encode(codes, capacity=cap)
    bf_r, pl_r, nnz_r = enc.encode(shf.bitshuffle(codes), capacity=cap)
    np.testing.assert_array_equal(np.asarray(bf_k), np.asarray(bf_r))
    np.testing.assert_array_equal(np.asarray(pl_k), np.asarray(pl_r))
    assert int(nnz_k) == int(nnz_r)
