"""Wire accounting: the analytic per-leaf byte model vs real containers.

``wire_bytes_per_leaf`` is what the dry-run and benchmarks report for the
cross-pod link, so it must agree exactly with what a real ``FZCompressed``
container puts on the wire (``wire_bytes()``: capacity-sized, data
independent) and stay an upper bound on the data-dependent ``used_bytes()``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fz
from repro.dist.compressed_allreduce import GradCompressionConfig, wire_bytes_per_leaf


def _smooth_grad(n: int, seed: int = 0) -> jnp.ndarray:
    rng = np.random.default_rng(seed)
    return jnp.asarray(np.cumsum(rng.standard_normal(n).astype(np.float32)) * 1e-3)


@pytest.mark.parametrize("capacity_frac", [0.5, 0.75, 1.0])
@pytest.mark.parametrize("n", [1 << 14, 1 << 16])
def test_wire_bytes_matches_real_container(capacity_frac, n):
    cfg = GradCompressionConfig(capacity_frac=capacity_frac)
    acc = wire_bytes_per_leaf(n, cfg)
    c = fz.compress(_smooth_grad(n), cfg.fz_config())
    assert acc["raw"] == 4 * n == c.raw_bytes()
    # the analytic model IS the container layout, byte for byte
    assert acc["compressed"] == c.wire_bytes()
    assert acc["reduction"] == pytest.approx(acc["raw"] / acc["compressed"])


@pytest.mark.parametrize("capacity_frac", [0.5, 0.75, 1.0])
def test_used_bytes_within_wire_budget(capacity_frac):
    """Smooth gradients: data-dependent used bytes fit the capacity-sized
    wire container (modulo the 32B used-bytes header vs 12B of scalar
    leaves — used_bytes() accounts a serialized header the pytree wire
    format carries as scalars)."""
    n = 1 << 16
    cfg = GradCompressionConfig(capacity_frac=capacity_frac)
    c = fz.compress(_smooth_grad(n), cfg.fz_config())
    header_delta = 32 - 12
    assert int(c.used_bytes()) <= c.wire_bytes() + header_delta
    # and compression is genuinely happening on the wire at these settings
    assert wire_bytes_per_leaf(n, cfg)["reduction"] > 1.9


def test_wire_accounting_scales_with_capacity():
    """Smaller capacity_frac -> fewer wire bytes, monotonically."""
    n = 1 << 16
    wires = [wire_bytes_per_leaf(n, GradCompressionConfig(capacity_frac=cf))["compressed"]
             for cf in (0.5, 0.75, 1.0)]
    assert wires[0] < wires[1] < wires[2]
