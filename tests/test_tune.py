"""repro.tune: registry/tuner/cache/dispatch contracts.

Pins the satellite checklist for the autotuner PR: cache persistence
round-trip, shape-bucket collapsing, schema-version invalidation,
corrupted/truncated-file recovery, the parity gate rejecting a seeded
wrong-output candidate (and never selecting it), the backend-aware
fallback ordering, the analysis budget skip, and the end-to-end
``kernel_mode="auto"`` path staying bit-identical to the reference.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import tune
from repro.core import fz
from repro.tune import cache as tcache
from repro.tune import dispatch, impls, registry, tuner


@pytest.fixture
def tmp_cache(tmp_path):
    """Point the process-wide dispatch cache at a throwaway file."""
    tc = dispatch.configure(tmp_path / "tune_cache.json")
    yield tc
    dispatch.reset()


def _fake_op(name, impls=("ref", "fast")):
    """Register a trivial op (instant candidates, bit-identity gate)."""
    def make_context(*, n, dtype):
        return {"n": n, "dtype": dtype,
                "x": jnp.arange(16, dtype=jnp.float32)}

    def parity(ctx, out, ref_out):
        if np.array_equal(np.asarray(out), np.asarray(ref_out)):
            return None
        return "mismatch"

    registry.register_op(registry.OpSpec(
        name=name, reference="ref", make_context=make_context,
        parity=parity, gate="bit-identity"))
    for impl in impls:
        def make_runner(ctx, impl=impl):
            return lambda: ctx["x"] * 2.0
        registry.register(registry.Candidate(
            op=name, impl=impl, make_runner=make_runner))


@pytest.fixture
def fake_op():
    name = "test.fake"
    _fake_op(name)
    yield name
    registry._OPS.pop(name, None)
    registry._CANDS.pop(name, None)


def test_shape_bucket_powers_of_two():
    assert tcache.shape_bucket(1) == 1
    assert tcache.shape_bucket(4096) == 4096
    assert tcache.shape_bucket(4097) == 8192
    assert tcache.shape_bucket(50_000) == 65_536
    key = tcache.cache_key("interpret", "fz.compress", 50_000, "float32", "cpu")
    assert "pow2:65536" in key


def test_cache_roundtrip_persistence(tmp_path):
    path = tmp_path / "tc.json"
    tc = tcache.TuneCache(path).load()
    assert tc.status == "missing" and len(tc) == 0
    key = tcache.cache_key("interpret", "op", 4096, "float32", "cpu")
    tc.put(key, {"impl": "staged", "measured_us": {"staged": 1.0}})
    tc.save()
    tc2 = tcache.TuneCache(path).load()
    assert tc2.status == "ok"
    assert tc2.get(key)["impl"] == "staged"


def test_cache_schema_bump_invalidates(tmp_path):
    path = tmp_path / "tc.json"
    doc = {"schema": tcache.SCHEMA_VERSION + 1,
           "entries": {"k": {"impl": "fused"}}}
    path.write_text(json.dumps(doc))
    tc = tcache.TuneCache(path).load()
    assert tc.status == "schema-mismatch" and len(tc) == 0


@pytest.mark.parametrize("blob", [b"{not json", b"", b"[1,2,3]", b"\x00\xff"])
def test_cache_corrupt_file_recovers(tmp_path, blob):
    path = tmp_path / "tc.json"
    path.write_bytes(blob)
    tc = tcache.TuneCache(path).load()
    assert len(tc) == 0          # never raises, loads empty
    tc.put("k", {"impl": "staged"})
    tc.save()                    # rewrites a clean file
    assert tcache.TuneCache(path).load().status == "ok"


def test_truncated_cache_retunes_cleanly(tmp_path, fake_op):
    path = tmp_path / "tc.json"
    tc = dispatch.configure(path)
    try:
        entry, measured = tuner.tune_op(fake_op, n=64, dtype="float32",
                                        cache=tc, k=1, warmup=0, log=lambda *a: None)
        assert measured and entry["impl"] in ("ref", "fast")
        # truncate the file mid-stream, then reload: the tuner must measure
        # again (clean retune) and write a valid file back
        path.write_bytes(path.read_bytes()[: path.stat().st_size // 2])
        tc = dispatch.configure(path)
        entry2, measured2 = tuner.tune_op(fake_op, n=64, dtype="float32",
                                          cache=tc, k=1, warmup=0, log=lambda *a: None)
        assert measured2
        assert tcache.TuneCache(path).load().status == "ok"
    finally:
        dispatch.reset()


def test_shape_bucket_collapsing(tmp_cache, fake_op):
    _, measured = tuner.tune_op(fake_op, n=3000, dtype="float32",
                                cache=tmp_cache, k=1, warmup=0, log=lambda *a: None)
    assert measured
    # 3000 and 4096 share the pow2:4096 bucket -> pure cache hit
    _, measured2 = tuner.tune_op(fake_op, n=4096, dtype="float32",
                                 cache=tmp_cache, k=1, warmup=0, log=lambda *a: None)
    assert not measured2
    # a different bucket tunes afresh
    _, measured3 = tuner.tune_op(fake_op, n=8192, dtype="float32",
                                 cache=tmp_cache, k=1, warmup=0, log=lambda *a: None)
    assert measured3


def test_second_run_zero_measurements(tmp_cache, fake_op):
    workloads = [(fake_op, 64, "float32"), (fake_op, 256, "float32")]
    s1 = tuner.ensure_tuned(workloads, cache=tmp_cache, k=1, warmup=0,
                            log=lambda *a: None)
    assert s1["misses"] == 2 and s1["measurements"] > 0
    s2 = tuner.ensure_tuned(workloads, cache=tmp_cache, k=1, warmup=0,
                            log=lambda *a: None)
    assert s2["hits"] == 2 and s2["misses"] == 0 and s2["measurements"] == 0


def test_parity_gate_rejects_wrong_candidate(tmp_cache, fake_op):
    def make_runner(ctx):
        return lambda: ctx["x"] * -1.0   # instant, structurally right, wrong
    evil = registry.Candidate(op=fake_op, impl="evil", make_runner=make_runner)
    with registry.scoped(evil):
        entry, _ = tuner.tune_op(fake_op, n=64, dtype="float32",
                                 cache=tmp_cache, k=1, warmup=0, log=lambda *a: None)
    assert entry["impl"] != "evil"
    assert "evil" in entry["rejected"]
    assert "evil" not in entry["measured_us"]   # never even timed


def test_parity_gate_rejects_wrong_fz_decode(tmp_cache):
    """The seeded wrong-output candidate on the *real* fz.decompress op:
    zeroed reconstructions are instant but fail bit-identity — the gate must
    reject them however fast they are."""
    evil = impls.evil_candidate("fz.decompress")
    with registry.scoped(evil):
        entry, _ = tuner.tune_op("fz.decompress", n=4096, dtype="float32",
                                 cache=tmp_cache, k=1, warmup=0,
                                 log=lambda *a: None)
    assert entry["impl"] != "evil"
    assert "bit-identical" in entry["rejected"]["evil"]
    assert entry["gate"] == "bit-identity"


def test_compress_parity_gate_is_error_bound(tmp_cache):
    evil = impls.evil_candidate("fz.compress")
    with registry.scoped(evil):
        entry, _ = tuner.tune_op("fz.compress", n=4096, dtype="float32",
                                 cache=tmp_cache, k=1, warmup=0,
                                 log=lambda *a: None)
    assert entry["impl"] != "evil"
    assert "error bound" in entry["rejected"]["evil"]
    assert entry["gate"] == "error-bound"


def test_fallback_ordering_interpret(tmp_cache):
    """No cache entry: interpret-class backends must prefer staged over
    fused (the measured 4x fused-compress interpreter regression)."""
    assert dispatch.backend() == "interpret"   # CI runs on CPU
    assert dispatch.fz_fallback_mode() == "staged"
    assert tune.resolve_fz("compress", 4096, "float32") == "staged"
    assert tune.resolve_fz("decompress", 4096, "float32") == "staged"
    # untuned decode attention honors the explicit kernel request
    assert tune.decode_attention_impl(4096, "bfloat16") == "kernel"
    assert dispatch.FZ_FALLBACK["tpu"][0] == "fused"


def test_cached_winner_overrides_fallback(tmp_cache):
    key = tcache.cache_key(dispatch.backend(), "fz.decompress", 4096,
                           "float32", dispatch.arch())
    tmp_cache.put(key, {"impl": "fused"})
    dispatch.invalidate_memo()
    assert tune.resolve_fz("decompress", 4096, "float32") == "fused"


def test_auto_resolution_in_fzconfig(tmp_cache):
    """kernel_mode="auto" is the default and resolves before jit; the
    resolved config is concrete (never "auto")."""
    cfg = fz.FZConfig(eb=1e-3, use_kernels=True, exact_outliers=False)
    assert cfg.kernel_mode == "auto"
    r = fz._resolved(cfg, "compress", 4096, "float32")
    assert r.kernel_mode in ("staged", "fused")
    # reference winner maps to use_kernels=False
    key = tcache.cache_key(dispatch.backend(), "fz.compress", 4096,
                           "float32", dispatch.arch())
    tmp_cache.put(key, {"impl": "reference"})
    dispatch.invalidate_memo()
    r2 = fz._resolved(cfg, "compress", 4096, "float32")
    assert not r2.use_kernels
    # non-auto and non-kernel configs pass through untouched
    explicit = fz.FZConfig(eb=1e-3, use_kernels=True, kernel_mode="fused",
                           exact_outliers=False)
    assert fz._resolved(explicit, "compress", 4096, "float32") is explicit


def test_auto_path_bit_identical_to_reference(tmp_cache):
    x = jnp.asarray(np.cumsum(
        np.random.default_rng(3).standard_normal(4096).astype(np.float32)) * 0.1)
    ref = fz.FZConfig(eb=1e-3, exact_outliers=False)
    auto = fz.FZConfig(eb=1e-3, use_kernels=True, exact_outliers=False)
    c_ref, c_auto = fz.compress(x, ref), fz.compress(x, auto)
    for a, b in zip(jax.tree.leaves(c_ref), jax.tree.leaves(c_auto)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert np.array_equal(np.asarray(fz.decompress(c_ref, ref)),
                          np.asarray(fz.decompress(c_auto, auto)))


def test_budget_skip_vmem_overflow():
    """analysis integration: the fused megakernel candidates overflow VMEM
    at the 1M-element reduce-bucket point (the committed baseline findings)
    and must be skipped, not measured; staged stays eligible."""
    ctx = {"n": 1 << 20, "dtype": "float32"}
    cands = {c.impl: c for c in registry.candidates("fz.compress")}
    why = tuner._budget_skip(cands["fused"], ctx)
    assert why is not None and "vmem-overflow" in why
    assert tuner._budget_skip(cands["staged"], ctx) is None
    assert tuner._budget_skip(cands["reference"], ctx) is None
    # small shapes fit: nothing is skipped there
    assert tuner._budget_skip(cands["fused"], {"n": 4096,
                                               "dtype": "float32"}) is None


def test_tuner_records_skips_in_entry(tmp_cache, fake_op):
    cand = registry._CANDS[fake_op]["fast"]
    skipping = registry.Candidate(
        op=fake_op, impl="huge", make_runner=cand.make_runner,
        kernel_specs=lambda ctx: [_overflow_spec()])
    with registry.scoped(skipping):
        entry, _ = tuner.tune_op(fake_op, n=64, dtype="float32",
                                 cache=tmp_cache, k=1, warmup=0,
                                 log=lambda *a: None)
    assert "huge" in entry["skipped"]
    assert "huge" not in entry["measured_us"]


def _overflow_spec():
    import repro.kernels  # noqa: F401  -- registers the spec builders
    from repro.analysis.kernelspec import spec_builders
    return spec_builders()["fused_compress"](shape=(1 << 20,),
                                             dtype="float32",
                                             capacity_frac=1.0)


def test_cli_smoke_json(tmp_path, capsys):
    from repro.tune import __main__ as cli
    cache_path = str(tmp_path / "cli_cache.json")
    try:
        rc = cli.main(["--smoke", "--cache", cache_path, "--json",
                       "--ops", "fz.decompress", "--k", "1", "--warmup", "0"])
        assert rc == 0
        out = json.loads(capsys.readouterr().out)
        assert out["misses"] == len(out["results"]) > 0
        rc2 = cli.main(["--smoke", "--cache", cache_path, "--json",
                        "--ops", "fz.decompress", "--k", "1", "--warmup", "0"])
        assert rc2 == 0
        out2 = json.loads(capsys.readouterr().out)
        assert out2["measurements"] == 0 and out2["misses"] == 0
        rc3 = cli.main(["--dump", "--cache", cache_path])
        assert rc3 == 0
        assert "fz.decompress" in capsys.readouterr().out
    finally:
        dispatch.reset()
