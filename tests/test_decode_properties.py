"""Property tests: paged vs contiguous decode-attention parity.

The invariant: for any cache, any page size, and any ragged length vector —
0, 1, exact page boundaries, non-multiples of the page size, full length —
the page-native formulation (jnp vmap-combine AND the Pallas KV-tile kernel)
matches the contiguous flash-decoding partials combine to the 2e-4 pin, and
length-0 lanes are exactly 0 on every path.

Same two tiers as tests/test_fz_properties.py: hypothesis-driven search
(the real wheel in CI, the bundled minihypothesis shim in hermetic boxes —
see tests/conftest.py) plus a fixed seeded matrix that always runs.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_decode as fdk
from repro.models.attention import decode_attention
from repro.serve.kvpool import paged_decode_attention, pages_from_cache

from hypothesis import given, settings, strategies as st

SET = dict(max_examples=15, deadline=None)


def make_case(seed: int, B: int, S: int, H: int, KVH: int, D: int, ps: int,
              length_kind: str):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((B, H, D)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, S, KVH, D)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, S, KVH, D)).astype(np.float32))
    picks = {
        "zero": 0,
        "one": 1,
        "page_boundary": ps * max(1, rng.integers(1, S // ps + 1)),
        "ragged": int(rng.integers(1, S + 1)),
        "full": S,
    }
    length = np.asarray(
        [picks[length_kind] if b == 0 else int(rng.integers(0, S + 1))
         for b in range(B)], np.int32)
    return q, k, v, jnp.asarray(length)


def check_paged_matches_contiguous(seed: int, ps_idx: int, length_kind: str) -> None:
    B, H, KVH, D = 2, 4, 2, 8
    ps = (4, 8, 16)[ps_idx]
    S = ps * 4
    q, k, v, length = make_case(seed, B, S, H, KVH, D, ps, length_kind)
    kp, vp = pages_from_cache(k, v, ps)
    ref = decode_attention(q, k, v, length)
    outs = {
        "jnp": paged_decode_attention(q, kp, vp, length),
        "kernel": paged_decode_attention(q, kp, vp, length, use_kernels=True),
        "kernel_contig": fdk.flash_decode(q, k, v, length, kv_tile=ps,
                                          interpret=True),
    }
    lengths = np.asarray(length)
    for name, out in outs.items():
        out = np.asarray(out)
        for b in range(B):
            if lengths[b] == 0:
                # flash-decode zero convention; the oracle's unmasked
                # softmax degenerates to a mean here
                assert np.all(out[b] == 0.0), (name, b)
            else:
                np.testing.assert_allclose(out[b], np.asarray(ref)[b],
                                           atol=2e-4, err_msg=f"{name}[{b}]")
    # jnp and kernel paged paths also agree with each other everywhere
    np.testing.assert_allclose(np.asarray(outs["jnp"]),
                               np.asarray(outs["kernel"]), atol=2e-4)


# ---------------------------------------------------------------------------
# Tier 1: hypothesis-driven search
# ---------------------------------------------------------------------------

@given(st.integers(0, 2**31 - 1), st.integers(0, 2),
       st.sampled_from(["zero", "one", "page_boundary", "ragged", "full"]))
@settings(**SET)
def test_paged_vs_contiguous_parity(seed, ps_idx, length_kind):
    check_paged_matches_contiguous(seed, ps_idx, length_kind)


# ---------------------------------------------------------------------------
# Tier 2: fixed seeded matrix (always runs)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("length_kind",
                         ["zero", "one", "page_boundary", "ragged", "full"])
@pytest.mark.parametrize("seed,ps_idx", [(0, 0), (1, 1), (2, 2)])
def test_paged_vs_contiguous_parity_seeded(seed, ps_idx, length_kind):
    check_paged_matches_contiguous(seed, ps_idx, length_kind)
