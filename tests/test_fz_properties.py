"""Property-based tests (hypothesis) for the FZ pipeline invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import encode as enc
from repro.core import fz, metrics, quant, shuffle

SET = dict(max_examples=25, deadline=None)


def arrays(draw, max_elems=20_000):
    ndim = draw(st.integers(1, 3))
    dims = draw(st.lists(st.integers(1, 40), min_size=ndim, max_size=ndim))
    n = int(np.prod(dims))
    if n > max_elems:
        dims = [min(d, 16) for d in dims]
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    kind = draw(st.sampled_from(["normal", "smooth", "constant", "zeros"]))
    if kind == "normal":
        x = rng.standard_normal(dims)
    elif kind == "smooth":
        x = rng.standard_normal(dims)
        for ax in range(len(dims)):
            x = np.cumsum(x, axis=ax) * 0.1
    elif kind == "constant":
        x = np.full(dims, rng.uniform(-100, 100))
    else:
        x = np.zeros(dims)
    return x.astype(np.float32)


@st.composite
def field_and_eb(draw):
    x = arrays(draw)
    eb = draw(st.sampled_from([1e-2, 1e-3, 1e-4, 1e-5]))
    return x, eb


@given(field_and_eb())
@settings(**SET)
def test_error_bound_invariant(case):
    """|x - D(C(x))|_inf <= eb_abs with exact outliers ON (strict mode)."""
    x, eb = case
    cfg = fz.FZConfig(eb=eb, eb_mode="rel", exact_outliers=True, outlier_frac=1.0)
    rec, c = fz.roundtrip(jnp.asarray(x), cfg)
    eb_abs = float(c.eb_abs)
    assert float(metrics.max_abs_err(jnp.asarray(x), rec)) <= eb_abs * 1.001 + 1e-30


@given(field_and_eb())
@settings(**SET)
def test_compression_ratio_accounting(case):
    """used_bytes is positive, <= capacity bytes, and CR >= header-limited floor."""
    x, eb = case
    cfg = fz.FZConfig(eb=eb)
    c = fz.compress(jnp.asarray(x), cfg)
    used = int(c.used_bytes())
    assert used > 0
    assert int(c.nnz_blocks) <= fz.FZConfig.n_blocks(x.size)


@given(st.integers(0, 2**31 - 1), st.integers(1, 6))
@settings(**SET)
def test_bitshuffle_involution(seed, n_tiles):
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.integers(0, 1 << 16, size=n_tiles * shuffle.TILE, dtype=np.uint16))
    assert jnp.array_equal(shuffle.bitunshuffle(shuffle.bitshuffle(codes)), codes)


@given(st.integers(0, 2**31 - 1))
@settings(**SET)
def test_transpose16_is_involution(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(0, 1 << 16, size=(32, 16), dtype=np.uint16))
    assert jnp.array_equal(shuffle.transpose16(shuffle.transpose16(x)), x)


@given(st.integers(0, 2**31 - 1), st.floats(0.0, 0.9))
@settings(**SET)
def test_encoder_roundtrip_exact(seed, density):
    """encode/decode is lossless when capacity >= nnz (any sparsity)."""
    rng = np.random.default_rng(seed)
    words = rng.integers(0, 1 << 16, size=4096, dtype=np.uint16)
    mask = rng.random(4096 // 8) < density
    words = words.reshape(-1, 8) * mask[:, None]
    words = jnp.asarray(words.reshape(-1).astype(np.uint16))
    n_blocks = words.size // enc.BLOCK_WORDS
    bitflags, payload, nnz = enc.encode(words, capacity=n_blocks)
    dec = enc.decode(bitflags, payload, n_blocks=n_blocks)
    assert jnp.array_equal(dec, words)
    assert int(nnz) == int(jnp.sum(jnp.any(words.reshape(-1, 8) != 0, axis=1)))


@given(st.integers(0, 2**31 - 1))
@settings(**SET)
def test_lorenzo_inverse_exact(seed):
    rng = np.random.default_rng(seed)
    for shape in [(100,), (17, 23), (5, 7, 11)]:
        q = jnp.asarray(rng.integers(-1000, 1000, size=shape, dtype=np.int32))
        assert jnp.array_equal(quant.lorenzo_inverse(quant.lorenzo_delta(q)), q)


@given(st.integers(0, 2**31 - 1), st.sampled_from(["sign_mag", "zigzag"]))
@settings(**SET)
def test_code_roundtrip(seed, mode):
    rng = np.random.default_rng(seed)
    d = jnp.asarray(rng.integers(-32767, 32768, size=1000, dtype=np.int32))
    codes, over, resid = quant.to_codes(d, code_mode=mode)
    assert not bool(jnp.any(over))
    assert bool(jnp.all(resid == 0))
    assert jnp.array_equal(quant.from_codes(codes, code_mode=mode), d)


@given(st.integers(0, 2**31 - 1))
@settings(**SET)
def test_monotone_ratio_in_eb(seed):
    """Looser error bounds never compress worse (same data)."""
    rng = np.random.default_rng(seed)
    x = np.cumsum(rng.standard_normal((64, 64)).astype(np.float32), axis=0)
    crs = []
    for eb in (1e-4, 1e-3, 1e-2):
        c = fz.compress(jnp.asarray(x), fz.FZConfig(eb=eb))
        crs.append(float(c.compression_ratio()))
    assert crs[0] <= crs[1] * 1.01 and crs[1] <= crs[2] * 1.01, crs


def test_paper_mode_matches_strict_when_no_outliers():
    rng = np.random.default_rng(0)
    x = jnp.asarray((np.cumsum(rng.standard_normal(30_000)) * 0.01).astype(np.float32))
    strict = fz.FZConfig(eb=1e-3, exact_outliers=True)
    paper = fz.FZConfig(eb=1e-3, exact_outliers=False)
    rs, cs = fz.roundtrip(x, strict)
    rp, cp = fz.roundtrip(x, paper)
    assert int(cs.n_outliers) == 0
    assert jnp.array_equal(rs, rp)
