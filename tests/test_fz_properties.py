"""Property-based tests for the FZ pipeline invariants.

Two tiers share one set of checkers:
  * hypothesis-driven search when the wheel is available;
  * a seeded ``np.random`` parametrized fallback that always runs, so the
    round-trip / error-bound properties are exercised even in hermetic
    (no-network) environments where ``hypothesis`` cannot be installed.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import encode as enc
from repro.core import fz, metrics, quant, shuffle

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # hermetic box: the seeded fallback tier below still runs
    HAVE_HYPOTHESIS = False

SET = dict(max_examples=25, deadline=None)
KINDS = ("normal", "smooth", "constant", "zeros")
EBS = (1e-2, 1e-3, 1e-4, 1e-5)


def make_array(seed: int, kind: str, dims) -> np.ndarray:
    rng = np.random.default_rng(seed)
    if kind == "normal":
        x = rng.standard_normal(dims)
    elif kind == "smooth":
        x = rng.standard_normal(dims)
        for ax in range(len(dims)):
            x = np.cumsum(x, axis=ax) * 0.1
    elif kind == "constant":
        x = np.full(dims, rng.uniform(-100, 100))
    else:
        x = np.zeros(dims)
    return x.astype(np.float32)


# ---------------------------------------------------------------------------
# Checkers (shared by both tiers)
# ---------------------------------------------------------------------------

def check_error_bound_invariant(x: np.ndarray, eb: float) -> None:
    """|x - D(C(x))|_inf <= eb_abs with exact outliers ON (strict mode).

    The bound is exact in real arithmetic; the float32 reconstruction
    ``code * 2eb`` adds up to ~|x|_inf * 2^-22 of rounding noise on top
    (visible at tight bounds on O(1) data, e.g. eb=1e-5 on |x| ~ 4 — found
    by the property search once it actually ran), so the tolerance carries
    an explicit f32-rounding allowance rather than a magic slack factor.
    """
    cfg = fz.FZConfig(eb=eb, eb_mode="rel", exact_outliers=True, outlier_frac=1.0)
    rec, c = fz.roundtrip(jnp.asarray(x), cfg)
    eb_abs = float(c.eb_abs)
    f32_round = float(np.max(np.abs(x), initial=0.0)) * 2.0 ** -22
    assert float(metrics.max_abs_err(jnp.asarray(x), rec)) \
        <= eb_abs * 1.001 + f32_round + 1e-30


def check_compression_ratio_accounting(x: np.ndarray, eb: float) -> None:
    """used_bytes is positive and nnz never exceeds the block count."""
    cfg = fz.FZConfig(eb=eb)
    c = fz.compress(jnp.asarray(x), cfg)
    used = int(c.used_bytes())
    assert used > 0
    assert int(c.nnz_blocks) <= fz.FZConfig.n_blocks(x.size)


def check_bitshuffle_involution(seed: int, n_tiles: int) -> None:
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.integers(0, 1 << 16, size=n_tiles * shuffle.TILE,
                                     dtype=np.uint16))
    assert jnp.array_equal(shuffle.bitunshuffle(shuffle.bitshuffle(codes)), codes)


def check_transpose16_involution(seed: int) -> None:
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(0, 1 << 16, size=(32, 16), dtype=np.uint16))
    assert jnp.array_equal(shuffle.transpose16(shuffle.transpose16(x)), x)


def check_encoder_roundtrip_exact(seed: int, density: float) -> None:
    """encode/decode is lossless when capacity >= nnz (any sparsity)."""
    rng = np.random.default_rng(seed)
    words = rng.integers(0, 1 << 16, size=4096, dtype=np.uint16)
    mask = rng.random(4096 // 8) < density
    words = words.reshape(-1, 8) * mask[:, None]
    words = jnp.asarray(words.reshape(-1).astype(np.uint16))
    n_blocks = words.size // enc.BLOCK_WORDS
    bitflags, payload, nnz = enc.encode(words, capacity=n_blocks)
    dec = enc.decode(bitflags, payload, n_blocks=n_blocks)
    assert jnp.array_equal(dec, words)
    assert int(nnz) == int(jnp.sum(jnp.any(words.reshape(-1, 8) != 0, axis=1)))


def check_lorenzo_inverse_exact(seed: int) -> None:
    rng = np.random.default_rng(seed)
    for shape in [(100,), (17, 23), (5, 7, 11)]:
        q = jnp.asarray(rng.integers(-1000, 1000, size=shape, dtype=np.int32))
        assert jnp.array_equal(quant.lorenzo_inverse(quant.lorenzo_delta(q)), q)


def check_code_roundtrip(seed: int, mode: str) -> None:
    rng = np.random.default_rng(seed)
    d = jnp.asarray(rng.integers(-32767, 32768, size=1000, dtype=np.int32))
    codes, over, resid = quant.to_codes(d, code_mode=mode)
    assert not bool(jnp.any(over))
    assert bool(jnp.all(resid == 0))
    assert jnp.array_equal(quant.from_codes(codes, code_mode=mode), d)


def check_monotone_ratio_in_eb(seed: int) -> None:
    """Looser error bounds never compress worse (same data)."""
    rng = np.random.default_rng(seed)
    x = np.cumsum(rng.standard_normal((64, 64)).astype(np.float32), axis=0)
    crs = []
    for eb in (1e-4, 1e-3, 1e-2):
        c = fz.compress(jnp.asarray(x), fz.FZConfig(eb=eb))
        crs.append(float(c.compression_ratio()))
    assert crs[0] <= crs[1] * 1.01 and crs[1] <= crs[2] * 1.01, crs


# ---------------------------------------------------------------------------
# Tier 1: hypothesis-driven search (skipped wholesale when unavailable)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    def arrays(draw, max_elems=20_000):
        ndim = draw(st.integers(1, 3))
        dims = draw(st.lists(st.integers(1, 40), min_size=ndim, max_size=ndim))
        n = int(np.prod(dims))
        if n > max_elems:
            dims = [min(d, 16) for d in dims]
        seed = draw(st.integers(0, 2**31 - 1))
        kind = draw(st.sampled_from(list(KINDS)))
        return make_array(seed, kind, dims)

    @st.composite
    def field_and_eb(draw):
        return arrays(draw), draw(st.sampled_from(list(EBS)))

    @given(field_and_eb())
    @settings(**SET)
    def test_error_bound_invariant(case):
        check_error_bound_invariant(*case)

    @given(field_and_eb())
    @settings(**SET)
    def test_compression_ratio_accounting(case):
        check_compression_ratio_accounting(*case)

    @given(st.integers(0, 2**31 - 1), st.integers(1, 6))
    @settings(**SET)
    def test_bitshuffle_involution(seed, n_tiles):
        check_bitshuffle_involution(seed, n_tiles)

    @given(st.integers(0, 2**31 - 1))
    @settings(**SET)
    def test_transpose16_is_involution(seed):
        check_transpose16_involution(seed)

    @given(st.integers(0, 2**31 - 1), st.floats(0.0, 0.9))
    @settings(**SET)
    def test_encoder_roundtrip_exact(seed, density):
        check_encoder_roundtrip_exact(seed, density)

    @given(st.integers(0, 2**31 - 1))
    @settings(**SET)
    def test_lorenzo_inverse_exact(seed):
        check_lorenzo_inverse_exact(seed)

    @given(st.integers(0, 2**31 - 1), st.sampled_from(["sign_mag", "zigzag"]))
    @settings(**SET)
    def test_code_roundtrip(seed, mode):
        check_code_roundtrip(seed, mode)

    @given(st.integers(0, 2**31 - 1))
    @settings(**SET)
    def test_monotone_ratio_in_eb(seed):
        check_monotone_ratio_in_eb(seed)


def test_importorskip_guard():
    """Document the dependency: everything above this line must not require
    hypothesis at collection time; this canary is the only test that does."""
    pytest.importorskip("hypothesis")


# ---------------------------------------------------------------------------
# Tier 2: seeded np.random fallback (always runs; fixed case matrix)
# ---------------------------------------------------------------------------

_FALLBACK_CASES = [
    (seed, kind, dims, eb)
    for seed, (kind, dims, eb) in enumerate([
        ("normal", (40,), 1e-3), ("normal", (17, 23), 1e-4),
        ("smooth", (20_000,), 1e-4), ("smooth", (64, 64), 1e-5),
        ("smooth", (16, 16, 16), 1e-3), ("constant", (7, 11), 1e-2),
        ("zeros", (33,), 1e-3), ("normal", (5, 7, 11), 1e-2),
    ])
]


@pytest.mark.parametrize("seed,kind,dims,eb", _FALLBACK_CASES)
def test_error_bound_invariant_seeded(seed, kind, dims, eb):
    check_error_bound_invariant(make_array(seed, kind, list(dims)), eb)


@pytest.mark.parametrize("seed,kind,dims,eb", _FALLBACK_CASES)
def test_compression_ratio_accounting_seeded(seed, kind, dims, eb):
    check_compression_ratio_accounting(make_array(seed, kind, list(dims)), eb)


@pytest.mark.parametrize("seed,n_tiles", [(0, 1), (1, 3), (2, 6)])
def test_bitshuffle_involution_seeded(seed, n_tiles):
    check_bitshuffle_involution(seed, n_tiles)


@pytest.mark.parametrize("seed", range(3))
def test_transpose16_is_involution_seeded(seed):
    check_transpose16_involution(seed)


@pytest.mark.parametrize("seed,density", [(0, 0.0), (1, 0.3), (2, 0.9)])
def test_encoder_roundtrip_exact_seeded(seed, density):
    check_encoder_roundtrip_exact(seed, density)


@pytest.mark.parametrize("seed", range(3))
def test_lorenzo_inverse_exact_seeded(seed):
    check_lorenzo_inverse_exact(seed)


@pytest.mark.parametrize("seed", range(2))
@pytest.mark.parametrize("mode", ["sign_mag", "zigzag"])
def test_code_roundtrip_seeded(seed, mode):
    check_code_roundtrip(seed, mode)


@pytest.mark.parametrize("seed", range(2))
def test_monotone_ratio_in_eb_seeded(seed):
    check_monotone_ratio_in_eb(seed)


def test_paper_mode_matches_strict_when_no_outliers():
    rng = np.random.default_rng(0)
    x = jnp.asarray((np.cumsum(rng.standard_normal(30_000)) * 0.01).astype(np.float32))
    strict = fz.FZConfig(eb=1e-3, exact_outliers=True)
    paper = fz.FZConfig(eb=1e-3, exact_outliers=False)
    rs, cs = fz.roundtrip(x, strict)
    rp, cp = fz.roundtrip(x, paper)
    assert int(cs.n_outliers) == 0
    assert jnp.array_equal(rs, rp)
