"""Property-based tests for the FZ pipeline invariants.

Two tiers share one set of checkers:
  * hypothesis-driven search when the wheel is available;
  * a seeded ``np.random`` parametrized fallback that always runs, so the
    round-trip / error-bound properties are exercised even in hermetic
    (no-network) environments where ``hypothesis`` cannot be installed.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import encode as enc
from repro.core import fz, metrics, quant, shuffle

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # hermetic box: the seeded fallback tier below still runs
    HAVE_HYPOTHESIS = False

SET = dict(max_examples=25, deadline=None)
KINDS = ("normal", "smooth", "constant", "zeros")
EBS = (1e-2, 1e-3, 1e-4, 1e-5)


def make_array(seed: int, kind: str, dims) -> np.ndarray:
    rng = np.random.default_rng(seed)
    if kind == "normal":
        x = rng.standard_normal(dims)
    elif kind == "smooth":
        x = rng.standard_normal(dims)
        for ax in range(len(dims)):
            x = np.cumsum(x, axis=ax) * 0.1
    elif kind == "constant":
        x = np.full(dims, rng.uniform(-100, 100))
    else:
        x = np.zeros(dims)
    return x.astype(np.float32)


# ---------------------------------------------------------------------------
# Checkers (shared by both tiers)
# ---------------------------------------------------------------------------

def check_error_bound_invariant(x: np.ndarray, eb: float) -> None:
    """|x - D(C(x))|_inf <= eb_abs with exact outliers ON (strict mode).

    The bound is exact in real arithmetic; the float32 reconstruction
    ``code * 2eb`` adds up to ~|x|_inf * 2^-22 of rounding noise on top
    (visible at tight bounds on O(1) data, e.g. eb=1e-5 on |x| ~ 4 — found
    by the property search once it actually ran), so the tolerance carries
    an explicit f32-rounding allowance rather than a magic slack factor.
    """
    cfg = fz.FZConfig(eb=eb, eb_mode="rel", exact_outliers=True, outlier_frac=1.0)
    rec, c = fz.roundtrip(jnp.asarray(x), cfg)
    eb_abs = float(c.eb_abs)
    f32_round = float(np.max(np.abs(x), initial=0.0)) * 2.0 ** -22
    assert float(metrics.max_abs_err(jnp.asarray(x), rec)) \
        <= eb_abs * 1.001 + f32_round + 1e-30


def check_compression_ratio_accounting(x: np.ndarray, eb: float) -> None:
    """used_bytes is positive and nnz never exceeds the block count."""
    cfg = fz.FZConfig(eb=eb)
    c = fz.compress(jnp.asarray(x), cfg)
    used = int(c.used_bytes())
    assert used > 0
    assert int(c.nnz_blocks) <= fz.FZConfig.n_blocks(x.size)


def check_bitshuffle_involution(seed: int, n_tiles: int) -> None:
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.integers(0, 1 << 16, size=n_tiles * shuffle.TILE,
                                     dtype=np.uint16))
    assert jnp.array_equal(shuffle.bitunshuffle(shuffle.bitshuffle(codes)), codes)


def check_transpose16_involution(seed: int) -> None:
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(0, 1 << 16, size=(32, 16), dtype=np.uint16))
    assert jnp.array_equal(shuffle.transpose16(shuffle.transpose16(x)), x)


def check_encoder_roundtrip_exact(seed: int, density: float) -> None:
    """encode/decode is lossless when capacity >= nnz (any sparsity)."""
    rng = np.random.default_rng(seed)
    words = rng.integers(0, 1 << 16, size=4096, dtype=np.uint16)
    mask = rng.random(4096 // 8) < density
    words = words.reshape(-1, 8) * mask[:, None]
    words = jnp.asarray(words.reshape(-1).astype(np.uint16))
    n_blocks = words.size // enc.BLOCK_WORDS
    bitflags, payload, nnz = enc.encode(words, capacity=n_blocks)
    dec = enc.decode(bitflags, payload, n_blocks=n_blocks)
    assert jnp.array_equal(dec, words)
    assert int(nnz) == int(jnp.sum(jnp.any(words.reshape(-1, 8) != 0, axis=1)))


def check_lorenzo_inverse_exact(seed: int) -> None:
    rng = np.random.default_rng(seed)
    for shape in [(100,), (17, 23), (5, 7, 11)]:
        q = jnp.asarray(rng.integers(-1000, 1000, size=shape, dtype=np.int32))
        assert jnp.array_equal(quant.lorenzo_inverse(quant.lorenzo_delta(q)), q)


def check_code_roundtrip(seed: int, mode: str) -> None:
    rng = np.random.default_rng(seed)
    d = jnp.asarray(rng.integers(-32767, 32768, size=1000, dtype=np.int32))
    codes, over, resid = quant.to_codes(d, code_mode=mode)
    assert not bool(jnp.any(over))
    assert bool(jnp.all(resid == 0))
    assert jnp.array_equal(quant.from_codes(codes, code_mode=mode), d)


def check_monotone_ratio_in_eb(seed: int) -> None:
    """Looser error bounds never compress worse (same data)."""
    rng = np.random.default_rng(seed)
    x = np.cumsum(rng.standard_normal((64, 64)).astype(np.float32), axis=0)
    crs = []
    for eb in (1e-4, 1e-3, 1e-2):
        c = fz.compress(jnp.asarray(x), fz.FZConfig(eb=eb))
        crs.append(float(c.compression_ratio()))
    assert crs[0] <= crs[1] * 1.01 and crs[1] <= crs[2] * 1.01, crs


def _three_way_cfgs(code_mode: str, eb: float, eb_mode: str = "rel"):
    base = dict(eb=eb, eb_mode=eb_mode, code_mode=code_mode,
                exact_outliers=False)
    return {"reference": fz.FZConfig(**base),
            "staged": fz.FZConfig(**base, use_kernels=True,
                                  kernel_mode="staged"),
            "fused": fz.FZConfig(**base, use_kernels=True,
                                 kernel_mode="fused")}


def check_three_way_bit_identity(x: np.ndarray, eb: float,
                                 code_mode: str = "sign_mag") -> None:
    """fused == staged == reference: bitflags, payload, nnz AND roundtrip are
    bit-identical across the three execution paths on the same data."""
    data = jnp.asarray(x)
    outs = {name: fz.roundtrip(data, cfg)
            for name, cfg in _three_way_cfgs(code_mode, eb).items()}
    rec0, c0 = outs["reference"]
    for name in ("staged", "fused"):
        rec, c = outs[name]
        assert jnp.array_equal(c0.bitflags, c.bitflags), name
        assert jnp.array_equal(c0.payload, c.payload), name
        assert int(c0.nnz_blocks) == int(c.nnz_blocks), name
        assert jnp.array_equal(rec0, rec), name


def check_three_way_shared_eb_vmap(seed: int, page_shape, eb_abs: float,
                                   code_mode: str = "sign_mag") -> None:
    """compress_with_eb pages under vmap (the kvpool batched dispatch): all
    three paths produce bit-identical stacked containers, and each path's
    vmapped dispatch is bit-identical to its own single-page calls."""
    rng = np.random.default_rng(seed)
    pages = jnp.asarray(np.cumsum(
        rng.standard_normal((3, *page_shape)), axis=-1).astype(np.float32))
    eb = jnp.float32(eb_abs)
    stacked = {}
    for name, cfg in _three_way_cfgs(code_mode, 1.0, eb_mode="abs").items():
        batched = jax.vmap(lambda d: fz.compress_with_eb(d, eb, cfg))(pages)
        singles = [fz.compress_with_eb(pages[i], eb, cfg) for i in range(3)]
        for i, s in enumerate(singles):
            assert jnp.array_equal(batched.bitflags[i], s.bitflags), name
            assert jnp.array_equal(batched.payload[i], s.payload), name
        recs = jax.vmap(lambda c: fz.decompress(c, cfg))(batched)
        for i, s in enumerate(singles):
            assert jnp.array_equal(recs[i], fz.decompress(s, cfg)), name
        stacked[name] = (batched, recs)
    b0, r0 = stacked["reference"]
    for name in ("staged", "fused"):
        b, r = stacked[name]
        assert jnp.array_equal(b0.bitflags, b.bitflags), name
        assert jnp.array_equal(b0.payload, b.payload), name
        assert jnp.array_equal(r0, r), name


# ---------------------------------------------------------------------------
# Tier 1: hypothesis-driven search (skipped wholesale when unavailable)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    def arrays(draw, max_elems=20_000):
        ndim = draw(st.integers(1, 3))
        dims = draw(st.lists(st.integers(1, 40), min_size=ndim, max_size=ndim))
        n = int(np.prod(dims))
        if n > max_elems:
            dims = [min(d, 16) for d in dims]
        seed = draw(st.integers(0, 2**31 - 1))
        kind = draw(st.sampled_from(list(KINDS)))
        return make_array(seed, kind, dims)

    @st.composite
    def field_and_eb(draw):
        return arrays(draw), draw(st.sampled_from(list(EBS)))

    @given(field_and_eb())
    @settings(**SET)
    def test_error_bound_invariant(case):
        check_error_bound_invariant(*case)

    @given(field_and_eb())
    @settings(**SET)
    def test_compression_ratio_accounting(case):
        check_compression_ratio_accounting(*case)

    @given(st.integers(0, 2**31 - 1), st.integers(1, 6))
    @settings(**SET)
    def test_bitshuffle_involution(seed, n_tiles):
        check_bitshuffle_involution(seed, n_tiles)

    @given(st.integers(0, 2**31 - 1))
    @settings(**SET)
    def test_transpose16_is_involution(seed):
        check_transpose16_involution(seed)

    @given(st.integers(0, 2**31 - 1), st.floats(0.0, 0.9))
    @settings(**SET)
    def test_encoder_roundtrip_exact(seed, density):
        check_encoder_roundtrip_exact(seed, density)

    @given(st.integers(0, 2**31 - 1))
    @settings(**SET)
    def test_lorenzo_inverse_exact(seed):
        check_lorenzo_inverse_exact(seed)

    @given(st.integers(0, 2**31 - 1), st.sampled_from(["sign_mag", "zigzag"]))
    @settings(**SET)
    def test_code_roundtrip(seed, mode):
        check_code_roundtrip(seed, mode)

    @given(st.integers(0, 2**31 - 1))
    @settings(**SET)
    def test_monotone_ratio_in_eb(seed):
        check_monotone_ratio_in_eb(seed)

    @st.composite
    def field_eb_mode(draw):
        # three Pallas compiles per example: fewer, fatter cases
        return (arrays(draw, max_elems=12_000),
                draw(st.sampled_from([1e-2, 1e-3, 1e-4])),
                draw(st.sampled_from(["sign_mag", "zigzag"])))

    @given(field_eb_mode())
    @settings(max_examples=10, deadline=None)
    def test_three_way_bit_identity(case):
        check_three_way_bit_identity(*case)


def test_importorskip_guard():
    """Document the dependency: everything above this line must not require
    hypothesis at collection time; this canary is the only test that does."""
    pytest.importorskip("hypothesis")


# ---------------------------------------------------------------------------
# Tier 2: seeded np.random fallback (always runs; fixed case matrix)
# ---------------------------------------------------------------------------

_FALLBACK_CASES = [
    (seed, kind, dims, eb)
    for seed, (kind, dims, eb) in enumerate([
        ("normal", (40,), 1e-3), ("normal", (17, 23), 1e-4),
        ("smooth", (20_000,), 1e-4), ("smooth", (64, 64), 1e-5),
        ("smooth", (16, 16, 16), 1e-3), ("constant", (7, 11), 1e-2),
        ("zeros", (33,), 1e-3), ("normal", (5, 7, 11), 1e-2),
    ])
]


@pytest.mark.parametrize("seed,kind,dims,eb", _FALLBACK_CASES)
def test_error_bound_invariant_seeded(seed, kind, dims, eb):
    check_error_bound_invariant(make_array(seed, kind, list(dims)), eb)


@pytest.mark.parametrize("seed,kind,dims,eb", _FALLBACK_CASES)
def test_compression_ratio_accounting_seeded(seed, kind, dims, eb):
    check_compression_ratio_accounting(make_array(seed, kind, list(dims)), eb)


@pytest.mark.parametrize("seed,n_tiles", [(0, 1), (1, 3), (2, 6)])
def test_bitshuffle_involution_seeded(seed, n_tiles):
    check_bitshuffle_involution(seed, n_tiles)


@pytest.mark.parametrize("seed", range(3))
def test_transpose16_is_involution_seeded(seed):
    check_transpose16_involution(seed)


@pytest.mark.parametrize("seed,density", [(0, 0.0), (1, 0.3), (2, 0.9)])
def test_encoder_roundtrip_exact_seeded(seed, density):
    check_encoder_roundtrip_exact(seed, density)


@pytest.mark.parametrize("seed", range(3))
def test_lorenzo_inverse_exact_seeded(seed):
    check_lorenzo_inverse_exact(seed)


@pytest.mark.parametrize("seed", range(2))
@pytest.mark.parametrize("mode", ["sign_mag", "zigzag"])
def test_code_roundtrip_seeded(seed, mode):
    check_code_roundtrip(seed, mode)


@pytest.mark.parametrize("seed", range(2))
def test_monotone_ratio_in_eb_seeded(seed):
    check_monotone_ratio_in_eb(seed)


# three-way fused == staged == reference: 1/2/3D, non-tile-multiple sizes,
# both code modes (the full kernel_mode matrix of core/fz.py)
_THREE_WAY_CASES = [
    ("normal", (40,), 1e-3, "sign_mag"),          # sub-tile 1D
    ("smooth", (10_001,), 1e-4, "sign_mag"),      # non-tile-multiple 1D
    ("smooth", (10_001,), 1e-4, "zigzag"),
    ("smooth", (17, 23), 1e-3, "sign_mag"),       # tiny odd 2D
    ("smooth", (33, 1000), 1e-4, "zigzag"),       # tile-straddling rows
    ("normal", (64, 64), 1e-2, "sign_mag"),       # exactly one tile
    ("smooth", (16, 16, 16), 1e-3, "sign_mag"),   # 3D
    ("normal", (5, 7, 11), 1e-2, "zigzag"),       # tiny odd 3D
    ("zeros", (4096,), 1e-3, "sign_mag"),         # all-zero stream
    ("constant", (7, 11), 1e-2, "sign_mag"),
]


@pytest.mark.parametrize("kind,dims,eb,code_mode", _THREE_WAY_CASES)
def test_three_way_bit_identity_seeded(kind, dims, eb, code_mode):
    check_three_way_bit_identity(make_array(0, kind, list(dims)), eb,
                                 code_mode)


@pytest.mark.parametrize("page_shape,code_mode",
                         [((8192,), "sign_mag"), ((4, 2048), "zigzag")])
def test_three_way_shared_eb_vmap_seeded(page_shape, code_mode):
    check_three_way_shared_eb_vmap(11, page_shape, 0.01, code_mode)


def test_paper_mode_matches_strict_when_no_outliers():
    rng = np.random.default_rng(0)
    x = jnp.asarray((np.cumsum(rng.standard_normal(30_000)) * 0.01).astype(np.float32))
    strict = fz.FZConfig(eb=1e-3, exact_outliers=True)
    paper = fz.FZConfig(eb=1e-3, exact_outliers=False)
    rs, cs = fz.roundtrip(x, strict)
    rp, cp = fz.roundtrip(x, paper)
    assert int(cs.n_outliers) == 0
    assert jnp.array_equal(rs, rp)
