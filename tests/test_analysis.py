"""repro.analysis: seeded-bad fixtures must flag; the real tree must be
clean modulo the committed baseline."""
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import carry, jitlint, report, resources
from repro.analysis.kernelspec import (BlockDecl, KernelSpec, ScratchDecl,
                                       probe_index_map, spec_builders)
from repro.kernels import lorenzo_quant as lq
from repro.kernels import ref

RNG = np.random.default_rng(7)


def _rules(findings):
    return sorted({f.rule for f in findings})


# ---------------------------------------------------------------------------
# Resource pass: footprint model units + seeded over-budget specs
# ---------------------------------------------------------------------------

def test_padded_bytes_tile_model():
    # f32: (8, 128) is one native tile
    assert resources.padded_bytes((8, 128), 4) == 8 * 128 * 4
    # a scalar-ish block still occupies a full tile
    assert resources.padded_bytes((1, 1), 4) == 8 * 128 * 4
    # rank-1 lives on the lane axis: (300,) -> (8, 384)
    assert resources.padded_bytes((300,), 4) == 8 * 384 * 4
    # u16 sublane count is 16: (1, 128) pads the sublane axis 1 -> 16
    assert resources.padded_bytes((1, 128), 2) == 16 * 128 * 2
    # SMEM is raw bytes, no tile padding
    assert resources.padded_bytes((4,), 4, memory="smem") == 16


def test_seeded_vmem_overflow_flagged():
    spec = KernelSpec(
        name="bad_vmem", module="tests", grid=(4,),
        in_blocks=(BlockDecl("big", (4096, 4096), "float32",
                             index_map=lambda i: (i, 0)),),
        out_blocks=(BlockDecl("o", (8, 128), "float32",
                              index_map=lambda i: (i, 0)),),
        point="fixture")
    findings = resources.analyze_spec(spec)
    assert "vmem-overflow" in _rules(findings)
    # double-buffered 64MiB block dominates
    assert any("big" in f.message for f in findings)


def test_seeded_smem_overflow_flagged():
    spec = KernelSpec(
        name="bad_smem", module="tests", grid=(2,),
        in_blocks=(BlockDecl("x", (8, 128), "float32",
                             index_map=lambda i: (i, 0)),),
        out_blocks=(BlockDecl("o", (8, 128), "float32",
                              index_map=lambda i: (i, 0)),),
        scratch=(ScratchDecl("s", (100_000,), "int32", "smem"),),
        point="fixture")
    assert "smem-overflow" in _rules(resources.analyze_spec(spec))


def test_seeded_lane_underfill_and_pad_waste_flagged():
    spec = KernelSpec(
        name="bad_lanes", module="tests", grid=(2,),
        in_blocks=(
            # 1MiB buffer with an 8-wide trailing axis: 16x lane padding
            BlockDecl("narrow", (65536, 8), "uint16",
                      index_map=lambda i: (0, 0)),
            # trailing axis full, but sublane padding 1 -> 8 inflates 8x
            BlockDecl("thin", (130, 1, 128), "float32",
                      index_map=lambda i: (i, 0, 0)),
        ),
        out_blocks=(BlockDecl("o", (8, 128), "float32",
                              index_map=lambda i: (i, 0)),),
        critical_lanes=(("kv_tile", 8),),
        point="fixture")
    findings = resources.analyze_spec(spec)
    objs = {f.obj for f in findings if f.rule == "lane-underfill"}
    assert "bad_lanes.narrow" in objs
    assert "bad_lanes.kv_tile" in objs          # declared critical dim < 128
    assert any(f.rule == "pad-waste" and f.obj == "bad_lanes.thin"
               for f in findings)


def test_within_budget_spec_is_clean():
    spec = KernelSpec(
        name="ok", module="tests", grid=(8,),
        in_blocks=(BlockDecl("x", (8, 128), "float32",
                             index_map=lambda i: (i, 0)),),
        out_blocks=(BlockDecl("o", (8, 128), "float32",
                              index_map=lambda i: (i, 0)),),
        dimension_semantics=("parallel",), point="fixture")
    assert resources.analyze_spec(spec) == []


def test_band_helpers_cross_check_clean():
    assert resources.check_band_helpers() == []


def test_band_for_is_dtype_aware():
    # at the budget frontier, halving itemsize doubles the band
    t = 1 << 20
    assert lq.band_for(t, itemsize=4) == 1
    assert lq.band_for(t, itemsize=2) == 2
    # small trailing dims clamp at MAX_BAND for every itemsize
    assert lq.band_for(64, itemsize=4) == lq.MAX_BAND
    assert lq.band_for(64, itemsize=2) == lq.MAX_BAND


# ---------------------------------------------------------------------------
# Carry pass: seeded carry-under-parallel + correctly-declared variants
# ---------------------------------------------------------------------------

def _carry_kernel(x_ref, o_ref, acc_ref):
    acc = acc_ref[...]                    # read before any write: a carry
    acc_ref[...] = acc + x_ref[...]
    o_ref[...] = acc_ref[...]


def _per_step_kernel(x_ref, o_ref, tmp_ref):
    tmp_ref[...] = x_ref[...] * 2         # unguarded write first: per-step
    o_ref[...] = tmp_ref[...]


def _guarded_carry_kernel(x_ref, o_ref, acc_ref):
    import jax.experimental.pallas as pl  # noqa: F401  (body is AST-only)
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)   # step-0 init, not a write

    acc_ref[...] += x_ref[...]            # read-modify-write: still a carry
    o_ref[...] = acc_ref[...]


def _spec_with(kernel_fn, semantics):
    return KernelSpec(
        name="fixture_kernel", module="tests", grid=(4,),
        in_blocks=(BlockDecl("x", (8, 128), "float32",
                             index_map=lambda i: (i, 0)),),
        out_blocks=(BlockDecl("o", (8, 128), "float32",
                              index_map=lambda i: (i, 0)),),
        scratch=(ScratchDecl("acc", (8, 128), "float32", "vmem"),),
        dimension_semantics=semantics, kernel_fn=kernel_fn, point="fixture")


def test_seeded_carry_under_parallel_flagged():
    findings = carry.analyze_spec(_spec_with(_carry_kernel, ("parallel",)))
    assert _rules(findings) == ["carry-under-parallel"]


def test_seeded_carry_without_semantics_flagged():
    findings = carry.analyze_spec(_spec_with(_carry_kernel, None))
    assert _rules(findings) == ["carry-default-semantics"]


def test_guarded_init_still_counts_as_carry():
    findings = carry.analyze_spec(
        _spec_with(_guarded_carry_kernel, ("parallel",)))
    assert "carry-under-parallel" in _rules(findings)


def test_carry_under_arbitrary_is_clean():
    assert carry.analyze_spec(_spec_with(_carry_kernel, ("arbitrary",))) == []


def test_per_step_scratch_allows_parallel():
    assert carry.analyze_spec(
        _spec_with(_per_step_kernel, ("parallel",))) == []


def test_per_step_scratch_missing_semantics_warns():
    findings = carry.analyze_spec(_spec_with(_per_step_kernel, None))
    assert _rules(findings) == ["missing-semantics"]
    assert all(f.severity == "warn" for f in findings)


def test_revisited_output_pins_only_ignored_axes():
    # flash-decode shape: out index map ignores the sequential axis 1
    def kernel(x_ref, o_ref):
        o_ref[...] += x_ref[...]

    spec = KernelSpec(
        name="revisit", module="tests", grid=(2, 4),
        in_blocks=(BlockDecl("x", (8, 128), "float32",
                             index_map=lambda b, t: (b, t)),),
        out_blocks=(BlockDecl("o", (8, 128), "float32",
                              index_map=lambda b, t: (b, 0)),),
        dimension_semantics=("parallel", "parallel"),
        kernel_fn=kernel, point="fixture")
    findings = carry.analyze_spec(spec)
    assert _rules(findings) == ["carry-under-parallel"]
    assert all("axis 1" in f.message for f in findings)
    spec_ok = KernelSpec(**{**spec.__dict__,
                            "dimension_semantics": ("parallel", "arbitrary")})
    assert carry.analyze_spec(spec_ok) == []


def test_star_refs_unpack_is_classified():
    def kernel(*refs):
        (x_ref, o_ref, acc_ref) = refs
        acc = acc_ref[...]
        acc_ref[...] = acc + x_ref[...]
        o_ref[...] = acc_ref[...]

    findings = carry.analyze_spec(_spec_with(kernel, ("parallel",)))
    assert _rules(findings) == ["carry-under-parallel"]


# ---------------------------------------------------------------------------
# jit-discipline linter: seeded bad sources through lint_source
# ---------------------------------------------------------------------------

def _lint(src, **kw):
    return jitlint.lint_source(textwrap.dedent(src), "fixture.py", **kw)


def test_seeded_traced_branch_flagged():
    findings = _lint("""
        import jax

        @jax.jit
        def f(x):
            if x > 0:
                return x
            return -x
    """)
    assert _rules(findings) == ["traced-branch"]


def test_traced_branch_in_kernel_body_flagged():
    findings = _lint("""
        def kernel(x_ref, o_ref):
            while x_ref[0] > 0:
                o_ref[...] = 1
    """)
    assert _rules(findings) == ["traced-branch"]


def test_static_branches_are_exempt():
    findings = _lint("""
        import functools
        import jax

        @functools.partial(jax.jit, static_argnames=("mode",))
        def f(x, plan, mode="a"):
            if mode == "b":                  # static_argnames param
                return x
            if x is None:                    # None-ness is trace-static
                return plan
            if x.shape[0] > 2:               # array metadata
                return x
            if plan.kern_nd == 1:            # config-dataclass attribute
                return x
            return x
    """)
    assert findings == []


def test_seeded_host_calls_flagged():
    findings = _lint("""
        import numpy as np
        import jax

        @jax.jit
        def f(x):
            y = np.sum(x)
            z = float(x)
            w = x.item()
            return y + z + w
    """)
    assert _rules(findings) == ["host-call"]
    assert len(findings) == 3


def test_seeded_eager_obs_in_trace_flagged():
    findings = _lint("""
        import jax
        from repro import obs

        @jax.jit
        def f(x):
            obs.counter("fz.dispatch")
            with obs.span("fz.encode"):      # span is trace-safe: allowed
                return x
    """)
    assert _rules(findings) == ["eager-obs-in-trace"]


def test_seeded_unknown_static_arg_flagged():
    findings = _lint("""
        import jax
        from functools import partial

        @partial(jax.jit, static_argnames=("shap",))
        def f(x, shape):
            return x
    """)
    assert _rules(findings) == ["unknown-static-arg"]


def test_seeded_unhashable_static_arg_flagged():
    findings = _lint("""
        import jax
        from functools import partial

        @partial(jax.jit, static_argnames=("dims",))
        def f(x, dims=[1, 2]):
            return x
    """)
    assert _rules(findings) == ["unhashable-static-arg"]


def test_unjitted_python_is_not_linted():
    findings = _lint("""
        import numpy as np

        def f(x):
            if x > 0:
                return np.sum(x)
            return float(x)
    """)
    assert findings == []


def test_style_unused_import_and_noqa():
    findings = _lint("""
        from __future__ import annotations
        import os
        import sys  # noqa: F401
        import json

        def f():
            return json.dumps({})
    """, style=True)
    assert _rules(findings) == ["unused-import"]
    assert len(findings) == 1 and ":os" in findings[0].obj


# ---------------------------------------------------------------------------
# Real tree: clean modulo the committed baseline; specs cover every site
# ---------------------------------------------------------------------------

def test_real_tree_clean_modulo_baseline():
    rep = report.run_all()
    assert rep.clean, "new findings:\n" + rep.render_text()
    assert rep.stale == [], f"stale baseline entries: {rep.stale}"


def test_every_kernel_site_registers_a_spec():
    import repro.kernels  # noqa: F401  (importing populates the registry)
    assert set(spec_builders()) >= {
        "lorenzo_quant", "bitshuffle_flag.shuffle", "bitshuffle_flag.unshuffle",
        "flash_decode", "fused_compress", "fused_shuffle_encode",
        "fused_decode"}


def test_probe_index_map_classifies_axes():
    ignored, varies = probe_index_map(lambda b, t: (b, 0), (2, 4))
    assert ignored == (1,) and varies
    ignored, varies = probe_index_map(lambda i: (0, 0), (4,))
    assert ignored == (0,) and not varies


# ---------------------------------------------------------------------------
# Satellite: bf16 inputs stay native through the standalone quantizer
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(4096,), (33, 100)])
def test_lorenzo_quant_bf16_matches_f32_reference(shape):
    x = jnp.asarray(RNG.standard_normal(shape), jnp.bfloat16)
    k = lq.lorenzo_quant(x, jnp.float32(1e-2), interpret=True)
    r = ref.lorenzo_quant_ref(x, jnp.float32(1e-2))
    np.testing.assert_array_equal(np.asarray(k), np.asarray(r))
