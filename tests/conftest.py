"""Suite-wide conftest: optional-dependency shims.

The property tier (tests/test_fz_properties.py, tests/test_decode_properties.py)
prefers the real ``hypothesis`` wheel — CI installs it via the pyproject
``[test]`` extra. Hermetic environments (no network) fall back to the bundled
``repro.testing.minihypothesis`` shim: the same API subset driven by seeded
random search, so the property tests run everywhere instead of silently
skipping.
"""
try:
    import hypothesis  # noqa: F401
except ImportError:
    from repro.testing import minihypothesis
    minihypothesis.install()
