"""Paper Figure 10: per-kernel effect of the proposed optimizations.

Wall-clock per stage pair (relative speedups are the claim; absolute GB/s
needs the target TPU — on CPU the Pallas kernels run under the interpreter,
on TPU the same calls lower to Mosaic because every kernel variant routes
through the shared backend check ``repro.kernels.ops.backend_interpret()``
instead of hardcoding interpret mode). Version pairs mirror the paper's bars:

  pred-quant-v1     dual-quantization with the cuSZ-style outlier side path
  pred-quant-v2     optimized: branch-free saturating codes (paper §3.2)
  shuffle-mark-v1   bitshuffle and zero-flagging as two passes
  shuffle-mark-v2   fused single pass (paper §3.4 fusion)
  encode-v1/v2      phase-2 encode fed by v1 vs v2 quantization (the v2
                    codes produce fewer non-zero blocks -> faster compaction)

Beyond the paper's bars, the staged-vs-fused section times the three whole
execution paths (reference / staged kernels / single-launch megakernels) in
both directions, with two traffic columns:

  * ``hbm_model_bytes`` — analytic per-variant HBM traffic: input + outputs
    plus 4 bytes/elem for every u16 stream a staged pipeline round-trips
    (write + read of codes, then of shuffled words) and 8 bytes/elem for the
    reference path's int32 pre-quant stream. The fused megakernels' model is
    exactly input + outputs: their streams live in VMEM.
  * ``measured_traffic`` — ``hlo_cost.compiled_memory_traffic`` ratio of the
    actually-compiled program ((args + outs + 2*temps) / (args + outs)).
    Honest on TPU; under the CPU interpreter the megakernels' loop carries
    inflate their compress-side temps (see the helper's docstring), so the
    analytic column is the claim and this one is the measurement floor.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import encode as enc
from repro.core import fz, quant, shuffle
from repro.data import make_field
from repro.kernels import ops as kops
from repro.launch import hlo_cost
from .common import FZ_PATHS, fz_path_config, gbps, timeit


def _pad_flat(codes):
    return shuffle.pad_to_tiles(codes.reshape(-1))


def hbm_model_bytes(path: str, direction: str, n: int, out_bytes: int) -> int:
    """Analytic HBM bytes for one (path, direction) variant on an n-element
    f32 field. Streams are u16 (2 bytes/elem); a round trip costs a write
    plus a read (4 bytes/elem)."""
    io = 4 * n + out_bytes                   # float field + container, 1 pass
    if path == "fused":
        return io
    streams = 2 * 4 * n                      # codes + shuffled words (u16 rt)
    if path == "reference" and direction == "compress":
        streams += 8 * n                     # int32 pre-quant stream as well
    return io + streams


def run(shape=(128, 128, 64), eb=1e-3, smoke=False):
    if smoke:
        shape = (32, 64, 32)
    f = jnp.asarray(make_field("smooth", shape, seed=3))
    rng = float(jnp.max(f) - jnp.min(f))
    eb_abs = jnp.float32(eb * rng)
    nbytes = f.size * 4
    rows = []

    def add(name, secs, bytes_moved, hbm_model=None, measured=None):
        rows.append({"name": name, "us": secs * 1e6,
                     "gbps": gbps(bytes_moved, secs),
                     "hbm_model_bytes": hbm_model,
                     "measured_traffic": measured})

    # ---- pred-quant v1 (outlier path) vs v2 (branch-free saturating)
    q_v1 = jax.jit(lambda x: quant.dual_quantize(
        x, eb_abs, outlier_capacity=max(1, f.size // 64))[0])
    q_v2 = jax.jit(lambda x: quant.dual_quantize(x, eb_abs, outlier_capacity=0)[0])
    add("pred-quant-v1", timeit(q_v1, f), nbytes)
    add("pred-quant-v2", timeit(q_v2, f), nbytes)

    codes = _pad_flat(q_v2(f))
    n_blocks = codes.size // enc.BLOCK_WORDS

    # ---- bitshuffle+mark: two passes vs fused (real lowering on TPU)
    def v1(c):
        sh = shuffle.bitshuffle(c)
        return sh, enc.block_flags(sh)

    def v2(c):
        from repro.kernels import bitshuffle_flag as bsf
        return bsf.bitshuffle_flag(c.reshape(-1, shuffle.TILE),
                                   interpret=kops.backend_interpret())

    add("bitshuffle-mark-v1", timeit(jax.jit(v1), codes), 2 * codes.size)
    add("bitshuffle-mark-v2-fused", timeit(jax.jit(v2), codes), 2 * codes.size)

    # ---- encode phase 2 fed by v1-style codes (more nnz) vs v2 codes
    codes_v1 = _pad_flat(q_v1(f))
    sh_v1 = shuffle.bitshuffle(codes_v1)
    sh_v2 = shuffle.bitshuffle(codes)
    e = jax.jit(lambda s: enc.encode(s, capacity=n_blocks))
    nnz1, nnz2 = int(e(sh_v1)[2]), int(e(sh_v2)[2])
    add(f"prefix-sum-encode-v1(nnz={nnz1})", timeit(e, sh_v1), 2 * codes.size)
    add(f"prefix-sum-encode-v2(nnz={nnz2})", timeit(e, sh_v2), 2 * codes.size)

    # ---- whole-path staged vs fused megakernels (this PR's fusion claim);
    # one AOT compile per variant serves both the timing loop and the
    # memory_analysis traffic column
    for path in FZ_PATHS:
        cfg = fz_path_config(path, eb)
        comp = jax.jit(lambda x, cfg=cfg: fz.compress(x, cfg)) \
            .lower(f).compile()
        c = comp(f)
        out_bytes = int(c.wire_bytes())
        dec = jax.jit(lambda cc, cfg=cfg: fz.decompress(cc, cfg)) \
            .lower(c).compile()
        m_c = hlo_cost.compiled_memory_traffic(comp)
        m_d = hlo_cost.compiled_memory_traffic(dec)
        add(f"pipeline-compress-{path}", timeit(comp, f), nbytes,
            hbm_model_bytes(path, "compress", f.size, out_bytes),
            round(m_c["traffic_ratio"], 3))
        add(f"pipeline-decompress-{path}", timeit(dec, c), nbytes,
            hbm_model_bytes(path, "decompress", f.size, out_bytes),
            round(m_d["traffic_ratio"], 3))
    return rows


def main(smoke=False):
    rows = run(smoke=smoke)
    print("kernel,us_per_call,proxy_GBps,hbm_model_bytes,measured_traffic")
    for r in rows:
        model = "" if r["hbm_model_bytes"] is None else r["hbm_model_bytes"]
        meas = "" if r["measured_traffic"] is None else r["measured_traffic"]
        print(f"{r['name']},{r['us']:.0f},{r['gbps']:.3f},{model},{meas}")
    return rows


if __name__ == "__main__":
    main()
