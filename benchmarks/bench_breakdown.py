"""Paper Figure 10: per-kernel effect of the proposed optimizations.

CPU-proxy wall-clock (relative speedups are the claim; absolute GB/s needs
the target TPU). Version pairs mirror the paper's bars:

  pred-quant-v1     dual-quantization with the cuSZ-style outlier side path
  pred-quant-v2     optimized: branch-free saturating codes (paper §3.2)
  shuffle-mark-v1   bitshuffle and zero-flagging as two passes
  shuffle-mark-v2   fused single pass (paper §3.4 fusion)
  encode-v1/v2      phase-2 encode fed by v1 vs v2 quantization (the v2
                    codes produce fewer non-zero blocks -> faster compaction)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encode as enc
from repro.core import quant, shuffle
from repro.data import make_field
from .common import gbps, timeit


def _pad_flat(codes):
    return shuffle.pad_to_tiles(codes.reshape(-1))


def run(shape=(128, 128, 64), eb=1e-3):
    f = jnp.asarray(make_field("smooth", shape, seed=3))
    rng = float(jnp.max(f) - jnp.min(f))
    eb_abs = jnp.float32(eb * rng)
    nbytes = f.size * 4
    rows = []

    # ---- pred-quant v1 (outlier path) vs v2 (branch-free saturating)
    q_v1 = jax.jit(lambda x: quant.dual_quantize(
        x, eb_abs, outlier_capacity=max(1, f.size // 64))[0])
    q_v2 = jax.jit(lambda x: quant.dual_quantize(x, eb_abs, outlier_capacity=0)[0])
    t1, t2 = timeit(q_v1, f), timeit(q_v2, f)
    rows.append(("pred-quant-v1", t1, nbytes))
    rows.append(("pred-quant-v2", t2, nbytes))

    codes = _pad_flat(q_v2(f))
    n_blocks = codes.size // enc.BLOCK_WORDS

    # ---- bitshuffle+mark: two passes vs fused
    def v1(c):
        sh = shuffle.bitshuffle(c)
        return sh, enc.block_flags(sh)

    def v2(c):
        from repro.kernels import bitshuffle_flag as bsf
        sh, fl = bsf.bitshuffle_flag(c.reshape(-1, shuffle.TILE), interpret=True)
        return sh, fl

    t1 = timeit(jax.jit(v1), codes)
    t2 = timeit(jax.jit(v2), codes)
    rows.append(("bitshuffle-mark-v1", t1, 2 * codes.size))
    rows.append(("bitshuffle-mark-v2-fused", t2, 2 * codes.size))

    # ---- encode phase 2 fed by v1-style codes (more nnz) vs v2 codes
    codes_v1 = _pad_flat(q_v1(f))
    sh_v1 = shuffle.bitshuffle(codes_v1)
    sh_v2 = shuffle.bitshuffle(codes)
    e = jax.jit(lambda s: enc.encode(s, capacity=n_blocks))
    t1, t2 = timeit(e, sh_v1), timeit(e, sh_v2)
    nnz1 = int(e(sh_v1)[2])
    nnz2 = int(e(sh_v2)[2])
    rows.append((f"prefix-sum-encode-v1(nnz={nnz1})", t1, 2 * codes.size))
    rows.append((f"prefix-sum-encode-v2(nnz={nnz2})", t2, 2 * codes.size))
    return rows


def main():
    rows = run()
    print("kernel,us_per_call,cpu_proxy_GBps")
    out = []
    for name, secs, nbytes in rows:
        print(f"{name},{secs * 1e6:.0f},{gbps(nbytes, secs):.3f}")
        out.append((name, secs, nbytes))
    return out


if __name__ == "__main__":
    main()
