"""BENCH trajectory: append per-run summary rows, gate on regressions.

``scripts/ci.sh bench`` overwrites ``BENCH_ci.json`` every run — good for
"what does this tree do", useless for "what did the last ten PRs do". This
module compacts one bench document into a flat ``{key: value}`` summary row
and appends it to ``BENCH_history.jsonl`` (one JSON object per line, commit
the file to carry the trajectory), then soft-gates the new row against the
previous row *of the same smoke flag*: any tracked metric that moved more
than ``--threshold`` (default 25%) in its bad direction prints a warning,
and ``--strict`` turns warnings into a non-zero exit.

Tracked keys and their good direction:

  * ``throughput/<path>/<direction>_gbps``  (higher) — mean GB/s per FZ
    execution path, including the tuned ``auto`` path;
  * ``kvcache/decode/<name>_ms``            (lower)  — paged decode steps;
  * ``overlap/<mode>_s``                    (lower)  — reduce wall time;
  * ``rate_distortion/<kind>_cold_bitrate`` (lower)  — entropy-tier bits
    per element at the frontier.

The gate is *soft* by default because CI boxes differ: a >25% drop is worth
a look, not an automatic revert — the history line is the evidence either
way.

    python -m benchmarks.history BENCH_ci.json
    python -m benchmarks.history BENCH_ci.json --strict --threshold 0.3
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

DEFAULT_HISTORY = "BENCH_history.jsonl"
DEFAULT_THRESHOLD = 0.25


def _mean(vals) -> float | None:
    vals = [float(v) for v in vals]
    return sum(vals) / len(vals) if vals else None


def summarize(doc: dict) -> dict[str, dict]:
    """Compact one bench document into {key: {value, better}} metrics."""
    out: dict[str, dict] = {}

    def put(key: str, value, better: str) -> None:
        if value is not None:
            out[key] = {"value": float(value), "better": better}

    sections = doc.get("sections", {})
    thr = sections.get("throughput") or {}
    rows = thr.get("rows", [])
    for path in sorted({r["path"] for r in rows}):
        for direction in ("compress", "decompress"):
            sel = [r["gbps"] for r in rows
                   if r["path"] == path and r["direction"] == direction]
            put(f"throughput/{path}/{direction}_gbps", _mean(sel), "higher")
    kv = sections.get("kvcache") or {}
    for r in kv.get("decode_ms", []):
        if isinstance(r, dict) and "name" in r and "step_ms" in r:
            put(f"kvcache/decode/{r['name']}_ms", r["step_ms"], "lower")
    ov = sections.get("overlap") or {}
    for mode in sorted({r["mode"] for r in ov.get("rows", [])}):
        sel = [r["seconds"] for r in ov.get("rows", [])
               if r["mode"] == mode and "seconds" in r]
        put(f"overlap/{mode}_s", _mean(sel), "lower")
    rd = sections.get("rate_distortion") or {}
    for kind in sorted({r["kind"] for r in rd.get("rows", [])}):
        sel = [r["fz_cold_bitrate"] for r in rd.get("rows", [])
               if r["kind"] == kind and "fz_cold_bitrate" in r]
        put(f"rate_distortion/{kind}_cold_bitrate", _mean(sel), "lower")
    return out


def gate(prev: dict, cur: dict, threshold: float) -> list[str]:
    """Regressions of ``cur`` vs ``prev`` (same-key, > threshold, bad way)."""
    warnings = []
    pm, cm = prev.get("metrics", {}), cur.get("metrics", {})
    for key, c in sorted(cm.items()):
        p = pm.get(key)
        if not p or p["value"] <= 0:
            continue
        rel = (c["value"] - p["value"]) / p["value"]
        drop = -rel if c["better"] == "higher" else rel
        if drop > threshold:
            warnings.append(
                f"{key}: {p['value']:.4g} -> {c['value']:.4g} "
                f"({drop:+.0%} worse than the previous "
                f"{'smoke' if cur.get('smoke') else 'full'} row)")
    return warnings


def append_and_gate(bench_json: str, history_path: str,
                    threshold: float = DEFAULT_THRESHOLD) -> tuple[dict, list[str]]:
    doc = json.loads(pathlib.Path(bench_json).read_text())
    meta = doc.get("meta", {})
    row = {"unix_time": meta.get("unix_time"),
           "smoke": bool(meta.get("smoke")),
           "sections": meta.get("sections", []),
           "metrics": summarize(doc)}
    hist = pathlib.Path(history_path)
    warnings: list[str] = []
    if hist.exists():
        prev = None
        for line in hist.read_text().splitlines():
            line = line.strip()
            if not line:
                continue
            try:
                cand = json.loads(line)
            except json.JSONDecodeError:
                continue  # a mangled line must not block the trajectory
            if isinstance(cand, dict) and cand.get("smoke") == row["smoke"]:
                prev = cand
        if prev is not None:
            warnings = gate(prev, row, threshold)
    with hist.open("a") as f:
        f.write(json.dumps(row, sort_keys=True) + "\n")
    return row, warnings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.history",
        description="append a bench summary row and soft-gate regressions")
    ap.add_argument("bench_json", help="BENCH_ci.json from benchmarks.run")
    ap.add_argument("--history", default=DEFAULT_HISTORY)
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="relative drop that counts as a regression")
    ap.add_argument("--strict", action="store_true",
                    help="exit non-zero on regressions (default: warn only)")
    args = ap.parse_args(argv)
    row, warnings = append_and_gate(args.bench_json, args.history,
                                    args.threshold)
    print(f"history: appended {len(row['metrics'])} metric(s) to "
          f"{args.history} (smoke={row['smoke']})")
    for w in warnings:
        print(f"history: REGRESSION {w}", file=sys.stderr)
    if warnings and args.strict:
        return 1
    if not warnings:
        print("history: no regressions vs the previous comparable row")
    return 0


if __name__ == "__main__":
    sys.exit(main())
