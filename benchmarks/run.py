"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # all, reduced sizes
    PYTHONPATH=src python -m benchmarks.run --only rate_distortion
    PYTHONPATH=src python -m benchmarks.run --only kvcache,overlap --smoke \
        --json-out BENCH_ci.json                       # the CI bench tier

Sections map to the paper:
    rate_distortion  -> Fig. 7   (bitrate vs PSNR, 4 compressors)
    throughput       -> Fig. 8/9 (compression/decompression, CPU-proxy)
    breakdown        -> Fig. 10  (per-kernel optimization effects)
    overall          -> Fig. 11  (overall data-transfer throughput model)
    integrations     -> §2.4 use cases in the framework (grads/KV/ckpt)
    kvcache          -> §2.4 in-memory: KV parking sweep + paged-pool trace
    overlap          -> §2.4 wire: barrier vs bucketed compressed reduce
    roofline         -> §Roofline table from the dry-run JSONs

``--smoke`` shrinks shapes/sweeps for CI; sections whose ``main`` accepts a
``smoke`` kwarg honour it, the rest run their defaults. ``--json-out``
collects every section's machine-readable return value (sections returning
None are recorded as null) into one document — CI writes ``BENCH_ci.json``
at the repo root and uploads it, the first datapoint of the perf
trajectory. The document also embeds a ``metrics_snapshot`` of the
repro.obs registry (dispatch counters, span histograms, sentinel state) so
the trajectory carries the telemetry of the run that produced it;
``--trace-out`` additionally dumps the span event ring as a Chrome trace.
"""
from __future__ import annotations

import argparse
import inspect
import json
import sys
import time

SECTIONS = ("rate_distortion", "throughput", "breakdown", "overall",
            "integrations", "kvcache", "overlap", "roofline")


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--only", default=None,
                   help=f"comma-separated subset of {', '.join(SECTIONS)}")
    p.add_argument("--smoke", action="store_true",
                   help="tiny shapes / reduced sweeps (CI preset)")
    p.add_argument("--json-out", default=None,
                   help="write all sections' machine-readable results here")
    p.add_argument("--trace-out", default=None,
                   help="write the run's span events as Chrome trace JSON")
    args = p.parse_args()
    if args.only:
        todo = [s.strip() for s in args.only.split(",") if s.strip()]
        unknown = [s for s in todo if s not in SECTIONS]
        if unknown:
            p.error(f"unknown sections {unknown}; choose from {SECTIONS}")
    else:
        todo = list(SECTIONS)

    results: dict[str, object] = {}
    for name in todo:
        print(f"\n===== {name} =====", flush=True)
        t0 = time.time()
        mod = __import__(f"benchmarks.bench_{name}", fromlist=["main"])
        fn = mod.main
        kwargs = {}
        if args.smoke and "smoke" in inspect.signature(fn).parameters:
            kwargs["smoke"] = True
        try:
            results[name] = fn(**kwargs)
        except Exception as e:  # keep the harness going; report the failure
            print(f"{name},FAILED,{e!r}", file=sys.stderr)
            raise
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)

    from repro import obs
    if args.json_out:
        snap = obs.snapshot()
        snap["sentinel_violations"] = obs.violations()
        doc = {"meta": {"smoke": args.smoke, "sections": todo,
                        "unix_time": int(time.time())},
               "sections": results,
               "metrics_snapshot": snap}
        with open(args.json_out, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
        print(f"# wrote {args.json_out}", flush=True)
    if args.trace_out:
        obs.write_chrome_trace(args.trace_out,
                               metadata={"smoke": args.smoke,
                                         "sections": todo})
        print(f"# wrote {args.trace_out}", flush=True)


if __name__ == "__main__":
    main()
