"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # all, reduced sizes
    PYTHONPATH=src python -m benchmarks.run --only rate_distortion

Sections map to the paper:
    rate_distortion  -> Fig. 7   (bitrate vs PSNR, 4 compressors)
    throughput       -> Fig. 8/9 (compression/decompression, CPU-proxy)
    breakdown        -> Fig. 10  (per-kernel optimization effects)
    overall          -> Fig. 11  (overall data-transfer throughput model)
    integrations     -> §2.4 use cases in the framework (grads/KV/ckpt)
    kvcache          -> §2.4 in-memory: KV parking sweep + paged-pool trace
    roofline         -> §Roofline table from the dry-run JSONs
"""
from __future__ import annotations

import argparse
import sys
import time

SECTIONS = ("rate_distortion", "throughput", "breakdown", "overall",
            "integrations", "kvcache", "roofline")


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--only", choices=SECTIONS, default=None)
    args = p.parse_args()
    todo = [args.only] if args.only else list(SECTIONS)
    for name in todo:
        print(f"\n===== {name} =====", flush=True)
        t0 = time.time()
        mod = __import__(f"benchmarks.bench_{name}", fromlist=["main"])
        try:
            mod.main()
        except Exception as e:  # keep the harness going; report the failure
            print(f"{name},FAILED,{e!r}", file=sys.stderr)
            raise
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
