"""Paper Figure 11 / §4.6: overall data-transfer throughput model.

T_overall = ((BW * CR)^-1 + T_compr^-1)^-1 with measured CRs and measured
(CPU-proxy, relative) compression throughputs. Evaluated at the paper's two
interconnect operating points: 32 GB/s (dedicated PCIe4 x16) and 11.4 GB/s
(4-GPU contended), plus a 3 GB/s DCN-like point for the cross-pod story.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import baselines, fz
from repro.data import make_field
from .common import timeit

LINKS_GBPS = (32.0, 11.4, 3.0)


def overall(bw_gbps, cr, compr_gbps):
    return 1.0 / (1.0 / (bw_gbps * cr) + 1.0 / compr_gbps)


def run(shape=(128, 128, 64)):
    f = jnp.asarray(make_field("smooth", shape, seed=9))
    nbytes = f.size * 4
    rows = []
    # FZ at a mid bound
    cfg = fz.FZConfig(eb=1e-3, exact_outliers=False)
    comp = jax.jit(lambda x: fz.compress(x, cfg))
    cr_fz = float(comp(f).compression_ratio())
    thr_fz = nbytes / timeit(comp, f) / 1e9
    # cuSZx-like: faster kernel, lower ratio
    ebj = jnp.float32(1e-3 * float(jnp.max(f) - jnp.min(f)))
    cx = jax.jit(lambda x: baselines.cuszx_like(x, ebj))
    _, bx = cx(f)
    cr_x = nbytes / float(bx)
    thr_x = nbytes / timeit(cx, f) / 1e9
    for bw in LINKS_GBPS:
        rows.append(("fz", bw, cr_fz, thr_fz, overall(bw, cr_fz, thr_fz)))
        rows.append(("cuszx-like", bw, cr_x, thr_x, overall(bw, cr_x, thr_x)))
        rows.append(("no-compression", bw, 1.0, float("inf"), bw))
    return rows


def main():
    rows = run()
    print("compressor,link_GBps,CR,compr_GBps(proxy),overall_GBps(model)")
    for name, bw, cr, thr, ov in rows:
        t = "inf" if thr == float("inf") else f"{thr:.2f}"
        print(f"{name},{bw},{cr:.2f},{t},{ov:.2f}")
    return rows


if __name__ == "__main__":
    main()
