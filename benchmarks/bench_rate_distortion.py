"""Paper Figure 7: rate-distortion (bitrate vs PSNR) across compressors.

Synthetic SDRBench-proxy fields (data/fields.py); five relative error bounds;
FZ vs cuSZ-like / cuSZx-like / cuZFP-like. cuZFP has no error-bounded mode,
so (faithful to the paper's method) its point is chosen at the bitrate whose
PSNR is closest to FZ's.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import baselines, fz, metrics
from repro.data import FIELD_KINDS, make_field
from .common import PAPER_EBS


def run(shape=(64, 64, 64), kinds=FIELD_KINDS, ebs=PAPER_EBS):
    rows = []
    for kind in kinds:
        f = jnp.asarray(make_field(kind, shape, seed=11))
        raw = f.size * f.dtype.itemsize
        br = lambda comp_bytes: float(metrics.bitrate(raw, comp_bytes, f.dtype))
        for eb in ebs:
            cfg = fz.FZConfig(eb=eb)
            rec, c = fz.roundtrip(f, cfg)
            eb_abs = float(c.eb_abs)
            psnr_fz = float(metrics.psnr(f, rec))
            br_fz = br(float(c.used_bytes()))
            cz = baselines.cusz_like(np.asarray(f), eb_abs)
            psnr_cz = float(metrics.psnr(f, jnp.asarray(cz.reconstruction)))
            br_cz = br(cz.compressed_bytes)
            rx, bx = baselines.cuszx_like(f, jnp.float32(eb_abs))
            psnr_x = float(metrics.psnr(f, rx))
            br_x = br(float(bx))
            # cuZFP: search the rate whose PSNR best matches FZ's
            best = None
            for rate in (2, 4, 6, 8, 10, 12, 14, 16):
                rz, bz = baselines.cuzfp_like(f, rate)
                p = float(metrics.psnr(f, rz))
                if best is None or abs(p - psnr_fz) < abs(best[0] - psnr_fz):
                    best = (p, br(float(bz)), rate)
            rows.append(dict(kind=kind, eb=eb,
                             fz_bitrate=br_fz, fz_psnr=psnr_fz,
                             cusz_bitrate=br_cz, cusz_psnr=psnr_cz,
                             cuszx_bitrate=br_x, cuszx_psnr=psnr_x,
                             cuzfp_bitrate=best[1], cuzfp_psnr=best[0]))
    return rows


def main():
    rows = run()
    print("kind,eb,fz_br,fz_psnr,cusz_br,cusz_psnr,cuszx_br,cuszx_psnr,cuzfp_br,cuzfp_psnr")
    for r in rows:
        print(f"{r['kind']},{r['eb']:.0e},{r['fz_bitrate']:.2f},{r['fz_psnr']:.1f},"
              f"{r['cusz_bitrate']:.2f},{r['cusz_psnr']:.1f},"
              f"{r['cuszx_bitrate']:.2f},{r['cuszx_psnr']:.1f},"
              f"{r['cuzfp_bitrate']:.2f},{r['cuzfp_psnr']:.1f}")
    return rows


if __name__ == "__main__":
    main()
