"""Paper Figure 7: rate-distortion (bitrate vs PSNR) across compressors.

Synthetic SDRBench-proxy fields (data/fields.py); five relative error bounds;
FZ vs cuSZ-like / cuSZx-like / cuZFP-like. cuZFP has no error-bounded mode,
so (faithful to the paper's method) its point is chosen at the bitrate whose
PSNR is closest to FZ's.

Cold-tier columns: every row also serializes the FZ container both plain and
with the probe-gated entropy stage (docs/CONTAINER_FORMAT.md) and reports the
serialized bitrates plus the PSNR *measured from the decoded blob* — decode
must be bit-exact, so ``fz_cold_psnr == fz_psnr`` is asserted, making the
"extra ratio at equal distortion" claim self-checking. ``probe_section``
pins the skip probe: on incompressible noise the exact-size histogram probe
rejects the entropy stage at a small fraction of what a wasted encode would
have cost; scripts/ci.sh bench asserts both behaviours from BENCH_ci.json.
"""
from __future__ import annotations

import struct

import jax.numpy as jnp
import numpy as np

from repro.core import baselines, entropy as ent, fz, metrics
from repro.data import FIELD_KINDS, make_field
from .common import PAPER_EBS, timeit


def run(shape=(64, 64, 64), kinds=FIELD_KINDS, ebs=PAPER_EBS):
    rows = []
    for kind in kinds:
        f = jnp.asarray(make_field(kind, shape, seed=11))
        raw = f.size * f.dtype.itemsize
        br = lambda comp_bytes: float(metrics.bitrate(raw, comp_bytes, f.dtype))
        for eb in ebs:
            cfg = fz.FZConfig(eb=eb)
            rec, c = fz.roundtrip(f, cfg)
            eb_abs = float(c.eb_abs)
            psnr_fz = float(metrics.psnr(f, rec))
            br_fz = br(float(c.used_bytes()))

            # cold tier: serialized container, plain vs probe-gated entropy
            plain = fz.to_bytes(c, cfg, entropy=False)
            t_cold = timeit(lambda: fz.to_bytes(c, cfg, entropy="auto"),
                            warmup=0, iters=1)
            cold = fz.to_bytes(c, cfg, entropy="auto")
            selected = bool(struct.unpack_from("<H", cold, 6)[0]
                            & fz.FLAG_ENTROPY)
            t_dec = timeit(lambda: fz.decompress_bytes(cold),
                           warmup=0, iters=1)
            rec_cold = fz.decompress_bytes(cold)
            assert jnp.array_equal(rec_cold, rec), (kind, eb)
            psnr_cold = float(metrics.psnr(f, rec_cold))

            cz = baselines.cusz_like(np.asarray(f), eb_abs)
            psnr_cz = float(metrics.psnr(f, jnp.asarray(cz.reconstruction)))
            br_cz = br(cz.compressed_bytes)
            rx, bx = baselines.cuszx_like(f, jnp.float32(eb_abs))
            psnr_x = float(metrics.psnr(f, rx))
            br_x = br(float(bx))
            # cuZFP: search the rate whose PSNR best matches FZ's
            best = None
            for rate in (2, 4, 6, 8, 10, 12, 14, 16):
                rz, bz = baselines.cuzfp_like(f, rate)
                p = float(metrics.psnr(f, rz))
                if best is None or abs(p - psnr_fz) < abs(best[0] - psnr_fz):
                    best = (p, br(float(bz)), rate)
            rows.append(dict(kind=kind, eb=eb,
                             fz_bitrate=br_fz, fz_psnr=psnr_fz,
                             fz_plain_bitrate=br(len(plain)),
                             fz_cold_bitrate=br(len(cold)),
                             fz_cold_psnr=psnr_cold,
                             entropy_selected=selected,
                             cold_encode_ms=t_cold * 1e3,
                             cold_decode_ms=t_dec * 1e3,
                             cusz_bitrate=br_cz, cusz_psnr=psnr_cz,
                             cuszx_bitrate=br_x, cuszx_psnr=psnr_x,
                             cuzfp_bitrate=best[1], cuzfp_psnr=best[0]))
    return rows


def probe_section(smoke: bool = False) -> dict:
    """Skip-probe cost model on one compressible / one incompressible buffer.

    The probe is a byte histogram plus a 256-symbol Huffman plan — it knows
    the *exact* encoded size without touching the bitstream, so rejecting
    the entropy stage on noise costs a fraction of the encode it avoids."""
    n = (1 << 18) if smoke else (1 << 20)
    rng = np.random.default_rng(3)
    bufs = {
        "skew": np.minimum(rng.gamma(1.0, 8.0, n), 255).astype(np.uint8),
        "noise": rng.integers(0, 256, n, dtype=np.uint8),
    }
    out = {}
    for name, arr in bufs.items():
        data = arr.tobytes()
        counts = np.bincount(arr, minlength=256)
        _, planned = ent.plan(counts, n, ent.DEFAULT_CHUNK)
        t_probe = timeit(
            lambda: ent.plan(np.bincount(np.frombuffer(data, np.uint8),
                                         minlength=256), n, ent.DEFAULT_CHUNK),
            warmup=1, iters=3)
        t_encode = timeit(lambda: ent.encode(data), warmup=1, iters=3)
        out[name] = {
            "n_bytes": n,
            "planned_bytes": int(planned),
            # same gate to_bytes applies: the stage must win the min gain
            "selected": bool(planned < n * (1.0 - fz.ENTROPY_MIN_GAIN)),
            "probe_ms": t_probe * 1e3,
            "encode_ms": t_encode * 1e3,
            "probe_frac": t_probe / t_encode,
        }
    return out


def main(smoke: bool = False) -> dict:
    kw = dict(shape=(48, 48, 48), ebs=(1e-2, 1e-3)) if smoke else {}
    rows = run(**kw)
    print("kind,eb,fz_br,fz_psnr,cold_br(plain_br),cold_psnr,entropy,"
          "cusz_br,cusz_psnr,cuszx_br,cuszx_psnr,cuzfp_br,cuzfp_psnr")
    for r in rows:
        print(f"{r['kind']},{r['eb']:.0e},{r['fz_bitrate']:.2f},"
              f"{r['fz_psnr']:.1f},"
              f"{r['fz_cold_bitrate']:.2f}({r['fz_plain_bitrate']:.2f}),"
              f"{r['fz_cold_psnr']:.1f},"
              f"{'y' if r['entropy_selected'] else 'n'},"
              f"{r['cusz_bitrate']:.2f},{r['cusz_psnr']:.1f},"
              f"{r['cuszx_bitrate']:.2f},{r['cuszx_psnr']:.1f},"
              f"{r['cuzfp_bitrate']:.2f},{r['cuzfp_psnr']:.1f}")
    probe = probe_section(smoke=smoke)
    print("probe,n_bytes,planned_bytes,selected,probe_ms,encode_ms,frac")
    for name, p in probe.items():
        print(f"probe[{name}],{p['n_bytes']},{p['planned_bytes']},"
              f"{'y' if p['selected'] else 'n'},{p['probe_ms']:.2f},"
              f"{p['encode_ms']:.2f},{p['probe_frac']:.3f}")
    return {"rows": rows, "probe": probe}


if __name__ == "__main__":
    main()
