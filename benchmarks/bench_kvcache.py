"""KV-cache compression benchmark (paper §2.4 in-memory use case, served).

Cited from serve/engine.py. Three measurements:

  * memory ratio of a parked cache across the paper's relative error bounds
    (whole-cache path, ``used_bytes`` accounting). Ratios are charged against
    the slab dtype: containers record the source dtype (a bfloat16 cache
    reports n*2 raw bytes), so the printed ratio and the containers' own
    ``compression_ratio()`` agree instead of the latter inflating ~2x;
  * park/resume latency — the cost FZ must beat for compress-park preemption
    to outrun drop-and-recompute;
  * decode-logit deviation: max |logit delta| of one decode step running on a
    reconstructed cache vs the raw cache;
  * decode latency: one scheduler decode step through (a) the contiguous
    gather + reference model decode, (b) the page-native jnp partials path,
    (c) the page-native Pallas flash-decode kernel (interpret mode off-TPU,
    so (c) measures dispatch shape, not TPU speed);

plus one paged-pool row: a continuous-batching trace over a slab smaller than
its raw demand, reporting the memory high-water mark vs demand and the
preempt/resume traffic (serve/kvpool);

plus the serving section: one seeded prefix-skewed trace (tracegen — Poisson
arrivals, template reuse) replayed through the pool in all three
``prefix_mode`` s. "radix" shares refcounted pages, "copy" matches but
duplicates (the numerics-parity twin), "off" is the non-shared baseline; the
rows report prefill tokens issued vs saved, prefix-hit rate, CoW traffic,
cold-decompress dispatch counts (dedup), high-water bytes, and p50/p99
TTFT / inter-token latency — the radix-vs-off deltas CI pins.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import PAPER_EBS, timeit
from repro import configs
from repro.models import zoo
from repro.serve import Engine, KVCompressionConfig, PoolConfig, Request
from repro.serve.engine import (cache_bytes, compress_cache,
                                compressed_cache_bytes, decompress_cache)
from repro.serve.kvpool import TraceGenConfig, generate, latency_summary


def parking_sweep(arch="glm4-9b", S=128, B=2, n_tokens=2, ebs=PAPER_EBS):
    cfg = configs.get(arch, smoke=True)
    model = zoo.build(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S), dtype=np.int32))}
    eng = Engine(model, params)
    logits_raw, cache = eng.generate(batch, n_tokens)
    raw = cache_bytes(cache)
    tok = jnp.zeros((B,), jnp.int32)
    base_logits, _ = eng.decode_step(cache, tok)

    rows = []
    for eb in ebs:
        kcfg = KVCompressionConfig(enabled=True, eb=eb, min_leaf_size=1024)
        parked = compress_cache(cache, kcfg)
        packed = compressed_cache_bytes(parked)
        # container-level sanity: per-leaf ratios are charged against the
        # leaf's own dtype (bf16 cache => n*2 raw), matching raw/packed
        for _, (codec, payload, _, dtype) in parked.items():
            if codec == "fz":
                assert payload.raw_bytes() == payload.n * jnp.dtype(dtype).itemsize

        def park():
            c = compress_cache(cache, kcfg)
            return [l for l in jax.tree.leaves(c) if hasattr(l, "block_until_ready")]

        def resume():
            return jax.tree.leaves(decompress_cache(parked, kcfg))

        t_park = timeit(park, warmup=1, iters=3)
        t_resume = timeit(resume, warmup=1, iters=3)
        rec = decompress_cache(parked, kcfg)
        logits_rec, _ = eng.decode_step(rec, tok)
        dev = float(jnp.max(jnp.abs(logits_rec - base_logits)))
        rows.append((f"kv-park[eb={eb:g}]", raw / packed,
                     t_park * 1e3, t_resume * 1e3, dev))
    return rows


def decode_latency(arch="glm4-9b", n_seqs=2, prompt=24):
    """Per-step decode latency: contiguous reference vs page-native paths.

    Half of each sequence's pages are tiered cold first, so every variant
    pays the transient batched decompress its gather actually does."""
    cfg = configs.get(arch, smoke=True)
    model = zoo.build(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    pool_cfg = PoolConfig(num_pages=16, page_size=8, seq_capacity=64,
                          cold_after=10**9, eb=1e-4)
    engines = {False: Engine(model, params, pool=pool_cfg),
               True: Engine(model, params,
                            pool=dataclasses.replace(pool_cfg, use_kernels=True))}
    pool = engines[False].make_pool()
    for seq in range(n_seqs):
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (1, prompt), dtype=np.int32))}
        _, cache = engines[False].prefill(batch)
        assert pool.write_prefill(seq, cache["k"], cache["v"], prompt, step=0)
        pids = [p.page_id for p in pool.pages_of(seq)]
        pool.compress_pages(pids[: len(pids) // 2])       # cold half
    lanes = list(range(n_seqs))
    tokens = jnp.zeros((n_seqs,), jnp.int32)

    def contiguous():
        logits, _ = engines[False].decode_step(pool.gather(lanes), tokens)
        return [logits]

    def paged(uk):
        def run():
            logits, _ = engines[uk].decode_step_paged(pool.gather_pages(lanes),
                                                      tokens)
            return [logits]
        return run

    rows = []
    for name, fn in (("decode-contiguous-ref", contiguous),
                     ("decode-paged-jnp", paged(False)),
                     ("decode-paged-kernel", paged(True))):
        rows.append((name, timeit(fn, warmup=1, iters=5) * 1e3))
    return rows


def pool_trace(arch="glm4-9b"):
    cfg = configs.get(arch, smoke=True)
    model = zoo.build(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    reqs = [Request(req_id=i,
                    tokens=rng.integers(0, cfg.vocab, (s,), dtype=np.int32),
                    n_new=8, priority=i % 2)
            for i, s in enumerate((16, 8, 16, 8))]
    eng = Engine(model, params,
                 pool=PoolConfig(num_pages=4, page_size=8, seq_capacity=48,
                                 cold_after=2, eb=1e-4))
    outputs, stats, pool = eng.serve(reqs, max_batch=2)
    assert len(outputs) == len(reqs)
    return [("kvpool-trace", stats.high_water_used_bytes,
             stats.high_water_demand_bytes,
             f"{stats.preemptions}preempt/{stats.resumes}resume/"
             f"{stats.tiered_pages}tiered")]


def serving_trace(arch="glm4-9b", smoke=False):
    """One seeded prefix-skewed trace through radix / copy / off pools,
    plus a "radix_entropy" replay (radix sharing + entropy-coded cold tier).

    The slab (6 pages) is smaller than the trace's raw demand, so completion
    leans on compress-parking in every mode; the radix rows additionally get
    prefix hits, CoW forks and deduped shared cold reads. All three replays
    see byte-identical requests and fully deterministic scheduling, so the
    row fields are stable run-to-run (scripts/ci.sh asserts on them)."""
    cfg = configs.get(arch, smoke=True)
    model = zoo.build(cfg)
    params = model.init(jax.random.key(0))
    tg = TraceGenConfig(
        seed=7, n_requests=6 if smoke else 20, vocab=cfg.vocab,
        arrival_rate=1.5, n_templates=1 if smoke else 2,
        template_len=(16, 22), template_reuse=0.75, suffix_len=(2, 5),
        n_new=(3, 6) if smoke else (4, 8), priorities=(0, 1),
        ttft_slo=8, itl_slo=6)
    reqs = generate(tg)
    raw_demand = sum(-(-len(r.tokens) // 8) + -(-r.n_new // 8) for r in reqs)
    rows = []
    radix_outputs, radix_prefill = None, None
    for mode in ("radix", "copy", "off", "radix_entropy"):
        # the radix cache is LRU-capped so retained cold containers stay a
        # bounded overhead against the high-water comparison with "off".
        # "radix_entropy" is radix with the cold tier stored as entropy-coded
        # byte containers (PoolConfig.cold_entropy) — the decode is bit-exact,
        # so its outputs must be bit-identical to plain radix (CI pins this).
        prefix_mode = "radix" if mode == "radix_entropy" else mode
        pool_cfg = PoolConfig(num_pages=6, page_size=8, seq_capacity=48,
                              cold_after=2, eb=1e-4, prefix_mode=prefix_mode,
                              cold_entropy=(mode == "radix_entropy"),
                              max_cached_pages=6 if smoke else 8)
        eng = Engine(model, params, pool=pool_cfg)
        outputs, stats, pool = eng.serve(reqs, max_batch=3)
        assert len(outputs) == len(reqs), f"{mode}: trace incomplete"
        total_prompt = sum(len(r.tokens) for r in reqs)
        assert (stats.prefill_tokens + stats.prefill_tokens_saved
                == total_prompt), mode
        extra = {}
        if mode == "radix":
            radix_outputs, radix_prefill = outputs, stats.prefill_tokens
        elif mode == "radix_entropy":
            ident = (set(outputs) == set(radix_outputs) and
                     all(np.array_equal(outputs[k], radix_outputs[k])
                         for k in outputs))
            assert ident, "entropy cold tier changed served tokens"
            assert stats.prefill_tokens == radix_prefill, \
                "entropy cold tier changed prefix-sharing behaviour"
            extra["bit_identical_to_radix"] = bool(ident)
        rows.append({
            **extra,
            "name": f"kvpool-serve[{mode}]", "mode": mode,
            "requests": len(reqs), "raw_demand_pages": raw_demand,
            "prefill_tokens": stats.prefill_tokens,
            "prefill_tokens_saved": stats.prefill_tokens_saved,
            "prefix_hit_rate": stats.prefix_hits / len(reqs),
            "cow_promotions": stats.cow_promotions,
            "decompressions": stats.pool_decompressions,
            "decompress_dispatches": stats.decompress_dispatches,
            "shared_cold_reads_deduped": stats.shared_cold_reads_deduped,
            "high_water_bytes": int(stats.high_water_used_bytes),
            "high_water_logical_bytes": int(stats.high_water_logical_bytes),
            "preemptions": stats.preemptions,
            "decode_steps": stats.decode_steps,
            **latency_summary(stats, tg),
        })
    return rows


def main(smoke: bool = False) -> dict:
    """Prints the tables; returns machine-readable rows (BENCH_ci.json).

    ``smoke``: one error bound and a smaller prefill for the parking sweep —
    the CI preset keeps every section (park, decode latency, pool trace)
    live while staying minutes-cheap on the runner.
    """
    park_kw = dict(S=64, B=1, n_tokens=1, ebs=(1e-3,)) if smoke else {}
    out = {"parking": [], "decode_ms": [], "pool": [], "serving": []}
    print("bench,ratio,park_ms,resume_ms,decode_logit_dev")
    for name, ratio, park_ms, resume_ms, dev in parking_sweep(**park_kw):
        print(f"{name},{ratio:.2f}x,{park_ms:.1f},{resume_ms:.1f},{dev:.2e}")
        out["parking"].append({"name": name, "ratio": ratio, "park_ms": park_ms,
                               "resume_ms": resume_ms, "logit_dev": dev})
    print("bench,step_ms")
    for name, ms in decode_latency(**(dict(n_seqs=1, prompt=16) if smoke else {})):
        print(f"{name},{ms:.1f}")
        out["decode_ms"].append({"name": name, "step_ms": ms})
    print("bench,high_water_bytes,raw_demand_bytes,traffic")
    for name, hw, demand, traffic in pool_trace():
        print(f"{name},{hw},{demand},{traffic}")
        out["pool"].append({"name": name, "high_water_bytes": int(hw),
                            "raw_demand_bytes": int(demand), "traffic": traffic})
    print("bench,prefill_tok,saved,hit_rate,cow,dispatches,deduped,"
          "hw_bytes,ttft_p50/p99,itl_p50/p99")
    for row in serving_trace(smoke=smoke):
        print(f"{row['name']},{row['prefill_tokens']},"
              f"{row['prefill_tokens_saved']},{row['prefix_hit_rate']:.2f},"
              f"{row['cow_promotions']},{row['decompress_dispatches']},"
              f"{row['shared_cold_reads_deduped']},{row['high_water_bytes']},"
              f"{row['ttft_p50']:.0f}/{row['ttft_p99']:.0f},"
              f"{row['itl_p50']:.0f}/{row['itl_p99']:.0f}")
        out["serving"].append(row)
    return out


if __name__ == "__main__":
    main()
