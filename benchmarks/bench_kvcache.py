"""KV-cache compression benchmark (paper §2.4 in-memory use case, served).

Cited from serve/engine.py. Three measurements:

  * memory ratio of a parked cache across the paper's relative error bounds
    (whole-cache path, ``used_bytes`` accounting). Ratios are charged against
    the slab dtype: containers record the source dtype (a bfloat16 cache
    reports n*2 raw bytes), so the printed ratio and the containers' own
    ``compression_ratio()`` agree instead of the latter inflating ~2x;
  * park/resume latency — the cost FZ must beat for compress-park preemption
    to outrun drop-and-recompute;
  * decode-logit deviation: max |logit delta| of one decode step running on a
    reconstructed cache vs the raw cache;
  * decode latency: one scheduler decode step through (a) the contiguous
    gather + reference model decode, (b) the page-native jnp partials path,
    (c) the page-native Pallas flash-decode kernel (interpret mode off-TPU,
    so (c) measures dispatch shape, not TPU speed);

plus one paged-pool row: a continuous-batching trace over a slab smaller than
its raw demand, reporting the memory high-water mark vs demand and the
preempt/resume traffic (serve/kvpool).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import PAPER_EBS, timeit
from repro import configs
from repro.models import zoo
from repro.serve import Engine, KVCompressionConfig, PoolConfig, Request
from repro.serve.engine import (cache_bytes, compress_cache,
                                compressed_cache_bytes, decompress_cache)


def parking_sweep(arch="glm4-9b", S=128, B=2, n_tokens=2, ebs=PAPER_EBS):
    cfg = configs.get(arch, smoke=True)
    model = zoo.build(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S), dtype=np.int32))}
    eng = Engine(model, params)
    logits_raw, cache = eng.generate(batch, n_tokens)
    raw = cache_bytes(cache)
    tok = jnp.zeros((B,), jnp.int32)
    base_logits, _ = eng.decode_step(cache, tok)

    rows = []
    for eb in ebs:
        kcfg = KVCompressionConfig(enabled=True, eb=eb, min_leaf_size=1024)
        parked = compress_cache(cache, kcfg)
        packed = compressed_cache_bytes(parked)
        # container-level sanity: per-leaf ratios are charged against the
        # leaf's own dtype (bf16 cache => n*2 raw), matching raw/packed
        for _, (codec, payload, _, dtype) in parked.items():
            if codec == "fz":
                assert payload.raw_bytes() == payload.n * jnp.dtype(dtype).itemsize

        def park():
            c = compress_cache(cache, kcfg)
            return [l for l in jax.tree.leaves(c) if hasattr(l, "block_until_ready")]

        def resume():
            return jax.tree.leaves(decompress_cache(parked, kcfg))

        t_park = timeit(park, warmup=1, iters=3)
        t_resume = timeit(resume, warmup=1, iters=3)
        rec = decompress_cache(parked, kcfg)
        logits_rec, _ = eng.decode_step(rec, tok)
        dev = float(jnp.max(jnp.abs(logits_rec - base_logits)))
        rows.append((f"kv-park[eb={eb:g}]", raw / packed,
                     t_park * 1e3, t_resume * 1e3, dev))
    return rows


def decode_latency(arch="glm4-9b", n_seqs=2, prompt=24):
    """Per-step decode latency: contiguous reference vs page-native paths.

    Half of each sequence's pages are tiered cold first, so every variant
    pays the transient batched decompress its gather actually does."""
    cfg = configs.get(arch, smoke=True)
    model = zoo.build(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    pool_cfg = PoolConfig(num_pages=16, page_size=8, seq_capacity=64,
                          cold_after=10**9, eb=1e-4)
    engines = {False: Engine(model, params, pool=pool_cfg),
               True: Engine(model, params,
                            pool=dataclasses.replace(pool_cfg, use_kernels=True))}
    pool = engines[False].make_pool()
    for seq in range(n_seqs):
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (1, prompt), dtype=np.int32))}
        _, cache = engines[False].prefill(batch)
        assert pool.write_prefill(seq, cache["k"], cache["v"], prompt, step=0)
        pids = [p.page_id for p in pool.pages_of(seq)]
        pool.compress_pages(pids[: len(pids) // 2])       # cold half
    lanes = list(range(n_seqs))
    tokens = jnp.zeros((n_seqs,), jnp.int32)

    def contiguous():
        logits, _ = engines[False].decode_step(pool.gather(lanes), tokens)
        return [logits]

    def paged(uk):
        def run():
            logits, _ = engines[uk].decode_step_paged(pool.gather_pages(lanes),
                                                      tokens)
            return [logits]
        return run

    rows = []
    for name, fn in (("decode-contiguous-ref", contiguous),
                     ("decode-paged-jnp", paged(False)),
                     ("decode-paged-kernel", paged(True))):
        rows.append((name, timeit(fn, warmup=1, iters=5) * 1e3))
    return rows


def pool_trace(arch="glm4-9b"):
    cfg = configs.get(arch, smoke=True)
    model = zoo.build(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    reqs = [Request(req_id=i,
                    tokens=rng.integers(0, cfg.vocab, (s,), dtype=np.int32),
                    n_new=8, priority=i % 2)
            for i, s in enumerate((16, 8, 16, 8))]
    eng = Engine(model, params,
                 pool=PoolConfig(num_pages=4, page_size=8, seq_capacity=48,
                                 cold_after=2, eb=1e-4))
    outputs, stats, pool = eng.serve(reqs, max_batch=2)
    assert len(outputs) == len(reqs)
    return [("kvpool-trace", stats.high_water_used_bytes,
             stats.high_water_demand_bytes,
             f"{stats.preemptions}preempt/{stats.resumes}resume/"
             f"{stats.tiered_pages}tiered")]


def main(smoke: bool = False) -> dict:
    """Prints the tables; returns machine-readable rows (BENCH_ci.json).

    ``smoke``: one error bound and a smaller prefill for the parking sweep —
    the CI preset keeps every section (park, decode latency, pool trace)
    live while staying minutes-cheap on the runner.
    """
    park_kw = dict(S=64, B=1, n_tokens=1, ebs=(1e-3,)) if smoke else {}
    out = {"parking": [], "decode_ms": [], "pool": []}
    print("bench,ratio,park_ms,resume_ms,decode_logit_dev")
    for name, ratio, park_ms, resume_ms, dev in parking_sweep(**park_kw):
        print(f"{name},{ratio:.2f}x,{park_ms:.1f},{resume_ms:.1f},{dev:.2e}")
        out["parking"].append({"name": name, "ratio": ratio, "park_ms": park_ms,
                               "resume_ms": resume_ms, "logit_dev": dev})
    print("bench,step_ms")
    for name, ms in decode_latency(**(dict(n_seqs=1, prompt=16) if smoke else {})):
        print(f"{name},{ms:.1f}")
        out["decode_ms"].append({"name": name, "step_ms": ms})
    print("bench,high_water_bytes,raw_demand_bytes,traffic")
    for name, hw, demand, traffic in pool_trace():
        print(f"{name},{hw},{demand},{traffic}")
        out["pool"].append({"name": name, "high_water_bytes": int(hw),
                            "raw_demand_bytes": int(demand), "traffic": traffic})
    return out


if __name__ == "__main__":
    main()
