"""Paper Figures 8/9: end-to-end compression throughput across error bounds.

CPU-proxy numbers (relative across error bounds and vs. baselines-in-repo;
the absolute GB/s claims in the paper require the target accelerator).
Includes compression AND the symmetric decompression path (§4.4 note).

Beyond the paper's figures, every (kind, eb) point now runs the three FZ
execution paths — ``reference`` (pure jnp), ``staged`` (per-stage Pallas
kernels + XLA phase 2) and ``fused`` (single-launch megakernels) — so the CI
bench tier tracks compressor throughput per PR for all of them. The returned
rows are machine-readable; ``scripts/ci.sh bench`` asserts all three paths
land in BENCH_ci.json and all three are bit-identical on the sampled field
(ratio and container bytes must agree exactly).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import baselines, fz
from repro.data import make_field
from .common import FZ_PATHS, PAPER_EBS, fz_path_config, gbps, timeit


def run(shape=(128, 128, 64), kinds=("smooth", "turbulent"), ebs=PAPER_EBS,
        paths=FZ_PATHS):
    rows = []
    for kind in kinds:
        f = jnp.asarray(make_field(kind, shape, seed=5))
        nbytes = f.size * 4
        for eb in ebs:
            used = {}
            for path in paths:
                cfg = fz_path_config(path, eb)
                comp = jax.jit(lambda x, cfg=cfg: fz.compress(x, cfg))
                c = comp(f)
                dec = jax.jit(lambda cc, cfg=cfg: fz.decompress(cc, cfg))
                t_c, t_d = timeit(comp, f), timeit(dec, c)
                cr = float(c.compression_ratio())
                used[path] = int(c.used_bytes())
                for direction, secs in (("compress", t_c), ("decompress", t_d)):
                    rows.append({
                        "pipeline": f"fz-{direction}[{kind},{eb:.0e},{path}]",
                        "kind": kind, "eb": eb, "path": path,
                        "direction": direction, "us": secs * 1e6,
                        "gbps": gbps(nbytes, secs), "ratio": cr,
                    })
            # the three paths share one oracle: exact byte agreement
            assert len(set(used.values())) == 1, \
                f"paths disagree on container bytes: {used}"
        # cuSZx-like comparison point (the paper's fastest baseline)
        ebj = jnp.float32(1e-3 * float(jnp.max(f) - jnp.min(f)))
        cx = jax.jit(lambda x: baselines.cuszx_like(x, ebj))
        t_x = timeit(cx, f)
        _, bx = cx(f)
        rows.append({"pipeline": f"cuszx-like[{kind},1e-3]", "kind": kind,
                     "eb": 1e-3, "path": "baseline", "direction": "compress",
                     "us": t_x * 1e6, "gbps": gbps(nbytes, t_x),
                     "ratio": nbytes / float(bx)})
    return rows


def main(smoke=False):
    if smoke:
        # CI preset: small field, two bounds, all three paths
        rows = run(shape=(32, 64, 32), kinds=("smooth",), ebs=(1e-2, 1e-4))
    else:
        rows = run()
    print("pipeline,us_per_call,cpu_proxy_GBps,compression_ratio")
    for r in rows:
        print(f"{r['pipeline']},{r['us']:.0f},{r['gbps']:.3f},{r['ratio']:.2f}")
    return {"rows": rows}


if __name__ == "__main__":
    main()
