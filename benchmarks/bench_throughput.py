"""Paper Figures 8/9: end-to-end compression throughput across error bounds.

CPU-proxy numbers (relative across error bounds and vs. baselines-in-repo;
the absolute GB/s claims in the paper require the target accelerator).
Includes compression AND the symmetric decompression path (§4.4 note).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import baselines, fz
from repro.data import make_field
from .common import PAPER_EBS, gbps, timeit


def run(shape=(128, 128, 64), kinds=("smooth", "turbulent")):
    rows = []
    for kind in kinds:
        f = jnp.asarray(make_field(kind, shape, seed=5))
        nbytes = f.size * 4
        for eb in PAPER_EBS:
            cfg = fz.FZConfig(eb=eb, exact_outliers=False)
            comp = jax.jit(lambda x: fz.compress(x, cfg))
            c = comp(f)
            dec = jax.jit(lambda cc: fz.decompress(cc, cfg))
            t_c = timeit(comp, f)
            t_d = timeit(dec, c)
            cr = float(c.compression_ratio())
            rows.append((f"fz-compress[{kind},{eb:.0e}]", t_c, nbytes, cr))
            rows.append((f"fz-decompress[{kind},{eb:.0e}]", t_d, nbytes, cr))
        # cuSZx-like comparison point (the paper's fastest baseline)
        ebj = jnp.float32(1e-3 * float(jnp.max(f) - jnp.min(f)))
        cx = jax.jit(lambda x: baselines.cuszx_like(x, ebj))
        t_x = timeit(cx, f)
        _, bx = cx(f)
        rows.append((f"cuszx-like[{kind},1e-3]", t_x, nbytes, nbytes / float(bx)))
    return rows


def main():
    rows = run()
    print("pipeline,us_per_call,cpu_proxy_GBps,compression_ratio")
    for name, secs, nbytes, cr in rows:
        print(f"{name},{secs * 1e6:.0f},{gbps(nbytes, secs):.3f},{cr:.2f}")
    return rows


if __name__ == "__main__":
    main()
