"""Paper Figures 8/9: end-to-end compression throughput across error bounds.

CPU-proxy numbers (relative across error bounds and vs. baselines-in-repo;
the absolute GB/s claims in the paper require the target accelerator).
Includes compression AND the symmetric decompression path (§4.4 note).

Beyond the paper's figures, every (kind, eb) point now runs the three FZ
execution paths — ``reference`` (pure jnp), ``staged`` (per-stage Pallas
kernels + XLA phase 2) and ``fused`` (single-launch megakernels) — so the CI
bench tier tracks compressor throughput per PR for all of them. The returned
rows are machine-readable; ``scripts/ci.sh bench`` asserts all three paths
land in BENCH_ci.json and all three are bit-identical on the sampled field
(ratio and container bytes must agree exactly).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import obs, tune
from repro.core import baselines, fz
from repro.data import make_field
from .common import FZ_PATHS, PAPER_EBS, fz_path_config, gbps, timeit


def run(shape=(128, 128, 64), kinds=("smooth", "turbulent"), ebs=PAPER_EBS,
        paths=FZ_PATHS):
    rows = []
    for kind in kinds:
        f = jnp.asarray(make_field(kind, shape, seed=5))
        nbytes = f.size * 4
        for eb in ebs:
            used = {}
            for path in paths:
                cfg = fz_path_config(path, eb)
                comp = jax.jit(lambda x, cfg=cfg: fz.compress(x, cfg))
                c = comp(f)
                dec = jax.jit(lambda cc, cfg=cfg: fz.decompress(cc, cfg))
                t_c, t_d = timeit(comp, f), timeit(dec, c)
                cr = float(c.compression_ratio())
                used[path] = int(c.used_bytes())
                for direction, secs in (("compress", t_c), ("decompress", t_d)):
                    rows.append({
                        "pipeline": f"fz-{direction}[{kind},{eb:.0e},{path}]",
                        "kind": kind, "eb": eb, "path": path,
                        "direction": direction, "us": secs * 1e6,
                        "gbps": gbps(nbytes, secs), "ratio": cr,
                    })
            # the three paths share one oracle: exact byte agreement
            assert len(set(used.values())) == 1, \
                f"paths disagree on container bytes: {used}"
        # cuSZx-like comparison point (the paper's fastest baseline)
        ebj = jnp.float32(1e-3 * float(jnp.max(f) - jnp.min(f)))
        cx = jax.jit(lambda x: baselines.cuszx_like(x, ebj))
        t_x = timeit(cx, f)
        _, bx = cx(f)
        rows.append({"pipeline": f"cuszx-like[{kind},1e-3]", "kind": kind,
                     "eb": 1e-3, "path": "baseline", "direction": "compress",
                     "us": t_x * 1e6, "gbps": gbps(nbytes, t_x),
                     "ratio": nbytes / float(bx)})
    return rows


def tuned(shape=(128, 128, 64), kinds=("smooth",), ebs=PAPER_EBS):
    """Tuned-dispatch rows: pre-tune in-process, then time ``path="auto"``.

    Each row records which impl the tuner selected, so the CI bench tier can
    assert the acceptance property directly: on the interpret backend the
    compress winner is never the fused megakernel (measured ~4x slower than
    staged there) and the tuned path's throughput tracks the best static
    path. Returns ``(rows, tune_summary)``; the summary's ``measured_us``
    tables are embedded in BENCH_ci.json as the selection evidence.
    """
    n = 1
    for s in shape:
        n *= s
    summary = tune.ensure_tuned([("fz.compress", n, "float32"),
                                 ("fz.decompress", n, "float32")])
    rows = []
    for kind in kinds:
        f = jnp.asarray(make_field(kind, shape, seed=5))
        nbytes = f.size * 4
        for eb in ebs:
            cfg = fz_path_config("auto", eb)
            comp = jax.jit(lambda x, cfg=cfg: fz.compress(x, cfg))
            c = comp(f)
            dec = jax.jit(lambda cc, cfg=cfg: fz.decompress(cc, cfg))
            t_c, t_d = timeit(comp, f), timeit(dec, c)
            cr = float(c.compression_ratio())
            for direction, secs in (("compress", t_c), ("decompress", t_d)):
                rows.append({
                    "pipeline": f"fz-{direction}[{kind},{eb:.0e},auto]",
                    "kind": kind, "eb": eb, "path": "auto",
                    "selected": tune.resolve_fz(direction, n, "float32"),
                    "direction": direction, "us": secs * 1e6,
                    "gbps": gbps(nbytes, secs), "ratio": cr,
                })
    return rows, summary


def obs_overhead(shape=(128, 128, 64)) -> dict:
    """Instrumentation overhead on the eager FZ entry points.

    The rows above time *jitted* callables, where spans compile to no-ops —
    their overhead is structurally zero. The eager public wrappers are where
    telemetry actually runs (span + dispatch counters around the cached
    jitted inner), so that is what gets pinned: one compress+decompress
    roundtrip timed with telemetry on vs suspended (``obs.disabled()``).
    ``scripts/ci.sh bench`` asserts ``overhead_frac`` < 5%.
    """
    f = jnp.asarray(make_field("smooth", shape, seed=5))
    cfg = fz_path_config("reference", 1e-3)
    roundtrip = lambda: fz.decompress(fz.compress(f, cfg), cfg)
    roundtrip()                       # compile both directions once
    t_on = timeit(roundtrip, iters=10)
    with obs.disabled():
        t_off = timeit(roundtrip, iters=10)
    return {"on_us": t_on * 1e6, "off_us": t_off * 1e6,
            "overhead_frac": max(t_on - t_off, 0.0) / t_off}


def main(smoke=False):
    if smoke:
        # CI preset: small field, two bounds, all three paths + tuned auto
        shape, kinds, ebs = (32, 64, 32), ("smooth",), (1e-2, 1e-4)
        rows = run(shape=shape, kinds=kinds, ebs=ebs)
    else:
        shape, kinds, ebs = (128, 128, 64), ("smooth",), PAPER_EBS
        rows = run()
    arows, tune_summary = tuned(shape=shape, kinds=kinds, ebs=ebs)
    rows = rows + arows
    print("pipeline,us_per_call,cpu_proxy_GBps,compression_ratio")
    for r in rows:
        print(f"{r['pipeline']},{r['us']:.0f},{r['gbps']:.3f},{r['ratio']:.2f}")
    oh = obs_overhead()
    print(f"obs overhead (eager wrapper): {oh['on_us']:.0f}us on vs "
          f"{oh['off_us']:.0f}us off ({oh['overhead_frac'] * 100:.2f}%)")
    return {"rows": rows, "obs_overhead": oh, "tune": tune_summary}


if __name__ == "__main__":
    main()
