"""Barrier vs bucketed compressed-gradient reduce sweep (machine-readable).

The overlap claim (dist/bucketed_reduce.py) is structural — per-bucket
compress/all_gather/decompress regions issued in backward production order
give XLA's latency-hiding scheduler something to overlap — so this bench
measures the reduce hop itself over synthetic gradient trees on the fake
multi-device CPU mesh: bucket count x leaf-size mix x pod count, barrier vs
bucketed. On this box the wall clock reflects orchestration shape (region
count, per-region work), not DCN speed; the analytic wire bytes per
configuration ride along so the trajectory stays comparable when the same
sweep runs on real multi-pod hardware.

Runs its measurement in a subprocess with 8 fake XLA CPU devices (the main
benchmark process keeps the default single-device view, like
tests/test_dist.py). Emits one JSON document; ``benchmarks/run.py
--json-out`` folds it into BENCH_ci.json, the CI perf-trajectory artifact.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

N_DEVICES = 8

# leaf-size mixes: elements per leaf of one pod's gradient tree. "uniform"
# is the homogeneous-layer case; "skewed" is the realistic embed-heavy tree
# (two dominant leaves + a tail of small ones) where bucketing decides
# whether the tail amortizes or the big leaves serialize.
MIXES = {
    "uniform": [1 << 14] * 8,
    "skewed": [1 << 16] * 2 + [1 << 12] * 8,
}
FULL_SCALE = 4                      # full mode: 4x the smoke element counts


def _child(smoke: bool) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks.common import timeit
    from repro.dist import bucketed_reduce as bkt
    from repro.dist import compat
    from repro.dist.compressed_allreduce import (GradCompressionConfig,
                                                 init_error_state,
                                                 reduce_stacked)

    assert jax.device_count() >= N_DEVICES, jax.device_count()
    scale = 1 if smoke else FULL_SCALE
    pods_sweep = (2,) if smoke else (2, 4)
    bucket_sweep = (1 << 16,) if smoke else (1 << 15, 1 << 17, 1 << 20)
    iters = 3 if smoke else 5

    rows = []
    for pods in pods_sweep:
        mesh = compat.make_mesh((pods, N_DEVICES // pods), ("pod", "data"))
        for mix_name, sizes in MIXES.items():
            rng = np.random.default_rng(0)
            g_stack = {f"leaf{i:02d}": jnp.asarray(
                np.cumsum(rng.standard_normal((pods, n * scale)), axis=1)
                .astype(np.float32) * 1e-3)
                for i, n in enumerate(sizes)}
            g_abs = jax.tree.map(
                lambda g: jax.ShapeDtypeStruct(g.shape[1:], g.dtype), g_stack)
            raw_mb = sum(4 * n * scale for n in sizes) / 1e6

            def measure(fn, err):
                jitted = jax.jit(fn)
                return timeit(jitted, g_stack, err, warmup=1, iters=iters)

            gc = GradCompressionConfig(enabled=True, min_leaf_size=1024)
            sec = measure(lambda g, e: reduce_stacked(g, e, gc, mesh),
                          init_error_state(g_abs, pods, gc))
            base = {"mix": mix_name, "pods": pods, "raw_mb": round(raw_mb, 3)}
            rows.append({**base, "mode": "barrier", "bucket_bytes": None,
                         "n_buckets": len(sizes), "seconds": sec,
                         "wire_mb": None})
            for bb in bucket_sweep:
                gcb = GradCompressionConfig(enabled=True, min_leaf_size=1024,
                                            overlap=True, bucket_bytes=bb)
                plan = bkt.assign_buckets(g_abs, gcb)
                sec = measure(
                    lambda g, e: bkt.reduce_stacked_bucketed(g, e, gcb, mesh,
                                                             plan=plan),
                    init_error_state(g_abs, pods, gcb))
                wire_mb = sum(b.wire_bytes for b in plan.buckets) / 1e6
                rows.append({**base, "mode": "bucketed", "bucket_bytes": bb,
                             "n_buckets": plan.n_buckets, "seconds": sec,
                             "wire_mb": round(wire_mb, 3)})
    return {"rows": rows, "device_count": N_DEVICES, "smoke": smoke}


def main(smoke: bool = False) -> dict:
    """Spawn the fake-device child, print a table, return the JSON dict."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={N_DEVICES}"
    env["JAX_PLATFORMS"] = env.get("JAX_PLATFORMS", "cpu")
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    root = os.path.join(os.path.dirname(__file__), "..")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.abspath(src), os.path.abspath(root)]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    cmd = [sys.executable, "-m", "benchmarks.bench_overlap", "--child"]
    if smoke:
        cmd.append("--smoke")
    r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                       timeout=1800, cwd=os.path.abspath(root))
    if r.returncode != 0:
        raise RuntimeError(f"overlap child failed:\n{r.stdout[-2000:]}\n"
                           f"{r.stderr[-2000:]}")
    out = json.loads(r.stdout.splitlines()[-1])
    print("mix,pods,mode,bucket_bytes,n_buckets,raw_mb,wire_mb,ms")
    for row in out["rows"]:
        wire = "" if row["wire_mb"] is None else f'{row["wire_mb"]}'
        bb = "" if row["bucket_bytes"] is None else str(row["bucket_bytes"])
        print(f'{row["mix"]},{row["pods"]},{row["mode"]},{bb},'
              f'{row["n_buckets"]},{row["raw_mb"]},{wire},'
              f'{row["seconds"] * 1e3:.1f}')
    return out


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--child", action="store_true",
                   help="run the measurement in-process (expects fake devices)")
    p.add_argument("--smoke", action="store_true")
    args = p.parse_args()
    if args.child:
        print(json.dumps(_child(args.smoke)))
    else:
        main(smoke=args.smoke)
