"""Shared benchmark utilities."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fz

PAPER_EBS = (1e-2, 5e-3, 1e-3, 5e-4, 1e-4)  # the paper's relative bounds

FZ_PATHS = ("reference", "staged", "fused")  # the three static execution paths


def fz_path_config(path: str, eb: float) -> fz.FZConfig:
    """One FZConfig per execution path (core/fz.py module docstring), shared
    by every benchmark so the path matrix can't silently diverge. "auto" is
    the tuned-dispatch path: use_kernels on, resolution via repro.tune."""
    if path == "auto":
        return fz.FZConfig(eb=eb, exact_outliers=False, use_kernels=True,
                           kernel_mode="auto")
    if path not in FZ_PATHS:
        raise ValueError(f"unknown FZ path {path!r}; choose from "
                         f"{FZ_PATHS + ('auto',)}")
    return fz.FZConfig(eb=eb, exact_outliers=False,
                       use_kernels=path != "reference",
                       kernel_mode=path if path != "reference" else "staged")


def timeit(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall seconds per call (jit-compiled callables)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def gbps(nbytes: int, seconds: float) -> float:
    return nbytes / max(seconds, 1e-12) / 1e9


def row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"
