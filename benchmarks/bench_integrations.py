"""Framework-integration benchmarks (the paper's §2.4 use cases, deployed):

  * gradient compression: wire bytes over the cross-pod link + convergence
    delta on a real (tiny) LM, compressed vs exact reduction;
  * KV-cache parking: in-memory ratio + decode-token agreement;
  * checkpoint compression: on-disk ratio + restore error.
"""
from __future__ import annotations

import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.ckpt import checkpoint as ckpt
from repro.core import fz
from repro.data.tokens import TokenStream
from repro.dist.compressed_allreduce import GradCompressionConfig, wire_bytes_per_leaf
from repro.models import zoo
from repro.serve import Engine, KVCompressionConfig
from repro.serve.engine import cache_bytes, compressed_cache_bytes


def grad_wire_accounting():
    rows = []
    for n in (1 << 16, 1 << 20, 1 << 24):
        acc = wire_bytes_per_leaf(n, GradCompressionConfig(capacity_frac=0.75))
        rows.append((f"gradwire[n={n}]", acc["raw"], acc["compressed"], acc["reduction"]))
    return rows


def kv_parking(arch="glm4-9b", S=64, B=2, n_tokens=6):
    cfg = configs.get(arch, smoke=True)
    model = zoo.build(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S), dtype=np.int32))}
    eng_plain = Engine(model, params)
    eng_comp = Engine(model, params,
                      kv_compress=KVCompressionConfig(enabled=True, eb=1e-4, min_leaf_size=1024))
    t1, cache = eng_plain.generate(batch, n_tokens)
    t2, _ = eng_comp.generate(batch, n_tokens, park_between=True)
    parked = eng_comp.park(cache)
    ratio = cache_bytes(cache) / compressed_cache_bytes(parked)
    agree = float(jnp.mean((t1 == t2).astype(jnp.float32)))
    return [("kv-parking", ratio, agree)]


def ckpt_compression(arch="yi-6b"):
    """Two regimes: random-init weights are near-incompressible at eb=1e-5
    (honest worst case — high-entropy mantissas), while smooth/correlated
    state (trained weights, EMA moments, fields) compresses well."""
    cfg = configs.get(arch, smoke=True)
    model = zoo.build(cfg)
    params = model.init(jax.random.key(0))
    rows = []
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 0, {"params": params}, codec="fz")
        rep = ckpt.compression_report(d, 0)
        restored, _ = ckpt.restore(d, {"params": params})
        errs = jax.tree.map(
            lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
            {"params": params}, restored)
        rows.append(("ckpt-fz-random-init", rep["ratio"], max(jax.tree.leaves(errs))))
    import numpy as np
    rng = np.random.default_rng(0)
    smooth = {f"m{i}": jnp.asarray(
        np.cumsum(rng.standard_normal((256, 512)).astype(np.float32), axis=1) * 1e-3)
        for i in range(4)}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 0, smooth, codec="fz")
        rep = ckpt.compression_report(d, 0)
        restored, _ = ckpt.restore(d, smooth)
        err = max(float(jnp.max(jnp.abs(a - b))) for a, b in
                  zip(jax.tree.leaves(smooth), jax.tree.leaves(restored)))
        rows.append(("ckpt-fz-smooth-state", rep["ratio"], err))
    return rows


def grad_convergence(steps=8):
    """Tiny LM: loss trajectory, compressed-with-error-feedback vs exact."""
    cfg = configs.get("yi-6b", smoke=True)
    model = zoo.build(cfg)
    stream = TokenStream(vocab_size=cfg.vocab, seq_len=32, global_batch=4, seed=1)
    from repro.optim import AdamWConfig, adamw_init, adamw_update

    def run(compress: bool):
        params = model.init(jax.random.key(0))
        opt = adamw_init(params)
        fzc = fz.FZConfig(eb=1e-4, eb_mode="rel", exact_outliers=False)
        losses = []
        for s in range(steps):
            arr = stream.shard_batch(s, 0, 1)
            batch = {"tokens": jnp.asarray(arr[:, :-1]), "labels": jnp.asarray(arr[:, 1:])}
            loss, g = jax.value_and_grad(lambda p: model.train_loss(p, batch)[0])(params)
            if compress:
                g = jax.tree.map(
                    lambda x: fz.decompress(fz.compress(x.astype(jnp.float32).reshape(-1), fzc), fzc)
                    .reshape(x.shape).astype(x.dtype) if x.size >= 4096 else x, g)
            params, opt = adamw_update(g, opt, jnp.float32(3e-4), AdamWConfig(), params)
            losses.append(float(loss))
        return losses

    exact = run(False)
    comp = run(True)
    return [("gradconv-exact-final", exact[-1], exact[0]),
            ("gradconv-compressed-final", comp[-1], comp[0])]


def main():
    print("integration,metric1,metric2[,metric3]")
    for name, raw, compressed, red in grad_wire_accounting():
        print(f"{name},{raw},{compressed},{red:.2f}x")
    for name, ratio, agree in kv_parking():
        print(f"{name},{ratio:.2f}x,{agree:.3f}")
    for name, ratio, err in ckpt_compression():
        print(f"{name},{ratio:.2f}x,{err:.2e}")
    for name, final, first in grad_convergence():
        print(f"{name},{final:.4f},{first:.4f}")


if __name__ == "__main__":
    main()
